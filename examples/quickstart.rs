//! Quickstart: quantize one layer with GANQ and compare the layer-wise
//! output error against RTN/GPTQ — the paper's §3 story in 60 lines.
//!
//!     cargo run --release --example quickstart

use ganq::quant;
use ganq::tensor::{linalg, Mat};
use ganq::util::rng::Rng;
use ganq::util::timer::{fmt_f, Table};

fn main() {
    // A synthetic "linear layer": heavy-tailed weights (the Fig. 1(b)
    // situation) + correlated calibration activations.
    let (m, n, p) = (256, 128, 512);
    let mut rng = Rng::new(0xC0FFEE);
    let mut w = Mat::from_vec(m, n, rng.normal_vec_f32(m * n));
    for i in 0..m {
        // a few outliers per row stretch the uniform-quantization range
        for _ in 0..2 {
            let j = rng.below(n as u64) as usize;
            w[(i, j)] = 8.0 * rng.normal() as f32;
        }
    }
    let x = Mat::from_vec(n, p, rng.normal_vec_f32(n * p));
    let h = x.gram();
    let hp = linalg::precondition(&h);

    println!("layer: W[{m}x{n}], calibration X[{n}x{p}]");
    let mut table = Table::new(
        "layer-wise output error  ||WX - What X||_F^2  (lower is better)",
        &["method", "4-bit", "3-bit", "storage % of fp16 (4-bit)"],
    );
    for method in ["rtn", "gptq", "omniq", "squeezellm", "ganq", "ganq-star"] {
        let mut row = vec![method.to_string()];
        let mut storage = String::new();
        for bits in [4u8, 3] {
            let q = quant::by_name(method, bits).unwrap();
            let t0 = std::time::Instant::now();
            let r = q.quantize(&w, &h);
            let err = linalg::layer_error(&w, &r.w_hat, &hp);
            row.push(format!(
                "{} ({:.2}s)",
                fmt_f(err, 1),
                t0.elapsed().as_secs_f64()
            ));
            if bits == 4 {
                storage = format!(
                    "{:.2}%",
                    100.0 * r.storage.ratio_vs_fp16(m, n)
                );
            }
        }
        row.push(storage);
        table.row(row);
    }
    table.print();

    // The LUT form is what serves: show a dequant-free matmul.
    let r = quant::by_name("ganq", 4).unwrap().quantize(&w, &h);
    let lut = r.lut.expect("ganq is LUT-servable");
    let xt = Mat::from_vec(4, n, rng.normal_vec_f32(4 * n));
    let y = lut.lut_matmul(&xt);
    let y_ref = xt.matmul_tb(&r.w_hat);
    let maxdiff = y
        .data
        .iter()
        .zip(&y_ref.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "\nLUT-mpGEMM vs dense reconstruction: max |diff| = {maxdiff:.2e} \
         (dequantization-free inference, Fig. 1(a) right)"
    );
    println!(
        "weight bytes streamed per token: {} (fp32 would be {})",
        lut.bytes_per_decode(),
        m * n * 4
    );
}
