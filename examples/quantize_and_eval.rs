//! Full PTQ pipeline on a trained model: calibrate -> quantize with each
//! method -> perplexity on all three corpora. The Table-2-in-miniature
//! driver. Requires `make artifacts`.
//!
//!     cargo run --release --example quantize_and_eval -- \
//!         --model opt-small --bits 3 --batches 2

use ganq::coordinator::{self, QuantEngine};
use ganq::data::corpus::{self, Split};
use ganq::eval::{perplexity, PplEngine};
use ganq::model::forward::Weights;
use ganq::model::WeightStore;
use ganq::runtime::Runtime;
use ganq::util::cli::Args;
use ganq::util::timer::{fmt_f, Table};

fn main() {
    let args = Args::from_env();
    let model = args.get_or("model", "opt-small").to_string();
    let bits = args.get_usize("bits", 3) as u8;
    let batches = args.get_usize("batches", 2);

    let rt = match Runtime::load() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifacts required: {} (run `make artifacts`)", e);
            std::process::exit(1);
        }
    };
    let cfg = rt.manifest.models[&model].config;
    let store = WeightStore::load(&rt.base, &model, cfg)
        .expect("trained weights in artifacts/");

    eprintln!("calibrating on c4s (paper: C4 first shard) ...");
    let calib = coordinator::calibrate(&store, 32, 128);

    let flavors = ["wiki2s", "c4s", "ptbs"];
    let mut table = Table::new(
        &format!("perplexity, {} @ {}-bit (HLO nll graph)", model, bits),
        &["method", "wiki2s", "c4s", "ptbs", "quant time"],
    );

    // FP16 baseline row
    {
        let mut eng = PplEngine::hlo(&rt, &model, &store, None)
            .unwrap_or_else(|_| PplEngine::native(Weights::Fp(&store)));
        let mut row = vec!["full (fp)".to_string()];
        for f in flavors {
            let fl = corpus::flavor(f).unwrap();
            let ppl =
                perplexity(&mut eng, fl, Split::Valid, batches).unwrap();
            row.push(fmt_f(ppl, 3));
        }
        row.push("-".into());
        table.row(row);
    }

    for method in ["rtn", "gptq", "omniq", "ganq", "ganq-star"] {
        let t0 = std::time::Instant::now();
        let qm = coordinator::quantize_model(
            &store,
            method,
            bits,
            &calib,
            &QuantEngine::Hlo(&rt),
            false,
        )
        .expect("quantize");
        let dt = t0.elapsed().as_secs_f64();
        let mut eng = PplEngine::hlo(&rt, &model, &store, Some(&qm))
            .unwrap_or_else(|_| PplEngine::native(Weights::Quant(&qm)));
        let mut row = vec![method.to_string()];
        for f in flavors {
            let fl = corpus::flavor(f).unwrap();
            let ppl =
                perplexity(&mut eng, fl, Split::Valid, batches).unwrap();
            row.push(fmt_f(ppl, 3));
        }
        row.push(format!("{:.1}s", dt));
        table.row(row);
    }
    table.print();
    println!(
        "\nexpected shape (paper Table 2): full < ganq < omniq/gptq < rtn"
    );
}
