//! Decode-throughput bench: one batched `Engine::step` vs per-sequence
//! single-item steps at batch 1/4/16 for fp32 / 4-bit LUT / 3-bit LUT
//! on the micro model, plus the packed-code kernel vs the unpacked LUT
//! matmul at batch 1. Emits `BENCH_decode.json` so the decode perf
//! trajectory is tracked.
//!
//! Asserts the acceptance criteria: batch=16 batched decode on the
//! LUT-quantized model is >= 2x the tokens/sec of 16 per-sequence
//! steps, and the packed kernel is no slower than the unpacked path at
//! batch 1. `GANQ_SMOKE=1` shrinks the run for CI and relaxes the
//! throughput bar to >= 1x (shared runners are noisy).

use std::time::Instant;

use ganq::model::forward::{Engine, KvCache, KvSeq, SeqRefs, Weights};
use ganq::model::{LayerWeights, ModelConfig, QuantizedModel, WeightStore};
use ganq::obs::hist::Samples;
use ganq::quant::ganq::fit_codebook_identity;
use ganq::quant::lut::lut_from_parts;
use ganq::quant::PackedLut;
use ganq::tensor::Mat;
use ganq::util::json::{self, Json};
use ganq::util::rng::Rng;
use ganq::util::timer::{bench_for, Table};

const PREFILL: usize = 8;

fn smoke() -> bool {
    std::env::var("GANQ_SMOKE").is_ok()
}

/// Quantize every linear to a per-row non-uniform LUT (identity
/// Hessian) — the servable form the batched engine packs.
fn lut_model(store: &WeightStore, bits: u8) -> QuantizedModel {
    let k = 1usize << bits;
    let mut linears = std::collections::BTreeMap::new();
    for (name, _m, _n) in store.cfg.linear_shapes() {
        let w = store.mat(&name);
        let mut codes = vec![0u8; w.rows * w.cols];
        let mut cb = Mat::zeros(w.rows, k);
        for i in 0..w.rows {
            let (c, t) = fit_codebook_identity(w.row(i), bits, 2);
            codes[i * w.cols..(i + 1) * w.cols].copy_from_slice(&c);
            cb.row_mut(i).copy_from_slice(&t);
        }
        linears.insert(
            name,
            LayerWeights::Lut(lut_from_parts(
                w.rows, w.cols, bits, codes, cb,
            )),
        );
    }
    QuantizedModel {
        base: store.clone(),
        method: format!("lut{}-identity", bits),
        bits,
        linears,
        weight_bits: 0,
    }
}

/// Wall seconds for `steps` batched decode steps over `b` sequences
/// (fresh caches, `PREFILL` unmeasured warmup tokens per sequence).
fn run_batched(w: &Weights, b: usize, steps: usize) -> f64 {
    let cfg = w.store().cfg;
    let mut caches = vec![KvCache::new(cfg); b];
    let mut engine = Engine::new(w);
    let mut step = |s: usize, caches: &mut [KvCache]| {
        let toks: Vec<i32> =
            (0..b).map(|i| ((11 * i + s) % 256) as i32).collect();
        let mut refs: Vec<&mut dyn KvSeq> = caches
            .iter_mut()
            .map(|c| c as &mut dyn KvSeq)
            .collect();
        engine.decode_batch(&toks, &mut SeqRefs(&mut refs));
    };
    for s in 0..PREFILL {
        step(s, &mut caches);
    }
    let t0 = Instant::now();
    for s in 0..steps {
        step(PREFILL + s, &mut caches);
    }
    t0.elapsed().as_secs_f64()
}

/// Wall seconds for the same token schedule fed as `b` independent
/// single-sequence engine steps per step (the pre-batching path: each
/// sequence streams the full weight set on its own).
fn run_sequential(w: &Weights, b: usize, steps: usize) -> f64 {
    let cfg = w.store().cfg;
    let mut caches = vec![KvCache::new(cfg); b];
    let mut engine = Engine::new(w);
    let mut one = |tok: i32, c: &mut KvCache| {
        let mut refs: Vec<&mut dyn KvSeq> = vec![c];
        engine.decode_batch(&[tok], &mut SeqRefs(&mut refs));
    };
    for s in 0..PREFILL {
        for (i, c) in caches.iter_mut().enumerate() {
            one(((11 * i + s) % 256) as i32, c);
        }
    }
    let t0 = Instant::now();
    for s in 0..steps {
        for (i, c) in caches.iter_mut().enumerate() {
            one(((11 * i + PREFILL + s) % 256) as i32, c);
        }
    }
    t0.elapsed().as_secs_f64()
}

/// Best-of-`reps` tokens/sec for both paths.
fn measure(w: &Weights, b: usize, steps: usize, reps: usize) -> (f64, f64) {
    let tokens = (b * steps) as f64;
    let mut batched = Samples::new();
    let mut sequential = Samples::new();
    for _ in 0..reps {
        batched.push(run_batched(w, b, steps));
        sequential.push(run_sequential(w, b, steps));
    }
    (tokens / batched.min(), tokens / sequential.min())
}

fn main() {
    let cfg = ModelConfig::builtin("opt-micro").unwrap();
    let store = WeightStore::random("bench", cfg, 411);
    let qm4 = lut_model(&store, 4);
    let qm3 = lut_model(&store, 3);
    let (steps, reps) = if smoke() { (8, 1) } else { (40, 3) };
    println!(
        "opt-micro decode throughput, {} timed steps (+{} prefill), \
         best of {} rep(s){}",
        steps,
        PREFILL,
        reps,
        if smoke() { " [smoke]" } else { "" }
    );

    let mut t = Table::new(
        "batched engine step vs per-sequence steps",
        &["fmt", "batch", "batched tok/s", "sequential tok/s", "speedup"],
    );
    let mut rows = Vec::new();
    let mut lut4_b16_speedup = 0.0f64;
    for (fmt, w) in [
        ("fp32", Weights::Fp(&store)),
        ("lut4", Weights::Quant(&qm4)),
        ("lut3", Weights::Quant(&qm3)),
    ] {
        for b in [1usize, 4, 16] {
            let (tb, ts) = measure(&w, b, steps, reps);
            let speedup = tb / ts;
            if fmt == "lut4" && b == 16 {
                lut4_b16_speedup = speedup;
            }
            t.row(vec![
                fmt.into(),
                format!("{}", b),
                format!("{:.0}", tb),
                format!("{:.0}", ts),
                format!("{:.2}x", speedup),
            ]);
            rows.push(json::obj(vec![
                ("fmt", json::s(fmt)),
                ("batch", json::num(b as f64)),
                ("batched_tok_s", json::num(tb)),
                ("sequential_tok_s", json::num(ts)),
                ("speedup", json::num(speedup)),
            ]));
        }
    }
    t.print();

    // packed-code kernel vs unpacked LUT matmul at batch 1, on the two
    // micro linear shapes (d x d and ff x d)
    let mut kernel_rows = Vec::new();
    let mut kt = Table::new(
        "packed vs unpacked LUT kernel (p=1)",
        &["shape", "bits", "unpacked us", "packed us"],
    );
    let mut packed_ok = true;
    for (m, n) in [(cfg.d, cfg.d), (cfg.ff, cfg.d)] {
        for bits in [4u8, 3] {
            let mut rng = Rng::new(5 + m as u64 + bits as u64);
            let k = 1usize << bits;
            let codes: Vec<u8> =
                (0..m * n).map(|_| rng.below(k as u64) as u8).collect();
            let cb = Mat::from_vec(m, k, rng.normal_vec_f32(m * k));
            let lut = lut_from_parts(m, n, bits, codes, cb);
            let pl = PackedLut::pack(&lut);
            let x = Mat::from_vec(1, n, rng.normal_vec_f32(n));
            let budget = if smoke() { 0.05 } else { 0.25 };
            let s_unpacked = bench_for(budget, 2000, || {
                let _ = lut.lut_matmul(&x);
            });
            let s_packed = bench_for(budget, 2000, || {
                let _ = pl.matmul(&x);
            });
            if s_packed.p50_s > s_unpacked.p50_s * 1.5 {
                packed_ok = false;
            }
            kt.row(vec![
                format!("{}x{}", m, n),
                bits.to_string(),
                format!("{:.1}", s_unpacked.mean_us()),
                format!("{:.1}", s_packed.mean_us()),
            ]);
            kernel_rows.push(json::obj(vec![
                ("m", json::num(m as f64)),
                ("n", json::num(n as f64)),
                ("bits", json::num(bits as f64)),
                ("unpacked_us", json::num(s_unpacked.mean_us())),
                ("packed_us", json::num(s_packed.mean_us())),
            ]));
        }
    }
    kt.print();

    let out = json::obj(vec![
        ("model", json::s("opt-micro")),
        ("steps", json::num(steps as f64)),
        ("prefill", json::num(PREFILL as f64)),
        ("smoke", Json::Bool(smoke())),
        ("decode", Json::Arr(rows)),
        ("kernel_p1", Json::Arr(kernel_rows)),
    ]);
    std::fs::write("BENCH_decode.json", out.to_string_pretty())
        .expect("write BENCH_decode.json");
    println!("\nwrote BENCH_decode.json");

    let bar = if smoke() { 1.0 } else { 2.0 };
    assert!(
        lut4_b16_speedup >= bar,
        "acceptance FAILED: lut4 batch=16 batched/sequential = {:.2}x \
         (need >= {:.1}x)",
        lut4_b16_speedup,
        bar
    );
    assert!(
        packed_ok,
        "acceptance FAILED: packed kernel slower than unpacked at p=1"
    );
    println!(
        "acceptance OK: lut4 batch=16 batched decode is {:.2}x sequential; \
         packed kernel holds at p=1",
        lut4_b16_speedup
    );
}
