//! Self-speculative decoding bench: greedy decode throughput of
//! `SpecBackend` (lut2 drafts, lut4 verify, one shared bit-plane store)
//! vs plain greedy decode of the same nested model at batch 1-4, plus
//! acceptance rate by draft width and a paged-KV exact-match sanity
//! pass. The exact-match property is asserted on every run — a speedup
//! that changes output would be a bug, not a win. Emits
//! `BENCH_speculative.json`. Acceptance: speculative decode >= 1.8x
//! plain greedy tokens/s at every batch (smoke-relaxed to >= 0.9x:
//! tiny models underutilize the weight-stream amortization the round
//! depends on).
//!
//! The model is built *draft-faithful*: per-row codebooks where the two
//! low code bits only add a tiny perturbation to the value chosen by
//! the top two bits, so the nested width-2 merge lands almost exactly
//! on the width-4 values and the draft's argmax usually survives
//! verification — the high-acceptance regime the speedup math needs
//! (round cost k*frac2 + 1 weight streams for k+1 tokens, vs k+1
//! streams for plain decode).

use ganq::coordinator::{
    serve, GenRequest, KvStoreKind, NativeBackend, SpecBackend,
    SpecOptions,
};
use ganq::model::forward::Weights;
use ganq::model::{
    LayerWeights, ModelConfig, QuantizedModel, WeightStore,
};
use ganq::quant::lut::lut_from_parts;
use ganq::quant::BitPlaneStore;
use ganq::tensor::Mat;
use ganq::util::json::{self, Json};
use ganq::util::rng::Rng;

fn smoke() -> bool {
    std::env::var("GANQ_SMOKE").is_ok()
}

/// Nested any-precision model whose low-width slices agree with the
/// max-width model: row codebooks `t[c] = base[c>>2] + eps*(c&3)`, so
/// the count-weighted width-2 merge is `base + O(eps)`.
fn draft_faithful_model(model: &str, seed: u64) -> QuantizedModel {
    let cfg = ModelConfig::builtin(model).unwrap();
    let store = WeightStore::random("bench", cfg, seed);
    let mut rng = Rng::new(seed ^ 0xdf);
    let mut linears = std::collections::BTreeMap::new();
    for (name, m, n) in store.cfg.linear_shapes() {
        let codes: Vec<u8> =
            (0..m * n).map(|_| rng.below(16) as u8).collect();
        let mut cb = Mat::zeros(m, 16);
        for i in 0..m {
            let base: Vec<f32> = rng
                .normal_vec_f32(4)
                .into_iter()
                .map(|v| v * 0.08)
                .collect();
            for c in 0..16 {
                cb.row_mut(i)[c] =
                    base[c >> 2] + 1e-4 * (c & 3) as f32;
            }
        }
        let parent = lut_from_parts(m, n, 4, codes, cb);
        linears.insert(
            name,
            LayerWeights::AnyPrec(BitPlaneStore::nest(&parent, &[2, 3, 4])),
        );
    }
    QuantizedModel {
        base: store,
        method: "ganq-anyprec".into(),
        bits: 4,
        linears,
        weight_bits: 0,
    }
}

fn reqs(n: usize, max_new: usize) -> Vec<GenRequest> {
    (0..n)
        .map(|i| {
            GenRequest::greedy(
                i as u64 + 1,
                vec![5 + i as i32, 11, 3 + 2 * i as i32, 8],
                max_new,
            )
        })
        .collect()
}

fn main() {
    let model = if smoke() { "opt-mini" } else { "opt-med" };
    let max_new = if smoke() { 24 } else { 48 };
    let qm = draft_faithful_model(model, 413);
    let so = SpecOptions::new(2, 8);
    let frac2 = qm
        .linears
        .values()
        .find_map(|lw| match lw {
            LayerWeights::AnyPrec(b) => Some(b.draft_cost_frac(2)),
            _ => None,
        })
        .expect("nested linears");
    println!(
        "model {} (draft-faithful), max_new {}, draft width 2 streams \
         {:.2}x the verify bytes",
        model, max_new, frac2
    );

    // -- throughput: plain greedy vs speculative greedy, batch 1-4 --
    let mut rows = Vec::new();
    let mut min_speedup = f64::INFINITY;
    for batch in [1usize, 2, 3, 4] {
        let mut plain = NativeBackend::new(Weights::Quant(&qm), batch);
        let (want, mp) = serve(&mut plain, reqs(batch, max_new)).unwrap();
        let mut spec = SpecBackend::dense(&qm, batch, so).expect("backend");
        let (got, ms) = serve(&mut spec, reqs(batch, max_new)).unwrap();
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(
                w.tokens, g.tokens,
                "speculative output diverged from plain greedy (batch \
                 {}, req {})",
                batch, w.id
            );
            assert_eq!(w.finish, g.finish);
        }
        let (tp, ts) = (mp.tokens_per_s(), ms.tokens_per_s());
        let speedup = ts / tp;
        min_speedup = min_speedup.min(speedup);
        println!(
            "batch {}: plain {:.0} tok/s, speculative {:.0} tok/s \
             ({:.2}x), acceptance {:.2}, {} rounds",
            batch,
            tp,
            ts,
            speedup,
            ms.acceptance_rate(),
            ms.spec_rounds
        );
        rows.push(json::obj(vec![
            ("batch", json::num(batch as f64)),
            ("plain_tok_s", json::num(tp)),
            ("spec_tok_s", json::num(ts)),
            ("speedup", json::num(speedup)),
            ("acceptance_rate", json::num(ms.acceptance_rate())),
            ("spec_rounds", json::num(ms.spec_rounds as f64)),
        ]));
    }

    // -- acceptance rate by draft width --
    let mut acc_rows = Vec::new();
    let mut rate2 = 0.0f64;
    for width in [2u8, 3] {
        let mut spec = SpecBackend::dense(
            &qm,
            4,
            SpecOptions::new(width, 8),
        )
        .expect("backend");
        let (_, m) = serve(&mut spec, reqs(4, max_new)).unwrap();
        let rate = m.acceptance_rate();
        if width == 2 {
            rate2 = rate;
        }
        println!(
            "draft width {}: acceptance {:.3} ({} drafted, {} accepted)",
            width, rate, m.draft_tokens, m.accepted_tokens
        );
        acc_rows.push(json::obj(vec![
            ("draft_width", json::num(width as f64)),
            ("acceptance_rate", json::num(rate)),
            ("draft_tokens", json::num(m.draft_tokens as f64)),
            ("accepted_tokens", json::num(m.accepted_tokens as f64)),
        ]));
    }

    // -- paged-KV sanity: same exact-match property on F32 blocks --
    let mut plain = NativeBackend::new(Weights::Quant(&qm), 4);
    let (want, _) = serve(&mut plain, reqs(4, max_new)).unwrap();
    let mut paged =
        SpecBackend::paged(&qm, 4, 16, 256, KvStoreKind::F32, so)
            .expect("backend");
    let (got, _) = serve(&mut paged, reqs(4, max_new)).unwrap();
    for (w, g) in want.iter().zip(&got) {
        assert_eq!(
            w.tokens, g.tokens,
            "paged speculative output diverged (req {})",
            w.id
        );
    }
    println!("paged F32 exact-match: ok");

    let bar = if smoke() { 0.9 } else { 1.8 };
    let out = json::obj(vec![
        ("model", json::s(model)),
        ("smoke", Json::Bool(smoke())),
        ("draft_width", json::num(so.draft_width as f64)),
        ("draft_len", json::num(so.draft_len as f64)),
        ("max_new", json::num(max_new as f64)),
        ("draft_cost_frac_w2", json::num(frac2)),
        ("batches", Json::Arr(rows)),
        ("acceptance", Json::Arr(acc_rows)),
        ("speedup_min", json::num(min_speedup)),
        ("speedup_bar", json::num(bar)),
    ]);
    std::fs::write("BENCH_speculative.json", out.to_string_pretty())
        .expect("write BENCH_speculative.json");
    println!("\nwrote BENCH_speculative.json");

    assert!(
        min_speedup >= bar,
        "acceptance FAILED: speculative decode {:.2}x plain greedy at \
         the worst batch, below the {:.1}x bar",
        min_speedup,
        bar
    );
    if !smoke() {
        assert!(
            rate2 >= 0.5,
            "acceptance FAILED: lut2-draft acceptance rate {:.2} < 0.5 \
             on a draft-faithful model — the verify loop is rejecting \
             drafts it should accept",
            rate2
        );
    }
    println!(
        "acceptance OK: speculative >= {:.2}x plain greedy at every \
         batch (bar {:.1}x), lut2 acceptance {:.2}",
        min_speedup, bar, rate2
    );
}
