//! Table 1: storage of FP16 vs basic per-channel uniform vs LUT-based
//! non-uniform quantization — analytic at the paper's sizes, plus measured
//! storage of our actual quantized models.

use ganq::bench::BenchCtx;
use ganq::model::storage;
use ganq::util::timer::Table;

fn main() {
    let mut t = Table::new(
        "Table 1: storage vs FP16 (4-bit, per-channel) — paper sizes",
        &["config", "full (fp16)", "basic uniform", "lut-based"],
    );
    for (mn, label) in [
        (2048usize, "m=n=2048 (OPT-1.3B Wq)"),
        (4096, "m=n=4096 (LLaMA-2-7B Wq)"),
        (8192, "m=n=8192 (LLaMA-2-70B Wq)"),
    ] {
        t.row(vec![
            label.to_string(),
            "100.00%".to_string(),
            format!(
                "{:.2}%",
                storage::pct_of_fp16(storage::uniform_bits(mn, mn, 4), mn, mn)
            ),
            format!(
                "{:.2}%",
                storage::pct_of_fp16(storage::lut_bits(mn, mn, 4), mn, mn)
            ),
        ]);
    }
    t.print();
    println!("paper: 25.10/25.78, 25.05/25.39, 25.02/25.20 — exact match expected (same formula).");

    // measured on our models
    let ctx = BenchCtx::load();
    let mut t2 = Table::new(
        "measured whole-model weight memory (GANQ)",
        &["model", "fp16 MiB", "4-bit MiB", "3-bit MiB"],
    );
    for model in ["opt-micro", "opt-small", "opt-med"] {
        let Some(store) = ctx.store(model) else { continue };
        let calib = ctx.calibrate(&store, 8);
        let mut cells = vec![
            model.to_string(),
            format!(
                "{:.2}",
                storage::fp16_model_bytes(&store.cfg) as f64 / (1 << 20) as f64
            ),
        ];
        for bits in [4u8, 3] {
            let qm = ctx.quantize(&store, &calib, "ganq", bits);
            cells.push(format!(
                "{:.2}",
                storage::model_weight_bytes(&qm) as f64 / (1 << 20) as f64
            ));
        }
        t2.row(cells);
    }
    t2.print();
}
