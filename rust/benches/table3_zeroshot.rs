//! Table 3: zero-shot accuracy on six likelihood-scored tasks for the
//! quantized opt-small model (LLaMA-2-7B analogue), 4-bit and 3-bit.
//! Expected shape: GANQ mean closest to FP; RTN collapses at 3-bit.

use ganq::bench::BenchCtx;
use ganq::eval::tasks::zero_shot_suite;
use ganq::model::forward::Weights;
use ganq::util::cli::Args;
use ganq::util::timer::Table;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.get_or("model", "opt-small").to_string();
    let cases = args.get_usize("cases", 30);
    let ctx = BenchCtx::load();
    let Some(store) = ctx.store(&model) else { return };
    let calib = ctx.calibrate(&store, 32);

    let task_names: Vec<String> = ganq::data::tasks::PAIR_TASKS
        .iter()
        .map(|t| t.name().to_string())
        .collect();
    let mut headers: Vec<&str> = vec!["method", "bits"];
    headers.extend(task_names.iter().map(|s| s.as_str()));
    headers.push("mean");
    let mut t = Table::new(
        &format!("Table 3: zero-shot accuracies (%), {}", model),
        &headers,
    );

    let mut add_row = |label: &str, bits: u8, w: &Weights| {
        let (rows, mean) = zero_shot_suite(w, cases, 5);
        let mut cells = vec![label.to_string(), bits.to_string()];
        for (_, acc) in &rows {
            cells.push(format!("{:.1}", acc));
        }
        cells.push(format!("{:.2}", mean));
        t.row(cells);
    };

    add_row("full", 16, &Weights::Fp(&store));
    for bits in [4u8, 3] {
        for method in ["rtn", "gptq", "omniq", "ganq"] {
            let qm = ctx.quantize(&store, &calib, method, bits);
            add_row(method, bits, &Weights::Quant(&qm));
        }
    }
    t.print();
    println!("\npaper shape: GANQ ~= full at 4-bit; clearly best at 3-bit.");
}
