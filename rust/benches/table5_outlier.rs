//! Table 5: weight-only quantization WITH outlier handling — grouped
//! uniform baselines (g128), SqueezeLLM-like, and GANQ* (GANQ + sparse
//! outlier split). wiki2s perplexity.

use ganq::bench::{ppl_grid, print_ppl_table, BenchCtx};
use ganq::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let batches = args.get_usize("batches", 1);
    let default_models = "opt-micro,opt-mini,opt-small".to_string();
    let models_arg = args.get_or("models", &default_models).to_string();
    let models: Vec<&str> = models_arg.split(',').collect();
    let ctx = BenchCtx::load();
    // note: group 128 on our layer widths (128-768 cols) still subdivides
    // the wider mlp rows; on d=128 attention mats it equals per-channel
    let rows = ppl_grid(
        &ctx,
        &models,
        &["rtn-g128", "gptq-g128", "awq-g128", "omniq-g128", "squeezellm", "ganq-star"],
        "wiki2s",
        batches,
    );
    print_ppl_table(
        "Table 5: wiki2s perplexity with outlier handling",
        &models,
        &rows,
    );
    println!("\npaper shape: GANQ* lowest, SqueezeLLM second.");
}
