//! Table 6: generation latency / speedup / weight memory — FP32 serving
//! graph vs GANQ LUT graphs (4-bit, 3-bit) and GANQ* (dense+sparse via the
//! native path). Single sequence (batch 1), long generation, matching the
//! paper's profiling protocol scaled to our context window.
//!
//! The paper's speedup comes from memory-bound weight traffic on GPU; the
//! hardware-independent column here is weights-MiB/step (exact), alongside
//! measured CPU wall-clock (PJRT CPU executes f32 compute either way, so
//! wall-clock gains are modest — see EXPERIMENTS.md discussion).

use ganq::bench::BenchCtx;
use ganq::coordinator::{self, GenRequest, WeightFmt};
use ganq::model::forward::Weights;
use ganq::util::cli::Args;
use ganq::util::timer::Table;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let max_new = args.get_usize("max-new", 96);
    let default_models = "opt-small,opt-med".to_string();
    let models_arg = args.get_or("models", &default_models).to_string();
    let models: Vec<&str> = models_arg.split(',').collect();
    let ctx = BenchCtx::load();
    let Some(rt) = ctx.rt.as_ref() else {
        eprintln!("table 6 requires artifacts");
        return;
    };

    for model in models {
        let Some(store) = ctx.store(model) else { continue };
        let calib = ctx.calibrate(&store, 16);
        let qm4 = ctx.quantize(&store, &calib, "ganq", 4);
        let qm3 = ctx.quantize(&store, &calib, "ganq", 3);
        let qms4 = ctx.quantize(&store, &calib, "ganq-star", 4);

        let mut t = Table::new(
            &format!(
                "Table 6: {} — 1 x {}-token generation (HLO serving graphs)",
                model, max_new
            ),
            &[
                "method",
                "bits",
                "time (s)",
                "speedup",
                "tok/s",
                "weights MiB/step",
                "traffic reduction",
            ],
        );
        let req = || {
            vec![GenRequest::greedy(
                1,
                b"once upon a time ".iter().map(|&b| b as i32).collect(),
                max_new,
            )]
        };
        let mut base_time = None;
        let mut base_bytes = None;
        let mut run = |label: &str,
                       bits: &str,
                       be: &mut dyn coordinator::DecodeBackend| {
            // warmup: compile + first-dispatch outside the timed region
            let warm = vec![GenRequest::greedy(0, vec![32], 2)];
            let _ = coordinator::serve(be, warm).expect("warmup");
            let (_r, m) = coordinator::serve(be, req()).expect("serve");
            let time = m.wall_s;
            let bytes = m.weight_bytes_per_step;
            let speedup = base_time.map(|b: f64| b / time).unwrap_or(1.0);
            let red = base_bytes
                .map(|b: usize| b as f64 / bytes as f64)
                .unwrap_or(1.0);
            if base_time.is_none() {
                base_time = Some(time);
                base_bytes = Some(bytes);
            }
            t.row(vec![
                label.to_string(),
                bits.to_string(),
                format!("{:.2}", time),
                format!("{:.2}x", speedup),
                format!("{:.1}", m.tokens_per_s()),
                format!("{:.2}", bytes as f64 / (1 << 20) as f64),
                format!("{:.2}x", red),
            ]);
        };

        // default path: literal arguments (measured FASTER than staged
        // device buffers at our sizes — execute_b adds per-buffer
        // overheads that outweigh re-converting <1 MiB of packed weights;
        // see EXPERIMENTS.md §Perf iteration log)
        let mut be = coordinator::HloBackend::new(
            rt, model, WeightFmt::Fp32, 1, &store, None, false,
        )
        .expect("fp32 backend");
        run("Full", "32", &mut be);
        let mut be4 = coordinator::HloBackend::new(
            rt, model, WeightFmt::Lut4, 1, &store, Some(&qm4), false,
        )
        .expect("lut4 backend");
        run("GANQ", "4", &mut be4);
        // §Perf ablation: device-resident staged weights via execute_b
        let mut be4_res = coordinator::HloBackend::new(
            rt, model, WeightFmt::Lut4, 1, &store, Some(&qm4), true,
        )
        .expect("lut4 resident backend");
        run("GANQ (staged bufs)", "4", &mut be4_res);
        let mut be3 = coordinator::HloBackend::new(
            rt, model, WeightFmt::Lut3, 1, &store, Some(&qm3), false,
        )
        .expect("lut3 backend");
        run("GANQ", "3", &mut be3);
        // native decode (no graph-dispatch overhead) — dominates at toy
        // model sizes; included for the L3 perf story
        let wq4 = Weights::Quant(&qm4);
        let mut ben4 = coordinator::NativeBackend::new(wq4, 1);
        run("GANQ (native)", "4", &mut ben4);
        // GANQ*: sparse branch only exists on the native path
        let w = Weights::Quant(&qms4);
        let mut ben = coordinator::NativeBackend::new(w, 1);
        run("GANQ* (native)", "4", &mut ben);
        t.print();
    }
    println!(
        "\npaper shape: 3-bit < 4-bit < FP16 in weight traffic (that is \
         the 2.57x speedup driver on GPU); GANQ* adds sparse overhead."
    );
}
