//! Table 2: wiki2s perplexity of quantized models, 4-bit and 3-bit,
//! RTN/GPTQ/OmniQuant-like/GANQ across the model family.
//! Expected shape: full < GANQ < OmniQ/GPTQ < RTN; 4-bit < 3-bit gaps.

use ganq::bench::{ppl_grid, print_ppl_table, BenchCtx};
use ganq::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let batches = args.get_usize("batches", 1);
    let default_models = "opt-micro,opt-mini,opt-small,opt-med".to_string();
    let models_arg =
        args.get_or("models", &default_models).to_string();
    let models: Vec<&str> = models_arg.split(',').collect();
    let ctx = BenchCtx::load();
    let rows = ppl_grid(
        &ctx,
        &models,
        &["rtn", "gptq", "omniq", "ganq"],
        "wiki2s",
        batches,
    );
    print_ppl_table(
        "Table 2: wiki2s perplexity (lower is better)",
        &models,
        &rows,
    );
    println!(
        "\npaper shape: GANQ lowest at both widths; RTN collapses at 3-bit."
    );
}
