//! Any-precision store bench: pins the two claims the nested bit-plane
//! layout makes. (1) Memory: at a serving-scale linear shape the one
//! resident artifact (max-width planes + per-width codebooks) costs
//! <= 1.1x the largest standalone width — not the sum of widths. (2)
//! Quality: serving the nested store at width w is perplexity-identical
//! (<= 1e-3 relative) to the standalone w-bit sliced model, because the
//! plane slice is bitwise the standalone layer. Emits
//! `BENCH_anyprec.json`. `GANQ_SMOKE=1` shrinks the ppl token budget.

use ganq::model::forward::{Engine, Weights};
use ganq::model::{LayerWeights, ModelConfig, QuantizedModel, WeightStore};
use ganq::quant::ganq::fit_codebook_identity;
use ganq::quant::lut::lut_from_parts;
use ganq::quant::BitPlaneStore;
use ganq::tensor::Mat;
use ganq::util::json::{self, Json};
use ganq::util::rng::Rng;

const WIDTHS: [u8; 3] = [2, 3, 4];

fn smoke() -> bool {
    std::env::var("GANQ_SMOKE").is_ok()
}

/// Random 4-bit parent layer at a serving-scale shape (micro shapes are
/// misleading here: codebooks would dominate the planes).
fn big_parent(m: usize, n: usize) -> ganq::quant::LutLayer {
    let mut rng = Rng::new(77);
    let codes: Vec<u8> = (0..m * n).map(|_| rng.below(16) as u8).collect();
    let cb = Mat::from_vec(
        m,
        16,
        rng.normal_vec_f32(m * 16).into_iter().map(|v| v * 0.08).collect(),
    );
    lut_from_parts(m, n, 4, codes, cb)
}

/// Every linear nested: identity-Hessian 4-bit fit, then bit-plane
/// decomposition with codebooks for each width.
fn anyprec_model(store: &WeightStore) -> QuantizedModel {
    let mut linears = std::collections::BTreeMap::new();
    for (name, _m, _n) in store.cfg.linear_shapes() {
        let w = store.mat(&name);
        let mut codes = vec![0u8; w.rows * w.cols];
        let mut cb = Mat::zeros(w.rows, 16);
        for i in 0..w.rows {
            let (c, t) = fit_codebook_identity(w.row(i), 4, 2);
            codes[i * w.cols..(i + 1) * w.cols].copy_from_slice(&c);
            cb.row_mut(i).copy_from_slice(&t);
        }
        let parent = lut_from_parts(w.rows, w.cols, 4, codes, cb);
        linears.insert(
            name,
            LayerWeights::AnyPrec(BitPlaneStore::nest(&parent, &WIDTHS)),
        );
    }
    QuantizedModel {
        base: store.clone(),
        method: "ganq-anyprec".into(),
        bits: 4,
        linears,
        weight_bits: 0,
    }
}

/// The standalone width-w model the nested store must match: every
/// linear materialized as its sliced `LutLayer`.
fn sliced_model(qm: &QuantizedModel, w: u8) -> QuantizedModel {
    let mut out = qm.clone();
    out.bits = w;
    out.method = format!("lut{}-sliced", w);
    for lw in out.linears.values_mut() {
        if let LayerWeights::AnyPrec(b) = lw {
            *lw = LayerWeights::Lut(b.slice(w));
        }
    }
    out
}

fn main() {
    // -- memory: one resident artifact vs standalone width families --
    let (m, n) = (512usize, 2048usize);
    let parent = big_parent(m, n);
    let bp = BitPlaneStore::nest(&parent, &WIDTHS);
    let resident = bp.resident_bytes();
    let mut standalone = Vec::new();
    for &w in &WIDTHS {
        standalone.push((w, bp.slice(w).bytes_per_decode()));
    }
    let max_width = standalone.iter().map(|&(_, b)| b).max().unwrap();
    let sum_widths: usize = standalone.iter().map(|&(_, b)| b).sum();
    let ratio = resident as f64 / max_width as f64;
    println!(
        "resident memory at {}x{}: anyprec(2,3,4) {} B vs lut4 {} B \
         ({:.3}x max width; sum of widths {} B)",
        m, n, resident, max_width, ratio, sum_widths
    );
    for &(w, b) in &standalone {
        println!(
            "  width {}: standalone {} B, nested streams {} B/step",
            w,
            b,
            bp.bytes_per_decode(w)
        );
    }

    // -- quality: per-width ppl parity with the standalone slices --
    let cfg = ModelConfig::builtin("opt-micro").unwrap();
    let store = WeightStore::random("bench", cfg, 413);
    let qm = anyprec_model(&store);
    let (bsz, s_len) = if smoke() { (2, 32) } else { (4, 64) };
    let mut rng = Rng::new(99);
    let tokens: Vec<Vec<i32>> = (0..bsz)
        .map(|_| (0..s_len).map(|_| rng.below(256) as i32).collect())
        .collect();
    let preds = (bsz * (s_len - 1)) as f64;
    let w_any = Weights::Quant(&qm);
    let mut ppl_rows = Vec::new();
    let mut worst_rel = 0.0f64;
    for &w in &WIDTHS {
        let nll_any = Engine::new_at(&w_any, Some(w))
            .nll_sum_chunked(&tokens, usize::MAX);
        let std = sliced_model(&qm, w);
        let w_std = Weights::Quant(&std);
        let nll_std = Engine::new(&w_std).nll_sum_chunked(&tokens, usize::MAX);
        let (ppl_a, ppl_s) =
            ((nll_any / preds).exp(), (nll_std / preds).exp());
        let rel = (ppl_a - ppl_s).abs() / ppl_s;
        worst_rel = worst_rel.max(rel);
        println!(
            "width {}: ppl nested {:.4} vs standalone {:.4} (rel {:.2e})",
            w, ppl_a, ppl_s, rel
        );
        ppl_rows.push(json::obj(vec![
            ("width", json::num(w as f64)),
            ("ppl_anyprec", json::num(ppl_a)),
            ("ppl_standalone", json::num(ppl_s)),
            ("rel_diff", json::num(rel)),
        ]));
    }

    let out = json::obj(vec![
        ("shape", Json::Arr(vec![json::num(m as f64), json::num(n as f64)])),
        ("smoke", Json::Bool(smoke())),
        (
            "resident_bytes",
            json::obj(vec![
                ("anyprec", json::num(resident as f64)),
                ("lut4", json::num(standalone[2].1 as f64)),
                ("lut3", json::num(standalone[1].1 as f64)),
                ("lut2", json::num(standalone[0].1 as f64)),
            ]),
        ),
        ("resident_ratio_vs_max_width", json::num(ratio)),
        ("sum_widths_bytes", json::num(sum_widths as f64)),
        (
            "bytes_per_decode",
            Json::Arr(
                WIDTHS
                    .iter()
                    .map(|&w| {
                        json::obj(vec![
                            ("width", json::num(w as f64)),
                            (
                                "nested",
                                json::num(bp.bytes_per_decode(w) as f64),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("ppl", Json::Arr(ppl_rows)),
        ("ppl_worst_rel_diff", json::num(worst_rel)),
    ]);
    std::fs::write("BENCH_anyprec.json", out.to_string_pretty())
        .expect("write BENCH_anyprec.json");
    println!("\nwrote BENCH_anyprec.json");

    assert!(
        ratio <= 1.1,
        "acceptance FAILED: anyprec resident {} B is {:.3}x the largest \
         standalone width ({} B); the nested layout must cost ~max(width), \
         not sum(widths)",
        resident,
        ratio,
        max_width
    );
    assert!(
        resident < sum_widths,
        "acceptance FAILED: anyprec resident {} B >= sum of standalone \
         widths {} B",
        resident,
        sum_widths
    );
    assert!(
        worst_rel <= 1e-3,
        "acceptance FAILED: nested-vs-standalone ppl diverged ({:.2e} \
         relative; slices must be bitwise)",
        worst_rel
    );
    println!(
        "acceptance OK: resident = {:.3}x max width (<= 1.1x), per-width \
         ppl parity {:.2e} (<= 1e-3)",
        ratio, worst_rel
    );
}
