//! Figure 1(b): weight-distribution summaries (violin-plot analogue) for
//! the first decoder layer — the non-uniformity that motivates GANQ.

use ganq::bench::BenchCtx;
use ganq::quant::stats;
use ganq::util::cli::Args;
use ganq::util::timer::Table;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.get_or("model", "opt-small").to_string();
    let ctx = BenchCtx::load();
    let store = match ctx.store(&model) {
        Some(s) => s,
        None => return,
    };
    let mut t = Table::new(
        &format!("Fig 1(b): first-layer weight distributions, {}", model),
        &["matrix", "min", "max", "std", "kurtosis", "central-99% range"],
    );
    for nm in ["wq", "wk", "wv", "wo", "w1", "w2"] {
        let name = format!("l0.{}", nm);
        let w = store.mat(&name);
        let s = stats::dist_stats(&name, &w);
        t.row(vec![
            name.clone(),
            format!("{:.3}", s.min),
            format!("{:.3}", s.max),
            format!("{:.4}", s.std),
            format!("{:+.2}", s.kurtosis),
            format!("{:.1}%", 100.0 * s.central99_range_frac),
        ]);
    }
    t.print();
    println!(
        "\nkurtosis > 0 and central-99% range << 100% => heavy tails: a \
         uniform grid wastes levels on outliers (the paper's motivation)."
    );
    let w = store.mat("l0.w2");
    println!("\nASCII violin of l0.w2:\n{}", stats::ascii_violin(&w, 17, 50));
}
