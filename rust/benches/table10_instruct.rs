//! Table 10 (Appendix C): quantized *instruct* models — wiki2s and c4s
//! perplexity at 4-bit and 3-bit.

use ganq::bench::{ppl_grid, print_ppl_table, BenchCtx};
use ganq::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let batches = args.get_usize("batches", 1);
    let models = ["opt-mini-instruct", "opt-small-instruct"];
    let ctx = BenchCtx::load();
    for flavor in ["wiki2s", "c4s"] {
        let rows = ppl_grid(
            &ctx,
            &models,
            &["rtn", "gptq", "omniq", "ganq"],
            flavor,
            batches,
        );
        print_ppl_table(
            &format!("Table 10: {} perplexity (instruct models)", flavor),
            &models,
            &rows,
        );
    }
    println!("\npaper shape: GANQ most stable at 3-bit on instruct models.");
}
