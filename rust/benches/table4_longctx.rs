//! Table 4: 4-bit quantized instruct models on longbench-s (long-context
//! kv recall) and gsm-s (arithmetic) — generation-based exact match.

use ganq::bench::BenchCtx;
use ganq::data::tasks;
use ganq::eval::tasks::exact_match;
use ganq::model::forward::Weights;
use ganq::util::cli::Args;
use ganq::util::timer::Table;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let cases = args.get_usize("cases", 40);
    let ctx = BenchCtx::load();
    let models = ["opt-mini-instruct", "opt-small-instruct"];

    let mut headers = vec!["method"];
    for m in &models {
        headers.push(m);
        headers.push("gsm-s (%)");
    }
    let mut t = Table::new(
        "Table 4: instruct models, longbench-s recall (%) / gsm-s (%), 4-bit",
        &["method", "mini: longbench-s", "mini: gsm-s", "small: longbench-s", "small: gsm-s"],
    );

    let lb = tasks::longbench_cases(cases, 10, 17);
    let gsm = tasks::gsm_cases(cases, 23);

    let stores: Vec<_> = models.iter().map(|m| ctx.store(m)).collect();
    for method in ["full", "rtn", "gptq", "omniq", "ganq"] {
        let mut cells = vec![method.to_string()];
        for s in &stores {
            let Some(store) = s else {
                cells.push("-".into());
                cells.push("-".into());
                continue;
            };
            if method == "full" {
                let w = Weights::Fp(store);
                cells.push(format!("{:.1}", 100.0 * exact_match(&w, &lb)));
                cells.push(format!("{:.1}", 100.0 * exact_match(&w, &gsm)));
            } else {
                let calib = ctx.calibrate(store, 32);
                let qm = ctx.quantize(store, &calib, method, 4);
                let w = Weights::Quant(&qm);
                cells.push(format!("{:.1}", 100.0 * exact_match(&w, &lb)));
                cells.push(format!("{:.1}", 100.0 * exact_match(&w, &gsm)));
            }
        }
        t.row(cells);
    }
    t.print();
    println!(
        "\npaper shape: GANQ closest to FP16 on both tasks; RTN unstable \
         at the smaller scale."
    );
}
