//! Table 9 (Appendix C): ptbs perplexity for the OPT-family stand-ins
//! (the paper reports PTB for OPT models only).

use ganq::bench::{ppl_grid, print_ppl_table, BenchCtx};
use ganq::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let batches = args.get_usize("batches", 1);
    let default_models = "opt-micro,opt-mini,opt-small".to_string();
    let models_arg = args.get_or("models", &default_models).to_string();
    let models: Vec<&str> = models_arg.split(',').collect();
    let ctx = BenchCtx::load();
    let rows = ppl_grid(
        &ctx,
        &models,
        &["rtn", "gptq", "omniq", "ganq"],
        "ptbs",
        batches,
    );
    print_ppl_table("Table 9: ptbs perplexity", &models, &rows);
}
