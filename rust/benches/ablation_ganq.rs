//! GANQ ablations (DESIGN.md): iteration-count K sweep, the GPU-adaptive
//! batched-rows formulation vs a serial per-row loop (the paper's §3.2
//! parallelization claim), and native-vs-HLO solver agreement + timing.

use ganq::bench::BenchCtx;
use ganq::quant::ganq::Precond;
use ganq::quant::ganq as solver;
use ganq::quant::rtn::rtn_codebook;
use ganq::util::pool::default_threads;
use ganq::runtime::ganq_hlo;
use ganq::tensor::{linalg, Mat};
use ganq::util::rng::Rng;
use ganq::util::timer::{bench, Table};

fn main() {
    let ctx = BenchCtx::load();
    let mut rng = Rng::new(0xAB1A);
    let (m, n, p) = (768, 512, 1024);
    let w = Mat::from_vec(m, n, rng.normal_vec_f32(m * n));
    let x = Mat::from_vec(n, p, rng.normal_vec_f32(n * p));
    let h = x.gram();
    let hp = linalg::precondition(&h);

    // --- K sweep (error vs iterations; paper uses K=10)
    let mut t = Table::new(
        "ablation: GANQ iterations K (layer error, 4-bit, 768x512)",
        &["K", "layer err", "vs K=1"],
    );
    let mut e1 = None;
    for k in [1usize, 2, 4, 6, 10, 16] {
        let sol = solver::solve(&w, &h, 4, k, Precond::Adaptive, false);
        let w_hat = solver::reconstruct(m, n, &sol.codes, &sol.codebook);
        let err = linalg::layer_error(&w, &w_hat, &hp);
        if e1.is_none() {
            e1 = Some(err);
        }
        t.row(vec![
            k.to_string(),
            format!("{:.4e}", err),
            format!("{:.3}x", err / e1.unwrap()),
        ]);
    }
    t.print();

    // --- GPU-adaptive (all rows in parallel) vs serial per-row loop
    let l = linalg::cholesky(&hp).unwrap();
    let (_, t0) = rtn_codebook(&w, 4);
    let mut tt = Table::new(
        "ablation: batched-row S-step (paper's GPU-adaptive axis) vs serial",
        &["variant", "ms / S-step", "speedup"],
    );
    let threads = default_threads();
    let s_serial = bench(1, 5, || {
        let _ = solver::sstep(&w, &l, &t0, 1);
    });
    let s_par = bench(1, 5, || {
        let _ = solver::sstep(&w, &l, &t0, threads);
    });
    tt.row(vec![
        "serial (1 row-lane)".into(),
        format!("{:.2}", s_serial.mean_ms()),
        "1.00x".into(),
    ]);
    tt.row(vec![
        "batched rows (all lanes)".into(),
        format!("{:.2}", s_par.mean_ms()),
        format!("{:.2}x", s_serial.mean_s / s_par.mean_s),
    ]);
    tt.print();

    // --- native vs HLO solver (same algorithm through the AOT stack)
    if let Some(rt) = ctx.rt.as_ref() {
        let mut rng2 = Rng::new(0xCD);
        let w2 = Mat::from_vec(64, 64, rng2.normal_vec_f32(64 * 64));
        let x2 = Mat::from_vec(64, 160, rng2.normal_vec_f32(64 * 160));
        let h2 = x2.gram();
        let hp2 = linalg::precondition(&h2);
        let mut te = Table::new(
            "ablation: native solver vs AOT HLO graph (64x64, K=10)",
            &["engine", "time (s)", "layer err"],
        );
        let tn = std::time::Instant::now();
        let sol = solver::solve(&w2, &h2, 4, 10, Precond::Adaptive, false);
        let wn = solver::reconstruct(64, 64, &sol.codes, &sol.codebook);
        te.row(vec![
            "native (rust)".into(),
            format!("{:.3}", tn.elapsed().as_secs_f64()),
            format!("{:.4e}", linalg::layer_error(&w2, &wn, &hp2)),
        ]);
        let th = std::time::Instant::now();
        if let Ok(Some(r)) = ganq_hlo::quantize_layer_hlo(rt, &w2, &h2, 4) {
            te.row(vec![
                "AOT HLO (pallas step)".into(),
                format!("{:.3}", th.elapsed().as_secs_f64()),
                format!(
                    "{:.4e}",
                    linalg::layer_error(&w2, &r.w_hat, &hp2)
                ),
            ]);
        }
        te.print();
    }
}
