//! Paged-KV serving bench: contiguous per-slot caches vs the paged block
//! pool at the SAME KV memory budget, across shared-prefix workloads
//! (0% / 50% / 90% of the prompt shared). Reports peak concurrent
//! requests, throughput, preemptions and prefix-hit rate, and asserts
//! the PR acceptance criterion: at 50% sharing the paged scheduler
//! admits >= 1.5x more concurrent requests than the contiguous baseline.

use ganq::coordinator::{
    self, GenRequest, KvStoreKind, NativeBackend, PagedNativeBackend,
};
use ganq::model::forward::Weights;
use ganq::model::{ModelConfig, WeightStore};
use ganq::util::timer::Table;

const N_REQS: usize = 24;
const PROMPT_LEN: usize = 40;
const MAX_NEW: usize = 12;
const BLOCK_SIZE: usize = 8;
const CONTIG_SLOTS: usize = 4;

/// `shared` of the PROMPT_LEN prompt tokens are common to all requests.
fn workload(shared: usize) -> Vec<GenRequest> {
    (0..N_REQS)
        .map(|i| {
            let mut prompt: Vec<i32> =
                (0..shared).map(|j| 200 + j as i32).collect();
            prompt.extend(
                (shared..PROMPT_LEN)
                    .map(|j| ((i * PROMPT_LEN + j) % 199) as i32),
            );
            GenRequest::greedy(i as u64, prompt, MAX_NEW)
        })
        .collect()
}

fn main() {
    let cfg = ModelConfig::builtin("opt-micro").unwrap();
    let store = WeightStore::random("bench", cfg, 917);
    let slot_bytes =
        cfg.layers * cfg.heads * cfg.ctx * cfg.head_dim() * 4 * 2;
    let budget = CONTIG_SLOTS * slot_bytes;
    println!(
        "model opt-micro, {} reqs x ({} prompt + {} new), kv budget {} KiB \
         ({} contiguous slots)",
        N_REQS,
        PROMPT_LEN,
        MAX_NEW,
        budget / 1024,
        CONTIG_SLOTS
    );

    let mut t = Table::new(
        "contiguous vs paged KV at fixed memory",
        &[
            "backend",
            "shared%",
            "peak conc",
            "tok/s",
            "ttft p50 ms",
            "preempt",
            "hit%",
            "wall ms",
        ],
    );

    let mut paged_peak_at_50 = 0usize;
    let mut contig_peak_at_50 = 0usize;

    for &shared in &[0usize, 20, 36] {
        let pct = 100 * shared / PROMPT_LEN;
        let reqs = workload(shared);

        let mut be = NativeBackend::new(Weights::Fp(&store), CONTIG_SLOTS);
        let (resp_c, m_c) =
            coordinator::serve(&mut be, reqs.clone()).expect("contiguous");
        assert_eq!(resp_c.len(), N_REQS);
        if shared == 20 {
            contig_peak_at_50 = m_c.peak_concurrency;
        }
        t.row(vec![
            "contiguous".into(),
            format!("{}", pct),
            format!("{}", m_c.peak_concurrency),
            format!("{:.0}", m_c.tokens_per_s()),
            format!("{:.1}", m_c.ttft_p50_ms()),
            "0".into(),
            "-".into(),
            format!("{:.1}", m_c.wall_s * 1e3),
        ]);

        for (name, kind) in
            [("paged-f32", KvStoreKind::F32), ("paged-lut4", KvStoreKind::Lut4)]
        {
            let mut bp = PagedNativeBackend::with_memory_budget(
                Weights::Fp(&store),
                N_REQS,
                BLOCK_SIZE,
                kind,
                budget,
            );
            let (resp_p, m_p) =
                coordinator::serve(&mut bp, reqs.clone()).expect("paged");
            assert_eq!(resp_p.len(), N_REQS);
            if kind == KvStoreKind::F32 {
                // greedy outputs must match the contiguous baseline
                // exactly (F32 blocks are bit-exact)
                for (c, p) in resp_c.iter().zip(&resp_p) {
                    assert_eq!(c.tokens, p.tokens, "req {}", c.id);
                }
                if shared == 20 {
                    paged_peak_at_50 = m_p.peak_concurrency;
                }
            }
            let kv = m_p.kv.expect("pool stats");
            t.row(vec![
                name.into(),
                format!("{}", pct),
                format!("{}", m_p.peak_concurrency),
                format!("{:.0}", m_p.tokens_per_s()),
                format!("{:.1}", m_p.ttft_p50_ms()),
                format!("{}", m_p.preemptions),
                format!("{:.0}", 100.0 * kv.prefix_hit_rate()),
                format!("{:.1}", m_p.wall_s * 1e3),
            ]);
        }
    }
    t.print();

    assert!(
        paged_peak_at_50 * 2 >= contig_peak_at_50 * 3,
        "acceptance FAILED: paged {} vs contiguous {} at 50% shared is \
         below 1.5x",
        paged_peak_at_50,
        contig_peak_at_50
    );
    println!(
        "\nacceptance OK: paged admits {} concurrent vs {} contiguous \
         ({:.1}x) at 50% shared prefix and the same kv budget",
        paged_peak_at_50,
        contig_peak_at_50,
        paged_peak_at_50 as f64 / contig_peak_at_50 as f64
    );
}
