//! Prefill time-to-first-token bench: chunked prefill (the engine's
//! prefill-chunk work items, default 128-position budget) vs per-token
//! prefill (`prefill_chunk = 1`, the historical "prefill as decode"
//! path) through the real serve scheduler, at prompt lengths 128 / 512
//! / 2048. Two series share `BENCH_prefill.json`:
//!
//! * `backend: "native"` — `NativeBackend` on a long-context micro
//!   config (ctx 2176), fp32 and 4-bit LUT weights;
//! * `backend: "hlo"` — `HloBackend` on the `opt-longctx` AOT model
//!   (compiled `prefill_*_c{8,16,32}` graphs vs per-token decode-graph
//!   dispatch), present only when artifacts are built.
//!
//! Asserts the PR acceptance criteria: chunked prefill reaches the
//! first token >= 2x faster than per-token prefill at the 2048-token
//! prompt — on the native path always, and on the HLO path whenever
//! prefill artifacts exist. `GANQ_SMOKE=1` shrinks rep counts for CI
//! but keeps both 2x bars — the native win comes from streaming weights
//! once per chunk instead of once per position, the HLO win from
//! amortizing graph dispatch + full-cache traffic over C positions;
//! both hold on any hardware.

use std::time::Instant;

use ganq::coordinator::{
    serve_with, GenRequest, HloBackend, NativeBackend, ServeOptions,
    WeightFmt,
};
use ganq::model::forward::Weights;
use ganq::model::{LayerWeights, ModelConfig, QuantizedModel, WeightStore};
use ganq::obs::hist::Samples;
use ganq::quant::ganq::fit_codebook_identity;
use ganq::quant::lut::lut_from_parts;
use ganq::runtime::Runtime;
use ganq::tensor::Mat;
use ganq::util::json::{self, Json};
use ganq::util::timer::Table;

const PROMPT_LENS: [usize; 3] = [128, 512, 2048];
const CHUNK: usize = 128;
const MAX_NEW: usize = 4;

fn smoke() -> bool {
    std::env::var("GANQ_SMOKE").is_ok()
}

/// Long-context micro config: big enough ctx for the 2048 prompt, small
/// enough d/layers that the per-token baseline finishes in CI time.
fn long_ctx_cfg() -> ModelConfig {
    ModelConfig {
        d: 128,
        layers: 2,
        heads: 2,
        ff: 256,
        ctx: 2176,
        vocab: 256,
        eos: None,
    }
}

/// Quantize every linear to a per-row non-uniform LUT (identity
/// Hessian) — the servable form the engine packs.
fn lut_model(store: &WeightStore, bits: u8) -> QuantizedModel {
    let k = 1usize << bits;
    let mut linears = std::collections::BTreeMap::new();
    for (name, _m, _n) in store.cfg.linear_shapes() {
        let w = store.mat(&name);
        let mut codes = vec![0u8; w.rows * w.cols];
        let mut cb = Mat::zeros(w.rows, k);
        for i in 0..w.rows {
            let (c, t) = fit_codebook_identity(w.row(i), bits, 2);
            codes[i * w.cols..(i + 1) * w.cols].copy_from_slice(&c);
            cb.row_mut(i).copy_from_slice(&t);
        }
        linears.insert(
            name,
            LayerWeights::Lut(lut_from_parts(
                w.rows, w.cols, bits, codes, cb,
            )),
        );
    }
    QuantizedModel {
        base: store.clone(),
        method: format!("lut{}-identity", bits),
        bits,
        linears,
        weight_bits: 0,
    }
}

/// TTFT (ms) and prompt-positions-per-step for one serve run of a single
/// request with the given prompt length and prefill budget.
fn run_once(w: &Weights, prompt_len: usize, chunk: usize) -> (f64, f64) {
    let prompt: Vec<i32> =
        (0..prompt_len as i32).map(|i| (i * 31 + 7) % 256).collect();
    let reqs = vec![GenRequest::greedy(1, prompt, MAX_NEW)];
    let mut be = NativeBackend::new(*w, 1);
    let (_resp, m) = serve_with(
        &mut be,
        reqs,
        ServeOptions { prefill_chunk: chunk, ..Default::default() },
    )
    .expect("serve");
    let ttft = m.requests[0].ttft_ms().expect("first token");
    (ttft, m.prompt_positions_per_step())
}

/// Best-of-`reps` TTFT for one (weights, prompt, chunk) cell.
fn measure(w: &Weights, prompt_len: usize, chunk: usize, reps: usize) -> (f64, f64) {
    let mut ts = Samples::new();
    let mut pps = 0.0;
    for _ in 0..reps {
        let (t, p) = run_once(w, prompt_len, chunk);
        if t < ts.min() {
            pps = p;
        }
        ts.push(t);
    }
    (ts.min(), pps)
}

/// TTFT (ms) through the HLO backend for one prompt length and prefill
/// budget, best of `reps` serve runs on one (pre-warmed) backend.
fn measure_hlo(
    be: &mut HloBackend,
    prompt_len: usize,
    chunk: usize,
    reps: usize,
) -> f64 {
    let prompt: Vec<i32> =
        (0..prompt_len as i32).map(|i| (i * 31 + 7) % 256).collect();
    let mut ts = Samples::new();
    for _ in 0..reps {
        let reqs = vec![GenRequest::greedy(1, prompt.clone(), MAX_NEW)];
        let (_resp, m) = serve_with(
            &mut *be,
            reqs,
            ServeOptions { prefill_chunk: chunk, ..Default::default() },
        )
        .expect("hlo serve");
        ts.push(m.requests[0].ttft_ms().expect("first token"));
    }
    ts.min()
}

/// The HLO-backend series: chunked (compiled prefill graphs) vs
/// per-token (decode-graph dispatch) TTFT on the long-context AOT
/// model. Returns the worst 2048-prompt speedup, or `None` (with a
/// note) when prefill artifacts are absent — absence is not a failure.
fn hlo_series(
    t: &mut Table,
    rows: &mut Vec<Json>,
    reps: usize,
) -> Option<f64> {
    let model = "opt-longctx";
    let rt = match Runtime::load() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("no artifacts ({}); skipping HLO series", e);
            return None;
        }
    };
    let entry = rt.manifest.models.get(model)?;
    if rt
        .manifest
        .prefill_chunks("fp32", &entry.base_config, 1)
        .is_empty()
    {
        eprintln!("no prefill graphs for {}; skipping HLO series", model);
        return None;
    }
    let store = match WeightStore::load(&rt.base, model, entry.config) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("no {} weights ({}); skipping HLO series", model, e);
            return None;
        }
    };
    let mut be = HloBackend::new(
        &rt, model, WeightFmt::Fp32, 1, &store, None, true,
    )
    .expect("hlo backend");
    // warm: compile the graphs the timed runs dispatch, outside the
    // timing (37 tokens = one c32 dispatch + a 5-token tail bucketed
    // into a padded c8; per-token warms the decode dispatch, which also
    // serves the post-prefill decode steps)
    measure_hlo(&mut be, 37, 128, 1);
    measure_hlo(&mut be, 2, 1, 1);
    let mut speedup_2048 = f64::INFINITY;
    for len in PROMPT_LENS {
        let chunked = measure_hlo(&mut be, len, CHUNK, reps);
        let per_token = measure_hlo(&mut be, len, 1, reps);
        let speedup = per_token / chunked;
        if len == 2048 {
            speedup_2048 = speedup_2048.min(speedup);
        }
        t.row(vec![
            "hlo fp32".into(),
            format!("{}", len),
            format!("{:.1}", chunked),
            format!("{:.1}", per_token),
            format!("{:.2}x", speedup),
            "-".into(),
        ]);
        rows.push(json::obj(vec![
            ("backend", json::s("hlo")),
            ("fmt", json::s("fp32")),
            ("prompt_len", json::num(len as f64)),
            ("ttft_chunked_ms", json::num(chunked)),
            ("ttft_per_token_ms", json::num(per_token)),
            ("speedup", json::num(speedup)),
        ]));
    }
    Some(speedup_2048)
}

fn main() {
    let cfg = long_ctx_cfg();
    let store = WeightStore::random("bench", cfg, 611);
    eprintln!("fitting 4-bit LUT model...");
    let qm4 = lut_model(&store, 4);
    let reps = if smoke() { 1 } else { 2 };
    println!(
        "prefill TTFT (ctx {}): chunked (budget {}) vs per-token, best of \
         {} rep(s){}",
        cfg.ctx,
        CHUNK,
        reps,
        if smoke() { " [smoke]" } else { "" }
    );

    let mut t = Table::new(
        "chunked vs per-token prefill TTFT",
        &[
            "fmt",
            "prompt",
            "chunked ms",
            "per-token ms",
            "speedup",
            "prompt-pos/step",
        ],
    );
    let mut rows = Vec::new();
    let mut speedup_2048 = f64::INFINITY;
    let t_all = Instant::now();
    for (fmt, w) in
        [("fp32", Weights::Fp(&store)), ("lut4", Weights::Quant(&qm4))]
    {
        for len in PROMPT_LENS {
            let (chunked, pps) = measure(&w, len, CHUNK, reps);
            let (per_token, _) = measure(&w, len, 1, reps);
            let speedup = per_token / chunked;
            if len == 2048 {
                speedup_2048 = speedup_2048.min(speedup);
            }
            t.row(vec![
                fmt.into(),
                format!("{}", len),
                format!("{:.1}", chunked),
                format!("{:.1}", per_token),
                format!("{:.2}x", speedup),
                format!("{:.1}", pps),
            ]);
            rows.push(json::obj(vec![
                ("backend", json::s("native")),
                ("fmt", json::s(fmt)),
                ("prompt_len", json::num(len as f64)),
                ("ttft_chunked_ms", json::num(chunked)),
                ("ttft_per_token_ms", json::num(per_token)),
                ("speedup", json::num(speedup)),
                ("prompt_positions_per_step", json::num(pps)),
            ]));
        }
    }
    let hlo_speedup_2048 = hlo_series(&mut t, &mut rows, reps);
    t.print();

    let out = json::obj(vec![
        ("model", json::s("longctx-micro")),
        ("ctx", json::num(cfg.ctx as f64)),
        ("prefill_chunk", json::num(CHUNK as f64)),
        ("max_new", json::num(MAX_NEW as f64)),
        ("smoke", Json::Bool(smoke())),
        ("wall_s", json::num(t_all.elapsed().as_secs_f64())),
        ("ttft", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_prefill.json", out.to_string_pretty())
        .expect("write BENCH_prefill.json");
    println!("\nwrote BENCH_prefill.json");

    assert!(
        speedup_2048 >= 2.0,
        "acceptance FAILED: chunked prefill TTFT speedup at 2048-token \
         prompt = {:.2}x (need >= 2x)",
        speedup_2048
    );
    println!(
        "acceptance OK: chunked prefill >= 2x TTFT at the 2048 prompt \
         (worst format {:.2}x)",
        speedup_2048
    );
    match hlo_speedup_2048 {
        Some(s) => {
            assert!(
                s >= 2.0,
                "acceptance FAILED: HLO chunked prefill TTFT speedup at \
                 the 2048-token prompt = {:.2}x (need >= 2x)",
                s
            );
            println!(
                "acceptance OK: HLO chunked prefill >= 2x TTFT at the \
                 2048 prompt ({:.2}x)",
                s
            );
        }
        None => println!(
            "HLO series skipped (no prefill artifacts); native \
             acceptance only"
        ),
    }
}
