//! §4.4: quantization cost — wall-clock and peak working-set proxy per
//! method per model. The paper's point: GANQ's GPU-adaptive row-parallel
//! formulation quantizes a 7B model in ~1h; gradient-based methods
//! (OmniQuant / SqueezeLLM's Fisher pass) cost far more.

use ganq::bench::BenchCtx;
use ganq::util::cli::Args;
use ganq::util::timer::Table;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let default_models = "opt-micro,opt-small".to_string();
    let models_arg = args.get_or("models", &default_models).to_string();
    let models: Vec<&str> = models_arg.split(',').collect();
    let ctx = BenchCtx::load();

    let mut headers = vec!["method"];
    headers.extend(models.iter().copied());
    let mut t = Table::new(
        "quantization cost (seconds, 4-bit, incl. all layers)",
        &headers,
    );
    let mut rows: Vec<Vec<String>> = Vec::new();
    for method in
        ["rtn", "gptq", "awq-g128", "omniq", "squeezellm", "ganq", "ganq-star"]
    {
        rows.push(vec![method.to_string()]);
    }
    for model in &models {
        let Some(store) = ctx.store(model) else {
            for r in rows.iter_mut() {
                r.push("-".into());
            }
            continue;
        };
        let calib = ctx.calibrate(&store, 32);
        for (mi, method) in
            ["rtn", "gptq", "awq-g128", "omniq", "squeezellm", "ganq", "ganq-star"]
                .iter()
                .enumerate()
        {
            let t0 = std::time::Instant::now();
            let _ = ctx.quantize(&store, &calib, method, 4);
            rows[mi].push(format!("{:.2}", t0.elapsed().as_secs_f64()));
        }
    }
    for r in rows {
        t.row(r);
    }
    t.print();
    println!(
        "\npaper shape: RTN fastest; GANQ between GPTQ and the \
         search/clustering methods, and far below OmniQuant's 3h-per-7B."
    );
}
