//! L1 kernel bench: the AOT LUT-mpGEMM artifact (Pallas, interpret-lowered)
//! vs the Rust-native LUT matmul vs dense f32 matmul, per layer shape.
//! Interpret-mode wall-clock is NOT a TPU proxy (DESIGN.md); the structural
//! VMEM/MXU estimates that carry to hardware live in EXPERIMENTS.md §Perf.

use ganq::bench::BenchCtx;
use ganq::quant::lut::lut_from_parts;
use ganq::runtime::HostTensor;
use ganq::tensor::Mat;
use ganq::util::rng::Rng;
use ganq::util::timer::{bench_for, Table};

fn main() {
    let ctx = BenchCtx::load();
    let mut t = Table::new(
        "LUT-mpGEMM kernel paths (p=8 activations)",
        &["shape", "bits", "dense f32 us", "native LUT us", "HLO (pallas) us"],
    );
    for (m, n) in [(128usize, 128usize), (512, 128), (128, 512)] {
        for bits in [4u8, 3] {
            let mut rng = Rng::new(7 + m as u64);
            let k = 1usize << bits;
            let codes: Vec<u8> =
                (0..m * n).map(|_| rng.below(k as u64) as u8).collect();
            let cb = Mat::from_vec(m, k, rng.normal_vec_f32(m * k));
            let lut = lut_from_parts(m, n, bits, codes, cb);
            let w = lut.dequant();
            let x = Mat::from_vec(8, n, rng.normal_vec_f32(8 * n));

            let s_dense = bench_for(0.3, 500, || {
                let _ = x.matmul_tb(&w);
            });
            let s_lut = bench_for(0.3, 500, || {
                let _ = lut.lut_matmul(&x);
            });
            let hlo_us = match ctx.rt.as_ref() {
                Some(rt) => {
                    let name = format!("lutgemm{}_p{}_{}x{}", bits, 8, m, n);
                    if rt.has_graph(&name) {
                        let inputs = [
                            HostTensor::F32(vec![8, n], x.data.clone()),
                            HostTensor::U8(
                                vec![m, n / 2],
                                lut.packed_nibbles(),
                            ),
                            HostTensor::F32(
                                vec![m, k],
                                lut.codebook.data.clone(),
                            ),
                        ];
                        let _ = rt.run(&name, &inputs); // compile+warm
                        let s = bench_for(0.3, 200, || {
                            let _ = rt.run(&name, &inputs).unwrap();
                        });
                        format!("{:.1}", s.mean_us())
                    } else {
                        "-".into()
                    }
                }
                None => "-".into(),
            };
            t.row(vec![
                format!("{}x{}", m, n),
                bits.to_string(),
                format!("{:.1}", s_dense.mean_us()),
                format!("{:.1}", s_lut.mean_us()),
                hlo_us,
            ]);
        }
    }
    t.print();
    println!(
        "\nnote: on CPU the dense f32 GEMM is compute-bound and fast; the \
         LUT path wins on *bytes moved* (see table6), which is what the \
         paper's GPU kernels exploit."
    );
}
