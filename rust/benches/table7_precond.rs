//! Table 7 (Appendix A): preconditioning ablation — fixed lambda vs the
//! adaptive diagonal-dominance method, 4-bit opt-micro, wiki2s perplexity.

use ganq::bench::BenchCtx;
use ganq::coordinator;
use ganq::data::corpus;
use ganq::model::{LayerWeights, QuantizedModel};
use ganq::quant::ganq::{Ganq, Precond};
use ganq::quant::Quantizer;
use ganq::util::timer::Table;

fn main() {
    let ctx = BenchCtx::load();
    let model = "opt-micro";
    let Some(store) = ctx.store(model) else { return };
    let calib = ctx.calibrate(&store, 32);
    let flavor = corpus::flavor("wiki2s").unwrap();

    let mut t = Table::new(
        "Table 7: 4-bit opt-micro wiki2s ppl under preconditioning variants",
        &["preconditioning", "ppl", "total layer err"],
    );
    let variants: Vec<(String, Precond)> = [0.5, 1.0, 10.0, 40.0, 100.0]
        .iter()
        .map(|&l| (format!("lambda = {}", l), Precond::Lambda(l)))
        .chain(std::iter::once((
            "adaptive (eq. 23-24)".to_string(),
            Precond::Adaptive,
        )))
        .collect();
    for (label, pc) in variants {
        let q = Ganq::with_precond(4, pc);
        let mut linears = std::collections::BTreeMap::new();
        let mut bits_total = 0;
        for (name, _m, _n) in store.cfg.linear_shapes() {
            let w = store.mat(&name);
            let r = q.quantize(&w, &calib.grams[&name]);
            bits_total += r.storage.total_bits();
            linears.insert(name, LayerWeights::from_result(&r));
        }
        let qm = QuantizedModel {
            base: store.clone(),
            method: label.clone(),
            bits: 4,
            linears,
            weight_bits: bits_total,
        };
        let ppl = ctx.ppl(model, &store, Some(&qm), flavor, 2);
        let err =
            coordinator::pipeline::total_layer_error(&store, &qm, &calib);
        t.row(vec![label, format!("{:.4}", ppl), format!("{:.3e}", err)]);
    }
    t.print();
    println!("\npaper shape: all variants close; adaptive best or tied.");
}
