//! Open-loop serving bench: a mixed traffic pool (short chat turns,
//! 2048-token RAG prompts, long generations, mid-flight cancellers,
//! stop-seq-heavy agents) replayed against the threaded server under
//! Poisson and bursty arrivals, with per-class latency SLOs. The backend
//! is `PagedNativeBackend` on the long-context micro config with a block
//! pool deliberately tight enough that bursts force preemptions. Emits
//! `BENCH_serve.json`: goodput (SLO-attaining tokens/s), TTFT/TPOT
//! p50/p99, queue-delay tails, and preemption/rejection/cancellation
//! rates per class and per arrival pattern.
//!
//! Also pins two robustness properties:
//!
//! * **Goodput retention under worker kill** — the same workload runs
//!   twice through a multi-replica [`Cluster`] (default `--replicas 2`),
//!   once clean and once with a fault plan (default `kill:1@6`: panic
//!   replica 1 on its 6th scheduler step). Every request must still
//!   reach a terminal outcome (`lost == 0`) and
//!   `goodput_retention = faulted / unfaulted` must stay ≥ 0.70 —
//!   the workload is arrival-bound, so the surviving replica absorbs
//!   the requeued work.
//! * **Tracing tax** — a closed-loop batch-16 lut4 decode run on
//!   `opt-micro` measured with the ring recorder enabled vs the no-op
//!   sink. Asserts enabled tracing costs < 5% throughput (< 50% under
//!   `GANQ_SMOKE=1` — shared runners are noisy); the overhead fraction
//!   is part of the JSON so CI can watch it drift.

use std::sync::Arc;
use std::time::Instant;

use ganq::bench::traffic::{
    run_open_loop, run_open_loop_cluster, standard_classes, Arrivals,
    TrafficReport, TrafficSpec,
};
use ganq::coordinator::{
    serve, serve_batch, Cluster, ClusterMetrics, ClusterOptions, Fault,
    FaultPlan, GenRequest, KvStoreKind, NativeBackend, PagedNativeBackend,
    ReplicaEngine, RoundCtx, SamplingParams, ServeMetrics, ServeOptions,
    StopCriteria,
};
use ganq::model::forward::Weights;
use ganq::model::{LayerWeights, ModelConfig, QuantizedModel, WeightStore};
use ganq::obs::hist::fnum;
use ganq::obs::trace;
use ganq::quant::ganq::fit_codebook_identity;
use ganq::quant::lut::lut_from_parts;
use ganq::tensor::Mat;
use ganq::util::cli::Args;
use ganq::util::json::{self, Json};
use ganq::util::timer::Table;

fn smoke() -> bool {
    std::env::var("GANQ_SMOKE").is_ok()
}

/// Long-context micro config (same shape as the prefill bench): ctx
/// large enough for the full-size 2048-token RAG prompts.
fn long_ctx_cfg() -> ModelConfig {
    ModelConfig {
        d: 128,
        layers: 2,
        heads: 2,
        ff: 256,
        ctx: 2176,
        vocab: 256,
        eos: None,
    }
}

/// Quantize every linear to a per-row non-uniform LUT (identity
/// Hessian) — the servable form the engine packs.
fn lut_model(store: &WeightStore, bits: u8) -> QuantizedModel {
    let k = 1usize << bits;
    let mut linears = std::collections::BTreeMap::new();
    for (name, _m, _n) in store.cfg.linear_shapes() {
        let w = store.mat(&name);
        let mut codes = vec![0u8; w.rows * w.cols];
        let mut cb = Mat::zeros(w.rows, k);
        for i in 0..w.rows {
            let (c, t) = fit_codebook_identity(w.row(i), bits, 2);
            codes[i * w.cols..(i + 1) * w.cols].copy_from_slice(&c);
            cb.row_mut(i).copy_from_slice(&t);
        }
        linears.insert(
            name,
            LayerWeights::Lut(lut_from_parts(w.rows, w.cols, bits, codes, cb)),
        );
    }
    QuantizedModel {
        base: store.clone(),
        method: format!("lut{}-identity", bits),
        bits,
        linears,
        weight_bits: 0,
    }
}

/// One open-loop round against a paged-native backend built fresh on the
/// engine thread per micro-batch (requests arriving mid-round queue for
/// the next one — that wait is exactly what the queue-delay tail
/// measures).
fn traffic_round(pattern: Arrivals, seed: u64) -> TrafficReport {
    let (scale, n_requests, mean_gap_ms, slots, blocks) = if smoke() {
        (8usize, 18usize, 5.0f64, 6usize, 48usize)
    } else {
        (1, 96, 20.0, 8, 256)
    };
    let cfg = long_ctx_cfg();
    let spec = TrafficSpec {
        classes: standard_classes(scale),
        n_requests,
        mean_gap_ms,
        pattern,
        seed,
        vocab: cfg.vocab,
        deadline_ms: None,
    };
    let opts = ServeOptions::default();
    // the engine thread owns the weights; the backend (and with it the
    // block pool) is rebuilt per micro-batch round, so queue delay for
    // requests arriving mid-round is real scheduler wait
    let store = WeightStore::random("traffic", cfg, 611);
    let report = run_open_loop(&spec, opts, move |batch| {
        let w = Weights::Fp(&store);
        let mut be = PagedNativeBackend::new(
            w,
            slots,
            16,
            blocks,
            KvStoreKind::F32,
        );
        serve_batch(&mut be, batch, opts)
    });
    assert_eq!(report.lost, 0, "every stream must end in a Done");
    assert!(
        report.classes_sent() >= 4,
        "{} run covered only {} traffic classes",
        pattern.tag(),
        report.classes_sent()
    );
    report
}

/// One cluster replica over the shared weights: a fresh paged-native
/// backend per micro-batch round, same shape as the single-server
/// bench's engine loop.
struct PagedReplica {
    store: Arc<WeightStore>,
    slots: usize,
    blocks: usize,
}

impl ReplicaEngine for PagedReplica {
    fn run(&mut self, round: RoundCtx<'_>) -> Result<ServeMetrics, String> {
        let w = Weights::Fp(&self.store);
        let mut be = PagedNativeBackend::new(
            w,
            self.slots,
            16,
            self.blocks,
            KvStoreKind::F32,
        );
        round.run(&mut be)
    }
}

/// One open-loop round through the cluster router. Identical spec +
/// seed across calls, so a faulted run is directly comparable to a
/// clean one.
fn cluster_round(
    pattern: Arrivals,
    seed: u64,
    replicas: usize,
    plan: &FaultPlan,
) -> (TrafficReport, ClusterMetrics) {
    let (scale, n_requests, mean_gap_ms, slots, blocks) = if smoke() {
        (8usize, 18usize, 5.0f64, 6usize, 48usize)
    } else {
        (1, 96, 20.0, 8, 256)
    };
    let cfg = long_ctx_cfg();
    let spec = TrafficSpec {
        classes: standard_classes(scale),
        n_requests,
        mean_gap_ms,
        pattern,
        seed,
        vocab: cfg.vocab,
        deadline_ms: None,
    };
    let opts = ClusterOptions {
        backoff_ms: 5, // requeue fast: the kill is the point, not the wait
        ..ClusterOptions::default()
    };
    let store = Arc::new(WeightStore::random("traffic", cfg, 611));
    let engines: Vec<PagedReplica> = (0..replicas)
        .map(|_| PagedReplica { store: Arc::clone(&store), slots, blocks })
        .collect();
    let cluster = Cluster::spawn(engines, opts, plan);
    let (report, cm) = run_open_loop_cluster(&spec, cluster);
    assert_eq!(
        report.lost, 0,
        "every stream must end in a Done, even under faults"
    );
    assert!(
        report.classes_sent() >= 4,
        "cluster {} run covered only {} traffic classes",
        pattern.tag(),
        report.classes_sent()
    );
    (report, cm)
}

fn overhead_requests(max_new: usize) -> Vec<GenRequest> {
    (0..16u64)
        .map(|i| {
            let prompt: Vec<i32> =
                (0..8).map(|j: i32| (j * 29 + i as i32 * 13) % 256).collect();
            GenRequest::new(
                i,
                prompt,
                SamplingParams::greedy(),
                StopCriteria::max_tokens(max_new),
            )
        })
        .collect()
}

/// Best-of-`reps` wall seconds for the closed-loop batch-16 decode run.
/// With `traced` the ring recorder is installed and drained per rep —
/// the steady-state cost of every span/instant on the serve hot path.
fn measure_overhead(
    w: &Weights,
    max_new: usize,
    traced: bool,
    reps: usize,
) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        if traced {
            trace::enable(trace::DEFAULT_CAPACITY);
        } else {
            trace::disable();
        }
        let mut be = NativeBackend::new(*w, 16);
        let t0 = Instant::now();
        let (resp, m) = serve(&mut be, overhead_requests(max_new)).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(resp.len(), 16);
        assert_eq!(m.total_generated(), 16 * max_new);
        if traced {
            let (events, _) = trace::take();
            assert!(
                !events.is_empty(),
                "tracing enabled but no events recorded"
            );
        }
        best = best.min(wall);
    }
    trace::disable();
    best
}

/// Tracing tax on decode throughput: (overhead fraction, tok/s off,
/// tok/s on).
fn tracing_overhead() -> (f64, f64, f64) {
    let cfg = ModelConfig::builtin("opt-micro").unwrap();
    let store = WeightStore::random("bench", cfg, 813);
    eprintln!("fitting 4-bit LUT model for the overhead pin...");
    let qm4 = lut_model(&store, 4);
    let w = Weights::Quant(&qm4);
    let (max_new, reps) = if smoke() { (12, 2) } else { (32, 5) };
    // warmup packs weights + faults pages outside the timing
    measure_overhead(&w, 2, false, 1);
    let off_s = measure_overhead(&w, max_new, false, reps);
    let on_s = measure_overhead(&w, max_new, true, reps);
    let tokens = (16 * max_new) as f64;
    (on_s / off_s - 1.0, tokens / off_s, tokens / on_s)
}

fn main() {
    let t_all = Instant::now();
    let args = Args::from_env();
    let replicas = args.get_usize_min("replicas", 2, 1);
    let plan_spec = args.get_or("fault-plan", "kill:1@6");
    let plan = FaultPlan::parse(plan_spec)
        .unwrap_or_else(|e| panic!("--fault-plan: {}", e));
    println!(
        "open-loop serve traffic, paged-native on longctx-micro{}",
        if smoke() { " [smoke]" } else { "" }
    );

    let runs = vec![
        traffic_round(Arrivals::Poisson, 99),
        traffic_round(Arrivals::Bursty, 100),
    ];

    // goodput retention: the identical workload through the cluster,
    // clean vs fault-injected
    println!(
        "cluster rounds: {} replicas, fault plan `{}`",
        replicas, plan_spec
    );
    let (clean, cm_clean) =
        cluster_round(Arrivals::Poisson, 7, replicas, &FaultPlan::none());
    let (faulted, cm_faulted) =
        cluster_round(Arrivals::Poisson, 7, replicas, &plan);
    let goodput_retention = if clean.goodput_tok_s > 0.0 {
        faulted.goodput_tok_s / clean.goodput_tok_s
    } else {
        1.0
    };
    println!("  clean:   {}", cm_clean.summary());
    println!("  faulted: {}", cm_faulted.summary());
    for r in &cm_faulted.replicas {
        println!("  {}", r.summary());
    }
    println!(
        "  goodput {:.1} -> {:.1} tok/s, retention {:.2}",
        clean.goodput_tok_s, faulted.goodput_tok_s, goodput_retention
    );

    let mut t = Table::new(
        "open-loop traffic by arrival pattern",
        &[
            "pattern",
            "reqs",
            "goodput tok/s",
            "ttft p50/p99 ms",
            "tpot p50/p99 ms",
            "preempt",
            "rejected",
            "cancelled",
        ],
    );
    for r in &runs {
        let m = &r.metrics;
        t.row(vec![
            r.pattern.tag().into(),
            format!("{}", r.n_requests),
            format!("{:.1}", r.goodput_tok_s),
            format!("{:.0}/{:.0}", m.ttft_p50_ms(), m.ttft_p99_ms()),
            format!("{:.1}/{:.1}", m.tpot_p50_ms(), m.tpot_p99_ms()),
            format!("{}", m.preemptions),
            format!("{}", r.rejected()),
            format!("{}", r.cancelled()),
        ]);
    }
    t.print();
    let mut tc = Table::new(
        "per-class (poisson run)",
        &["class", "sent", "done", "slo ok", "ttft p99", "tpot p99"],
    );
    for c in &runs[0].per_class {
        tc.row(vec![
            c.name.into(),
            format!("{}", c.sent),
            format!("{}", c.completed),
            format!("{}", c.slo_attained),
            format!("{:.0}", c.ttft_ms.percentile(0.99)),
            format!("{:.1}", c.tpot_ms.percentile(0.99)),
        ]);
    }
    tc.print();

    let (overhead, off_tok_s, on_tok_s) = tracing_overhead();
    println!(
        "tracing: {:.0} tok/s off, {:.0} tok/s on, overhead {:+.2}%",
        off_tok_s,
        on_tok_s,
        100.0 * overhead
    );

    // headline aggregates: token-weighted goodput across both runs,
    // conservative (max) latency tails, summed event counts
    let wall_total: f64 = runs.iter().map(|r| r.wall_s).sum();
    let attained_tokens: f64 =
        runs.iter().map(|r| r.goodput_tok_s * r.wall_s).sum();
    let total_requests: usize = runs.iter().map(|r| r.n_requests).sum();
    let rejected: usize = runs.iter().map(|r| r.rejected()).sum();
    let cancelled: usize = runs.iter().map(|r| r.cancelled()).sum();
    let preemptions: usize =
        runs.iter().map(|r| r.metrics.preemptions).sum();
    let goodput =
        if wall_total > 0.0 { attained_tokens / wall_total } else { 0.0 };
    let maxf = |f: &dyn Fn(&TrafficReport) -> f64| {
        runs.iter().map(f).fold(f64::NAN, f64::max)
    };
    let out = json::obj(vec![
        ("model", json::s("longctx-micro")),
        ("backend", json::s("paged-native")),
        ("smoke", Json::Bool(smoke())),
        ("classes", json::num(runs[0].per_class.len() as f64)),
        ("requests", json::num(total_requests as f64)),
        ("goodput", json::num(goodput)),
        (
            "goodput_req_s",
            json::num(runs.iter().map(|r| r.goodput_req_s).sum::<f64>() / 2.0),
        ),
        ("ttft_p50", fnum(maxf(&|r| r.metrics.ttft_p50_ms()))),
        ("ttft_p99", fnum(maxf(&|r| r.metrics.ttft_p99_ms()))),
        ("tpot_p50", fnum(maxf(&|r| r.metrics.tpot_p50_ms()))),
        ("tpot_p99", fnum(maxf(&|r| r.metrics.tpot_p99_ms()))),
        ("preemptions", json::num(preemptions as f64)),
        ("rejected", json::num(rejected as f64)),
        (
            "rejection_rate",
            json::num(rejected as f64 / total_requests as f64),
        ),
        ("cancelled", json::num(cancelled as f64)),
        ("trace_overhead_frac", json::num(overhead)),
        ("trace_off_tok_s", json::num(off_tok_s)),
        ("trace_on_tok_s", json::num(on_tok_s)),
        ("replicas", json::num(replicas as f64)),
        ("fault_plan", json::s(plan_spec)),
        ("cluster_goodput", json::num(clean.goodput_tok_s)),
        ("cluster_goodput_faulted", json::num(faulted.goodput_tok_s)),
        ("goodput_retention", json::num(goodput_retention)),
        (
            "cluster_workers_died",
            json::num(cm_faulted.workers_died as f64),
        ),
        ("cluster_requeues", json::num(cm_faulted.requeues as f64)),
        ("cluster_shed", json::num(cm_faulted.shed as f64)),
        (
            "cluster_affinity_hits",
            json::num(cm_faulted.affinity_hits as f64),
        ),
        ("wall_s", json::num(t_all.elapsed().as_secs_f64())),
        (
            "runs",
            Json::Arr(
                runs.iter()
                    .chain([&clean, &faulted])
                    .map(|r| r.to_json())
                    .collect(),
            ),
        ),
    ]);
    std::fs::write("BENCH_serve.json", out.to_string_pretty())
        .expect("write BENCH_serve.json");
    println!("\nwrote BENCH_serve.json");

    assert!(
        goodput.is_finite() && goodput >= 0.0,
        "goodput must be a finite number, got {}",
        goodput
    );
    let killed = plan
        .faults
        .iter()
        .any(|f| matches!(f, Fault::Kill { .. }));
    if killed {
        assert!(
            cm_faulted.workers_died >= 1,
            "acceptance FAILED: fault plan `{}` includes a kill but no \
             worker died",
            plan_spec
        );
    }
    assert!(
        goodput_retention >= 0.70,
        "acceptance FAILED: goodput retention {:.2} under fault plan `{}` \
         (need >= 0.70: survivors must absorb a killed replica's load)",
        goodput_retention,
        plan_spec
    );
    let bar = if smoke() { 0.50 } else { 0.05 };
    assert!(
        overhead < bar,
        "acceptance FAILED: enabled tracing costs {:.1}% of batch-16 lut4 \
         decode throughput (need < {:.0}%)",
        100.0 * overhead,
        100.0 * bar
    );
    println!(
        "acceptance OK: tracing overhead {:.2}% < {:.0}% on batch-16 lut4 \
         decode; goodput {:.1} tok/s over {} requests x 2 arrival patterns; \
         goodput retention {:.2} >= 0.70 under `{}` with {} replicas",
        100.0 * overhead,
        100.0 * bar,
        goodput,
        total_requests,
        goodput_retention,
        plan_spec,
        replicas
    );
}
