//! Sampling-overhead bench: the same batch-16 serving workload decoded
//! greedy (temperature 0, pure argmax) vs sampled (temperature 0.9,
//! top-k 64, top-p 0.95, per-request seeds) through the real scheduler +
//! `NativeBackend` on the 4-bit LUT model. Token counts are identical by
//! construction (budget-only stop criteria), so the wall-clock delta is
//! exactly the Sampler stage: the per-row sort + softmax + one RNG draw.
//! Emits `BENCH_sampling.json`.
//!
//! Asserts the acceptance criterion: sampling adds < 5% per-step
//! overhead vs greedy at batch 16. `GANQ_SMOKE=1` shrinks the run for CI
//! and relaxes the bar to < 50% (shared runners are noisy).

use std::time::Instant;

use ganq::coordinator::{
    serve, GenRequest, NativeBackend, SamplingParams, StopCriteria,
};
use ganq::model::forward::Weights;
use ganq::model::{LayerWeights, ModelConfig, QuantizedModel, WeightStore};
use ganq::obs::hist::Samples;
use ganq::quant::ganq::fit_codebook_identity;
use ganq::quant::lut::lut_from_parts;
use ganq::tensor::Mat;
use ganq::util::json::{self, Json};

const BATCH: usize = 16;
const PROMPT_LEN: usize = 8;

fn smoke() -> bool {
    std::env::var("GANQ_SMOKE").is_ok()
}

/// Quantize every linear to a per-row non-uniform LUT (identity
/// Hessian) — the servable form the engine packs.
fn lut_model(store: &WeightStore, bits: u8) -> QuantizedModel {
    let k = 1usize << bits;
    let mut linears = std::collections::BTreeMap::new();
    for (name, _m, _n) in store.cfg.linear_shapes() {
        let w = store.mat(&name);
        let mut codes = vec![0u8; w.rows * w.cols];
        let mut cb = Mat::zeros(w.rows, k);
        for i in 0..w.rows {
            let (c, t) = fit_codebook_identity(w.row(i), bits, 2);
            codes[i * w.cols..(i + 1) * w.cols].copy_from_slice(&c);
            cb.row_mut(i).copy_from_slice(&t);
        }
        linears.insert(
            name,
            LayerWeights::Lut(lut_from_parts(w.rows, w.cols, bits, codes, cb)),
        );
    }
    QuantizedModel {
        base: store.clone(),
        method: format!("lut{}-identity", bits),
        bits,
        linears,
        weight_bits: 0,
    }
}

fn requests(max_new: usize, sampled: bool) -> Vec<GenRequest> {
    (0..BATCH as u64)
        .map(|i| {
            let prompt: Vec<i32> = (0..PROMPT_LEN as i32)
                .map(|j| (j * 29 + i as i32 * 13) % 256)
                .collect();
            let sampling = if sampled {
                SamplingParams::sample(0.9, 7000 + i)
                    .with_top_k(64)
                    .with_top_p(0.95)
            } else {
                SamplingParams::greedy()
            };
            GenRequest::new(
                i,
                prompt,
                sampling,
                StopCriteria::max_tokens(max_new),
            )
        })
        .collect()
}

/// Best-of-`reps` wall seconds serving the batch to completion.
fn measure(w: &Weights, max_new: usize, sampled: bool, reps: usize) -> f64 {
    let mut walls = Samples::new();
    for _ in 0..reps {
        let mut be = NativeBackend::new(*w, BATCH);
        let t0 = Instant::now();
        let (resp, m) = serve(&mut be, requests(max_new, sampled)).unwrap();
        walls.push(t0.elapsed().as_secs_f64());
        assert_eq!(resp.len(), BATCH);
        assert_eq!(m.total_generated(), BATCH * max_new);
    }
    walls.min()
}

fn main() {
    let cfg = ModelConfig::builtin("opt-micro").unwrap();
    let store = WeightStore::random("bench", cfg, 813);
    eprintln!("fitting 4-bit LUT model...");
    let qm4 = lut_model(&store, 4);
    let w = Weights::Quant(&qm4);
    let (max_new, reps) = if smoke() { (12, 2) } else { (32, 5) };
    println!(
        "sampling overhead, opt-micro lut4, batch {} x {} tokens, best of \
         {} rep(s){}",
        BATCH,
        max_new,
        reps,
        if smoke() { " [smoke]" } else { "" }
    );

    // warmup (packs weights, faults pages) outside the timing
    measure(&w, 2, true, 1);
    let greedy_s = measure(&w, max_new, false, reps);
    let sampled_s = measure(&w, max_new, true, reps);
    let tokens = (BATCH * max_new) as f64;
    let overhead = sampled_s / greedy_s - 1.0;
    println!(
        "greedy {:.0} tok/s, sampled {:.0} tok/s, overhead {:+.2}%",
        tokens / greedy_s,
        tokens / sampled_s,
        100.0 * overhead
    );

    let out = json::obj(vec![
        ("model", json::s("opt-micro")),
        ("fmt", json::s("lut4")),
        ("batch", json::num(BATCH as f64)),
        ("max_new", json::num(max_new as f64)),
        ("smoke", Json::Bool(smoke())),
        ("greedy_tok_s", json::num(tokens / greedy_s)),
        ("sampled_tok_s", json::num(tokens / sampled_s)),
        ("overhead_frac", json::num(overhead)),
    ]);
    std::fs::write("BENCH_sampling.json", out.to_string_pretty())
        .expect("write BENCH_sampling.json");
    println!("wrote BENCH_sampling.json");

    let bar = if smoke() { 0.50 } else { 0.05 };
    assert!(
        overhead < bar,
        "acceptance FAILED: sampling adds {:.1}% per-step overhead at \
         batch {} (need < {:.0}%)",
        100.0 * overhead,
        BATCH,
        100.0 * bar
    );
    println!(
        "acceptance OK: sampling adds {:.2}% overhead vs greedy at batch {}",
        100.0 * overhead,
        BATCH
    );
}
