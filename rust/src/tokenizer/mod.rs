//! Byte-level tokenizer. The model family is byte-level (vocab 256), so
//! tokenization is identity over bytes — this module still owns the
//! boundary (token type, detokenization, prompt assembly) so a subword
//! tokenizer could be swapped in without touching the coordinator.

pub type Token = i32;

#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn new() -> Self {
        ByteTokenizer
    }

    pub fn vocab_size(&self) -> usize {
        256
    }

    pub fn encode(&self, text: &[u8]) -> Vec<Token> {
        text.iter().map(|&b| b as Token).collect()
    }

    pub fn decode(&self, tokens: &[Token]) -> Vec<u8> {
        tokens
            .iter()
            .map(|&t| t.clamp(0, 255) as u8)
            .collect()
    }

    pub fn decode_string(&self, tokens: &[Token]) -> String {
        String::from_utf8_lossy(&self.decode(tokens)).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = ByteTokenizer::new();
        let text = b"hello, ganq. 3+4=7";
        let toks = t.encode(text);
        assert_eq!(toks.len(), text.len());
        assert_eq!(t.decode(&toks), text.to_vec());
    }

    #[test]
    fn clamps_out_of_range() {
        let t = ByteTokenizer::new();
        assert_eq!(t.decode(&[-5, 300]), vec![0u8, 255]);
    }
}
