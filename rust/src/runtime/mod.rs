//! PJRT runtime: loads AOT HLO-text artifacts, compiles them once per
//! process (executable cache), and executes them from the coordinator hot
//! path. Adapts the /opt/xla-example/load_hlo pattern: HLO *text* is the
//! interchange format (jax >= 0.5 protos are rejected by xla_extension
//! 0.5.1; the text parser reassigns instruction ids).

pub mod artifacts;
pub mod ganq_hlo;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use crate::obs::trace;

pub use artifacts::{AnyPrecEntry, Dtype, GraphSpec, Manifest, TensorSpec};

/// Host-side tensor value crossing the runtime boundary.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
    U8(Vec<usize>, Vec<u8>),
}

/// The empty tensor — exists so hot paths can `mem::take` a cache out of
/// a struct field, hand it to the runtime by reference, and move the
/// graph output back in without ever cloning the buffer.
impl Default for HostTensor {
    fn default() -> HostTensor {
        HostTensor::F32(Vec::new(), Vec::new())
    }
}

/// A dtype accessor was called on a tensor of a different dtype —
/// carries both sides so graph-output mismatches are diagnosable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DtypeMismatch {
    pub expected: Dtype,
    pub actual: Dtype,
}

impl std::fmt::Display for DtypeMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "dtype mismatch: expected {:?}, got {:?}",
            self.expected, self.actual
        )
    }
}

impl From<DtypeMismatch> for String {
    fn from(e: DtypeMismatch) -> String {
        e.to_string()
    }
}

impl HostTensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            HostTensor::F32(d, _) | HostTensor::I32(d, _) | HostTensor::U8(d, _) => d,
        }
    }

    pub fn numel(&self) -> usize {
        self.dims().iter().product()
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32(..) => Dtype::F32,
            HostTensor::I32(..) => Dtype::I32,
            HostTensor::U8(..) => Dtype::U8,
        }
    }

    fn mismatch(&self, expected: Dtype) -> DtypeMismatch {
        DtypeMismatch { expected, actual: self.dtype() }
    }

    pub fn as_f32(&self) -> Result<&[f32], DtypeMismatch> {
        match self {
            HostTensor::F32(_, v) => Ok(v),
            _ => Err(self.mismatch(Dtype::F32)),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32], DtypeMismatch> {
        match self {
            HostTensor::I32(_, v) => Ok(v),
            _ => Err(self.mismatch(Dtype::I32)),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8], DtypeMismatch> {
        match self {
            HostTensor::U8(_, v) => Ok(v),
            _ => Err(self.mismatch(Dtype::U8)),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32, DtypeMismatch> {
        Ok(self.as_f32()?[0])
    }

    /// (element type, dims, little-endian bytes) for raw-buffer upload.
    pub fn to_raw(&self) -> (xla::ElementType, &[usize], Vec<u8>) {
        match self {
            HostTensor::F32(d, v) => (
                xla::ElementType::F32,
                d,
                v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            ),
            HostTensor::I32(d, v) => (
                xla::ElementType::S32,
                d,
                v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            ),
            HostTensor::U8(d, v) => (xla::ElementType::U8, d, v.clone()),
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal, String> {
        let (ty, dims, bytes): (xla::ElementType, &[usize], Vec<u8>) =
            match self {
                HostTensor::F32(d, v) => (
                    xla::ElementType::F32,
                    d,
                    v.iter().flat_map(|x| x.to_le_bytes()).collect(),
                ),
                HostTensor::I32(d, v) => (
                    xla::ElementType::S32,
                    d,
                    v.iter().flat_map(|x| x.to_le_bytes()).collect(),
                ),
                HostTensor::U8(d, v) => {
                    (xla::ElementType::U8, d, v.clone())
                }
            };
        xla::Literal::create_from_shape_and_untyped_data(ty, dims, &bytes)
            .map_err(|e| format!("literal: {:?}", e))
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor, String> {
        let shape = lit
            .array_shape()
            .map_err(|e| format!("shape: {:?}", e))?;
        let dims: Vec<usize> =
            shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32(
                dims,
                lit.to_vec::<f32>().map_err(|e| format!("{:?}", e))?,
            )),
            xla::ElementType::S32 => Ok(HostTensor::I32(
                dims,
                lit.to_vec::<i32>().map_err(|e| format!("{:?}", e))?,
            )),
            xla::ElementType::U8 => Ok(HostTensor::U8(
                dims,
                lit.to_vec::<u8>().map_err(|e| format!("{:?}", e))?,
            )),
            other => Err(format!("unsupported output dtype {:?}", other)),
        }
    }
}

/// The PJRT runtime. Not Sync: owns raw PJRT handles; the coordinator
/// keeps it on a single engine thread.
pub struct Runtime {
    pub base: PathBuf,
    pub manifest: Manifest,
    pub client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Load from the resolved artifacts directory.
    pub fn load() -> Result<Runtime, String> {
        let base = crate::util::artifacts_dir();
        let manifest = Manifest::load(&base)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| format!("pjrt cpu client: {:?}", e))?;
        Ok(Runtime {
            base,
            manifest,
            client,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn has_graph(&self, name: &str) -> bool {
        self.manifest.graphs.contains_key(name)
    }

    pub fn graph(&self, name: &str) -> Result<&GraphSpec, String> {
        self.manifest
            .graphs
            .get(name)
            .ok_or_else(|| format!("no graph '{}' in manifest", name))
    }

    /// Compile (or fetch cached) executable for a graph.
    pub fn executable(
        &self,
        name: &str,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>, String> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.graph(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            spec.path.to_str().ok_or("bad path")?,
        )
        .map_err(|e| format!("parse hlo {}: {:?}", name, e))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format!("compile {}: {:?}", name, e))?;
        let rc = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Validate inputs against the manifest spec (shape + dtype).
    fn check_inputs(
        spec: &GraphSpec,
        inputs: &[&HostTensor],
    ) -> Result<(), String> {
        if spec.inputs.len() != inputs.len() {
            return Err(format!(
                "graph {} expects {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            ));
        }
        for (ts, ht) in spec.inputs.iter().zip(inputs) {
            if ts.dims != ht.dims() || ts.dtype != ht.dtype() {
                return Err(format!(
                    "graph {} input '{}': expected {:?}{:?}, got {:?}{:?}",
                    spec.name,
                    ts.name,
                    ts.dtype,
                    ts.dims,
                    ht.dtype(),
                    ht.dims()
                ));
            }
        }
        Ok(())
    }

    /// Execute a graph with host tensors; returns the decomposed output
    /// tuple as host tensors.
    pub fn run(
        &self,
        name: &str,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>, String> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.run_refs(name, &refs)
    }

    /// [`Runtime::run`] over borrowed inputs — the serving hot path hands
    /// per-step tensors and the long weight tail as references, so no
    /// host-side weight copy happens per step.
    pub fn run_refs(
        &self,
        name: &str,
        inputs: &[&HostTensor],
    ) -> Result<Vec<HostTensor>, String> {
        let _sp = trace::span("pjrt.run");
        let spec = self.graph(name)?.clone();
        Self::check_inputs(&spec, inputs)?;
        let exe = self.executable(name)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_, _>>()?;
        let out = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| format!("execute {}: {:?}", name, e))?;
        let result = out[0][0]
            .to_literal_sync()
            .map_err(|e| format!("to_literal {}: {:?}", name, e))?;
        let parts = result
            .to_tuple()
            .map_err(|e| format!("tuple {}: {:?}", name, e))?;
        parts.iter().map(HostTensor::from_literal).collect()
    }

    /// Execute with pre-staged device buffers for the weight suffix of the
    /// argument list (serving hot path: weights upload once). `head` are
    /// per-step host tensors; `tail` are resident buffers.
    pub fn run_with_resident(
        &self,
        name: &str,
        head: &[HostTensor],
        tail: &[xla::PjRtBuffer],
    ) -> Result<Vec<HostTensor>, String> {
        let _sp = trace::span("pjrt.run");
        let exe = self.executable(name)?;
        let mut bufs: Vec<&xla::PjRtBuffer> = Vec::new();
        let head_bufs: Vec<xla::PjRtBuffer> = head
            .iter()
            .map(|t| self.upload(t))
            .collect::<Result<_, String>>()?;
        bufs.extend(head_bufs.iter());
        bufs.extend(tail.iter());
        let out = exe
            .execute_b(&bufs)
            .map_err(|e| format!("execute_b {}: {:?}", name, e))?;
        let result = out[0][0]
            .to_literal_sync()
            .map_err(|e| format!("to_literal {}: {:?}", name, e))?;
        let parts =
            result.to_tuple().map_err(|e| format!("tuple: {:?}", e))?;
        parts.iter().map(HostTensor::from_literal).collect()
    }

    /// Upload one host tensor to a device buffer. Uses the *typed*
    /// buffer_from_host_buffer path: the C shim runs it with
    /// kImmutableOnlyDuringCall semantics (synchronous copy), whereas
    /// buffer_from_host_literal copies *asynchronously* and races with the
    /// literal being dropped (observed SIGSEGV in AbstractTfrtCpuBuffer::
    /// CopyFromLiteral). The raw-bytes variant is also unusable: it passes
    /// `ElementType as i32` where the C API expects a PrimitiveType value
    /// (F32 -> F16), corrupting the buffer size.
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer, String> {
        match t {
            HostTensor::F32(d, v) => self
                .client
                .buffer_from_host_buffer(v, d, None)
                .map_err(|e| format!("upload f32: {:?}", e)),
            HostTensor::I32(d, v) => self
                .client
                .buffer_from_host_buffer(v, d, None)
                .map_err(|e| format!("upload i32: {:?}", e)),
            HostTensor::U8(d, v) => self
                .client
                .buffer_from_host_buffer(v, d, None)
                .map_err(|e| format!("upload u8: {:?}", e)),
        }
    }

    /// Upload host tensors to device buffers (weights staging).
    pub fn stage(
        &self,
        tensors: &[HostTensor],
    ) -> Result<Vec<xla::PjRtBuffer>, String> {
        tensors.iter().map(|t| self.upload(t)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_roundtrip_via_literal() {
        for t in [
            HostTensor::F32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.5]),
            HostTensor::I32(vec![4], vec![-1, 0, 7, 2_000_000]),
            HostTensor::U8(vec![2, 2], vec![0, 127, 200, 255]),
        ] {
            let lit = t.to_literal().unwrap();
            let back = HostTensor::from_literal(&lit).unwrap();
            assert_eq!(back.dims(), t.dims());
            match (&t, &back) {
                (HostTensor::F32(_, a), HostTensor::F32(_, b)) => {
                    assert_eq!(a, b)
                }
                (HostTensor::I32(_, a), HostTensor::I32(_, b)) => {
                    assert_eq!(a, b)
                }
                (HostTensor::U8(_, a), HostTensor::U8(_, b)) => {
                    assert_eq!(a, b)
                }
                _ => panic!("dtype changed"),
            }
        }
    }

    #[test]
    fn dtype_accessors_carry_expected_and_actual() {
        let t = HostTensor::I32(vec![1], vec![7]);
        assert_eq!(t.as_i32().unwrap(), &[7]);
        let err = t.as_f32().unwrap_err();
        assert_eq!(
            err,
            DtypeMismatch { expected: Dtype::F32, actual: Dtype::I32 }
        );
        let msg: String = err.into();
        assert!(msg.contains("expected F32"), "{}", msg);
        assert!(msg.contains("got I32"), "{}", msg);
        assert!(t.scalar_f32().is_err());
        assert!(HostTensor::F32(vec![1], vec![2.5]).scalar_f32().unwrap() == 2.5);
        assert!(HostTensor::U8(vec![1], vec![3]).as_u8().is_ok());
    }

    #[test]
    fn shape_validation_messages() {
        let spec = GraphSpec {
            name: "g".into(),
            path: "x".into(),
            inputs: vec![TensorSpec {
                name: "a".into(),
                dtype: Dtype::F32,
                dims: vec![2],
            }],
            outputs: vec!["y".into()],
        };
        let bad = HostTensor::F32(vec![3], vec![0.0; 3]);
        let err = Runtime::check_inputs(&spec, &[&bad]).unwrap_err();
        assert!(err.contains("input 'a'"), "{}", err);
        assert!(Runtime::check_inputs(&spec, &[]).is_err());
        let ok = HostTensor::F32(vec![2], vec![0.0; 2]);
        assert!(Runtime::check_inputs(&spec, &[&ok]).is_ok());
    }
}
