//! GANQ through the AOT stack: the L2 solver graph (with the L1 Pallas
//! back-substitution kernel inside its scan) executed via PJRT. The Rust
//! side computes the preconditioning + Cholesky factor natively (tensor::
//! linalg) and hands (W, L, T0) to the `ganq{bits}_{m}x{n}` artifact.
//!
//! Cross-validated against the native solver (quant::ganq) in integration
//! tests; the ablation bench compares their wall-clock.

use crate::quant::lut::lut_from_parts;
use crate::quant::{rtn, QuantResult, Storage};
use crate::tensor::{linalg, Mat};

use super::{HostTensor, Runtime};

/// Quantize one layer via the AOT GANQ graph. Returns None if no artifact
/// exists for this (bits, m, n) shape — callers fall back to the native
/// solver.
pub fn quantize_layer_hlo(
    rt: &Runtime,
    w: &Mat,
    h: &Mat,
    bits: u8,
) -> Result<Option<QuantResult>, String> {
    let (m, n) = (w.rows, w.cols);
    let graph = format!("ganq{}_{}x{}", bits, m, n);
    if !rt.has_graph(&graph) {
        return Ok(None);
    }
    let hp = linalg::precondition(h);
    let l = linalg::cholesky(&hp)
        .ok_or("preconditioned H not SPD (unexpected)")?;
    let (_, t0) = rtn::rtn_codebook(w, bits);
    let k = 1usize << bits;

    let inputs = [
        HostTensor::F32(vec![m, n], w.data.clone()),
        HostTensor::F32(vec![n, n], l.data.clone()),
        HostTensor::F32(vec![m, k], t0.data.clone()),
    ];
    let out = rt.run(&graph, &inputs)?;
    if out.len() != 3 {
        return Err(format!("ganq graph returned {} outputs", out.len()));
    }
    let q = out[0].as_i32()?;
    let t = Mat::from_vec(m, k, out[1].as_f32()?.to_vec());
    let codes: Vec<u8> = q.iter().map(|&c| c.clamp(0, 255) as u8).collect();
    let lut = lut_from_parts(m, n, bits, codes, t);
    let w_hat = lut.dequant();
    let storage = Storage {
        code_bits: m * n * bits as usize,
        meta_bits: m * k * 16,
        sparse_bits: 0,
    };
    Ok(Some(QuantResult {
        method: "ganq-hlo".into(),
        bits,
        w_hat,
        lut: Some(lut),
        sparse: None,
        storage,
    }))
}

/// Per-iteration errors from the graph (third output) — used by the
/// monotonicity integration test and the ablation bench.
pub fn solve_errors_hlo(
    rt: &Runtime,
    w: &Mat,
    h: &Mat,
    bits: u8,
) -> Result<Option<Vec<f32>>, String> {
    let (m, n) = (w.rows, w.cols);
    let graph = format!("ganq{}_{}x{}", bits, m, n);
    if !rt.has_graph(&graph) {
        return Ok(None);
    }
    let hp = linalg::precondition(h);
    let l = linalg::cholesky(&hp).ok_or("not SPD")?;
    let (_, t0) = rtn::rtn_codebook(w, bits);
    let k = 1usize << bits;
    let out = rt.run(
        &graph,
        &[
            HostTensor::F32(vec![m, n], w.data.clone()),
            HostTensor::F32(vec![n, n], l.data.clone()),
            HostTensor::F32(vec![m, k], t0.data.clone()),
        ],
    )?;
    Ok(Some(out[2].as_f32()?.to_vec()))
}
