//! Manifest parsing: artifacts/manifest.json describes every AOT graph
//! (path, ordered input specs, output names) and every model (config,
//! weight files, param order). Written by python/compile/aot.py.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::model::ModelConfig;
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U8,
}

impl Dtype {
    pub fn parse(s: &str) -> Option<Dtype> {
        match s {
            "f32" => Some(Dtype::F32),
            "i32" => Some(Dtype::I32),
            "u8" => Some(Dtype::U8),
            _ => None,
        }
    }

    pub fn size(&self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::U8 => 1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: Dtype,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct GraphSpec {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<String>,
}

/// One nested any-precision artifact for a model: a single bit-plane
/// file serving every width in `widths` (resident once; only per-width
/// codebooks repeat). Written by python/compile/aot.py's nested export.
#[derive(Debug, Clone)]
pub struct AnyPrecEntry {
    pub widths: Vec<u8>,
    pub path: PathBuf,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub config: ModelConfig,
    pub base_config: String,
    /// Optional nested any-precision family (`"anyprec"` in the
    /// manifest): one artifact, many servable widths.
    pub anyprec: Option<AnyPrecEntry>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub ganq_iters: usize,
    pub models: BTreeMap<String, ModelEntry>,
    pub graphs: BTreeMap<String, GraphSpec>,
}

impl Manifest {
    pub fn load(base: &Path) -> Result<Manifest, String> {
        let txt = std::fs::read_to_string(base.join("manifest.json"))
            .map_err(|e| format!("read manifest: {}", e))?;
        Self::parse(&txt, base)
    }

    pub fn parse(txt: &str, base: &Path) -> Result<Manifest, String> {
        let j = Json::parse(txt)?;
        let ganq_iters = j
            .get("ganq_iters")
            .and_then(|v| v.as_usize())
            .unwrap_or(10);
        let mut models = BTreeMap::new();
        for (name, m) in
            j.get("models").and_then(|v| v.as_obj()).ok_or("models")?
        {
            let config = ModelConfig::from_json(
                m.get("config").ok_or("model config")?,
            )
            .ok_or("bad config")?;
            let base_config = m
                .get("base_config")
                .and_then(|v| v.as_str())
                .unwrap_or(name)
                .to_string();
            let anyprec = match m.get("anyprec") {
                None => None,
                Some(a) => {
                    let mut widths: Vec<u8> = a
                        .get("widths")
                        .and_then(|v| v.as_usize_vec())
                        .ok_or("anyprec widths")?
                        .into_iter()
                        .map(|w| w as u8)
                        .collect();
                    widths.sort_unstable();
                    widths.dedup();
                    if widths.is_empty() {
                        return Err(format!("{}: empty anyprec widths", name));
                    }
                    let rel = a
                        .get("path")
                        .and_then(|v| v.as_str())
                        .ok_or("anyprec path")?;
                    Some(AnyPrecEntry { widths, path: base.join(rel) })
                }
            };
            models.insert(
                name.clone(),
                ModelEntry { config, base_config, anyprec },
            );
        }
        let mut graphs = BTreeMap::new();
        for (name, g) in
            j.get("graphs").and_then(|v| v.as_obj()).ok_or("graphs")?
        {
            let rel = g.get("path").and_then(|v| v.as_str()).ok_or("path")?;
            let mut inputs = Vec::new();
            for i in
                g.get("inputs").and_then(|v| v.as_arr()).ok_or("inputs")?
            {
                inputs.push(TensorSpec {
                    name: i
                        .get("name")
                        .and_then(|v| v.as_str())
                        .ok_or("input name")?
                        .to_string(),
                    dtype: Dtype::parse(
                        i.get("dtype").and_then(|v| v.as_str()).ok_or("dt")?,
                    )
                    .ok_or("bad dtype")?,
                    dims: i
                        .get("dims")
                        .and_then(|v| v.as_usize_vec())
                        .ok_or("dims")?,
                });
            }
            let outputs = g
                .get("outputs")
                .and_then(|v| v.as_arr())
                .ok_or("outputs")?
                .iter()
                .map(|o| o.as_str().unwrap_or("").to_string())
                .collect();
            graphs.insert(
                name.clone(),
                GraphSpec {
                    name: name.clone(),
                    path: base.join(rel),
                    inputs,
                    outputs,
                },
            );
        }
        Ok(Manifest { ganq_iters, models, graphs })
    }

    /// Chunk sizes with a compiled positioned-prefill graph for this
    /// (format, base config, batch) — ascending. Serving uses this to
    /// size `HloBackend::max_chunk` and to bucket prompt runs onto the
    /// `prefill_{fmt}_{model}_b{B}_c{C}` family; empty means the backend
    /// falls back to per-token prefill through the decode graph.
    pub fn prefill_chunks(
        &self,
        fmt: &str,
        base_config: &str,
        b: usize,
    ) -> Vec<usize> {
        let prefix = format!("prefill_{}_{}_b{}_c", fmt, base_config, b);
        let mut out: Vec<usize> = self
            .graphs
            .keys()
            .filter_map(|name| name.strip_prefix(&prefix))
            .filter_map(|c| c.parse().ok())
            .collect();
        out.sort_unstable();
        out
    }

    /// The nested any-precision family for a model, if the manifest
    /// declares one (one artifact path + its servable widths).
    pub fn anyprec(&self, model: &str) -> Option<&AnyPrecEntry> {
        self.models.get(model).and_then(|m| m.anyprec.as_ref())
    }

    /// The graph name `prefill_chunks` enumerated — one compiled chunk.
    pub fn prefill_graph(
        fmt: &str,
        base_config: &str,
        b: usize,
        chunk: usize,
    ) -> String {
        format!("prefill_{}_{}_b{}_c{}", fmt, base_config, b, chunk)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "ganq_iters": 10,
      "models": {"opt-micro": {"config": {"d":64,"layers":2,"heads":2,"ff":256,"ctx":128,"vocab":256}, "base_config": "opt-micro"}},
      "graphs": {"g1": {"path": "hlo/g1.hlo.txt",
        "inputs": [{"name":"x","dtype":"f32","dims":[2,3]},
                   {"name":"q","dtype":"u8","dims":[4]}],
        "outputs": ["y"]}}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        assert_eq!(m.ganq_iters, 10);
        let g = &m.graphs["g1"];
        assert_eq!(g.inputs.len(), 2);
        assert_eq!(g.inputs[0].dtype, Dtype::F32);
        assert_eq!(g.inputs[0].numel(), 6);
        assert_eq!(g.inputs[1].dtype, Dtype::U8);
        assert!(g.path.ends_with("hlo/g1.hlo.txt"));
        let cfg = m.models["opt-micro"].config;
        assert_eq!(cfg.d, 64);
    }

    #[test]
    fn enumerates_prefill_chunks() {
        let extra = r#"
          "prefill_lut4_opt-mini_b4_c32":
            {"path": "hlo/p32.hlo.txt", "inputs": [], "outputs": ["l"]},
          "prefill_lut4_opt-mini_b4_c8":
            {"path": "hlo/p8.hlo.txt", "inputs": [], "outputs": ["l"]},
          "prefill_lut4_opt-mini_b1_c16":
            {"path": "hlo/p16.hlo.txt", "inputs": [], "outputs": ["l"]},
          "prefill_lut4_opt-mini_b4_cbad":
            {"path": "hlo/px.hlo.txt", "inputs": [], "outputs": []},
          "g1""#;
        let txt = SAMPLE.replace("\"g1\"", extra);
        let m = Manifest::parse(&txt, Path::new("/art")).unwrap();
        assert_eq!(m.prefill_chunks("lut4", "opt-mini", 4), vec![8, 32]);
        assert_eq!(m.prefill_chunks("lut4", "opt-mini", 1), vec![16]);
        assert!(m.prefill_chunks("fp32", "opt-mini", 4).is_empty());
        assert!(m.prefill_chunks("lut4", "opt-small", 4).is_empty());
        assert_eq!(
            Manifest::prefill_graph("lut4", "opt-mini", 4, 8),
            "prefill_lut4_opt-mini_b4_c8"
        );
    }

    #[test]
    fn parses_anyprec_family() {
        // no family declared -> None
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        assert!(m.anyprec("opt-micro").is_none());
        assert!(m.anyprec("nope").is_none());
        // declared family: widths sorted + deduped, path joined on base
        let with = SAMPLE.replace(
            "\"base_config\": \"opt-micro\"",
            "\"base_config\": \"opt-micro\", \
             \"anyprec\": {\"widths\": [4, 2, 3, 3], \
                           \"path\": \"quant/opt-micro.anyprec.bin\"}",
        );
        let m = Manifest::parse(&with, Path::new("/art")).unwrap();
        let ap = m.anyprec("opt-micro").unwrap();
        assert_eq!(ap.widths, vec![2, 3, 4]);
        assert!(ap.path.ends_with("quant/opt-micro.anyprec.bin"));
        // malformed families fail loudly
        let empty = SAMPLE.replace(
            "\"base_config\": \"opt-micro\"",
            "\"base_config\": \"opt-micro\", \
             \"anyprec\": {\"widths\": [], \"path\": \"q.bin\"}",
        );
        assert!(Manifest::parse(&empty, Path::new("/art")).is_err());
        let no_path = SAMPLE.replace(
            "\"base_config\": \"opt-micro\"",
            "\"base_config\": \"opt-micro\", \
             \"anyprec\": {\"widths\": [2, 4]}",
        );
        assert!(Manifest::parse(&no_path, Path::new("/art")).is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("\"u8\"", "\"u7\"");
        assert!(Manifest::parse(&bad, Path::new("/")).is_err());
    }
}
