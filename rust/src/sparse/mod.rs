//! CSR sparse matrix + SpMV/SpMM — the substrate for GANQ*'s outlier
//! branch (paper §3.3): y = W_dense_hat x + W_sparse x, where W_sparse
//! holds the extracted outliers (~0.5% nnz).

use crate::tensor::Mat;

#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    /// Build from a dense matrix keeping nonzeros.
    pub fn from_dense(m: &Mat) -> Csr {
        let mut row_ptr = Vec::with_capacity(m.rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0);
        for i in 0..m.rows {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    col_idx.push(j as u32);
                    values.push(v);
                }
            }
            row_ptr.push(values.len());
        }
        Csr { rows: m.rows, cols: m.cols, row_ptr, col_idx, values }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Storage bytes: values f32 + 32-bit col indices + row pointers.
    pub fn storage_bytes(&self) -> usize {
        self.values.len() * 4 + self.col_idx.len() * 4 + self.row_ptr.len() * 8
    }

    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                out[(i, self.col_idx[k] as usize)] = self.values[k];
            }
        }
        out
    }

    /// y += A x for a single activation vector x (len = cols).
    pub fn spmv_add(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            let mut acc = 0.0f32;
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                acc += self.values[k] * x[self.col_idx[k] as usize];
            }
            y[i] += acc;
        }
    }

    /// Y += X A^T for a batch X [p, cols] -> adds into Y [p, rows]
    /// (activation-major layout used by the serving path).
    pub fn spmm_add(&self, x: &Mat, y: &mut Mat) {
        assert_eq!(x.cols, self.cols);
        assert_eq!(y.cols, self.rows);
        assert_eq!(x.rows, y.rows);
        for p in 0..x.rows {
            let xr = x.row(p);
            let yr = y.row_mut(p);
            for i in 0..self.rows {
                let mut acc = 0.0f32;
                for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                    acc += self.values[k] * xr[self.col_idx[k] as usize];
                }
                yr[i] += acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand_sparse(rng: &mut Rng, r: usize, c: usize, density: f64) -> Mat {
        let mut m = Mat::zeros(r, c);
        for v in &mut m.data {
            if rng.uniform() < density {
                *v = rng.normal() as f32;
            }
        }
        m
    }

    #[test]
    fn dense_roundtrip() {
        prop::check("csr_roundtrip", 21, 10, |rng, _| {
            let r = 1 + rng.below(20) as usize;
            let c = 1 + rng.below(20) as usize;
            let m = rand_sparse(rng, r, c, 0.2);
            let csr = Csr::from_dense(&m);
            crate::prop_assert!(csr.to_dense() == m, "roundtrip failed");
            Ok(())
        });
    }

    #[test]
    fn spmv_matches_dense() {
        prop::check("spmv", 22, 10, |rng, _| {
            let r = 1 + rng.below(16) as usize;
            let c = 1 + rng.below(16) as usize;
            let m = rand_sparse(rng, r, c, 0.3);
            let csr = Csr::from_dense(&m);
            let x: Vec<f32> = rng.normal_vec_f32(c);
            let mut y = vec![0.0f32; r];
            csr.spmv_add(&x, &mut y);
            for i in 0..r {
                let expect = crate::tensor::dot(m.row(i), &x);
                crate::prop_assert!(
                    prop::close(y[i] as f64, expect as f64, 1e-4, 1e-4),
                    "row {}",
                    i
                );
            }
            Ok(())
        });
    }

    #[test]
    fn spmm_matches_matmul_tb() {
        let mut rng = Rng::new(23);
        let m = rand_sparse(&mut rng, 12, 8, 0.25);
        let csr = Csr::from_dense(&m);
        let x = Mat::from_vec(5, 8, rng.normal_vec_f32(40));
        let mut y = Mat::zeros(5, 12);
        csr.spmm_add(&x, &mut y);
        let expect = x.matmul_tb(&m);
        assert!(prop::all_close(&y.data, &expect.data, 1e-4, 1e-4));
    }

    #[test]
    fn nnz_and_density() {
        let mut m = Mat::zeros(10, 10);
        m[(0, 0)] = 1.0;
        m[(9, 9)] = -1.0;
        let csr = Csr::from_dense(&m);
        assert_eq!(csr.nnz(), 2);
        assert!((csr.density() - 0.02).abs() < 1e-12);
        assert!(csr.storage_bytes() > 0);
    }

    #[test]
    fn empty_matrix() {
        let m = Mat::zeros(3, 4);
        let csr = Csr::from_dense(&m);
        assert_eq!(csr.nnz(), 0);
        let mut y = vec![0.0f32; 3];
        csr.spmv_add(&[1.0; 4], &mut y);
        assert_eq!(y, vec![0.0; 3]);
    }
}
