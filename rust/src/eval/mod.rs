//! Evaluation harness: perplexity (Tables 2/5/8/9/10), likelihood-scored
//! zero-shot tasks (Table 3), and generation-based tasks (Table 4).
//!
//! Perplexity runs through either the AOT `nll_fp32_*` HLO graph (one graph
//! per architecture; reconstructed weights are passed as arguments, so a
//! single artifact serves every quantization method) or the native forward
//! fallback. Both paths are cross-checked in integration tests.

pub mod tasks;

use crate::data::corpus::{Flavor, Split};
use crate::model::forward::{Engine, Weights};
use crate::model::{ModelConfig, QuantizedModel, WeightStore};
use crate::runtime::{HostTensor, Runtime};

/// The fixed NLL-graph batch geometry (must match aot.py).
pub const NLL_BATCH: usize = 8;
pub const NLL_SEQ: usize = 128;

/// Weight tensors in canonical param order, with quantized linears
/// reconstructed to dense f32 — the argument list of `nll_fp32_*`.
pub fn weight_tensors_fp32(
    cfg: &ModelConfig,
    store: &WeightStore,
    qm: Option<&QuantizedModel>,
) -> Vec<HostTensor> {
    let quant_names: std::collections::BTreeSet<String> = cfg
        .linear_shapes()
        .into_iter()
        .map(|(n, _, _)| n)
        .collect();
    cfg.param_spec()
        .into_iter()
        .map(|(name, shape)| {
            let data = if quant_names.contains(&name) {
                match qm {
                    Some(q) => q.dense_linear(&name).data,
                    None => store.get(&name).data.clone(),
                }
            } else {
                store.get(&name).data.clone()
            };
            HostTensor::F32(shape, data)
        })
        .collect()
}

/// A perplexity engine: sums NLL over fixed-size batches. The native
/// variant holds one `forward::Engine` across batches (weights resolved
/// and packed once) and prefills each batch in `chunk`-position pieces —
/// the same session API serving uses.
pub enum PplEngine<'a> {
    Native {
        engine: Engine<'a>,
        /// prefill chunk size per step (`usize::MAX` = whole sequence)
        chunk: usize,
    },
    Hlo {
        rt: &'a Runtime,
        graph: String,
        weights: Vec<HostTensor>,
    },
}

impl<'a> PplEngine<'a> {
    /// Native engine, whole-sequence prefill.
    pub fn native(w: Weights<'a>) -> PplEngine<'a> {
        PplEngine::native_chunked(w, usize::MAX)
    }

    /// Native engine prefilling each sequence in `chunk`-position steps
    /// (dense-cache math is identical at every chunk size; this exists
    /// so `--prefill-chunk` bounds eval's per-step footprint too).
    pub fn native_chunked(w: Weights<'a>, chunk: usize) -> PplEngine<'a> {
        PplEngine::Native { engine: Engine::new(&w), chunk: chunk.max(1) }
    }

    /// HLO engine for a model; graph name comes from the base config.
    pub fn hlo(
        rt: &'a Runtime,
        model_name: &str,
        store: &WeightStore,
        qm: Option<&QuantizedModel>,
    ) -> Result<PplEngine<'a>, String> {
        let entry = rt
            .manifest
            .models
            .get(model_name)
            .ok_or_else(|| format!("model {} not in manifest", model_name))?;
        let graph = format!("nll_fp32_{}", entry.base_config);
        if !rt.has_graph(&graph) {
            return Err(format!("graph {} missing", graph));
        }
        let weights = weight_tensors_fp32(&entry.config, store, qm);
        Ok(PplEngine::Hlo { rt, graph, weights })
    }

    /// NLL sum over one batch of NLL_BATCH x NLL_SEQ tokens.
    pub fn nll_batch(&mut self, tokens: &[Vec<i32>]) -> Result<f64, String> {
        match self {
            PplEngine::Native { engine, chunk } => {
                Ok(engine.nll_sum_chunked(tokens, *chunk))
            }
            PplEngine::Hlo { rt, graph, weights } => {
                assert_eq!(tokens.len(), NLL_BATCH);
                let flat: Vec<i32> =
                    tokens.iter().flat_map(|t| t.iter().copied()).collect();
                let mut inputs =
                    vec![HostTensor::I32(vec![NLL_BATCH, NLL_SEQ], flat)];
                inputs.extend(weights.iter().cloned());
                let out = rt.run(graph, &inputs)?;
                Ok(out[0].scalar_f32()? as f64)
            }
        }
    }
}

/// Perplexity over `n_batches` batches of a corpus split.
pub fn perplexity(
    engine: &mut PplEngine,
    flavor: Flavor,
    split: Split,
    n_batches: usize,
) -> Result<f64, String> {
    let seqs = crate::data::eval_sequences(
        flavor,
        split,
        NLL_SEQ,
        n_batches * NLL_BATCH,
    );
    let mut total = 0.0f64;
    let mut count = 0usize;
    for chunk in seqs.chunks(NLL_BATCH) {
        let tokens: Vec<Vec<i32>> = chunk
            .iter()
            .map(|s| s.iter().map(|&b| b as i32).collect())
            .collect();
        total += engine.nll_batch(&tokens)?;
        count += tokens.len() * (NLL_SEQ - 1);
    }
    Ok((total / count as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus;

    #[test]
    fn native_ppl_of_random_model_near_vocab() {
        // an untrained model is ~uniform over 256 bytes, but the corpus
        // uses ~29 distinct bytes; ppl must be >> trained-model ppl and
        // <= vocab size-ish
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("r", cfg, 5);
        let mut eng = PplEngine::native(Weights::Fp(&store));
        let f = corpus::flavor("wiki2s").unwrap();
        let ppl = perplexity(&mut eng, f, Split::Valid, 1).unwrap();
        assert!(ppl > 20.0 && ppl < 2000.0, "ppl {}", ppl);
    }

    #[test]
    fn chunked_native_ppl_matches_whole_sequence() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("r", cfg, 6);
        let f = corpus::flavor("wiki2s").unwrap();
        let mut full = PplEngine::native(Weights::Fp(&store));
        let ppl_full = perplexity(&mut full, f, Split::Valid, 1).unwrap();
        for chunk in [1usize, 17, 128] {
            let mut eng =
                PplEngine::native_chunked(Weights::Fp(&store), chunk);
            let ppl = perplexity(&mut eng, f, Split::Valid, 1).unwrap();
            assert!(
                (ppl - ppl_full).abs() < 1e-9 * ppl_full.max(1.0),
                "chunk {}: {} vs {}",
                chunk,
                ppl,
                ppl_full
            );
        }
    }

    #[test]
    fn weight_tensors_order_matches_spec() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("r", cfg, 6);
        let ts = weight_tensors_fp32(&cfg, &store, None);
        let spec = cfg.param_spec();
        assert_eq!(ts.len(), spec.len());
        for (t, (_, shape)) in ts.iter().zip(&spec) {
            assert_eq!(t.dims(), &shape[..]);
        }
    }
}
