//! Task evaluation: likelihood-scored binary tasks (Table 3) and
//! generation/exact-match tasks (Table 4: gsm-s, longbench-s).

use crate::data::tasks::{GenCase, PairCase};
use crate::model::forward::{Engine, SamplingParams, Weights};

/// Length-normalized NLL of one variable-length sequence (native path;
/// the HLO nll graph has fixed geometry, tasks need arbitrary lengths).
pub fn seq_nll_per_byte(engine: &mut Engine, text: &[u8]) -> f64 {
    let toks: Vec<i32> = text.iter().map(|&b| b as i32).collect();
    if toks.len() < 2 {
        return 0.0;
    }
    let n = toks.len();
    engine.nll_sum_chunked(&[toks], usize::MAX) / (n - 1) as f64
}

/// Accuracy of one pair task: fraction of cases where the model assigns a
/// lower per-byte NLL to the real sentence (LM-Harness-style likelihood
/// comparison; length-normalized because corruptions change length). One
/// engine (weights resolved/packed once) scores every case.
pub fn pair_accuracy(w: &Weights, cases: &[PairCase]) -> f64 {
    if cases.is_empty() {
        return 0.0;
    }
    let mut engine = Engine::new(w);
    let mut correct = 0usize;
    for c in cases {
        let n_good = seq_nll_per_byte(&mut engine, &c.good);
        let n_bad = seq_nll_per_byte(&mut engine, &c.bad);
        if n_good < n_bad {
            correct += 1;
        }
    }
    correct as f64 / cases.len() as f64
}

/// Run all six pair tasks; returns (task name, accuracy %) rows + mean.
pub fn zero_shot_suite(
    w: &Weights,
    cases_per_task: usize,
    seed: u64,
) -> (Vec<(String, f64)>, f64) {
    let mut rows = Vec::new();
    let mut sum = 0.0;
    for task in crate::data::tasks::PAIR_TASKS {
        let cases =
            crate::data::tasks::pair_cases(task, cases_per_task, seed);
        let acc = 100.0 * pair_accuracy(w, &cases);
        sum += acc;
        rows.push((task.name().to_string(), acc));
    }
    let mean = sum / rows.len() as f64;
    (rows, mean)
}

/// Exact-match accuracy on generation cases (chunked-prefill greedy
/// decode through one engine). The prompt is truncated from the left to
/// fit the context window — mirrors how long-context evaluation clips
/// inputs.
pub fn exact_match(w: &Weights, cases: &[GenCase]) -> f64 {
    let cfg = w.store().cfg;
    if cases.is_empty() {
        return 0.0;
    }
    let mut engine = Engine::new(w);
    let mut correct = 0usize;
    for c in cases {
        let start = c.prompt.len().saturating_sub(cfg.ctx - c.answer.len() - 1);
        let toks: Vec<i32> =
            c.prompt[start..].iter().map(|&b| b as i32).collect();
        let out = engine.generate(
            &toks,
            c.answer.len(),
            &SamplingParams::greedy(),
        );
        let got: Vec<u8> = out.iter().map(|&t| t as u8).collect();
        if got == c.answer {
            correct += 1;
        }
    }
    correct as f64 / cases.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{self, PairTask};
    use crate::model::{ModelConfig, WeightStore};

    #[test]
    fn random_model_near_chance_on_pairs() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("r", cfg, 7);
        let w = Weights::Fp(&store);
        let cases = tasks::pair_cases(PairTask::Shuffle, 12, 3);
        let acc = pair_accuracy(&w, &cases);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn exact_match_zero_for_random_model() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("r", cfg, 8);
        let w = Weights::Fp(&store);
        let cases = tasks::gsm_cases(5, 1);
        let acc = exact_match(&w, &cases);
        assert!(acc <= 0.4); // random bytes ~never match digits
    }

    #[test]
    fn long_prompt_is_clipped_not_panicking() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("r", cfg, 9);
        let w = Weights::Fp(&store);
        let cases = tasks::longbench_cases(2, 60, 2); // prompt > ctx
        let _ = exact_match(&w, &cases); // must not panic
    }
}
