//! Rust port of python/compile/corpus.py — byte-for-byte identical output
//! (pinned by artifacts/golden/corpus.json in integration tests). The
//! coordinator generates calibration and evaluation text natively so the
//! request path never needs Python.

use crate::util::rng::Rng;

pub const LETTER_FREQ: [u64; 26] = [
    8167, 1492, 2782, 4253, 12702, 2228, 2015, 6094, 6966, 153, 772, 4025,
    2406, 6749, 7507, 1929, 95, 5987, 6327, 9056, 2758, 978, 2360, 150,
    1974, 74,
];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Flavor {
    pub name: &'static str,
    pub vocab: u64,
    pub alpha2: u32,
    pub chain_mul: u64,
    pub chain_add: u64,
    pub base_seed: u64,
}

pub const FLAVORS: [Flavor; 3] = [
    Flavor { name: "wiki2s", vocab: 512, alpha2: 2, chain_mul: 17, chain_add: 7, base_seed: 0x57494B49 },
    Flavor { name: "c4s", vocab: 800, alpha2: 3, chain_mul: 29, chain_add: 11, base_seed: 0x00C40C40 },
    Flavor { name: "ptbs", vocab: 300, alpha2: 4, chain_mul: 13, chain_add: 5, base_seed: 0x00507442 },
];

pub fn flavor(name: &str) -> Option<Flavor> {
    FLAVORS.iter().find(|f| f.name == name).copied()
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Valid,
    Test,
    Calib,
}

impl Split {
    fn offset(self) -> u64 {
        match self {
            Split::Train => 0,
            Split::Valid => 1,
            Split::Test => 2,
            Split::Calib => 3,
        }
    }
}

fn cumsum(ws: &[u64]) -> (Vec<u64>, u64) {
    let mut total = 0u64;
    let cum = ws
        .iter()
        .map(|&w| {
            total += w;
            total
        })
        .collect();
    (cum, total)
}

fn isqrt(n: u64) -> u64 {
    if n == 0 {
        return 0;
    }
    let mut x = (n as f64).sqrt() as u64;
    // fix float rounding both ways; checked_mul treats overflow as "> n"
    while x.checked_mul(x).map_or(true, |v| v > n) {
        x -= 1;
    }
    while (x + 1).checked_mul(x + 1).is_some_and(|v| v <= n) {
        x += 1;
    }
    x
}

fn zipf_weights(vocab: u64, alpha2: u32) -> Vec<u64> {
    (1..=vocab)
        .map(|k| {
            let w = match alpha2 {
                2 => 1_000_000_000 / k,
                4 => 1_000_000_000 / (k * k),
                _ => 1_000_000_000 / isqrt(k * k * k),
            };
            w.max(1)
        })
        .collect()
}

pub fn build_vocab(f: Flavor) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(f.base_seed ^ 0xA5A5_A5A5_A5A5_A5A5);
    let (cum_l, tot_l) = cumsum(&LETTER_FREQ);
    let mut words: Vec<Vec<u8>> = Vec::with_capacity(f.vocab as usize);
    let mut seen = std::collections::HashSet::new();
    while words.len() < f.vocab as usize {
        let wlen = 2 + rng.below(7);
        let w: Vec<u8> = (0..wlen)
            .map(|_| b'a' + rng.sample_cum(&cum_l, tot_l) as u8)
            .collect();
        if !seen.insert(w.clone()) {
            continue;
        }
        words.push(w);
    }
    words
}

/// Generate `nbytes` of deterministic text — identical to corpus.generate.
pub fn generate(f: Flavor, split: Split, nbytes: usize) -> Vec<u8> {
    let words = build_vocab(f);
    let ws = zipf_weights(f.vocab, f.alpha2);
    let (cum_w, tot_w) = cumsum(&ws);
    let seed = f
        .base_seed
        .wrapping_mul(2_654_435_761)
        .wrapping_add(split.offset());
    let mut rng = Rng::new(seed);

    let mut out: Vec<u8> = Vec::with_capacity(nbytes + 64);
    let mut prev: u64 = 0;
    while out.len() < nbytes {
        let slen = 4 + rng.below(9);
        for i in 0..slen {
            if i > 0 {
                out.push(b' ');
            }
            let idx = if i > 0 && rng.below(4) == 0 {
                (prev * f.chain_mul + f.chain_add) % f.vocab
            } else {
                rng.sample_cum(&cum_w, tot_w) as u64
            };
            out.extend_from_slice(&words[idx as usize]);
            prev = idx;
            if i == slen - 2 && rng.below(5) == 0 {
                out.push(b',');
            }
        }
        out.extend_from_slice(b". ");
    }
    out.truncate(nbytes);
    out
}

/// Task-formatted text (arithmetic + kv-recall), identical to
/// corpus.instruct_text — used by the instruct fine-tune and the Table 4
/// task generators.
pub fn instruct_text(nbytes: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    let mut out: Vec<u8> = Vec::with_capacity(nbytes + 64);
    while out.len() < nbytes {
        if rng.below(2) == 0 {
            let a = rng.below(10);
            let b = rng.below(10);
            let s = a + b;
            if s < 10 {
                out.extend_from_slice(format!("{}+{}={}. ", a, b, s).as_bytes());
            } else {
                out.extend_from_slice(
                    format!("{}+{}=1{}. ", a, b, s - 10).as_bytes(),
                );
            }
        } else {
            let nkv = 2 + rng.below(11);
            let mut keys = Vec::new();
            let mut vals = Vec::new();
            for _ in 0..nkv {
                let k = (b'a' + rng.below(26) as u8) as char;
                let v = rng.below(10);
                keys.push(k);
                vals.push(v);
                out.extend_from_slice(format!("{}={};", k, v).as_bytes());
            }
            let qi = rng.below(nkv) as usize;
            let mut v = 0;
            for (k2, v2) in keys.iter().zip(&vals) {
                if *k2 == keys[qi] {
                    v = *v2;
                }
            }
            out.extend_from_slice(format!("{}?{}. ", keys[qi], v).as_bytes());
        }
    }
    out.truncate(nbytes);
    out
}

pub const INSTRUCT_SEED: u64 = 0x1257;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_prefix_stable() {
        let f = flavor("wiki2s").unwrap();
        let a = generate(f, Split::Train, 400);
        let b = generate(f, Split::Train, 800);
        assert_eq!(&b[..400], &a[..]);
    }

    #[test]
    fn splits_and_flavors_differ() {
        let f = flavor("wiki2s").unwrap();
        assert_ne!(
            generate(f, Split::Train, 300),
            generate(f, Split::Valid, 300)
        );
        let g = flavor("c4s").unwrap();
        assert_ne!(
            generate(f, Split::Train, 300),
            generate(g, Split::Train, 300)
        );
    }

    #[test]
    fn charset_is_clean() {
        let f = flavor("ptbs").unwrap();
        let text = generate(f, Split::Train, 2000);
        assert!(text
            .iter()
            .all(|&b| b.is_ascii_lowercase() || b == b' ' || b == b',' || b == b'.'));
    }

    #[test]
    fn isqrt_exact() {
        for n in 0..2000u64 {
            let r = isqrt(n);
            assert!(r * r <= n && (r + 1) * (r + 1) > n, "n={}", n);
        }
        assert_eq!(isqrt(u64::MAX), 4294967295);
    }

    #[test]
    fn vocab_is_unique_and_sized() {
        let f = flavor("wiki2s").unwrap();
        let v = build_vocab(f);
        assert_eq!(v.len(), 512);
        let set: std::collections::HashSet<_> = v.iter().collect();
        assert_eq!(set.len(), 512);
    }

    #[test]
    fn instruct_arithmetic_is_correct() {
        let text = instruct_text(4000, INSTRUCT_SEED);
        let s = String::from_utf8(text).unwrap();
        for frag in s.split(". ") {
            if frag.contains('+') && frag.contains('=') && !frag.contains(';')
            {
                let parts: Vec<&str> = frag.split('=').collect();
                if parts.len() == 2 {
                    let lhs: Vec<&str> = parts[0].split('+').collect();
                    if let (Ok(a), Ok(b), Ok(r)) = (
                        lhs[0].parse::<u32>(),
                        lhs[1].parse::<u32>(),
                        parts[1].parse::<u32>(),
                    ) {
                        assert_eq!(a + b, r, "bad arithmetic: {}", frag);
                    }
                }
            }
        }
    }
}
