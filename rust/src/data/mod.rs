//! Data substrates: the synthetic corpus (WikiText-2/C4/PTB stand-ins),
//! evaluation-task generators, and calibration sampling (the paper uses
//! 32-128 sequences of 2,048 tokens from C4's first shard; we sample
//! sequences from the c4s calib split at our context length).

pub mod corpus;
pub mod tasks;

use crate::data::corpus::{Flavor, Split};

/// Contiguous non-overlapping sequences of `seq` bytes for evaluation.
pub fn eval_sequences(flavor: Flavor, split: Split, seq: usize, count: usize) -> Vec<Vec<u8>> {
    let text = corpus::generate(flavor, split, seq * count);
    text.chunks(seq).take(count).map(|c| c.to_vec()).collect()
}

/// Calibration sequences — mirrors the paper's protocol (C4 -> c4s).
pub fn calibration_sequences(seq: usize, count: usize) -> Vec<Vec<u8>> {
    let f = corpus::flavor("c4s").unwrap();
    eval_sequences(f, Split::Calib, seq, count)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_sequences_shape() {
        let f = corpus::flavor("wiki2s").unwrap();
        let seqs = eval_sequences(f, Split::Valid, 64, 10);
        assert_eq!(seqs.len(), 10);
        assert!(seqs.iter().all(|s| s.len() == 64));
    }

    #[test]
    fn calibration_differs_from_eval() {
        let f = corpus::flavor("c4s").unwrap();
        let calib = calibration_sequences(64, 2);
        let eval = eval_sequences(f, Split::Valid, 64, 2);
        assert_ne!(calib[0], eval[0]);
    }
}
