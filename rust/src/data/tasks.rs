//! Synthetic evaluation-task generators — the zero-shot / GSM8K /
//! LongBench stand-ins (DESIGN.md substitution table).
//!
//! * Six likelihood-scored binary tasks (Table 3): the model must assign a
//!   lower NLL to a real corpus sentence than to a corrupted variant. Each
//!   task corrupts differently; scoring matches LM-Harness (answer
//!   likelihood), so quantization-induced degradation shows the same way.
//! * gsm-s (Table 4 GSM8K analogue): "a+b=" prompts, exact-match digit(s).
//! * longbench-s (Table 4 LongBench analogue): long "k=v;" contexts, query
//!   "k?" at the end, exact-match recall of the bound value.

use crate::data::corpus::{self, Flavor, Split};
use crate::util::rng::Rng;

/// One binary likelihood comparison: model should prefer `good` over `bad`.
#[derive(Debug, Clone)]
pub struct PairCase {
    pub good: Vec<u8>,
    pub bad: Vec<u8>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PairTask {
    /// word order shuffled (HellaSwag-ish "plausible continuation")
    Shuffle,
    /// random characters swapped in-place (BoolQ-ish wellformedness)
    CharSwap,
    /// continuation taken from a different flavor (RTE-ish entailment)
    WrongContinuation,
    /// a word duplicated several times (WinoGrande-ish fluency)
    RepeatWord,
    /// word boundaries removed in a span (Arc-e-ish)
    JoinWords,
    /// span replaced by uniform-random letters (Arc-c-ish)
    RandomBytes,
}

pub const PAIR_TASKS: [PairTask; 6] = [
    PairTask::Shuffle,
    PairTask::CharSwap,
    PairTask::WrongContinuation,
    PairTask::RepeatWord,
    PairTask::JoinWords,
    PairTask::RandomBytes,
];

impl PairTask {
    pub fn name(&self) -> &'static str {
        match self {
            PairTask::Shuffle => "shuffle",
            PairTask::CharSwap => "charswap",
            PairTask::WrongContinuation => "wrongcont",
            PairTask::RepeatWord => "repeat",
            PairTask::JoinWords => "join",
            PairTask::RandomBytes => "randbytes",
        }
    }
}

fn sentences(flavor: Flavor, split: Split, count: usize, min_len: usize) -> Vec<Vec<u8>> {
    let text = corpus::generate(flavor, split, count * 120 + 4096);
    let mut out = Vec::new();
    for frag in text.split(|&b| b == b'.') {
        let s: Vec<u8> = frag
            .iter()
            .copied()
            .skip_while(|&b| b == b' ')
            .collect();
        if s.len() >= min_len && s.len() < 110 {
            out.push(s);
        }
        if out.len() >= count {
            break;
        }
    }
    out
}

/// Build `n` cases of one pair task, deterministic per (task, seed).
pub fn pair_cases(task: PairTask, n: usize, seed: u64) -> Vec<PairCase> {
    let f = corpus::flavor("wiki2s").unwrap();
    let goods = sentences(f, Split::Test, n, 24);
    let mut rng = Rng::new(seed ^ (task as u64).wrapping_mul(0x9E37));
    let alt_f = corpus::flavor("ptbs").unwrap();
    let alts = sentences(alt_f, Split::Test, n, 24);
    let mut out = Vec::with_capacity(goods.len());
    for (ci, good) in goods.into_iter().enumerate() {
        let bad = corrupt(&good, task, &mut rng, alts.get(ci));
        out.push(PairCase { good, bad });
    }
    out
}

fn corrupt(
    good: &[u8],
    task: PairTask,
    rng: &mut Rng,
    alt: Option<&Vec<u8>>,
) -> Vec<u8> {
    let words: Vec<&[u8]> = good.split(|&b| b == b' ').collect();
    match task {
        PairTask::Shuffle => {
            let mut idx: Vec<usize> = (0..words.len()).collect();
            rng.shuffle(&mut idx);
            // ensure it actually changed
            if idx.iter().enumerate().all(|(i, &j)| i == j) {
                idx.rotate_left(1);
            }
            join(&idx.iter().map(|&i| words[i]).collect::<Vec<_>>())
        }
        PairTask::CharSwap => {
            let mut v = good.to_vec();
            let swaps = (v.len() / 6).max(2);
            for _ in 0..swaps {
                let i = rng.below(v.len() as u64) as usize;
                let j = rng.below(v.len() as u64) as usize;
                v.swap(i, j);
            }
            v
        }
        PairTask::WrongContinuation => {
            let half = words.len() / 2;
            let mut keep: Vec<&[u8]> = words[..half.max(1)].to_vec();
            if let Some(a) = alt {
                let awords: Vec<&[u8]> = a.split(|&b| b == b' ').collect();
                keep.extend(awords.iter().take(words.len() - keep.len()));
            } else {
                keep.extend(words.iter().rev().take(words.len() - keep.len()));
            }
            join(&keep)
        }
        PairTask::RepeatWord => {
            let wi = rng.below(words.len() as u64) as usize;
            let mut v: Vec<&[u8]> = Vec::new();
            for (i, w) in words.iter().enumerate() {
                v.push(w);
                if i == wi {
                    v.push(w);
                    v.push(w);
                    v.push(w);
                }
            }
            join(&v)
        }
        PairTask::JoinWords => {
            good.iter().copied().filter(|&b| b != b' ').collect()
        }
        PairTask::RandomBytes => {
            let mut v = good.to_vec();
            let start = v.len() / 3;
            let end = (2 * v.len() / 3).min(v.len());
            for b in &mut v[start..end] {
                *b = b'a' + rng.below(26) as u8;
            }
            v
        }
    }
}

fn join(words: &[&[u8]]) -> Vec<u8> {
    let mut out = Vec::new();
    for (i, w) in words.iter().enumerate() {
        if i > 0 {
            out.push(b' ');
        }
        out.extend_from_slice(w);
    }
    out
}

// ---------------------------------------------------------------------------
// gsm-s: arithmetic exact-match generation task
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct GenCase {
    /// prompt ends right before the answer digits
    pub prompt: Vec<u8>,
    /// expected generated prefix
    pub answer: Vec<u8>,
}

pub fn gsm_cases(n: usize, seed: u64) -> Vec<GenCase> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        // few-shot style context of solved examples, then the query
        let mut prompt = Vec::new();
        for _ in 0..3 {
            let a = rng.below(10);
            let b = rng.below(10);
            prompt.extend_from_slice(fmt_sum(a, b).as_bytes());
        }
        let a = rng.below(10);
        let b = rng.below(10);
        prompt.extend_from_slice(format!("{}+{}=", a, b).as_bytes());
        let s = a + b;
        let answer = if s < 10 {
            format!("{}", s)
        } else {
            format!("1{}", s - 10)
        };
        out.push(GenCase { prompt, answer: answer.into_bytes() });
    }
    out
}

fn fmt_sum(a: u64, b: u64) -> String {
    let s = a + b;
    if s < 10 {
        format!("{}+{}={}. ", a, b, s)
    } else {
        format!("{}+{}=1{}. ", a, b, s - 10)
    }
}

// ---------------------------------------------------------------------------
// longbench-s: long-context key-value recall
// ---------------------------------------------------------------------------

pub fn longbench_cases(n: usize, ctx_bindings: usize, seed: u64) -> Vec<GenCase> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let mut prompt = Vec::new();
        let mut keys = Vec::new();
        let mut vals = Vec::new();
        for _ in 0..ctx_bindings {
            let k = (b'a' + rng.below(26) as u8) as char;
            let v = rng.below(10);
            keys.push(k);
            vals.push(v);
            prompt.extend_from_slice(format!("{}={};", k, v).as_bytes());
        }
        let qi = rng.below(ctx_bindings as u64) as usize;
        // last binding wins (matches corpus::instruct_text semantics)
        let mut v = 0;
        for (k2, v2) in keys.iter().zip(&vals) {
            if *k2 == keys[qi] {
                v = *v2;
            }
        }
        prompt.extend_from_slice(format!("{}?", keys[qi]).as_bytes());
        out.push(GenCase {
            prompt,
            answer: format!("{}", v).into_bytes(),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_cases_all_tasks_nonempty_and_distinct() {
        for task in PAIR_TASKS {
            let cases = pair_cases(task, 10, 1);
            assert!(cases.len() >= 8, "{:?}", task);
            for c in &cases {
                assert_ne!(c.good, c.bad, "{:?} produced identical pair", task);
                assert!(!c.good.is_empty());
            }
        }
    }

    #[test]
    fn pair_cases_deterministic() {
        let a = pair_cases(PairTask::Shuffle, 5, 9);
        let b = pair_cases(PairTask::Shuffle, 5, 9);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.bad, y.bad);
        }
    }

    #[test]
    fn gsm_answers_are_correct() {
        for c in gsm_cases(50, 3) {
            let s = String::from_utf8(c.prompt.clone()).unwrap();
            let q = s.rsplit(". ").next().unwrap();
            let lhs = q.trim_end_matches('=');
            let parts: Vec<&str> = lhs.split('+').collect();
            let a: u32 = parts[0].parse().unwrap();
            let b: u32 = parts[1].parse().unwrap();
            let ans: u32 =
                String::from_utf8(c.answer.clone()).unwrap().parse().unwrap();
            assert_eq!(a + b, ans);
        }
    }

    #[test]
    fn longbench_recalls_last_binding() {
        for c in longbench_cases(30, 12, 5) {
            let s = String::from_utf8(c.prompt.clone()).unwrap();
            let q = s.chars().rev().nth(1).unwrap(); // "<k>?"
            let mut expect = None;
            for b in s.split(';') {
                if let Some((k, v)) = b.split_once('=') {
                    if k.chars().next() == Some(q) {
                        expect = Some(v.to_string());
                    }
                }
            }
            assert_eq!(
                expect.unwrap(),
                String::from_utf8(c.answer.clone()).unwrap()
            );
        }
    }

    #[test]
    fn longbench_prompt_length_scales() {
        let short = longbench_cases(1, 4, 1)[0].prompt.len();
        let long = longbench_cases(1, 24, 1)[0].prompt.len();
        assert!(long > 4 * short / 2);
    }
}
