//! Open-loop traffic generation for the serving stack: arrival
//! processes, a mixed scenario pool with per-class latency SLOs, and the
//! goodput accounting behind `BENCH_serve.json`.
//!
//! *Open-loop* means arrival times come from a process that does not
//! wait for the system — requests land at their scheduled instants
//! whether or not earlier ones finished, so queueing delay and
//! preemption pressure show up in the tails instead of being absorbed
//! by the load generator (the closed-loop failure mode). Each request
//! is submitted to a [`ServerHandle`] at its arrival offset and drained
//! by its own consumer thread (which also plays the mid-flight
//! canceller role); [`run_open_loop`] then distills the server's
//! [`ServeMetrics`] into a per-class [`TrafficReport`]. The same
//! workload drives a multi-replica [`Cluster`] through
//! [`run_open_loop_cluster`] — identical spec + seed produce identical
//! requests, so faulted and unfaulted cluster runs are directly
//! comparable (the goodput-retention gate in `benches/serve_traffic`).
//!
//! **Goodput** is throughput that met its class SLO: a request counts
//! only if it completed normally (budget, stop token, or stop sequence
//! — not cancelled, not rejected) *and* its TTFT (and steady-state
//! TPOT, where measured) came in under the class bound. Generated
//! tokens of SLO-attaining requests divided by wall time is
//! `goodput_tok_s`.

use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

use crate::coordinator::{
    CancelHandle, Cluster, ClusterMetrics, FinishReason, GenRequest,
    RequestMetrics, SamplingParams, ServeMetrics, ServeOptions,
    ServerHandle, StopCriteria, TokenEvent,
};
use crate::obs::hist::{fnum, Samples};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// Per-class latency service-level objective. `tpot_ms` is `None` for
/// classes whose outputs are too short for a steady-state cadence.
#[derive(Debug, Clone, Copy)]
pub struct Slo {
    pub ttft_ms: f64,
    pub tpot_ms: Option<f64>,
}

impl Slo {
    /// Did a normally-completed request meet this SLO? `tpot` is the
    /// request's measured cadence when it has one; an unmeasurable TPOT
    /// (single-token output) never fails the bound.
    pub fn attained(&self, ttft_ms: f64, tpot_ms: Option<f64>) -> bool {
        if ttft_ms > self.ttft_ms {
            return false;
        }
        match (self.tpot_ms, tpot_ms) {
            (Some(bound), Some(t)) => t <= bound,
            _ => true,
        }
    }
}

/// One scenario in the mixed pool.
#[derive(Debug, Clone)]
pub struct TrafficClass {
    pub name: &'static str,
    pub prompt_len: usize,
    pub max_new: usize,
    /// 0.0 = greedy; sampled classes exercise the sampling stage
    pub temperature: f32,
    /// random 2-token stop sequences attached to the request
    pub stop_seqs: usize,
    /// cancel from the consumer thread after this many streamed tokens
    pub cancel_after: Option<usize>,
    /// relative sampling weight in the mix
    pub weight: u64,
    pub slo: Slo,
}

/// The standard ≥5-class pool the serve bench and CLI share. `scale`
/// shrinks prompt/generation lengths for smoke runs (1 = full size);
/// SLO bounds are deliberately loose — CI machines vary widely and the
/// bench gates on *reporting* goodput, not on absolute speed.
pub fn standard_classes(scale: usize) -> Vec<TrafficClass> {
    let s = scale.max(1);
    let d = |v: usize| (v / s).max(4);
    vec![
        TrafficClass {
            name: "chat-short",
            prompt_len: d(64),
            max_new: d(32),
            temperature: 0.0,
            stop_seqs: 0,
            cancel_after: None,
            weight: 4,
            slo: Slo { ttft_ms: 2_500.0, tpot_ms: Some(250.0) },
        },
        TrafficClass {
            name: "rag-long-prompt",
            prompt_len: 2048 / s.min(8),
            max_new: d(24),
            temperature: 0.0,
            stop_seqs: 0,
            cancel_after: None,
            weight: 2,
            slo: Slo { ttft_ms: 8_000.0, tpot_ms: Some(250.0) },
        },
        TrafficClass {
            name: "long-gen",
            prompt_len: d(32),
            max_new: d(128),
            temperature: 0.7,
            stop_seqs: 0,
            cancel_after: None,
            weight: 2,
            slo: Slo { ttft_ms: 4_000.0, tpot_ms: Some(250.0) },
        },
        TrafficClass {
            name: "canceller",
            prompt_len: d(48),
            max_new: d(64),
            temperature: 0.0,
            stop_seqs: 0,
            // fire well inside the budget so the cancel usually lands
            // mid-flight (cross-thread cancels are racy by nature —
            // the report counts whichever way each one resolved)
            cancel_after: Some((d(64) / 4).max(1)),
            weight: 1,
            slo: Slo { ttft_ms: 2_500.0, tpot_ms: None },
        },
        TrafficClass {
            name: "agent-stop-seq",
            prompt_len: d(64),
            max_new: d(48),
            temperature: 0.7,
            stop_seqs: 4,
            cancel_after: None,
            weight: 2,
            slo: Slo { ttft_ms: 2_500.0, tpot_ms: Some(250.0) },
        },
    ]
}

/// Arrival process shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrivals {
    /// exponential inter-arrival gaps with the given mean — memoryless
    /// load, the classic open-loop baseline
    Poisson,
    /// groups of 8 simultaneous arrivals separated by 8x the mean gap —
    /// same average rate, maximally lumpy; stresses admission and
    /// preemption
    Bursty,
}

impl Arrivals {
    pub fn tag(&self) -> &'static str {
        match self {
            Arrivals::Poisson => "poisson",
            Arrivals::Bursty => "bursty",
        }
    }
}

/// Arrival offsets (ms since harness start), nondecreasing, one per
/// request. Both shapes have the same mean rate `1/mean_gap_ms`.
pub fn arrival_times_ms(
    pattern: Arrivals,
    n: usize,
    mean_gap_ms: f64,
    rng: &mut Rng,
) -> Vec<f64> {
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    match pattern {
        Arrivals::Poisson => {
            for _ in 0..n {
                // exponential gap via inverse CDF; uniform() is in [0,1)
                // so 1-u is in (0,1] and ln stays finite
                t += -mean_gap_ms * (1.0 - rng.uniform()).ln();
                out.push(t);
            }
        }
        Arrivals::Bursty => {
            const BURST: usize = 8;
            for i in 0..n {
                if i > 0 && i % BURST == 0 {
                    t += mean_gap_ms * BURST as f64;
                }
                out.push(t);
            }
        }
    }
    out
}

/// A full workload specification: the class mix, how many requests, and
/// the arrival process.
#[derive(Debug, Clone)]
pub struct TrafficSpec {
    pub classes: Vec<TrafficClass>,
    pub n_requests: usize,
    pub mean_gap_ms: f64,
    pub pattern: Arrivals,
    pub seed: u64,
    /// vocab to draw prompt tokens from (match the serving model)
    pub vocab: usize,
    /// optional per-request wall-clock deadline applied to every
    /// request (`GenRequest::with_deadline_ms`); expired requests end
    /// [`FinishReason::DeadlineExceeded`] with partial output
    pub deadline_ms: Option<f64>,
}

/// Class index per request. The first `classes.len()` requests get one
/// of each class in order (coverage guarantee — every class appears in
/// every run, which CI asserts on); the rest draw weighted.
fn assign_classes(spec: &TrafficSpec, rng: &mut Rng) -> Vec<usize> {
    // sample_cum wants cumulative integer weights
    let mut cum = Vec::with_capacity(spec.classes.len());
    let mut total = 0u64;
    for c in &spec.classes {
        total += c.weight.max(1);
        cum.push(total);
    }
    (0..spec.n_requests)
        .map(|i| {
            if i < spec.classes.len() {
                i
            } else {
                rng.sample_cum(&cum, total)
            }
        })
        .collect()
}

/// Build the request for one (index, class) pair. Ids are `i + 1`
/// (never 0, and disjoint per request) so the report can key per-class
/// stats off `RequestMetrics::id`.
fn build_request(
    i: usize,
    class: &TrafficClass,
    vocab: usize,
    rng: &mut Rng,
) -> GenRequest {
    let prompt: Vec<i32> = (0..class.prompt_len.max(1))
        .map(|_| rng.below(vocab as u64) as i32)
        .collect();
    let sampling = if class.temperature > 0.0 {
        SamplingParams::sample(class.temperature, 1000 + i as u64)
    } else {
        SamplingParams::greedy()
    };
    let mut stop = StopCriteria::max_tokens(class.max_new.max(1));
    for _ in 0..class.stop_seqs {
        stop = stop.with_stop_seq(vec![
            rng.below(vocab as u64) as i32,
            rng.below(vocab as u64) as i32,
        ]);
    }
    GenRequest::new(i as u64 + 1, prompt, sampling, stop)
}

/// What one consumer thread observed for its request.
struct Drained {
    finish: Option<FinishReason>,
    streamed: usize,
}

fn drain_stream(
    rx: Receiver<TokenEvent>,
    cancel: CancelHandle,
    cancel_after: Option<usize>,
) -> Drained {
    let mut streamed = 0usize;
    loop {
        match rx.recv() {
            Ok(TokenEvent::Token { .. }) => {
                streamed += 1;
                if cancel_after == Some(streamed) {
                    cancel.cancel();
                }
            }
            Ok(TokenEvent::Done(o)) => {
                return Drained { finish: Some(o.finish), streamed };
            }
            // engine dropped the stream (serve error): count as lost
            Err(_) => return Drained { finish: None, streamed },
        }
    }
}

/// Per-class rollup in a [`TrafficReport`].
pub struct ClassStats {
    pub name: &'static str,
    pub sent: usize,
    /// finished normally (budget / stop token / stop sequence)
    pub completed: usize,
    pub cancelled: usize,
    pub rejected: usize,
    /// ended [`FinishReason::DeadlineExceeded`] (partial output)
    pub deadline: usize,
    pub slo_attained: usize,
    pub generated_tokens: usize,
    pub attained_tokens: usize,
    pub ttft_ms: Samples,
    pub tpot_ms: Samples,
    pub slo: Slo,
}

impl ClassStats {
    fn to_json(&self) -> Json {
        json::obj(vec![
            ("name", json::s(self.name)),
            ("sent", json::num(self.sent as f64)),
            ("completed", json::num(self.completed as f64)),
            ("cancelled", json::num(self.cancelled as f64)),
            ("rejected", json::num(self.rejected as f64)),
            ("deadline_exceeded", json::num(self.deadline as f64)),
            ("slo_attained", json::num(self.slo_attained as f64)),
            (
                "generated_tokens",
                json::num(self.generated_tokens as f64),
            ),
            ("slo_ttft_ms", fnum(self.slo.ttft_ms)),
            (
                "slo_tpot_ms",
                match self.slo.tpot_ms {
                    Some(t) => fnum(t),
                    None => Json::Null,
                },
            ),
            ("ttft_p50_ms", fnum(self.ttft_ms.percentile(0.50))),
            ("ttft_p99_ms", fnum(self.ttft_ms.percentile(0.99))),
            ("tpot_p50_ms", fnum(self.tpot_ms.percentile(0.50))),
            ("tpot_p99_ms", fnum(self.tpot_ms.percentile(0.99))),
        ])
    }
}

/// The distilled result of one open-loop run.
pub struct TrafficReport {
    pub pattern: Arrivals,
    pub n_requests: usize,
    pub wall_s: f64,
    /// generated tokens of SLO-attaining requests per wall second
    pub goodput_tok_s: f64,
    /// SLO-attaining requests per wall second
    pub goodput_req_s: f64,
    pub per_class: Vec<ClassStats>,
    pub metrics: ServeMetrics,
    /// streams that ended without a Done (engine error) — should be 0
    pub lost: usize,
}

impl TrafficReport {
    pub fn completed(&self) -> usize {
        self.per_class.iter().map(|c| c.completed).sum()
    }

    pub fn attained(&self) -> usize {
        self.per_class.iter().map(|c| c.slo_attained).sum()
    }

    pub fn rejected(&self) -> usize {
        self.per_class.iter().map(|c| c.rejected).sum()
    }

    pub fn cancelled(&self) -> usize {
        self.per_class.iter().map(|c| c.cancelled).sum()
    }

    pub fn deadline_exceeded(&self) -> usize {
        self.per_class.iter().map(|c| c.deadline).sum()
    }

    /// Classes that actually sent at least one request.
    pub fn classes_sent(&self) -> usize {
        self.per_class.iter().filter(|c| c.sent > 0).count()
    }

    pub fn to_json(&self) -> Json {
        let m = &self.metrics;
        json::obj(vec![
            ("pattern", json::s(self.pattern.tag())),
            ("requests", json::num(self.n_requests as f64)),
            ("wall_s", fnum(self.wall_s)),
            ("goodput_tok_s", fnum(self.goodput_tok_s)),
            ("goodput_req_s", fnum(self.goodput_req_s)),
            ("completed", json::num(self.completed() as f64)),
            ("slo_attained", json::num(self.attained() as f64)),
            ("rejected", json::num(self.rejected() as f64)),
            ("cancelled", json::num(self.cancelled() as f64)),
            (
                "deadline_exceeded",
                json::num(self.deadline_exceeded() as f64),
            ),
            ("lost", json::num(self.lost as f64)),
            ("ttft_p50_ms", fnum(m.ttft_p50_ms())),
            ("ttft_p99_ms", fnum(m.ttft_p99_ms())),
            ("tpot_p50_ms", fnum(m.tpot_p50_ms())),
            ("tpot_p99_ms", fnum(m.tpot_p99_ms())),
            ("queue_delay_p50_ms", fnum(m.queue_delay_p50_ms())),
            ("queue_delay_p99_ms", fnum(m.queue_delay_p99_ms())),
            ("preemptions", json::num(m.preemptions as f64)),
            (
                "per_class",
                Json::Arr(
                    self.per_class.iter().map(|c| c.to_json()).collect(),
                ),
            ),
            ("metrics", m.snapshot()),
        ])
    }
}

/// Run one open-loop round: spawn the engine thread, submit each
/// request at its scheduled arrival offset, drain every stream on its
/// own consumer thread (cancellers fire from there), shut down, and
/// roll the server's metrics up per class.
///
/// `engine_loop` is handed to [`ServerHandle::spawn`] unchanged — it
/// owns the backend (see `benches/serve_traffic.rs` for a paged-native
/// example).
pub fn run_open_loop<F>(
    spec: &TrafficSpec,
    opts: ServeOptions,
    engine_loop: F,
) -> TrafficReport
where
    F: FnMut(Vec<(GenRequest, Sender<TokenEvent>)>) -> ServeMetrics
        + Send
        + 'static,
{
    let (assignment, arrivals, requests) = prepare(spec);
    let handle = ServerHandle::spawn(opts, engine_loop);
    let (drained, wall_s) =
        drive_requests(spec, &assignment, &arrivals, requests, &|req| {
            handle.submit_request(req)
        });
    // an engine panic already disconnected the streams (counted as
    // lost); keep reporting with whatever metrics survived
    let metrics = handle.shutdown().unwrap_or_else(|e| {
        eprintln!("traffic: engine failed: {}", e);
        ServeMetrics::default()
    });
    rollup(spec, &assignment, &drained, metrics, wall_s)
}

/// [`run_open_loop`] against a multi-replica [`Cluster`]: the same
/// deterministic workload, submitted through the router. The cluster
/// is drained by `Cluster::shutdown`, its merged [`ServeMetrics`]
/// become the report's, and the full [`ClusterMetrics`] (per-replica
/// stats + routing/robustness counters) ride along for fault-plan
/// benches.
pub fn run_open_loop_cluster(
    spec: &TrafficSpec,
    cluster: Cluster,
) -> (TrafficReport, ClusterMetrics) {
    let (assignment, arrivals, requests) = prepare(spec);
    let (drained, wall_s) =
        drive_requests(spec, &assignment, &arrivals, requests, &|req| {
            cluster.submit_request(req)
        });
    let cm = cluster.shutdown();
    let report =
        rollup(spec, &assignment, &drained, cm.total.clone(), wall_s);
    (report, cm)
}

/// Deterministic workload materialization shared by the single-server
/// and cluster drivers: class assignment, arrival offsets, and the
/// built requests (with the spec's deadline applied). Same spec + seed
/// ⇒ identical workload, which is what makes faulted/unfaulted runs
/// comparable.
fn prepare(spec: &TrafficSpec) -> (Vec<usize>, Vec<f64>, Vec<GenRequest>) {
    assert!(!spec.classes.is_empty(), "traffic needs at least one class");
    assert!(spec.n_requests > 0, "traffic needs at least one request");
    let mut rng = Rng::new(spec.seed);
    let assignment = assign_classes(spec, &mut rng);
    let arrivals = arrival_times_ms(
        spec.pattern,
        spec.n_requests,
        spec.mean_gap_ms,
        &mut rng,
    );
    let requests: Vec<GenRequest> = assignment
        .iter()
        .enumerate()
        .map(|(i, &ci)| {
            let req = build_request(
                i,
                &spec.classes[ci],
                spec.vocab.max(2),
                &mut rng,
            );
            match spec.deadline_ms {
                Some(d) => req.with_deadline_ms(d),
                None => req,
            }
        })
        .collect();
    (assignment, arrivals, requests)
}

/// Submit every request at its scheduled arrival offset through
/// `submit` and drain each stream on its own consumer thread
/// (cancellers fire from there). Returns each consumer's observation
/// plus the wall time to the last terminal event.
fn drive_requests(
    spec: &TrafficSpec,
    assignment: &[usize],
    arrivals: &[f64],
    requests: Vec<GenRequest>,
    submit: &dyn Fn(GenRequest) -> (Receiver<TokenEvent>, CancelHandle),
) -> (Vec<Drained>, f64) {
    let t0 = Instant::now();
    let mut consumers = Vec::with_capacity(requests.len());
    for (i, req) in requests.into_iter().enumerate() {
        let target_s = arrivals[i] / 1e3;
        let now_s = t0.elapsed().as_secs_f64();
        if target_s > now_s {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                target_s - now_s,
            ));
        }
        let cancel_after = spec.classes[assignment[i]].cancel_after;
        let (rx, cancel) = submit(req);
        consumers.push(std::thread::spawn(move || {
            drain_stream(rx, cancel, cancel_after)
        }));
    }
    let drained: Vec<Drained> = consumers
        .into_iter()
        .map(|j| j.join().expect("consumer thread"))
        .collect();
    let wall_s = t0.elapsed().as_secs_f64();
    (drained, wall_s)
}

/// Join the serve-side request timelines (by id) with each consumer's
/// observed finish and distill per-class stats + goodput.
fn rollup(
    spec: &TrafficSpec,
    assignment: &[usize],
    drained: &[Drained],
    metrics: ServeMetrics,
    wall_s: f64,
) -> TrafficReport {
    let by_id: std::collections::HashMap<u64, &RequestMetrics> =
        metrics.requests.iter().map(|r| (r.id, r)).collect();
    let mut per_class: Vec<ClassStats> = spec
        .classes
        .iter()
        .map(|c| ClassStats {
            name: c.name,
            sent: 0,
            completed: 0,
            cancelled: 0,
            rejected: 0,
            deadline: 0,
            slo_attained: 0,
            generated_tokens: 0,
            attained_tokens: 0,
            ttft_ms: Samples::new(),
            tpot_ms: Samples::new(),
            slo: c.slo,
        })
        .collect();
    let mut lost = 0usize;
    for (i, d) in drained.iter().enumerate() {
        let cs = &mut per_class[assignment[i]];
        cs.sent += 1;
        let rm = by_id.get(&(i as u64 + 1));
        let (ttft, tpot, generated) = match rm {
            Some(r) => {
                (r.ttft_ms(), r.tpot_ms(), r.generated_tokens)
            }
            None => (None, None, d.streamed),
        };
        cs.generated_tokens += generated;
        if let Some(t) = ttft {
            cs.ttft_ms.push(t);
        }
        if let Some(t) = tpot {
            cs.tpot_ms.push(t);
        }
        match d.finish {
            Some(FinishReason::Cancelled) => cs.cancelled += 1,
            Some(FinishReason::Rejected) => cs.rejected += 1,
            Some(FinishReason::DeadlineExceeded) => cs.deadline += 1,
            Some(_) => {
                cs.completed += 1;
                if cs.slo.attained(ttft.unwrap_or(f64::INFINITY), tpot) {
                    cs.slo_attained += 1;
                    cs.attained_tokens += generated;
                }
            }
            None => lost += 1,
        }
    }
    let attained_tokens: usize =
        per_class.iter().map(|c| c.attained_tokens).sum();
    let attained: usize = per_class.iter().map(|c| c.slo_attained).sum();
    TrafficReport {
        pattern: spec.pattern,
        n_requests: spec.n_requests,
        wall_s,
        goodput_tok_s: if wall_s > 0.0 {
            attained_tokens as f64 / wall_s
        } else {
            0.0
        },
        goodput_req_s: if wall_s > 0.0 {
            attained as f64 / wall_s
        } else {
            0.0
        },
        per_class,
        metrics,
        lost,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{serve_batch, NativeBackend};
    use crate::model::forward::Weights;
    use crate::model::{ModelConfig, WeightStore};

    #[test]
    fn arrival_processes_have_matching_rates() {
        let mut rng = Rng::new(7);
        let n = 400;
        let gap = 10.0;
        let p = arrival_times_ms(Arrivals::Poisson, n, gap, &mut rng);
        let b = arrival_times_ms(Arrivals::Bursty, n, gap, &mut rng);
        assert_eq!(p.len(), n);
        assert!(p.windows(2).all(|w| w[1] >= w[0]), "nondecreasing");
        assert!(b.windows(2).all(|w| w[1] >= w[0]), "nondecreasing");
        // mean inter-arrival of the Poisson stream ~ gap (law of large n)
        let mean = p.last().unwrap() / n as f64;
        assert!(
            (mean - gap).abs() < gap * 0.3,
            "poisson mean gap {} vs {}",
            mean,
            gap
        );
        // bursty: first burst arrives simultaneously
        assert_eq!(b[0], b[7]);
        assert!(b[8] > b[7]);
    }

    #[test]
    fn class_assignment_covers_every_class() {
        let spec = TrafficSpec {
            classes: standard_classes(8),
            n_requests: 12,
            mean_gap_ms: 1.0,
            pattern: Arrivals::Poisson,
            seed: 3,
            vocab: 256,
            deadline_ms: None,
        };
        let mut rng = Rng::new(spec.seed);
        let assign = assign_classes(&spec, &mut rng);
        assert_eq!(assign.len(), 12);
        for ci in 0..spec.classes.len() {
            assert!(
                assign.contains(&ci),
                "class {} must appear",
                spec.classes[ci].name
            );
        }
    }

    #[test]
    fn slo_attainment_logic() {
        let slo = Slo { ttft_ms: 100.0, tpot_ms: Some(10.0) };
        assert!(slo.attained(50.0, Some(5.0)));
        assert!(!slo.attained(150.0, Some(5.0)));
        assert!(!slo.attained(50.0, Some(50.0)));
        // unmeasurable TPOT never fails the bound
        assert!(slo.attained(50.0, None));
        let no_tpot = Slo { ttft_ms: 100.0, tpot_ms: None };
        assert!(no_tpot.attained(50.0, Some(1e9)));
    }

    #[test]
    fn open_loop_round_reports_all_classes() {
        // tiny end-to-end smoke on the native backend: every stream
        // drains, per-class accounting adds up, JSON parses
        let spec = TrafficSpec {
            classes: standard_classes(16),
            n_requests: 6,
            mean_gap_ms: 1.0,
            pattern: Arrivals::Poisson,
            seed: 11,
            vocab: 64,
            deadline_ms: None,
        };
        let opts = ServeOptions::default();
        let report = run_open_loop(&spec, opts, move |batch| {
            let cfg = ModelConfig::builtin("opt-micro").unwrap();
            let store = WeightStore::random("t", cfg, 41);
            let w = Weights::Fp(&store);
            let mut be = NativeBackend::new(w, 4);
            serve_batch(&mut be, batch, opts)
        });
        assert_eq!(report.n_requests, 6);
        assert_eq!(report.lost, 0);
        let sent: usize = report.per_class.iter().map(|c| c.sent).sum();
        assert_eq!(sent, 6);
        // first 5 requests covered all 5 classes
        assert_eq!(report.classes_sent(), 5);
        assert_eq!(
            report.completed() + report.cancelled() + report.rejected(),
            6
        );
        // the canceller's request resolved one way or the other (the
        // cancel races the tiny budget — either outcome is legal here;
        // tests/observability.rs pins a deterministic mid-serve cancel)
        let canceller = report
            .per_class
            .iter()
            .find(|c| c.name == "canceller")
            .unwrap();
        assert_eq!(canceller.cancelled + canceller.completed, canceller.sent);
        let parsed = Json::parse(&report.to_json().to_string_pretty())
            .expect("report JSON parses");
        assert!(parsed.get("goodput_tok_s").is_some());
        assert!(parsed.at(&["metrics", "ttft_p99_ms"]).is_some());
        assert_eq!(
            parsed.get("per_class").and_then(|p| p.as_arr()).unwrap().len(),
            5
        );
    }
}
