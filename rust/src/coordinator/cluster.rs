//! Fault-tolerant multi-replica serving: N worker threads, each owning
//! a [`DecodeBackend`] replica, fronted by a router thread that owns all
//! cluster state (no shared locks on the hot path).
//!
//! * **Prefix-affinity load balancing** — the router keeps a
//!   [`PrefixIndex`] whose "blocks" are replica ids: a request routes to
//!   the replica that served the longest cached prefix of its prompt
//!   ([`PrefixIndex::peek_blocks`], so routing probes never perturb the
//!   LRU), because that replica's paged KV pool already holds those
//!   blocks. When the affine replica's queue is deeper than
//!   [`ClusterOptions::spill_depth`] the request spills to the
//!   least-loaded live replica.
//! * **Failure detection and requeue** — every worker round runs under
//!   `catch_unwind`; a panic (or backend error) reports the worker dead.
//!   Wedged workers are caught by a heartbeat: the scheduler loop ticks
//!   a per-worker [`Heartbeat`] every step, and a busy worker whose tick
//!   is older than [`ClusterOptions::stall_timeout_ms`] is marked down.
//!   Down workers' in-flight and queued requests requeue onto survivors
//!   with capped exponential backoff and an at-most-N-retries budget.
//!   Retries are idempotent by construction: sampling is a pure function
//!   of `(seed, token index)`, so a replayed request reproduces the same
//!   tokens, and the router de-duplicates the replayed stream so clients
//!   see each token and the final `Done` exactly once.
//! * **Graceful degradation** — requests carry an optional
//!   `deadline_ms` (enforced inside the scheduler at admission and step
//!   boundaries → [`FinishReason::DeadlineExceeded`] with partial
//!   output) and a `priority`; when the cluster's outstanding depth
//!   crosses [`ClusterOptions::shed_watermark`], requests below
//!   [`ClusterOptions::shed_below_priority`] are fast-rejected at
//!   submission instead of queued.
//! * **Deterministic fault injection** — a [`FaultPlan`] threads
//!   per-worker faults (kill at step s, stall for d ms at step s, fail
//!   one admission) through the worker spawn path, so chaos scenarios
//!   replay identically in tests (`tests/cluster.rs`).
//!
//! Observability: per-round [`ServeMetrics`] roll up through
//! `merge_round` into [`ClusterMetrics`] (plus per-replica stats and
//! router counters), and the router emits `cluster.route` /
//! `cluster.requeue` / `cluster.retry` / `cluster.shed` /
//! `cluster.worker_down` trace instants.

use std::collections::HashMap;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Once};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::metrics::ServeMetrics;
use super::serve::{
    serve_events, CancelHandle, DecodeBackend, FinishReason, GenOutcome,
    GenRequest, SamplingParams, ServeOptions, SlotWork, StopCriteria,
    TokenEvent,
};
use crate::kv::{KvPoolStats, PrefixIndex};
use crate::model::ModelConfig;
use crate::obs::trace;
use crate::util::json::{self, Json};
use crate::util::ordered_lock::{rank, OrderedMutex};

// ---------------------------------------------------------------------------
// fault injection
// ---------------------------------------------------------------------------

/// One injected fault, addressed to a worker. Steps count that worker's
/// scheduler steps monotonically across rounds (first step is 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// panic the worker's engine loop on its `step`-th scheduler step
    Kill { worker: usize, step: u64 },
    /// sleep `ms` inside the worker's `step`-th scheduler step (wedges
    /// the heartbeat; recovers afterwards)
    Stall { worker: usize, step: u64, ms: u64 },
    /// refuse the worker's next admission once (transient pool-full)
    AdmitFail { worker: usize },
}

/// A deterministic chaos scenario: the set of faults each worker will
/// execute. Parsed from CLI specs like `kill:1@8,stall:0@3:50,admit:0`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn with(mut self, f: Fault) -> FaultPlan {
        self.faults.push(f);
        self
    }

    /// Parse a comma/semicolon-separated spec: `kill:<w>@<s>`,
    /// `stall:<w>@<s>:<ms>`, `admit:<w>`.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        fn num<T: std::str::FromStr>(s: &str, what: &str) -> Result<T, String> {
            s.trim()
                .parse()
                .map_err(|_| format!("fault spec: bad {} `{}`", what, s))
        }
        let mut plan = FaultPlan::default();
        for part in spec.split([',', ';']) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (kind, rest) = part.split_once(':').ok_or_else(|| {
                format!("fault `{}`: expected kind:args", part)
            })?;
            match kind {
                "kill" => {
                    let (w, s) = rest.split_once('@').ok_or_else(|| {
                        format!("kill `{}`: expected worker@step", rest)
                    })?;
                    plan.faults.push(Fault::Kill {
                        worker: num(w, "worker")?,
                        step: num(s, "step")?,
                    });
                }
                "stall" => {
                    let (w, tail) = rest.split_once('@').ok_or_else(|| {
                        format!("stall `{}`: expected worker@step:ms", rest)
                    })?;
                    let (s, ms) = tail.split_once(':').ok_or_else(|| {
                        format!("stall `{}`: expected worker@step:ms", rest)
                    })?;
                    plan.faults.push(Fault::Stall {
                        worker: num(w, "worker")?,
                        step: num(s, "step")?,
                        ms: num(ms, "ms")?,
                    });
                }
                "admit" => plan.faults.push(Fault::AdmitFail {
                    worker: num(rest, "worker")?,
                }),
                other => {
                    return Err(format!(
                        "unknown fault kind `{}` (kill|stall|admit)",
                        other
                    ))
                }
            }
        }
        Ok(plan)
    }

    /// The runtime fault state handed to worker `w` at spawn.
    fn for_worker(&self, w: usize) -> WorkerFaults {
        let mut out = WorkerFaults::default();
        for f in &self.faults {
            match *f {
                Fault::Kill { worker, step } if worker == w => {
                    out.kill_at = Some(step);
                }
                Fault::Stall { worker, step, ms } if worker == w => {
                    out.stalls.push((step, ms));
                }
                Fault::AdmitFail { worker } if worker == w => {
                    out.admit_fails += 1;
                }
                _ => {}
            }
        }
        out
    }
}

/// Per-worker runtime fault state: a monotonic step counter (across
/// rounds) plus the pending faults addressed to this worker.
#[derive(Debug, Default)]
struct WorkerFaults {
    step: u64,
    kill_at: Option<u64>,
    stalls: Vec<(u64, u64)>,
    admit_fails: usize,
}

impl WorkerFaults {
    /// Called at the top of every scheduler step; fires stalls and
    /// kills scheduled for this step.
    fn on_step(&mut self) {
        self.step += 1;
        let s = self.step;
        if let Some(i) = self.stalls.iter().position(|&(at, _)| at == s) {
            let (_, ms) = self.stalls.remove(i);
            std::thread::sleep(Duration::from_millis(ms));
        }
        if self.kill_at == Some(s) {
            // lint:allow(hot-panic): deliberate fault injection — the
            // worker loop catches this and reports the replica dead
            panic!("fault-plan kill at step {}", s);
        }
    }

    /// True once per queued admit-fail fault: the admission is refused.
    fn take_admit_fail(&mut self) -> bool {
        if self.admit_fails > 0 {
            self.admit_fails -= 1;
            true
        } else {
            false
        }
    }
}

// ---------------------------------------------------------------------------
// heartbeat + monitored backend
// ---------------------------------------------------------------------------

/// Shared liveness state for one worker, pulsed from inside its
/// scheduler loop and read by the router's stall scan.
#[derive(Debug)]
pub struct Heartbeat {
    epoch: Instant,
    steps: AtomicU64,
    last_beat_ms: AtomicU64,
    busy: AtomicBool,
}

impl Heartbeat {
    fn new(epoch: Instant) -> Heartbeat {
        Heartbeat {
            epoch,
            steps: AtomicU64::new(0),
            last_beat_ms: AtomicU64::new(0),
            busy: AtomicBool::new(false),
        }
    }

    fn now_ms(&self, now: Instant) -> u64 {
        now.saturating_duration_since(self.epoch).as_millis() as u64
    }

    // Every mutation has an explicit-clock `_at` variant so the model
    // checker (`modelcheck_*` tests below) can replay the worker/router
    // handoff deterministically at chosen timestamps; the wall-clock
    // entry points delegate.

    fn beat_at(&self, now: Instant) {
        self.last_beat_ms.store(self.now_ms(now), Ordering::Relaxed);
    }

    fn beat(&self) {
        self.beat_at(Instant::now());
    }

    fn begin_round_at(&self, now: Instant) {
        self.busy.store(true, Ordering::Relaxed);
        self.beat_at(now);
    }

    fn begin_round(&self) {
        self.begin_round_at(Instant::now());
    }

    fn end_round_at(&self, now: Instant) {
        self.beat_at(now);
        self.busy.store(false, Ordering::Relaxed);
    }

    fn end_round(&self) {
        self.end_round_at(Instant::now());
    }

    fn step_tick(&self) {
        self.steps.fetch_add(1, Ordering::Relaxed);
        self.beat();
    }

    /// Total scheduler steps this worker has run.
    pub fn steps(&self) -> u64 {
        self.steps.load(Ordering::Relaxed)
    }

    /// Milliseconds since the last pulse (0 while the clock agrees the
    /// pulse is current).
    fn age_ms(&self, now: Instant) -> u64 {
        self.now_ms(now)
            .saturating_sub(self.last_beat_ms.load(Ordering::Relaxed))
    }

    fn is_busy(&self) -> bool {
        self.busy.load(Ordering::Relaxed)
    }
}

/// Backend wrapper threading heartbeat pulses and fault injection into
/// the scheduler's step path. Every worker round serves through this.
struct Monitored<'m> {
    inner: &'m mut dyn DecodeBackend,
    hb: &'m Heartbeat,
    faults: &'m mut WorkerFaults,
}

impl DecodeBackend for Monitored<'_> {
    fn slots(&self) -> usize {
        self.inner.slots()
    }
    fn cfg(&self) -> ModelConfig {
        self.inner.cfg()
    }
    fn max_chunk(&self) -> usize {
        self.inner.max_chunk()
    }
    fn plan_chunk(&self, cap: usize) -> usize {
        self.inner.plan_chunk(cap)
    }
    fn step(&mut self, work: &[SlotWork]) -> Result<Vec<Vec<f32>>, String> {
        self.hb.step_tick();
        self.faults.on_step();
        self.inner.step(work)
    }
    fn reset_slot(&mut self, slot: usize) {
        self.inner.reset_slot(slot)
    }
    fn slot_pos(&self, slot: usize) -> usize {
        self.inner.slot_pos(slot)
    }
    fn weight_bytes_per_step(&self) -> usize {
        self.inner.weight_bytes_per_step()
    }
    fn kv_bytes_per_step(&self) -> usize {
        self.inner.kv_bytes_per_step()
    }
    fn admit(
        &mut self,
        slot: usize,
        prompt: &[i32],
        max_new: usize,
    ) -> Option<usize> {
        if self.faults.take_admit_fail() {
            return None;
        }
        self.inner.admit(slot, prompt, max_new)
    }
    fn pre_step(&mut self, need: &[usize]) -> Vec<usize> {
        self.hb.beat();
        self.inner.pre_step(need)
    }
    fn release_slot(&mut self, slot: usize) {
        self.inner.release_slot(slot)
    }
    fn pool_stats(&self) -> Option<KvPoolStats> {
        self.inner.pool_stats()
    }
}

// ---------------------------------------------------------------------------
// replica engines
// ---------------------------------------------------------------------------

/// One continuous-batching round handed to a [`ReplicaEngine`]: the
/// drained micro-batch plus the cluster's monitoring hooks. The engine
/// builds (or reuses) its backend and calls [`RoundCtx::run`].
pub struct RoundCtx<'c> {
    reqs: Vec<GenRequest>,
    opts: ServeOptions,
    hb: &'c Heartbeat,
    faults: &'c mut WorkerFaults,
    sink: &'c mut dyn FnMut(TokenEvent),
}

impl RoundCtx<'_> {
    /// Serve the round through `backend` (wrapped with heartbeat pulses
    /// and fault injection), returning the round's metrics.
    pub fn run(
        self,
        backend: &mut dyn DecodeBackend,
    ) -> Result<ServeMetrics, String> {
        let RoundCtx { reqs, opts, hb, faults, sink } = self;
        let mut mon = Monitored { inner: backend, hb, faults };
        serve_events(&mut mon, reqs, opts, sink).map(|(_, m)| m)
    }
}

/// A factory-plus-loop for one replica: called on the worker thread
/// with each drained round. Implementations own whatever shared state
/// the backend needs (typically an `Arc<WeightStore>`) and construct
/// the non-`Send` backend per round — the same inversion
/// `server::ServerHandle::spawn` uses, made a trait so the cluster can
/// hold a heterogeneous `Vec<Box<dyn ReplicaEngine>>`.
pub trait ReplicaEngine: Send {
    fn run(&mut self, round: RoundCtx<'_>) -> Result<ServeMetrics, String>;
}

impl ReplicaEngine for Box<dyn ReplicaEngine> {
    fn run(&mut self, round: RoundCtx<'_>) -> Result<ServeMetrics, String> {
        (**self).run(round)
    }
}

// ---------------------------------------------------------------------------
// options + metrics
// ---------------------------------------------------------------------------

/// Cluster routing/robustness knobs.
#[derive(Debug, Clone, Copy)]
pub struct ClusterOptions {
    /// per-replica scheduling knobs (each worker runs its own loop)
    pub serve: ServeOptions,
    /// token-block size of the affinity routing key; requests matching
    /// a cached chain route to the replica that served it
    pub affinity_block: usize,
    /// spill to least-loaded when the affine replica already has this
    /// many outstanding requests
    pub spill_depth: usize,
    /// how many times a request may be requeued after worker failures
    /// before it finishes [`FinishReason::Rejected`]
    pub max_retries: usize,
    /// base requeue backoff, doubled per retry attempt
    pub backoff_ms: u64,
    /// backoff ceiling
    pub backoff_cap_ms: u64,
    /// a busy worker whose heartbeat is older than this is marked down
    pub stall_timeout_ms: u64,
    /// shed when outstanding requests reach this depth
    /// (`usize::MAX` = shedding off)
    pub shed_watermark: usize,
    /// shed only requests whose priority is below this cutoff
    pub shed_below_priority: u8,
}

impl Default for ClusterOptions {
    fn default() -> ClusterOptions {
        ClusterOptions {
            serve: ServeOptions::default(),
            affinity_block: 16,
            spill_depth: 8,
            max_retries: 3,
            backoff_ms: 10,
            backoff_cap_ms: 500,
            stall_timeout_ms: 10_000,
            shed_watermark: usize::MAX,
            shed_below_priority: 1,
        }
    }
}

/// Final per-replica accounting.
#[derive(Debug, Clone, Default)]
pub struct ReplicaStats {
    pub worker: usize,
    pub alive: bool,
    pub rounds: usize,
    pub steps: u64,
    /// outcomes this replica delivered (as the request's final owner)
    pub served: usize,
    pub fail_reason: Option<String>,
    pub metrics: ServeMetrics,
}

impl ReplicaStats {
    /// One human line for the CLI's per-replica report.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "replica {}: {} | rounds {}, steps {}, served {}, {} tokens",
            self.worker,
            if self.alive { "up" } else { "DOWN" },
            self.rounds,
            self.steps,
            self.served,
            self.metrics.total_generated(),
        );
        if let Some(why) = &self.fail_reason {
            s.push_str(&format!(" — {}", why));
        }
        s
    }
}

/// Cluster-wide rollup: per-round [`ServeMetrics`] merged across all
/// replicas, per-replica stats, and the router's own counters.
#[derive(Debug, Clone, Default)]
pub struct ClusterMetrics {
    pub total: ServeMetrics,
    pub replicas: Vec<ReplicaStats>,
    /// requests pulled off a dead/wedged worker and rescheduled
    pub requeues: usize,
    /// requests that exhausted the retry budget (finished Rejected)
    pub retries_exhausted: usize,
    /// requests fast-rejected by the load-shed watermark
    pub shed: usize,
    pub workers_died: usize,
    /// routing decisions that followed the prefix-affinity chain
    pub affinity_hits: usize,
    /// affine routes redirected because the affine replica was too deep
    pub spills: usize,
}

impl ClusterMetrics {
    pub fn replicas_alive(&self) -> usize {
        self.replicas.iter().filter(|r| r.alive).count()
    }

    pub fn summary(&self) -> String {
        format!(
            "cluster: {}/{} replicas up, {} requeues, {} retries-exhausted, \
             {} shed, {} died, affinity {} hit / {} spill",
            self.replicas_alive(),
            self.replicas.len(),
            self.requeues,
            self.retries_exhausted,
            self.shed,
            self.workers_died,
            self.affinity_hits,
            self.spills,
        )
    }

    pub fn to_json(&self) -> Json {
        let replicas: Vec<Json> = self
            .replicas
            .iter()
            .map(|r| {
                json::obj(vec![
                    ("worker", json::num(r.worker as f64)),
                    ("alive", Json::Bool(r.alive)),
                    ("rounds", json::num(r.rounds as f64)),
                    ("steps", json::num(r.steps as f64)),
                    ("served", json::num(r.served as f64)),
                    (
                        "fail_reason",
                        match &r.fail_reason {
                            Some(why) => json::s(why),
                            None => Json::Null,
                        },
                    ),
                ])
            })
            .collect();
        json::obj(vec![
            ("replicas", Json::Arr(replicas)),
            ("requeues", json::num(self.requeues as f64)),
            (
                "retries_exhausted",
                json::num(self.retries_exhausted as f64),
            ),
            ("shed", json::num(self.shed as f64)),
            ("workers_died", json::num(self.workers_died as f64)),
            ("affinity_hits", json::num(self.affinity_hits as f64)),
            ("spills", json::num(self.spills as f64)),
        ])
    }
}

// ---------------------------------------------------------------------------
// router
// ---------------------------------------------------------------------------

/// The stall predicate, pure in its inputs so the model checker
/// (`modelcheck_heartbeat_*` below) can drive it through every
/// worker/router interleaving at explicit timestamps: a live worker
/// with assigned load whose busy-flagged heartbeat went silent past
/// the timeout.
fn is_stalled(
    alive: bool,
    load: usize,
    busy: bool,
    age_ms: u64,
    timeout_ms: u64,
) -> bool {
    alive && load > 0 && busy && age_ms > timeout_ms
}

/// Point-in-time cluster occupancy, published by the router after
/// every message batch and readable from any thread through
/// [`Cluster::status`] without a router round-trip. Guarded by a
/// rank-tagged [`OrderedMutex`] (`rank::CLUSTER_STATUS`) so the lock
/// lint can prove it participates in no cycle.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ClusterStatus {
    pub alive_workers: usize,
    pub tracked_requests: usize,
    pub pending_retries: usize,
    /// outstanding assignments summed over live workers
    pub total_load: usize,
    pub draining: bool,
}

enum WorkerJob {
    Run(Vec<GenRequest>),
    Stop,
}

enum RouterMsg {
    Submit(GenRequest, Sender<TokenEvent>),
    Event { worker: usize, ev: TokenEvent },
    Round { worker: usize, metrics: ServeMetrics },
    Died { worker: usize, reason: String },
    Shutdown(Sender<ClusterMetrics>),
}

/// Exactly-once client-stream bookkeeping for one tracked request,
/// factored out of [`Tracked`] so the replay protocol is a pure state
/// machine the model checker can drive through every interleaving
/// (`modelcheck_stream_dedup_*` below). A requeued request regenerates
/// its stream from token 0 (sampling is pure in `(seed, index)`), and
/// only tokens past the delivered high-water mark are forwarded — so
/// the client stream is exactly-once even across retries.
#[derive(Debug, Default)]
struct StreamDedup {
    /// tokens forwarded to the client so far (kept by value, so a
    /// retries-exhausted rejection can deliver the partial output)
    tokens: Vec<i32>,
    delivered: usize,
    seen: usize,
}

impl StreamDedup {
    /// A fresh worker assignment replays the stream from position 0.
    fn begin_replay(&mut self) {
        self.seen = 0;
    }

    /// Observe the next streamed token; `true` means it is new to the
    /// client and must be forwarded, `false` that the replay is still
    /// at or below the delivered high-water mark.
    fn on_token(&mut self, tok: i32) -> bool {
        self.seen += 1;
        if self.seen > self.delivered {
            self.delivered = self.seen;
            self.tokens.push(tok);
            true
        } else {
            debug_assert_eq!(
                self.tokens.get(self.seen - 1),
                Some(&tok),
                "replayed stream diverged from the delivered one"
            );
            false
        }
    }

    /// Everything forwarded so far, surrendered for a terminal outcome.
    fn into_tokens(self) -> Vec<i32> {
        self.tokens
    }
}

/// One live request, as the router sees it.
struct Tracked {
    req: GenRequest,
    client: Sender<TokenEvent>,
    worker: Option<usize>,
    /// replay de-duplication state for the client-facing stream
    stream: StreamDedup,
    /// times this request has been requeued after a worker failure
    attempts: usize,
}

struct WorkerState {
    tx: Sender<WorkerJob>,
    hb: Arc<Heartbeat>,
    alive: bool,
    /// outstanding requests currently assigned to this worker
    load: usize,
    rounds: usize,
    served: usize,
    fail_reason: Option<String>,
    metrics: ServeMetrics,
}

struct Router {
    opts: ClusterOptions,
    workers: Vec<WorkerState>,
    tracked: HashMap<u64, Tracked>,
    /// prefix-affinity routing history: chains of replica ids keyed by
    /// prompt blocks
    affinity: PrefixIndex,
    /// backoff-delayed requeues: (due, request id)
    pending: Vec<(Instant, u64)>,
    draining: Option<Sender<ClusterMetrics>>,
    /// occupancy board shared with [`Cluster::status`]; the router is
    /// the only writer
    status: Arc<OrderedMutex<ClusterStatus>>,
    requeues: usize,
    retries_exhausted: usize,
    shed: usize,
    workers_died: usize,
    affinity_hits: usize,
    spills: usize,
}

impl Router {
    fn run(mut self, rx: Receiver<RouterMsg>) {
        loop {
            match rx.recv_timeout(self.next_wakeup()) {
                Ok(RouterMsg::Shutdown(reply)) => {
                    self.draining = Some(reply)
                }
                Ok(msg) => self.handle(msg),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => return,
            }
            self.fire_due_retries();
            self.scan_stalled();
            self.publish_status();
            if self.draining.is_some()
                && self.tracked.is_empty()
                && self.pending.is_empty()
            {
                self.finish_drain();
                return;
            }
        }
    }

    /// Refresh the shared occupancy board. Routing state stays owned by
    /// this thread; the board is a copied-out snapshot, so the lock is
    /// held only for the swap and nests inside nothing.
    fn publish_status(&self) {
        let snap = ClusterStatus {
            alive_workers: self.workers.iter().filter(|w| w.alive).count(),
            tracked_requests: self.tracked.len(),
            pending_retries: self.pending.len(),
            total_load: self.workers.iter().map(|w| w.load).sum(),
            draining: self.draining.is_some(),
        };
        *self.status.lock() = snap;
    }

    /// Sleep until the next retry comes due, but never longer than the
    /// stall-scan interval (a quarter of the stall timeout).
    fn next_wakeup(&self) -> Duration {
        let scan = Duration::from_millis(
            (self.opts.stall_timeout_ms / 4).clamp(5, 500),
        );
        let now = Instant::now();
        self.pending
            .iter()
            .map(|(due, _)| due.saturating_duration_since(now))
            .min()
            .map_or(scan, |d| d.min(scan))
    }

    fn handle(&mut self, msg: RouterMsg) {
        match msg {
            RouterMsg::Submit(req, client) => self.submit(req, client),
            RouterMsg::Event { worker, ev } => self.event(worker, ev),
            RouterMsg::Round { worker, metrics } => {
                self.workers[worker].rounds += 1;
                self.workers[worker].metrics.merge_round(metrics);
            }
            RouterMsg::Died { worker, reason } => {
                if self.workers[worker].alive {
                    self.mark_down(worker, reason);
                } else if self.workers[worker].fail_reason.is_none() {
                    self.workers[worker].fail_reason = Some(reason);
                }
            }
            RouterMsg::Shutdown(reply) => self.draining = Some(reply),
        }
    }

    fn submit(&mut self, req: GenRequest, client: Sender<TokenEvent>) {
        let id = req.id;
        if self.tracked.len() >= self.opts.shed_watermark
            && req.priority < self.opts.shed_below_priority
        {
            self.shed += 1;
            trace::instant("cluster.shed", &[("id", id as f64)]);
            let _ = client.send(TokenEvent::Done(GenOutcome {
                id,
                tokens: Vec::new(),
                finish: FinishReason::Rejected,
            }));
            return;
        }
        debug_assert!(
            !self.tracked.contains_key(&id),
            "duplicate request id {} submitted to the cluster",
            id
        );
        self.tracked.insert(
            id,
            Tracked {
                req,
                client,
                worker: None,
                stream: StreamDedup::default(),
                attempts: 0,
            },
        );
        self.assign(id);
    }

    /// Route by prefix affinity, spilling to least-loaded; `None` when
    /// no replica is alive.
    fn route(&mut self, prompt: &[i32]) -> Option<usize> {
        let n = self.workers.len();
        let alive: Vec<usize> =
            (0..n).filter(|&w| self.workers[w].alive).collect();
        if alive.is_empty() {
            return None;
        }
        let bs = self.opts.affinity_block.max(1);
        // deepest live replica on the cached chain = most shared blocks
        let affine = self
            .affinity
            .peek_blocks(prompt, bs)
            .into_iter()
            .rev()
            .find(|&w| w < n && self.workers[w].alive);
        let pick = match affine {
            Some(w) if self.workers[w].load < self.opts.spill_depth => {
                self.affinity_hits += 1;
                w
            }
            other => {
                let least = alive
                    .into_iter()
                    .min_by_key(|&w| self.workers[w].load)
                    // lint:allow(hot-expect): the is_empty check above
                    // returned None before this arm
                    .expect("alive nonempty");
                if other.is_some() {
                    self.spills += 1;
                }
                least
            }
        };
        // record the routing decision for future prefix matches
        let chunks = prompt.len() / bs;
        if chunks > 0 {
            let picks = vec![pick; chunks];
            self.affinity.insert_chain(prompt, bs, &picks);
        }
        Some(pick)
    }

    fn assign(&mut self, id: u64) {
        let Some(prompt) =
            self.tracked.get(&id).map(|t| t.req.prompt.clone())
        else {
            return;
        };
        match self.route(&prompt) {
            Some(w) => {
                let req = {
                    // lint:allow(hot-expect): presence checked at the
                    // top of assign() (prompt clone returned early)
                    let t = self.tracked.get_mut(&id).expect("tracked");
                    t.worker = Some(w);
                    t.stream.begin_replay();
                    t.req.clone()
                };
                self.workers[w].load += 1;
                trace::instant(
                    "cluster.route",
                    &[("id", id as f64), ("worker", w as f64)],
                );
                let _ = self.workers[w].tx.send(WorkerJob::Run(vec![req]));
            }
            // no live replicas left: fail fast instead of queueing on
            // a cluster that cannot recover
            None => self.finish_direct(id, FinishReason::Rejected),
        }
    }

    /// Deliver a terminal outcome from the router itself (shed, retry
    /// budget exhausted, no live replicas), carrying the tokens already
    /// streamed to the client.
    fn finish_direct(&mut self, id: u64, why: FinishReason) {
        if let Some(t) = self.tracked.remove(&id) {
            if let Some(w) = t.worker {
                self.workers[w].load =
                    self.workers[w].load.saturating_sub(1);
            }
            let _ = t.client.send(TokenEvent::Done(GenOutcome {
                id,
                tokens: t.stream.into_tokens(),
                finish: why,
            }));
        }
    }

    fn event(&mut self, worker: usize, ev: TokenEvent) {
        match ev {
            TokenEvent::Token { id, tok } => {
                let Some(t) = self.tracked.get_mut(&id) else { return };
                if t.worker != Some(worker) {
                    return; // stale stream from a de-assigned worker
                }
                if t.stream.on_token(tok) {
                    let _ = t.client.send(TokenEvent::Token { id, tok });
                }
            }
            TokenEvent::Done(o) => {
                let current = self
                    .tracked
                    .get(&o.id)
                    .map(|t| t.worker == Some(worker))
                    .unwrap_or(false);
                if !current {
                    return; // late Done from a superseded assignment
                }
                // lint:allow(hot-expect): `current` above proved the
                // entry exists and belongs to this worker
                let t = self.tracked.remove(&o.id).expect("checked");
                self.workers[worker].load =
                    self.workers[worker].load.saturating_sub(1);
                self.workers[worker].served += 1;
                let _ = t.client.send(TokenEvent::Done(o));
            }
        }
    }

    fn mark_down(&mut self, worker: usize, reason: String) {
        self.workers[worker].alive = false;
        self.workers[worker].fail_reason = Some(reason);
        self.workers[worker].load = 0;
        self.workers_died += 1;
        trace::instant(
            "cluster.worker_down",
            &[("worker", worker as f64)],
        );
        let orphans: Vec<u64> = self
            .tracked
            .iter()
            .filter(|(_, t)| t.worker == Some(worker))
            .map(|(&id, _)| id)
            .collect();
        for id in orphans {
            self.requeue(id);
        }
    }

    /// Reschedule a request whose worker died, with capped exponential
    /// backoff; exhausting the retry budget finishes it Rejected.
    fn requeue(&mut self, id: u64) {
        let attempts = {
            let Some(t) = self.tracked.get_mut(&id) else { return };
            t.worker = None;
            t.attempts += 1;
            t.attempts
        };
        self.requeues += 1;
        trace::instant(
            "cluster.requeue",
            &[("id", id as f64), ("attempt", attempts as f64)],
        );
        if attempts > self.opts.max_retries {
            self.retries_exhausted += 1;
            self.finish_direct(id, FinishReason::Rejected);
            return;
        }
        let backoff = self
            .opts
            .backoff_ms
            .saturating_mul(1u64 << (attempts - 1).min(16))
            .min(self.opts.backoff_cap_ms);
        self.pending
            .push((Instant::now() + Duration::from_millis(backoff), id));
    }

    fn fire_due_retries(&mut self) {
        let now = Instant::now();
        let mut due = Vec::new();
        self.pending.retain(|&(at, id)| {
            if at <= now {
                due.push(id);
                false
            } else {
                true
            }
        });
        for id in due {
            if self.tracked.contains_key(&id) {
                trace::instant("cluster.retry", &[("id", id as f64)]);
                self.assign(id);
            }
        }
    }

    /// A busy worker whose heartbeat went silent past the stall timeout
    /// is as good as dead: mark it down and requeue its requests. (If
    /// it later wakes and finishes, its stale events are filtered by
    /// the assignment check.)
    fn scan_stalled(&mut self) {
        let now = Instant::now();
        let stalled: Vec<usize> = (0..self.workers.len())
            .filter(|&w| {
                let ws = &self.workers[w];
                is_stalled(
                    ws.alive,
                    ws.load,
                    ws.hb.is_busy(),
                    ws.hb.age_ms(now),
                    self.opts.stall_timeout_ms,
                )
            })
            .collect();
        for w in stalled {
            self.mark_down(
                w,
                format!(
                    "stalled: no heartbeat for {}ms",
                    self.opts.stall_timeout_ms
                ),
            );
        }
    }

    fn finish_drain(&mut self) {
        for ws in &self.workers {
            let _ = ws.tx.send(WorkerJob::Stop);
        }
        let mut cm = ClusterMetrics {
            requeues: self.requeues,
            retries_exhausted: self.retries_exhausted,
            shed: self.shed,
            workers_died: self.workers_died,
            affinity_hits: self.affinity_hits,
            spills: self.spills,
            ..ClusterMetrics::default()
        };
        for (w, ws) in self.workers.iter().enumerate() {
            cm.total.merge_round(ws.metrics.clone());
            cm.replicas.push(ReplicaStats {
                worker: w,
                alive: ws.alive,
                rounds: ws.rounds,
                steps: ws.hb.steps(),
                served: ws.served,
                fail_reason: ws.fail_reason.clone(),
                metrics: ws.metrics.clone(),
            });
        }
        if let Some(reply) = self.draining.take() {
            let _ = reply.send(cm);
        }
    }
}

// ---------------------------------------------------------------------------
// worker loop + cluster front-end
// ---------------------------------------------------------------------------

/// Suppress the default panic printout for `ganq-`named engine/worker
/// threads (their panics are caught, reported through channels, and
/// surfaced in metrics — the stderr backtrace is pure noise in chaos
/// tests). Other threads keep the previous hook. Process-global,
/// installed once.
pub fn quiet_ganq_thread_panics() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = panic::take_hook();
        panic::set_hook(Box::new(move |info| {
            let quiet = std::thread::current()
                .name()
                .map(|n| n.starts_with("ganq-"))
                .unwrap_or(false);
            if !quiet {
                prev(info);
            }
        }));
    });
}

fn worker_loop<E: ReplicaEngine>(
    wid: usize,
    mut engine: E,
    opts: ServeOptions,
    mut faults: WorkerFaults,
    hb: Arc<Heartbeat>,
    rx: Receiver<WorkerJob>,
    tx: Sender<RouterMsg>,
) {
    let window = opts.serve_window.max(1);
    loop {
        let mut reqs = match rx.recv() {
            Ok(WorkerJob::Run(r)) => r,
            Ok(WorkerJob::Stop) | Err(_) => break,
        };
        let mut stop = false;
        while reqs.len() < window {
            match rx.try_recv() {
                Ok(WorkerJob::Run(r)) => reqs.extend(r),
                Ok(WorkerJob::Stop) => {
                    stop = true;
                    break;
                }
                Err(_) => break,
            }
        }
        hb.begin_round();
        let round = panic::catch_unwind(AssertUnwindSafe(|| {
            let mut sink = |ev: TokenEvent| {
                let _ = tx.send(RouterMsg::Event { worker: wid, ev });
            };
            engine.run(RoundCtx {
                reqs,
                opts,
                hb: &hb,
                faults: &mut faults,
                sink: &mut sink,
            })
        }));
        hb.end_round();
        match round {
            Ok(Ok(metrics)) => {
                let _ = tx.send(RouterMsg::Round { worker: wid, metrics });
            }
            Ok(Err(e)) => {
                let _ = tx.send(RouterMsg::Died {
                    worker: wid,
                    reason: format!("engine error: {}", e),
                });
                return;
            }
            Err(p) => {
                let _ = tx.send(RouterMsg::Died {
                    worker: wid,
                    reason: super::server::panic_message(&*p),
                });
                return;
            }
        }
        if stop {
            break;
        }
    }
}

/// Handle to a running cluster: submit from any thread, then
/// [`Cluster::shutdown`] to drain and collect [`ClusterMetrics`].
pub struct Cluster {
    router_tx: Sender<RouterMsg>,
    next_id: AtomicU64,
    status: Arc<OrderedMutex<ClusterStatus>>,
    router_join: Option<JoinHandle<()>>,
    worker_joins: Vec<JoinHandle<()>>,
}

impl Cluster {
    /// Spawn one worker thread per engine plus the router thread.
    /// `plan` injects deterministic faults (pass
    /// [`FaultPlan::none()`] for production).
    pub fn spawn<E: ReplicaEngine + 'static>(
        engines: Vec<E>,
        opts: ClusterOptions,
        plan: &FaultPlan,
    ) -> Cluster {
        assert!(!engines.is_empty(), "cluster needs at least one replica");
        if !plan.is_empty() {
            // injected kills panic on purpose; keep stderr clean
            quiet_ganq_thread_panics();
        }
        let epoch = Instant::now();
        let (router_tx, router_rx) = mpsc::channel();
        let mut workers = Vec::new();
        let mut worker_joins = Vec::new();
        for (wid, engine) in engines.into_iter().enumerate() {
            let (wtx, wrx) = mpsc::channel();
            let hb = Arc::new(Heartbeat::new(epoch));
            let hb_worker = Arc::clone(&hb);
            let faults = plan.for_worker(wid);
            let tx = router_tx.clone();
            let serve_opts = opts.serve;
            let join = std::thread::Builder::new()
                .name(format!("ganq-replica-{}", wid))
                .spawn(move || {
                    worker_loop(
                        wid, engine, serve_opts, faults, hb_worker, wrx,
                        tx,
                    )
                })
                // lint:allow(hot-expect): thread spawn fails only on OS
                // resource exhaustion at cluster startup, never mid-serve
                .expect("spawn replica thread");
            worker_joins.push(join);
            workers.push(WorkerState {
                tx: wtx,
                hb,
                alive: true,
                load: 0,
                rounds: 0,
                served: 0,
                fail_reason: None,
                metrics: ServeMetrics::default(),
            });
        }
        let status = Arc::new(OrderedMutex::new(
            rank::CLUSTER_STATUS,
            "cluster.status",
            ClusterStatus::default(),
        ));
        let router = Router {
            opts,
            workers,
            tracked: HashMap::new(),
            affinity: PrefixIndex::new(),
            pending: Vec::new(),
            draining: None,
            status: Arc::clone(&status),
            requeues: 0,
            retries_exhausted: 0,
            shed: 0,
            workers_died: 0,
            affinity_hits: 0,
            spills: 0,
        };
        let router_join = std::thread::Builder::new()
            .name("ganq-router".into())
            .spawn(move || router.run(router_rx))
            // lint:allow(hot-expect): thread spawn fails only on OS
            // resource exhaustion at cluster startup, never mid-serve
            .expect("spawn router thread");
        Cluster {
            router_tx,
            next_id: AtomicU64::new(1),
            status,
            router_join: Some(router_join),
            worker_joins,
        }
    }

    /// Latest router-published occupancy snapshot (refreshed after
    /// every router message batch; may lag in-flight messages).
    pub fn status(&self) -> ClusterStatus {
        self.status.lock().clone()
    }

    /// Submit a pre-built request (caller-chosen id, unique across the
    /// cluster's lifetime); mirrors `ServerHandle::submit_request`.
    pub fn submit_request(
        &self,
        mut req: GenRequest,
    ) -> (Receiver<TokenEvent>, CancelHandle) {
        req.mark_submitted();
        self.next_id.fetch_max(req.id + 1, Ordering::Relaxed);
        let cancel = req.cancel_handle();
        let (tx, rx) = mpsc::channel();
        let _ = self.router_tx.send(RouterMsg::Submit(req, tx));
        (rx, cancel)
    }

    /// Submit with an auto-assigned id.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        sampling: SamplingParams,
        stop: StopCriteria,
    ) -> (Receiver<TokenEvent>, CancelHandle) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.submit_request(GenRequest::new(id, prompt, sampling, stop))
    }

    /// Drain every outstanding request to a terminal outcome, stop all
    /// threads, and return the cluster rollup.
    pub fn shutdown(mut self) -> ClusterMetrics {
        let (tx, rx) = mpsc::channel();
        let _ = self.router_tx.send(RouterMsg::Shutdown(tx));
        let cm = rx.recv().unwrap_or_default();
        if let Some(j) = self.router_join.take() {
            let _ = j.join();
        }
        for j in self.worker_joins.drain(..) {
            let _ = j.join();
        }
        cm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::serve::NativeBackend;
    use crate::coordinator::server::recv_outcome;
    use crate::model::forward::Weights;
    use crate::model::{ModelConfig, WeightStore};
    use crate::util::modelcheck::explore;

    // ---- model-checked protocol scenarios (CI: `cargo test --release
    // modelcheck`). Each replays the worker/router handoff under EVERY
    // interleaving of the participating threads' operations and asserts
    // the protocol invariant in all of them.

    /// Exactly-once stream delivery across a worker failure: the stale
    /// worker's remaining tokens race the router's reassignment and the
    /// replacement's full replay. In every interleaving the client must
    /// see the stream exactly once, in order.
    #[test]
    fn modelcheck_stream_dedup_exactly_once() {
        let stream = [10i32, 11, 12];
        // thread 0 = stale worker 0 streaming its first two tokens;
        // thread 1 = router reassignment to worker 1, then worker 1's
        // full replay
        let schedules = explore(&[2, 4], 10_000, |order| {
            let mut dedup = StreamDedup::default();
            let mut assigned = 0usize;
            let mut client: Vec<i32> = Vec::new();
            let mut sent0 = 0usize;
            let mut step1 = 0usize;
            for &th in order {
                if th == 0 {
                    // stale worker streams its next token
                    let tok = stream[sent0];
                    sent0 += 1;
                    if assigned == 0 && dedup.on_token(tok) {
                        client.push(tok);
                    }
                } else if step1 == 0 {
                    // router: worker 0 died — reassign to worker 1
                    assigned = 1;
                    dedup.begin_replay();
                    step1 += 1;
                } else {
                    // replacement worker replays from token 0
                    let tok = stream[step1 - 1];
                    step1 += 1;
                    if assigned == 1 && dedup.on_token(tok) {
                        client.push(tok);
                    }
                }
            }
            assert_eq!(
                client, stream,
                "client stream must be exactly-once and in order"
            );
        });
        assert_eq!(schedules, 15, "C(6,2) interleavings of [2,4]");
    }

    /// Heartbeat/stall-detection handoff at explicit timestamps: a
    /// worker wedges mid-round (begins, never beats again, eventually
    /// ends late); the router scans twice. In every interleaving the
    /// worker is marked down at most once, never after its round ended
    /// (busy flag down), and some interleaving does catch the stall.
    #[test]
    fn modelcheck_heartbeat_stall_handoff() {
        let epoch = Instant::now();
        let at = |ms: u64| epoch + Duration::from_millis(ms);
        let timeout_ms = 100u64;
        let mut detections = 0usize;
        let schedules = explore(&[2, 2], 10_000, |order| {
            let hb = Heartbeat::new(epoch);
            let mut wstep = 0usize;
            let mut scan = 0usize;
            let mut alive = true;
            let mut downs = 0usize;
            let mut ended = false;
            for &th in order {
                if th == 0 {
                    // worker: begin at t=0 (then wedge), end at t=200
                    if wstep == 0 {
                        hb.begin_round_at(at(0));
                    } else {
                        hb.end_round_at(at(200));
                        ended = true;
                    }
                    wstep += 1;
                } else {
                    // router: stall scans at t=150 and t=300
                    scan += 1;
                    let now = at(if scan == 1 { 150 } else { 300 });
                    if is_stalled(
                        alive,
                        1,
                        hb.is_busy(),
                        hb.age_ms(now),
                        timeout_ms,
                    ) {
                        assert!(
                            !ended,
                            "a cleanly finished round must never be \
                             declared stalled"
                        );
                        alive = false;
                        downs += 1;
                    }
                }
            }
            assert!(downs <= 1, "mark_down must fire at most once");
            detections += downs;
        });
        assert_eq!(schedules, 6, "C(4,2) interleavings of [2,2]");
        assert!(
            detections > 0,
            "some interleaving must catch the wedged round"
        );
    }

    struct NativeReplica {
        store: Arc<WeightStore>,
        slots: usize,
    }

    impl ReplicaEngine for NativeReplica {
        fn run(
            &mut self,
            round: RoundCtx<'_>,
        ) -> Result<ServeMetrics, String> {
            let w = Weights::Fp(&self.store);
            let mut be = NativeBackend::new(w, self.slots);
            round.run(&mut be)
        }
    }

    fn engines(n: usize, seed: u64) -> Vec<NativeReplica> {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = Arc::new(WeightStore::random("t", cfg, seed));
        (0..n)
            .map(|_| NativeReplica { store: Arc::clone(&store), slots: 2 })
            .collect()
    }

    #[test]
    fn fault_plan_parses_every_kind() {
        let plan =
            FaultPlan::parse("kill:1@8, stall:0@3:50; admit:0").unwrap();
        assert_eq!(
            plan.faults,
            vec![
                Fault::Kill { worker: 1, step: 8 },
                Fault::Stall { worker: 0, step: 3, ms: 50 },
                Fault::AdmitFail { worker: 0 },
            ]
        );
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("kill:1").is_err());
        assert!(FaultPlan::parse("stall:0@3").is_err());
        assert!(FaultPlan::parse("explode:2@1").is_err());
        assert!(FaultPlan::parse("kill:x@1").is_err());
    }

    #[test]
    fn fault_plan_routes_faults_per_worker() {
        let plan = FaultPlan::none()
            .with(Fault::Kill { worker: 1, step: 4 })
            .with(Fault::AdmitFail { worker: 0 });
        let f0 = plan.for_worker(0);
        assert_eq!(f0.kill_at, None);
        assert_eq!(f0.admit_fails, 1);
        let f1 = plan.for_worker(1);
        assert_eq!(f1.kill_at, Some(4));
        assert_eq!(f1.admit_fails, 0);
    }

    #[test]
    fn two_replicas_serve_and_route_by_prefix_affinity() {
        let cluster = Cluster::spawn(
            engines(2, 51),
            ClusterOptions::default(),
            &FaultPlan::none(),
        );
        // two prompt families, each one affinity block (16 tokens) plus
        // a distinct tail: the first of each family routes least-loaded,
        // the second must follow the recorded chain (affinity hit)
        let family = |base: i32, tail: i32| {
            let mut p: Vec<i32> = (base..base + 16).collect();
            p.push(tail);
            p
        };
        let prompts = [
            family(10, 1),
            family(10, 2),
            family(60, 1),
            family(60, 2),
        ];
        let rxs: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let req =
                    GenRequest::greedy(i as u64 + 1, p.clone(), 3);
                cluster.submit_request(req).0
            })
            .collect();
        for rx in &rxs {
            let o = recv_outcome(rx).unwrap();
            assert_eq!(o.finish, FinishReason::MaxTokens);
            assert_eq!(o.tokens.len(), 3);
        }
        let cm = cluster.shutdown();
        assert_eq!(cm.replicas.len(), 2);
        assert_eq!(cm.replicas_alive(), 2);
        assert_eq!(cm.workers_died, 0);
        assert_eq!(cm.total.total_generated(), 12);
        assert_eq!(cm.total.finish.max_tokens, 4);
        assert_eq!(cm.affinity_hits, 2, "{}", cm.summary());
        assert_eq!(
            cm.replicas.iter().map(|r| r.served).sum::<usize>(),
            4
        );
    }

    #[test]
    fn load_shed_fast_rejects_low_priority() {
        let opts = ClusterOptions {
            shed_watermark: 0, // shed everything below the cutoff
            shed_below_priority: 1,
            ..ClusterOptions::default()
        };
        let cluster =
            Cluster::spawn(engines(1, 52), opts, &FaultPlan::none());
        let low = GenRequest::greedy(1, vec![1, 2], 4).with_priority(0);
        let (rx_low, _) = cluster.submit_request(low);
        let o = recv_outcome(&rx_low).unwrap();
        assert_eq!(o.finish, FinishReason::Rejected);
        assert!(o.tokens.is_empty());
        // default priority rides above the cutoff and still serves
        let (rx_hi, _) =
            cluster.submit_request(GenRequest::greedy(2, vec![3, 4], 4));
        assert_eq!(
            recv_outcome(&rx_hi).unwrap().finish,
            FinishReason::MaxTokens
        );
        let cm = cluster.shutdown();
        assert_eq!(cm.shed, 1);
        assert_eq!(cm.total.finish.max_tokens, 1);
    }
}
