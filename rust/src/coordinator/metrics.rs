//! Serving metrics: per-request latency breakdown and aggregate
//! throughput / weight-traffic numbers (Table 6 columns), per-finish-
//! reason request counts (plus cancelled-token waste), and paged-KV
//! counters (block-pool occupancy, prefix-hit rate, preemptions) when
//! the backend pages its cache.

use std::time::{Duration, Instant};

use super::serve::FinishReason;
use crate::kv::KvPoolStats;

/// How many requests ended for each [`FinishReason`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FinishCounts {
    pub max_tokens: usize,
    pub stop_token: usize,
    pub stop_seq: usize,
    pub cancelled: usize,
    pub rejected: usize,
}

impl FinishCounts {
    pub fn bump(&mut self, why: FinishReason) {
        match why {
            FinishReason::MaxTokens => self.max_tokens += 1,
            FinishReason::StopToken => self.stop_token += 1,
            FinishReason::StopSeq => self.stop_seq += 1,
            FinishReason::Cancelled => self.cancelled += 1,
            FinishReason::Rejected => self.rejected += 1,
        }
    }

    pub fn merge(&mut self, other: &FinishCounts) {
        self.max_tokens += other.max_tokens;
        self.stop_token += other.stop_token;
        self.stop_seq += other.stop_seq;
        self.cancelled += other.cancelled;
        self.rejected += other.rejected;
    }

    pub fn total(&self) -> usize {
        self.max_tokens
            + self.stop_token
            + self.stop_seq
            + self.cancelled
            + self.rejected
    }
}

#[derive(Debug, Clone)]
pub struct RequestMetrics {
    pub id: u64,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub enqueued: Instant,
    pub first_token: Option<Instant>,
    pub finished: Option<Instant>,
}

impl RequestMetrics {
    pub fn ttft(&self) -> Option<Duration> {
        self.first_token.map(|t| t - self.enqueued)
    }

    pub fn total(&self) -> Option<Duration> {
        self.finished.map(|t| t - self.enqueued)
    }
}

/// `q`-th percentile (0..=1) by nearest-rank (`ceil(q*n)`-th order
/// statistic) over an unsorted sample — never below the true quantile,
/// so tail numbers are not flattered.
fn percentile_ms(mut vals: Vec<f64>, q: f64) -> f64 {
    if vals.is_empty() {
        return f64::NAN;
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (vals.len() as f64 * q).ceil() as usize;
    vals[rank.clamp(1, vals.len()) - 1]
}

#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub requests: Vec<RequestMetrics>,
    pub decode_steps: usize,
    /// prompt positions fed through steps (prefill work; decode
    /// positions are not counted). Divided by steps this shows how many
    /// prompt tokens ride along per weight-stream — the chunked-prefill
    /// win.
    pub prompt_positions: usize,
    pub wall_s: f64,
    /// weight bytes streamed per decode step (the memory-bound quantity
    /// the paper's LUT kernels optimize)
    pub weight_bytes_per_step: usize,
    /// KV-cache bytes touched per step
    pub kv_bytes_per_step: usize,
    /// requests preempted and requeued by the scheduler (paged backends)
    pub preemptions: usize,
    /// how each request's lifecycle ended (stop conditions, budget,
    /// cancellation, rejection)
    pub finish: FinishCounts,
    /// tokens generated for requests that were then cancelled — the
    /// decode work wasted on outputs nobody consumed
    pub cancelled_tokens: usize,
    /// maximum simultaneously-decoding requests observed
    pub peak_concurrency: usize,
    /// block-pool counters (None for contiguous-cache backends)
    pub kv: Option<KvPoolStats>,
}

impl ServeMetrics {
    pub fn total_generated(&self) -> usize {
        self.requests.iter().map(|r| r.generated_tokens).sum()
    }

    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total_generated() as f64 / self.wall_s
        } else {
            0.0
        }
    }

    fn ttfts_ms(&self) -> Vec<f64> {
        self.requests
            .iter()
            .filter_map(|r| r.ttft())
            .map(|d| d.as_secs_f64() * 1e3)
            .collect()
    }

    pub fn mean_ttft_ms(&self) -> f64 {
        let vals = self.ttfts_ms();
        if vals.is_empty() {
            f64::NAN
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }

    /// Median time-to-first-token across requests.
    pub fn ttft_p50_ms(&self) -> f64 {
        percentile_ms(self.ttfts_ms(), 0.50)
    }

    /// Tail time-to-first-token across requests.
    pub fn ttft_p95_ms(&self) -> f64 {
        percentile_ms(self.ttfts_ms(), 0.95)
    }

    pub fn p95_latency_ms(&self) -> f64 {
        percentile_ms(
            self.requests
                .iter()
                .filter_map(|r| r.total())
                .map(|d| d.as_secs_f64() * 1e3)
                .collect(),
            0.95,
        )
    }

    /// Average prompt positions advanced per step (1.0 with per-token
    /// prefill; larger when chunks amortize the weight stream).
    pub fn prompt_positions_per_step(&self) -> f64 {
        if self.decode_steps > 0 {
            self.prompt_positions as f64 / self.decode_steps as f64
        } else {
            0.0
        }
    }

    /// Total weight traffic over the run (bytes) — scales with steps.
    pub fn total_weight_bytes(&self) -> usize {
        self.weight_bytes_per_step * self.decode_steps
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} reqs, {} tokens in {:.2}s ({:.1} tok/s), ttft p50 {:.1}ms p95 {:.1}ms, e2e p95 {:.1}ms, {:.1} prompt-pos/step, {:.1} MiB weights/step",
            self.requests.len(),
            self.total_generated(),
            self.wall_s,
            self.tokens_per_s(),
            self.ttft_p50_ms(),
            self.ttft_p95_ms(),
            self.p95_latency_ms(),
            self.prompt_positions_per_step(),
            self.weight_bytes_per_step as f64 / (1 << 20) as f64,
        );
        if let Some(kv) = &self.kv {
            s.push_str(&format!(
                ", kv pool {}/{} blocks (peak {:.0}%), prefix hit {:.0}%, {} preempt, {} evict",
                kv.blocks_in_use,
                kv.blocks_total,
                100.0 * kv.peak_occupancy(),
                100.0 * kv.prefix_hit_rate(),
                self.preemptions,
                kv.evictions,
            ));
        }
        let f = &self.finish;
        for (n, tag) in [
            (f.stop_token, "stop-token"),
            (f.stop_seq, "stop-seq"),
            (f.cancelled, "cancelled"),
            (f.rejected, "rejected"),
        ] {
            if n > 0 {
                s.push_str(&format!(", {} {}", n, tag));
            }
        }
        if self.cancelled_tokens > 0 {
            s.push_str(&format!(
                " ({} tokens wasted)",
                self.cancelled_tokens
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_aggregate() {
        let t0 = Instant::now();
        let m = ServeMetrics {
            requests: vec![
                RequestMetrics {
                    id: 1,
                    prompt_tokens: 4,
                    generated_tokens: 10,
                    enqueued: t0,
                    first_token: Some(t0 + Duration::from_millis(5)),
                    finished: Some(t0 + Duration::from_millis(50)),
                },
                RequestMetrics {
                    id: 2,
                    prompt_tokens: 4,
                    generated_tokens: 20,
                    enqueued: t0,
                    first_token: Some(t0 + Duration::from_millis(9)),
                    finished: Some(t0 + Duration::from_millis(80)),
                },
            ],
            decode_steps: 30,
            wall_s: 0.1,
            weight_bytes_per_step: 1000,
            kv_bytes_per_step: 10,
            ..Default::default()
        };
        assert_eq!(m.total_generated(), 30);
        assert!((m.tokens_per_s() - 300.0).abs() < 1e-9);
        assert!((m.mean_ttft_ms() - 7.0).abs() < 1e-9);
        // nearest-rank percentiles over {5, 9}: p50 = ceil(1.0)th = 5,
        // p95 = ceil(1.9)th = 9 (the tail is never flattered)
        assert!((m.ttft_p50_ms() - 5.0).abs() < 1e-9);
        assert!((m.ttft_p95_ms() - 9.0).abs() < 1e-9);
        assert_eq!(m.total_weight_bytes(), 30_000);
        assert!(m.summary().contains("2 reqs"));
        assert!(m.summary().contains("ttft p50"), "{}", m.summary());
    }

    #[test]
    fn prompt_positions_per_step_surfaces() {
        let m = ServeMetrics {
            decode_steps: 10,
            prompt_positions: 64,
            ..Default::default()
        };
        assert!((m.prompt_positions_per_step() - 6.4).abs() < 1e-9);
        assert!(m.summary().contains("prompt-pos/step"), "{}", m.summary());
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = ServeMetrics::default();
        assert_eq!(m.tokens_per_s(), 0.0);
        assert!(m.mean_ttft_ms().is_nan());
        assert!(m.p95_latency_ms().is_nan());
        assert!(m.kv.is_none());
        assert!(!m.summary().contains("kv pool"));
    }

    #[test]
    fn finish_counts_aggregate_and_surface() {
        let mut f = FinishCounts::default();
        f.bump(FinishReason::MaxTokens);
        f.bump(FinishReason::StopSeq);
        f.bump(FinishReason::Cancelled);
        f.bump(FinishReason::Cancelled);
        let mut g = FinishCounts::default();
        g.bump(FinishReason::Rejected);
        f.merge(&g);
        assert_eq!(f.total(), 5);
        assert_eq!(f.cancelled, 2);
        let m = ServeMetrics {
            finish: f,
            cancelled_tokens: 17,
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("2 cancelled"), "{}", s);
        assert!(s.contains("1 rejected"), "{}", s);
        assert!(s.contains("1 stop-seq"), "{}", s);
        assert!(s.contains("17 tokens wasted"), "{}", s);
        // max_tokens is the normal case and stays out of the summary
        assert!(!s.contains("max"), "{}", s);
    }

    #[test]
    fn kv_pool_counters_surface_in_summary() {
        let m = ServeMetrics {
            preemptions: 3,
            kv: Some(KvPoolStats {
                blocks_total: 16,
                blocks_in_use: 4,
                peak_blocks_in_use: 12,
                prefix_lookup_tokens: 100,
                prefix_hit_tokens: 25,
                evictions: 2,
                ..Default::default()
            }),
            ..Default::default()
        };
        let kv = m.kv.as_ref().unwrap();
        assert!((kv.peak_occupancy() - 0.75).abs() < 1e-9);
        assert!((kv.prefix_hit_rate() - 0.25).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("kv pool 4/16"), "{}", s);
        assert!(s.contains("prefix hit 25%"), "{}", s);
        assert!(s.contains("3 preempt"), "{}", s);
    }
}
