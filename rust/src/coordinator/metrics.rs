//! Serving metrics: per-request latency breakdown and aggregate
//! throughput / weight-traffic numbers (Table 6 columns), per-finish-
//! reason request counts (plus cancelled-token waste), paged-KV
//! counters (block-pool occupancy, prefix-hit rate, preemptions), and
//! per-step latency / KV-occupancy histograms on the shared
//! [`crate::obs::hist`] core.
//!
//! Request timestamps are stored as **milliseconds relative to the
//! serve epoch** (the instant the serve round started), not as
//! [`std::time::Instant`]s — offsets serialize cleanly into the
//! machine-readable [`ServeMetrics::snapshot`]. A request submitted to
//! a [`super::server::ServerHandle`] before the round starts gets a
//! negative `enqueued_ms`; all derived durations (TTFT, queue delay,
//! end-to-end) remain correct differences.

use std::collections::BTreeMap;
use std::time::Instant;

use super::serve::FinishReason;
use crate::kv::KvPoolStats;
use crate::obs::hist::{fnum, percentile_exact, Histogram};
use crate::util::json::{self, Json};

/// Signed milliseconds from `epoch` to `t` (negative when `t` precedes
/// the epoch — e.g. a request enqueued before the serve round began).
pub fn rel_ms(epoch: Instant, t: Instant) -> f64 {
    match t.checked_duration_since(epoch) {
        Some(d) => d.as_secs_f64() * 1e3,
        None => -(epoch.duration_since(t).as_secs_f64() * 1e3),
    }
}

/// How many requests ended for each [`FinishReason`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FinishCounts {
    pub max_tokens: usize,
    pub stop_token: usize,
    pub stop_seq: usize,
    pub cancelled: usize,
    pub rejected: usize,
    pub deadline: usize,
}

impl FinishCounts {
    pub fn bump(&mut self, why: FinishReason) {
        match why {
            FinishReason::MaxTokens => self.max_tokens += 1,
            FinishReason::StopToken => self.stop_token += 1,
            FinishReason::StopSeq => self.stop_seq += 1,
            FinishReason::Cancelled => self.cancelled += 1,
            FinishReason::Rejected => self.rejected += 1,
            FinishReason::DeadlineExceeded => self.deadline += 1,
        }
    }

    pub fn merge(&mut self, other: &FinishCounts) {
        self.max_tokens += other.max_tokens;
        self.stop_token += other.stop_token;
        self.stop_seq += other.stop_seq;
        self.cancelled += other.cancelled;
        self.rejected += other.rejected;
        self.deadline += other.deadline;
    }

    pub fn total(&self) -> usize {
        self.max_tokens
            + self.stop_token
            + self.stop_seq
            + self.cancelled
            + self.rejected
            + self.deadline
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("max_tokens", json::num(self.max_tokens as f64)),
            ("stop_token", json::num(self.stop_token as f64)),
            ("stop_seq", json::num(self.stop_seq as f64)),
            ("cancelled", json::num(self.cancelled as f64)),
            ("rejected", json::num(self.rejected as f64)),
            ("deadline", json::num(self.deadline as f64)),
        ])
    }
}

/// One request's timeline, in milliseconds relative to the serve epoch:
/// `enqueued → admitted (first scheduled) → first_token → finished`.
#[derive(Debug, Clone, Default)]
pub struct RequestMetrics {
    pub id: u64,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub enqueued_ms: f64,
    /// first scheduled onto a backend slot (None if rejected in queue)
    pub admitted_ms: Option<f64>,
    pub first_token_ms: Option<f64>,
    pub finished_ms: Option<f64>,
}

impl RequestMetrics {
    /// Time-to-first-token: enqueue → first streamed token.
    pub fn ttft_ms(&self) -> Option<f64> {
        self.first_token_ms.map(|t| t - self.enqueued_ms)
    }

    /// Time spent queued before first being scheduled.
    pub fn queue_delay_ms(&self) -> Option<f64> {
        self.admitted_ms.map(|t| t - self.enqueued_ms)
    }

    /// Admission → first token: the prefill (+ any preemption) part of
    /// TTFT, i.e. `ttft = queue_delay + prefill`.
    pub fn prefill_ms(&self) -> Option<f64> {
        match (self.admitted_ms, self.first_token_ms) {
            (Some(a), Some(f)) => Some(f - a),
            _ => None,
        }
    }

    /// Time-per-output-token after the first: steady-state decode
    /// cadence. None until a second token exists.
    pub fn tpot_ms(&self) -> Option<f64> {
        match (self.first_token_ms, self.finished_ms) {
            (Some(f), Some(e)) if self.generated_tokens >= 2 => {
                Some((e - f) / (self.generated_tokens - 1) as f64)
            }
            _ => None,
        }
    }

    /// End-to-end: enqueue → finished.
    pub fn e2e_ms(&self) -> Option<f64> {
        self.finished_ms.map(|t| t - self.enqueued_ms)
    }

    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| match v {
            Some(x) => fnum(x),
            None => Json::Null,
        };
        json::obj(vec![
            ("id", json::num(self.id as f64)),
            ("prompt_tokens", json::num(self.prompt_tokens as f64)),
            (
                "generated_tokens",
                json::num(self.generated_tokens as f64),
            ),
            ("enqueued_ms", fnum(self.enqueued_ms)),
            ("admitted_ms", opt(self.admitted_ms)),
            ("first_token_ms", opt(self.first_token_ms)),
            ("finished_ms", opt(self.finished_ms)),
            ("ttft_ms", opt(self.ttft_ms())),
            ("queue_delay_ms", opt(self.queue_delay_ms())),
            ("tpot_ms", opt(self.tpot_ms())),
            ("e2e_ms", opt(self.e2e_ms())),
        ])
    }
}

#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub requests: Vec<RequestMetrics>,
    pub decode_steps: usize,
    /// prompt positions fed through steps (prefill work; decode
    /// positions are not counted). Divided by steps this shows how many
    /// prompt tokens ride along per weight-stream — the chunked-prefill
    /// win.
    pub prompt_positions: usize,
    pub wall_s: f64,
    /// weight bytes streamed per decode step (the memory-bound quantity
    /// the paper's LUT kernels optimize)
    pub weight_bytes_per_step: usize,
    /// KV-cache bytes touched per step
    pub kv_bytes_per_step: usize,
    /// requests preempted and requeued by the scheduler (paged backends)
    pub preemptions: usize,
    /// how each request's lifecycle ended (stop conditions, budget,
    /// cancellation, rejection)
    pub finish: FinishCounts,
    /// tokens generated for requests that were then cancelled — the
    /// decode work wasted on outputs nobody consumed
    pub cancelled_tokens: usize,
    /// maximum simultaneously-decoding requests observed
    pub peak_concurrency: usize,
    /// precision-policy transitions (the scheduler switching its
    /// admission width under an auto policy — see
    /// [`super::serve::PrecisionPolicy`])
    pub precision_switches: usize,
    /// generated tokens per decode width, for width-pinned admissions
    /// (empty when the round served at the backend's native width)
    pub tokens_by_width: BTreeMap<u8, u64>,
    /// draft tokens proposed by a speculative backend (0 when the round
    /// decoded plainly)
    pub draft_tokens: usize,
    /// draft tokens the verifier accepted (each one is a generated
    /// token that skipped a full-width weight stream)
    pub accepted_tokens: usize,
    /// draft tokens rejected and rolled back (`KvSeq::truncate`d)
    pub rollback_tokens: usize,
    /// speculative draft→verify→accept rounds executed
    pub spec_rounds: usize,
    /// block-pool counters (None for contiguous-cache backends)
    pub kv: Option<KvPoolStats>,
    /// per-step `DecodeBackend::step` dispatch latency (ms)
    pub step_ms: Histogram,
    /// KV-pool occupancy (blocks_in_use / blocks_total, 0..=1) sampled
    /// once per step — occupancy *over time*, not just the final state
    pub kv_occupancy: Histogram,
}

impl ServeMetrics {
    pub fn total_generated(&self) -> usize {
        self.requests.iter().map(|r| r.generated_tokens).sum()
    }

    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total_generated() as f64 / self.wall_s
        } else {
            0.0
        }
    }

    fn ttfts_ms(&self) -> Vec<f64> {
        self.requests.iter().filter_map(|r| r.ttft_ms()).collect()
    }

    fn tpots_ms(&self) -> Vec<f64> {
        self.requests.iter().filter_map(|r| r.tpot_ms()).collect()
    }

    fn queue_delays_ms(&self) -> Vec<f64> {
        self.requests
            .iter()
            .filter_map(|r| r.queue_delay_ms())
            .collect()
    }

    pub fn mean_ttft_ms(&self) -> f64 {
        let vals = self.ttfts_ms();
        if vals.is_empty() {
            f64::NAN
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }

    /// Median time-to-first-token across requests.
    pub fn ttft_p50_ms(&self) -> f64 {
        percentile_exact(&self.ttfts_ms(), 0.50)
    }

    /// Tail time-to-first-token across requests.
    pub fn ttft_p95_ms(&self) -> f64 {
        percentile_exact(&self.ttfts_ms(), 0.95)
    }

    pub fn ttft_p99_ms(&self) -> f64 {
        percentile_exact(&self.ttfts_ms(), 0.99)
    }

    /// Median steady-state time-per-output-token.
    pub fn tpot_p50_ms(&self) -> f64 {
        percentile_exact(&self.tpots_ms(), 0.50)
    }

    pub fn tpot_p99_ms(&self) -> f64 {
        percentile_exact(&self.tpots_ms(), 0.99)
    }

    /// Median time spent queued before first being scheduled.
    pub fn queue_delay_p50_ms(&self) -> f64 {
        percentile_exact(&self.queue_delays_ms(), 0.50)
    }

    pub fn queue_delay_p99_ms(&self) -> f64 {
        percentile_exact(&self.queue_delays_ms(), 0.99)
    }

    pub fn p95_latency_ms(&self) -> f64 {
        let e2e: Vec<f64> =
            self.requests.iter().filter_map(|r| r.e2e_ms()).collect();
        percentile_exact(&e2e, 0.95)
    }

    /// Average prompt positions advanced per step (1.0 with per-token
    /// prefill; larger when chunks amortize the weight stream).
    pub fn prompt_positions_per_step(&self) -> f64 {
        if self.decode_steps > 0 {
            self.prompt_positions as f64 / self.decode_steps as f64
        } else {
            0.0
        }
    }

    /// Total weight traffic over the run (bytes) — scales with steps.
    pub fn total_weight_bytes(&self) -> usize {
        self.weight_bytes_per_step * self.decode_steps
    }

    /// Fraction of drafted tokens the verifier accepted (NaN when the
    /// run never speculated).
    pub fn acceptance_rate(&self) -> f64 {
        if self.draft_tokens > 0 {
            self.accepted_tokens as f64 / self.draft_tokens as f64
        } else {
            f64::NAN
        }
    }

    /// Fold one serve round into a running total (the
    /// [`super::server::ServerHandle`] engine thread aggregates windows
    /// this way). Counters add, histograms merge bucket-wise, rates
    /// (`*_per_step`) and pool stats take the latest round's value.
    pub fn merge_round(&mut self, m: ServeMetrics) {
        self.requests.extend(m.requests);
        self.decode_steps += m.decode_steps;
        self.prompt_positions += m.prompt_positions;
        self.wall_s += m.wall_s;
        self.weight_bytes_per_step = m.weight_bytes_per_step;
        self.kv_bytes_per_step = m.kv_bytes_per_step;
        self.preemptions += m.preemptions;
        self.finish.merge(&m.finish);
        self.cancelled_tokens += m.cancelled_tokens;
        self.peak_concurrency = self.peak_concurrency.max(m.peak_concurrency);
        self.precision_switches += m.precision_switches;
        for (w, n) in m.tokens_by_width {
            *self.tokens_by_width.entry(w).or_insert(0) += n;
        }
        self.draft_tokens += m.draft_tokens;
        self.accepted_tokens += m.accepted_tokens;
        self.rollback_tokens += m.rollback_tokens;
        self.spec_rounds += m.spec_rounds;
        if m.kv.is_some() {
            self.kv = m.kv;
        }
        self.step_ms.merge(&m.step_ms);
        self.kv_occupancy.merge(&m.kv_occupancy);
    }

    /// Machine-readable snapshot: aggregates, tail latencies, finish
    /// tallies, KV-pool counters, per-step histograms, and every
    /// request's timeline. Written by `serve --metrics-out` and
    /// consumed by the traffic harness.
    pub fn snapshot(&self) -> Json {
        let requests: Vec<Json> =
            self.requests.iter().map(|r| r.to_json()).collect();
        let kv = match &self.kv {
            Some(kv) => json::obj(vec![
                ("blocks_total", json::num(kv.blocks_total as f64)),
                ("blocks_in_use", json::num(kv.blocks_in_use as f64)),
                (
                    "peak_blocks_in_use",
                    json::num(kv.peak_blocks_in_use as f64),
                ),
                ("cached_blocks", json::num(kv.cached_blocks as f64)),
                ("peak_occupancy", fnum(kv.peak_occupancy())),
                ("prefix_hit_rate", fnum(kv.prefix_hit_rate())),
                ("preemptions", json::num(kv.preemptions as f64)),
                ("cow_copies", json::num(kv.cow_copies as f64)),
                ("evictions", json::num(kv.evictions as f64)),
            ]),
            None => Json::Null,
        };
        json::obj(vec![
            ("requests_total", json::num(self.requests.len() as f64)),
            ("generated_tokens", json::num(self.total_generated() as f64)),
            ("decode_steps", json::num(self.decode_steps as f64)),
            (
                "prompt_positions",
                json::num(self.prompt_positions as f64),
            ),
            ("wall_s", fnum(self.wall_s)),
            ("tokens_per_s", fnum(self.tokens_per_s())),
            ("mean_ttft_ms", fnum(self.mean_ttft_ms())),
            ("ttft_p50_ms", fnum(self.ttft_p50_ms())),
            ("ttft_p95_ms", fnum(self.ttft_p95_ms())),
            ("ttft_p99_ms", fnum(self.ttft_p99_ms())),
            ("tpot_p50_ms", fnum(self.tpot_p50_ms())),
            ("tpot_p99_ms", fnum(self.tpot_p99_ms())),
            ("queue_delay_p50_ms", fnum(self.queue_delay_p50_ms())),
            ("queue_delay_p99_ms", fnum(self.queue_delay_p99_ms())),
            ("e2e_p95_ms", fnum(self.p95_latency_ms())),
            (
                "prompt_positions_per_step",
                fnum(self.prompt_positions_per_step()),
            ),
            (
                "weight_bytes_per_step",
                json::num(self.weight_bytes_per_step as f64),
            ),
            (
                "kv_bytes_per_step",
                json::num(self.kv_bytes_per_step as f64),
            ),
            ("preemptions", json::num(self.preemptions as f64)),
            (
                "cancelled_tokens",
                json::num(self.cancelled_tokens as f64),
            ),
            (
                "peak_concurrency",
                json::num(self.peak_concurrency as f64),
            ),
            (
                "precision_switches",
                json::num(self.precision_switches as f64),
            ),
            ("draft_tokens", json::num(self.draft_tokens as f64)),
            ("accepted_tokens", json::num(self.accepted_tokens as f64)),
            ("rollback_tokens", json::num(self.rollback_tokens as f64)),
            ("acceptance_rate", fnum(self.acceptance_rate())),
            ("spec_rounds", json::num(self.spec_rounds as f64)),
            (
                "tokens_by_width",
                Json::Obj(
                    self.tokens_by_width
                        .iter()
                        .map(|(w, n)| {
                            (format!("w{}", w), json::num(*n as f64))
                        })
                        .collect(),
                ),
            ),
            ("finish", self.finish.to_json()),
            ("kv_pool", kv),
            ("step_ms", self.step_ms.to_json()),
            ("kv_occupancy", self.kv_occupancy.to_json()),
            ("requests", Json::Arr(requests)),
        ])
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} reqs, {} tokens in {:.2}s ({:.1} tok/s), ttft p50 {:.1}ms p95 {:.1}ms, e2e p95 {:.1}ms, {:.1} prompt-pos/step, {:.1} MiB weights/step",
            self.requests.len(),
            self.total_generated(),
            self.wall_s,
            self.tokens_per_s(),
            self.ttft_p50_ms(),
            self.ttft_p95_ms(),
            self.p95_latency_ms(),
            self.prompt_positions_per_step(),
            self.weight_bytes_per_step as f64 / (1 << 20) as f64,
        );
        let tpot = self.tpot_p50_ms();
        if tpot.is_finite() {
            s.push_str(&format!(
                ", tpot p50 {:.1}ms p99 {:.1}ms",
                tpot,
                self.tpot_p99_ms()
            ));
        }
        let qd = self.queue_delay_p50_ms();
        if qd.is_finite() {
            s.push_str(&format!(
                ", queue p50 {:.1}ms p99 {:.1}ms",
                qd,
                self.queue_delay_p99_ms()
            ));
        }
        if !self.step_ms.is_empty() {
            s.push_str(&format!(
                ", step p50 {:.1}ms p99 {:.1}ms",
                self.step_ms.quantile(0.50),
                self.step_ms.quantile(0.99)
            ));
        }
        if let Some(kv) = &self.kv {
            s.push_str(&format!(
                ", kv pool {}/{} blocks (peak {:.0}%), prefix hit {:.0}%, {} preempt, {} evict",
                kv.blocks_in_use,
                kv.blocks_total,
                100.0 * kv.peak_occupancy(),
                100.0 * kv.prefix_hit_rate(),
                self.preemptions,
                kv.evictions,
            ));
        }
        if !self.tokens_by_width.is_empty() {
            let per: Vec<String> = self
                .tokens_by_width
                .iter()
                .map(|(w, n)| format!("{}tok@{}b", n, w))
                .collect();
            s.push_str(&format!(
                ", precision {} switches ({})",
                self.precision_switches,
                per.join(" ")
            ));
        }
        if self.spec_rounds > 0 {
            s.push_str(&format!(
                ", spec {} rounds ({} drafted, {} accepted = {:.0}%, {} rolled back)",
                self.spec_rounds,
                self.draft_tokens,
                self.accepted_tokens,
                100.0 * self.acceptance_rate(),
                self.rollback_tokens,
            ));
        }
        let f = &self.finish;
        for (n, tag) in [
            (f.stop_token, "stop-token"),
            (f.stop_seq, "stop-seq"),
            (f.cancelled, "cancelled"),
            (f.rejected, "rejected"),
            (f.deadline, "deadline"),
        ] {
            if n > 0 {
                s.push_str(&format!(", {} {}", n, tag));
            }
        }
        if self.cancelled_tokens > 0 {
            s.push_str(&format!(
                " ({} tokens wasted)",
                self.cancelled_tokens
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(
        id: u64,
        gen: usize,
        enq: f64,
        adm: f64,
        first: f64,
        fin: f64,
    ) -> RequestMetrics {
        RequestMetrics {
            id,
            prompt_tokens: 4,
            generated_tokens: gen,
            enqueued_ms: enq,
            admitted_ms: Some(adm),
            first_token_ms: Some(first),
            finished_ms: Some(fin),
        }
    }

    #[test]
    fn metrics_aggregate() {
        let m = ServeMetrics {
            requests: vec![
                req(1, 10, 0.0, 2.0, 5.0, 50.0),
                req(2, 20, 0.0, 3.0, 9.0, 80.0),
            ],
            decode_steps: 30,
            wall_s: 0.1,
            weight_bytes_per_step: 1000,
            kv_bytes_per_step: 10,
            ..Default::default()
        };
        assert_eq!(m.total_generated(), 30);
        assert!((m.tokens_per_s() - 300.0).abs() < 1e-9);
        assert!((m.mean_ttft_ms() - 7.0).abs() < 1e-9);
        // nearest-rank percentiles over {5, 9}: p50 = ceil(1.0)th = 5,
        // p95 = ceil(1.9)th = 9 (the tail is never flattered)
        assert!((m.ttft_p50_ms() - 5.0).abs() < 1e-9);
        assert!((m.ttft_p95_ms() - 9.0).abs() < 1e-9);
        assert!((m.ttft_p99_ms() - 9.0).abs() < 1e-9);
        assert_eq!(m.total_weight_bytes(), 30_000);
        assert!(m.summary().contains("2 reqs"));
        assert!(m.summary().contains("ttft p50"), "{}", m.summary());
    }

    #[test]
    fn request_timeline_decomposes() {
        let r = req(1, 11, 10.0, 14.0, 30.0, 130.0);
        assert_eq!(r.ttft_ms(), Some(20.0));
        assert_eq!(r.queue_delay_ms(), Some(4.0));
        assert_eq!(r.prefill_ms(), Some(16.0));
        // ttft = queue_delay + prefill
        assert_eq!(
            r.ttft_ms().unwrap(),
            r.queue_delay_ms().unwrap() + r.prefill_ms().unwrap()
        );
        assert_eq!(r.e2e_ms(), Some(120.0));
        // 10 inter-token gaps over 100ms
        assert!((r.tpot_ms().unwrap() - 10.0).abs() < 1e-9);
        // single-token request has no steady-state cadence
        let single = req(2, 1, 0.0, 1.0, 2.0, 2.0);
        assert!(single.tpot_ms().is_none());
    }

    #[test]
    fn negative_enqueue_offset_keeps_durations() {
        // submitted before the serve epoch: offset is negative, but the
        // duration views stay correct
        let r = req(1, 5, -8.0, 1.0, 2.0, 42.0);
        assert_eq!(r.ttft_ms(), Some(10.0));
        assert_eq!(r.queue_delay_ms(), Some(9.0));
        assert_eq!(r.e2e_ms(), Some(50.0));
    }

    #[test]
    fn rel_ms_is_signed() {
        let t0 = Instant::now();
        let t1 = t0 + std::time::Duration::from_millis(25);
        assert!((rel_ms(t0, t1) - 25.0).abs() < 1.0);
        assert!((rel_ms(t1, t0) + 25.0).abs() < 1.0);
        assert_eq!(rel_ms(t0, t0), 0.0);
    }

    #[test]
    fn prompt_positions_per_step_surfaces() {
        let m = ServeMetrics {
            decode_steps: 10,
            prompt_positions: 64,
            ..Default::default()
        };
        assert!((m.prompt_positions_per_step() - 6.4).abs() < 1e-9);
        assert!(m.summary().contains("prompt-pos/step"), "{}", m.summary());
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = ServeMetrics::default();
        assert_eq!(m.tokens_per_s(), 0.0);
        assert!(m.mean_ttft_ms().is_nan());
        assert!(m.p95_latency_ms().is_nan());
        assert!(m.tpot_p50_ms().is_nan());
        assert!(m.queue_delay_p99_ms().is_nan());
        assert!(m.kv.is_none());
        assert!(!m.summary().contains("kv pool"));
        assert!(!m.summary().contains("tpot"));
        // an empty snapshot still parses, with nulls where no sample
        let js = m.snapshot();
        let parsed = Json::parse(&js.to_string_pretty()).expect("parses");
        assert_eq!(parsed.get("ttft_p50_ms"), Some(&Json::Null));
        assert_eq!(
            parsed.get("requests_total").and_then(|v| v.as_f64()),
            Some(0.0)
        );
    }

    #[test]
    fn finish_counts_aggregate_and_surface() {
        let mut f = FinishCounts::default();
        f.bump(FinishReason::MaxTokens);
        f.bump(FinishReason::StopSeq);
        f.bump(FinishReason::Cancelled);
        f.bump(FinishReason::Cancelled);
        let mut g = FinishCounts::default();
        g.bump(FinishReason::Rejected);
        f.merge(&g);
        assert_eq!(f.total(), 5);
        assert_eq!(f.cancelled, 2);
        let m = ServeMetrics {
            finish: f,
            cancelled_tokens: 17,
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("2 cancelled"), "{}", s);
        assert!(s.contains("1 rejected"), "{}", s);
        assert!(s.contains("1 stop-seq"), "{}", s);
        assert!(s.contains("17 tokens wasted"), "{}", s);
        // max_tokens is the normal case and stays out of the summary
        assert!(!s.contains("max"), "{}", s);
    }

    #[test]
    fn spec_counters_surface_and_merge() {
        let mut a = ServeMetrics {
            draft_tokens: 10,
            accepted_tokens: 7,
            rollback_tokens: 3,
            spec_rounds: 4,
            ..Default::default()
        };
        assert!((a.acceptance_rate() - 0.7).abs() < 1e-12);
        let b = ServeMetrics {
            draft_tokens: 10,
            accepted_tokens: 3,
            rollback_tokens: 7,
            spec_rounds: 2,
            ..Default::default()
        };
        a.merge_round(b);
        assert_eq!(a.draft_tokens, 20);
        assert_eq!(a.accepted_tokens, 10);
        assert_eq!(a.rollback_tokens, 10);
        assert_eq!(a.spec_rounds, 6);
        assert!((a.acceptance_rate() - 0.5).abs() < 1e-12);
        let s = a.summary();
        assert!(s.contains("spec 6 rounds"), "{}", s);
        assert!(s.contains("50%"), "{}", s);
        let parsed = Json::parse(&a.snapshot().to_string_pretty())
            .expect("parses");
        assert_eq!(
            parsed.get("draft_tokens").and_then(|v| v.as_f64()),
            Some(20.0)
        );
        assert_eq!(
            parsed.get("acceptance_rate").and_then(|v| v.as_f64()),
            Some(0.5)
        );
        assert_eq!(
            parsed.get("spec_rounds").and_then(|v| v.as_f64()),
            Some(6.0)
        );
        // a plain run keeps NaN out of the json and spec off the summary
        let plain = ServeMetrics::default();
        assert!(plain.acceptance_rate().is_nan());
        assert!(!plain.summary().contains("spec"));
        let pj = Json::parse(&plain.snapshot().to_string_pretty())
            .expect("parses");
        assert_eq!(pj.get("acceptance_rate"), Some(&Json::Null));
    }

    #[test]
    fn merge_round_rolls_up_windows() {
        let mut round1 = ServeMetrics {
            requests: vec![req(1, 10, 0.0, 1.0, 5.0, 50.0)],
            decode_steps: 10,
            prompt_positions: 40,
            wall_s: 0.5,
            weight_bytes_per_step: 500,
            preemptions: 1,
            cancelled_tokens: 3,
            peak_concurrency: 2,
            ..Default::default()
        };
        round1.finish.bump(FinishReason::MaxTokens);
        round1.step_ms.record(2.0);
        round1.kv_occupancy.record(0.25);

        let mut round2 = ServeMetrics {
            requests: vec![req(2, 20, 0.0, 2.0, 9.0, 80.0)],
            decode_steps: 20,
            prompt_positions: 20,
            wall_s: 0.5,
            weight_bytes_per_step: 1000,
            preemptions: 2,
            cancelled_tokens: 0,
            peak_concurrency: 4,
            kv: Some(KvPoolStats {
                blocks_total: 16,
                blocks_in_use: 8,
                ..Default::default()
            }),
            ..Default::default()
        };
        round2.finish.bump(FinishReason::Cancelled);
        round2.step_ms.record(4.0);
        round2.step_ms.record(6.0);
        round2.kv_occupancy.record(0.5);
        round1.precision_switches = 1;
        round1.tokens_by_width.insert(3, 6);
        round2.precision_switches = 2;
        round2.tokens_by_width.insert(3, 4);
        round2.tokens_by_width.insert(4, 10);

        let mut total = ServeMetrics::default();
        total.merge_round(round1);
        total.merge_round(round2);
        assert_eq!(total.requests.len(), 2);
        assert_eq!(total.decode_steps, 30);
        assert_eq!(total.prompt_positions, 60);
        assert!((total.wall_s - 1.0).abs() < 1e-12);
        assert_eq!(total.weight_bytes_per_step, 1000); // latest round
        assert_eq!(total.preemptions, 3);
        assert_eq!(total.cancelled_tokens, 3);
        assert_eq!(total.peak_concurrency, 4);
        assert_eq!(total.finish.total(), 2);
        assert_eq!(total.finish.cancelled, 1);
        assert_eq!(total.kv.as_ref().unwrap().blocks_total, 16);
        assert_eq!(total.step_ms.count(), 3);
        assert_eq!(total.kv_occupancy.count(), 2);
        assert_eq!(total.total_generated(), 30);
        assert_eq!(total.precision_switches, 3);
        assert_eq!(total.tokens_by_width.get(&3), Some(&10));
        assert_eq!(total.tokens_by_width.get(&4), Some(&10));
        let s = total.summary();
        assert!(s.contains("precision 3 switches"), "{}", s);
        assert!(s.contains("10tok@3b"), "{}", s);
    }

    #[test]
    fn snapshot_parses_with_all_sections() {
        let mut m = ServeMetrics {
            requests: vec![
                req(1, 10, 0.0, 1.0, 5.0, 50.0),
                req(2, 20, 0.0, 2.0, 9.0, 80.0),
            ],
            decode_steps: 30,
            wall_s: 0.1,
            preemptions: 2,
            kv: Some(KvPoolStats {
                blocks_total: 16,
                blocks_in_use: 4,
                peak_blocks_in_use: 12,
                ..Default::default()
            }),
            ..Default::default()
        };
        m.finish.bump(FinishReason::MaxTokens);
        m.step_ms.record(3.0);
        m.kv_occupancy.record(0.75);
        let parsed = Json::parse(&m.snapshot().to_string_pretty())
            .expect("snapshot is valid JSON");
        for key in [
            "tokens_per_s",
            "ttft_p50_ms",
            "ttft_p99_ms",
            "tpot_p50_ms",
            "tpot_p99_ms",
            "queue_delay_p50_ms",
            "queue_delay_p99_ms",
            "preemptions",
            "precision_switches",
            "tokens_by_width",
            "finish",
            "kv_pool",
            "step_ms",
            "kv_occupancy",
        ] {
            assert!(parsed.get(key).is_some(), "missing {}", key);
        }
        assert_eq!(
            parsed.at(&["kv_pool", "blocks_total"]).and_then(|v| v.as_f64()),
            Some(16.0)
        );
        assert_eq!(
            parsed.at(&["step_ms", "count"]).and_then(|v| v.as_f64()),
            Some(1.0)
        );
        let reqs = parsed.get("requests").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(reqs.len(), 2);
        assert_eq!(
            reqs[0].get("ttft_ms").and_then(|v| v.as_f64()),
            Some(5.0)
        );
    }

    #[test]
    fn kv_pool_counters_surface_in_summary() {
        let m = ServeMetrics {
            preemptions: 3,
            kv: Some(KvPoolStats {
                blocks_total: 16,
                blocks_in_use: 4,
                peak_blocks_in_use: 12,
                prefix_lookup_tokens: 100,
                prefix_hit_tokens: 25,
                evictions: 2,
                ..Default::default()
            }),
            ..Default::default()
        };
        let kv = m.kv.as_ref().unwrap();
        assert!((kv.peak_occupancy() - 0.75).abs() < 1e-9);
        assert!((kv.prefix_hit_rate() - 0.25).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("kv pool 4/16"), "{}", s);
        assert!(s.contains("prefix hit 25%"), "{}", s);
        assert!(s.contains("3 preempt"), "{}", s);
    }
}
