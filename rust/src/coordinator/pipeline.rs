//! Layer-wise PTQ pipeline: calibration capture -> per-layer quantization
//! (native GANQ/baselines or the AOT GANQ graph) -> a servable
//! QuantizedModel. This is the offline path of the coordinator; the paper's
//! protocol (32-128 calibration sequences from C4's first shard) maps to
//! c4s calib sequences at our context length.

use std::collections::BTreeMap;

use crate::model::forward::{Engine, Weights};
use crate::model::{LayerWeights, QuantizedModel, WeightStore};
use crate::quant::{self, Quantizer};
use crate::runtime::{ganq_hlo, Runtime};
use crate::tensor::Mat;

/// Per-linear calibration Gram matrices H = X X^T.
pub struct Calibration {
    pub grams: BTreeMap<String, Mat>,
    pub n_tokens: usize,
}

/// Run the FP model over calibration sequences, accumulating per-linear
/// input Grams. `n_seqs` sequences of `seq` tokens (paper: 32-128 x
/// 2048). Capture runs as full-length prefill chunks on one
/// [`Engine`] with the observation hook — the same code path serving
/// and evaluation use.
pub fn calibrate(store: &WeightStore, n_seqs: usize, seq: usize) -> Calibration {
    let seqs = crate::data::calibration_sequences(seq, n_seqs);
    let mut grams: BTreeMap<String, Mat> = BTreeMap::new();
    let mut n_tokens = 0usize;
    let w = Weights::Fp(store);
    let mut engine = Engine::new(&w);
    for chunk in seqs.chunks(4) {
        let tokens: Vec<Vec<i32>> = chunk
            .iter()
            .map(|s| s.iter().map(|&b| b as i32).collect())
            .collect();
        n_tokens += tokens.len() * seq;
        let mut obs = |name: &str, x: &Mat| {
            // x is [p, n]; H += x^T x
            let ht = x.t().matmul(x);
            grams
                .entry(name.to_string())
                .and_modify(|h| h.add_assign(&ht))
                .or_insert(ht);
        };
        engine.prefill_full(&tokens, Some(&mut obs));
    }
    Calibration { grams, n_tokens }
}

/// Which solver runs GANQ layers.
pub enum QuantEngine<'a> {
    /// Native Rust solver (quant::ganq) for everything.
    Native,
    /// Prefer the AOT HLO GANQ graph (L1 Pallas kernel inside); fall back
    /// to native for shapes without artifacts. Baselines always native.
    Hlo(&'a Runtime),
}

/// Quantize every decoder linear of a model with the named method.
pub fn quantize_model(
    store: &WeightStore,
    method: &str,
    bits: u8,
    calib: &Calibration,
    engine: &QuantEngine,
    verbose: bool,
) -> Result<QuantizedModel, String> {
    let q: Box<dyn Quantizer> = quant::by_name(method, bits)
        .ok_or_else(|| format!("unknown method '{}'", method))?;
    let mut linears = BTreeMap::new();
    let mut weight_bits = 0usize;
    for (name, _m, _n) in store.cfg.linear_shapes() {
        let w = store.mat(&name);
        let h = calib
            .grams
            .get(&name)
            .ok_or_else(|| format!("no calibration for {}", name))?;
        let result = match (engine, method) {
            (QuantEngine::Hlo(rt), "ganq") => {
                match ganq_hlo::quantize_layer_hlo(rt, &w, h, bits)? {
                    Some(r) => r,
                    None => q.quantize(&w, h),
                }
            }
            _ => q.quantize(&w, h),
        };
        if verbose {
            let err = result.layer_error(&w, h);
            eprintln!(
                "  [{} {}b] {}: layer err {:.4e}, storage {:.2}% of fp16",
                method,
                bits,
                name,
                err,
                100.0 * result.storage.ratio_vs_fp16(w.rows, w.cols)
            );
        }
        weight_bits += result.storage.total_bits();
        linears.insert(name.clone(), LayerWeights::from_result(&result));
    }
    Ok(QuantizedModel {
        base: store.clone(),
        method: method.to_string(),
        bits,
        linears,
        weight_bits,
    })
}

/// Quantize every decoder linear into the nested any-precision layout:
/// one GANQ solve at the max width per layer, then
/// [`BitPlaneStore::derive`] re-fits a codebook for each narrower width
/// against the same calibration Gram (the seedless upgrade path — no
/// second calibration pass). The resulting model serves every width in
/// `widths` from one resident artifact (`QuantizedModel::anyprec_widths`),
/// and `weight_bits` counts the nested storage: max-width planes once +
/// all per-width codebooks.
pub fn quantize_model_anyprec(
    store: &WeightStore,
    calib: &Calibration,
    widths: &[u8],
    engine: &QuantEngine,
    verbose: bool,
) -> Result<QuantizedModel, String> {
    let mut ws: Vec<u8> = widths.to_vec();
    ws.sort_unstable();
    ws.dedup();
    if ws.is_empty() {
        return Err("anyprec needs at least one width".into());
    }
    if ws[0] < 1 || *ws.last().expect("nonempty") > 8 {
        return Err(format!("unsupported widths {:?}", ws));
    }
    let bits = *ws.last().expect("nonempty");
    let q: Box<dyn Quantizer> =
        quant::by_name("ganq", bits).ok_or("ganq unavailable")?;
    let mut linears = BTreeMap::new();
    let mut weight_bits = 0usize;
    for (name, _m, _n) in store.cfg.linear_shapes() {
        let w = store.mat(&name);
        let h = calib
            .grams
            .get(&name)
            .ok_or_else(|| format!("no calibration for {}", name))?;
        let result = match engine {
            QuantEngine::Hlo(rt) => {
                match ganq_hlo::quantize_layer_hlo(rt, &w, h, bits)? {
                    Some(r) => r,
                    None => q.quantize(&w, h),
                }
            }
            QuantEngine::Native => q.quantize(&w, h),
        };
        let lut = result
            .lut
            .as_ref()
            .ok_or_else(|| format!("{}: ganq produced no LUT layer", name))?;
        let bp = crate::quant::BitPlaneStore::derive(lut, &w, h, &ws);
        if verbose {
            let rep = bp.storage_report();
            eprintln!(
                "  [anyprec {:?}b] {}: nested {} bits vs standalone {} bits",
                ws,
                name,
                rep.nested.total_bits(),
                rep.standalone_total_bits()
            );
        }
        weight_bits += bp.storage().total_bits();
        linears.insert(name.clone(), LayerWeights::AnyPrec(bp));
    }
    Ok(QuantizedModel {
        base: store.clone(),
        method: "ganq-anyprec".to_string(),
        bits,
        linears,
        weight_bits,
    })
}

/// Sequential (error-propagating) variant: decoder blocks are quantized
/// in order, and the calibration Grams for each block are captured by
/// forwarding through the *already-quantized* prefix — so later layers
/// compensate for the quantization error of earlier ones (the "true
/// sequential" mode of GPTQ-style pipelines; an extension beyond the
/// paper's one-shot calibration, ablated in ablation_ganq).
pub fn quantize_model_sequential(
    store: &WeightStore,
    method: &str,
    bits: u8,
    n_seqs: usize,
    seq: usize,
    verbose: bool,
) -> Result<QuantizedModel, String> {
    let q: Box<dyn Quantizer> = quant::by_name(method, bits)
        .ok_or_else(|| format!("unknown method '{}'", method))?;
    let seqs = crate::data::calibration_sequences(seq, n_seqs);
    let tokens: Vec<Vec<Vec<i32>>> = seqs
        .chunks(4)
        .map(|chunk| {
            chunk
                .iter()
                .map(|s| s.iter().map(|&b| b as i32).collect())
                .collect()
        })
        .collect();
    let mut qm = QuantizedModel {
        base: store.clone(),
        method: format!("{}-seq", method),
        bits,
        linears: BTreeMap::new(),
        weight_bits: 0,
    };
    for li in 0..store.cfg.layers {
        let prefix = format!("l{}.", li);
        // capture Grams for this block under the quantized prefix (the
        // engine is rebuilt per block because the weights just changed)
        let mut grams: BTreeMap<String, Mat> = BTreeMap::new();
        {
            let w = Weights::Quant(&qm);
            let mut engine = Engine::new(&w);
            for batch in &tokens {
                let mut obs = |name: &str, x: &Mat| {
                    if name.starts_with(&prefix) {
                        let ht = x.t().matmul(x);
                        grams
                            .entry(name.to_string())
                            .and_modify(|h| h.add_assign(&ht))
                            .or_insert(ht);
                    }
                };
                engine.prefill_full(batch, Some(&mut obs));
            }
        }
        for (name, _m, _n) in store.cfg.linear_shapes() {
            if !name.starts_with(&prefix) {
                continue;
            }
            let w = store.mat(&name);
            let h = grams
                .get(&name)
                .ok_or_else(|| format!("no grams for {}", name))?;
            let result = q.quantize(&w, h);
            if verbose {
                eprintln!(
                    "  [seq {} {}b] {}: err {:.4e}",
                    method,
                    bits,
                    name,
                    result.layer_error(&w, h)
                );
            }
            qm.weight_bits += result.storage.total_bits();
            qm.linears
                .insert(name.clone(), LayerWeights::from_result(&result));
        }
    }
    Ok(qm)
}

/// Sum of layer errors across the model (pipeline-level quality signal).
pub fn total_layer_error(
    store: &WeightStore,
    qm: &QuantizedModel,
    calib: &Calibration,
) -> f64 {
    let mut total = 0.0;
    for (name, _m, _n) in store.cfg.linear_shapes() {
        let w = store.mat(&name);
        let w_hat = qm.dense_linear(&name);
        if let Some(h) = calib.grams.get(&name) {
            total += crate::tensor::linalg::layer_error(&w, &w_hat, h);
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn setup() -> (WeightStore, Calibration) {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 21);
        let calib = calibrate(&store, 4, 32);
        (store, calib)
    }

    #[test]
    fn calibration_covers_all_linears() {
        let (store, calib) = setup();
        assert_eq!(calib.grams.len(), store.cfg.linear_shapes().len());
        for (name, _m, n) in store.cfg.linear_shapes() {
            let h = &calib.grams[&name];
            assert_eq!((h.rows, h.cols), (n, n));
            // PSD-ish: non-negative diagonal
            for j in 0..n {
                assert!(h[(j, j)] >= 0.0);
            }
        }
        assert_eq!(calib.n_tokens, 4 * 32);
    }

    #[test]
    fn quantize_model_all_methods_native() {
        let (store, calib) = setup();
        for method in ["rtn", "ganq"] {
            let qm = quantize_model(
                &store,
                method,
                4,
                &calib,
                &QuantEngine::Native,
                false,
            )
            .unwrap();
            assert_eq!(qm.linears.len(), store.cfg.linear_shapes().len());
            assert!(qm.weight_bits > 0);
        }
    }

    #[test]
    fn ganq_total_error_below_rtn() {
        let (store, calib) = setup();
        let qm_g =
            quantize_model(&store, "ganq", 3, &calib, &QuantEngine::Native, false)
                .unwrap();
        let qm_r =
            quantize_model(&store, "rtn", 3, &calib, &QuantEngine::Native, false)
                .unwrap();
        let e_g = total_layer_error(&store, &qm_g, &calib);
        let e_r = total_layer_error(&store, &qm_r, &calib);
        assert!(e_g < e_r, "ganq {} !< rtn {}", e_g, e_r);
    }

    #[test]
    fn sequential_mode_quantizes_and_is_competitive() {
        let (store, calib) = setup();
        let qm_seq =
            quantize_model_sequential(&store, "ganq", 3, 4, 32, false)
                .unwrap();
        assert_eq!(qm_seq.linears.len(), store.cfg.linear_shapes().len());
        let qm_par = quantize_model(
            &store,
            "ganq",
            3,
            &calib,
            &QuantEngine::Native,
            false,
        )
        .unwrap();
        // both must be loadable/finite; sequential should not be wildly
        // worse on the shared one-shot-error metric
        let e_seq = total_layer_error(&store, &qm_seq, &calib);
        let e_par = total_layer_error(&store, &qm_par, &calib);
        assert!(e_seq.is_finite() && e_par.is_finite());
        assert!(e_seq < 4.0 * e_par + 1e-9, "{} vs {}", e_seq, e_par);
    }

    #[test]
    fn anyprec_pipeline_nests_and_matches_max_width_ganq() {
        let (store, calib) = setup();
        let qa = quantize_model_anyprec(
            &store,
            &calib,
            &[2, 3, 4],
            &QuantEngine::Native,
            false,
        )
        .unwrap();
        assert_eq!(qa.method, "ganq-anyprec");
        assert_eq!(qa.bits, 4);
        assert_eq!(qa.anyprec_widths(), vec![2, 3, 4]);
        assert_eq!(qa.linears.len(), store.cfg.linear_shapes().len());
        // the max-width family is the plain 4-bit GANQ solve verbatim, so
        // the model-level error matches the non-nested pipeline exactly
        let qg =
            quantize_model(&store, "ganq", 4, &calib, &QuantEngine::Native, false)
                .unwrap();
        let ea = total_layer_error(&store, &qa, &calib);
        let eg = total_layer_error(&store, &qg, &calib);
        assert!(
            (ea - eg).abs() <= 1e-6 * eg.max(1e-12),
            "anyprec@4 {} vs ganq4 {}",
            ea,
            eg
        );
        // nested accounting: one plane set + 3 codebooks beats 3
        // standalone width families, and weight_bits records the former
        let mut nested = 0usize;
        let mut standalone = 0usize;
        for lw in qa.linears.values() {
            let LayerWeights::AnyPrec(b) = lw else {
                panic!("expected nested linears")
            };
            let rep = b.storage_report();
            nested += rep.nested.total_bits();
            standalone += rep.standalone_total_bits();
        }
        assert_eq!(nested, qa.weight_bits);
        assert!(nested < standalone, "{} !< {}", nested, standalone);
        // narrower slices trade accuracy for bits
        let e2: f64 = store
            .cfg
            .linear_shapes()
            .iter()
            .map(|(name, _, _)| {
                let w = store.mat(name);
                let LayerWeights::AnyPrec(b) = &qa.linears[name] else {
                    panic!("expected nested linears")
                };
                crate::tensor::linalg::layer_error(
                    &w,
                    &b.slice(2).dequant(),
                    &calib.grams[name],
                )
            })
            .sum();
        assert!(e2 > ea, "2-bit err {} should exceed 4-bit {}", e2, ea);
    }

    #[test]
    fn unknown_method_errors() {
        let (store, calib) = setup();
        assert!(quantize_model(
            &store,
            "bogus",
            4,
            &calib,
            &QuantEngine::Native,
            false
        )
        .is_err());
    }
}
