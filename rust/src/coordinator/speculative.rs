//! Self-speculative decoding over the any-precision store.
//!
//! Decode is memory-bound — the paper's premise — so the biggest
//! per-request lever after batching is emitting more than one token per
//! weight stream. The nested [`crate::quant::anyprec::BitPlaneStore`]
//! makes the classic draft/verify split nearly free: the low-width
//! *drafter* and the max-width *verifier* are two width-views of the
//! same resident planes (`Engine::new_at(w, Some(width))`), so
//! speculation costs no extra weight memory — the drafter just streams
//! fewer planes per step
//! ([`crate::quant::anyprec::BitPlaneStore::draft_cost_frac`]).
//!
//! One speculative round for a slot whose committed stream ends in the
//! pending (not yet fed) token `c`:
//!
//! 1. **Draft** — feed `c, d_1, .., d_{k-1}` through the draft engine
//!    as `k` single-token micro-steps (batched across speculative
//!    slots), taking the argmax each time: draft tokens `d_1..d_k`.
//!    Paged appends run inside the KV *draft window*
//!    ([`PagedKv::set_draft_window`]) so the draft-width rows are never
//!    sealed or prefix-indexed.
//! 2. **Rollback** — `KvSeq::truncate` back to the anchor position:
//!    the persistent KV only ever holds verify-width rows.
//! 3. **Verify** — one chunked step `[c, d_1..d_k]` through the verify
//!    engine with `LogitsMode::All`: exactly a prefill chunk, sharing
//!    the step with any plain prefill/decode items in the batch. Row
//!    `i` is the logits plain greedy would see after `i` accepted
//!    tokens.
//! 4. **Accept** — the longest prefix with `d_i == argmax(row_{i-1})`
//!    (`a` tokens); truncate the rejected tail to `anchor + 1 + a` and
//!    return row `a` to the scheduler, which samples the bonus token
//!    from it. The accepted drafts surface through
//!    [`super::serve::DecodeBackend::take_committed`].
//!
//! Acceptance is temperature-0 exact match, so speculative output is
//! bitwise identical to plain greedy decode; sampled requests
//! explicitly fall back to plain decode
//! ([`super::serve::DecodeBackend::set_slot_speculative`]). An adaptive
//! controller grows `k` while a slot's running acceptance is high and
//! shrinks it toward 1 when drafts keep missing, so a poorly-matched
//! drafter degrades to plain decode cost plus one draft per round.

use crate::kv::{
    F32Blocks, KvBlockStore, KvLayout, KvPoolStats, LutBlocks, PagedKv,
};
use crate::model::forward::{
    argmax, Engine, KvCache, KvSeq, LogitsMode, SeqRefs, StepItem, StepPlan,
    Weights,
};
use crate::model::{ModelConfig, QuantizedModel};
use crate::obs::trace;
use crate::tensor::Mat;

use super::serve::{DecodeBackend, KvStoreKind, SlotWork};

/// Cumulative speculation counters since backend construction
/// (monotone; the scheduler records per-round deltas into
/// [`super::metrics::ServeMetrics`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// draft tokens proposed
    pub draft_tokens: usize,
    /// drafts accepted by exact-match verification
    pub accepted_tokens: usize,
    /// drafts rejected and rolled back
    pub rollback_tokens: usize,
    /// draft→verify→accept rounds executed
    pub rounds: usize,
}

impl SpecStats {
    /// Counters accumulated since `earlier` (a snapshot of the same
    /// backend taken before a serve round).
    pub fn delta_since(&self, earlier: &SpecStats) -> SpecStats {
        SpecStats {
            draft_tokens: self.draft_tokens - earlier.draft_tokens,
            accepted_tokens: self.accepted_tokens - earlier.accepted_tokens,
            rollback_tokens: self.rollback_tokens - earlier.rollback_tokens,
            rounds: self.rounds - earlier.rounds,
        }
    }

    /// Fraction of drafted tokens the verifier accepted.
    pub fn acceptance_rate(&self) -> f64 {
        if self.draft_tokens > 0 {
            self.accepted_tokens as f64 / self.draft_tokens as f64
        } else {
            f64::NAN
        }
    }
}

/// Speculation knobs (`serve --speculative --draft-width W
/// --draft-len K` on the CLI).
#[derive(Debug, Clone, Copy)]
pub struct SpecOptions {
    /// drafter width; must be nested in the store and below the
    /// (maximum) verify width
    pub draft_width: u8,
    /// draft length `k` each fresh request starts at
    pub draft_len: usize,
    /// adapt `k` per slot from its running acceptance rate
    pub adaptive: bool,
    /// ceiling for adaptive growth (floor is always 1)
    pub max_draft_len: usize,
}

impl SpecOptions {
    /// Adaptive speculation starting at `draft_len` and growing up to
    /// twice that while acceptance stays high.
    pub fn new(draft_width: u8, draft_len: usize) -> SpecOptions {
        let k = draft_len.max(1);
        SpecOptions {
            draft_width,
            draft_len: k,
            adaptive: true,
            max_draft_len: 2 * k,
        }
    }

    /// Fixed draft length (the exact-match property tests sweep this).
    pub fn fixed(draft_width: u8, draft_len: usize) -> SpecOptions {
        let k = draft_len.max(1);
        SpecOptions {
            draft_width,
            draft_len: k,
            adaptive: false,
            max_draft_len: k,
        }
    }
}

/// Per-slot draft state, living beside the slot exactly like the
/// scheduler's own `SlotState`.
#[derive(Debug, Clone)]
struct SlotSpec {
    /// greedy request — may speculate (set by the scheduler right
    /// after admission)
    eligible: bool,
    /// current draft length
    k: usize,
    /// running acceptance rate (EWMA over rounds)
    accept_ewma: f64,
    /// accepted tokens awaiting `take_committed`
    committed: Vec<i32>,
    /// remaining generation budget — drafting past it is pure waste
    budget: usize,
    /// draft length planned by `pre_step` for the coming step (0 =
    /// plain decode; the paged path reserves its blocks there)
    planned: usize,
}

impl SlotSpec {
    fn fresh(opts: &SpecOptions, budget: usize) -> SlotSpec {
        SlotSpec {
            eligible: false,
            k: opts.draft_len,
            accept_ewma: 1.0,
            committed: Vec::new(),
            budget,
            planned: 0,
        }
    }
}

/// KV storage behind the backend: one contiguous cache per slot, or
/// the shared paged block pool.
enum SpecKv {
    Dense(Vec<KvCache>),
    Paged(PagedKv),
}

/// Speculative [`DecodeBackend`]: a draft engine and a verify engine
/// over one shared bit-plane artifact, slotting under the existing
/// scheduler / server / cluster machinery with no router changes.
/// Mixed steps are fine — speculative decode slots and plain prefill
/// chunks share one verify dispatch.
pub struct SpecBackend<'a> {
    draft: Engine<'a>,
    verify: Engine<'a>,
    kv: SpecKv,
    slots: Vec<SlotSpec>,
    opts: SpecOptions,
    stats: SpecStats,
}

fn build_engines<'a>(
    qm: &'a QuantizedModel,
    draft_width: u8,
) -> Result<(Engine<'a>, Engine<'a>), String> {
    let widths = qm.anyprec_widths();
    if widths.is_empty() {
        return Err(
            "model has no nested any-precision linears (quantize with \
             --widths 2,3,4); self-speculative decoding drafts and \
             verifies over one bit-plane store"
                .into(),
        );
    }
    // lint:allow(hot-expect): the is_empty check above returned Err
    let verify_w = *widths.last().expect("nonempty widths");
    if !widths.contains(&draft_width) {
        return Err(format!(
            "draft width {} is not in the nested family {:?}",
            draft_width, widths
        ));
    }
    if draft_width >= verify_w {
        return Err(format!(
            "draft width {} must be below the verify width {}",
            draft_width, verify_w
        ));
    }
    let w = Weights::Quant(qm);
    Ok((
        Engine::new_at(&w, Some(draft_width)),
        Engine::new_at(&w, Some(verify_w)),
    ))
}

impl<'a> SpecBackend<'a> {
    /// Speculative serving over contiguous per-slot caches (the
    /// [`super::serve::NativeBackend`] layout).
    pub fn dense(
        qm: &'a QuantizedModel,
        slots: usize,
        opts: SpecOptions,
    ) -> Result<SpecBackend<'a>, String> {
        let (draft, verify) = build_engines(qm, opts.draft_width)?;
        let cfg = verify.cfg();
        Ok(SpecBackend {
            draft,
            verify,
            kv: SpecKv::Dense(
                (0..slots).map(|_| KvCache::new(cfg)).collect(),
            ),
            slots: vec![SlotSpec::fresh(&opts, 0); slots],
            opts,
            stats: SpecStats::default(),
        })
    }

    /// Speculative serving over the paged KV cache (prefix sharing,
    /// CoW, preemption — the [`super::serve::PagedNativeBackend`]
    /// layout). Draft rows append inside the KV draft window so they
    /// are never sealed or prefix-indexed.
    pub fn paged(
        qm: &'a QuantizedModel,
        slots: usize,
        block_size: usize,
        num_blocks: usize,
        kind: KvStoreKind,
        opts: SpecOptions,
    ) -> Result<SpecBackend<'a>, String> {
        let (draft, verify) = build_engines(qm, opts.draft_width)?;
        let cfg = verify.cfg();
        let layout = KvLayout::new(&cfg, block_size);
        let store: Box<dyn KvBlockStore> = match kind {
            KvStoreKind::F32 => Box::new(F32Blocks::new(layout, num_blocks)),
            KvStoreKind::Lut4 => {
                Box::new(LutBlocks::new(layout, num_blocks))
            }
        };
        Ok(SpecBackend {
            draft,
            verify,
            kv: SpecKv::Paged(PagedKv::new(store, num_blocks, slots)),
            slots: vec![SlotSpec::fresh(&opts, 0); slots],
            opts,
            stats: SpecStats::default(),
        })
    }

    /// The speculation knobs this backend runs with.
    pub fn options(&self) -> SpecOptions {
        self.opts
    }

    /// Mutable paged-pool handle (None on the dense arm) for auditor
    /// control ([`PagedKv::set_audit`]) and fault injection in tests.
    pub fn paged_kv_mut(&mut self) -> Option<&mut PagedKv> {
        match &mut self.kv {
            SpecKv::Dense(_) => None,
            SpecKv::Paged(kv) => Some(kv),
        }
    }

    fn pos_of(&self, slot: usize) -> usize {
        match &self.kv {
            SpecKv::Dense(caches) => caches[slot].len,
            SpecKv::Paged(kv) => kv.pos(slot),
        }
    }

    fn truncate_to(&mut self, slot: usize, n: usize) {
        match &mut self.kv {
            SpecKv::Dense(caches) => caches[slot].truncate(n),
            SpecKv::Paged(kv) => kv.truncate_slot(slot, n),
        }
    }

    /// Plain verify-width step (no speculative item this round) — the
    /// exact [`super::serve::NativeBackend`] / `PagedNativeBackend`
    /// behavior.
    fn plain_step(&mut self, work: &[SlotWork]) -> Vec<Vec<f32>> {
        let items = work
            .iter()
            .enumerate()
            .map(|(i, wk)| StepItem {
                seq: i,
                tokens: wk.tokens.clone(),
                logits: if wk.want_logits {
                    LogitsMode::Last
                } else {
                    LogitsMode::None
                },
            })
            .collect();
        let pushes: Vec<Vec<i32>> =
            work.iter().map(|wk| wk.tokens.clone()).collect();
        let slot_ids: Vec<usize> = work.iter().map(|wk| wk.slot).collect();
        let outs = run_plan(
            &mut self.verify,
            &mut self.kv,
            &slot_ids,
            &pushes,
            &StepPlan { items },
        );
        for wk in work {
            if wk.want_logits {
                let s = &mut self.slots[wk.slot];
                s.budget = s.budget.saturating_sub(1);
            }
        }
        outs.into_iter().map(|m| m.data).collect()
    }
}

/// Run `plan` over `slot_ids` through `engine`: the one dispatch shape
/// both phases and both KV layouts share. `pushes[x]` records the
/// tokens item `x` appends (the paged table needs token identity;
/// dense caches ignore it).
fn run_plan(
    engine: &mut Engine<'_>,
    kv: &mut SpecKv,
    slot_ids: &[usize],
    pushes: &[Vec<i32>],
    plan: &StepPlan,
) -> Vec<Mat> {
    match kv {
        SpecKv::Dense(caches) => {
            let mut refs: Vec<&mut dyn KvSeq> = caches
                .iter_mut()
                .enumerate()
                .filter(|(si, _)| slot_ids.contains(si))
                .map(|(_, c)| c as &mut dyn KvSeq)
                .collect();
            engine.step(plan, &mut SeqRefs(&mut refs))
        }
        SpecKv::Paged(pkv) => {
            for (x, &slot) in slot_ids.iter().enumerate() {
                pkv.push_tokens(slot, &pushes[x]);
            }
            let mut seqs = pkv.seqs(slot_ids.to_vec());
            engine.step(plan, &mut seqs)
        }
    }
}

impl DecodeBackend for SpecBackend<'_> {
    fn slots(&self) -> usize {
        self.slots.len()
    }

    fn cfg(&self) -> ModelConfig {
        self.verify.cfg()
    }

    fn max_chunk(&self) -> usize {
        usize::MAX
    }

    fn step(&mut self, work: &[SlotWork]) -> Result<Vec<Vec<f32>>, String> {
        let ctx = self.verify.cfg().ctx;
        let opts = self.opts;
        // classify: a speculative item is a single-token logits-wanting
        // feed (a decode position — or the final token of a one-token
        // prompt run, same semantics) on an eligible slot with a usable
        // draft length once the ctx/budget caps apply
        let mut spec: Vec<(usize, usize)> = Vec::new(); // (work idx, k)
        for (i, wk) in work.iter().enumerate() {
            let s = &self.slots[wk.slot];
            if !(wk.want_logits && wk.tokens.len() == 1 && s.eligible) {
                continue;
            }
            let pos = self.pos_of(wk.slot);
            // the verify chunk feeds k+1 positions from pos. Capping at
            // ctx - pos - 2 keeps a round from emitting more tokens
            // than plain greedy would before the scheduler's
            // pos+1 >= ctx stop; the budget cap stops drafting past
            // max_new
            let k = s
                .planned
                .min(s.budget.saturating_sub(1))
                .min(ctx.saturating_sub(pos + 2));
            if k >= 1 {
                spec.push((i, k));
            }
        }
        for s in &mut self.slots {
            s.planned = 0;
        }
        if spec.is_empty() {
            return Ok(self.plain_step(work));
        }

        // ---- draft phase: k_max single-token micro-steps at the draft
        // width, batched across speculative slots
        let anchors: Vec<usize> = spec
            .iter()
            .map(|&(i, _)| self.pos_of(work[i].slot))
            .collect();
        let mut drafts: Vec<Vec<i32>> = vec![Vec::new(); spec.len()];
        let mut pend: Vec<i32> =
            // bound: speculative work items are single-token decodes
            spec.iter().map(|&(i, _)| work[i].tokens[0]).collect();
        if let SpecKv::Paged(pkv) = &mut self.kv {
            pkv.set_draft_window(true);
        }
        let kmax = spec.iter().map(|&(_, k)| k).max().unwrap_or(0);
        for _ in 0..kmax {
            let live: Vec<usize> = (0..spec.len())
                .filter(|&x| drafts[x].len() < spec[x].1)
                .collect();
            if live.is_empty() {
                break;
            }
            let slot_ids: Vec<usize> =
                live.iter().map(|&x| work[spec[x].0].slot).collect();
            let toks: Vec<i32> = live.iter().map(|&x| pend[x]).collect();
            let pushes: Vec<Vec<i32>> =
                toks.iter().map(|&t| vec![t]).collect();
            let plan = StepPlan::decode(&toks);
            let outs = run_plan(
                &mut self.draft,
                &mut self.kv,
                &slot_ids,
                &pushes,
                &plan,
            );
            for (j, &x) in live.iter().enumerate() {
                let d = argmax(&outs[j].data) as i32;
                drafts[x].push(d);
                pend[x] = d;
            }
        }
        if let SpecKv::Paged(pkv) = &mut self.kv {
            // audit inside the still-open window: catches draft rows
            // leaking into the prefix index at the moment it matters
            pkv.maybe_audit();
            pkv.set_draft_window(false);
        }
        // roll every draft row back before verification: the
        // persistent KV only ever holds verify-width rows
        for (x, &(i, _)) in spec.iter().enumerate() {
            self.truncate_to(work[i].slot, anchors[x]);
        }
        let drafted: usize = drafts.iter().map(|d| d.len()).sum();
        trace::instant(
            "spec.draft",
            &[("slots", spec.len() as f64), ("tokens", drafted as f64)],
        );

        // ---- verify phase: one chunked verify-width step over every
        // worked slot — speculative items feed [pending, d_1..d_k] and
        // score every position; plain prefill/decode items ride along
        let mut spec_of = vec![usize::MAX; work.len()];
        for (x, &(i, _)) in spec.iter().enumerate() {
            spec_of[i] = x;
        }
        let mut items = Vec::with_capacity(work.len());
        let mut pushes = Vec::with_capacity(work.len());
        for (i, wk) in work.iter().enumerate() {
            let x = spec_of[i];
            let item = if x != usize::MAX {
                let mut t = Vec::with_capacity(drafts[x].len() + 1);
                // bound: speculative work items are single-token decodes
                t.push(wk.tokens[0]);
                t.extend_from_slice(&drafts[x]);
                StepItem::verify(i, t)
            } else {
                StepItem {
                    seq: i,
                    tokens: wk.tokens.clone(),
                    logits: if wk.want_logits {
                        LogitsMode::Last
                    } else {
                        LogitsMode::None
                    },
                }
            };
            pushes.push(item.tokens.clone());
            items.push(item);
        }
        let slot_ids: Vec<usize> = work.iter().map(|wk| wk.slot).collect();
        let mut outs = run_plan(
            &mut self.verify,
            &mut self.kv,
            &slot_ids,
            &pushes,
            &StepPlan { items },
        );
        trace::instant(
            "spec.verify",
            &[
                ("slots", spec.len() as f64),
                ("tokens", (drafted + spec.len()) as f64),
            ],
        );

        // ---- accept the longest exact-match prefix per speculative
        // slot, truncate the rejected tail, hand the scheduler row `a`
        let mut out: Vec<Vec<f32>> = vec![Vec::new(); work.len()];
        for (i, wk) in work.iter().enumerate() {
            let x = spec_of[i];
            if x == usize::MAX {
                out[i] = std::mem::take(&mut outs[i].data);
                if wk.want_logits {
                    let s = &mut self.slots[wk.slot];
                    s.budget = s.budget.saturating_sub(1);
                }
                continue;
            }
            let k = drafts[x].len();
            let m = &outs[i];
            debug_assert_eq!(m.rows, k + 1, "verify scores every position");
            let mut a = 0usize;
            while a < k && argmax(m.row(a)) as i32 == drafts[x][a] {
                a += 1;
            }
            // row `a` is what plain greedy would see after the `a`
            // accepted tokens — the scheduler samples the bonus from it
            out[i] = m.row(a).to_vec();
            self.truncate_to(wk.slot, anchors[x] + 1 + a);
            let s = &mut self.slots[wk.slot];
            s.committed = drafts[x][..a].to_vec();
            s.budget = s.budget.saturating_sub(a + 1);
            let rate = a as f64 / k as f64;
            s.accept_ewma = 0.5 * s.accept_ewma + 0.5 * rate;
            if opts.adaptive {
                let old = s.k;
                if a == k && s.accept_ewma >= 0.75 {
                    s.k = (s.k + 1).min(opts.max_draft_len);
                } else if s.accept_ewma < 0.4 {
                    s.k = s.k.saturating_sub(1).max(1);
                }
                if s.k != old {
                    trace::counter("spec.k", s.k as f64);
                }
            }
            self.stats.draft_tokens += k;
            self.stats.accepted_tokens += a;
            self.stats.rollback_tokens += k - a;
            self.stats.rounds += 1;
            trace::instant(
                "spec.accept",
                &[
                    ("slot", wk.slot as f64),
                    ("accepted", a as f64),
                    ("k", k as f64),
                ],
            );
            if k > a {
                trace::instant(
                    "spec.rollback",
                    &[
                        ("slot", wk.slot as f64),
                        ("dropped", (k - a) as f64),
                    ],
                );
            }
        }
        if let SpecKv::Paged(kv) = &mut self.kv {
            // step boundary: every rollback/commit has settled — sweep
            // refcount conservation over the shared pool
            kv.maybe_audit();
        }
        Ok(out)
    }

    fn reset_slot(&mut self, slot: usize) {
        let cfg = self.verify.cfg();
        match &mut self.kv {
            SpecKv::Dense(caches) => caches[slot] = KvCache::new(cfg),
            SpecKv::Paged(kv) => kv.release(slot),
        }
        self.slots[slot] = SlotSpec::fresh(&self.opts, 0);
    }

    fn slot_pos(&self, slot: usize) -> usize {
        self.pos_of(slot)
    }

    fn weight_bytes_per_step(&self) -> usize {
        // the verify plan — the figure comparable to plain decode (the
        // drafter streams draft_cost_frac of it per micro-step)
        self.verify.weight_bytes_per_step()
    }

    fn kv_bytes_per_step(&self) -> usize {
        match &self.kv {
            SpecKv::Dense(_) => {
                let c = self.cfg();
                c.layers * c.heads * c.ctx * c.head_dim() * 4 * 2
            }
            SpecKv::Paged(kv) => {
                kv.bytes_per_block() * kv.stats().peak_blocks_in_use
            }
        }
    }

    fn admit(
        &mut self,
        slot: usize,
        prompt: &[i32],
        max_new: usize,
    ) -> Option<usize> {
        let cfg = self.verify.cfg();
        let cached = match &mut self.kv {
            SpecKv::Dense(caches) => {
                caches[slot] = KvCache::new(cfg);
                Some(0)
            }
            SpecKv::Paged(kv) => {
                kv.release(slot);
                kv.admit(slot, prompt, max_new)
            }
        };
        if cached.is_some() {
            self.slots[slot] = SlotSpec::fresh(&self.opts, max_new);
        }
        cached
    }

    fn pre_step(&mut self, need: &[usize]) -> Vec<usize> {
        // plan this step's draft length per speculative decode slot;
        // the paged pool must reserve the whole k+1-position verify
        // window up front (the draft phase peaks at k appended rows
        // before rollback, the verify chunk at k+1)
        let mut planned = vec![0usize; need.len()];
        for (si, s) in self.slots.iter().enumerate().take(need.len()) {
            if need[si] == 1 && s.eligible && s.budget > 1 {
                planned[si] = s.k;
            }
        }
        match &mut self.kv {
            SpecKv::Dense(_) => {
                for (si, &p) in planned.iter().enumerate() {
                    self.slots[si].planned = p;
                }
                Vec::new()
            }
            SpecKv::Paged(kv) => {
                // split the pool headroom beyond what the plain step
                // needs across the speculative slots, so drafting never
                // preempts a slot that plain decode could have served
                let bs = kv.block_size();
                let plain_blocks: usize =
                    need.iter().map(|&n| n.div_ceil(bs) + 1).sum();
                let spare = kv
                    .reclaimable_blocks()
                    .saturating_sub(plain_blocks)
                    * bs;
                let nspec =
                    planned.iter().filter(|&&p| p > 0).count().max(1);
                let mut inflated = need.to_vec();
                for (si, p) in planned.iter_mut().enumerate() {
                    if *p == 0 {
                        continue;
                    }
                    *p = (*p).min(spare / nspec);
                    inflated[si] = need[si] + *p;
                }
                for (si, &p) in planned.iter().enumerate() {
                    self.slots[si].planned = p;
                }
                let victims = kv.prepare_step_n(&inflated);
                // preemption/eviction just moved references; audit
                // before the draft phase writes through the new tables
                kv.maybe_audit();
                victims
            }
        }
    }

    fn release_slot(&mut self, slot: usize) {
        if let SpecKv::Paged(kv) = &mut self.kv {
            kv.release(slot);
        }
        self.slots[slot] = SlotSpec::fresh(&self.opts, 0);
    }

    fn pool_stats(&self) -> Option<KvPoolStats> {
        match &self.kv {
            SpecKv::Dense(_) => None,
            SpecKv::Paged(kv) => Some(kv.stats()),
        }
    }

    // widths() stays empty on purpose: this backend's width policy IS
    // speculation (draft low, verify high); pinning admissions to one
    // width would defeat it, so only PrecisionPolicy::Native is valid.

    fn set_slot_speculative(&mut self, slot: usize, on: bool) {
        self.slots[slot].eligible = on;
    }

    fn take_committed(&mut self, slot: usize) -> Vec<i32> {
        std::mem::take(&mut self.slots[slot].committed)
    }

    fn spec_stats(&self) -> Option<SpecStats> {
        Some(self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{GenRequest, SamplingParams, StopCriteria};
    use crate::model::{LayerWeights, WeightStore};
    use crate::quant::lut::lut_from_parts;
    use crate::quant::BitPlaneStore;

    /// Quantized model whose every linear is a random nested
    /// any-precision store (widths 2/3/4) — the serve-test idiom.
    fn anyprec_model(seed: u64) -> QuantizedModel {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, seed);
        let mut rng = crate::util::rng::Rng::new(seed ^ 0x5bec);
        let mut linears = std::collections::BTreeMap::new();
        for (name, m, n) in store.cfg.linear_shapes() {
            let codes: Vec<u8> =
                (0..m * n).map(|_| rng.below(16) as u8).collect();
            let cb = Mat::from_vec(
                m,
                16,
                rng.normal_vec_f32(m * 16)
                    .into_iter()
                    .map(|v| v * 0.08)
                    .collect(),
            );
            let parent = lut_from_parts(m, n, 4, codes, cb);
            linears.insert(
                name,
                LayerWeights::AnyPrec(BitPlaneStore::nest(
                    &parent,
                    &[2, 3, 4],
                )),
            );
        }
        QuantizedModel {
            base: store,
            method: "ganq-anyprec".into(),
            bits: 4,
            linears,
            weight_bits: 0,
        }
    }

    #[test]
    fn rejects_non_anyprec_models() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 7);
        let calib = super::super::pipeline::calibrate(&store, 2, 16);
        let qm = super::super::pipeline::quantize_model(
            &store,
            "rtn",
            4,
            &calib,
            &super::super::pipeline::QuantEngine::Native,
            false,
        )
        .unwrap();
        let err = SpecBackend::dense(&qm, 1, SpecOptions::new(2, 4))
            .err()
            .expect("plain lut model must be rejected");
        assert!(err.contains("any-precision"), "err: {}", err);
    }

    #[test]
    fn rejects_bad_draft_widths() {
        let qm = anyprec_model(11);
        // verify width (4) cannot draft for itself
        assert!(SpecBackend::dense(&qm, 1, SpecOptions::new(4, 4)).is_err());
        // width outside the nested family
        assert!(SpecBackend::dense(&qm, 1, SpecOptions::new(5, 4)).is_err());
        assert!(SpecBackend::dense(&qm, 1, SpecOptions::new(2, 4)).is_ok());
    }

    #[test]
    fn spec_stats_add_up_and_delta() {
        let qm = anyprec_model(12);
        let mut be = SpecBackend::dense(&qm, 2, SpecOptions::fixed(2, 4))
            .expect("backend");
        let reqs = vec![
            GenRequest::greedy(1, vec![3, 4, 5], 8),
            GenRequest::greedy(2, vec![9, 1], 6),
        ];
        let base = be.spec_stats().unwrap();
        assert_eq!(base, SpecStats::default());
        let (_, m) = super::super::serve::serve(&mut be, reqs).unwrap();
        let s = be.spec_stats().unwrap();
        assert!(s.rounds > 0, "greedy requests must speculate");
        assert_eq!(
            s.accepted_tokens + s.rollback_tokens,
            s.draft_tokens,
            "every draft is either accepted or rolled back"
        );
        let d = s.delta_since(&base);
        assert_eq!(d, s);
        // the scheduler surfaced the same counters in ServeMetrics
        assert_eq!(m.draft_tokens, s.draft_tokens);
        assert_eq!(m.accepted_tokens, s.accepted_tokens);
        assert_eq!(m.rollback_tokens, s.rollback_tokens);
        assert_eq!(m.spec_rounds, s.rounds);
    }

    #[test]
    fn sampled_requests_fall_back_to_plain_decode() {
        let qm = anyprec_model(13);
        let sampling = SamplingParams {
            temperature: 0.8,
            top_k: 0,
            top_p: 1.0,
            seed: 5,
        };
        let stop = StopCriteria::max_tokens(6);
        let reqs = vec![GenRequest::new(
            1,
            vec![4, 5, 6],
            sampling,
            stop.clone(),
        )];
        let mut be = SpecBackend::dense(&qm, 1, SpecOptions::new(2, 4))
            .expect("backend");
        let (out, m) = super::super::serve::serve(&mut be, reqs).unwrap();
        assert_eq!(be.spec_stats().unwrap().rounds, 0);
        assert_eq!(m.spec_rounds, 0);
        // identical to the plain max-width engine under the same seed
        let mut plain =
            super::super::serve::NativeBackend::new(Weights::Quant(&qm), 1);
        let reqs2 =
            vec![GenRequest::new(1, vec![4, 5, 6], sampling, stop)];
        let (out2, _) =
            super::super::serve::serve(&mut plain, reqs2).unwrap();
        assert_eq!(out[0].tokens, out2[0].tokens);
    }

    #[test]
    fn speculative_greedy_matches_plain_greedy_dense() {
        let qm = anyprec_model(21);
        let prompts: Vec<Vec<i32>> =
            vec![vec![1, 2, 3, 4], vec![7, 8], vec![5; 6], vec![9]];
        let reqs = |off: u64| -> Vec<GenRequest> {
            prompts
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    GenRequest::greedy(off + i as u64, p.clone(), 12)
                })
                .collect()
        };
        let mut plain =
            super::super::serve::NativeBackend::new(Weights::Quant(&qm), 4);
        let (want, _) =
            super::super::serve::serve(&mut plain, reqs(0)).unwrap();
        for k in [1usize, 4, 8] {
            let mut be =
                SpecBackend::dense(&qm, 4, SpecOptions::fixed(2, k))
                    .expect("backend");
            let (got, _) =
                super::super::serve::serve(&mut be, reqs(0)).unwrap();
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(
                    g.tokens, w.tokens,
                    "spec k={} diverged from plain greedy",
                    k
                );
            }
        }
    }

    #[test]
    fn ctx_edge_matches_plain_greedy() {
        // a huge max_new forces generation to the context-full stop on
        // opt-micro (ctx 128): speculation must finish at the same token
        let qm = anyprec_model(22);
        let reqs = || vec![GenRequest::greedy(1, vec![3, 1, 2], 4096)];
        let mut plain =
            super::super::serve::NativeBackend::new(Weights::Quant(&qm), 1);
        let (want, _) =
            super::super::serve::serve(&mut plain, reqs()).unwrap();
        let mut be = SpecBackend::dense(&qm, 1, SpecOptions::new(3, 6))
            .expect("backend");
        let (got, _) = super::super::serve::serve(&mut be, reqs()).unwrap();
        assert_eq!(got[0].tokens, want[0].tokens);
        assert_eq!(got[0].finish, want[0].finish);
    }
}
