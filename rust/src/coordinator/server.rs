//! Threaded serving front-end. PJRT handles are not Send, so a dedicated
//! engine thread owns the backend; callers submit requests through a
//! channel and receive responses on per-request channels. Requests are
//! micro-batched: the engine drains whatever is queued (up to a window)
//! and runs one continuous-batching round.

use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;

use super::metrics::ServeMetrics;
use super::serve::{Request, Response};

pub enum Job {
    Run(Request, Sender<Response>),
    Shutdown(Sender<ServeMetrics>),
}

pub struct ServerHandle {
    tx: Sender<Job>,
    join: Option<JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl ServerHandle {
    /// Spawn the engine thread. `make_backend_and_serve` is called on the
    /// engine thread with each drained batch (it owns any non-Send state
    /// via the closure's captured constructor).
    pub fn spawn<F>(mut engine_loop: F) -> ServerHandle
    where
        F: FnMut(Vec<(Request, Sender<Response>)>) -> ServeMetrics
            + Send
            + 'static,
    {
        let (tx, rx): (Sender<Job>, Receiver<Job>) = mpsc::channel();
        let join = std::thread::spawn(move || {
            let mut total = ServeMetrics::default();
            loop {
                // block for the first job, then drain a window
                let first = match rx.recv() {
                    Ok(j) => j,
                    Err(_) => break,
                };
                let mut batch = Vec::new();
                let mut shutdown: Option<Sender<ServeMetrics>> = None;
                match first {
                    Job::Run(r, s) => batch.push((r, s)),
                    Job::Shutdown(s) => shutdown = Some(s),
                }
                if shutdown.is_none() {
                    // micro-batch window: drain whatever is already queued
                    while batch.len() < 16 {
                        match rx.try_recv() {
                            Ok(Job::Run(r, s)) => batch.push((r, s)),
                            Ok(Job::Shutdown(s)) => {
                                shutdown = Some(s);
                                break;
                            }
                            Err(_) => break,
                        }
                    }
                }
                if !batch.is_empty() {
                    let m = engine_loop(batch);
                    total.requests.extend(m.requests);
                    total.decode_steps += m.decode_steps;
                    total.prompt_positions += m.prompt_positions;
                    total.wall_s += m.wall_s;
                    total.weight_bytes_per_step = m.weight_bytes_per_step;
                    total.kv_bytes_per_step = m.kv_bytes_per_step;
                }
                if let Some(s) = shutdown {
                    let _ = s.send(total.clone());
                    break;
                }
            }
        });
        ServerHandle {
            tx,
            join: Some(join),
            next_id: std::sync::atomic::AtomicU64::new(1),
        }
    }

    /// Submit a request; returns the receiver for its response.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> Receiver<Response> {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(Job::Run(
            Request { id, prompt, max_new },
            tx,
        ));
        rx
    }

    /// Drain, stop the engine thread, and return aggregate metrics.
    pub fn shutdown(mut self) -> ServeMetrics {
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(Job::Shutdown(tx));
        let m = rx.recv().unwrap_or_default();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::serve::{serve, NativeBackend};
    use crate::model::forward::Weights;
    use crate::model::{ModelConfig, WeightStore};

    #[test]
    fn threaded_server_round_trip() {
        let handle = ServerHandle::spawn(move |batch| {
            // engine thread: build a fresh native backend per micro-batch
            let cfg = ModelConfig::builtin("opt-micro").unwrap();
            let store = WeightStore::random("t", cfg, 41);
            let w = Weights::Fp(&store);
            let mut be = NativeBackend::new(w, 2);
            let (reqs, senders): (Vec<_>, Vec<_>) = batch
                .into_iter()
                .map(|(r, s)| (r, s))
                .unzip();
            let ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
            let (resps, m) = serve(&mut be, reqs).unwrap();
            for (resp, (id, s)) in resps
                .into_iter()
                .zip(ids.into_iter().zip(senders))
            {
                assert_eq!(resp.id, id);
                let _ = s.send(resp);
            }
            m
        });
        let rx1 = handle.submit(vec![104, 105], 3);
        let rx2 = handle.submit(vec![97], 5);
        let r1 = rx1.recv().unwrap();
        let r2 = rx2.recv().unwrap();
        assert_eq!(r1.tokens.len(), 3);
        assert_eq!(r2.tokens.len(), 5);
        let m = handle.shutdown();
        assert_eq!(m.total_generated(), 8);
    }
}
