//! Threaded serving front-end. PJRT handles are not Send, so a dedicated
//! engine thread owns the backend; callers submit [`GenRequest`]s through
//! a channel and consume a per-request [`TokenEvent`] stream: `Token`
//! events arrive as the scheduler produces them (before the request
//! completes) and a final `Done` carries the [`GenOutcome`]. `submit`
//! also hands back a [`CancelHandle`] so callers can abandon a request
//! mid-flight; the scheduler releases its KV slot at the next step
//! boundary. Requests are micro-batched: the engine drains whatever is
//! queued (up to `ServeOptions::serve_window`) and runs one
//! continuous-batching round.
//!
//! The engine loop runs under `catch_unwind`: a panicking round drops
//! its per-request senders (receivers observe the disconnect instead of
//! hanging) and [`ServerHandle::shutdown`] surfaces the captured panic
//! as an error. [`recv_outcome_timeout`] bounds the wait on a stream
//! whose engine may have died.

use std::panic::{self, AssertUnwindSafe};
use std::sync::mpsc::{
    self, Receiver, RecvError, RecvTimeoutError, Sender,
};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::ordered_lock::{rank, OrderedMutex};

use super::metrics::ServeMetrics;
use super::serve::{
    serve_events, CancelHandle, DecodeBackend, GenOutcome, GenRequest,
    SamplingParams, ServeOptions, StopCriteria, TokenEvent,
};

/// Render a caught panic payload (`&str` or `String`) for error
/// reporting; the cluster router reuses this for worker post-mortems.
pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

pub enum Job {
    Run(GenRequest, Sender<TokenEvent>),
    Shutdown(Sender<ServeMetrics>),
}

/// Run one continuous-batching round over a drained micro-batch,
/// streaming each request's events to its submitter — the glue between
/// [`serve_events`]'s single sink and the per-request channels. Backends
/// that cannot serve (construction failed upstream) simply drop their
/// senders; receivers observe the disconnect.
pub fn serve_batch(
    backend: &mut dyn DecodeBackend,
    batch: Vec<(GenRequest, Sender<TokenEvent>)>,
    opts: ServeOptions,
) -> ServeMetrics {
    // events route by request id, so ids must be unique within a batch
    // (ServerHandle::submit guarantees this; hand-built batches must too)
    let mut senders: std::collections::HashMap<u64, Sender<TokenEvent>> =
        batch.iter().map(|(r, s)| (r.id, s.clone())).collect();
    debug_assert_eq!(
        senders.len(),
        batch.len(),
        "duplicate request ids in a serve_batch round"
    );
    let reqs: Vec<GenRequest> = batch.into_iter().map(|(r, _)| r).collect();
    let result = serve_events(backend, reqs, opts, &mut |ev| {
        let (id, done) = match &ev {
            TokenEvent::Token { id, .. } => (*id, false),
            TokenEvent::Done(o) => (o.id, true),
        };
        if let Some(s) = senders.get(&id) {
            let _ = s.send(ev);
        }
        if done {
            senders.remove(&id);
        }
    });
    match result {
        Ok((_, m)) => m,
        Err(e) => {
            eprintln!("serve round failed: {}", e);
            ServeMetrics::default()
        }
    }
}

/// Drain a request's event stream to completion; `Err` means the engine
/// thread dropped the stream before a `Done` arrived.
pub fn recv_outcome(rx: &Receiver<TokenEvent>) -> Result<GenOutcome, RecvError> {
    loop {
        if let TokenEvent::Done(o) = rx.recv()? {
            return Ok(o);
        }
    }
}

/// [`recv_outcome`] with a bound on the *total* wait: `Err(Timeout)`
/// when no `Done` arrives within `timeout`, `Err(Disconnected)` when
/// the engine dropped the stream (e.g. its thread panicked mid-round).
pub fn recv_outcome_timeout(
    rx: &Receiver<TokenEvent>,
    timeout: Duration,
) -> Result<GenOutcome, RecvTimeoutError> {
    let deadline = Instant::now() + timeout;
    loop {
        let remaining = deadline
            .checked_duration_since(Instant::now())
            .unwrap_or(Duration::ZERO);
        if let TokenEvent::Done(o) = rx.recv_timeout(remaining)? {
            return Ok(o);
        }
    }
}

pub struct ServerHandle {
    tx: Sender<Job>,
    join: Option<JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
    /// set by the engine thread when a round panicked; surfaced by
    /// [`ServerHandle::shutdown`]. Rank-tagged (`rank::SERVER_PANIC`)
    /// so the lock lint can order it against every other lock.
    panic: Arc<OrderedMutex<Option<String>>>,
}

impl ServerHandle {
    /// Spawn the engine thread. `engine_loop` is called on the engine
    /// thread with each drained micro-batch (it owns any non-Send state
    /// via the closure's captured constructor; most impls call
    /// [`serve_batch`]). `opts.serve_window` bounds how many queued
    /// requests join one continuous-batching round.
    pub fn spawn<F>(opts: ServeOptions, mut engine_loop: F) -> ServerHandle
    where
        F: FnMut(Vec<(GenRequest, Sender<TokenEvent>)>) -> ServeMetrics
            + Send
            + 'static,
    {
        let window = opts.serve_window.max(1);
        let (tx, rx): (Sender<Job>, Receiver<Job>) = mpsc::channel();
        let panic_slot = Arc::new(OrderedMutex::new(
            rank::SERVER_PANIC,
            "server.panic",
            None::<String>,
        ));
        let panic_in = Arc::clone(&panic_slot);
        let join = std::thread::Builder::new()
            .name("ganq-engine".into())
            .spawn(move || {
                let mut total = ServeMetrics::default();
                loop {
                    // block for the first job, then drain a window
                    let first = match rx.recv() {
                        Ok(j) => j,
                        Err(_) => break,
                    };
                    let mut batch = Vec::new();
                    let mut shutdown: Option<Sender<ServeMetrics>> = None;
                    match first {
                        Job::Run(r, s) => batch.push((r, s)),
                        Job::Shutdown(s) => shutdown = Some(s),
                    }
                    if shutdown.is_none() {
                        // micro-batch window: drain whatever is queued
                        while batch.len() < window {
                            match rx.try_recv() {
                                Ok(Job::Run(r, s)) => batch.push((r, s)),
                                Ok(Job::Shutdown(s)) => {
                                    shutdown = Some(s);
                                    break;
                                }
                                Err(_) => break,
                            }
                        }
                    }
                    if !batch.is_empty() {
                        // a panicking round drops its senders mid-unwind,
                        // so receivers observe a disconnect, not a hang
                        let round = panic::catch_unwind(
                            AssertUnwindSafe(|| engine_loop(batch)),
                        );
                        match round {
                            Ok(m) => total.merge_round(m),
                            Err(p) => {
                                *panic_in.lock() = Some(panic_message(&*p));
                                break;
                            }
                        }
                    }
                    if let Some(s) = shutdown {
                        let _ = s.send(total.clone());
                        break;
                    }
                }
            })
            .expect("spawn engine thread");
        ServerHandle {
            tx,
            join: Some(join),
            next_id: std::sync::atomic::AtomicU64::new(1),
            panic: panic_slot,
        }
    }

    /// Submit a request with explicit sampling and stop configs; returns
    /// the request's event stream and its cancellation handle.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        sampling: SamplingParams,
        stop: StopCriteria,
    ) -> (Receiver<TokenEvent>, CancelHandle) {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let req = GenRequest::new(id, prompt, sampling, stop);
        self.submit_request(req)
    }

    /// Submit a pre-built [`GenRequest`] (caller-chosen id — the traffic
    /// harness keys its per-class bookkeeping on ids). The enqueue time
    /// is stamped here (first stamp wins), so queue delay covers the
    /// whole wait including micro-batch windows the request missed.
    pub fn submit_request(
        &self,
        mut req: GenRequest,
    ) -> (Receiver<TokenEvent>, CancelHandle) {
        req.mark_submitted();
        // keep auto-assigned ids disjoint from caller-chosen ones
        self.next_id
            .fetch_max(req.id + 1, std::sync::atomic::Ordering::Relaxed);
        let cancel = req.cancel_handle();
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(Job::Run(req, tx));
        (rx, cancel)
    }

    /// Submit with the historical greedy-to-budget behavior.
    pub fn submit_greedy(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> Receiver<TokenEvent> {
        self.submit(
            prompt,
            SamplingParams::greedy(),
            StopCriteria::max_tokens(max_new),
        )
        .0
    }

    /// Drain, stop the engine thread, and return aggregate metrics.
    /// `Err` carries the panic message when an engine round panicked
    /// (the thread was already torn down — this never hangs on join).
    pub fn shutdown(mut self) -> Result<ServeMetrics, String> {
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(Job::Shutdown(tx));
        let reply = rx.recv();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        if let Some(p) = self.panic.lock().take() {
            return Err(format!("engine thread panicked: {}", p));
        }
        reply.map_err(|_| "engine thread exited before shutdown".to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::serve::{FinishReason, NativeBackend};
    use crate::model::forward::Weights;
    use crate::model::{ModelConfig, WeightStore};

    fn spawn_native(window: usize) -> ServerHandle {
        let opts = ServeOptions { serve_window: window, ..Default::default() };
        ServerHandle::spawn(opts, move |batch| {
            // engine thread: build a fresh native backend per micro-batch
            let cfg = ModelConfig::builtin("opt-micro").unwrap();
            let store = WeightStore::random("t", cfg, 41);
            let w = Weights::Fp(&store);
            let mut be = NativeBackend::new(w, 2);
            serve_batch(&mut be, batch, opts)
        })
    }

    #[test]
    fn threaded_server_streams_tokens_then_done() {
        let handle = spawn_native(16);
        let rx1 = handle.submit_greedy(vec![104, 105], 3);
        let rx2 = handle.submit_greedy(vec![97], 5);
        // collect request 1's full stream: tokens first, Done last
        let mut toks = Vec::new();
        let o1 = loop {
            match rx1.recv().unwrap() {
                TokenEvent::Token { tok, .. } => toks.push(tok),
                TokenEvent::Done(o) => break o,
            }
        };
        assert_eq!(o1.tokens, toks, "stream matches outcome (no trimming)");
        assert_eq!(o1.tokens.len(), 3);
        assert_eq!(o1.finish, FinishReason::MaxTokens);
        let o2 = recv_outcome(&rx2).unwrap();
        assert_eq!(o2.tokens.len(), 5);
        let m = handle.shutdown().unwrap();
        assert_eq!(m.total_generated(), 8);
        assert_eq!(m.finish.max_tokens, 2);
    }

    /// Paces the inner backend so a cancel issued from another thread
    /// reliably lands mid-generation (decode on the micro model is
    /// otherwise faster than cross-thread wakeups).
    struct Throttled<B>(B);

    impl<B: DecodeBackend> DecodeBackend for Throttled<B> {
        fn slots(&self) -> usize {
            self.0.slots()
        }
        fn cfg(&self) -> ModelConfig {
            self.0.cfg()
        }
        fn max_chunk(&self) -> usize {
            self.0.max_chunk()
        }
        fn plan_chunk(&self, cap: usize) -> usize {
            self.0.plan_chunk(cap)
        }
        fn step(
            &mut self,
            work: &[crate::coordinator::SlotWork],
        ) -> Result<Vec<Vec<f32>>, String> {
            std::thread::sleep(std::time::Duration::from_millis(2));
            self.0.step(work)
        }
        fn reset_slot(&mut self, slot: usize) {
            self.0.reset_slot(slot)
        }
        fn slot_pos(&self, slot: usize) -> usize {
            self.0.slot_pos(slot)
        }
        fn weight_bytes_per_step(&self) -> usize {
            self.0.weight_bytes_per_step()
        }
        fn kv_bytes_per_step(&self) -> usize {
            self.0.kv_bytes_per_step()
        }
    }

    #[test]
    fn threaded_server_cancellation() {
        let opts = ServeOptions::default();
        let handle = ServerHandle::spawn(opts, move |batch| {
            let cfg = ModelConfig::builtin("opt-micro").unwrap();
            let store = WeightStore::random("t", cfg, 41);
            let w = Weights::Fp(&store);
            let mut be = Throttled(NativeBackend::new(w, 2));
            serve_batch(&mut be, batch, opts)
        });
        let (rx, cancel) = handle.submit(
            vec![104, 105],
            SamplingParams::greedy(),
            StopCriteria::max_tokens(64),
        );
        // cancel as soon as the first token streams out
        let first = rx.recv().unwrap();
        assert!(matches!(first, TokenEvent::Token { .. }));
        cancel.cancel();
        let o = recv_outcome(&rx).unwrap();
        assert_eq!(o.finish, FinishReason::Cancelled);
        assert!(o.tokens.len() < 64, "cancelled well before the budget");
        let m = handle.shutdown().unwrap();
        assert_eq!(m.finish.cancelled, 1);
        assert!(m.cancelled_tokens > 0);
    }

    #[test]
    fn serve_window_bounds_micro_batch() {
        // window 1: each request runs in its own round; metrics still
        // aggregate across rounds
        let handle = spawn_native(1);
        let rx1 = handle.submit_greedy(vec![104, 105], 2);
        let rx2 = handle.submit_greedy(vec![97], 2);
        assert_eq!(recv_outcome(&rx1).unwrap().tokens.len(), 2);
        assert_eq!(recv_outcome(&rx2).unwrap().tokens.len(), 2);
        let m = handle.shutdown().unwrap();
        assert_eq!(m.total_generated(), 4);
    }

    /// Quantized model whose every linear is a random nested
    /// any-precision store (widths 2/3/4) — the serve-test idiom.
    fn anyprec_model(seed: u64) -> crate::model::QuantizedModel {
        use crate::model::LayerWeights;
        use crate::quant::lut::lut_from_parts;
        use crate::quant::BitPlaneStore;
        use crate::tensor::Mat;
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, seed);
        let mut rng = crate::util::rng::Rng::new(seed ^ 0x5bec);
        let mut linears = std::collections::BTreeMap::new();
        for (name, m, n) in store.cfg.linear_shapes() {
            let codes: Vec<u8> =
                (0..m * n).map(|_| rng.below(16) as u8).collect();
            let cb = Mat::from_vec(
                m,
                16,
                rng.normal_vec_f32(m * 16)
                    .into_iter()
                    .map(|v| v * 0.08)
                    .collect(),
            );
            let parent = lut_from_parts(m, n, 4, codes, cb);
            linears.insert(
                name,
                LayerWeights::AnyPrec(BitPlaneStore::nest(
                    &parent,
                    &[2, 3, 4],
                )),
            );
        }
        crate::model::QuantizedModel {
            base: store,
            method: "ganq-anyprec".into(),
            bits: 4,
            linears,
            weight_bits: 0,
        }
    }

    #[test]
    fn threaded_server_serves_speculative_backend() {
        use crate::coordinator::speculative::{SpecBackend, SpecOptions};
        use crate::coordinator::GenRequest;

        let opts = ServeOptions::default();
        let handle = ServerHandle::spawn(opts, move |batch| {
            // engine thread: the speculative backend is one more
            // DecodeBackend, so the server loop needs no changes
            let qm = anyprec_model(29);
            let mut be = SpecBackend::dense(&qm, 2, SpecOptions::new(2, 4))
                .expect("anyprec model");
            serve_batch(&mut be, batch, opts)
        });
        let rx1 = handle.submit_greedy(vec![104, 105], 6);
        let rx2 = handle.submit_greedy(vec![97], 4);
        let o1 = recv_outcome(&rx1).unwrap();
        let o2 = recv_outcome(&rx2).unwrap();
        assert_eq!(o1.tokens.len(), 6);
        assert_eq!(o2.tokens.len(), 4);
        let m = handle.shutdown().unwrap();
        assert!(m.spec_rounds > 0, "greedy requests must speculate");
        assert_eq!(m.accepted_tokens + m.rollback_tokens, m.draft_tokens);

        // bitwise identical to plain greedy over the same model
        let qm = anyprec_model(29);
        let mut plain = NativeBackend::new(Weights::Quant(&qm), 2);
        let (outs, _) = crate::coordinator::serve(
            &mut plain,
            vec![
                GenRequest::greedy(1, vec![104, 105], 6),
                GenRequest::greedy(2, vec![97], 4),
            ],
        )
        .unwrap();
        assert_eq!(o1.tokens, outs[0].tokens);
        assert_eq!(o2.tokens, outs[1].tokens);
    }

    #[test]
    fn engine_panic_disconnects_streams_and_surfaces_on_shutdown() {
        crate::coordinator::cluster::quiet_ganq_thread_panics();
        let handle = ServerHandle::spawn(ServeOptions::default(), |_batch| {
            panic!("injected engine failure");
        });
        let (rx, _cancel) = handle.submit(
            vec![104, 105],
            SamplingParams::greedy(),
            StopCriteria::max_tokens(4),
        );
        // the stream disconnects instead of hanging...
        let got =
            recv_outcome_timeout(&rx, Duration::from_secs(10));
        assert_eq!(got.unwrap_err(), RecvTimeoutError::Disconnected);
        // ...and shutdown reports the captured panic instead of
        // unwrapping a dead reply channel
        let err = handle.shutdown().unwrap_err();
        assert!(
            err.contains("injected engine failure"),
            "unexpected shutdown error: {}",
            err
        );
    }

    #[test]
    fn recv_outcome_timeout_bounds_the_wait() {
        // a server that never receives work never sends events; the
        // timed drain returns Timeout instead of blocking forever
        let handle = spawn_native(16);
        let (tx, rx) = mpsc::channel::<TokenEvent>();
        let got = recv_outcome_timeout(&rx, Duration::from_millis(20));
        assert_eq!(got.unwrap_err(), RecvTimeoutError::Timeout);
        drop(tx);
        handle.shutdown().unwrap();
    }
}
