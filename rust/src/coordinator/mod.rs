//! L3 coordinator — the system around the paper's quantization method:
//!
//! * `pipeline` — the offline layer-wise PTQ path: calibration capture,
//!   per-layer GANQ/baseline quantization (native or through the AOT HLO
//!   solver graph), servable model assembly. `quantize_model_anyprec`
//!   produces the nested bit-plane layout instead: one max-width GANQ
//!   solve per layer plus per-width codebook re-fits, servable at every
//!   requested width from a single resident artifact.
//! * `serve` — the online path, organized around a request lifecycle:
//!   a [`GenRequest`] carries per-request [`SamplingParams`]
//!   (temperature / top-k / top-p / seed; temperature 0 is the exact
//!   greedy path) and [`StopCriteria`] (token budget, stop tokens, stop
//!   sequences, optional model EOS) plus a [`CancelHandle`] for
//!   mid-flight cancellation. The scheduler continuously batches
//!   requests over a [`DecodeBackend`] (AOT decode + chunked-prefill
//!   graphs via PJRT, the native engine with contiguous KV caches, or
//!   the paged-KV backend with prefix sharing and preemption), planning
//!   mixed steps of prefill chunks and decode positions under a
//!   per-step prefill budget (`ServeOptions::prefill_chunk`, bucketed
//!   onto compiled chunk sizes by [`DecodeBackend::plan_chunk`]).
//!   A `Sampler` stage turns each
//!   slot's logits row into the next token — deterministic in
//!   `(seed, draw index)` regardless of batch composition, preemption,
//!   or prefill chunking. [`serve_events`] streams [`TokenEvent`]s
//!   incrementally; every request ends in a [`GenOutcome`] with a
//!   [`FinishReason`]. On any-precision models ([`AnyPrecBackend`]) a
//!   [`PrecisionPolicy`] picks the serving width per admission — fixed,
//!   or load-adaptive with queue-depth hysteresis — with admitted
//!   requests pinned to their admission-time width.
//! * `speculative` — self-speculative decoding over one shared
//!   [`quant::BitPlaneStore`](crate::quant::BitPlaneStore): a
//!   [`SpecBackend`] holds a low-width draft engine and a max-width
//!   verify engine over the *same* resident nested planes (no second
//!   model in memory), drafts k tokens per greedy slot at the cheap
//!   width, re-scores them as one verification chunk
//!   (`StepItem::verify`, `LogitsMode::All`), accepts the longest
//!   matching prefix and rolls the KV back past the first mismatch
//!   (`truncate`). It plugs in as a [`DecodeBackend`] under the
//!   unchanged scheduler/server/cluster stack: per-slot draft state
//!   lives beside the slot, mixed steps may combine speculative decode
//!   slots with plain prefill chunks, an adaptive controller resizes k
//!   per request from the running acceptance rate, and sampled
//!   (temperature > 0) requests explicitly fall back to plain decode.
//!   Acceptance is temperature-0 exact-match, so speculative greedy
//!   output is bitwise-identical to plain greedy output.
//! * `metrics` — request latency + throughput + weight-traffic accounting
//!   (Table 6's CUDA-time/speedup/peak-memory analogues), per-finish-
//!   reason counts and cancelled-token waste, plus block-pool occupancy /
//!   prefix-hit / preemption counters for paged serving. Request
//!   timelines are epoch-relative milliseconds (enqueued → admitted →
//!   first token → finished), so TTFT decomposes into queue delay +
//!   prefill, TPOT measures steady-state decode cadence, and everything
//!   serializes via [`ServeMetrics::snapshot`].
//! * `server` — a threaded front: submit requests from any thread,
//!   consume a per-request `TokenEvent` stream, cancel via the returned
//!   handle; a dedicated engine thread owns the (non-Send) runtime and
//!   drains up to `ServeOptions::serve_window` requests per round. The
//!   engine loop runs under `catch_unwind`: a panic disconnects the
//!   outstanding streams and surfaces as an error from
//!   `ServerHandle::shutdown` instead of a hang.
//! * `cluster` — fault-tolerant multi-replica serving on top of the
//!   same request lifecycle: N worker threads each drive one
//!   [`DecodeBackend`] replica (built per round via [`ReplicaEngine`]),
//!   fronted by a router that load-balances with prefix affinity
//!   (`kv::PrefixIndex` chains keyed by prompt blocks, replica ids as
//!   "blocks"), detects dead/wedged workers (`catch_unwind` + per-step
//!   heartbeat with a stall timeout) and requeues their requests onto
//!   survivors with capped exponential backoff — retried streams are
//!   de-duplicated, exploiting the sampler's `(seed, draw index)`
//!   determinism. Degradation is explicit: per-request deadlines end in
//!   [`FinishReason::DeadlineExceeded`] with partial output, and a
//!   load-shed watermark fast-rejects low-priority requests. A
//!   [`FaultPlan`] injects deterministic kills/stalls/admit-failures
//!   for chaos testing (`tests/cluster.rs`).
//!
//! ## Observability flow
//!
//! The serve path is instrumented end to end on `crate::obs`: the
//! scheduler emits spans/instants per step (`sched.plan`,
//! `backend.step`, `sched.sample`, admit/preempt/reject markers), the
//! engine its per-layer phases, the paged pool its CoW/eviction/
//! preemption events, the cluster router its routing/robustness
//! decisions (`cluster.route`, `cluster.requeue`, `cluster.retry`,
//! `cluster.shed`, `cluster.worker_down`), and the PJRT runtime its
//! dispatches — all into a
//! thread-local ring recorder exportable as Chrome `trace_event` JSON
//! (`serve --trace-out`). In parallel, every round records step
//! latencies and KV occupancy into `obs::hist` histograms carried on
//! [`ServeMetrics`]; rounds roll up with `ServeMetrics::merge_round`
//! (histograms merge exactly) and export with `snapshot()`
//! (`--metrics-out`). The open-loop traffic harness (`bench::traffic`,
//! `benches/serve_traffic.rs`) drives this whole pipeline and distills
//! it to `BENCH_serve.json`: engine → sink → snapshot → BENCH_serve.

pub mod cluster;
pub mod metrics;
pub mod pipeline;
pub mod serve;
pub mod server;
pub mod speculative;

pub use cluster::{
    quiet_ganq_thread_panics, Cluster, ClusterMetrics, ClusterOptions,
    Fault, FaultPlan, ReplicaEngine, ReplicaStats, RoundCtx,
};
pub use metrics::{FinishCounts, RequestMetrics, ServeMetrics};
pub use pipeline::{
    calibrate, quantize_model, quantize_model_anyprec, Calibration,
    QuantEngine,
};
pub use serve::{
    serve, serve_events, serve_with, AnyPrecBackend, CancelHandle,
    DecodeBackend, FinishReason, GenOutcome, GenRequest, HloBackend,
    KvStoreKind, NativeBackend, PagedNativeBackend, PrecisionPolicy,
    Sampler, SamplerStep, SamplingParams, ServeOptions, SlotWork,
    StopCriteria, TokenEvent, WeightFmt, DEFAULT_PREFILL_CHUNK,
    DEFAULT_SERVE_WINDOW,
};
pub use server::{
    recv_outcome, recv_outcome_timeout, serve_batch, ServerHandle,
};
pub use speculative::{SpecBackend, SpecOptions, SpecStats};
