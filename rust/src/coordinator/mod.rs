//! L3 coordinator — the system around the paper's quantization method:
//!
//! * `pipeline` — the offline layer-wise PTQ path: calibration capture,
//!   per-layer GANQ/baseline quantization (native or through the AOT HLO
//!   solver graph), servable model assembly.
//! * `serve` — the online path: continuous batching over the AOT decode
//!   graphs (PJRT), the native engine with contiguous KV caches, or the
//!   paged-KV native backend (block tables + prefix sharing +
//!   preemption; see `kv`). The scheduler plans mixed steps of prefill
//!   chunks and decode positions under a per-step prefill budget
//!   (`ServeOptions::prefill_chunk`); backends map them onto
//!   `forward::Engine::step`.
//! * `metrics` — request latency + throughput + weight-traffic accounting
//!   (Table 6's CUDA-time/speedup/peak-memory analogues), plus block-pool
//!   occupancy / prefix-hit / preemption counters for paged serving.
//! * `server` — a threaded front: submit requests from any thread; a
//!   dedicated engine thread owns the (non-Send) runtime.

pub mod metrics;
pub mod pipeline;
pub mod serve;
pub mod server;

pub use metrics::ServeMetrics;
pub use pipeline::{calibrate, quantize_model, Calibration, QuantEngine};
pub use serve::{
    serve, serve_with, DecodeBackend, HloBackend, KvStoreKind,
    NativeBackend, PagedNativeBackend, Request, Response, ServeOptions,
    SlotWork, WeightFmt, DEFAULT_PREFILL_CHUNK,
};
