//! Serving: token-level continuous batching (Orca-style) over a decode
//! backend, organized around a request lifecycle. A [`GenRequest`]
//! carries per-request [`SamplingParams`] (temperature / top-k / top-p /
//! seed; temperature 0 is the exact greedy path) and [`StopCriteria`]
//! (token budget, stop tokens, stop sequences, optional model EOS) plus
//! a [`CancelHandle`]; every request ends in a [`GenOutcome`] with a
//! [`FinishReason`]. [`serve_events`] streams [`TokenEvent`]s as steps
//! produce them, so callers see tokens before requests complete.
//!
//! The scheduler is a thin admission/planning policy: every step it
//! hands the backend a list of [`SlotWork`] items — one per active
//! slot, each either a **prefill chunk** (a run of prompt positions,
//! bounded by the per-step prefill budget so decode latency stays
//! bounded while prompts drain) or a **single decode position** — then
//! runs the [`Sampler`] stage over the returned logits rows. The
//! sampler's RNG draw for a request's `i`-th token is a pure function of
//! `(seed, i)`, so sampled outputs are identical at every batch size,
//! prefill chunking, and across preempt-and-resume. Backends map the
//! step plan onto `forward::Engine::step` (native paths) or the AOT
//! decode graphs.
//!
//! Three backends implement the same contract:
//!
//! * [`HloBackend`] — the AOT serving graphs via PJRT. Two graph
//!   families share one weight argument list and thread the KV caches
//!   through their outputs:
//!
//!   - `decode_{fmt}_{model}_b{B}` advances every slot by one position
//!     (`tok[b]`, `pos[b]`); inactive slots park at the scratch
//!     position `ctx-1`, which is overwritten before any masked read
//!     can see it.
//!   - `prefill_{fmt}_{model}_b{B}_c{C}` advances every slot by a
//!     C-token chunk at per-slot positions (`tokens[b,c]`, `pos[b]`,
//!     `last[b]`): token `c` of slot `b` lands at cache position
//!     `pos[b]+c`, the causal mask is offset per token, and the logits
//!     row comes from in-chunk index `last[b]` — the final *real* token
//!     when a ragged tail was end-padded with pos-masked scratch tokens
//!     (padded rows are overwritten before any masked read, or dropped
//!     at the `ctx` edge).
//!
//!   `max_chunk()` reports the largest compiled chunk and
//!   [`DecodeBackend::plan_chunk`] buckets each prompt run down to a
//!   compiled size, so prompts drain through the chunk family (several
//!   dispatches per step for runs past the largest chunk) and fall back
//!   to per-token decode dispatch when no prefill artifact exists.
//!   Weights are optionally staged as device-resident buffers; the
//!   non-resident path hands them to the runtime by reference, so
//!   neither path copies weights per step.
//! * [`NativeBackend`] — the pure-Rust engine with one contiguous
//!   [`KvCache`] per slot: every step advances the whole active set
//!   through each layer together, so quantized weights stream once per
//!   step regardless of how many prompt positions ride along.
//! * [`PagedNativeBackend`] — the same engine over the paged KV cache
//!   (`kv::PagedKv`): block tables, prefix sharing, and dynamic
//!   capacity; prefill chunks append whole block runs at a time.
//!
//! ## Admission / preemption contract (paged backends)
//!
//! Capacity is dynamic: [`DecodeBackend::admit`] may refuse a request
//! (`None`) while the block pool is full — the scheduler keeps it queued
//! in FIFO order and retries each round. An admit may also report `k`
//! prompt positions already covered by shared prefix blocks; the
//! scheduler skips feeding those tokens (`k` is always less than the
//! prompt length so the final prompt token still produces first-token
//! logits). Before every step the scheduler calls
//! [`DecodeBackend::pre_step`] with the per-slot position counts it
//! plans to feed; a backend that ran out of blocks preempts its
//! youngest-admitted slots there, and the scheduler requeues the victims
//! at the front of the queue with their generated tokens folded into the
//! replay prompt (recompute-style preemption — the position-keyed
//! sampler draws make the final output identical even for sampled
//! requests). Finished and cancelled slots are returned with
//! [`DecodeBackend::release_slot`]; their shared blocks stay cached for
//! future prefix hits. A request that can never fit in the pool
//! (admission keeps refusing with an idle backend, or every admit is
//! immediately preempted) is rejected rather than wedging the batch: it
//! completes with [`FinishReason::Rejected`] carrying whatever it
//! generated so far (usually nothing).
//!
//! ## Load-adaptive precision (any-precision backends)
//!
//! When the backend serves a nested any-precision model
//! ([`AnyPrecBackend`] over `quant::anyprec::BitPlaneStore`s), the
//! scheduler can trade a little accuracy for queue drain under load via
//! a [`PrecisionPolicy`] in [`ServeOptions`]: `Fixed(w)` pins every
//! admission to `w` bits; `Auto` degrades **new admissions** to the low
//! width once queue depth crosses `degrade_depth` and restores the high
//! width when it falls back to `restore_depth` (hysteresis, so the
//! policy cannot flap every round). A request's width is pinned at its
//! first admission and survives preemption/re-admission, so every
//! already-admitted stream is unaffected by later switches and each
//! output is a deterministic function of `(request, width)`. Switches
//! and per-width token counts surface in
//! [`ServeMetrics::precision_switches`] / `tokens_by_width` and as
//! `serve.precision_switch` trace instants.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::kv::{
    F32Blocks, KvBlockStore, KvLayout, KvPoolStats, LutBlocks, PagedKv,
};
use crate::model::forward::{
    self, Engine, KvCache, KvSeq, LogitsMode, SeqRefs, StepItem, StepPlan,
    Weights,
};
use crate::model::{LayerWeights, ModelConfig, QuantizedModel, WeightStore};
use crate::runtime::{HostTensor, Manifest, Runtime};

use super::metrics::{rel_ms, FinishCounts, RequestMetrics, ServeMetrics};
use super::speculative::SpecStats;
use crate::obs::hist::Histogram;
use crate::obs::trace;

pub use crate::model::forward::SamplingParams;

// ---------------------------------------------------------------------------
// request lifecycle types
// ---------------------------------------------------------------------------

/// When a request stops generating. The criteria compose: whichever
/// fires first wins and is recorded as the request's [`FinishReason`].
/// Stop tokens/sequences apply to the *generated* stream only — a stop
/// sequence straddling the prompt boundary does not fire.
#[derive(Debug, Clone, Default)]
pub struct StopCriteria {
    /// hard budget on generated tokens (the scheduler additionally
    /// finishes a request when the context window fills)
    pub max_new: usize,
    /// token ids that end generation; the stop token itself is not
    /// included in the output
    pub stop_tokens: Vec<i32>,
    /// token sequences that end generation once one appears at the tail
    /// of the generated stream; the matched sequence is trimmed from
    /// `GenOutcome::tokens`. Streamed `TokenEvent::Token`s are eager, so
    /// they may include tokens the final outcome trims — the outcome is
    /// authoritative.
    pub stop_seqs: Vec<Vec<i32>>,
    /// optional end-of-sequence id ([`ModelConfig::eos`]), treated as an
    /// extra stop token
    pub eos: Option<i32>,
}

impl StopCriteria {
    /// Budget-only criteria — the historical `max_new` behavior.
    pub fn max_tokens(max_new: usize) -> StopCriteria {
        StopCriteria { max_new, ..StopCriteria::default() }
    }

    /// Budget plus the model's EOS token, when the config declares one.
    pub fn for_model(cfg: &ModelConfig, max_new: usize) -> StopCriteria {
        StopCriteria { max_new, eos: cfg.eos, ..StopCriteria::default() }
    }

    pub fn with_stop_tokens(mut self, toks: Vec<i32>) -> StopCriteria {
        self.stop_tokens = toks;
        self
    }

    pub fn with_stop_seq(mut self, seq: Vec<i32>) -> StopCriteria {
        self.stop_seqs.push(seq);
        self
    }

    fn is_stop_token(&self, t: i32) -> bool {
        self.eos == Some(t) || self.stop_tokens.contains(&t)
    }

    /// Longest stop sequence sitting at the tail of `stream ++ [tok]`;
    /// returns its length.
    fn stop_seq_hit(&self, stream: &[i32], tok: i32) -> Option<usize> {
        self.stop_seqs
            .iter()
            .filter(|s| !s.is_empty() && s.len() <= stream.len() + 1)
            .filter(|s| {
                // lint:allow(hot-expect): prior filter dropped empty seqs
                *s.last().expect("nonempty") == tok
                    && stream[stream.len() - (s.len() - 1)..]
                        == s[..s.len() - 1]
            })
            .map(|s| s.len())
            .max()
    }
}

/// Why a request stopped generating.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// `max_new` reached, or the context window filled
    MaxTokens,
    /// a stop token (or the model's EOS) was sampled
    StopToken,
    /// a stop sequence appeared at the tail of the generated stream
    StopSeq,
    /// the submitter cancelled mid-flight (partial tokens are returned)
    Cancelled,
    /// the request can never fit the backend's KV pool, or the cluster
    /// router shed it (load watermark / retry budget exhausted)
    Rejected,
    /// the request's `deadline_ms` elapsed before it finished (partial
    /// tokens are returned, like a cancellation)
    DeadlineExceeded,
}

impl FinishReason {
    pub fn tag(&self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::StopToken => "stop_token",
            FinishReason::StopSeq => "stop_seq",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Rejected => "rejected",
            FinishReason::DeadlineExceeded => "deadline",
        }
    }
}

/// Shared cancellation flag. Clone it out of a [`GenRequest`] (or take
/// the one `server::ServerHandle::submit` returns) and call
/// [`CancelHandle::cancel`] from any thread; the scheduler observes the
/// flag at the next step boundary, finishes the request with
/// [`FinishReason::Cancelled`] (tokens generated so far are delivered),
/// and releases its KV slot.
#[derive(Debug, Clone, Default)]
pub struct CancelHandle(Arc<AtomicBool>);

impl CancelHandle {
    pub fn new() -> CancelHandle {
        CancelHandle(Arc::new(AtomicBool::new(false)))
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// A generation request: prompt plus per-request sampling and stop
/// configs and a cooperative cancellation flag (clones share it).
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub sampling: SamplingParams,
    pub stop: StopCriteria,
    pub cancel: CancelHandle,
    /// when the request entered the system (set by
    /// [`GenRequest::mark_submitted`], e.g. at `ServerHandle::submit`).
    /// Queue delay and TTFT measure from here; unset requests measure
    /// from the serve round's start.
    pub submitted: Option<Instant>,
    /// optional wall-clock budget measured from `submitted` (or the
    /// serve round's start when never stamped). The scheduler checks it
    /// at admission and every step boundary and finishes the request
    /// with [`FinishReason::DeadlineExceeded`], delivering whatever it
    /// generated so far.
    pub deadline_ms: Option<f64>,
    /// load-shedding class: when a cluster router's queue depth crosses
    /// its watermark, requests below its priority cutoff are
    /// fast-rejected instead of queued. Higher is more important;
    /// default 1.
    pub priority: u8,
}

impl GenRequest {
    pub fn new(
        id: u64,
        prompt: Vec<i32>,
        sampling: SamplingParams,
        mut stop: StopCriteria,
    ) -> GenRequest {
        // an empty stop sequence can never match (and historically
        // panicked the matcher) — drop them at the boundary
        stop.stop_seqs.retain(|s| !s.is_empty());
        GenRequest {
            id,
            prompt,
            sampling,
            stop,
            cancel: CancelHandle::new(),
            submitted: None,
            deadline_ms: None,
            priority: 1,
        }
    }

    /// The historical `{id, prompt, max_new}` greedy request — argmax
    /// decoding to the token budget, no stop conditions.
    pub fn greedy(id: u64, prompt: Vec<i32>, max_new: usize) -> GenRequest {
        GenRequest::new(
            id,
            prompt,
            SamplingParams::greedy(),
            StopCriteria::max_tokens(max_new),
        )
    }

    pub fn cancel_handle(&self) -> CancelHandle {
        self.cancel.clone()
    }

    /// Stamp the enqueue time (idempotent — the first stamp wins).
    pub fn mark_submitted(&mut self) {
        if self.submitted.is_none() {
            self.submitted = Some(Instant::now());
        }
    }

    /// Set a wall-clock deadline in milliseconds (see `deadline_ms`).
    pub fn with_deadline_ms(mut self, ms: f64) -> GenRequest {
        self.deadline_ms = Some(ms);
        self
    }

    /// Set the load-shedding priority class (see `priority`).
    pub fn with_priority(mut self, priority: u8) -> GenRequest {
        self.priority = priority;
        self
    }

    /// True once the optional deadline has elapsed. `epoch` is the
    /// fallback basis for requests never stamped by
    /// [`GenRequest::mark_submitted`].
    pub fn deadline_hit(&self, epoch: Instant, now: Instant) -> bool {
        let Some(d) = self.deadline_ms else { return false };
        let basis = self.submitted.unwrap_or(epoch);
        now.checked_duration_since(basis)
            .map(|el| el.as_secs_f64() * 1e3 > d)
            .unwrap_or(false)
    }
}

/// A finished request: everything it generated (stop token excluded,
/// matched stop sequence trimmed) and why it stopped.
#[derive(Debug, Clone)]
pub struct GenOutcome {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
}

/// Incremental serving output. `Token` events stream out of the
/// scheduler as soon as a step produces them — before the request
/// completes — and `Done` is always a request's last event.
#[derive(Debug, Clone)]
pub enum TokenEvent {
    Token { id: u64, tok: i32 },
    Done(GenOutcome),
}

// ---------------------------------------------------------------------------
// sampler stage
// ---------------------------------------------------------------------------

/// What the [`Sampler`] decided for one slot's logits row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SamplerStep {
    /// append `tok` and keep decoding
    Token { tok: i32 },
    /// the request is finished: append `tok` first when set, then trim
    /// `trim` tokens from the tail of the output (matched stop sequence)
    Finish { tok: Option<i32>, why: FinishReason, trim: usize },
}

/// The per-step sampling + stop stage, between the backend's logits rows
/// and the scheduler's slot bookkeeping. Pure: the decision depends only
/// on the request's params, its generated stream so far (whose length is
/// the RNG draw index), and the logits row — never on batch composition —
/// so sampled serving is deterministic under rebatching, preemption, and
/// prefill chunking, and temperature 0 is bitwise the old greedy path.
pub struct Sampler;

impl Sampler {
    pub fn next(
        sampling: &SamplingParams,
        stop: &StopCriteria,
        stream: &[i32],
        logits: &[f32],
    ) -> SamplerStep {
        let tok =
            forward::sample_logits(logits, sampling, stream.len() as u64);
        if stop.is_stop_token(tok) {
            return SamplerStep::Finish {
                tok: None,
                why: FinishReason::StopToken,
                trim: 0,
            };
        }
        if let Some(len) = stop.stop_seq_hit(stream, tok) {
            return SamplerStep::Finish {
                tok: Some(tok),
                why: FinishReason::StopSeq,
                trim: len,
            };
        }
        if stream.len() + 1 >= stop.max_new {
            return SamplerStep::Finish {
                tok: Some(tok),
                why: FinishReason::MaxTokens,
                trim: 0,
            };
        }
        SamplerStep::Token { tok }
    }
}

/// One slot's work for a step: a run of tokens to feed, in ascending
/// slot order. `tokens.len() == 1` is a decode position; longer runs are
/// prefill chunks. `want_logits` is set when the run's last position
/// must produce logits (the final prompt token, or any decode).
#[derive(Debug, Clone)]
pub struct SlotWork {
    pub slot: usize,
    pub tokens: Vec<i32>,
    pub want_logits: bool,
}

pub trait DecodeBackend {
    fn slots(&self) -> usize;
    fn cfg(&self) -> ModelConfig;
    /// Most prompt positions one slot can feed in a single step. The
    /// engine-backed natives take whole chunks; the HLO backend reports
    /// its largest compiled prefill chunk (1 when only decode graphs
    /// exist, so prompts feed per-token).
    fn max_chunk(&self) -> usize {
        1
    }

    /// Positions the scheduler should actually take for a prompting slot
    /// that could feed up to `cap` this step (`cap` already folds in the
    /// remaining prompt, `max_chunk`, and the shared prefill budget).
    /// Backends with fixed compiled chunk sizes bucket down to the
    /// largest compiled size so most dispatches run unpadded; the
    /// default takes everything.
    fn plan_chunk(&self, cap: usize) -> usize {
        cap
    }
    /// Advance the slots in `work` (one entry per active slot, ascending
    /// slot order); returns one logits row per work item (empty when
    /// `want_logits` was false).
    fn step(&mut self, work: &[SlotWork]) -> Result<Vec<Vec<f32>>, String>;
    fn reset_slot(&mut self, slot: usize);
    fn slot_pos(&self, slot: usize) -> usize;
    fn weight_bytes_per_step(&self) -> usize;
    fn kv_bytes_per_step(&self) -> usize;

    /// Admit a request into `slot` before its first step. `Some(k)`
    /// means `k` prompt positions are already cached (prefix hit, always
    /// `< prompt.len()`); the scheduler skips feeding them. `None` means
    /// the backend has no KV capacity right now and the scheduler should
    /// retry later. Static-capacity backends always admit at position 0.
    fn admit(
        &mut self,
        slot: usize,
        prompt: &[i32],
        max_new: usize,
    ) -> Option<usize> {
        let _ = (prompt, max_new);
        self.reset_slot(slot);
        Some(0)
    }

    /// Called before every step with the positions the scheduler plans
    /// to append per slot (`0` = idle this step). Returns the slots the
    /// backend preempted to reclaim KV memory (their state is gone); the
    /// scheduler requeues those requests. Default: none.
    fn pre_step(&mut self, need: &[usize]) -> Vec<usize> {
        let _ = need;
        Vec::new()
    }

    /// Release a slot's KV state once its request finished. Paged
    /// backends return blocks to the pool (shared prefixes stay cached).
    fn release_slot(&mut self, slot: usize) {
        let _ = slot;
    }

    /// Block-pool counters (paged backends only).
    fn pool_stats(&self) -> Option<KvPoolStats> {
        None
    }

    /// Decode widths this backend can pin per slot, ascending (nested
    /// any-precision models). Empty means fixed-width: only
    /// [`PrecisionPolicy::Native`] is valid.
    fn widths(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Pin `slot` to decode at `w` bits for its current residency
    /// (called right after a successful `admit`). No-op on fixed-width
    /// backends; any-precision backends ignore unsupported widths.
    fn set_slot_width(&mut self, slot: usize, w: u8) {
        let _ = (slot, w);
    }

    /// Mark whether `slot`'s request may decode speculatively (called
    /// right after a successful `admit` with the request's greediness —
    /// exact-match draft acceptance needs temperature 0, so sampled
    /// requests explicitly fall back to plain decode). No-op on
    /// non-speculative backends.
    fn set_slot_speculative(&mut self, slot: usize, on: bool) {
        let _ = (slot, on);
    }

    /// Drain the draft tokens the backend committed for `slot` during
    /// the last [`DecodeBackend::step`] (a verified exact-match prefix).
    /// The scheduler appends them — running each through the stop
    /// checks — *before* sampling the returned logits row, which
    /// already reflects these tokens. Default: none.
    fn take_committed(&mut self, slot: usize) -> Vec<i32> {
        let _ = slot;
        Vec::new()
    }

    /// Cumulative speculation counters (speculative backends only).
    fn spec_stats(&self) -> Option<SpecStats> {
        None
    }
}

// ---------------------------------------------------------------------------
// scheduler
// ---------------------------------------------------------------------------

/// Default per-step prefill budget (prompt positions across all slots).
pub const DEFAULT_PREFILL_CHUNK: usize = 128;

/// Default threaded-server micro-batch drain window (`server`).
pub const DEFAULT_SERVE_WINDOW: usize = 16;

/// How the scheduler picks a decode width for new admissions on a
/// backend that serves several nested widths (see the module docs'
/// *Load-adaptive precision* section). The policy only ever applies at
/// admission: an admitted request keeps its width for its whole
/// lifetime, across preemptions, so its output stays deterministic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PrecisionPolicy {
    /// Serve at the backend's native width; no per-slot pinning. The
    /// only valid policy for fixed-width backends.
    #[default]
    Native,
    /// Pin every admission to `w` bits.
    Fixed(u8),
    /// Degrade admissions from `high` to `low` bits while the queue is
    /// deeper than `degrade_depth`; restore once it drains to
    /// `restore_depth` or below. `restore_depth < degrade_depth` gives
    /// the hysteresis band.
    Auto {
        high: u8,
        low: u8,
        degrade_depth: usize,
        restore_depth: usize,
    },
}

impl PrecisionPolicy {
    /// The default auto policy between the two widths (degrade when
    /// more requests wait than fit the backend, restore when nearly
    /// drained).
    pub fn auto(high: u8, low: u8, slots: usize) -> PrecisionPolicy {
        PrecisionPolicy::Auto {
            high,
            low,
            degrade_depth: slots.max(1) * 2,
            restore_depth: 1,
        }
    }
}

/// Scheduling knobs (`--prefill-chunk` / `--serve-window` /
/// `--precision` on the CLI).
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// Prompt positions the scheduler may feed per step, across slots.
    /// Every prompting slot still gets at least one position so it
    /// cannot starve; `1` reproduces the historical per-token prefill.
    pub prefill_chunk: usize,
    /// Most requests the threaded server (`coordinator::server`) drains
    /// into one continuous-batching round.
    pub serve_window: usize,
    /// Admission-width policy for any-precision backends.
    pub precision: PrecisionPolicy,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            serve_window: DEFAULT_SERVE_WINDOW,
            precision: PrecisionPolicy::Native,
        }
    }
}

struct SlotState {
    req: GenRequest,
    /// effective prompt for this residency: original prompt plus any
    /// generated tokens replayed after a preemption
    prompt: Vec<i32>,
    prompt_idx: usize,
    /// the full generated stream across residencies — its length is the
    /// sampler's RNG draw index, so preemption cannot shift draws
    generated: Vec<i32>,
    /// decode width pinned at first admission (0 = backend-native)
    width: u8,
    metrics: RequestMetrics,
}

/// A queued request, possibly carrying generated state from a
/// preemption (replayed as prompt on re-admission).
struct Queued {
    req: GenRequest,
    generated: Vec<i32>,
    /// width pinned at a previous residency (0 = not yet admitted);
    /// preserved so preemption cannot change an output mid-stream
    width: u8,
    metrics: Option<RequestMetrics>,
}

/// Finish a queued (never-admitted or requeued) request without serving
/// it further: deliver whatever it generated with the given reason
/// instead of poisoning the whole serve call.
fn finish_queued(
    q: Queued,
    why: FinishReason,
    epoch: Instant,
    outcomes: &mut Vec<GenOutcome>,
    all_metrics: &mut Vec<RequestMetrics>,
    finish: &mut FinishCounts,
    sink: &mut dyn FnMut(TokenEvent),
) {
    let mut m = q.metrics.unwrap_or(RequestMetrics {
        id: q.req.id,
        prompt_tokens: q.req.prompt.len(),
        generated_tokens: q.generated.len(),
        enqueued_ms: rel_ms(epoch, q.req.submitted.unwrap_or(epoch)),
        admitted_ms: None,
        first_token_ms: None,
        finished_ms: None,
    });
    m.finished_ms = Some(rel_ms(epoch, Instant::now()));
    finish.bump(why);
    let out = GenOutcome { id: q.req.id, tokens: q.generated, finish: why };
    sink(TokenEvent::Done(out.clone()));
    outcomes.push(out);
    all_metrics.push(m);
}

/// Serve a batch of requests to completion with continuous batching and
/// the default options.
pub fn serve(
    backend: &mut dyn DecodeBackend,
    requests: Vec<GenRequest>,
) -> Result<(Vec<GenOutcome>, ServeMetrics), String> {
    serve_with(backend, requests, ServeOptions::default())
}

/// Serve a batch of requests to completion with continuous batching.
pub fn serve_with(
    backend: &mut dyn DecodeBackend,
    requests: Vec<GenRequest>,
    opts: ServeOptions,
) -> Result<(Vec<GenOutcome>, ServeMetrics), String> {
    serve_events(backend, requests, opts, &mut |_| {})
}

/// [`serve_with`] with incremental delivery: `sink` observes every
/// [`TokenEvent`] as the scheduler produces it — `Token`s as soon as
/// their step completes (i.e. while the request is still decoding) and
/// one final `Done` per request. The returned outcomes duplicate the
/// `Done` payloads, sorted by request id.
pub fn serve_events(
    backend: &mut dyn DecodeBackend,
    requests: Vec<GenRequest>,
    opts: ServeOptions,
    sink: &mut dyn FnMut(TokenEvent),
) -> Result<(Vec<GenOutcome>, ServeMetrics), String> {
    let nslots = backend.slots();
    let ctx = backend.cfg().ctx;
    let max_chunk = backend.max_chunk().max(1);
    // speculation counters are cumulative on the backend (which may be
    // reused across server rounds); this serve reports only its delta
    let spec_base = backend.spec_stats().unwrap_or_default();
    // serve epoch: every RequestMetrics offset is relative to this
    let t_start = Instant::now();
    let total_reqs = requests.len();
    let mut queue: std::collections::VecDeque<Queued> = requests
        .into_iter()
        .map(|mut r| {
            // left-truncate prompts that cannot fit with generation room
            let budget = ctx
                .saturating_sub(r.stop.max_new.saturating_add(1))
                .max(1);
            if r.prompt.len() > budget {
                r.prompt = r.prompt[r.prompt.len() - budget..].to_vec();
            }
            Queued { req: r, generated: Vec::new(), width: 0, metrics: None }
        })
        .collect();
    let mut slots: Vec<Option<SlotState>> =
        (0..nslots).map(|_| None).collect();
    let mut outcomes = Vec::new();
    let mut all_metrics = Vec::new();
    let mut finish = FinishCounts::default();
    let mut cancelled_tokens = 0usize;
    let mut steps = 0usize;
    let mut prompt_positions = 0usize;
    let mut preemptions = 0usize;
    let mut peak_concurrency = 0usize;
    let mut stalls = 0usize;
    let mut step_ms = Histogram::new();
    let mut kv_occupancy = Histogram::new();

    // resolve the admission-width policy against the backend up front so
    // a misconfigured serve fails loudly instead of silently pinning
    // widths a backend ignores
    let policy = opts.precision;
    let bwidths = backend.widths();
    let mut cur_width: u8 = match policy {
        PrecisionPolicy::Native => 0,
        PrecisionPolicy::Fixed(w) => {
            if !bwidths.contains(&w) {
                return Err(format!(
                    "precision policy wants {}-bit but the backend serves \
                     {:?}",
                    w, bwidths
                ));
            }
            w
        }
        PrecisionPolicy::Auto { high, low, degrade_depth, restore_depth } => {
            for w in [high, low] {
                if !bwidths.contains(&w) {
                    return Err(format!(
                        "precision policy wants {}-bit but the backend \
                         serves {:?}",
                        w, bwidths
                    ));
                }
            }
            if low >= high || restore_depth >= degrade_depth {
                return Err(format!(
                    "auto precision needs low < high and restore_depth < \
                     degrade_depth, got {:?}",
                    policy
                ));
            }
            high
        }
    };
    let mut precision_switches = 0usize;
    let mut tokens_by_width: BTreeMap<u8, u64> = BTreeMap::new();

    // finish an active slot: release its KV, trim the output, emit Done
    macro_rules! finish_slot {
        ($si:expr, $why:expr, $trim:expr) => {{
            // lint:allow(hot-expect): only invoked on slots the caller
            // just observed as occupied (scan/step loops above each site)
            let st = slots[$si].take().expect("finished slot occupied");
            backend.release_slot($si);
            let why: FinishReason = $why;
            let mut m = st.metrics;
            m.generated_tokens = st.generated.len();
            m.finished_ms = Some(rel_ms(t_start, Instant::now()));
            finish.bump(why);
            if why == FinishReason::Cancelled {
                cancelled_tokens += st.generated.len();
            }
            let mut tokens = st.generated;
            let keep = tokens.len().saturating_sub($trim);
            tokens.truncate(keep);
            let out = GenOutcome { id: st.req.id, tokens, finish: why };
            sink(TokenEvent::Done(out.clone()));
            outcomes.push(out);
            all_metrics.push(m);
        }};
    }

    loop {
        // step boundary: observe cancellations and expired deadlines
        // first. Active slots hand their KV back right here (with
        // partial output); queued requests finish without ever being
        // admitted — which is also what enforces deadlines at admission.
        let t_scan = Instant::now();
        for si in 0..nslots {
            let verdict = slots[si].as_ref().and_then(|st| {
                if st.req.cancel.is_cancelled() {
                    Some(FinishReason::Cancelled)
                } else if st.req.deadline_hit(t_start, t_scan) {
                    Some(FinishReason::DeadlineExceeded)
                } else {
                    None
                }
            });
            if let Some(why) = verdict {
                finish_slot!(si, why, 0);
            }
        }
        for _ in 0..queue.len() {
            // lint:allow(hot-expect): the loop pops at most len() items
            let q = queue.pop_front().expect("iterating queue length");
            let why = if q.req.cancel.is_cancelled() {
                cancelled_tokens += q.generated.len();
                Some(FinishReason::Cancelled)
            } else if q.req.deadline_hit(t_start, t_scan) {
                Some(FinishReason::DeadlineExceeded)
            } else {
                None
            };
            match why {
                Some(why) => finish_queued(
                    q,
                    why,
                    t_start,
                    &mut outcomes,
                    &mut all_metrics,
                    &mut finish,
                    sink,
                ),
                None => queue.push_back(q),
            }
        }

        // precision hysteresis: pick this round's admission width from
        // the queue depth BEFORE admitting, so the requests admitted
        // this round already see the updated width
        if let PrecisionPolicy::Auto {
            high,
            low,
            degrade_depth,
            restore_depth,
        } = policy
        {
            let depth = queue.len();
            let want = if cur_width == high {
                if depth >= degrade_depth {
                    low
                } else {
                    high
                }
            } else if depth <= restore_depth {
                high
            } else {
                low
            };
            if want != cur_width {
                cur_width = want;
                precision_switches += 1;
                trace::instant(
                    "serve.precision_switch",
                    &[("width", want as f64), ("depth", depth as f64)],
                );
            }
        }

        // admit in FIFO order; a paged backend may refuse (pool full)
        let mut admitted_n = 0usize;
        let mut prefix_skipped = 0usize;
        for si in 0..nslots {
            if slots[si].is_some() {
                continue;
            }
            let Some(q) = queue.front() else { break };
            let prompt: Vec<i32> = q
                .req
                .prompt
                .iter()
                .chain(q.generated.iter())
                .copied()
                .collect();
            let max_new =
                q.req.stop.max_new.saturating_sub(q.generated.len());
            match backend.admit(si, &prompt, max_new) {
                Some(cached) => {
                    debug_assert!(
                        cached < prompt.len().max(1),
                        "prefix hit must leave the last prompt token"
                    );
                    // lint:allow(hot-expect): queue.front() was Some or
                    // the admit loop broke out above
                    let q = queue.pop_front().expect("front checked");
                    let mut metrics =
                        q.metrics.unwrap_or(RequestMetrics {
                            id: q.req.id,
                            prompt_tokens: q.req.prompt.len(),
                            generated_tokens: q.generated.len(),
                            enqueued_ms: rel_ms(
                                t_start,
                                q.req.submitted.unwrap_or(t_start),
                            ),
                            admitted_ms: None,
                            first_token_ms: None,
                            finished_ms: None,
                        });
                    // first admission only — a preempted request keeps
                    // its original queue-delay measurement
                    if metrics.admitted_ms.is_none() {
                        metrics.admitted_ms =
                            Some(rel_ms(t_start, Instant::now()));
                    }
                    admitted_n += 1;
                    prefix_skipped += cached;
                    // first admission picks up the round's width; a
                    // re-admitted preemption victim keeps its pin
                    let width =
                        if q.width != 0 { q.width } else { cur_width };
                    if width != 0 {
                        backend.set_slot_width(si, width);
                    }
                    backend
                        .set_slot_speculative(si, q.req.sampling.is_greedy());
                    slots[si] = Some(SlotState {
                        req: q.req,
                        prompt,
                        prompt_idx: cached,
                        generated: q.generated,
                        width,
                        metrics,
                    });
                }
                None => break,
            }
        }
        if admitted_n > 0 {
            trace::instant(
                "sched.admit",
                &[
                    ("n", admitted_n as f64),
                    ("prefix_skipped", prefix_skipped as f64),
                ],
            );
        }
        if slots.iter().all(|s| s.is_none()) {
            if queue.is_empty() {
                break;
            }
            // the front request cannot be admitted into an idle backend;
            // give the rest of the queue a turn, and once everyone has
            // had one (a full rotation) reject the front as unserveable
            stalls += 1;
            if stalls > queue.len() + 1 {
                // lint:allow(hot-expect): the is_empty branch above broke
                // out of the serve loop
                let q = queue.pop_front().expect("queue nonempty");
                trace::instant("sched.reject", &[("id", q.req.id as f64)]);
                finish_queued(
                    q,
                    FinishReason::Rejected,
                    t_start,
                    &mut outcomes,
                    &mut all_metrics,
                    &mut finish,
                    sink,
                );
                stalls = 0;
            } else {
                queue.rotate_left(1);
            }
            continue;
        }

        // plan the step: positions to append per slot. Prompting slots
        // take a chunk of up to max_chunk positions from the shared
        // prefill budget (never less than one — progress is guaranteed);
        // decoding slots always take their single position.
        let mut need = vec![0usize; nslots];
        let mut budget = opts.prefill_chunk;
        {
            let _sp = trace::span("sched.plan");
            for (si, slot) in slots.iter().enumerate() {
                let Some(st) = slot else { continue };
                if st.prompt_idx < st.prompt.len() {
                    let remaining = st.prompt.len() - st.prompt_idx;
                    let cap = remaining.min(max_chunk).min(budget.max(1));
                    let take = backend.plan_chunk(cap).clamp(1, cap);
                    budget = budget.saturating_sub(take);
                    need[si] = take;
                    trace::instant(
                        "sched.chunk",
                        &[("slot", si as f64), ("take", take as f64)],
                    );
                } else {
                    need[si] = 1;
                }
            }
        }

        // let the backend reclaim KV memory; requeue its victims with
        // their generated tokens folded into the replay prompt
        for vi in backend.pre_step(&need) {
            // lint:allow(hot-expect): backends only preempt slots the
            // need[] vector marked active this step
            let st = slots[vi].take().expect("victim slot was active");
            need[vi] = 0;
            preemptions += 1;
            trace::instant(
                "sched.preempt",
                &[("slot", vi as f64), ("id", st.req.id as f64)],
            );
            let mut m = st.metrics;
            m.generated_tokens = st.generated.len();
            queue.push_front(Queued {
                req: st.req,
                generated: st.generated,
                width: st.width,
                metrics: Some(m),
            });
        }
        if need.iter().all(|&n| n == 0) {
            // every admitted slot was immediately preempted: if this
            // persists, the front request (the requeued victim) cannot
            // fit in the pool at all — reject it and move on
            stalls += 1;
            if stalls > total_reqs + 2 {
                if let Some(q) = queue.pop_front() {
                    trace::instant(
                        "sched.reject",
                        &[("id", q.req.id as f64)],
                    );
                    finish_queued(
                        q,
                        FinishReason::Rejected,
                        t_start,
                        &mut outcomes,
                        &mut all_metrics,
                        &mut finish,
                        sink,
                    );
                }
                stalls = 0;
            }
            continue;
        }
        stalls = 0;

        // build the work list (ascending slot order)
        let mut work: Vec<SlotWork> = Vec::new();
        for (si, slot) in slots.iter().enumerate() {
            if need[si] == 0 {
                continue;
            }
            // lint:allow(hot-expect): need[si] > 0 is only ever set for
            // occupied slots (computed from slots[] two loops up)
            let st = slot.as_ref().expect("need only set for occupied slots");
            if st.prompt_idx < st.prompt.len() {
                let take = need[si];
                let tokens =
                    st.prompt[st.prompt_idx..st.prompt_idx + take].to_vec();
                let want = st.prompt_idx + take >= st.prompt.len();
                prompt_positions += take;
                work.push(SlotWork { slot: si, tokens, want_logits: want });
            } else {
                // lint:allow(hot-expect): past the prompt ⇒ at least the
                // first generated token exists to feed back
                let t = *st.generated.last().expect("generated nonempty");
                work.push(SlotWork {
                    slot: si,
                    tokens: vec![t],
                    want_logits: true,
                });
            }
        }

        let t_step = Instant::now();
        let logits = {
            let _sp = trace::span("backend.step");
            backend.step(&work)?
        };
        step_ms.record(t_step.elapsed().as_secs_f64() * 1e3);
        debug_assert_eq!(logits.len(), work.len());
        steps += 1;
        peak_concurrency = peak_concurrency.max(work.len());
        if trace::enabled() {
            trace::counter("sched.active", work.len() as f64);
            trace::counter("sched.queue", queue.len() as f64);
        }
        if let Some(st) = backend.pool_stats() {
            if st.blocks_total > 0 {
                let occ = st.blocks_in_use as f64 / st.blocks_total as f64;
                kv_occupancy.record(occ);
                trace::counter("kv.occupancy", occ);
            }
        }

        // consume outputs: the sampler stage turns each logits row into
        // the next token (or a finish decision) per the slot's params
        let _sp_sample = trace::span("sched.sample");
        for (wi, wk) in work.iter().enumerate() {
            let si = wk.slot;
            let mut done: Option<(FinishReason, usize)> = None;
            {
                // lint:allow(hot-expect): work was built from occupied
                // slots this same step; nothing vacated them since
                let st = slots[si].as_mut().expect("worked slot occupied");
                if st.prompt_idx < st.prompt.len() {
                    st.prompt_idx += wk.tokens.len();
                }
                if wk.want_logits
                    && st.generated.len() >= st.req.stop.max_new
                {
                    // an exhausted budget (max_new == 0) never samples —
                    // the same outcome the mid-prompt branch below
                    // produces, so output cannot depend on chunking
                    done = Some((FinishReason::MaxTokens, 0));
                } else if wk.want_logits {
                    let mut push = |st: &mut SlotState, tok: i32| {
                        st.generated.push(tok);
                        st.metrics.generated_tokens = st.generated.len();
                        if st.metrics.first_token_ms.is_none() {
                            st.metrics.first_token_ms =
                                Some(rel_ms(t_start, Instant::now()));
                        }
                        if st.width != 0 {
                            *tokens_by_width
                                .entry(st.width)
                                .or_insert(0) += 1;
                        }
                        sink(TokenEvent::Token { id: st.req.id, tok });
                    };
                    // a speculative backend may have committed verified
                    // draft tokens during this step; fold each through
                    // the same stop checks the sampler applies, in the
                    // same order, before sampling the returned row
                    // (which already reflects these tokens)
                    for tok in backend.take_committed(si) {
                        if done.is_some() {
                            break;
                        }
                        if st.req.stop.is_stop_token(tok) {
                            done = Some((FinishReason::StopToken, 0));
                        } else if let Some(trim) =
                            st.req.stop.stop_seq_hit(&st.generated, tok)
                        {
                            push(st, tok);
                            done = Some((FinishReason::StopSeq, trim));
                        } else {
                            push(st, tok);
                            if st.generated.len() >= st.req.stop.max_new {
                                done = Some((FinishReason::MaxTokens, 0));
                            }
                        }
                    }
                    if done.is_none() {
                        match Sampler::next(
                            &st.req.sampling,
                            &st.req.stop,
                            &st.generated,
                            &logits[wi],
                        ) {
                            SamplerStep::Token { tok } => {
                                push(st, tok);
                                if backend.slot_pos(si) + 1 >= ctx {
                                    done =
                                        Some((FinishReason::MaxTokens, 0));
                                }
                            }
                            SamplerStep::Finish { tok, why, trim } => {
                                if let Some(t) = tok {
                                    push(st, t);
                                }
                                done = Some((why, trim));
                            }
                        }
                    }
                } else if st.generated.len() >= st.req.stop.max_new
                    || backend.slot_pos(si) + 1 >= ctx
                {
                    // degenerate budgets (max_new == 0) or a context
                    // window exhausted mid-prompt
                    done = Some((FinishReason::MaxTokens, 0));
                }
            }
            if let Some((why, trim)) = done {
                finish_slot!(si, why, trim);
            }
        }
    }

    let spec = backend
        .spec_stats()
        .unwrap_or_default()
        .delta_since(&spec_base);
    let metrics = ServeMetrics {
        requests: all_metrics,
        decode_steps: steps,
        prompt_positions,
        wall_s: t_start.elapsed().as_secs_f64(),
        weight_bytes_per_step: backend.weight_bytes_per_step(),
        kv_bytes_per_step: backend.kv_bytes_per_step(),
        preemptions,
        finish,
        cancelled_tokens,
        peak_concurrency,
        precision_switches,
        tokens_by_width,
        draft_tokens: spec.draft_tokens,
        accepted_tokens: spec.accepted_tokens,
        rollback_tokens: spec.rollback_tokens,
        spec_rounds: spec.rounds,
        kv: backend.pool_stats(),
        step_ms,
        kv_occupancy,
    };
    outcomes.sort_by_key(|r| r.id);
    Ok((outcomes, metrics))
}

/// Map a slot-ordered work list onto engine step items (`seq` = index
/// within the work list) — shared by both native backends.
fn plan_from_work(work: &[SlotWork]) -> StepPlan {
    debug_assert!(
        // bound: windows(2) yields exactly two elements per window
        work.windows(2).all(|w| w[0].slot < w[1].slot),
        "work must be in ascending slot order"
    );
    StepPlan {
        items: work
            .iter()
            .enumerate()
            .map(|(i, wk)| StepItem {
                seq: i,
                tokens: wk.tokens.clone(),
                logits: if wk.want_logits {
                    LogitsMode::Last
                } else {
                    LogitsMode::None
                },
            })
            .collect(),
    }
}

// ---------------------------------------------------------------------------
// native backend
// ---------------------------------------------------------------------------

pub struct NativeBackend<'a> {
    engine: Engine<'a>,
    caches: Vec<KvCache>,
}

impl<'a> NativeBackend<'a> {
    pub fn new(w: Weights<'a>, slots: usize) -> NativeBackend<'a> {
        let cfg = w.store().cfg;
        NativeBackend {
            engine: Engine::new(&w),
            caches: (0..slots).map(|_| KvCache::new(cfg)).collect(),
        }
    }
}

impl DecodeBackend for NativeBackend<'_> {
    fn slots(&self) -> usize {
        self.caches.len()
    }

    fn cfg(&self) -> ModelConfig {
        self.engine.cfg()
    }

    fn max_chunk(&self) -> usize {
        usize::MAX
    }

    fn step(&mut self, work: &[SlotWork]) -> Result<Vec<Vec<f32>>, String> {
        // one engine step over the whole active set: each linear's
        // weights stream once regardless of slots or chunk lengths
        let plan = plan_from_work(work);
        let mut active = vec![false; self.caches.len()];
        for wk in work {
            active[wk.slot] = true;
        }
        let mut refs: Vec<&mut dyn KvSeq> = self
            .caches
            .iter_mut()
            .enumerate()
            .filter(|(si, _)| active[*si])
            .map(|(_, c)| c as &mut dyn KvSeq)
            .collect();
        let outs = self.engine.step(&plan, &mut SeqRefs(&mut refs));
        Ok(outs.into_iter().map(|m| m.data).collect())
    }

    fn reset_slot(&mut self, slot: usize) {
        self.caches[slot] = KvCache::new(self.cfg());
    }

    fn slot_pos(&self, slot: usize) -> usize {
        self.caches[slot].len
    }

    fn weight_bytes_per_step(&self) -> usize {
        // the engine's resolved plan is the ground truth for what
        // actually streams (packed codes, dense fallbacks, outliers)
        self.engine.weight_bytes_per_step()
    }

    fn kv_bytes_per_step(&self) -> usize {
        let c = self.cfg();
        // read whole cache + write one position, per layer, K and V
        c.layers * c.heads * c.ctx * c.head_dim() * 4 * 2
    }
}

// ---------------------------------------------------------------------------
// any-precision backend
// ---------------------------------------------------------------------------

/// Native serving over one nested any-precision artifact
/// (`quant::anyprec::BitPlaneStore` linears): each supported width gets
/// its own [`Engine`] resolved at that width, all borrowing the same
/// resident weights — the bit-planes are stored once, only the per-width
/// codebooks differ. Slots are pinned to a width at admission
/// ([`DecodeBackend::set_slot_width`]); a step partitions its work by
/// slot width and advances each group through its engine, so mixed-width
/// batches stream the shared planes once per width present in the batch.
pub struct AnyPrecBackend<'a> {
    /// `(width, engine-at-width)`, ascending width
    engines: Vec<(u8, Engine<'a>)>,
    caches: Vec<KvCache>,
    /// current decode width per slot
    slot_w: Vec<u8>,
    /// max nested width — what fresh slots decode at
    default_w: u8,
}

impl<'a> AnyPrecBackend<'a> {
    /// Build over a quantized model whose every linear is a nested
    /// [`crate::model::LayerWeights::AnyPrec`] store (see
    /// `coordinator::pipeline::quantize_model_anyprec`).
    pub fn new(
        qm: &'a QuantizedModel,
        slots: usize,
    ) -> Result<AnyPrecBackend<'a>, String> {
        let widths = qm.anyprec_widths();
        if widths.is_empty() {
            return Err(
                "model has no nested any-precision linears (quantize \
                 with --widths 2,3,4)"
                    .into(),
            );
        }
        let cfg = qm.base.cfg;
        let w = Weights::Quant(qm);
        let engines: Vec<(u8, Engine<'a>)> = widths
            .iter()
            .map(|&wd| (wd, Engine::new_at(&w, Some(wd))))
            .collect();
        // lint:allow(hot-expect): the is_empty check above returned Err
        let default_w = *widths.last().expect("nonempty widths");
        Ok(AnyPrecBackend {
            engines,
            caches: (0..slots).map(|_| KvCache::new(cfg)).collect(),
            slot_w: vec![default_w; slots],
            default_w,
        })
    }
}

impl DecodeBackend for AnyPrecBackend<'_> {
    fn slots(&self) -> usize {
        self.caches.len()
    }

    fn cfg(&self) -> ModelConfig {
        // bound: construction guarantees at least one engine
        self.engines[0].1.cfg()
    }

    fn max_chunk(&self) -> usize {
        usize::MAX
    }

    fn step(&mut self, work: &[SlotWork]) -> Result<Vec<Vec<f32>>, String> {
        // partition by pinned width: one engine step per width present,
        // each over that width's slots only
        let mut out: Vec<Vec<f32>> = vec![Vec::new(); work.len()];
        let slot_w = &self.slot_w;
        let caches = &mut self.caches;
        for (wd, eng) in self.engines.iter_mut() {
            let idxs: Vec<usize> = work
                .iter()
                .enumerate()
                .filter(|(_, wk)| slot_w[wk.slot] == *wd)
                .map(|(i, _)| i)
                .collect();
            if idxs.is_empty() {
                continue;
            }
            let sub: Vec<SlotWork> =
                idxs.iter().map(|&i| work[i].clone()).collect();
            let plan = plan_from_work(&sub);
            let mut active = vec![false; caches.len()];
            for wk in &sub {
                active[wk.slot] = true;
            }
            let mut refs: Vec<&mut dyn KvSeq> = caches
                .iter_mut()
                .enumerate()
                .filter(|(si, _)| active[*si])
                .map(|(_, c)| c as &mut dyn KvSeq)
                .collect();
            let outs = eng.step(&plan, &mut SeqRefs(&mut refs));
            for (&i, m) in idxs.iter().zip(outs) {
                out[i] = m.data;
            }
        }
        Ok(out)
    }

    fn reset_slot(&mut self, slot: usize) {
        self.caches[slot] = KvCache::new(self.cfg());
        self.slot_w[slot] = self.default_w;
    }

    fn slot_pos(&self, slot: usize) -> usize {
        self.caches[slot].len
    }

    fn weight_bytes_per_step(&self) -> usize {
        // report the widest plan — the conservative (policy-idle) figure
        self.engines
            .last()
            // lint:allow(hot-expect): new() rejects empty width lists
            .expect("nonempty engines")
            .1
            .weight_bytes_per_step()
    }

    fn kv_bytes_per_step(&self) -> usize {
        let c = self.cfg();
        c.layers * c.heads * c.ctx * c.head_dim() * 4 * 2
    }

    fn widths(&self) -> Vec<u8> {
        self.engines.iter().map(|(w, _)| *w).collect()
    }

    fn set_slot_width(&mut self, slot: usize, w: u8) {
        if self.engines.iter().any(|(x, _)| *x == w) {
            self.slot_w[slot] = w;
        }
    }
}

// ---------------------------------------------------------------------------
// paged native backend
// ---------------------------------------------------------------------------

/// Which representation backs the paged KV blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvStoreKind {
    /// dense f32 — bit-exact with the contiguous [`NativeBackend`] path
    F32,
    /// per-(layer, head) 4-bit non-uniform codebooks, fitted on block
    /// fill with the GANQ machinery (~8x more blocks per byte)
    Lut4,
}

/// Native engine over the paged KV cache: dynamic admission (capacity is
/// the block pool, not the slot count), prefix sharing, CoW, LRU prefix
/// caching, and youngest-first preemption.
pub struct PagedNativeBackend<'a> {
    engine: Engine<'a>,
    kv: PagedKv,
}

impl<'a> PagedNativeBackend<'a> {
    /// `slots` bounds concurrency; real capacity is `num_blocks` blocks
    /// of `block_size` positions each.
    pub fn new(
        w: Weights<'a>,
        slots: usize,
        block_size: usize,
        num_blocks: usize,
        kind: KvStoreKind,
    ) -> PagedNativeBackend<'a> {
        let cfg = w.store().cfg;
        let layout = KvLayout::new(&cfg, block_size);
        let store: Box<dyn KvBlockStore> = match kind {
            KvStoreKind::F32 => Box::new(F32Blocks::new(layout, num_blocks)),
            KvStoreKind::Lut4 => {
                Box::new(LutBlocks::new(layout, num_blocks))
            }
        };
        PagedNativeBackend {
            engine: Engine::new(&w),
            kv: PagedKv::new(store, num_blocks, slots),
        }
    }

    /// Size the pool from a KV memory budget in bytes (at least one
    /// block).
    pub fn with_memory_budget(
        w: Weights<'a>,
        slots: usize,
        block_size: usize,
        kind: KvStoreKind,
        budget_bytes: usize,
    ) -> PagedNativeBackend<'a> {
        let layout = KvLayout::new(&w.store().cfg, block_size);
        let bpb = match kind {
            KvStoreKind::F32 => F32Blocks::bytes_per_block_for(layout),
            KvStoreKind::Lut4 => LutBlocks::bytes_per_block_for(layout),
        };
        let num_blocks = (budget_bytes / bpb).max(1);
        PagedNativeBackend::new(w, slots, block_size, num_blocks, kind)
    }

    pub fn kv(&self) -> &PagedKv {
        &self.kv
    }

    /// Mutable pool handle for auditor control ([`PagedKv::set_audit`])
    /// and fault injection in tests.
    pub fn kv_mut(&mut self) -> &mut PagedKv {
        &mut self.kv
    }
}

impl DecodeBackend for PagedNativeBackend<'_> {
    fn slots(&self) -> usize {
        self.kv.num_slots()
    }

    fn cfg(&self) -> ModelConfig {
        self.engine.cfg()
    }

    fn max_chunk(&self) -> usize {
        usize::MAX
    }

    fn step(&mut self, work: &[SlotWork]) -> Result<Vec<Vec<f32>>, String> {
        // one engine step over the admitted set; slot views are handed
        // to the engine one at a time (they alias the shared block pool)
        for wk in work {
            self.kv.push_tokens(wk.slot, &wk.tokens);
        }
        let plan = plan_from_work(work);
        let slots: Vec<usize> = work.iter().map(|wk| wk.slot).collect();
        let mut seqs = self.kv.seqs(slots);
        let outs = self.engine.step(&plan, &mut seqs);
        // step boundary: sweep the pool invariants (debug builds and
        // GANQ_AUDIT=1 serving; one boolean test otherwise)
        self.kv.maybe_audit();
        Ok(outs.into_iter().map(|m| m.data).collect())
    }

    fn reset_slot(&mut self, slot: usize) {
        self.kv.release(slot);
    }

    fn slot_pos(&self, slot: usize) -> usize {
        self.kv.pos(slot)
    }

    fn weight_bytes_per_step(&self) -> usize {
        self.engine.weight_bytes_per_step()
    }

    fn kv_bytes_per_step(&self) -> usize {
        // peak resident block bytes — the paged analogue of the
        // contiguous backends' ctx-sized per-slot caches (sampled at end
        // of run, when current occupancy is just prefix-cache residue)
        self.kv.bytes_per_block() * self.kv.stats().peak_blocks_in_use
    }

    fn admit(
        &mut self,
        slot: usize,
        prompt: &[i32],
        max_new: usize,
    ) -> Option<usize> {
        self.kv.release(slot);
        self.kv.admit(slot, prompt, max_new)
    }

    fn pre_step(&mut self, need: &[usize]) -> Vec<usize> {
        let victims = self.kv.prepare_step_n(need);
        // preemption/eviction just moved references around — audit the
        // pool before the engine writes through the new tables
        self.kv.maybe_audit();
        victims
    }

    fn release_slot(&mut self, slot: usize) {
        self.kv.release(slot);
    }

    fn pool_stats(&self) -> Option<KvPoolStats> {
        Some(self.kv.stats())
    }
}

// ---------------------------------------------------------------------------
// HLO backend
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightFmt {
    Fp32,
    Lut4,
    Lut3,
}

impl WeightFmt {
    pub fn tag(&self) -> &'static str {
        match self {
            WeightFmt::Fp32 => "fp32",
            WeightFmt::Lut4 => "lut4",
            WeightFmt::Lut3 => "lut3",
        }
    }

    pub fn bits(&self) -> u8 {
        match self {
            WeightFmt::Fp32 => 32,
            WeightFmt::Lut4 => 4,
            WeightFmt::Lut3 => 3,
        }
    }
}

/// Weight argument list for the LUT serving graphs (lut_param_spec order):
/// quantizable linears as (qp u8 [m, n/2], t f32 [m, 2^bits]).
pub fn weight_tensors_lut(
    cfg: &ModelConfig,
    qm: &QuantizedModel,
    bits: u8,
) -> Result<Vec<HostTensor>, String> {
    let k = 1usize << bits;
    let quant_names: std::collections::BTreeSet<String> = cfg
        .linear_shapes()
        .into_iter()
        .map(|(n, _, _)| n)
        .collect();
    let mut out = Vec::new();
    for (name, shape) in cfg.param_spec() {
        if quant_names.contains(&name) {
            let lut = match qm.linears.get(&name) {
                Some(LayerWeights::Lut(l)) => l,
                Some(LayerWeights::LutSparse(..)) => {
                    return Err(format!(
                        "{}: dense+sparse models (GANQ*/SqueezeLLM) need \
                         the sparse branch — serve via NativeBackend",
                        name
                    ))
                }
                Some(LayerWeights::AnyPrec(_)) => {
                    return Err(format!(
                        "{}: nested any-precision models serve via \
                         AnyPrecBackend (--precision), not the AOT LUT \
                         graphs",
                        name
                    ))
                }
                _ => {
                    return Err(format!(
                        "{} has no LUT form (method {})",
                        name, qm.method
                    ))
                }
            };
            if lut.bits != bits {
                return Err(format!(
                    "{}: lut bits {} != graph bits {}",
                    name, lut.bits, bits
                ));
            }
            // bound: linear weight shapes are validated 2-D above
            let (m, n) = (shape[0], shape[1]);
            out.push(HostTensor::U8(
                vec![m, n.div_ceil(2)],
                lut.packed_nibbles(),
            ));
            out.push(HostTensor::F32(vec![m, k], lut.codebook.data.clone()));
        } else {
            let t = qm.base.get(&name);
            out.push(HostTensor::F32(t.shape.clone(), t.data.clone()));
        }
    }
    Ok(out)
}

pub struct HloBackend<'a> {
    rt: &'a Runtime,
    graph: String,
    /// compiled positioned-prefill graphs, ascending `(chunk, name)`;
    /// empty means prompts feed per-token through the decode graph
    prefill: Vec<(usize, String)>,
    cfg: ModelConfig,
    b: usize,
    kcache: HostTensor,
    vcache: HostTensor,
    pos: Vec<usize>,
    weights: Vec<HostTensor>,
    resident: Option<Vec<xla::PjRtBuffer>>,
    weight_bytes: usize,
}

impl<'a> HloBackend<'a> {
    /// Build for `decode_{fmt}_{model}_b{B}`, discovering every compiled
    /// `prefill_{fmt}_{model}_b{B}_c{C}` chunk alongside it (prompts feed
    /// per-token when none exist). `resident` stages weights as device
    /// buffers once (the optimized path).
    pub fn new(
        rt: &'a Runtime,
        model: &str,
        fmt: WeightFmt,
        b: usize,
        store: &WeightStore,
        qm: Option<&QuantizedModel>,
        resident: bool,
    ) -> Result<HloBackend<'a>, String> {
        let entry = rt
            .manifest
            .models
            .get(model)
            .ok_or_else(|| format!("unknown model {}", model))?;
        let cfg = entry.config;
        let graph =
            format!("decode_{}_{}_b{}", fmt.tag(), entry.base_config, b);
        if !rt.has_graph(&graph) {
            return Err(format!("graph {} not in artifacts", graph));
        }
        let prefill: Vec<(usize, String)> = rt
            .manifest
            .prefill_chunks(fmt.tag(), &entry.base_config, b)
            .into_iter()
            .map(|c| {
                (
                    c,
                    Manifest::prefill_graph(
                        fmt.tag(),
                        &entry.base_config,
                        b,
                        c,
                    ),
                )
            })
            .collect();
        let weights = match fmt {
            WeightFmt::Fp32 => {
                crate::eval::weight_tensors_fp32(&cfg, store, qm)
            }
            WeightFmt::Lut4 | WeightFmt::Lut3 => weight_tensors_lut(
                &cfg,
                qm.ok_or("LUT format requires a quantized model")?,
                fmt.bits(),
            )?,
        };
        let weight_bytes = match (fmt, qm) {
            (WeightFmt::Fp32, _) => cfg
                .linear_shapes()
                .iter()
                .map(|(_, m, n)| m * n * 4)
                .sum(),
            (_, Some(q)) => q
                .linears
                .values()
                .map(|lw| match lw {
                    LayerWeights::Lut(l) => l.bytes_per_decode(),
                    LayerWeights::LutSparse(l, s) => {
                        l.bytes_per_decode() + s.storage_bytes()
                    }
                    LayerWeights::Dense(m) => m.data.len() * 4,
                    LayerWeights::AnyPrec(b) => {
                        b.bytes_per_decode(b.max_bits)
                    }
                })
                .sum(),
            _ => 0,
        };
        let cache_dims = vec![
            cfg.layers,
            b,
            cfg.heads,
            cfg.ctx,
            cfg.head_dim(),
        ];
        let cache_len: usize = cache_dims.iter().product();
        let resident_bufs = if resident {
            Some(rt.stage(&weights)?)
        } else {
            None
        };
        Ok(HloBackend {
            rt,
            graph,
            prefill,
            cfg,
            b,
            kcache: HostTensor::F32(cache_dims.clone(), vec![0.0; cache_len]),
            vcache: HostTensor::F32(cache_dims, vec![0.0; cache_len]),
            pos: vec![0; b],
            weights,
            resident: resident_bufs,
            weight_bytes,
        })
    }
}

impl<'a> HloBackend<'a> {
    /// Variant constructor with an explicit graph name (used by the
    /// pallas-kernel serving graph, which shares the lut4 signature).
    pub fn new_with_graph(
        rt: &'a Runtime,
        model: &str,
        graph: &str,
        b: usize,
        store: &WeightStore,
        qm: Option<&QuantizedModel>,
    ) -> Result<HloBackend<'a>, String> {
        let mut be =
            HloBackend::new(rt, model, WeightFmt::Lut4, b, store, qm, false)?;
        if !rt.has_graph(graph) {
            return Err(format!("graph {} not in artifacts", graph));
        }
        be.graph = graph.to_string();
        Ok(be)
    }

    /// Run one serving graph. `head` is the per-step input prefix (the
    /// K/V caches inside it were moved out of `self`; the caller moves
    /// the output caches back in). The weight tail rides as resident
    /// device buffers or borrowed host tensors — never cloned per step.
    fn dispatch(
        &self,
        graph: &str,
        head: &[HostTensor],
    ) -> Result<Vec<HostTensor>, String> {
        let out = {
            let _sp = trace::span("hlo.dispatch");
            match &self.resident {
                Some(bufs) => {
                    self.rt.run_with_resident(graph, head, bufs)?
                }
                None => {
                    let mut inputs: Vec<&HostTensor> = head.iter().collect();
                    inputs.extend(self.weights.iter());
                    self.rt.run_refs(graph, &inputs)?
                }
            }
        };
        if out.len() != 3 {
            return Err(format!(
                "{}: expected 3 outputs, got {}",
                graph,
                out.len()
            ));
        }
        Ok(out)
    }

    /// One decode-graph dispatch: every work item is a single position.
    fn decode_step(
        &mut self,
        work: &[SlotWork],
    ) -> Result<Vec<Vec<f32>>, String> {
        // inactive slots write to the scratch position ctx-1 (overwritten
        // before any real read — see module docs)
        let mut tok = vec![0i32; self.b];
        let mut active = vec![false; self.b];
        for wk in work {
            // bound: decode work items carry exactly one token
            tok[wk.slot] = wk.tokens[0];
            active[wk.slot] = true;
        }
        let pos: Vec<i32> = (0..self.b)
            .map(|i| {
                if active[i] {
                    self.pos[i] as i32
                } else {
                    (self.cfg.ctx - 1) as i32
                }
            })
            .collect();
        let head = [
            HostTensor::I32(vec![self.b], tok),
            HostTensor::I32(vec![self.b], pos),
            std::mem::take(&mut self.kcache),
            std::mem::take(&mut self.vcache),
        ];
        let mut out = match self.dispatch(&self.graph, &head) {
            Ok(o) => o,
            Err(e) => {
                // put the taken caches back so a failed dispatch does
                // not destroy the backend's KV state
                let [_, _, kc, vc] = head;
                self.kcache = kc;
                self.vcache = vc;
                return Err(e);
            }
        };
        // lint:allow(hot-expect): the decode graph is compiled with
        // exactly three outputs (logits, kcache, vcache)
        self.vcache = out.pop().expect("vcache output");
        // lint:allow(hot-expect): second of the three graph outputs
        self.kcache = out.pop().expect("kcache output");
        // bound: the remaining graph output is the logits tensor
        let logits_flat = out[0].as_f32()?;
        let vocab = self.cfg.vocab;
        for i in 0..self.b {
            if active[i] {
                self.pos[i] += 1;
            }
        }
        Ok(work
            .iter()
            .map(|wk| {
                if wk.want_logits {
                    logits_flat[wk.slot * vocab..(wk.slot + 1) * vocab]
                        .to_vec()
                } else {
                    Vec::new()
                }
            })
            .collect())
    }

    /// Drain a step that contains at least one prompt chunk through the
    /// positioned-prefill family: each dispatch picks the smallest
    /// compiled chunk covering the longest remaining run (runs past the
    /// largest compiled chunk take several dispatches), buckets every
    /// slot into it, and end-pads ragged tails with scratch tokens whose
    /// cache rows are pos-masked away. Slots with nothing left to feed
    /// park at the scratch position ctx-1, exactly like inactive decode
    /// slots. A `want_logits` item's row is captured from the dispatch
    /// that consumes its final token (`last[b]` points the in-graph
    /// gather at it).
    fn prefill_step(
        &mut self,
        work: &[SlotWork],
    ) -> Result<Vec<Vec<f32>>, String> {
        let vocab = self.cfg.vocab;
        let scratch_pos = (self.cfg.ctx - 1) as i32;
        let mut consumed = vec![0usize; work.len()];
        let mut logits_out: Vec<Vec<f32>> = vec![Vec::new(); work.len()];
        loop {
            let longest = work
                .iter()
                .zip(&consumed)
                .map(|(wk, &c)| wk.tokens.len() - c)
                .max()
                .unwrap_or(0);
            if longest == 0 {
                return Ok(logits_out);
            }
            let (chunk, graph) = self
                .prefill
                .iter()
                .find(|(c, _)| *c >= longest)
                .or_else(|| self.prefill.last())
                .cloned()
                // lint:allow(hot-expect): compile() builds at least one
                // prefill graph before serving starts
                .expect("prefill family checked nonempty");
            trace::instant(
                "hlo.chunk",
                &[("chunk", chunk as f64), ("longest", longest as f64)],
            );
            let mut tokens = vec![0i32; self.b * chunk];
            let mut pos = vec![scratch_pos; self.b];
            let mut last = vec![0i32; self.b];
            let mut took = vec![0usize; work.len()];
            for (wi, wk) in work.iter().enumerate() {
                let rem = wk.tokens.len() - consumed[wi];
                if rem == 0 {
                    continue;
                }
                let tk = rem.min(chunk);
                let base = consumed[wi];
                tokens[wk.slot * chunk..wk.slot * chunk + tk]
                    .copy_from_slice(&wk.tokens[base..base + tk]);
                pos[wk.slot] = self.pos[wk.slot] as i32;
                last[wk.slot] = (tk - 1) as i32;
                took[wi] = tk;
            }
            let head = [
                HostTensor::I32(vec![self.b, chunk], tokens),
                HostTensor::I32(vec![self.b], pos),
                HostTensor::I32(vec![self.b], last),
                std::mem::take(&mut self.kcache),
                std::mem::take(&mut self.vcache),
            ];
            let mut out = match self.dispatch(&graph, &head) {
                Ok(o) => o,
                Err(e) => {
                    // restore the taken caches (see decode_step)
                    let [_, _, _, kc, vc] = head;
                    self.kcache = kc;
                    self.vcache = vc;
                    return Err(e);
                }
            };
            // lint:allow(hot-expect): prefill graphs are compiled with
            // exactly three outputs (logits, kcache, vcache)
            self.vcache = out.pop().expect("vcache output");
            // lint:allow(hot-expect): second of the three graph outputs
            self.kcache = out.pop().expect("kcache output");
            // bound: the remaining graph output is the logits tensor
            let logits_flat = out[0].as_f32()?;
            for (wi, wk) in work.iter().enumerate() {
                if took[wi] == 0 {
                    continue;
                }
                consumed[wi] += took[wi];
                self.pos[wk.slot] += took[wi];
                if wk.want_logits && consumed[wi] == wk.tokens.len() {
                    logits_out[wi] = logits_flat
                        [wk.slot * vocab..(wk.slot + 1) * vocab]
                        .to_vec();
                }
            }
        }
    }
}

impl DecodeBackend for HloBackend<'_> {
    fn slots(&self) -> usize {
        self.b
    }

    fn cfg(&self) -> ModelConfig {
        self.cfg
    }

    fn max_chunk(&self) -> usize {
        self.prefill.last().map(|(c, _)| *c).unwrap_or(1)
    }

    fn plan_chunk(&self, cap: usize) -> usize {
        // largest compiled chunk that fits — so most dispatches run
        // unpadded; a run shorter than every compiled chunk is taken
        // whole and end-padded inside `prefill_step`
        self.prefill
            .iter()
            .rev()
            .map(|(c, _)| *c)
            .find(|&c| c <= cap)
            .unwrap_or(cap)
    }

    fn step(&mut self, work: &[SlotWork]) -> Result<Vec<Vec<f32>>, String> {
        if work.iter().all(|wk| wk.tokens.len() == 1) {
            return self.decode_step(work);
        }
        if self.prefill.is_empty() {
            return Err(
                "prompt chunk fed to an HLO backend without prefill \
                 graphs (decode graphs advance one position per slot)"
                    .into(),
            );
        }
        self.prefill_step(work)
    }

    fn reset_slot(&mut self, slot: usize) {
        self.pos[slot] = 0;
    }

    fn slot_pos(&self, slot: usize) -> usize {
        self.pos[slot]
    }

    fn weight_bytes_per_step(&self) -> usize {
        self.weight_bytes
    }

    fn kv_bytes_per_step(&self) -> usize {
        self.cfg.layers
            * self.b
            * self.cfg.heads
            * self.cfg.ctx
            * self.cfg.head_dim()
            * 4
            * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::WeightStore;
    use crate::quant::lut::lut_from_parts;
    use crate::quant::BitPlaneStore;
    use crate::tensor::Mat;

    /// Quantized model whose every linear is a random nested
    /// any-precision store (widths 2/3/4).
    fn anyprec_model(s: &WeightStore, seed: u64) -> QuantizedModel {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut linears = std::collections::BTreeMap::new();
        for (name, m, n) in s.cfg.linear_shapes() {
            let codes: Vec<u8> =
                (0..m * n).map(|_| rng.below(16) as u8).collect();
            let cb = Mat::from_vec(
                m,
                16,
                rng.normal_vec_f32(m * 16)
                    .into_iter()
                    .map(|v| v * 0.08)
                    .collect(),
            );
            let parent = lut_from_parts(m, n, 4, codes, cb);
            linears.insert(
                name,
                LayerWeights::AnyPrec(BitPlaneStore::nest(
                    &parent,
                    &[2, 3, 4],
                )),
            );
        }
        QuantizedModel {
            base: s.clone(),
            method: "ganq-anyprec".into(),
            bits: 4,
            linears,
            weight_bits: 0,
        }
    }

    /// The same model with every store materialized as a standalone
    /// `w`-bit LUT layer.
    fn sliced_model(qm: &QuantizedModel, w: u8) -> QuantizedModel {
        let mut out = qm.clone();
        for lw in out.linears.values_mut() {
            if let LayerWeights::AnyPrec(b) = lw {
                *lw = LayerWeights::Lut(b.slice(w));
            }
        }
        out.bits = w;
        out
    }

    fn backend() -> (WeightStore, Vec<GenRequest>) {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 31);
        let reqs = vec![
            GenRequest::greedy(1, vec![104, 105], 4),
            GenRequest::greedy(2, vec![97, 98, 99], 6),
            GenRequest::greedy(3, vec![120], 3),
        ];
        (store, reqs)
    }

    #[test]
    fn native_continuous_batching_completes_all() {
        let (store, reqs) = backend();
        let w = Weights::Fp(&store);
        let mut be = NativeBackend::new(w, 2); // 3 reqs through 2 slots
        let (resp, metrics) = serve(&mut be, reqs).unwrap();
        assert_eq!(resp.len(), 3);
        assert_eq!(resp[0].tokens.len(), 4);
        assert_eq!(resp[1].tokens.len(), 6);
        assert_eq!(resp[2].tokens.len(), 3);
        assert!(resp.iter().all(|r| r.finish == FinishReason::MaxTokens));
        assert_eq!(metrics.total_generated(), 13);
        assert_eq!(metrics.finish.max_tokens, 3);
        assert!(metrics.decode_steps > 0);
        assert!(metrics.weight_bytes_per_step > 0);
        assert!(metrics.prompt_positions >= 6, "prompts fed through steps");
    }

    #[test]
    fn batched_output_matches_sequential_generation() {
        let (store, reqs) = backend();
        let w = Weights::Fp(&store);
        let mut be = NativeBackend::new(w, 3);
        let (resp, _) = serve(&mut be, reqs.clone()).unwrap();
        for r in &reqs {
            let w2 = Weights::Fp(&store);
            let expect = Engine::new(&w2).generate(
                &r.prompt,
                r.stop.max_new,
                &SamplingParams::greedy(),
            );
            let got = &resp
                .iter()
                .find(|x| x.id == r.id)
                .unwrap()
                .tokens;
            assert_eq!(got, &expect, "req {}", r.id);
        }
    }

    #[test]
    fn chunked_prefill_serving_matches_per_token() {
        // the same workload served with per-token prefill (chunk=1),
        // modest chunks, and the default budget must produce identical
        // greedy outputs on dense KV — chunking changes wall clock, not
        // math
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 37);
        let reqs: Vec<GenRequest> = (0..4)
            .map(|i| {
                GenRequest::greedy(
                    i,
                    (0..40 + i as i32 * 7)
                        .map(|j| (j * 13 + i as i32) % 256)
                        .collect(),
                    5,
                )
            })
            .collect();
        let serve_chunk = |chunk: usize| {
            let w = Weights::Fp(&store);
            let mut be = NativeBackend::new(w, 2);
            serve_with(
                &mut be,
                reqs.clone(),
                ServeOptions {
                    prefill_chunk: chunk,
                    ..ServeOptions::default()
                },
            )
            .unwrap()
        };
        let (resp_1, m_1) = serve_chunk(1);
        let (resp_16, m_16) = serve_chunk(16);
        let (resp_def, _) = serve_chunk(DEFAULT_PREFILL_CHUNK);
        for (a, b) in resp_1.iter().zip(&resp_16) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "req {}", a.id);
        }
        for (a, b) in resp_1.iter().zip(&resp_def) {
            assert_eq!(a.tokens, b.tokens, "req {}", a.id);
        }
        // chunked prefill takes strictly fewer steps for the same work
        assert!(m_16.decode_steps < m_1.decode_steps);
        assert_eq!(m_16.prompt_positions, m_1.prompt_positions);
        assert!(m_16.prompt_positions_per_step() > 1.0);
    }

    #[test]
    fn chunked_prefill_paged_matches_contiguous() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 38);
        let reqs: Vec<GenRequest> = (0..3)
            .map(|i| {
                GenRequest::greedy(
                    i,
                    (0..30).map(|j| (j * 7 + i as i32) % 256).collect(),
                    4,
                )
            })
            .collect();
        let w = Weights::Fp(&store);
        let mut be = NativeBackend::new(w, 3);
        let (resp_c, _) = serve(&mut be, reqs.clone()).unwrap();
        let w2 = Weights::Fp(&store);
        let mut bp =
            PagedNativeBackend::new(w2, 3, 4, 64, KvStoreKind::F32);
        let (resp_p, m) = serve_with(
            &mut bp,
            reqs,
            ServeOptions { prefill_chunk: 16, ..ServeOptions::default() },
        )
        .unwrap();
        for (c, p) in resp_c.iter().zip(&resp_p) {
            assert_eq!(c.id, p.id);
            assert_eq!(c.tokens, p.tokens, "req {}", c.id);
        }
        assert!(m.kv.unwrap().sealed_blocks > 0);
    }

    #[test]
    fn paged_f32_serving_matches_contiguous_native() {
        let (store, reqs) = backend();
        let w = Weights::Fp(&store);
        let mut be = NativeBackend::new(w, 3);
        let (resp_c, _) = serve(&mut be, reqs.clone()).unwrap();

        let w2 = Weights::Fp(&store);
        let mut bp =
            PagedNativeBackend::new(w2, 3, 4, 64, KvStoreKind::F32);
        let (resp_p, m) = serve(&mut bp, reqs).unwrap();
        assert_eq!(resp_c.len(), resp_p.len());
        for (c, p) in resp_c.iter().zip(&resp_p) {
            assert_eq!(c.id, p.id);
            assert_eq!(c.tokens, p.tokens, "req {}", c.id);
        }
        let kv = m.kv.expect("paged backend reports pool stats");
        assert!(kv.sealed_blocks > 0);
        assert!(kv.peak_blocks_in_use > 0);
    }

    #[test]
    fn paged_preemption_preserves_greedy_output() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 33);
        let reqs: Vec<GenRequest> = (0..4)
            .map(|i| GenRequest::greedy(i, vec![10 + i as i32, 20, 30], 12))
            .collect();
        let w = Weights::Fp(&store);
        let mut be = NativeBackend::new(w, 4);
        let (expect, _) = serve(&mut be, reqs.clone()).unwrap();

        // a pool too small for 4 full requests forces preemption
        let w2 = Weights::Fp(&store);
        let mut bp =
            PagedNativeBackend::new(w2, 4, 4, 8, KvStoreKind::F32);
        let (got, m) = serve(&mut bp, reqs).unwrap();
        assert_eq!(expect.len(), got.len());
        for (e, g) in expect.iter().zip(&got) {
            assert_eq!(e.tokens, g.tokens, "req {}", e.id);
        }
        // with 8 blocks and 4 requests needing 4 blocks each, someone
        // must have been preempted or queued; either way all finished
        assert!(m.preemptions > 0 || m.peak_concurrency < 4);
    }

    #[test]
    fn unserveable_request_is_rejected_not_fatal() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 35);
        // 2-block pool (bs 4): a 12-token prompt can never fit, the
        // 2-token one can
        let reqs = vec![
            GenRequest::greedy(1, (0..12).collect(), 4),
            GenRequest::greedy(2, vec![7, 8], 3),
        ];
        let w = Weights::Fp(&store);
        let mut bp =
            PagedNativeBackend::new(w, 2, 4, 2, KvStoreKind::F32);
        let (resp, m) = serve(&mut bp, reqs).unwrap();
        assert_eq!(resp.len(), 2);
        assert!(resp[0].tokens.is_empty(), "oversized req rejected");
        assert_eq!(resp[0].finish, FinishReason::Rejected);
        assert_eq!(resp[1].tokens.len(), 3, "small req still served");
        assert_eq!(m.finish.rejected, 1);
    }

    #[test]
    fn paged_prefix_sharing_reports_hits() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 34);
        let shared: Vec<i32> = (0..8).collect();
        let reqs: Vec<GenRequest> = (0..3)
            .map(|i| GenRequest::greedy(i, shared.clone(), 4))
            .collect();
        let w = Weights::Fp(&store);
        let mut bp =
            PagedNativeBackend::new(w, 1, 4, 32, KvStoreKind::F32);
        // one slot: requests run serially, later ones hit the cached
        // prefix left by the first
        let (resp, m) = serve(&mut bp, reqs).unwrap();
        assert_eq!(resp.len(), 3);
        assert_eq!(resp[0].tokens, resp[1].tokens);
        assert_eq!(resp[0].tokens, resp[2].tokens);
        let kv = m.kv.unwrap();
        assert!(
            kv.prefix_hit_tokens >= 8,
            "expected shared-prefix hits, got {:?}",
            kv
        );
        assert!(kv.prefix_hit_rate() > 0.0);
    }

    #[test]
    fn oversized_prompt_is_truncated() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 32);
        let w = Weights::Fp(&store);
        let mut be = NativeBackend::new(w, 1);
        let reqs = vec![GenRequest::greedy(
            1,
            (0..300).map(|i| i % 256).collect(),
            5,
        )];
        let (resp, _) = serve(&mut be, reqs).unwrap();
        assert_eq!(resp[0].tokens.len(), 5);
    }

    #[test]
    fn stop_criteria_matching() {
        let sc = StopCriteria::max_tokens(100)
            .with_stop_tokens(vec![7])
            .with_stop_seq(vec![1, 2, 3])
            .with_stop_seq(vec![2, 3]);
        assert!(sc.is_stop_token(7));
        assert!(!sc.is_stop_token(8));
        // longest matching stop sequence wins
        assert_eq!(sc.stop_seq_hit(&[9, 1, 2], 3), Some(3));
        assert_eq!(sc.stop_seq_hit(&[9, 9, 2], 3), Some(2));
        assert_eq!(sc.stop_seq_hit(&[9, 1, 2], 4), None);
        // sequences longer than the stream cannot match
        assert_eq!(sc.stop_seq_hit(&[2], 3), Some(2));
        assert_eq!(sc.stop_seq_hit(&[], 3), None);
        let eos = StopCriteria {
            eos: Some(0),
            ..StopCriteria::max_tokens(10)
        };
        assert!(eos.is_stop_token(0));
    }

    #[test]
    fn sampler_stop_token_takes_precedence() {
        // logits peak at token 5; configured as a stop token it must end
        // the request without emitting
        let mut logits = vec![0.0f32; 16];
        logits[5] = 10.0;
        let stop = StopCriteria::max_tokens(100).with_stop_tokens(vec![5]);
        let step = Sampler::next(
            &SamplingParams::greedy(),
            &stop,
            &[1, 2],
            &logits,
        );
        assert_eq!(
            step,
            SamplerStep::Finish {
                tok: None,
                why: FinishReason::StopToken,
                trim: 0
            }
        );
    }

    #[test]
    fn serve_stop_token_and_stop_seq() {
        let (store, _) = backend();
        let w = Weights::Fp(&store);
        // reference greedy tokens for this prompt
        let prompt = vec![104i32, 105, 106];
        let full = Engine::new(&w).generate(
            &prompt,
            8,
            &SamplingParams::greedy(),
        );
        assert!(full.len() == 8);
        // greedy outputs on random models repeat; anchor the stop on the
        // last token value whose FIRST occurrence is at index k so the
        // criterion cannot fire earlier than intended
        let k = (0..full.len())
            .rev()
            .find(|&k| !full[..k].contains(&full[k]))
            .expect("index 0 is always a first occurrence");

        // stop token: generation ends right before full[k]
        let req = GenRequest::new(
            1,
            prompt.clone(),
            SamplingParams::greedy(),
            StopCriteria::max_tokens(8).with_stop_tokens(vec![full[k]]),
        );
        let mut be = NativeBackend::new(w, 1);
        let (resp, m) = serve(&mut be, vec![req]).unwrap();
        assert_eq!(resp[0].finish, FinishReason::StopToken);
        assert_eq!(resp[0].tokens, full[..k].to_vec());
        assert_eq!(m.finish.stop_token, 1);

        // stop sequence ending at full[k]: matched tokens are trimmed
        let (seq, expect) = if k >= 1 {
            (full[k - 1..=k].to_vec(), full[..k - 1].to_vec())
        } else {
            (vec![full[0]], Vec::new())
        };
        let w2 = Weights::Fp(&store);
        let req = GenRequest::new(
            2,
            prompt.clone(),
            SamplingParams::greedy(),
            StopCriteria::max_tokens(8).with_stop_seq(seq),
        );
        let mut be = NativeBackend::new(w2, 1);
        let (resp, m) = serve(&mut be, vec![req]).unwrap();
        assert_eq!(resp[0].finish, FinishReason::StopSeq);
        assert_eq!(resp[0].tokens, expect);
        assert_eq!(m.finish.stop_seq, 1);
    }

    #[test]
    fn serve_cancellation_releases_slot_and_reports_waste() {
        let (store, _) = backend();
        let w = Weights::Fp(&store);
        let reqs = vec![
            GenRequest::greedy(1, vec![104, 105], 12),
            GenRequest::greedy(2, vec![97, 98], 12),
        ];
        let cancel = reqs[0].cancel_handle();
        let mut be = NativeBackend::new(w, 2);
        let mut events = Vec::new();
        let (resp, m) = serve_events(
            &mut be,
            reqs,
            ServeOptions::default(),
            &mut |ev| {
                // cancel request 1 after its third streamed token; the
                // sink runs inside the scheduler, so this exercises the
                // next-step-boundary release path deterministically
                if let TokenEvent::Token { id: 1, .. } = ev {
                    events.push(());
                    if events.len() == 3 {
                        cancel.cancel();
                    }
                }
            },
        )
        .unwrap();
        let r1 = resp.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(r1.finish, FinishReason::Cancelled);
        assert_eq!(r1.tokens.len(), 3, "cancelled after 3 tokens");
        let r2 = resp.iter().find(|r| r.id == 2).unwrap();
        assert_eq!(r2.finish, FinishReason::MaxTokens);
        assert_eq!(r2.tokens.len(), 12, "other request unaffected");
        assert_eq!(m.finish.cancelled, 1);
        assert_eq!(m.cancelled_tokens, 3);
    }

    #[test]
    fn serve_cancelled_before_admission_never_runs() {
        let (store, _) = backend();
        let w = Weights::Fp(&store);
        let reqs = vec![GenRequest::greedy(9, vec![1, 2, 3], 4)];
        reqs[0].cancel_handle().cancel();
        let mut be = NativeBackend::new(w, 1);
        let (resp, m) = serve(&mut be, reqs).unwrap();
        assert_eq!(resp[0].finish, FinishReason::Cancelled);
        assert!(resp[0].tokens.is_empty());
        assert_eq!(m.decode_steps, 0, "no step ran for a dead request");
    }

    #[test]
    fn empty_stop_seq_is_filtered_not_fatal() {
        // regression: an empty stop_seqs entry used to reach the
        // matcher, whose tail inspection panicked the engine thread
        let sc = StopCriteria::max_tokens(8).with_stop_seq(Vec::new());
        assert_eq!(sc.stop_seq_hit(&[], 3), None);
        assert_eq!(sc.stop_seq_hit(&[1, 2], 3), None);

        // construction drops empties even when they were injected
        // directly into the struct
        let mut raw = StopCriteria::max_tokens(4);
        raw.stop_seqs = vec![Vec::new(), vec![9_999], Vec::new()];
        let req = GenRequest::new(
            1,
            vec![104, 105],
            SamplingParams::greedy(),
            raw,
        );
        assert_eq!(req.stop.stop_seqs, vec![vec![9_999]]);

        let (store, _) = backend();
        let w = Weights::Fp(&store);
        let mut be = NativeBackend::new(w, 1);
        let (resp, _) = serve(&mut be, vec![req]).unwrap();
        assert_eq!(resp[0].finish, FinishReason::MaxTokens);
        assert_eq!(resp[0].tokens.len(), 4);
    }

    #[test]
    fn deadline_expired_before_admission_returns_empty() {
        let (store, _) = backend();
        let w = Weights::Fp(&store);
        // deadline 0ms measured from the round's start: the admission
        // scan finishes it before any step runs
        let req = GenRequest::greedy(5, vec![1, 2, 3], 6)
            .with_deadline_ms(0.0);
        let mut be = NativeBackend::new(w, 1);
        let (resp, m) = serve(&mut be, vec![req]).unwrap();
        assert_eq!(resp[0].finish, FinishReason::DeadlineExceeded);
        assert!(resp[0].tokens.is_empty());
        assert_eq!(m.decode_steps, 0);
        assert_eq!(m.finish.deadline, 1);
    }

    #[test]
    fn deadline_mid_decode_returns_partial_output() {
        // a backend that sleeps per step so the wall clock moves
        struct Slow<B>(B);
        impl<B: DecodeBackend> DecodeBackend for Slow<B> {
            fn slots(&self) -> usize {
                self.0.slots()
            }
            fn cfg(&self) -> ModelConfig {
                self.0.cfg()
            }
            fn step(
                &mut self,
                work: &[SlotWork],
            ) -> Result<Vec<Vec<f32>>, String> {
                std::thread::sleep(std::time::Duration::from_millis(10));
                self.0.step(work)
            }
            fn reset_slot(&mut self, slot: usize) {
                self.0.reset_slot(slot)
            }
            fn slot_pos(&self, slot: usize) -> usize {
                self.0.slot_pos(slot)
            }
            fn weight_bytes_per_step(&self) -> usize {
                self.0.weight_bytes_per_step()
            }
            fn kv_bytes_per_step(&self) -> usize {
                self.0.kv_bytes_per_step()
            }
        }
        let (store, _) = backend();
        let w = Weights::Fp(&store);
        let req = GenRequest::greedy(7, vec![104, 105], 64)
            .with_deadline_ms(25.0);
        let mut be = Slow(NativeBackend::new(w, 1));
        let (resp, m) = serve(&mut be, vec![req]).unwrap();
        assert_eq!(resp[0].finish, FinishReason::DeadlineExceeded);
        assert!(
            resp[0].tokens.len() < 64,
            "deadline must cut the budget short"
        );
        assert_eq!(m.finish.deadline, 1);
    }

    #[test]
    fn anyprec_fixed_width_matches_sliced_native() {
        // Fixed(w) through the nested store must reproduce, token for
        // token, a NativeBackend over the separately materialized w-bit
        // model — the serving path changes, the math does not
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 41);
        let qm = anyprec_model(&store, 41);
        let reqs = vec![
            GenRequest::greedy(1, vec![104, 105], 4),
            GenRequest::greedy(2, vec![97, 98, 99], 6),
            GenRequest::greedy(3, vec![120], 3),
        ];
        for w in [2u8, 3, 4] {
            let mut be = AnyPrecBackend::new(&qm, 2).unwrap();
            let (got, m) = serve_with(
                &mut be,
                reqs.clone(),
                ServeOptions {
                    precision: PrecisionPolicy::Fixed(w),
                    ..ServeOptions::default()
                },
            )
            .unwrap();
            let std = sliced_model(&qm, w);
            let ws = Weights::Quant(&std);
            let mut nb = NativeBackend::new(ws, 2);
            let (want, _) = serve(&mut nb, reqs.clone()).unwrap();
            for (g, e) in got.iter().zip(&want) {
                assert_eq!(g.id, e.id);
                assert_eq!(g.tokens, e.tokens, "req {} width {}", g.id, w);
            }
            assert_eq!(
                m.tokens_by_width.get(&w).copied(),
                Some(m.total_generated() as u64),
                "every token counted at the pinned width"
            );
            assert_eq!(m.precision_switches, 0, "fixed policy never flips");
        }
    }

    #[test]
    fn anyprec_native_policy_serves_at_max_width() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 44);
        let qm = anyprec_model(&store, 44);
        let reqs = vec![GenRequest::greedy(1, vec![9, 8, 7], 5)];
        let mut be = AnyPrecBackend::new(&qm, 1).unwrap();
        let (got, m) = serve(&mut be, reqs.clone()).unwrap();
        let mut be4 = AnyPrecBackend::new(&qm, 1).unwrap();
        let (want, _) = serve_with(
            &mut be4,
            reqs,
            ServeOptions {
                precision: PrecisionPolicy::Fixed(4),
                ..ServeOptions::default()
            },
        )
        .unwrap();
        assert_eq!(got[0].tokens, want[0].tokens);
        assert!(m.tokens_by_width.is_empty(), "native policy tracks none");
    }

    #[test]
    fn auto_policy_degrades_restores_and_pins_admission_width() {
        // 6 requests through 1 slot with Auto{4→3}: the opening queue
        // depth (6 ≥ degrade_depth) degrades admissions to 3-bit; the
        // queue drains to restore_depth while the 5th request is still
        // decoding — it keeps its admission-time width (the mid-run pin)
        // and only the last request is admitted back at 4-bit
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 42);
        let qm = anyprec_model(&store, 42);
        let reqs: Vec<GenRequest> = (0..6)
            .map(|i| {
                GenRequest::greedy(i, vec![10 + i as i32, 3, 7], 3)
            })
            .collect();
        let mut be = AnyPrecBackend::new(&qm, 1).unwrap();
        let (got, m) = serve_with(
            &mut be,
            reqs.clone(),
            ServeOptions {
                precision: PrecisionPolicy::Auto {
                    high: 4,
                    low: 3,
                    degrade_depth: 3,
                    restore_depth: 1,
                },
                ..ServeOptions::default()
            },
        )
        .unwrap();
        assert_eq!(m.precision_switches, 2, "one degrade + one restore");
        assert_eq!(m.tokens_by_width.get(&3), Some(&15));
        assert_eq!(m.tokens_by_width.get(&4), Some(&3));
        // outputs are a pure function of (request, admission width):
        // compare each against solo generation through the standalone
        // slice at its pinned width
        for r in &got {
            let w = if r.id < 5 { 3 } else { 4 };
            let req = reqs.iter().find(|q| q.id == r.id).unwrap();
            let std = sliced_model(&qm, w);
            let ws = Weights::Quant(&std);
            let want = Engine::new(&ws).generate(
                &req.prompt,
                3,
                &SamplingParams::greedy(),
            );
            assert_eq!(r.tokens, want, "req {} at {} bits", r.id, w);
        }
    }

    #[test]
    fn anyprec_mixed_width_step_partitions_by_slot() {
        // one step with slots pinned at different widths must return
        // each slot the same logits row a single-width step would
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 43);
        let qm = anyprec_model(&store, 43);
        let prompt = vec![5i32, 6, 7];
        let mut be = AnyPrecBackend::new(&qm, 2).unwrap();
        be.admit(0, &prompt, 4).unwrap();
        be.set_slot_width(0, 2);
        be.admit(1, &prompt, 4).unwrap();
        be.set_slot_width(1, 4);
        let mk = |slot: usize| SlotWork {
            slot,
            tokens: prompt.clone(),
            want_logits: true,
        };
        let out = be.step(&[mk(0), mk(1)]).unwrap();
        for (w, row) in [(2u8, &out[0]), (4u8, &out[1])] {
            let mut rb = AnyPrecBackend::new(&qm, 1).unwrap();
            rb.admit(0, &prompt, 4).unwrap();
            rb.set_slot_width(0, w);
            let want = rb.step(&[mk(0)]).unwrap();
            assert_eq!(row, &want[0], "width {}", w);
        }
    }

    #[test]
    fn precision_policy_validation_fails_loudly() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 45);
        let qm = anyprec_model(&store, 45);
        let reqs = vec![GenRequest::greedy(1, vec![1, 2], 2)];

        // width the nested store does not carry
        let mut be = AnyPrecBackend::new(&qm, 1).unwrap();
        let opts = ServeOptions {
            precision: PrecisionPolicy::Fixed(5),
            ..ServeOptions::default()
        };
        assert!(serve_with(&mut be, reqs.clone(), opts).is_err());

        // fixed-width backend rejects any non-native policy
        let w = Weights::Fp(&store);
        let mut nb = NativeBackend::new(w, 1);
        let opts = ServeOptions {
            precision: PrecisionPolicy::Fixed(4),
            ..ServeOptions::default()
        };
        assert!(serve_with(&mut nb, reqs.clone(), opts).is_err());

        // inverted hysteresis band
        let mut be = AnyPrecBackend::new(&qm, 1).unwrap();
        let opts = ServeOptions {
            precision: PrecisionPolicy::Auto {
                high: 4,
                low: 3,
                degrade_depth: 2,
                restore_depth: 2,
            },
            ..ServeOptions::default()
        };
        assert!(serve_with(&mut be, reqs, opts).is_err());

        // and a non-anyprec model cannot build the backend at all
        let plain = QuantizedModel {
            base: store.clone(),
            method: "rtn".into(),
            bits: 4,
            linears: std::collections::BTreeMap::new(),
            weight_bits: 0,
        };
        assert!(AnyPrecBackend::new(&plain, 1).is_err());
    }

    #[test]
    fn token_events_stream_before_done() {
        let (store, reqs) = backend();
        let w = Weights::Fp(&store);
        let mut be = NativeBackend::new(w, 2);
        let mut log: Vec<(u64, bool)> = Vec::new(); // (id, is_done)
        let (resp, _) = serve_events(
            &mut be,
            reqs,
            ServeOptions::default(),
            &mut |ev| match ev {
                TokenEvent::Token { id, .. } => log.push((id, false)),
                TokenEvent::Done(o) => log.push((o.id, true)),
            },
        )
        .unwrap();
        for r in &resp {
            let toks: Vec<_> =
                log.iter().filter(|(id, d)| *id == r.id && !d).collect();
            assert_eq!(toks.len(), r.tokens.len(), "one event per token");
            let done_pos = log
                .iter()
                .position(|(id, d)| *id == r.id && *d)
                .expect("done event");
            let first_tok = log
                .iter()
                .position(|(id, d)| *id == r.id && !d)
                .expect("token event");
            assert!(
                first_tok < done_pos,
                "tokens must stream before completion"
            );
        }
    }
}
