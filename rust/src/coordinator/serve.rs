//! Serving: token-level continuous batching (Orca-style) over a decode
//! backend. The scheduler is a thin admission/planning policy: every
//! step it hands the backend a list of [`SlotWork`] items — one per
//! active slot, each either a **prefill chunk** (a run of prompt
//! positions, bounded by the per-step prefill budget so decode latency
//! stays bounded while prompts drain) or a **single decode position**.
//! Backends map that plan onto `forward::Engine::step` (native paths)
//! or the AOT decode graphs.
//!
//! Three backends implement the same contract:
//!
//! * [`HloBackend`] — the AOT decode graph via PJRT (`decode_{fmt}_{model}
//!   _b{B}`), per-slot positions as a vector input, KV caches threaded
//!   through the graph outputs; weights optionally staged as device-
//!   resident buffers (the §Perf optimization). The graphs advance one
//!   position per slot, so `max_chunk() == 1` (prompts feed per-token).
//! * [`NativeBackend`] — the pure-Rust engine with one contiguous
//!   [`KvCache`] per slot: every step advances the whole active set
//!   through each layer together, so quantized weights stream once per
//!   step regardless of how many prompt positions ride along.
//! * [`PagedNativeBackend`] — the same engine over the paged KV cache
//!   (`kv::PagedKv`): block tables, prefix sharing, and dynamic
//!   capacity; prefill chunks append whole block runs at a time.
//!
//! ## Admission / preemption contract (paged backends)
//!
//! Capacity is dynamic: [`DecodeBackend::admit`] may refuse a request
//! (`None`) while the block pool is full — the scheduler keeps it queued
//! in FIFO order and retries each round. An admit may also report `k`
//! prompt positions already covered by shared prefix blocks; the
//! scheduler skips feeding those tokens (`k` is always less than the
//! prompt length so the final prompt token still produces first-token
//! logits). Before every step the scheduler calls
//! [`DecodeBackend::pre_step`] with the per-slot position counts it
//! plans to feed; a backend that ran out of blocks preempts its
//! youngest-admitted slots there, and the scheduler requeues the victims
//! at the front of the queue with their generated tokens folded into the
//! replay prompt (recompute-style preemption — with greedy decoding the
//! final output is unchanged). Finished slots are returned with
//! [`DecodeBackend::release_slot`]; their shared blocks stay cached for
//! future prefix hits. A request that can never fit in the pool
//! (admission keeps refusing with an idle backend, or every admit is
//! immediately preempted) is rejected rather than wedging the batch: it
//! completes with whatever it generated so far (usually nothing) and is
//! counted in `ServeMetrics::rejected`.

use std::time::Instant;

use crate::kv::{
    F32Blocks, KvBlockStore, KvLayout, KvPoolStats, LutBlocks, PagedKv,
};
use crate::model::forward::{
    self, Engine, KvCache, KvSeq, LogitsMode, SeqRefs, StepItem, StepPlan,
    Weights,
};
use crate::model::{LayerWeights, ModelConfig, QuantizedModel, WeightStore};
use crate::runtime::{HostTensor, Runtime};

use super::metrics::{RequestMetrics, ServeMetrics};

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
}

/// One slot's work for a step: a run of tokens to feed, in ascending
/// slot order. `tokens.len() == 1` is a decode position; longer runs are
/// prefill chunks. `want_logits` is set when the run's last position
/// must produce logits (the final prompt token, or any decode).
#[derive(Debug, Clone)]
pub struct SlotWork {
    pub slot: usize,
    pub tokens: Vec<i32>,
    pub want_logits: bool,
}

pub trait DecodeBackend {
    fn slots(&self) -> usize;
    fn cfg(&self) -> ModelConfig;
    /// Most prompt positions one slot can feed in a single step. The
    /// engine-backed natives take whole chunks; the fixed decode graphs
    /// advance one position per slot.
    fn max_chunk(&self) -> usize {
        1
    }
    /// Advance the slots in `work` (one entry per active slot, ascending
    /// slot order); returns one logits row per work item (empty when
    /// `want_logits` was false).
    fn step(&mut self, work: &[SlotWork]) -> Result<Vec<Vec<f32>>, String>;
    fn reset_slot(&mut self, slot: usize);
    fn slot_pos(&self, slot: usize) -> usize;
    fn weight_bytes_per_step(&self) -> usize;
    fn kv_bytes_per_step(&self) -> usize;

    /// Admit a request into `slot` before its first step. `Some(k)`
    /// means `k` prompt positions are already cached (prefix hit, always
    /// `< prompt.len()`); the scheduler skips feeding them. `None` means
    /// the backend has no KV capacity right now and the scheduler should
    /// retry later. Static-capacity backends always admit at position 0.
    fn admit(
        &mut self,
        slot: usize,
        prompt: &[i32],
        max_new: usize,
    ) -> Option<usize> {
        let _ = (prompt, max_new);
        self.reset_slot(slot);
        Some(0)
    }

    /// Called before every step with the positions the scheduler plans
    /// to append per slot (`0` = idle this step). Returns the slots the
    /// backend preempted to reclaim KV memory (their state is gone); the
    /// scheduler requeues those requests. Default: none.
    fn pre_step(&mut self, need: &[usize]) -> Vec<usize> {
        let _ = need;
        Vec::new()
    }

    /// Release a slot's KV state once its request finished. Paged
    /// backends return blocks to the pool (shared prefixes stay cached).
    fn release_slot(&mut self, slot: usize) {
        let _ = slot;
    }

    /// Block-pool counters (paged backends only).
    fn pool_stats(&self) -> Option<KvPoolStats> {
        None
    }
}

// ---------------------------------------------------------------------------
// scheduler
// ---------------------------------------------------------------------------

/// Default per-step prefill budget (prompt positions across all slots).
pub const DEFAULT_PREFILL_CHUNK: usize = 128;

/// Scheduling knobs (`--prefill-chunk` on the CLI).
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// Prompt positions the scheduler may feed per step, across slots.
    /// Every prompting slot still gets at least one position so it
    /// cannot starve; `1` reproduces the historical per-token prefill.
    pub prefill_chunk: usize,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions { prefill_chunk: DEFAULT_PREFILL_CHUNK }
    }
}

struct SlotState {
    req: Request,
    /// tokens generated before a preemption (already part of `prompt`)
    gen_prefix: Vec<i32>,
    /// effective prompt for this residency: original prompt + gen_prefix
    prompt: Vec<i32>,
    prompt_idx: usize,
    generated: Vec<i32>,
    metrics: RequestMetrics,
}

/// A queued request, possibly carrying state from a preemption.
struct Queued {
    req: Request,
    gen_prefix: Vec<i32>,
    metrics: Option<RequestMetrics>,
}

/// Finish a request that cannot fit in the backend's KV pool: it gets a
/// response with whatever was generated before (usually empty) instead
/// of poisoning the whole serve call.
fn reject(
    q: Queued,
    responses: &mut Vec<Response>,
    all_metrics: &mut Vec<RequestMetrics>,
) {
    let mut m = q.metrics.unwrap_or(RequestMetrics {
        id: q.req.id,
        prompt_tokens: q.req.prompt.len(),
        generated_tokens: q.gen_prefix.len(),
        enqueued: Instant::now(),
        first_token: None,
        finished: None,
    });
    m.finished = Some(Instant::now());
    responses.push(Response { id: q.req.id, tokens: q.gen_prefix });
    all_metrics.push(m);
}

/// Serve a batch of requests to completion with continuous batching and
/// the default prefill budget.
pub fn serve(
    backend: &mut dyn DecodeBackend,
    requests: Vec<Request>,
) -> Result<(Vec<Response>, ServeMetrics), String> {
    serve_with(backend, requests, ServeOptions::default())
}

/// Serve a batch of requests to completion with continuous batching.
pub fn serve_with(
    backend: &mut dyn DecodeBackend,
    requests: Vec<Request>,
    opts: ServeOptions,
) -> Result<(Vec<Response>, ServeMetrics), String> {
    let nslots = backend.slots();
    let ctx = backend.cfg().ctx;
    let max_chunk = backend.max_chunk().max(1);
    let t_start = Instant::now();
    let total_reqs = requests.len();
    let mut queue: std::collections::VecDeque<Queued> = requests
        .into_iter()
        .map(|mut r| {
            // left-truncate prompts that cannot fit with generation room
            let budget = ctx.saturating_sub(r.max_new + 1).max(1);
            if r.prompt.len() > budget {
                r.prompt = r.prompt[r.prompt.len() - budget..].to_vec();
            }
            Queued { req: r, gen_prefix: Vec::new(), metrics: None }
        })
        .collect();
    let mut slots: Vec<Option<SlotState>> =
        (0..nslots).map(|_| None).collect();
    let mut responses = Vec::new();
    let mut all_metrics = Vec::new();
    let mut steps = 0usize;
    let mut prompt_positions = 0usize;
    let mut preemptions = 0usize;
    let mut rejected = 0usize;
    let mut peak_concurrency = 0usize;
    let mut stalls = 0usize;

    loop {
        // admit in FIFO order; a paged backend may refuse (pool full)
        for si in 0..nslots {
            if slots[si].is_some() {
                continue;
            }
            let Some(q) = queue.front() else { break };
            let prompt: Vec<i32> = q
                .req
                .prompt
                .iter()
                .chain(q.gen_prefix.iter())
                .copied()
                .collect();
            let max_new = q.req.max_new - q.gen_prefix.len();
            match backend.admit(si, &prompt, max_new) {
                Some(cached) => {
                    debug_assert!(
                        cached < prompt.len().max(1),
                        "prefix hit must leave the last prompt token"
                    );
                    let q = queue.pop_front().expect("front checked");
                    let metrics =
                        q.metrics.clone().unwrap_or(RequestMetrics {
                            id: q.req.id,
                            prompt_tokens: q.req.prompt.len(),
                            generated_tokens: 0,
                            enqueued: Instant::now(),
                            first_token: None,
                            finished: None,
                        });
                    slots[si] = Some(SlotState {
                        req: q.req,
                        gen_prefix: q.gen_prefix,
                        prompt,
                        prompt_idx: cached,
                        generated: Vec::new(),
                        metrics,
                    });
                }
                None => break,
            }
        }
        if slots.iter().all(|s| s.is_none()) {
            if queue.is_empty() {
                break;
            }
            // the front request cannot be admitted into an idle backend;
            // give the rest of the queue a turn, and once everyone has
            // had one (a full rotation) reject the front as unserveable
            stalls += 1;
            if stalls > queue.len() + 1 {
                let q = queue.pop_front().expect("queue nonempty");
                reject(q, &mut responses, &mut all_metrics);
                rejected += 1;
                stalls = 0;
            } else {
                queue.rotate_left(1);
            }
            continue;
        }

        // plan the step: positions to append per slot. Prompting slots
        // take a chunk of up to max_chunk positions from the shared
        // prefill budget (never less than one — progress is guaranteed);
        // decoding slots always take their single position.
        let mut need = vec![0usize; nslots];
        let mut budget = opts.prefill_chunk;
        for (si, slot) in slots.iter().enumerate() {
            let Some(st) = slot else { continue };
            if st.prompt_idx < st.prompt.len() {
                let remaining = st.prompt.len() - st.prompt_idx;
                let take = remaining.min(max_chunk).min(budget.max(1));
                budget = budget.saturating_sub(take);
                need[si] = take;
            } else {
                need[si] = 1;
            }
        }

        // let the backend reclaim KV memory; requeue its victims with
        // their generated tokens folded into the replay prompt
        for vi in backend.pre_step(&need) {
            let st = slots[vi].take().expect("victim slot was active");
            need[vi] = 0;
            preemptions += 1;
            let mut gen_prefix = st.gen_prefix;
            gen_prefix.extend_from_slice(&st.generated);
            let mut m = st.metrics;
            m.generated_tokens = gen_prefix.len();
            queue.push_front(Queued {
                req: st.req,
                gen_prefix,
                metrics: Some(m),
            });
        }
        if need.iter().all(|&n| n == 0) {
            // every admitted slot was immediately preempted: if this
            // persists, the front request (the requeued victim) cannot
            // fit in the pool at all — reject it and move on
            stalls += 1;
            if stalls > total_reqs + 2 {
                if let Some(q) = queue.pop_front() {
                    reject(q, &mut responses, &mut all_metrics);
                    rejected += 1;
                }
                stalls = 0;
            }
            continue;
        }
        stalls = 0;

        // build the work list (ascending slot order)
        let mut work: Vec<SlotWork> = Vec::new();
        for (si, slot) in slots.iter().enumerate() {
            if need[si] == 0 {
                continue;
            }
            let st = slot.as_ref().expect("need only set for occupied slots");
            if st.prompt_idx < st.prompt.len() {
                let take = need[si];
                let tokens =
                    st.prompt[st.prompt_idx..st.prompt_idx + take].to_vec();
                let want = st.prompt_idx + take >= st.prompt.len();
                prompt_positions += take;
                work.push(SlotWork { slot: si, tokens, want_logits: want });
            } else {
                let t = *st.generated.last().expect("generated nonempty");
                work.push(SlotWork {
                    slot: si,
                    tokens: vec![t],
                    want_logits: true,
                });
            }
        }

        let logits = backend.step(&work)?;
        debug_assert_eq!(logits.len(), work.len());
        steps += 1;
        peak_concurrency = peak_concurrency.max(work.len());

        // consume outputs
        for (wi, wk) in work.iter().enumerate() {
            let si = wk.slot;
            let finished = {
                let st = slots[si].as_mut().expect("worked slot occupied");
                if st.prompt_idx < st.prompt.len() {
                    st.prompt_idx += wk.tokens.len();
                }
                if wk.want_logits {
                    // this step's logits yield the next generated token
                    let next = forward::argmax(&logits[wi]) as i32;
                    st.generated.push(next);
                    st.metrics.generated_tokens =
                        st.gen_prefix.len() + st.generated.len();
                    if st.metrics.first_token.is_none() {
                        st.metrics.first_token = Some(Instant::now());
                    }
                }
                st.gen_prefix.len() + st.generated.len() >= st.req.max_new
                    || backend.slot_pos(si) + 1 >= ctx
            };
            if finished {
                let st = slots[si].take().expect("finished slot");
                backend.release_slot(si);
                let mut m = st.metrics;
                m.finished = Some(Instant::now());
                let mut tokens = st.gen_prefix;
                tokens.extend_from_slice(&st.generated);
                responses.push(Response { id: st.req.id, tokens });
                all_metrics.push(m);
            }
        }
    }

    let metrics = ServeMetrics {
        requests: all_metrics,
        decode_steps: steps,
        prompt_positions,
        wall_s: t_start.elapsed().as_secs_f64(),
        weight_bytes_per_step: backend.weight_bytes_per_step(),
        kv_bytes_per_step: backend.kv_bytes_per_step(),
        preemptions,
        rejected,
        peak_concurrency,
        kv: backend.pool_stats(),
    };
    responses.sort_by_key(|r| r.id);
    Ok((responses, metrics))
}

/// Map a slot-ordered work list onto engine step items (`seq` = index
/// within the work list) — shared by both native backends.
fn plan_from_work(work: &[SlotWork]) -> StepPlan {
    debug_assert!(
        work.windows(2).all(|w| w[0].slot < w[1].slot),
        "work must be in ascending slot order"
    );
    StepPlan {
        items: work
            .iter()
            .enumerate()
            .map(|(i, wk)| StepItem {
                seq: i,
                tokens: wk.tokens.clone(),
                logits: if wk.want_logits {
                    LogitsMode::Last
                } else {
                    LogitsMode::None
                },
            })
            .collect(),
    }
}

// ---------------------------------------------------------------------------
// native backend
// ---------------------------------------------------------------------------

pub struct NativeBackend<'a> {
    engine: Engine<'a>,
    caches: Vec<KvCache>,
}

impl<'a> NativeBackend<'a> {
    pub fn new(w: Weights<'a>, slots: usize) -> NativeBackend<'a> {
        let cfg = w.store().cfg;
        NativeBackend {
            engine: Engine::new(&w),
            caches: (0..slots).map(|_| KvCache::new(cfg)).collect(),
        }
    }
}

impl<'a> DecodeBackend for NativeBackend<'a> {
    fn slots(&self) -> usize {
        self.caches.len()
    }

    fn cfg(&self) -> ModelConfig {
        self.engine.cfg()
    }

    fn max_chunk(&self) -> usize {
        usize::MAX
    }

    fn step(&mut self, work: &[SlotWork]) -> Result<Vec<Vec<f32>>, String> {
        // one engine step over the whole active set: each linear's
        // weights stream once regardless of slots or chunk lengths
        let plan = plan_from_work(work);
        let wanted: Vec<usize> = work.iter().map(|wk| wk.slot).collect();
        let mut refs: Vec<&mut dyn KvSeq> = self
            .caches
            .iter_mut()
            .enumerate()
            .filter(|(si, _)| wanted.contains(si))
            .map(|(_, c)| c as &mut dyn KvSeq)
            .collect();
        let outs = self.engine.step(&plan, &mut SeqRefs(&mut refs));
        Ok(outs.into_iter().map(|m| m.data).collect())
    }

    fn reset_slot(&mut self, slot: usize) {
        self.caches[slot] = KvCache::new(self.cfg());
    }

    fn slot_pos(&self, slot: usize) -> usize {
        self.caches[slot].len
    }

    fn weight_bytes_per_step(&self) -> usize {
        // the engine's resolved plan is the ground truth for what
        // actually streams (packed codes, dense fallbacks, outliers)
        self.engine.weight_bytes_per_step()
    }

    fn kv_bytes_per_step(&self) -> usize {
        let c = self.cfg();
        // read whole cache + write one position, per layer, K and V
        c.layers * c.heads * c.ctx * c.head_dim() * 4 * 2
    }
}

// ---------------------------------------------------------------------------
// paged native backend
// ---------------------------------------------------------------------------

/// Which representation backs the paged KV blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvStoreKind {
    /// dense f32 — bit-exact with the contiguous [`NativeBackend`] path
    F32,
    /// per-(layer, head) 4-bit non-uniform codebooks, fitted on block
    /// fill with the GANQ machinery (~8x more blocks per byte)
    Lut4,
}

/// Native engine over the paged KV cache: dynamic admission (capacity is
/// the block pool, not the slot count), prefix sharing, CoW, LRU prefix
/// caching, and youngest-first preemption.
pub struct PagedNativeBackend<'a> {
    engine: Engine<'a>,
    kv: PagedKv,
}

impl<'a> PagedNativeBackend<'a> {
    /// `slots` bounds concurrency; real capacity is `num_blocks` blocks
    /// of `block_size` positions each.
    pub fn new(
        w: Weights<'a>,
        slots: usize,
        block_size: usize,
        num_blocks: usize,
        kind: KvStoreKind,
    ) -> PagedNativeBackend<'a> {
        let cfg = w.store().cfg;
        let layout = KvLayout::new(&cfg, block_size);
        let store: Box<dyn KvBlockStore> = match kind {
            KvStoreKind::F32 => Box::new(F32Blocks::new(layout, num_blocks)),
            KvStoreKind::Lut4 => {
                Box::new(LutBlocks::new(layout, num_blocks))
            }
        };
        PagedNativeBackend {
            engine: Engine::new(&w),
            kv: PagedKv::new(store, num_blocks, slots),
        }
    }

    /// Size the pool from a KV memory budget in bytes (at least one
    /// block).
    pub fn with_memory_budget(
        w: Weights<'a>,
        slots: usize,
        block_size: usize,
        kind: KvStoreKind,
        budget_bytes: usize,
    ) -> PagedNativeBackend<'a> {
        let layout = KvLayout::new(&w.store().cfg, block_size);
        let bpb = match kind {
            KvStoreKind::F32 => F32Blocks::bytes_per_block_for(layout),
            KvStoreKind::Lut4 => LutBlocks::bytes_per_block_for(layout),
        };
        let num_blocks = (budget_bytes / bpb).max(1);
        PagedNativeBackend::new(w, slots, block_size, num_blocks, kind)
    }

    pub fn kv(&self) -> &PagedKv {
        &self.kv
    }
}

impl<'a> DecodeBackend for PagedNativeBackend<'a> {
    fn slots(&self) -> usize {
        self.kv.num_slots()
    }

    fn cfg(&self) -> ModelConfig {
        self.engine.cfg()
    }

    fn max_chunk(&self) -> usize {
        usize::MAX
    }

    fn step(&mut self, work: &[SlotWork]) -> Result<Vec<Vec<f32>>, String> {
        // one engine step over the admitted set; slot views are handed
        // to the engine one at a time (they alias the shared block pool)
        for wk in work {
            self.kv.push_tokens(wk.slot, &wk.tokens);
        }
        let plan = plan_from_work(work);
        let slots: Vec<usize> = work.iter().map(|wk| wk.slot).collect();
        let mut seqs = self.kv.seqs(slots);
        let outs = self.engine.step(&plan, &mut seqs);
        Ok(outs.into_iter().map(|m| m.data).collect())
    }

    fn reset_slot(&mut self, slot: usize) {
        self.kv.release(slot);
    }

    fn slot_pos(&self, slot: usize) -> usize {
        self.kv.pos(slot)
    }

    fn weight_bytes_per_step(&self) -> usize {
        self.engine.weight_bytes_per_step()
    }

    fn kv_bytes_per_step(&self) -> usize {
        // peak resident block bytes — the paged analogue of the
        // contiguous backends' ctx-sized per-slot caches (sampled at end
        // of run, when current occupancy is just prefix-cache residue)
        self.kv.bytes_per_block() * self.kv.stats().peak_blocks_in_use
    }

    fn admit(
        &mut self,
        slot: usize,
        prompt: &[i32],
        max_new: usize,
    ) -> Option<usize> {
        self.kv.release(slot);
        self.kv.admit(slot, prompt, max_new)
    }

    fn pre_step(&mut self, need: &[usize]) -> Vec<usize> {
        self.kv.prepare_step_n(need)
    }

    fn release_slot(&mut self, slot: usize) {
        self.kv.release(slot);
    }

    fn pool_stats(&self) -> Option<KvPoolStats> {
        Some(self.kv.stats())
    }
}

// ---------------------------------------------------------------------------
// HLO backend
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightFmt {
    Fp32,
    Lut4,
    Lut3,
}

impl WeightFmt {
    pub fn tag(&self) -> &'static str {
        match self {
            WeightFmt::Fp32 => "fp32",
            WeightFmt::Lut4 => "lut4",
            WeightFmt::Lut3 => "lut3",
        }
    }

    pub fn bits(&self) -> u8 {
        match self {
            WeightFmt::Fp32 => 32,
            WeightFmt::Lut4 => 4,
            WeightFmt::Lut3 => 3,
        }
    }
}

/// Weight argument list for the LUT serving graphs (lut_param_spec order):
/// quantizable linears as (qp u8 [m, n/2], t f32 [m, 2^bits]).
pub fn weight_tensors_lut(
    cfg: &ModelConfig,
    qm: &QuantizedModel,
    bits: u8,
) -> Result<Vec<HostTensor>, String> {
    let k = 1usize << bits;
    let quant_names: std::collections::BTreeSet<String> = cfg
        .linear_shapes()
        .into_iter()
        .map(|(n, _, _)| n)
        .collect();
    let mut out = Vec::new();
    for (name, shape) in cfg.param_spec() {
        if quant_names.contains(&name) {
            let lut = match qm.linears.get(&name) {
                Some(LayerWeights::Lut(l)) => l,
                Some(LayerWeights::LutSparse(..)) => {
                    return Err(format!(
                        "{}: dense+sparse models (GANQ*/SqueezeLLM) need \
                         the sparse branch — serve via NativeBackend",
                        name
                    ))
                }
                _ => {
                    return Err(format!(
                        "{} has no LUT form (method {})",
                        name, qm.method
                    ))
                }
            };
            if lut.bits != bits {
                return Err(format!(
                    "{}: lut bits {} != graph bits {}",
                    name, lut.bits, bits
                ));
            }
            let (m, n) = (shape[0], shape[1]);
            out.push(HostTensor::U8(
                vec![m, n.div_ceil(2)],
                lut.packed_nibbles(),
            ));
            out.push(HostTensor::F32(vec![m, k], lut.codebook.data.clone()));
        } else {
            let t = qm.base.get(&name);
            out.push(HostTensor::F32(t.shape.clone(), t.data.clone()));
        }
    }
    Ok(out)
}

pub struct HloBackend<'a> {
    rt: &'a Runtime,
    graph: String,
    cfg: ModelConfig,
    b: usize,
    kcache: HostTensor,
    vcache: HostTensor,
    pos: Vec<usize>,
    weights: Vec<HostTensor>,
    resident: Option<Vec<xla::PjRtBuffer>>,
    weight_bytes: usize,
}

impl<'a> HloBackend<'a> {
    /// Build for `decode_{fmt}_{model}_b{B}`. `resident` stages weights as
    /// device buffers once (the optimized path).
    pub fn new(
        rt: &'a Runtime,
        model: &str,
        fmt: WeightFmt,
        b: usize,
        store: &WeightStore,
        qm: Option<&QuantizedModel>,
        resident: bool,
    ) -> Result<HloBackend<'a>, String> {
        let entry = rt
            .manifest
            .models
            .get(model)
            .ok_or_else(|| format!("unknown model {}", model))?;
        let cfg = entry.config;
        let graph =
            format!("decode_{}_{}_b{}", fmt.tag(), entry.base_config, b);
        if !rt.has_graph(&graph) {
            return Err(format!("graph {} not in artifacts", graph));
        }
        let weights = match fmt {
            WeightFmt::Fp32 => {
                crate::eval::weight_tensors_fp32(&cfg, store, qm)
            }
            WeightFmt::Lut4 | WeightFmt::Lut3 => weight_tensors_lut(
                &cfg,
                qm.ok_or("LUT format requires a quantized model")?,
                fmt.bits(),
            )?,
        };
        let weight_bytes = match (fmt, qm) {
            (WeightFmt::Fp32, _) => cfg
                .linear_shapes()
                .iter()
                .map(|(_, m, n)| m * n * 4)
                .sum(),
            (_, Some(q)) => q
                .linears
                .values()
                .map(|lw| match lw {
                    LayerWeights::Lut(l) => l.bytes_per_decode(),
                    LayerWeights::LutSparse(l, s) => {
                        l.bytes_per_decode() + s.storage_bytes()
                    }
                    LayerWeights::Dense(m) => m.data.len() * 4,
                })
                .sum(),
            _ => 0,
        };
        let cache_dims = vec![
            cfg.layers,
            b,
            cfg.heads,
            cfg.ctx,
            cfg.head_dim(),
        ];
        let cache_len: usize = cache_dims.iter().product();
        let resident_bufs = if resident {
            Some(rt.stage(&weights)?)
        } else {
            None
        };
        Ok(HloBackend {
            rt,
            graph,
            cfg,
            b,
            kcache: HostTensor::F32(cache_dims.clone(), vec![0.0; cache_len]),
            vcache: HostTensor::F32(cache_dims, vec![0.0; cache_len]),
            pos: vec![0; b],
            weights,
            resident: resident_bufs,
            weight_bytes,
        })
    }
}

impl<'a> HloBackend<'a> {
    /// Variant constructor with an explicit graph name (used by the
    /// pallas-kernel serving graph, which shares the lut4 signature).
    pub fn new_with_graph(
        rt: &'a Runtime,
        model: &str,
        graph: &str,
        b: usize,
        store: &WeightStore,
        qm: Option<&QuantizedModel>,
    ) -> Result<HloBackend<'a>, String> {
        let mut be =
            HloBackend::new(rt, model, WeightFmt::Lut4, b, store, qm, false)?;
        if !rt.has_graph(graph) {
            return Err(format!("graph {} not in artifacts", graph));
        }
        be.graph = graph.to_string();
        Ok(be)
    }
}

impl<'a> DecodeBackend for HloBackend<'a> {
    fn slots(&self) -> usize {
        self.b
    }

    fn cfg(&self) -> ModelConfig {
        self.cfg
    }

    fn step(&mut self, work: &[SlotWork]) -> Result<Vec<Vec<f32>>, String> {
        // inactive slots write to the scratch position ctx-1 (overwritten
        // before any real read — see module docs)
        let mut tok = vec![0i32; self.b];
        let mut active = vec![false; self.b];
        for wk in work {
            if wk.tokens.len() != 1 {
                return Err(
                    "decode graphs advance one position per slot".into()
                );
            }
            tok[wk.slot] = wk.tokens[0];
            active[wk.slot] = true;
        }
        let pos: Vec<i32> = (0..self.b)
            .map(|i| {
                if active[i] {
                    self.pos[i] as i32
                } else {
                    (self.cfg.ctx - 1) as i32
                }
            })
            .collect();
        let head = [
            HostTensor::I32(vec![self.b], tok),
            HostTensor::I32(vec![self.b], pos),
            self.kcache.clone(),
            self.vcache.clone(),
        ];
        let out = match &self.resident {
            Some(bufs) => {
                self.rt.run_with_resident(&self.graph, &head, bufs)?
            }
            None => {
                let mut inputs = head.to_vec();
                inputs.extend(self.weights.iter().cloned());
                self.rt.run(&self.graph, &inputs)?
            }
        };
        if out.len() != 3 {
            return Err(format!("decode returned {} outputs", out.len()));
        }
        let logits_flat = out[0].as_f32()?;
        let vocab = self.cfg.vocab;
        self.kcache = out[1].clone();
        self.vcache = out[2].clone();
        for i in 0..self.b {
            if active[i] {
                self.pos[i] += 1;
            }
        }
        Ok(work
            .iter()
            .map(|wk| {
                if wk.want_logits {
                    logits_flat[wk.slot * vocab..(wk.slot + 1) * vocab]
                        .to_vec()
                } else {
                    Vec::new()
                }
            })
            .collect())
    }

    fn reset_slot(&mut self, slot: usize) {
        self.pos[slot] = 0;
    }

    fn slot_pos(&self, slot: usize) -> usize {
        self.pos[slot]
    }

    fn weight_bytes_per_step(&self) -> usize {
        self.weight_bytes
    }

    fn kv_bytes_per_step(&self) -> usize {
        self.cfg.layers
            * self.b
            * self.cfg.heads
            * self.cfg.ctx
            * self.cfg.head_dim()
            * 4
            * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::WeightStore;

    fn backend() -> (WeightStore, Vec<Request>) {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 31);
        let reqs = vec![
            Request { id: 1, prompt: vec![104, 105], max_new: 4 },
            Request { id: 2, prompt: vec![97, 98, 99], max_new: 6 },
            Request { id: 3, prompt: vec![120], max_new: 3 },
        ];
        (store, reqs)
    }

    #[test]
    fn native_continuous_batching_completes_all() {
        let (store, reqs) = backend();
        let w = Weights::Fp(&store);
        let mut be = NativeBackend::new(w, 2); // 3 reqs through 2 slots
        let (resp, metrics) = serve(&mut be, reqs).unwrap();
        assert_eq!(resp.len(), 3);
        assert_eq!(resp[0].tokens.len(), 4);
        assert_eq!(resp[1].tokens.len(), 6);
        assert_eq!(resp[2].tokens.len(), 3);
        assert_eq!(metrics.total_generated(), 13);
        assert!(metrics.decode_steps > 0);
        assert!(metrics.weight_bytes_per_step > 0);
        assert!(metrics.prompt_positions >= 6, "prompts fed through steps");
    }

    #[test]
    fn batched_output_matches_sequential_generation() {
        let (store, reqs) = backend();
        let w = Weights::Fp(&store);
        let mut be = NativeBackend::new(w, 3);
        let (resp, _) = serve(&mut be, reqs.clone()).unwrap();
        for r in &reqs {
            let w2 = Weights::Fp(&store);
            let expect =
                forward::generate_greedy(&w2, &r.prompt, r.max_new);
            let got = &resp
                .iter()
                .find(|x| x.id == r.id)
                .unwrap()
                .tokens;
            assert_eq!(got, &expect, "req {}", r.id);
        }
    }

    #[test]
    fn chunked_prefill_serving_matches_per_token() {
        // the same workload served with per-token prefill (chunk=1),
        // modest chunks, and the default budget must produce identical
        // greedy outputs on dense KV — chunking changes wall clock, not
        // math
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 37);
        let reqs: Vec<Request> = (0..4)
            .map(|i| Request {
                id: i,
                prompt: (0..40 + i as i32 * 7)
                    .map(|j| (j * 13 + i as i32) % 256)
                    .collect(),
                max_new: 5,
            })
            .collect();
        let serve_chunk = |chunk: usize| {
            let w = Weights::Fp(&store);
            let mut be = NativeBackend::new(w, 2);
            serve_with(
                &mut be,
                reqs.clone(),
                ServeOptions { prefill_chunk: chunk },
            )
            .unwrap()
        };
        let (resp_1, m_1) = serve_chunk(1);
        let (resp_16, m_16) = serve_chunk(16);
        let (resp_def, _) = serve_chunk(DEFAULT_PREFILL_CHUNK);
        for (a, b) in resp_1.iter().zip(&resp_16) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "req {}", a.id);
        }
        for (a, b) in resp_1.iter().zip(&resp_def) {
            assert_eq!(a.tokens, b.tokens, "req {}", a.id);
        }
        // chunked prefill takes strictly fewer steps for the same work
        assert!(m_16.decode_steps < m_1.decode_steps);
        assert_eq!(m_16.prompt_positions, m_1.prompt_positions);
        assert!(m_16.prompt_positions_per_step() > 1.0);
    }

    #[test]
    fn chunked_prefill_paged_matches_contiguous() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 38);
        let reqs: Vec<Request> = (0..3)
            .map(|i| Request {
                id: i,
                prompt: (0..30).map(|j| (j * 7 + i as i32) % 256).collect(),
                max_new: 4,
            })
            .collect();
        let w = Weights::Fp(&store);
        let mut be = NativeBackend::new(w, 3);
        let (resp_c, _) = serve(&mut be, reqs.clone()).unwrap();
        let w2 = Weights::Fp(&store);
        let mut bp =
            PagedNativeBackend::new(w2, 3, 4, 64, KvStoreKind::F32);
        let (resp_p, m) = serve_with(
            &mut bp,
            reqs,
            ServeOptions { prefill_chunk: 16 },
        )
        .unwrap();
        for (c, p) in resp_c.iter().zip(&resp_p) {
            assert_eq!(c.id, p.id);
            assert_eq!(c.tokens, p.tokens, "req {}", c.id);
        }
        assert!(m.kv.unwrap().sealed_blocks > 0);
    }

    #[test]
    fn paged_f32_serving_matches_contiguous_native() {
        let (store, reqs) = backend();
        let w = Weights::Fp(&store);
        let mut be = NativeBackend::new(w, 3);
        let (resp_c, _) = serve(&mut be, reqs.clone()).unwrap();

        let w2 = Weights::Fp(&store);
        let mut bp =
            PagedNativeBackend::new(w2, 3, 4, 64, KvStoreKind::F32);
        let (resp_p, m) = serve(&mut bp, reqs).unwrap();
        assert_eq!(resp_c.len(), resp_p.len());
        for (c, p) in resp_c.iter().zip(&resp_p) {
            assert_eq!(c.id, p.id);
            assert_eq!(c.tokens, p.tokens, "req {}", c.id);
        }
        let kv = m.kv.expect("paged backend reports pool stats");
        assert!(kv.sealed_blocks > 0);
        assert!(kv.peak_blocks_in_use > 0);
    }

    #[test]
    fn paged_preemption_preserves_greedy_output() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 33);
        let reqs: Vec<Request> = (0..4)
            .map(|i| Request {
                id: i,
                prompt: vec![10 + i as i32, 20, 30],
                max_new: 12,
            })
            .collect();
        let w = Weights::Fp(&store);
        let mut be = NativeBackend::new(w, 4);
        let (expect, _) = serve(&mut be, reqs.clone()).unwrap();

        // a pool too small for 4 full requests forces preemption
        let w2 = Weights::Fp(&store);
        let mut bp =
            PagedNativeBackend::new(w2, 4, 4, 8, KvStoreKind::F32);
        let (got, m) = serve(&mut bp, reqs).unwrap();
        assert_eq!(expect.len(), got.len());
        for (e, g) in expect.iter().zip(&got) {
            assert_eq!(e.tokens, g.tokens, "req {}", e.id);
        }
        // with 8 blocks and 4 requests needing 4 blocks each, someone
        // must have been preempted or queued; either way all finished
        assert!(m.preemptions > 0 || m.peak_concurrency < 4);
    }

    #[test]
    fn unserveable_request_is_rejected_not_fatal() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 35);
        // 2-block pool (bs 4): a 12-token prompt can never fit, the
        // 2-token one can
        let reqs = vec![
            Request { id: 1, prompt: (0..12).collect(), max_new: 4 },
            Request { id: 2, prompt: vec![7, 8], max_new: 3 },
        ];
        let w = Weights::Fp(&store);
        let mut bp =
            PagedNativeBackend::new(w, 2, 4, 2, KvStoreKind::F32);
        let (resp, m) = serve(&mut bp, reqs).unwrap();
        assert_eq!(resp.len(), 2);
        assert!(resp[0].tokens.is_empty(), "oversized req rejected");
        assert_eq!(resp[1].tokens.len(), 3, "small req still served");
        assert_eq!(m.rejected, 1);
    }

    #[test]
    fn paged_prefix_sharing_reports_hits() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 34);
        let shared: Vec<i32> = (0..8).collect();
        let reqs: Vec<Request> = (0..3)
            .map(|i| Request {
                id: i,
                prompt: shared.clone(),
                max_new: 4,
            })
            .collect();
        let w = Weights::Fp(&store);
        let mut bp =
            PagedNativeBackend::new(w, 1, 4, 32, KvStoreKind::F32);
        // one slot: requests run serially, later ones hit the cached
        // prefix left by the first
        let (resp, m) = serve(&mut bp, reqs).unwrap();
        assert_eq!(resp.len(), 3);
        assert_eq!(resp[0].tokens, resp[1].tokens);
        assert_eq!(resp[0].tokens, resp[2].tokens);
        let kv = m.kv.unwrap();
        assert!(
            kv.prefix_hit_tokens >= 8,
            "expected shared-prefix hits, got {:?}",
            kv
        );
        assert!(kv.prefix_hit_rate() > 0.0);
    }

    #[test]
    fn oversized_prompt_is_truncated() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 32);
        let w = Weights::Fp(&store);
        let mut be = NativeBackend::new(w, 1);
        let reqs = vec![Request {
            id: 1,
            prompt: (0..300).map(|i| i % 256).collect(),
            max_new: 5,
        }];
        let (resp, _) = serve(&mut be, reqs).unwrap();
        assert_eq!(resp[0].tokens.len(), 5);
    }
}
