//! Serving: token-level continuous batching (Orca-style) over a decode
//! backend. Two backends implement the same scheduler contract:
//!
//! * [`HloBackend`] — the AOT decode graph via PJRT (`decode_{fmt}_{model}
//!   _b{B}`), per-slot positions as a vector input, KV caches threaded
//!   through the graph outputs; weights optionally staged as device-
//!   resident buffers (the §Perf optimization).
//! * [`NativeBackend`] — the pure-Rust forward path (works without
//!   artifacts; also the reference for cross-checking the HLO path).
//!
//! The scheduler admits requests into free slots, feeds one token per slot
//! per step (prompt tokens first — "prefill as decode" keeps the graph set
//! small; exact-size prefill graphs exist for the common 16/32-token
//! prompts and are used by the latency bench), and collects per-request
//! latency metrics.

use std::time::Instant;

use crate::model::forward::{self, KvCache, Weights};
use crate::model::{LayerWeights, ModelConfig, QuantizedModel, WeightStore};
use crate::runtime::{HostTensor, Runtime};

use super::metrics::{RequestMetrics, ServeMetrics};

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
}

pub trait DecodeBackend {
    fn slots(&self) -> usize;
    fn cfg(&self) -> ModelConfig;
    /// Advance every active slot by one token; returns logits per slot.
    fn step(
        &mut self,
        tok: &[i32],
        active: &[bool],
    ) -> Result<Vec<Vec<f32>>, String>;
    fn reset_slot(&mut self, slot: usize);
    fn slot_pos(&self, slot: usize) -> usize;
    fn weight_bytes_per_step(&self) -> usize;
    fn kv_bytes_per_step(&self) -> usize;
}

// ---------------------------------------------------------------------------
// scheduler
// ---------------------------------------------------------------------------

struct SlotState {
    req: Request,
    prompt_idx: usize,
    generated: Vec<i32>,
    metrics: RequestMetrics,
}

/// Serve a batch of requests to completion with continuous batching.
pub fn serve(
    backend: &mut dyn DecodeBackend,
    requests: Vec<Request>,
) -> Result<(Vec<Response>, ServeMetrics), String> {
    let nslots = backend.slots();
    let ctx = backend.cfg().ctx;
    let t_start = Instant::now();
    let mut queue: std::collections::VecDeque<Request> = requests
        .into_iter()
        .map(|mut r| {
            // left-truncate prompts that cannot fit with generation room
            let budget = ctx.saturating_sub(r.max_new + 1).max(1);
            if r.prompt.len() > budget {
                r.prompt = r.prompt[r.prompt.len() - budget..].to_vec();
            }
            r
        })
        .collect();
    let mut slots: Vec<Option<SlotState>> =
        (0..nslots).map(|_| None).collect();
    let mut done: Vec<(Vec<Response>, RequestMetrics)> = Vec::new();
    let mut responses = Vec::new();
    let mut all_metrics = Vec::new();
    let mut steps = 0usize;

    loop {
        // admit
        for (si, slot) in slots.iter_mut().enumerate() {
            if slot.is_none() {
                if let Some(req) = queue.pop_front() {
                    backend.reset_slot(si);
                    let m = RequestMetrics {
                        id: req.id,
                        prompt_tokens: req.prompt.len(),
                        generated_tokens: 0,
                        enqueued: Instant::now(),
                        first_token: None,
                        finished: None,
                    };
                    *slot = Some(SlotState {
                        req,
                        prompt_idx: 0,
                        generated: Vec::new(),
                        metrics: m,
                    });
                }
            }
        }
        if slots.iter().all(|s| s.is_none()) {
            break;
        }

        // build step inputs
        let mut tok = vec![0i32; nslots];
        let mut active = vec![false; nslots];
        for (si, slot) in slots.iter().enumerate() {
            if let Some(st) = slot {
                active[si] = true;
                tok[si] = if st.prompt_idx < st.req.prompt.len() {
                    st.req.prompt[st.prompt_idx]
                } else {
                    *st.generated.last().expect("generated nonempty")
                };
            }
        }
        let logits = backend.step(&tok, &active)?;
        steps += 1;

        // consume outputs
        for (si, slot) in slots.iter_mut().enumerate() {
            let finished = if let Some(st) = slot.as_mut() {
                if st.prompt_idx < st.req.prompt.len() {
                    st.prompt_idx += 1;
                }
                if st.prompt_idx >= st.req.prompt.len() {
                    // this step's logits yield the next generated token
                    let next = forward::argmax(&logits[si]) as i32;
                    st.generated.push(next);
                    st.metrics.generated_tokens = st.generated.len();
                    if st.metrics.first_token.is_none() {
                        st.metrics.first_token = Some(Instant::now());
                    }
                }
                st.generated.len() >= st.req.max_new
                    || backend.slot_pos(si) + 1 >= ctx
            } else {
                false
            };
            if finished {
                let st = slot.take().unwrap();
                let mut m = st.metrics;
                m.finished = Some(Instant::now());
                responses.push(Response { id: st.req.id, tokens: st.generated });
                all_metrics.push(m);
            }
        }
    }
    let _ = &mut done;

    let metrics = ServeMetrics {
        requests: all_metrics,
        decode_steps: steps,
        wall_s: t_start.elapsed().as_secs_f64(),
        weight_bytes_per_step: backend.weight_bytes_per_step(),
        kv_bytes_per_step: backend.kv_bytes_per_step(),
    };
    responses.sort_by_key(|r| r.id);
    Ok((responses, metrics))
}

// ---------------------------------------------------------------------------
// native backend
// ---------------------------------------------------------------------------

pub struct NativeBackend<'a> {
    w: Weights<'a>,
    caches: Vec<KvCache>,
    weight_bytes: usize,
}

impl<'a> NativeBackend<'a> {
    pub fn new(w: Weights<'a>, slots: usize) -> NativeBackend<'a> {
        let cfg = w.store().cfg;
        let weight_bytes = weight_bytes_of(&w);
        NativeBackend {
            w,
            caches: (0..slots).map(|_| KvCache::new(cfg)).collect(),
            weight_bytes,
        }
    }
}

fn weight_bytes_of(w: &Weights) -> usize {
    let store = w.store();
    match w {
        Weights::Fp(_) => store
            .cfg
            .linear_shapes()
            .iter()
            .map(|(_, m, n)| m * n * 4)
            .sum(),
        Weights::Quant(q) => q
            .linears
            .values()
            .map(|lw| match lw {
                LayerWeights::Dense(m) => m.data.len() * 4,
                LayerWeights::Lut(l) => l.bytes_per_decode(),
                LayerWeights::LutSparse(l, s) => {
                    l.bytes_per_decode() + s.storage_bytes()
                }
            })
            .sum(),
    }
}

impl<'a> DecodeBackend for NativeBackend<'a> {
    fn slots(&self) -> usize {
        self.caches.len()
    }

    fn cfg(&self) -> ModelConfig {
        self.w.store().cfg
    }

    fn step(
        &mut self,
        tok: &[i32],
        active: &[bool],
    ) -> Result<Vec<Vec<f32>>, String> {
        let vocab = self.cfg().vocab;
        let mut out = Vec::with_capacity(tok.len());
        for si in 0..tok.len() {
            if active[si] {
                out.push(forward::decode_step(
                    &self.w,
                    tok[si],
                    &mut self.caches[si],
                ));
            } else {
                out.push(vec![0.0; vocab]);
            }
        }
        Ok(out)
    }

    fn reset_slot(&mut self, slot: usize) {
        self.caches[slot] = KvCache::new(self.cfg());
    }

    fn slot_pos(&self, slot: usize) -> usize {
        self.caches[slot].len
    }

    fn weight_bytes_per_step(&self) -> usize {
        self.weight_bytes
    }

    fn kv_bytes_per_step(&self) -> usize {
        let c = self.cfg();
        // read whole cache + write one position, per layer, K and V
        c.layers * c.heads * c.ctx * c.head_dim() * 4 * 2
    }
}

// ---------------------------------------------------------------------------
// HLO backend
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightFmt {
    Fp32,
    Lut4,
    Lut3,
}

impl WeightFmt {
    pub fn tag(&self) -> &'static str {
        match self {
            WeightFmt::Fp32 => "fp32",
            WeightFmt::Lut4 => "lut4",
            WeightFmt::Lut3 => "lut3",
        }
    }

    pub fn bits(&self) -> u8 {
        match self {
            WeightFmt::Fp32 => 32,
            WeightFmt::Lut4 => 4,
            WeightFmt::Lut3 => 3,
        }
    }
}

/// Weight argument list for the LUT serving graphs (lut_param_spec order):
/// quantizable linears as (qp u8 [m, n/2], t f32 [m, 2^bits]).
pub fn weight_tensors_lut(
    cfg: &ModelConfig,
    qm: &QuantizedModel,
    bits: u8,
) -> Result<Vec<HostTensor>, String> {
    let k = 1usize << bits;
    let quant_names: std::collections::BTreeSet<String> = cfg
        .linear_shapes()
        .into_iter()
        .map(|(n, _, _)| n)
        .collect();
    let mut out = Vec::new();
    for (name, shape) in cfg.param_spec() {
        if quant_names.contains(&name) {
            let lut = match qm.linears.get(&name) {
                Some(LayerWeights::Lut(l)) => l,
                Some(LayerWeights::LutSparse(..)) => {
                    return Err(format!(
                        "{}: dense+sparse models (GANQ*/SqueezeLLM) need \
                         the sparse branch — serve via NativeBackend",
                        name
                    ))
                }
                _ => {
                    return Err(format!(
                        "{} has no LUT form (method {})",
                        name, qm.method
                    ))
                }
            };
            if lut.bits != bits {
                return Err(format!(
                    "{}: lut bits {} != graph bits {}",
                    name, lut.bits, bits
                ));
            }
            let (m, n) = (shape[0], shape[1]);
            out.push(HostTensor::U8(vec![m, n / 2], lut.packed_nibbles()));
            out.push(HostTensor::F32(vec![m, k], lut.codebook.data.clone()));
        } else {
            let t = qm.base.get(&name);
            out.push(HostTensor::F32(t.shape.clone(), t.data.clone()));
        }
    }
    Ok(out)
}

pub struct HloBackend<'a> {
    rt: &'a Runtime,
    graph: String,
    cfg: ModelConfig,
    b: usize,
    kcache: HostTensor,
    vcache: HostTensor,
    pos: Vec<usize>,
    weights: Vec<HostTensor>,
    resident: Option<Vec<xla::PjRtBuffer>>,
    weight_bytes: usize,
}

impl<'a> HloBackend<'a> {
    /// Build for `decode_{fmt}_{model}_b{B}`. `resident` stages weights as
    /// device buffers once (the optimized path).
    pub fn new(
        rt: &'a Runtime,
        model: &str,
        fmt: WeightFmt,
        b: usize,
        store: &WeightStore,
        qm: Option<&QuantizedModel>,
        resident: bool,
    ) -> Result<HloBackend<'a>, String> {
        let entry = rt
            .manifest
            .models
            .get(model)
            .ok_or_else(|| format!("unknown model {}", model))?;
        let cfg = entry.config;
        let graph =
            format!("decode_{}_{}_b{}", fmt.tag(), entry.base_config, b);
        if !rt.has_graph(&graph) {
            return Err(format!("graph {} not in artifacts", graph));
        }
        let weights = match fmt {
            WeightFmt::Fp32 => {
                crate::eval::weight_tensors_fp32(&cfg, store, qm)
            }
            WeightFmt::Lut4 | WeightFmt::Lut3 => weight_tensors_lut(
                &cfg,
                qm.ok_or("LUT format requires a quantized model")?,
                fmt.bits(),
            )?,
        };
        let weight_bytes = match (fmt, qm) {
            (WeightFmt::Fp32, _) => cfg
                .linear_shapes()
                .iter()
                .map(|(_, m, n)| m * n * 4)
                .sum(),
            (_, Some(q)) => q
                .linears
                .values()
                .map(|lw| match lw {
                    LayerWeights::Lut(l) => l.bytes_per_decode(),
                    LayerWeights::LutSparse(l, s) => {
                        l.bytes_per_decode() + s.storage_bytes()
                    }
                    LayerWeights::Dense(m) => m.data.len() * 4,
                })
                .sum(),
            _ => 0,
        };
        let cache_dims = vec![
            cfg.layers,
            b,
            cfg.heads,
            cfg.ctx,
            cfg.head_dim(),
        ];
        let cache_len: usize = cache_dims.iter().product();
        let resident_bufs = if resident {
            Some(rt.stage(&weights)?)
        } else {
            None
        };
        Ok(HloBackend {
            rt,
            graph,
            cfg,
            b,
            kcache: HostTensor::F32(cache_dims.clone(), vec![0.0; cache_len]),
            vcache: HostTensor::F32(cache_dims, vec![0.0; cache_len]),
            pos: vec![0; b],
            weights,
            resident: resident_bufs,
            weight_bytes,
        })
    }
}

impl<'a> HloBackend<'a> {
    /// Variant constructor with an explicit graph name (used by the
    /// pallas-kernel serving graph, which shares the lut4 signature).
    pub fn new_with_graph(
        rt: &'a Runtime,
        model: &str,
        graph: &str,
        b: usize,
        store: &WeightStore,
        qm: Option<&QuantizedModel>,
    ) -> Result<HloBackend<'a>, String> {
        let mut be =
            HloBackend::new(rt, model, WeightFmt::Lut4, b, store, qm, false)?;
        if !rt.has_graph(graph) {
            return Err(format!("graph {} not in artifacts", graph));
        }
        be.graph = graph.to_string();
        Ok(be)
    }
}

impl<'a> DecodeBackend for HloBackend<'a> {
    fn slots(&self) -> usize {
        self.b
    }

    fn cfg(&self) -> ModelConfig {
        self.cfg
    }

    fn step(
        &mut self,
        tok: &[i32],
        active: &[bool],
    ) -> Result<Vec<Vec<f32>>, String> {
        assert_eq!(tok.len(), self.b);
        // inactive slots write to the scratch position ctx-1 (overwritten
        // before any real read — see module docs)
        let pos: Vec<i32> = (0..self.b)
            .map(|i| {
                if active[i] {
                    self.pos[i] as i32
                } else {
                    (self.cfg.ctx - 1) as i32
                }
            })
            .collect();
        let head = [
            HostTensor::I32(vec![self.b], tok.to_vec()),
            HostTensor::I32(vec![self.b], pos),
            self.kcache.clone(),
            self.vcache.clone(),
        ];
        let out = match &self.resident {
            Some(bufs) => {
                self.rt.run_with_resident(&self.graph, &head, bufs)?
            }
            None => {
                let mut inputs = head.to_vec();
                inputs.extend(self.weights.iter().cloned());
                self.rt.run(&self.graph, &inputs)?
            }
        };
        if out.len() != 3 {
            return Err(format!("decode returned {} outputs", out.len()));
        }
        let logits_flat = out[0].as_f32();
        let vocab = self.cfg.vocab;
        let logits: Vec<Vec<f32>> = (0..self.b)
            .map(|i| logits_flat[i * vocab..(i + 1) * vocab].to_vec())
            .collect();
        self.kcache = out[1].clone();
        self.vcache = out[2].clone();
        for i in 0..self.b {
            if active[i] {
                self.pos[i] += 1;
            }
        }
        Ok(logits)
    }

    fn reset_slot(&mut self, slot: usize) {
        self.pos[slot] = 0;
    }

    fn slot_pos(&self, slot: usize) -> usize {
        self.pos[slot]
    }

    fn weight_bytes_per_step(&self) -> usize {
        self.weight_bytes
    }

    fn kv_bytes_per_step(&self) -> usize {
        self.cfg.layers
            * self.b
            * self.cfg.heads
            * self.cfg.ctx
            * self.cfg.head_dim()
            * 4
            * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::WeightStore;

    fn backend() -> (WeightStore, Vec<Request>) {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 31);
        let reqs = vec![
            Request { id: 1, prompt: vec![104, 105], max_new: 4 },
            Request { id: 2, prompt: vec![97, 98, 99], max_new: 6 },
            Request { id: 3, prompt: vec![120], max_new: 3 },
        ];
        (store, reqs)
    }

    #[test]
    fn native_continuous_batching_completes_all() {
        let (store, reqs) = backend();
        let w = Weights::Fp(&store);
        let mut be = NativeBackend::new(w, 2); // 3 reqs through 2 slots
        let (resp, metrics) = serve(&mut be, reqs).unwrap();
        assert_eq!(resp.len(), 3);
        assert_eq!(resp[0].tokens.len(), 4);
        assert_eq!(resp[1].tokens.len(), 6);
        assert_eq!(resp[2].tokens.len(), 3);
        assert_eq!(metrics.total_generated(), 13);
        assert!(metrics.decode_steps > 0);
        assert!(metrics.weight_bytes_per_step > 0);
    }

    #[test]
    fn batched_output_matches_sequential_generation() {
        let (store, reqs) = backend();
        let w = Weights::Fp(&store);
        let mut be = NativeBackend::new(w, 3);
        let (resp, _) = serve(&mut be, reqs.clone()).unwrap();
        for r in &reqs {
            let w2 = Weights::Fp(&store);
            let expect =
                forward::generate_greedy(&w2, &r.prompt, r.max_new);
            let got = &resp
                .iter()
                .find(|x| x.id == r.id)
                .unwrap()
                .tokens;
            assert_eq!(got, &expect, "req {}", r.id);
        }
    }

    #[test]
    fn oversized_prompt_is_truncated() {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        let store = WeightStore::random("t", cfg, 32);
        let w = Weights::Fp(&store);
        let mut be = NativeBackend::new(w, 1);
        let reqs = vec![Request {
            id: 1,
            prompt: (0..300).map(|i| i % 256).collect(),
            max_new: 5,
        }];
        let (resp, _) = serve(&mut be, reqs).unwrap();
        assert_eq!(resp[0].tokens.len(), 5);
    }
}
