//! Observability: the scoreboard layer for the serving stack.
//!
//! Two halves, one contract — *always compiled, near-zero cost when
//! off*:
//!
//! - [`trace`] — structured step tracing. The scheduler
//!   ([`crate::coordinator::serve`]), the dense engine
//!   ([`crate::model::forward`]), the paged KV pool
//!   ([`crate::kv::paged`]), and the PJRT dispatch path
//!   ([`crate::runtime`]) emit spans/instants/counters through a
//!   thread-local ring recorder; `serve --trace-out trace.json` exports
//!   Chrome `trace_event` JSON viewable in Perfetto. The cluster router
//!   ([`crate::coordinator::cluster`]) marks its robustness decisions
//!   the same way (`cluster.route` / `cluster.requeue` /
//!   `cluster.retry` / `cluster.shed` / `cluster.worker_down`), though
//!   ring drainage is per-thread, so `--trace-out` covers the
//!   single-engine path only. Disabled, every site is one thread-local
//!   bool check.
//! - [`names`] — the canonical dotted-name registry every trace site
//!   must draw from; `cargo xtask lint` enforces the pairing statically
//!   and debug builds re-check it at emit time.
//! - [`hist`] — the metrics core. One global log-scale histogram
//!   layout (exact merges, quantiles within a bucket of exact), the
//!   shared nearest-rank [`hist::percentile_exact`] every percentile in
//!   the crate routes through, and a counter/gauge/histogram
//!   [`hist::Registry`].
//!
//! Data flows: engine/backend/scheduler → trace sink + per-step
//! histograms → [`crate::coordinator::ServeMetrics::snapshot`] →
//! `BENCH_serve.json` (the open-loop traffic harness,
//! `bench::traffic` + `benches/serve_traffic.rs`).

pub mod hist;
pub mod names;
pub mod trace;

pub use hist::{percentile_exact, Histogram, Registry, Samples};
pub use trace::{span, SpanGuard, TraceEvent};
