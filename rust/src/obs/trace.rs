//! Step-level tracing: a thread-local ring-buffer recorder with Chrome
//! `trace_event` JSON export.
//!
//! Design constraints, in priority order:
//!
//! 1. **Near-zero cost when disabled.** Every emit site goes through a
//!    single thread-local `Cell<bool>` check ([`enabled`]); a disabled
//!    [`span`] returns an unarmed guard whose `Drop` is a branch on a
//!    bool. No allocation, no clock read, no locking on the cold path.
//! 2. **Balanced spans by construction.** [`span`] returns an RAII
//!    [`SpanGuard`] — the `End` event is emitted on drop, so early
//!    returns (`?` on a backend error, preemption mid-plan,
//!    cancellation) still close every open span.
//! 3. **Bounded memory.** Events land in a fixed-capacity ring
//!    (drop-oldest); the count of dropped events is reported alongside
//!    the export so a truncated trace is never mistaken for a quiet one.
//!
//! Tracing is **per-thread**: the recorder lives in a thread-local, so
//! the thread running the serve loop is the one that must call
//! [`enable`] and [`take`]. `enable` is idempotent (it keeps an already
//! installed recorder), which lets an engine-thread closure call it
//! every round and a coordinating thread collect batches via a shared
//! buffer. Export with [`export_chrome`] / [`write_chrome`]; the output
//! loads directly in `chrome://tracing` / Perfetto.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::time::Instant;

use crate::util::json::{self, Json};

/// Default ring capacity: 64k events ≈ a few thousand decode steps of
/// fully instrumented serving.
pub const DEFAULT_CAPACITY: usize = 1 << 16;

/// Chrome `trace_event` phase. `Begin`/`End` become duration spans,
/// `Instant` a point marker, `Counter` a value track.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Begin,
    End,
    Instant,
    Counter,
}

impl Phase {
    pub fn ph(self) -> &'static str {
        match self {
            Phase::Begin => "B",
            Phase::End => "E",
            Phase::Instant => "i",
            Phase::Counter => "C",
        }
    }
}

/// One recorded event. Names are `&'static str` so the hot path never
/// allocates; numeric args keep payloads fixed-size.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: &'static str,
    pub ph: Phase,
    /// Microseconds since the recorder's epoch (install time).
    pub ts_us: f64,
    pub args: Vec<(&'static str, f64)>,
}

struct Recorder {
    epoch: Instant,
    buf: VecDeque<TraceEvent>,
    cap: usize,
    dropped: u64,
}

impl Recorder {
    fn push(&mut self, ev: TraceEvent) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(ev);
    }
}

thread_local! {
    static ENABLED: Cell<bool> = const { Cell::new(false) };
    static RECORDER: RefCell<Option<Recorder>> = const { RefCell::new(None) };
}

/// Turn tracing on for the current thread. Idempotent: if a recorder is
/// already installed its buffer (and epoch) are kept, so a serve-loop
/// closure may call this every round without losing events.
pub fn enable(capacity: usize) {
    let cap = capacity.max(16);
    RECORDER.with(|r| {
        let mut r = r.borrow_mut();
        if r.is_none() {
            *r = Some(Recorder {
                epoch: Instant::now(),
                buf: VecDeque::with_capacity(cap.min(1 << 20)),
                cap,
                dropped: 0,
            });
        }
    });
    ENABLED.with(|e| e.set(true));
}

/// Turn tracing off and discard the recorder for the current thread.
pub fn disable() {
    ENABLED.with(|e| e.set(false));
    RECORDER.with(|r| *r.borrow_mut() = None);
}

/// The one check every emit site makes first. `#[inline]` so a disabled
/// instrumented build pays a thread-local bool read per site.
#[inline]
pub fn enabled() -> bool {
    ENABLED.with(|e| e.get())
}

/// Debug-build guard: every emit site must use a name from the
/// canonical [`super::names`] registry (the static half of the same
/// contract is `cargo xtask lint`, rule `trace-registry`). The module's
/// own unit tests exercise the recorder with ad-hoc names, so the check
/// compiles out under `cfg(test)`; release builds compile it out via
/// `debug_assert!`.
#[inline]
fn check_registered(name: &'static str) {
    #[cfg(not(test))]
    debug_assert!(
        super::names::is_registered(name),
        "trace name {:?} is not in obs::names::TRACE_NAMES",
        name
    );
    #[cfg(test)]
    let _ = name;
}

fn emit(name: &'static str, ph: Phase, args: Vec<(&'static str, f64)>) {
    RECORDER.with(|r| {
        if let Some(rec) = r.borrow_mut().as_mut() {
            let ts_us = rec.epoch.elapsed().as_secs_f64() * 1e6;
            rec.push(TraceEvent {
                name,
                ph,
                ts_us,
                args,
            });
        }
    });
}

/// RAII span: `Begin` is emitted on creation (when tracing is enabled),
/// `End` on drop. An unarmed guard (tracing disabled) does nothing.
#[must_use = "a span measures the scope it is bound to; binding to _ drops it immediately"]
pub struct SpanGuard {
    name: &'static str,
    armed: bool,
}

/// Open a span covering the guard's scope.
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    check_registered(name);
    let armed = enabled();
    if armed {
        emit(name, Phase::Begin, Vec::new());
    }
    SpanGuard { name, armed }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        // Close even if tracing was disabled mid-span only when a
        // recorder is still present; an armed Begin with the recorder
        // gone has nothing to balance against, and export tolerates it.
        if self.armed {
            emit(self.name, Phase::End, Vec::new());
        }
    }
}

/// Point event with numeric args (e.g. `("tokens", 17.0)`).
#[inline]
pub fn instant(name: &'static str, args: &[(&'static str, f64)]) {
    check_registered(name);
    if enabled() {
        emit(name, Phase::Instant, args.to_vec());
    }
}

/// Counter track sample (e.g. queue depth, KV occupancy).
#[inline]
pub fn counter(name: &'static str, value: f64) {
    check_registered(name);
    if enabled() {
        emit(name, Phase::Counter, vec![("value", value)]);
    }
}

/// Drain the current thread's recorded events. Returns
/// `(events, dropped_so_far)`; the recorder stays installed (with its
/// epoch), so timestamps across successive takes share one timeline.
pub fn take() -> (Vec<TraceEvent>, u64) {
    RECORDER.with(|r| {
        let mut r = r.borrow_mut();
        match r.as_mut() {
            Some(rec) => (rec.buf.drain(..).collect(), rec.dropped),
            None => (Vec::new(), 0),
        }
    })
}

/// Render events as a Chrome `trace_event` JSON document
/// (`{"traceEvents": [...], "displayTimeUnit": "ms", ...}`).
pub fn export_chrome(events: &[TraceEvent], dropped: u64) -> Json {
    let evs: Vec<Json> = events
        .iter()
        .map(|e| {
            let mut fields = vec![
                ("name", json::s(e.name)),
                ("cat", json::s("ganq")),
                ("ph", json::s(e.ph.ph())),
                ("ts", json::num(e.ts_us)),
                ("pid", json::num(0.0)),
                ("tid", json::num(0.0)),
            ];
            if e.ph == Phase::Instant {
                fields.push(("s", json::s("t"))); // thread-scoped marker
            }
            if !e.args.is_empty() {
                let args: Vec<(&str, Json)> = e
                    .args
                    .iter()
                    .map(|&(k, v)| (k, super::hist::fnum(v)))
                    .collect();
                fields.push(("args", json::obj(args)));
            }
            json::obj(fields)
        })
        .collect();
    json::obj(vec![
        ("traceEvents", Json::Arr(evs)),
        ("displayTimeUnit", json::s("ms")),
        (
            "otherData",
            json::obj(vec![("dropped", json::num(dropped as f64))]),
        ),
    ])
}

/// Drain the current thread's trace and write it to `path`.
pub fn write_chrome(path: &std::path::Path) -> std::io::Result<(usize, u64)> {
    let (events, dropped) = take();
    let doc = export_chrome(&events, dropped);
    std::fs::write(path, doc.to_string_pretty())?;
    Ok((events.len(), dropped))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tracing state is thread-local; run each scenario on its own
    // thread so tests can't interfere however the harness schedules
    // them.
    fn on_fresh_thread<F: FnOnce() + Send + 'static>(f: F) {
        std::thread::spawn(f).join().unwrap();
    }

    #[test]
    fn disabled_is_noop() {
        on_fresh_thread(|| {
            assert!(!enabled());
            {
                let _sp = span("never");
                instant("nope", &[("x", 1.0)]);
                counter("q", 3.0);
            }
            let (events, dropped) = take();
            assert!(events.is_empty());
            assert_eq!(dropped, 0);
        });
    }

    #[test]
    fn spans_balance_and_nest() {
        on_fresh_thread(|| {
            enable(DEFAULT_CAPACITY);
            {
                let _outer = span("outer");
                instant("mark", &[("tokens", 5.0)]);
                {
                    let _inner = span("inner");
                }
                counter("depth", 1.0);
            }
            let (events, dropped) = take();
            disable();
            assert_eq!(dropped, 0);
            let kinds: Vec<(&str, Phase)> =
                events.iter().map(|e| (e.name, e.ph)).collect();
            assert_eq!(
                kinds,
                vec![
                    ("outer", Phase::Begin),
                    ("mark", Phase::Instant),
                    ("inner", Phase::Begin),
                    ("inner", Phase::End),
                    ("depth", Phase::Counter),
                    ("outer", Phase::End),
                ]
            );
            // timestamps are monotone non-decreasing
            for w in events.windows(2) {
                assert!(w[1].ts_us >= w[0].ts_us);
            }
        });
    }

    #[test]
    fn early_return_still_closes_span() {
        on_fresh_thread(|| {
            enable(DEFAULT_CAPACITY);
            fn fallible(fail: bool) -> Result<(), String> {
                let _sp = span("fallible");
                if fail {
                    return Err("boom".into());
                }
                Ok(())
            }
            let _ = fallible(true);
            let (events, _) = take();
            disable();
            assert_eq!(events.len(), 2);
            assert_eq!(events[0].ph, Phase::Begin);
            assert_eq!(events[1].ph, Phase::End);
        });
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        on_fresh_thread(|| {
            enable(16); // minimum capacity
            for _ in 0..20 {
                instant("tick", &[]);
            }
            let (events, dropped) = take();
            disable();
            assert_eq!(events.len(), 16);
            assert_eq!(dropped, 4);
        });
    }

    #[test]
    fn enable_is_idempotent_across_rounds() {
        on_fresh_thread(|| {
            enable(DEFAULT_CAPACITY);
            instant("round0", &[]);
            enable(DEFAULT_CAPACITY); // must not clear the buffer
            instant("round1", &[]);
            let (events, _) = take();
            // recorder survives take(); later events keep accumulating
            instant("round2", &[]);
            let (more, _) = take();
            disable();
            assert_eq!(events.len(), 2);
            assert_eq!(more.len(), 1);
            assert_eq!(more[0].name, "round2");
        });
    }

    #[test]
    fn chrome_export_shape() {
        on_fresh_thread(|| {
            enable(DEFAULT_CAPACITY);
            {
                let _sp = span("step");
                instant("admit", &[("n", 2.0)]);
            }
            let (events, dropped) = take();
            disable();
            let doc = export_chrome(&events, dropped);
            let parsed =
                Json::parse(&doc.to_string_pretty()).expect("valid JSON");
            let evs = parsed
                .get("traceEvents")
                .and_then(|e| e.as_arr())
                .expect("traceEvents array");
            assert_eq!(evs.len(), 3);
            for ev in evs {
                assert!(ev.get("name").and_then(|n| n.as_str()).is_some());
                assert!(ev.get("ph").and_then(|p| p.as_str()).is_some());
                assert!(ev.get("ts").and_then(|t| t.as_f64()).is_some());
                assert!(ev.get("pid").is_some() && ev.get("tid").is_some());
            }
            // the instant carries scope + args
            let inst = evs
                .iter()
                .find(|e| e.get("ph").and_then(|p| p.as_str()) == Some("i"))
                .unwrap();
            assert_eq!(inst.get("s").and_then(|s| s.as_str()), Some("t"));
            assert_eq!(
                inst.at(&["args", "n"]).and_then(|n| n.as_f64()),
                Some(2.0)
            );
            assert_eq!(
                parsed
                    .at(&["otherData", "dropped"])
                    .and_then(|d| d.as_f64()),
                Some(0.0)
            );
        });
    }
}
