//! Canonical registry of trace event names.
//!
//! Every `obs::trace` span/instant/counter site must use a name from
//! this table — the dotted `subsystem.event` vocabulary is a public
//! contract consumed by the Perfetto export, the `sched.*`/`kv.*`/
//! `spec.*` trace analyses in the traffic bench, and the python schema
//! gates in CI. `cargo xtask lint` (rule `trace-registry`) enforces the
//! pairing statically; debug builds also check it at emit time.
//!
//! Adding an event name is a two-line change: the emit site and one
//! entry here (keep the table sorted — registration is a binary
//! search). Names are `subsystem.event`, lowercase, `_` inside a
//! segment, no trailing dot.

/// Sorted table of every registered trace name.
pub const TRACE_NAMES: &[&str] = &[
    "backend.step",
    "cluster.requeue",
    "cluster.retry",
    "cluster.route",
    "cluster.shed",
    "cluster.worker_down",
    "engine.attn",
    "engine.kv",
    "engine.logits",
    "engine.mlp",
    "engine.qkv",
    "engine.step",
    "hlo.chunk",
    "hlo.dispatch",
    "kv.audit",
    "kv.cow",
    "kv.evict",
    "kv.occupancy",
    "kv.preempt",
    "kv.prefix_hit",
    "kv.truncate",
    "pjrt.run",
    "sched.active",
    "sched.admit",
    "sched.chunk",
    "sched.plan",
    "sched.preempt",
    "sched.queue",
    "sched.reject",
    "sched.sample",
    "serve.precision_switch",
    "spec.accept",
    "spec.draft",
    "spec.k",
    "spec.rollback",
    "spec.verify",
];

/// Is `name` in the canonical registry?
pub fn is_registered(name: &str) -> bool {
    TRACE_NAMES.binary_search(&name).is_ok()
}

/// A registered name must be dotted (`subsystem.event`), lowercase
/// alphanumeric/underscore segments. The lint uses this shape check for
/// names it finds in the registry itself.
pub fn well_formed(name: &str) -> bool {
    let mut segments = 0;
    for seg in name.split('.') {
        segments += 1;
        if seg.is_empty()
            || !seg
                .chars()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
        {
            return false;
        }
    }
    segments >= 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_is_sorted_and_unique() {
        for w in TRACE_NAMES.windows(2) {
            assert!(w[0] < w[1], "registry out of order at {:?}", w);
        }
    }

    #[test]
    fn every_entry_is_well_formed() {
        for name in TRACE_NAMES {
            assert!(well_formed(name), "malformed registry entry {:?}", name);
        }
    }

    #[test]
    fn registration_lookup() {
        assert!(is_registered("kv.prefix_hit"));
        assert!(is_registered("sched.admit"));
        assert!(!is_registered("kv.bogus"));
        assert!(!is_registered(""));
    }

    #[test]
    fn shape_check_rejects_junk() {
        assert!(well_formed("a.b"));
        assert!(well_formed("kv.prefix_hit"));
        assert!(!well_formed("flat"));
        assert!(!well_formed("Upper.case"));
        assert!(!well_formed("trailing."));
        assert!(!well_formed(".leading"));
        assert!(!well_formed("space in.name"));
    }
}
