//! Shared latency statistics: one fixed-bucket log-scale [`Histogram`]
//! layout for every streamed quantity (step latency, pool occupancy,
//! harness TTFT/TPOT), the nearest-rank [`percentile_exact`] helper that
//! every exact-sample percentile in the crate routes through (the
//! ad-hoc copies that used to live in `coordinator/metrics.rs`,
//! `util/timer.rs`, and the bench binaries are gone), a raw-sample
//! [`Samples`] accumulator for best-of bench loops, and a small
//! counter/gauge/histogram [`Registry`] for named metric sets.
//!
//! The bucket layout is global and never configured per histogram, so
//! any two histograms merge exactly (bucket-wise addition — merge is
//! associative and commutative by construction) and a quantile read is
//! always within one bucket (< ~15% relative) of the exact sample
//! quantile.

use std::collections::BTreeMap;

use crate::util::json::{self, Json};

/// `q`-th percentile (0..=1) by nearest-rank (`ceil(q*n)`-th order
/// statistic) over an unsorted sample — never below the true quantile,
/// so tail numbers are not flattered. NaN on an empty sample.
pub fn percentile_exact(vals: &[f64], q: f64) -> f64 {
    if vals.is_empty() {
        return f64::NAN;
    }
    let mut s = vals.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (s.len() as f64 * q).ceil() as usize;
    s[rank.clamp(1, s.len()) - 1]
}

/// Finite numbers serialize as JSON numbers; NaN/inf (empty-sample
/// percentiles) as `null` so every snapshot stays parseable.
pub fn fnum(x: f64) -> Json {
    if x.is_finite() {
        json::num(x)
    } else {
        Json::Null
    }
}

/// Buckets per decade: relative bucket width is `10^(1/16) ≈ 1.155`.
const PER_DECADE: f64 = 16.0;
/// Lower edge of bucket 1; everything at or below lands in bucket 0.
const LO: f64 = 1e-3;
/// 10 decades: `[1e-3, 1e7)` plus under/overflow end buckets — in
/// milliseconds that spans 1 µs to ~3 h, in fractions it covers 0..1.
pub const BUCKETS: usize = 161;

fn bucket_of(v: f64) -> usize {
    if !(v > LO) {
        return 0; // underflow (and any non-finite negative garbage)
    }
    let b = ((v / LO).log10() * PER_DECADE).floor() as isize + 1;
    (b.max(1) as usize).min(BUCKETS - 1)
}

/// Geometric midpoint of a bucket — what a quantile read reports.
fn representative(bucket: usize) -> f64 {
    if bucket == 0 {
        return LO;
    }
    LO * 10f64.powf((bucket as f64 - 0.5) / PER_DECADE)
}

/// Fixed-bucket log-scale histogram. All histograms share one global
/// bucket layout (see module docs), so `merge` is exact and
/// associative. Counts are buckets; `min`/`max`/`sum` are tracked
/// exactly so small samples still report sane edges.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Histogram {
    counts: Vec<u64>, // allocated lazily on first record
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    pub fn from_values(vals: &[f64]) -> Histogram {
        let mut h = Histogram::new();
        for &v in vals {
            h.record(v);
        }
        h
    }

    /// Record one value. Non-finite values are dropped.
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        if self.counts.is_empty() {
            self.counts = vec![0; BUCKETS];
        }
        self.counts[bucket_of(v)] += 1;
        if self.total == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.total += 1;
        self.sum += v;
    }

    /// Bucket-wise addition — exact because the layout is global.
    pub fn merge(&mut self, other: &Histogram) {
        if other.total == 0 {
            return;
        }
        if self.counts.is_empty() {
            self.counts = vec![0; BUCKETS];
        }
        for (c, &o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        if self.total == 0 {
            self.min = other.min;
            self.max = other.max;
        } else {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
        self.total += other.total;
        self.sum += other.sum;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn min(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum / self.total as f64
        }
    }

    /// Nearest-rank quantile over the buckets: the same rank rule as
    /// [`percentile_exact`], so the reported bucket is exactly the one
    /// the exact sample quantile falls into; the value is that bucket's
    /// geometric midpoint, clamped to the observed `[min, max]`.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let rank = ((self.total as f64 * q).ceil() as u64)
            .clamp(1, self.total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return representative(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// `{count, mean, min, max, p50, p90, p99}` plus the nonzero
    /// buckets as `[lower_edge, count]` pairs.
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| {
                let lo = if i == 0 { 0.0 } else { representative(i) };
                Json::Arr(vec![json::num(lo), json::num(c as f64)])
            })
            .collect();
        json::obj(vec![
            ("count", json::num(self.total as f64)),
            ("mean", fnum(self.mean())),
            ("min", fnum(self.min())),
            ("max", fnum(self.max())),
            ("p50", fnum(self.quantile(0.50))),
            ("p90", fnum(self.quantile(0.90))),
            ("p99", fnum(self.quantile(0.99))),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

/// Raw-sample accumulator for the bench best-of loops: keeps every
/// value, reports min/mean and exact nearest-rank percentiles.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    vals: Vec<f64>,
}

impl Samples {
    pub fn new() -> Samples {
        Samples::default()
    }

    pub fn push(&mut self, v: f64) {
        self.vals.push(v);
    }

    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    pub fn values(&self) -> &[f64] {
        &self.vals
    }

    pub fn min(&self) -> f64 {
        self.vals.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.vals.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn mean(&self) -> f64 {
        if self.vals.is_empty() {
            f64::NAN
        } else {
            self.vals.iter().sum::<f64>() / self.vals.len() as f64
        }
    }

    pub fn percentile(&self, q: f64) -> f64 {
        percentile_exact(&self.vals, q)
    }
}

/// Named counters, gauges, and histograms — the aggregation surface the
/// traffic harness rolls per-class stats into and the snapshot format
/// metric sets export as.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Histogram>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Set a gauge to its latest value.
    pub fn gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Record into a named histogram (created on first use).
    pub fn observe(&mut self, name: &str, v: f64) {
        self.hists.entry(name.to_string()).or_default().record(v);
    }

    pub fn hist(&self, name: &str) -> Option<&Histogram> {
        self.hists.get(name)
    }

    /// Merge another registry in: counters add, gauges take the other's
    /// value, histograms merge bucket-wise.
    pub fn merge(&mut self, other: &Registry) {
        for (k, &v) in &other.counters {
            self.inc(k, v);
        }
        for (k, &v) in &other.gauges {
            self.gauge(k, v);
        }
        for (k, h) in &other.hists {
            self.hists.entry(k.clone()).or_default().merge(h);
        }
    }

    pub fn snapshot(&self) -> Json {
        let m = |it: &BTreeMap<String, Json>| Json::Obj(it.clone());
        let counters: BTreeMap<String, Json> = self
            .counters
            .iter()
            .map(|(k, &v)| (k.clone(), json::num(v as f64)))
            .collect();
        let gauges: BTreeMap<String, Json> = self
            .gauges
            .iter()
            .map(|(k, &v)| (k.clone(), fnum(v)))
            .collect();
        let hists: BTreeMap<String, Json> = self
            .hists
            .iter()
            .map(|(k, h)| (k.clone(), h.to_json()))
            .collect();
        json::obj(vec![
            ("counters", m(&counters)),
            ("gauges", m(&gauges)),
            ("hists", m(&hists)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn nearest_rank_matches_old_semantics() {
        // the exact values the old coordinator/metrics.rs helper pinned
        assert!((percentile_exact(&[5.0, 9.0], 0.50) - 5.0).abs() < 1e-12);
        assert!((percentile_exact(&[5.0, 9.0], 0.95) - 9.0).abs() < 1e-12);
        assert!(percentile_exact(&[], 0.5).is_nan());
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_exact(&v, 0.5), 2.0);
        assert_eq!(percentile_exact(&v, 0.95), 4.0);
        assert_eq!(percentile_exact(&v, 0.0), 1.0);
        assert_eq!(percentile_exact(&v, 1.0), 4.0);
    }

    #[test]
    fn histogram_basics() {
        let mut h = Histogram::new();
        assert!(h.is_empty());
        assert!(h.quantile(0.5).is_nan());
        for v in [1.0, 2.0, 3.0, 4.0] {
            h.record(v);
        }
        h.record(f64::NAN); // dropped
        assert_eq!(h.count(), 4);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 4.0);
        assert!((h.mean() - 2.5).abs() < 1e-12);
        // p50 lands in the bucket containing 2.0
        assert_eq!(bucket_of(h.quantile(0.5)), bucket_of(2.0));
    }

    #[test]
    fn edge_values_stay_in_range() {
        let mut h = Histogram::new();
        for v in [0.0, -5.0, 1e-9, 1e12, f64::INFINITY] {
            h.record(v);
        }
        // inf dropped; the rest land in the end buckets
        assert_eq!(h.count(), 4);
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(-5.0), 0);
        assert_eq!(bucket_of(1e12), BUCKETS - 1);
        assert!(h.quantile(0.99) <= h.max());
    }

    #[test]
    fn bucket_layout_is_monotone() {
        let mut prev = 0usize;
        let mut v = 1e-4;
        while v < 1e8 {
            let b = bucket_of(v);
            assert!(b >= prev, "bucket_of must be monotone at {}", v);
            prev = b;
            v *= 1.07;
        }
        // representatives sit inside their own bucket
        for b in 1..BUCKETS - 1 {
            assert_eq!(bucket_of(representative(b)), b, "bucket {}", b);
        }
    }

    /// Property: merge is associative (and order-independent) because
    /// the layout is global — (a+b)+c == a+(b+c) bucket for bucket.
    #[test]
    fn prop_merge_associative() {
        prop::check("hist merge associative", 11, 50, |rng, _| {
            let mk = |rng: &mut crate::util::rng::Rng| {
                let n = rng.below(30) as usize;
                let mut h = Histogram::new();
                for _ in 0..n {
                    h.record(rng.uniform() * 10f64.powi(rng.below(8) as i32 - 3));
                }
                h
            };
            let (a, b, c) = (mk(rng), mk(rng), mk(rng));
            let mut left = a.clone();
            left.merge(&b);
            left.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut right = a.clone();
            right.merge(&bc);
            prop_assert!(left == right, "merge not associative");
            prop_assert!(
                left.count() == a.count() + b.count() + c.count(),
                "count not additive"
            );
            Ok(())
        });
    }

    /// Property: p50/p99 reads land within one bucket of the exact
    /// nearest-rank sample percentile.
    #[test]
    fn prop_quantile_within_one_bucket_of_exact() {
        prop::check("hist quantile accuracy", 12, 50, |rng, _| {
            let n = rng.below(200) as usize + 1;
            let vals: Vec<f64> = (0..n)
                .map(|_| {
                    (rng.uniform() + 1e-6)
                        * 10f64.powi(rng.below(7) as i32 - 2)
                })
                .collect();
            let h = Histogram::from_values(&vals);
            for q in [0.5, 0.99] {
                let exact = percentile_exact(&vals, q);
                let approx = h.quantile(q);
                let (be, ba) =
                    (bucket_of(exact) as isize, bucket_of(approx) as isize);
                prop_assert!(
                    (be - ba).abs() <= 1,
                    "q{} exact {} (bucket {}) vs hist {} (bucket {})",
                    q,
                    exact,
                    be,
                    approx,
                    ba
                );
            }
            Ok(())
        });
    }

    #[test]
    fn samples_accumulator() {
        let mut s = Samples::new();
        assert!(s.is_empty());
        for v in [3.0, 1.0, 2.0] {
            s.push(v);
        }
        assert_eq!(s.len(), 3);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 3.0);
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert_eq!(s.percentile(0.5), 2.0);
    }

    #[test]
    fn registry_roundtrip() {
        let mut r = Registry::new();
        r.inc("reqs", 2);
        r.inc("reqs", 3);
        r.gauge("occupancy", 0.5);
        r.observe("ttft_ms", 10.0);
        r.observe("ttft_ms", 20.0);
        assert_eq!(r.counter("reqs"), 5);
        assert_eq!(r.gauge_value("occupancy"), Some(0.5));
        assert_eq!(r.hist("ttft_ms").unwrap().count(), 2);
        let mut other = Registry::new();
        other.inc("reqs", 1);
        other.gauge("occupancy", 0.75);
        other.observe("ttft_ms", 30.0);
        r.merge(&other);
        assert_eq!(r.counter("reqs"), 6);
        assert_eq!(r.gauge_value("occupancy"), Some(0.75));
        assert_eq!(r.hist("ttft_ms").unwrap().count(), 3);
        // snapshot is valid JSON with the three sections
        let js = r.snapshot();
        let parsed =
            Json::parse(&js.to_string_pretty()).expect("snapshot parses");
        assert!(parsed.get("counters").is_some());
        assert!(parsed.get("gauges").is_some());
        assert!(parsed.at(&["hists", "ttft_ms", "count"]).is_some());
    }

    #[test]
    fn fnum_guards_non_finite() {
        assert_eq!(fnum(f64::NAN), Json::Null);
        assert_eq!(fnum(f64::INFINITY), Json::Null);
        assert!(matches!(fnum(1.5), Json::Num(_)));
    }
}
