//! # ganq — GPU-Adaptive Non-Uniform Quantization for LLMs
//!
//! A from-scratch reproduction of *GANQ* (Zhao & Yuan, ICML 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (python, build-time): Pallas kernels for LUT-based mpGEMM and
//!   the GANQ back-substitution step.
//! * **L2** (python, build-time): the JAX transformer + GANQ solver graph,
//!   AOT-lowered to HLO text artifacts.
//! * **L3** (this crate): the coordinator — PJRT runtime, layer-wise PTQ
//!   pipeline (GANQ + every baseline), serving with continuous batching,
//!   evaluation harness, and the bench suite regenerating the paper's
//!   tables.
//!
//! ## Serving memory: the paged KV cache
//!
//! The `kv` module extends the paper's storage story from weights to the
//! KV cache, the memory consumer that dominates once weights are 3–4-bit
//! LUT codes. The cache is paged into fixed-size token blocks
//! (`kv::BlockPool`) mapped per request through block tables; prompts
//! sharing a prefix share physical blocks via a radix index
//! (`kv::PrefixIndex`) with copy-on-write on the first divergent append,
//! and freed prefixes stay cached until LRU eviction. Blocks are stored
//! either dense (`kv::F32Blocks`, bit-exact with the contiguous path) or
//! as per-(layer, head) 4-bit non-uniform codebooks fitted with the GANQ
//! machinery on block fill (`kv::LutBlocks`). The serve scheduler
//! (`coordinator::serve`) admits dynamically while free blocks remain and
//! preempts-and-requeues the youngest requests on pool exhaustion.
//!
//! See DESIGN.md for the system inventory and experiment index.

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod kv;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod sparse;
pub mod tensor;
pub mod tokenizer;
pub mod util;
