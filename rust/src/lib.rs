//! # ganq — GPU-Adaptive Non-Uniform Quantization for LLMs
//!
//! A from-scratch reproduction of *GANQ* (Zhao & Yuan, ICML 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (python, build-time): Pallas kernels for LUT-based mpGEMM and
//!   the GANQ back-substitution step.
//! * **L2** (python, build-time): the JAX transformer + GANQ solver graph,
//!   AOT-lowered to HLO text artifacts.
//! * **L3** (this crate): the coordinator — PJRT runtime, layer-wise PTQ
//!   pipeline (GANQ + every baseline), serving with continuous batching,
//!   evaluation harness, and the bench suite regenerating the paper's
//!   tables.
//!
//! See DESIGN.md for the system inventory and experiment index.

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod sparse;
pub mod tensor;
pub mod tokenizer;
pub mod util;
