//! # ganq — GPU-Adaptive Non-Uniform Quantization for LLMs
//!
//! A from-scratch reproduction of *GANQ* (Zhao & Yuan, ICML 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (python, build-time): Pallas kernels for LUT-based mpGEMM and
//!   the GANQ back-substitution step.
//! * **L2** (python, build-time): the JAX transformer + GANQ solver graph,
//!   AOT-lowered to HLO text artifacts.
//! * **L3** (this crate): the coordinator — PJRT runtime, layer-wise PTQ
//!   pipeline (GANQ + every baseline), serving with continuous batching,
//!   evaluation harness, and the bench suite regenerating the paper's
//!   tables.
//!
//! ## Serving memory: the paged KV cache
//!
//! The `kv` module extends the paper's storage story from weights to the
//! KV cache, the memory consumer that dominates once weights are 3–4-bit
//! LUT codes. The cache is paged into fixed-size token blocks
//! (`kv::BlockPool`) mapped per request through block tables; prompts
//! sharing a prefix share physical blocks via a radix index
//! (`kv::PrefixIndex`) with copy-on-write on the first divergent append,
//! and freed prefixes stay cached until LRU eviction. Blocks are stored
//! either dense (`kv::F32Blocks`, bit-exact with the contiguous path) or
//! as per-(layer, head) 4-bit non-uniform codebooks fitted with the GANQ
//! machinery on block fill (`kv::LutBlocks`). The serve scheduler
//! (`coordinator::serve`) admits dynamically while free blocks remain and
//! preempts-and-requeues the youngest requests on pool exhaustion.
//!
//! ## Inference: one session-based engine
//!
//! `model::forward::Engine` is the single native inference surface.
//! It owns the resolved/packed/interned per-layer weight plans
//! (`quant::kernels::PackedLut`) and a preallocated scratch arena, and
//! `Engine::step` advances a `StepPlan` — a mixed batch of work items
//! where each item is either a **prefill chunk** (several prompt
//! positions of one sequence, causally masked in-step, KV rows appended
//! as a range) or a **single decode position**. Weights stream once per
//! step no matter how many positions ride along — the memory-bound
//! mpGEMM speedup the paper targets, extended from decode to prefill so
//! long prompts stop paying per-token weight streaming (time-to-first-
//! token; see `benches/prefill_ttft.rs`).
//!
//! Everything runs through that one entry point: the serve scheduler
//! (`coordinator::serve` plans chunks under a `--prefill-chunk` budget),
//! evaluation (`forward_full` / `nll_sum` / `eval::PplEngine` are
//! full-length prefill chunks with all-position logits), calibration
//! (the same prefill with an `Observer` hook capturing per-linear
//! inputs), and generation (`Engine::generate`). Per-sequence op order
//! is identical at every chunk size, batch size, and thread count, so
//! dense (f32) KV stores are bit-identical between chunked and
//! per-token prefill.
//!
//! The AOT path mirrors the same contract with compiled graphs: the
//! `prefill_{fmt}_{model}_b{B}_c{C}` family advances whole prompt
//! chunks at per-slot positions through PJRT, `HloBackend` buckets each
//! run down to a compiled chunk (ragged tails end-padded with
//! pos-masked scratch tokens), and serving falls back to per-token
//! decode dispatch when no prefill artifact exists — chunked prefill,
//! and with it the TTFT win, is uniform across all three serving
//! backends.
//!
//! ## Any-precision weights: one artifact, many widths
//!
//! `quant::anyprec::BitPlaneStore` decomposes a parent 4-bit GANQ
//! solution into per-bit planes (bit p of every code, bitpacked row by
//! row) with a fitted codebook per width, so the top-`w` planes plus the
//! `w`-bit codebook reconstruct a valid `w`-bit model for every
//! `w ∈ {2,3,4}` — memory holds max-width planes once plus the small
//! per-width codebooks, not one model per width.
//! `coordinator::quantize_model_anyprec` builds it from a single
//! max-width solve (narrower codebooks come from count-weighted child
//! merges refined by one exact GANQ T-step against the same calibration
//! Gram — the seedless upgrade path), `quant::kernels` streams only the
//! top-`w` planes through the mpGEMM (`lut_gemm_planes_into`, bitwise
//! equal to the standalone sliced layer), and `Engine::new_at` /
//! `set_width` re-resolve the per-layer plans at any stored width. In
//! serving, `coordinator::AnyPrecBackend` holds one engine per width
//! over the shared planes and a `PrecisionPolicy` picks the width per
//! admission — `Fixed(w)`, or `Auto` with queue-depth hysteresis that
//! degrades new admissions under load and restores when drained, each
//! request pinned to its admission-time width for determinism
//! (`ganq serve --precision auto|2|3|4`).
//!
//! ## Self-speculative decoding: the store drafts for itself
//!
//! `coordinator::speculative::SpecBackend` turns the nested bit-plane
//! layout into a lossless decode accelerator: a low-width draft engine
//! and the max-width verify engine share one resident `BitPlaneStore`
//! (via `Engine::new_at`, the `AnyPrecBackend` pattern — no second
//! model in memory). Each round drafts `k` tokens per greedy slot
//! through the cheap width, rolls the KV back to the anchor
//! (`truncate`), then re-scores pending-token + draft as a single
//! verification chunk (`StepItem::verify` with `LogitsMode::All`) —
//! one max-width weight stream amortized over `k+1` positions. The
//! longest draft prefix matching the verifier's argmaxes is accepted
//! plus one bonus token from the verifier's own logits; acceptance is
//! temperature-0 exact-match, so speculative greedy output is bitwise
//! identical to plain greedy on dense and paged-f32 KV
//! (`tests/speculative.rs`). An adaptive controller resizes `k` per
//! request from a running acceptance EWMA; sampled requests fall back
//! to plain decode explicitly. The whole thing is one more
//! `DecodeBackend` — scheduler, server, cluster router, and metrics
//! are unchanged (`ganq serve --speculative --draft-width 2
//! --draft-len 8`; `benches/speculative.rs` pins the speedup).
//!
//! ## Serving: the request lifecycle
//!
//! The serving front (`coordinator::serve` / `coordinator::server`) is
//! organized around per-request lifecycles rather than fixed greedy
//! runs. A `GenRequest` carries `SamplingParams` (temperature / top-k /
//! top-p / per-request seed; temperature 0 is bitwise the greedy path)
//! and `StopCriteria` (token budget, stop tokens, stop sequences,
//! optional model EOS) plus a `CancelHandle` for mid-flight
//! cancellation. The scheduler's `Sampler` stage draws each token as a
//! pure function of `(seed, token index)` — `model::forward::
//! sample_logits` — so sampled outputs are reproducible across batch
//! sizes, prefill chunking, and preempt-and-resume. `serve_events`
//! streams `TokenEvent`s incrementally; every request finishes with a
//! `GenOutcome` and a `FinishReason`, tallied per reason (plus
//! cancelled-token waste) in `ServeMetrics`.
//!
//! ## Robustness: multi-replica serving under failure
//!
//! `coordinator::cluster` scales the same lifecycle across N replica
//! workers behind a router. Routing is prefix-affine (a
//! `kv::PrefixIndex` over prompt blocks with replica ids as "blocks",
//! spilling to the least-loaded worker past a queue depth); failure
//! handling is explicit — worker panics are caught, wedged workers are
//! detected by a per-step heartbeat with a stall timeout, and both
//! requeue their in-flight requests onto survivors with capped
//! exponential backoff. Retries are safe because sampling is pure in
//! `(seed, token index)`: a replayed request regenerates the identical
//! stream and the router de-duplicates already-delivered tokens, so
//! client streams are exactly-once end to end. Overload degrades
//! predictably via per-request deadlines (`FinishReason::
//! DeadlineExceeded` with partial output) and a load-shed watermark.
//! A `FaultPlan` injects deterministic kills/stalls/admit-failures;
//! `tests/cluster.rs` is the chaos matrix and `benches/serve_traffic.rs`
//! pins goodput retention >= 0.70 across a mid-run worker kill.
//!
//! ## Observability: tracing, histograms, and the traffic harness
//!
//! The `obs` module is the scoreboard layer. `obs::trace` records
//! per-step spans (engine phases, backend dispatch, KV CoW/eviction/
//! preemption, scheduler decisions) into a thread-local ring buffer and
//! exports Chrome `trace_event` JSON (`serve --trace-out trace.json`);
//! when disabled every site costs one thread-local bool check.
//! `obs::hist` is the shared metrics core: a global-layout log-scale
//! histogram (exact merges, quantiles within one bucket of exact), the
//! nearest-rank `percentile_exact` all percentile math routes through,
//! and a counter/gauge registry. `ServeMetrics` builds on it — TTFT /
//! TPOT / queue-delay / step-latency p50/p99 and KV-occupancy-over-time
//! — and snapshots to machine-readable JSON (`--metrics-out`). On top
//! sits the open-loop traffic harness (`bench::traffic` +
//! `benches/serve_traffic.rs` + the `traffic` subcommand): Poisson or
//! bursty arrivals over a mixed scenario pool with per-class SLOs,
//! emitting goodput and tail latencies to `BENCH_serve.json`. The flow:
//! engine → trace sink + step histograms → `ServeMetrics::snapshot` →
//! `BENCH_serve.json`.
//!
//! ## Static analysis & invariants: the `ganq-lint` layer
//!
//! Correctness tooling that checks repo-specific invariants no generic
//! lint can see, mechanically, on every commit. `cargo xtask lint`
//! (`lint::engine`, also compiled standalone under `rust/xtask/`) bans
//! `.unwrap()`/`.expect()`/`panic!`/unbounded literal indexing in the
//! serve hot path except under justified `// lint:allow(rule): reason`
//! escapes, pins every `obs::trace` name to the canonical registry in
//! `obs::names`, pairs every `BENCH_*.json` emitter with a CI schema
//! gate, and checks the declared lock-rank table
//! (`util::ordered_lock::rank`) against nested acquisitions in the
//! cluster/server/traffic modules. `util::ordered_lock::OrderedMutex`
//! enforces the same ranks dynamically in debug builds;
//! `util::modelcheck` exhaustively explores interleavings of the
//! cluster's dedup/heartbeat protocols (`modelcheck_*` tests); and
//! `kv::PagedKv::audit` sweeps refcount conservation / leak freedom /
//! index liveness / draft-window isolation at step boundaries (on in
//! debug builds and under `GANQ_AUDIT=1`, compiled out of release serve
//! paths otherwise). See `rust/xtask/README.md` for the full catalogue.
//!
//! See DESIGN.md for the system inventory and experiment index.

// House style tolerated under `cargo clippy --all-targets -- -D
// warnings` (the CI gate): index-loop numerics and small-arg-count
// conventions predate the gate and are kept for readability next to the
// paper's pseudocode. `uninlined_format_args` is deliberate: positional
// `format!("{}", x)` across hundreds of sites matches the codebase's
// paper-pseudocode style, and mass inlining buys nothing mechanical.
#![allow(
    clippy::needless_range_loop,
    clippy::new_without_default,
    clippy::too_many_arguments,
    clippy::manual_memcpy,
    clippy::type_complexity,
    clippy::len_without_is_empty,
    clippy::large_enum_variant,
    clippy::uninlined_format_args
)]

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod kv;
pub mod lint;
pub mod model;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod sparse;
pub mod tensor;
pub mod tokenizer;
pub mod util;
