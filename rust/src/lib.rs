//! # ganq — GPU-Adaptive Non-Uniform Quantization for LLMs
//!
//! A from-scratch reproduction of *GANQ* (Zhao & Yuan, ICML 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (python, build-time): Pallas kernels for LUT-based mpGEMM and
//!   the GANQ back-substitution step.
//! * **L2** (python, build-time): the JAX transformer + GANQ solver graph,
//!   AOT-lowered to HLO text artifacts.
//! * **L3** (this crate): the coordinator — PJRT runtime, layer-wise PTQ
//!   pipeline (GANQ + every baseline), serving with continuous batching,
//!   evaluation harness, and the bench suite regenerating the paper's
//!   tables.
//!
//! ## Serving memory: the paged KV cache
//!
//! The `kv` module extends the paper's storage story from weights to the
//! KV cache, the memory consumer that dominates once weights are 3–4-bit
//! LUT codes. The cache is paged into fixed-size token blocks
//! (`kv::BlockPool`) mapped per request through block tables; prompts
//! sharing a prefix share physical blocks via a radix index
//! (`kv::PrefixIndex`) with copy-on-write on the first divergent append,
//! and freed prefixes stay cached until LRU eviction. Blocks are stored
//! either dense (`kv::F32Blocks`, bit-exact with the contiguous path) or
//! as per-(layer, head) 4-bit non-uniform codebooks fitted with the GANQ
//! machinery on block fill (`kv::LutBlocks`). The serve scheduler
//! (`coordinator::serve`) admits dynamically while free blocks remain and
//! preempts-and-requeues the youngest requests on pool exhaustion.
//!
//! ## Serving compute: the batched decode engine
//!
//! `model::forward::DecodeEngine` + `decode_step_batch` advance every
//! active sequence through each layer together, so a batch of N
//! concurrent requests streams each layer's (packed) quantized weights
//! once per token-step instead of N times — the memory-bound mpGEMM
//! speedup the paper targets, realized natively. Weights are resolved,
//! packed (`quant::kernels::PackedLut`), and interned at engine build;
//! the per-step hot loop reuses a preallocated scratch arena and runs
//! attention as one job per (sequence, head). Both native serve
//! backends drive it, and
//! results stay bit-identical to the sequential `decode_step_kv` path
//! for dense KV stores.
//!
//! See DESIGN.md for the system inventory and experiment index.

// House style tolerated under `cargo clippy --all-targets -- -D
// warnings` (the CI gate): index-loop numerics and small-arg-count
// conventions predate the gate and are kept for readability next to the
// paper's pseudocode.
#![allow(
    clippy::needless_range_loop,
    clippy::new_without_default,
    clippy::too_many_arguments,
    clippy::manual_memcpy,
    clippy::type_complexity,
    clippy::len_without_is_empty,
    clippy::large_enum_variant,
    clippy::needless_lifetimes,
    clippy::useless_vec,
    clippy::uninlined_format_args
)]

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod kv;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod sparse;
pub mod tensor;
pub mod tokenizer;
pub mod util;
