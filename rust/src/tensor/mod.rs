//! Dense f32 matrix substrate for the native quantizers, calibration
//! capture, and the fallback forward path. Row-major `Mat` with blocked +
//! threaded matmul, plus the linear algebra the GANQ pipeline needs
//! (Cholesky, triangular solves, SPD solve) implemented from scratch —
//! no BLAS/LAPACK exists in this environment.

pub mod linalg;

use crate::util::pool;

#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// C = A @ B, blocked over k with the i-loop parallelized.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let mut out = Mat::zeros(m, n);
        let threads = pool::threads_for(m * k * n);
        let a = &self.data;
        let bd = &b.data;
        pool::par_rows_mut(&mut out.data, n, threads, |row0, chunk| {
            for (ri, orow) in chunk.chunks_mut(n).enumerate() {
                let i = row0 + ri;
                let arow = &a[i * k..(i + 1) * k];
                for (kk, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &bd[kk * n..(kk + 1) * n];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
        });
        out
    }

    /// C = A @ B^T — the layout used by linear layers (W stored [out, in]).
    pub fn matmul_tb(&self, b: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, b.rows);
        self.matmul_tb_into(b, &mut out);
        out
    }

    /// C = A @ B^T written into a caller-owned matrix (the decode scratch
    /// arena reuses `out` across steps). `out` must be [self.rows,
    /// b.rows]; every element is overwritten. Row-disjoint parallel
    /// writes keep this bit-identical to [`matmul_tb`] at any thread
    /// count.
    pub fn matmul_tb_into(&self, b: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, b.cols, "matmul_tb shape mismatch");
        matmul_tb_slice_into(self, &b.data, b.rows, out);
    }

    /// Resize in place to [rows, cols] without preserving contents (the
    /// scratch-arena reshape: no reallocation once capacity is reached).
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// Copy `src` into self, adopting its shape (arena-friendly clone).
    pub fn copy_from(&mut self, src: &Mat) {
        self.rows = src.rows;
        self.cols = src.cols;
        self.data.clear();
        self.data.extend_from_slice(&src.data);
    }

    /// H = X @ X^T accumulated in f64 (the calibration Gram matrix —
    /// numerical accuracy here feeds straight into GANQ's Cholesky).
    pub fn gram(&self) -> Mat {
        let n = self.rows;
        let k = self.cols;
        let mut out = Mat::zeros(n, n);
        let d = &self.data;
        let threads = pool::default_threads();
        pool::par_rows_mut(&mut out.data, n, threads, |row0, chunk| {
            for (ri, orow) in chunk.chunks_mut(n).enumerate() {
                let i = row0 + ri;
                let xi = &d[i * k..(i + 1) * k];
                for (j, o) in orow.iter_mut().enumerate() {
                    let xj = &d[j * k..(j + 1) * k];
                    let mut acc = 0.0f64;
                    for (a, b) in xi.iter().zip(xj) {
                        acc += *a as f64 * *b as f64;
                    }
                    *o = acc as f32;
                }
            }
        });
        out
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!(self.data.len(), other.data.len());
        Mat::from_vec(
            self.rows,
            self.cols,
            self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        )
    }

    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|&v| v as f64 * v as f64).sum()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

/// C = A @ B^T with B supplied as a raw row-major `[n, k]` slice
/// (`k = A.cols`) — lets the decode engine borrow FP weights straight
/// from tensor storage without cloning them into a `Mat`. Same per-row
/// dot and row-disjoint parallel writes as [`Mat::matmul_tb`], so the
/// result is bit-identical at any thread count.
pub fn matmul_tb_slice_into(a: &Mat, bd: &[f32], n: usize, out: &mut Mat) {
    let (m, k) = (a.rows, a.cols);
    assert_eq!(bd.len(), n * k, "weight slice shape");
    assert_eq!((out.rows, out.cols), (m, n), "matmul_tb_into out shape");
    let threads = pool::threads_for(m * k * n);
    let ad = &a.data;
    pool::par_rows_mut(&mut out.data, n, threads, |row0, chunk| {
        for (ri, orow) in chunk.chunks_mut(n).enumerate() {
            let i = row0 + ri;
            let arow = &ad[i * k..(i + 1) * k];
            for (j, o) in orow.iter_mut().enumerate() {
                let brow = &bd[j * k..(j + 1) * k];
                *o = dot(arow, brow);
            }
        }
    });
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: autovectorizes well and keeps error low
    let mut s0 = 0.0f32;
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    let mut s3 = 0.0f32;
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    for i in chunks * 4..a.len() {
        s0 += a[i] * b[i];
    }
    s0 + s1 + s2 + s3
}

pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// softmax in place over a slice (f32, max-subtracted).
pub fn softmax(xs: &mut [f32]) {
    let mx = xs.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0.0f32;
    for v in xs.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in xs.iter_mut() {
        *v *= inv;
    }
}

/// log-softmax value at one index (for NLL) without materializing the
/// whole distribution twice.
pub fn log_softmax_at(xs: &[f32], idx: usize) -> f32 {
    let mx = xs.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let lse: f32 = xs.iter().map(|&v| (v - mx).exp()).sum::<f32>().ln() + mx;
    xs[idx] - lse
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn randm(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, rng.normal_vec_f32(r * c))
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = randm(&mut rng, 5, 7);
        let i = Mat::eye(7);
        let c = a.matmul(&i);
        assert!(prop::all_close(&c.data, &a.data, 1e-6, 1e-6));
    }

    #[test]
    fn matmul_matches_naive() {
        prop::check("matmul", 11, 10, |rng, _| {
            let (m, k, n) = (
                1 + rng.below(20) as usize,
                1 + rng.below(20) as usize,
                1 + rng.below(20) as usize,
            );
            let a = randm(rng, m, k);
            let b = randm(rng, k, n);
            let c = a.matmul(&b);
            for i in 0..m {
                for j in 0..n {
                    let mut s = 0.0f64;
                    for kk in 0..k {
                        s += a[(i, kk)] as f64 * b[(kk, j)] as f64;
                    }
                    crate::prop_assert!(
                        prop::close(c[(i, j)] as f64, s, 1e-4, 1e-4),
                        "mismatch at ({}, {}): {} vs {}",
                        i, j, c[(i, j)], s
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn matmul_tb_consistent() {
        prop::check("matmul_tb", 13, 8, |rng, _| {
            let (m, k, n) = (
                1 + rng.below(16) as usize,
                1 + rng.below(16) as usize,
                1 + rng.below(16) as usize,
            );
            let a = randm(rng, m, k);
            let b = randm(rng, n, k);
            let c1 = a.matmul_tb(&b);
            let c2 = a.matmul(&b.t());
            crate::prop_assert!(
                prop::all_close(&c1.data, &c2.data, 1e-4, 1e-4),
                "tb != explicit transpose"
            );
            Ok(())
        });
    }

    #[test]
    fn matmul_tb_into_reuses_scratch_bitwise() {
        let mut rng = Rng::new(9);
        let mut out = Mat::zeros(1, 1);
        for _ in 0..4 {
            let (m, k, n) = (
                1 + rng.below(12) as usize,
                1 + rng.below(12) as usize,
                1 + rng.below(12) as usize,
            );
            let a = randm(&mut rng, m, k);
            let b = randm(&mut rng, n, k);
            out.reset(m, n);
            a.matmul_tb_into(&b, &mut out);
            let fresh = a.matmul_tb(&b);
            assert_eq!(out.data, fresh.data, "into-variant must be bitwise");
        }
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = Rng::new(3);
        let x = randm(&mut rng, 10, 30);
        let h = x.gram();
        for i in 0..10 {
            assert!(h[(i, i)] >= 0.0);
            for j in 0..10 {
                assert!((h[(i, j)] - h[(j, i)]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn softmax_normalizes() {
        let mut xs = vec![1.0, 2.0, 3.0, -1e9];
        softmax(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(xs[3] < 1e-12);
    }

    #[test]
    fn log_softmax_at_matches_softmax() {
        let xs = vec![0.3f32, -1.0, 2.5, 0.0];
        let mut sm = xs.clone();
        softmax(&mut sm);
        for i in 0..4 {
            assert!(
                (log_softmax_at(&xs, i) - sm[i].ln()).abs() < 1e-5,
                "idx {}",
                i
            );
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(4);
        let a = randm(&mut rng, 6, 9);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn matmul_shape_guard() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(4, 2);
        let _ = a.matmul(&b);
    }
}
