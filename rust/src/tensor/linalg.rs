//! Linear algebra for the GANQ pipeline: adaptive diagonal-dominance
//! preconditioning (paper eq. 23-24), Cholesky factorization (f64
//! internals), triangular solves, and small SPD solves for the T-step
//! (paper eq. 7). No LAPACK exists here; everything is from scratch and
//! pinned by tests (including against numpy via the golden fixtures).

use super::Mat;

/// Paper eq. 23: delta_i = max(sum_j |H_ij| - 2 H_ii, 1e-8); returns the
/// preconditioned H + Diag(delta) (eq. 24 input).
pub fn precondition(h: &Mat) -> Mat {
    assert_eq!(h.rows, h.cols);
    let n = h.rows;
    let mut out = h.clone();
    for i in 0..n {
        let absrow: f64 =
            h.row(i).iter().map(|&v| (v as f64).abs()).sum();
        let delta = (absrow - 2.0 * h[(i, i)] as f64).max(1e-8);
        out[(i, i)] = (h[(i, i)] as f64 + delta) as f32;
    }
    out
}

/// Fixed-lambda preconditioning (Remark 3.1) — the Table 7 ablation arm.
pub fn precondition_lambda(h: &Mat, lambda: f64) -> Mat {
    assert_eq!(h.rows, h.cols);
    let mut out = h.clone();
    for i in 0..h.rows {
        out[(i, i)] = (h[(i, i)] as f64 + lambda) as f32;
    }
    out
}

/// Cholesky factorization A = L L^T (lower). f64 accumulation; returns
/// None if A is not positive definite (caller should precondition).
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = vec![0.0f64; n * n];
    for j in 0..n {
        let mut d = a[(j, j)] as f64;
        for k in 0..j {
            d -= l[j * n + k] * l[j * n + k];
        }
        if d <= 0.0 || !d.is_finite() {
            return None;
        }
        let dj = d.sqrt();
        l[j * n + j] = dj;
        for i in j + 1..n {
            let mut s = a[(i, j)] as f64;
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            l[i * n + j] = s / dj;
        }
    }
    Some(Mat::from_vec(
        n,
        n,
        l.into_iter().map(|v| v as f32).collect(),
    ))
}

/// Solve L y = b (forward substitution), L lower-triangular.
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] as f64 * y[k];
        }
        y[i] = s / l[(i, i)] as f64;
    }
    y
}

/// Solve L^T x = y (back substitution).
pub fn solve_lower_t(l: &Mat, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l[(k, i)] as f64 * x[k];
        }
        x[i] = s / l[(i, i)] as f64;
    }
    x
}

/// Small dense SPD solve A x = b in f64 (the 2^N x 2^N T-step system).
/// Adds `eps` to the diagonal. Returns None if the (regularized) matrix
/// still fails to factor.
pub fn solve_spd_small(a: &[f64], n: usize, b: &[f64], eps: f64) -> Option<Vec<f64>> {
    let mut l = vec![0.0f64; n * n];
    for j in 0..n {
        let mut d = a[j * n + j] + eps;
        for k in 0..j {
            d -= l[j * n + k] * l[j * n + k];
        }
        if d <= 0.0 || !d.is_finite() {
            return None;
        }
        let dj = d.sqrt();
        l[j * n + j] = dj;
        for i in j + 1..n {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            l[i * n + j] = s / dj;
        }
    }
    // forward then back substitution
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * y[k];
        }
        y[i] = s / l[i * n + i];
    }
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    Some(x)
}

/// tr((W - W_hat) H (W - W_hat)^T) — the layer-wise objective (eq. 1),
/// f64 accumulation.
pub fn layer_error(w: &Mat, w_hat: &Mat, h: &Mat) -> f64 {
    assert_eq!(w.rows, w_hat.rows);
    assert_eq!(w.cols, w_hat.cols);
    assert_eq!(h.rows, w.cols);
    let n = w.cols;
    let mut total = 0.0f64;
    let mut dh = vec![0.0f64; n];
    for i in 0..w.rows {
        let wr = w.row(i);
        let wh = w_hat.row(i);
        // d = w - w_hat; total += d H d^T
        for j in 0..n {
            let mut s = 0.0f64;
            let hrow = h.row(j);
            for k in 0..n {
                s += (wr[k] - wh[k]) as f64 * hrow[k] as f64;
            }
            dh[j] = s;
        }
        for j in 0..n {
            total += dh[j] * (wr[j] - wh[j]) as f64;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand_spd(rng: &mut Rng, n: usize, p: usize) -> Mat {
        let x = Mat::from_vec(n, p, rng.normal_vec_f32(n * p));
        x.gram()
    }

    #[test]
    fn cholesky_reconstructs() {
        prop::check("chol", 5, 10, |rng, _| {
            let n = 2 + rng.below(20) as usize;
            let a = precondition(&rand_spd(rng, n, 2 * n + 4));
            let l = cholesky(&a).ok_or("factorization failed")?;
            let back = l.matmul(&l.t());
            crate::prop_assert!(
                prop::all_close(&back.data, &a.data, 2e-2, 2e-2),
                "LL^T != A (n={}), maxdiff {}",
                n,
                prop::max_abs_diff(&back.data, &a.data)
            );
            // strictly lower-triangular above diagonal is zero
            for i in 0..n {
                for j in i + 1..n {
                    crate::prop_assert!(l[(i, j)] == 0.0, "upper nonzero");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn precondition_fixes_rank_deficient() {
        // fc2-style degenerate Gram (rank << n) must factor afterwards
        let mut rng = Rng::new(9);
        let mut x = Mat::zeros(12, 30);
        for i in 0..3 {
            let row = rng.normal_vec_f32(30);
            x.row_mut(i).copy_from_slice(&row);
        }
        let h = x.gram();
        assert!(cholesky(&h).is_none(), "degenerate H should not factor");
        let hp = precondition(&h);
        assert!(cholesky(&hp).is_some());
    }

    #[test]
    fn precondition_is_diagonally_dominant() {
        let mut rng = Rng::new(10);
        let h = rand_spd(&mut rng, 16, 8);
        let hp = precondition(&h);
        for i in 0..16 {
            let off: f64 = hp
                .row(i)
                .iter()
                .enumerate()
                .filter(|(j, _)| *j != i)
                .map(|(_, &v)| (v as f64).abs())
                .sum();
            assert!(
                hp[(i, i)] as f64 >= off - 1e-3,
                "row {} not dominant",
                i
            );
        }
    }

    #[test]
    fn triangular_solves_invert() {
        let mut rng = Rng::new(11);
        let a = precondition(&rand_spd(&mut rng, 10, 24));
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..10).map(|i| i as f64 - 4.0).collect();
        let y = solve_lower(&l, &b);
        let x = solve_lower_t(&l, &y);
        // check A x = b
        for i in 0..10 {
            let mut s = 0.0f64;
            for j in 0..10 {
                s += a[(i, j)] as f64 * x[j];
            }
            assert!((s - b[i]).abs() < 1e-2, "row {}: {} vs {}", i, s, b[i]);
        }
    }

    #[test]
    fn spd_small_solve() {
        prop::check("spd_small", 12, 10, |rng, _| {
            let n = 1 + rng.below(16) as usize;
            let m = 2 * n + 2;
            let r: Vec<f64> =
                (0..n * m).map(|_| rng.normal()).collect();
            let mut a = vec![0.0f64; n * n];
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..m {
                        s += r[i * m + k] * r[j * m + k];
                    }
                    a[i * n + j] = s;
                }
            }
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let x = solve_spd_small(&a, n, &b, 1e-9)
                .ok_or("solve failed")?;
            for i in 0..n {
                let mut s = 0.0;
                for j in 0..n {
                    s += a[i * n + j] * x[j];
                }
                crate::prop_assert!(
                    prop::close(s, b[i], 1e-5, 1e-5),
                    "Ax != b at {}",
                    i
                );
            }
            Ok(())
        });
    }

    #[test]
    fn layer_error_zero_when_exact() {
        let mut rng = Rng::new(13);
        let w = Mat::from_vec(4, 6, rng.normal_vec_f32(24));
        let h = rand_spd(&mut rng, 6, 12);
        assert_eq!(layer_error(&w, &w, &h), 0.0);
    }

    #[test]
    fn layer_error_matches_direct_frobenius() {
        // ||W X - W_hat X||_F^2 computed directly must equal the trace form
        let mut rng = Rng::new(14);
        let w = Mat::from_vec(3, 5, rng.normal_vec_f32(15));
        let wh = Mat::from_vec(3, 5, rng.normal_vec_f32(15));
        let x = Mat::from_vec(5, 20, rng.normal_vec_f32(100));
        let h = x.gram();
        let direct = w.matmul(&x).sub(&wh.matmul(&x)).frob_sq();
        let trace = layer_error(&w, &wh, &h);
        assert!(
            prop::close(direct, trace, 1e-3, 1e-3),
            "{} vs {}",
            direct,
            trace
        );
    }
}
