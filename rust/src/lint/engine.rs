//! The `ganq-lint` engine: repo-invariant static analysis over the Rust
//! tree, dependency-free so the same source file compiles into both the
//! `ganq` library (`crate::lint`, giving the rules tier-1 test
//! coverage) and the standalone `cargo xtask lint` binary (via
//! `#[path]` inclusion — this module must never reference `crate::`
//! items outside itself).
//!
//! The analysis is lexical, not syntactic: a hand-rolled Rust lexer
//! (strings, raw strings, char-vs-lifetime disambiguation, nested block
//! comments) feeds line-tagged tokens to pattern rules. That is exactly
//! enough for the invariants we check — call-shape patterns like
//! `.unwrap()`, `trace::span("name")`, `OrderedMutex::new(rank::X)` —
//! and keeps the linter runnable in the offline build where `syn` is
//! unavailable.
//!
//! Rules (each escapable per-site with `// lint:allow(<rule>): <reason>`
//! on the same or an immediately preceding comment line):
//!
//! | rule             | scope            | forbids                                    |
//! |------------------|------------------|--------------------------------------------|
//! | `hot-unwrap`     | hot-path files   | `.unwrap()`                                |
//! | `hot-expect`     | hot-path files   | `.expect(..)` without justification        |
//! | `hot-panic`      | hot-path files   | `panic!`/`unreachable!`/`todo!`/`unimplemented!` |
//! | `hot-index`      | hot-path files   | integer-literal indexing without a bound comment |
//! | `trace-registry` | everywhere       | trace names outside `obs::names`, non-literal names |
//! | `bench-gate`     | everywhere       | `BENCH_*.json` emitters with no CI schema gate |
//! | `raw-mutex`      | watched modules  | raw `Mutex`/`RwLock` (use `util::ordered_lock`) |
//! | `lock-rank`      | watched modules  | nested lock acquisition with non-increasing rank |
//! | `safety-comment` | everywhere       | `unsafe` without a `// SAFETY:` comment    |
//! | `allow-format`   | everywhere       | malformed `lint:allow` (unknown rule / no reason) |
//!
//! `#[cfg(test)]` module bodies are exempt (tests assert on invariants
//! by violating them), as is any path containing a `fixtures` segment
//! (the lint's own seeded-violation corpus).

use std::collections::BTreeSet;
use std::fmt;
use std::path::{Path, PathBuf};

/// Serve hot-path files: panics here take down a request (or a whole
/// replica round) for traffic that retries could have saved.
pub const HOT_FILES: &[&str] = &[
    "src/coordinator/serve.rs",
    "src/coordinator/speculative.rs",
    "src/coordinator/cluster.rs",
    "src/kv/paged.rs",
    "src/quant/kernels.rs",
    "src/model/forward.rs",
];

/// Modules the lock-rank rules watch: everywhere threads and locks meet.
pub const LOCK_WATCHED: &[&str] = &[
    "src/coordinator/cluster.rs",
    "src/coordinator/server.rs",
    "src/bench/traffic.rs",
    "src/main.rs",
];

/// Every rule name, for `lint:allow` validation.
pub const RULES: &[&str] = &[
    "hot-unwrap",
    "hot-expect",
    "hot-panic",
    "hot-index",
    "trace-registry",
    "bench-gate",
    "raw-mutex",
    "lock-rank",
    "safety-comment",
    "allow-format",
];

/// One finding. `file` is crate-root-relative.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.msg
        )
    }
}

/// Cross-file facts the per-file rules check against.
#[derive(Debug, Default, Clone)]
pub struct LintCtx {
    /// Canonical trace names (parsed from `src/obs/names.rs`).
    pub trace_names: Vec<String>,
    /// Declared lock ranks, `(NAME, value)` (parsed from
    /// `src/util/ordered_lock.rs`'s `pub mod rank`).
    pub lock_ranks: Vec<(String, u32)>,
    /// `BENCH_*.json` artifacts with a CI schema gate (parsed from
    /// `.github/workflows/ci.yml`).
    pub bench_gates: Vec<String>,
}

// ---------------------------------------------------------------------
// Lexer
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Ident,
    Num,
    Str,
    Punct,
    Lifetime,
}

#[derive(Debug, Clone)]
struct Tok {
    kind: Kind,
    text: String,
    line: usize,
}

#[derive(Debug, Default)]
struct Lexed {
    toks: Vec<Tok>,
    /// `(line, text)` per comment, line/block alike (text without the
    /// delimiters, block comments keyed by their starting line).
    comments: Vec<(usize, String)>,
}

fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1usize;
    let n = b.len();
    while i < n {
        let c = b[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_whitespace() => i += 1,
            '/' if i + 1 < n && b[i + 1] == '/' => {
                let start = i + 2;
                while i < n && b[i] != '\n' {
                    i += 1;
                }
                out.comments
                    .push((line, b[start..i].iter().collect::<String>()));
            }
            '/' if i + 1 < n && b[i + 1] == '*' => {
                let start_line = line;
                let start = i + 2;
                let mut depth = 1;
                i += 2;
                while i < n && depth > 0 {
                    if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        if b[i] == '\n' {
                            line += 1;
                        }
                        i += 1;
                    }
                }
                let end = i.saturating_sub(2).max(start);
                out.comments.push((
                    start_line,
                    b[start..end].iter().collect::<String>(),
                ));
            }
            '"' => {
                let (text, len, nl) = scan_string(&b[i..]);
                out.toks.push(Tok { kind: Kind::Str, text, line });
                line += nl;
                i += len;
            }
            'r' | 'b' if starts_raw_or_byte_string(&b[i..]) => {
                let (text, len, nl) = scan_raw_or_byte(&b[i..]);
                out.toks.push(Tok { kind: Kind::Str, text, line });
                line += nl;
                i += len;
            }
            '\'' => {
                // char literal vs lifetime
                if i + 1 < n
                    && (b[i + 1] == '\\'
                        || (i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\''))
                {
                    // char literal: consume to the closing quote
                    let mut j = i + 1;
                    while j < n {
                        if b[j] == '\\' {
                            j += 2;
                            continue;
                        }
                        if b[j] == '\'' {
                            j += 1;
                            break;
                        }
                        j += 1;
                    }
                    let text: String = b[i..j.min(n)].iter().collect();
                    out.toks.push(Tok { kind: Kind::Str, text, line });
                    i = j;
                } else {
                    // lifetime: 'ident
                    let mut j = i + 1;
                    while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                        j += 1;
                    }
                    out.toks.push(Tok {
                        kind: Kind::Lifetime,
                        text: b[i..j].iter().collect(),
                        line,
                    });
                    i = j;
                }
            }
            c if c.is_alphabetic() || c == '_' => {
                let mut j = i;
                while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                    j += 1;
                }
                out.toks.push(Tok {
                    kind: Kind::Ident,
                    text: b[i..j].iter().collect(),
                    line,
                });
                i = j;
            }
            c if c.is_ascii_digit() => {
                let mut j = i;
                while j < n
                    && (b[j].is_alphanumeric() || b[j] == '_' || b[j] == '.')
                {
                    // `0..n` range: the dots are punctuation, not a float
                    if b[j] == '.' && j + 1 < n && b[j + 1] == '.' {
                        break;
                    }
                    j += 1;
                }
                out.toks.push(Tok {
                    kind: Kind::Num,
                    text: b[i..j].iter().collect(),
                    line,
                });
                i = j;
            }
            _ => {
                out.toks.push(Tok {
                    kind: Kind::Punct,
                    text: c.to_string(),
                    line,
                });
                i += 1;
            }
        }
    }
    out
}

fn starts_raw_or_byte_string(b: &[char]) -> bool {
    // r"..", r#".."#, b"..", br"..", br#".."#
    let mut i = 0;
    if b[i] == 'b' {
        i += 1;
    }
    if i < b.len() && b[i] == 'r' {
        i += 1;
        while i < b.len() && b[i] == '#' {
            i += 1;
        }
    }
    i > 0 && i < b.len() && b[i] == '"' && (b[0] == 'r' || b[0] == 'b')
}

/// Scan a `"..."` with escapes; returns (contents, chars consumed,
/// newlines inside).
fn scan_string(b: &[char]) -> (String, usize, usize) {
    let mut i = 1;
    let mut nl = 0;
    while i < b.len() {
        match b[i] {
            '\\' => i += 2,
            '"' => {
                i += 1;
                break;
            }
            c => {
                if c == '\n' {
                    nl += 1;
                }
                i += 1;
            }
        }
    }
    let end = i.saturating_sub(1).max(1);
    (b[1..end.min(b.len())].iter().collect(), i, nl)
}

fn scan_raw_or_byte(b: &[char]) -> (String, usize, usize) {
    let mut i = 0;
    if b[i] == 'b' {
        i += 1;
    }
    let raw = i < b.len() && b[i] == 'r';
    if raw {
        i += 1;
    }
    let mut hashes = 0;
    while i < b.len() && b[i] == '#' {
        hashes += 1;
        i += 1;
    }
    debug_assert!(i < b.len() && b[i] == '"');
    let start = i + 1;
    i += 1;
    let mut nl = 0;
    while i < b.len() {
        if b[i] == '\n' {
            nl += 1;
        }
        if !raw && b[i] == '\\' {
            i += 2;
            continue;
        }
        if b[i] == '"' {
            // raw strings close only on `"` + the right number of `#`
            let mut j = i + 1;
            let mut h = 0;
            while h < hashes && j < b.len() && b[j] == '#' {
                h += 1;
                j += 1;
            }
            if h == hashes {
                return (b[start..i].iter().collect(), j, nl);
            }
        }
        i += 1;
    }
    (b[start.min(b.len())..].iter().collect(), b.len(), nl)
}

// ---------------------------------------------------------------------
// Allow-comment parsing
// ---------------------------------------------------------------------

#[derive(Debug)]
struct Allows {
    /// `(line, rule)` of each well-formed allow.
    entries: Vec<(usize, String)>,
    /// lines that contain any comment (bound-comment satisfaction for
    /// `hot-index`)
    comment_lines: BTreeSet<usize>,
    /// lines whose entire content is a comment (allow blocks above code)
    pure_comment_lines: BTreeSet<usize>,
    /// malformed allows, reported as `allow-format`
    malformed: Vec<(usize, String)>,
    /// lines with a `SAFETY:` comment
    safety_lines: BTreeSet<usize>,
}

fn parse_allows(src: &str, lexed: &Lexed) -> Allows {
    let mut a = Allows {
        entries: Vec::new(),
        comment_lines: BTreeSet::new(),
        pure_comment_lines: BTreeSet::new(),
        malformed: Vec::new(),
        safety_lines: BTreeSet::new(),
    };
    for (lineno, text) in src.lines().enumerate() {
        let t = text.trim_start();
        if t.starts_with("//") {
            a.pure_comment_lines.insert(lineno + 1);
        }
    }
    for &(line, ref text) in &lexed.comments {
        a.comment_lines.insert(line);
        // doc comments (`///` lex as a line comment whose text starts
        // with `/`, `//!` with `!`) describe the allow syntax rather
        // than using it — never parse allows or SAFETY out of them
        if text.starts_with('/') || text.starts_with('!') {
            continue;
        }
        if text.contains("SAFETY:") {
            a.safety_lines.insert(line);
        }
        let mut rest = text.as_str();
        while let Some(p) = rest.find("lint:allow") {
            rest = &rest[p + "lint:allow".len()..];
            let Some(open) = rest.find('(') else {
                a.malformed.push((line, "missing (rule)".into()));
                break;
            };
            let Some(close) = rest[open..].find(')') else {
                a.malformed.push((line, "unclosed (rule)".into()));
                break;
            };
            let rule = rest[open + 1..open + close].trim().to_string();
            let after = rest[open + close + 1..].trim_start();
            if !RULES.contains(&rule.as_str()) {
                a.malformed.push((line, format!("unknown rule {:?}", rule)));
            } else if !after.starts_with(':')
                || after[1..].trim().is_empty()
            {
                a.malformed.push((
                    line,
                    format!("allow({}) needs a `: <reason>`", rule),
                ));
            } else {
                a.entries.push((line, rule));
            }
            rest = &rest[open + close + 1..];
        }
    }
    a
}

impl Allows {
    /// Is `rule` allowed at `line`? Same-line trailing comment, or a
    /// contiguous run of pure comment lines immediately above.
    fn allowed(&self, rule: &str, line: usize) -> bool {
        let hit = |l: usize| {
            self.entries
                .iter()
                .any(|(al, ar)| *al == l && ar == rule)
        };
        if hit(line) {
            return true;
        }
        let mut l = line.saturating_sub(1);
        while l > 0 && self.pure_comment_lines.contains(&l) {
            if hit(l) {
                return true;
            }
            l -= 1;
        }
        false
    }

    /// `hot-index` bound comment: any comment on the same or previous
    /// line counts as documenting the bound.
    fn bound_comment(&self, line: usize) -> bool {
        self.comment_lines.contains(&line)
            || line > 1 && self.comment_lines.contains(&(line - 1))
    }

    /// `// SAFETY:` within `window` lines above (or on) `line`.
    fn safety_near(&self, line: usize, window: usize) -> bool {
        self.safety_lines
            .range(line.saturating_sub(window)..=line)
            .next()
            .is_some()
    }
}

// ---------------------------------------------------------------------
// Test-region detection
// ---------------------------------------------------------------------

/// Token index ranges covered by `#[cfg(test)]`-gated items.
fn test_regions(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let n = toks.len();
    let mut i = 0;
    while i + 6 < n {
        let is_cfg_test = toks[i].text == "#"
            && toks[i + 1].text == "["
            && toks[i + 2].text == "cfg"
            && toks[i + 3].text == "("
            && toks[i + 4].text == "test"
            && toks[i + 5].text == ")"
            && toks[i + 6].text == "]";
        if !is_cfg_test {
            i += 1;
            continue;
        }
        // skip to the item's opening brace, then to its matching close
        let mut j = i + 7;
        while j < n && toks[j].text != "{" {
            j += 1;
        }
        let mut depth = 0usize;
        let start = j;
        while j < n {
            match toks[j].text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        regions.push((start, j.min(n.saturating_sub(1))));
        i = j + 1;
    }
    regions
}

fn in_regions(regions: &[(usize, usize)], idx: usize) -> bool {
    regions.iter().any(|&(a, b)| idx >= a && idx <= b)
}

// ---------------------------------------------------------------------
// Context parsers (registry / ranks / CI gates)
// ---------------------------------------------------------------------

/// Parse `pub const TRACE_NAMES: &[&str] = [ "a.b", ... ]` string
/// literals out of `obs/names.rs` source.
pub fn parse_trace_registry(src: &str) -> Vec<String> {
    let lexed = lex(src);
    let toks = &lexed.toks;
    let mut out = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if toks[i].kind == Kind::Ident && toks[i].text == "TRACE_NAMES" {
            // collect every string literal up to the closing `]` of the
            // slice literal
            let mut j = i + 1;
            while j < toks.len() && toks[j].text != "[" {
                j += 1;
            }
            let mut depth = 0usize;
            while j < toks.len() {
                match (toks[j].kind, toks[j].text.as_str()) {
                    (Kind::Punct, "[") => depth += 1,
                    (Kind::Punct, "]") => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    (Kind::Str, _) => out.push(toks[j].text.clone()),
                    _ => {}
                }
                j += 1;
            }
            break;
        }
        i += 1;
    }
    out
}

/// Parse the `pub mod rank { pub const NAME: u32 = N; ... }` table out
/// of `util/ordered_lock.rs` source.
pub fn parse_rank_table(src: &str) -> Vec<(String, u32)> {
    let lexed = lex(src);
    let toks = &lexed.toks;
    let mut out = Vec::new();
    // find `mod rank {`, then scan its braces for `const NAME ... = N`
    let mut i = 0;
    while i + 2 < toks.len() {
        if toks[i].text == "mod" && toks[i + 1].text == "rank" {
            let mut j = i + 2;
            while j < toks.len() && toks[j].text != "{" {
                j += 1;
            }
            let mut depth = 0usize;
            while j < toks.len() {
                match toks[j].text.as_str() {
                    "{" => depth += 1,
                    "}" => {
                        depth -= 1;
                        if depth == 0 {
                            return out;
                        }
                    }
                    "const" => {
                        let name = toks.get(j + 1).map(|t| t.text.clone());
                        let mut k = j + 2;
                        while k < toks.len()
                            && toks[k].text != "="
                            && toks[k].text != ";"
                        {
                            k += 1;
                        }
                        if let (Some(name), Some(v)) =
                            (name, toks.get(k + 1))
                        {
                            if let Ok(num) =
                                v.text.replace('_', "").parse::<u32>()
                            {
                                out.push((name, num));
                            }
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
        }
        i += 1;
    }
    out
}

/// `BENCH_*.json` names that appear inside an `open("BENCH_x.json")` in
/// the CI workflow (the schema-gate idiom).
pub fn parse_bench_gates(ci_yml: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = ci_yml;
    while let Some(p) = rest.find("open(\"BENCH_") {
        let tail = &rest[p + "open(\"".len()..];
        if let Some(q) = tail.find('"') {
            let name = &tail[..q];
            if name.ends_with(".json") && !out.contains(&name.to_string()) {
                out.push(name.to_string());
            }
        }
        rest = &rest[p + 1..];
    }
    out
}

// ---------------------------------------------------------------------
// Per-file analysis
// ---------------------------------------------------------------------

/// Lint one file's source. `relpath` is crate-root-relative with `/`
/// separators (it selects which rule sets apply).
pub fn lint_source(relpath: &str, src: &str, ctx: &LintCtx) -> Vec<Violation> {
    if relpath.split('/').any(|seg| seg == "fixtures") {
        return Vec::new();
    }
    let lexed = lex(src);
    let toks = &lexed.toks;
    let allows = parse_allows(src, &lexed);
    let regions = test_regions(toks);
    let hot = HOT_FILES.contains(&relpath);
    let watched = LOCK_WATCHED.contains(&relpath);
    let is_ordered_lock = relpath.ends_with("util/ordered_lock.rs");
    let is_names = relpath.ends_with("obs/names.rs");
    let mut v: Vec<Violation> = Vec::new();
    let mut push = |rule: &'static str, line: usize, msg: String| {
        v.push(Violation { file: relpath.to_string(), line, rule, msg });
    };

    for (line, msg) in &allows.malformed {
        push("allow-format", *line, msg.clone());
    }

    // Pre-pass for lock-rank: map binding idents to declared ranks via
    // `NAME (=|:) ... OrderedMutex::new(rank::R`
    let mut lock_vars: Vec<(String, u32)> = Vec::new();
    if watched {
        for i in 0..toks.len() {
            if toks[i].text != "OrderedMutex" {
                continue;
            }
            let is_new = toks.get(i + 1).map(|t| t.text.as_str())
                == Some(":")
                && toks.get(i + 2).map(|t| t.text.as_str()) == Some(":")
                && toks.get(i + 3).map(|t| t.text.as_str()) == Some("new");
            if !is_new {
                continue;
            }
            // rank constant: first `rank :: R` after the open paren
            let mut rank_name = None;
            for j in i + 4..(i + 14).min(toks.len()) {
                if toks[j].text == "rank"
                    && toks.get(j + 1).map(|t| t.text.as_str()) == Some(":")
                    && toks.get(j + 2).map(|t| t.text.as_str()) == Some(":")
                {
                    rank_name = toks.get(j + 3).map(|t| t.text.clone());
                    break;
                }
            }
            let Some(rank_name) = rank_name else { continue };
            let Some(&(_, rank_val)) = ctx
                .lock_ranks
                .iter()
                .find(|(n, _)| *n == rank_name)
            else {
                push(
                    "lock-rank",
                    toks[i].line,
                    format!("rank::{} is not in the declared table", rank_name),
                );
                continue;
            };
            // binding name: nearest `IDENT (=|:)` walking backwards
            for back in 1..=8usize {
                let Some(bi) = i.checked_sub(back) else { break };
                let next = &toks[bi + 1].text;
                if toks[bi].kind == Kind::Ident
                    && (next == "=" || next == ":")
                    && toks
                        .get(bi + 2)
                        .map(|t| t.text != ":")
                        .unwrap_or(true)
                    && !matches!(
                        toks[bi].text.as_str(),
                        "Arc" | "Box" | "Some" | "new" | "rank"
                    )
                {
                    lock_vars.push((toks[bi].text.clone(), rank_val));
                    break;
                }
            }
        }
    }

    // duplicate rank declarations (only meaningful on the table file)
    if is_ordered_lock {
        let table = parse_rank_table(src);
        for (i, (name, val)) in table.iter().enumerate() {
            for (name2, val2) in &table[i + 1..] {
                if name == name2 || val == val2 {
                    push(
                        "lock-rank",
                        1,
                        format!(
                            "duplicate rank declaration: {}={} vs {}={}",
                            name, val, name2, val2
                        ),
                    );
                }
            }
        }
    }

    // registry well-formedness (only on the registry file)
    if is_names {
        for name in parse_trace_registry(src) {
            if !trace_name_well_formed(&name) {
                push(
                    "trace-registry",
                    1,
                    format!("malformed registry entry {:?}", name),
                );
            }
        }
    }

    // token-pattern rules + lexical nested-lock tracking
    struct Guard {
        depth: usize,
        rank: u32,
        temp: bool,
    }
    let mut depth = 0usize;
    let mut guards: Vec<Guard> = Vec::new();
    let mut let_stmt = false;
    for i in 0..toks.len() {
        let t = &toks[i];
        let test_code = in_regions(&regions, i);
        let text = t.text.as_str();
        match (t.kind, text) {
            (Kind::Punct, "{") => {
                depth += 1;
                let_stmt = false;
            }
            (Kind::Punct, "}") => {
                depth = depth.saturating_sub(1);
                guards.retain(|g| g.depth <= depth);
            }
            (Kind::Punct, ";") => {
                let_stmt = false;
                guards.retain(|g| !(g.temp && g.depth >= depth));
            }
            (Kind::Ident, "let") => let_stmt = true,
            (Kind::Ident, "unwrap") if hot && !test_code => {
                if prev_is(toks, i, ".")
                    && next_is(toks, i, "(")
                    && !allows.allowed("hot-unwrap", t.line)
                {
                    push(
                        "hot-unwrap",
                        t.line,
                        ".unwrap() in a serve hot path (convert to a \
                         typed error, or expect + lint:allow)"
                            .into(),
                    );
                }
            }
            (Kind::Ident, "expect") if hot && !test_code => {
                if prev_is(toks, i, ".")
                    && next_is(toks, i, "(")
                    && !allows.allowed("hot-expect", t.line)
                {
                    push(
                        "hot-expect",
                        t.line,
                        ".expect() in a serve hot path needs \
                         `// lint:allow(hot-expect): <why the invariant holds>`"
                            .into(),
                    );
                }
            }
            (
                Kind::Ident,
                "panic" | "unreachable" | "todo" | "unimplemented",
            ) if hot && !test_code => {
                if next_is(toks, i, "!")
                    && !allows.allowed("hot-panic", t.line)
                {
                    push(
                        "hot-panic",
                        t.line,
                        format!(
                            "{}! in a serve hot path needs \
                             `// lint:allow(hot-panic): <reason>`",
                            text
                        ),
                    );
                }
            }
            (Kind::Ident, "unsafe") if !test_code => {
                if !allows.safety_near(t.line, 10)
                    && !allows.allowed("safety-comment", t.line)
                {
                    push(
                        "safety-comment",
                        t.line,
                        "`unsafe` without a `// SAFETY:` comment within \
                         10 lines"
                            .into(),
                    );
                }
            }
            (Kind::Ident, "Mutex" | "RwLock")
                if watched && !test_code =>
            {
                if !allows.allowed("raw-mutex", t.line) {
                    push(
                        "raw-mutex",
                        t.line,
                        format!(
                            "raw {} in a lock-ranked module; use \
                             util::ordered_lock::OrderedMutex",
                            text
                        ),
                    );
                }
            }
            (Kind::Ident, "lock") if watched && !test_code => {
                // `VAR.lock(` where VAR maps to a declared rank
                if prev_is(toks, i, ".") && next_is(toks, i, "(") {
                    let var = i
                        .checked_sub(2)
                        .map(|j| toks[j].text.as_str())
                        .unwrap_or("");
                    if let Some(&(_, rank)) =
                        lock_vars.iter().find(|(n, _)| n == var)
                    {
                        if let Some(held) = guards
                            .iter()
                            .find(|g| g.rank >= rank)
                        {
                            if !allows.allowed("lock-rank", t.line) {
                                push(
                                    "lock-rank",
                                    t.line,
                                    format!(
                                        "acquiring rank {} while rank {} \
                                         is held (acquisition order must \
                                         be strictly increasing)",
                                        rank, held.rank
                                    ),
                                );
                            }
                        }
                        guards.push(Guard {
                            depth,
                            rank,
                            temp: !let_stmt,
                        });
                    }
                }
            }
            (Kind::Ident, "span" | "instant" | "counter")
                if !test_code =>
            {
                // `trace :: span (` — the obs::trace call shape
                let is_trace_call = i >= 3
                    && toks[i - 1].text == ":"
                    && toks[i - 2].text == ":"
                    && toks[i - 3].text == "trace"
                    && next_is(toks, i, "(");
                if is_trace_call {
                    match toks.get(i + 2) {
                        Some(name) if name.kind == Kind::Str => {
                            if !ctx
                                .trace_names
                                .iter()
                                .any(|n| n == &name.text)
                                && !allows
                                    .allowed("trace-registry", t.line)
                            {
                                push(
                                    "trace-registry",
                                    t.line,
                                    format!(
                                        "trace name {:?} is not in \
                                         obs::names::TRACE_NAMES",
                                        name.text
                                    ),
                                );
                            }
                        }
                        _ => {
                            if !allows.allowed("trace-registry", t.line) {
                                push(
                                    "trace-registry",
                                    t.line,
                                    "trace name must be a string literal \
                                     (the registry is checked statically)"
                                        .into(),
                                );
                            }
                        }
                    }
                }
            }
            (Kind::Str, _) if !test_code => {
                if text.starts_with("BENCH_")
                    && text.ends_with(".json")
                    && !ctx.bench_gates.iter().any(|g| g == text)
                    && !allows.allowed("bench-gate", t.line)
                {
                    push(
                        "bench-gate",
                        t.line,
                        format!(
                            "{} has no schema-gate step in \
                             .github/workflows/ci.yml",
                            text
                        ),
                    );
                }
            }
            (Kind::Punct, "[") if hot && !test_code => {
                // integer-literal indexing `x[0]` / `)[1]` / `][2]`
                let prev_ok = i > 0
                    && (toks[i - 1].kind == Kind::Ident
                        && !matches!(
                            toks[i - 1].text.as_str(),
                            // attribute/macro heads, not indexing
                            "derive" | "cfg" | "doc" | "must_use"
                        )
                        || toks[i - 1].text == ")"
                        || toks[i - 1].text == "]");
                // preceded by `#` → attribute, not indexing
                let attr = i > 0 && toks[i - 1].text == "#";
                let lit_index = toks.get(i + 1).is_some_and(|t| {
                    t.kind == Kind::Num
                        && t.text.chars().all(|c| {
                            c.is_ascii_digit() || c == '_'
                        })
                }) && toks.get(i + 2).map(|t| t.text.as_str())
                    == Some("]");
                if prev_ok && !attr && lit_index {
                    let line = t.line;
                    if !allows.allowed("hot-index", line)
                        && !allows.bound_comment(line)
                    {
                        push(
                            "hot-index",
                            line,
                            "integer-literal indexing in a serve hot \
                             path needs a bound comment or \
                             `// lint:allow(hot-index): <reason>`"
                                .into(),
                        );
                    }
                }
            }
            _ => {}
        }
    }
    v
}

fn prev_is(toks: &[Tok], i: usize, s: &str) -> bool {
    i > 0 && toks[i - 1].text == s
}

fn next_is(toks: &[Tok], i: usize, s: &str) -> bool {
    toks.get(i + 1).map(|t| t.text.as_str()) == Some(s)
}

fn trace_name_well_formed(name: &str) -> bool {
    let mut segments = 0;
    for seg in name.split('.') {
        segments += 1;
        if seg.is_empty()
            || !seg.chars().all(|c| {
                c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'
            })
        {
            return false;
        }
    }
    segments >= 2
}

// ---------------------------------------------------------------------
// Tree walk
// ---------------------------------------------------------------------

/// Build the [`LintCtx`] from a crate root (the directory holding
/// `src/`); the CI workflow is looked up at `<root>/../.github/...`.
pub fn build_ctx(root: &Path) -> Result<LintCtx, String> {
    let names = std::fs::read_to_string(root.join("src/obs/names.rs"))
        .map_err(|e| format!("read src/obs/names.rs: {}", e))?;
    let locks =
        std::fs::read_to_string(root.join("src/util/ordered_lock.rs"))
            .map_err(|e| format!("read src/util/ordered_lock.rs: {}", e))?;
    let ci_path = root
        .parent()
        .map(|p| p.join(".github/workflows/ci.yml"))
        .filter(|p| p.exists())
        .unwrap_or_else(|| root.join(".github/workflows/ci.yml"));
    let ci = std::fs::read_to_string(&ci_path).unwrap_or_default();
    Ok(LintCtx {
        trace_names: parse_trace_registry(&names),
        lock_ranks: parse_rank_table(&locks),
        bench_gates: parse_bench_gates(&ci),
    })
}

/// Lint `src/`, `tests/`, `benches/` under the crate root. Returns all
/// findings, file order.
pub fn lint_tree(root: &Path) -> Result<Vec<Violation>, String> {
    let ctx = build_ctx(root)?;
    let mut files = Vec::new();
    for sub in ["src", "tests", "benches"] {
        collect_rs(&root.join(sub), &mut files);
    }
    files.sort();
    let mut out = Vec::new();
    for f in files {
        let rel = f
            .strip_prefix(root)
            .unwrap_or(&f)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(&f)
            .map_err(|e| format!("read {}: {}", rel, e))?;
        out.extend(lint_source(&rel, &src, &ctx));
    }
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for e in entries.flatten() {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().map(|x| x == "rs") == Some(true) {
            out.push(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> LintCtx {
        LintCtx {
            trace_names: vec!["kv.evict".into(), "sched.admit".into()],
            lock_ranks: vec![("LOW".into(), 10), ("HIGH".into(), 30)],
            bench_gates: vec!["BENCH_gated.json".into()],
        }
    }

    fn rules_of(v: &[Violation]) -> Vec<&'static str> {
        v.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn lexer_skips_strings_and_comments() {
        let src = r####"
            // a comment with .unwrap() inside
            /* block /* nested */ .unwrap() */
            let s = "quoted .unwrap() text";
            let r = r#"raw "inner" .unwrap()"#;
            let c = '\'';
            let lt: &'static str = "x";
        "####;
        let v = lint_source("src/kv/paged.rs", src, &ctx());
        assert!(v.is_empty(), "{:?}", v);
    }

    #[test]
    fn hot_unwrap_fires_only_in_hot_files() {
        let src = "fn f(x: Option<u8>) -> u8 { x.unwrap() }";
        let hot = lint_source("src/kv/paged.rs", src, &ctx());
        assert_eq!(rules_of(&hot), vec!["hot-unwrap"]);
        let cold = lint_source("src/kv/store.rs", src, &ctx());
        assert!(cold.is_empty());
    }

    #[test]
    fn allow_with_reason_suppresses() {
        let src = "fn f(x: Option<u8>) -> u8 {\n\
                   // lint:allow(hot-unwrap): slot checked two lines up\n\
                   x.unwrap()\n}";
        assert!(lint_source("src/kv/paged.rs", src, &ctx()).is_empty());
    }

    #[test]
    fn allow_without_reason_is_its_own_violation() {
        let src = "fn f(x: Option<u8>) -> u8 {\n\
                   // lint:allow(hot-unwrap)\n\
                   x.unwrap()\n}";
        let v = lint_source("src/kv/paged.rs", src, &ctx());
        assert!(rules_of(&v).contains(&"allow-format"), "{:?}", v);
        assert!(rules_of(&v).contains(&"hot-unwrap"));
    }

    #[test]
    fn test_modules_are_exempt() {
        let src = "fn ok() {}\n\
                   #[cfg(test)]\nmod tests {\n\
                   fn f(x: Option<u8>) -> u8 { x.unwrap() }\n\
                   }";
        assert!(lint_source("src/kv/paged.rs", src, &ctx()).is_empty());
    }

    #[test]
    fn trace_registry_checks_literals() {
        let good = "fn f() { trace::instant(\"kv.evict\", &[]); }";
        assert!(lint_source("src/kv/other.rs", good, &ctx()).is_empty());
        let bad = "fn f() { trace::instant(\"kv.bogus\", &[]); }";
        let v = lint_source("src/kv/other.rs", bad, &ctx());
        assert_eq!(rules_of(&v), vec!["trace-registry"]);
        let dynamic = "fn f(n: &'static str) { trace::span(n); }";
        let v = lint_source("src/kv/other.rs", dynamic, &ctx());
        assert_eq!(rules_of(&v), vec!["trace-registry"]);
    }

    #[test]
    fn bench_gate_requires_ci_pairing() {
        let gated = "fn f() { write(\"BENCH_gated.json\"); }";
        assert!(lint_source("benches/x.rs", gated, &ctx()).is_empty());
        let orphan = "fn f() { write(\"BENCH_orphan.json\"); }";
        let v = lint_source("benches/x.rs", orphan, &ctx());
        assert_eq!(rules_of(&v), vec!["bench-gate"]);
    }

    #[test]
    fn raw_mutex_banned_in_watched_modules() {
        let src = "use std::sync::Mutex;\n";
        let v = lint_source("src/main.rs", src, &ctx());
        assert_eq!(rules_of(&v), vec!["raw-mutex"]);
        assert!(lint_source("src/kv/store.rs", src, &ctx()).is_empty());
    }

    #[test]
    fn lock_rank_inversion_detected_lexically() {
        let src = "\
            fn f() {\n\
                let hi = OrderedMutex::new(rank::HIGH, \"hi\", ());\n\
                let lo = OrderedMutex::new(rank::LOW, \"lo\", ());\n\
                let g1 = hi.lock();\n\
                let g2 = lo.lock();\n\
            }\n";
        let v = lint_source("src/main.rs", src, &ctx());
        assert_eq!(rules_of(&v), vec!["lock-rank"], "{:?}", v);
        // increasing order is clean
        let ok = src
            .replace("rank::HIGH", "rank::TMP")
            .replace("rank::LOW", "rank::HIGH")
            .replace("rank::TMP", "rank::LOW");
        assert!(lint_source("src/main.rs", &ok, &ctx()).is_empty());
    }

    #[test]
    fn temporary_guard_releases_at_statement_end() {
        // two sequential temporary acquisitions of the same lock are
        // not nested
        let src = "\
            fn f() {\n\
                let lo = OrderedMutex::new(rank::LOW, \"lo\", 0);\n\
                *lo.lock() += 1;\n\
                *lo.lock() += 1;\n\
            }\n";
        assert!(lint_source("src/main.rs", src, &ctx()).is_empty());
    }

    #[test]
    fn unknown_rank_flagged() {
        let src =
            "fn f() { let m = OrderedMutex::new(rank::NOPE, \"x\", ()); }";
        let v = lint_source("src/main.rs", src, &ctx());
        assert_eq!(rules_of(&v), vec!["lock-rank"]);
    }

    #[test]
    fn safety_comment_required_for_unsafe() {
        let bare = "fn f() { unsafe { core::hint::unreachable_unchecked() } }";
        let v = lint_source("src/quant/x.rs", bare, &ctx());
        assert_eq!(rules_of(&v), vec!["safety-comment"]);
        let ok = "// SAFETY: caller guarantees the branch is dead\n\
                  fn f() { unsafe { core::hint::unreachable_unchecked() } }";
        assert!(lint_source("src/quant/x.rs", ok, &ctx()).is_empty());
    }

    #[test]
    fn hot_index_needs_bound_comment() {
        let bad = "fn f(v: &[u8]) -> u8 { v[0] }";
        let v = lint_source("src/kv/paged.rs", bad, &ctx());
        assert_eq!(rules_of(&v), vec!["hot-index"]);
        let ok = "fn f(v: &[u8]) -> u8 {\n\
                  // nonempty: admit() rejects empty prompts\n\
                  v[0]\n}";
        assert!(lint_source("src/kv/paged.rs", ok, &ctx()).is_empty());
        // non-literal indices are the borrow checker's problem
        let expr = "fn f(v: &[u8], i: usize) -> u8 { v[i + 1] }";
        assert!(lint_source("src/kv/paged.rs", expr, &ctx()).is_empty());
    }

    #[test]
    fn fixtures_are_skipped() {
        let src = "fn f(x: Option<u8>) -> u8 { x.unwrap() }";
        assert!(lint_source(
            "tests/fixtures/lint/src/kv/paged.rs",
            src,
            &ctx()
        )
        .is_empty());
    }

    #[test]
    fn registry_and_rank_parsers() {
        let names_src = "pub const TRACE_NAMES: &[&str] = &[\n\
                         \"a.b\",\n    \"c.d\",\n];";
        assert_eq!(parse_trace_registry(names_src), vec!["a.b", "c.d"]);
        let rank_src = "pub mod rank {\n\
                        pub const A: u32 = 10;\n\
                        pub const B: u32 = 20;\n}";
        assert_eq!(
            parse_rank_table(rank_src),
            vec![("A".into(), 10), ("B".into(), 20)]
        );
        let ci = "run: |\n  python3 - <<'EOF'\n  with open(\"BENCH_x.json\") as f:\n";
        assert_eq!(parse_bench_gates(ci), vec!["BENCH_x.json"]);
    }

    #[test]
    fn duplicate_ranks_flagged_on_table_file() {
        let src = "pub mod rank {\n\
                   pub const A: u32 = 10;\n\
                   pub const B: u32 = 10;\n}";
        let v = lint_source("src/util/ordered_lock.rs", src, &ctx());
        assert_eq!(rules_of(&v), vec!["lock-rank"]);
    }
}
