//! Repo-invariant static analysis (`ganq-lint`).
//!
//! The engine lives in [`engine`] as a dependency-free, self-contained
//! source file: the `rust/xtask` binary includes the same file via
//! `#[path]`, so `cargo xtask lint` and `crate::lint` are always the
//! same analysis — and the engine's rules get tier-1 test coverage
//! through this module (`tests/lint_self.rs` runs the linter over the
//! live tree and over seeded-violation fixtures).
//!
//! See `rust/xtask/README.md` for the rule catalogue, the
//! `lint:allow` escape-hatch format, and how the trace-name registry /
//! lock-rank table / CI bench gates are declared.

pub mod engine;

pub use engine::{
    build_ctx, lint_source, lint_tree, parse_bench_gates, parse_rank_table,
    parse_trace_registry, LintCtx, Violation, HOT_FILES, LOCK_WATCHED, RULES,
};
