//! Scoped-thread parallelism helpers (no rayon in the offline crate set).
//!
//! The quantizers and the native forward path parallelize across weight
//! rows / batch items with `par_chunks`; the serving coordinator uses
//! ordinary `std::thread` + channels (see coordinator/).

/// Number of worker threads to use for compute-bound loops.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Minimum arithmetic ops a worker thread must amortize before spawning
/// it pays for itself (scoped-thread spawn + join is ~tens of µs; below
/// this the serial loop wins).
pub const MIN_OPS_PER_THREAD: usize = 128 * 1024;

/// Thread count sized to the work: one thread per [`MIN_OPS_PER_THREAD`]
/// arithmetic ops, at least 1, at most [`default_threads`]. The decode
/// hot path calls this so micro-model shapes stay on the caller's thread
/// instead of paying spawn latency per matmul.
pub fn threads_for(ops: usize) -> usize {
    (ops / MIN_OPS_PER_THREAD).clamp(1, default_threads())
}

/// Run `f(chunk_index, start, end)` over `n` items split into contiguous
/// chunks across `threads` scoped threads. `f` must be Sync; chunks are
/// disjoint so callers typically write into distinct slices via raw
/// pointers or split_at_mut beforehand.
pub fn par_ranges<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let fr = &f;
            s.spawn(move || fr(t, start, end));
        }
    });
}

/// Parallel map over disjoint mutable row-chunks of a flat buffer:
/// splits `data` (len = n * stride) into per-thread sub-slices and calls
/// `f(row_start, rows_chunk)`.
pub fn par_rows_mut<T: Send, F>(
    data: &mut [T],
    stride: usize,
    threads: usize,
    f: F,
) where
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(stride > 0 && data.len() % stride == 0);
    let n = data.len() / stride;
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        f(0, data);
        return;
    }
    let chunk_rows = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = data;
        let mut row = 0usize;
        let fr = &f;
        while !rest.is_empty() {
            let take = (chunk_rows * stride).min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let r0 = row;
            row += take / stride;
            s.spawn(move || fr(r0, head));
        }
    });
}

/// Like [`par_rows_mut`] but over two flat buffers that share a row
/// count (possibly different strides): each thread gets the *same* row
/// range of both, so a worker can fill matching rows of two outputs
/// (e.g. per-row codes and per-row codebooks) without raw pointers.
/// Calls `f(row_start, chunk_a, chunk_b)`.
pub fn par_rows_mut2<A: Send, B: Send, F>(
    a: &mut [A],
    stride_a: usize,
    b: &mut [B],
    stride_b: usize,
    threads: usize,
    f: F,
) where
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert!(stride_a > 0 && a.len() % stride_a == 0);
    assert!(stride_b > 0 && b.len() % stride_b == 0);
    let n = a.len() / stride_a;
    assert_eq!(n, b.len() / stride_b, "row counts must match");
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        f(0, a, b);
        return;
    }
    let chunk_rows = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest_a = a;
        let mut rest_b = b;
        let mut row = 0usize;
        let fr = &f;
        while !rest_a.is_empty() {
            let rows = chunk_rows.min(rest_a.len() / stride_a);
            let (head_a, tail_a) = rest_a.split_at_mut(rows * stride_a);
            let (head_b, tail_b) = rest_b.split_at_mut(rows * stride_b);
            rest_a = tail_a;
            rest_b = tail_b;
            let r0 = row;
            row += rows;
            s.spawn(move || fr(r0, head_a, head_b));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn par_ranges_covers_everything_once() {
        let n = 1003;
        let counts: Vec<AtomicUsize> =
            (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_ranges(n, 7, |_t, s, e| {
            for i in s..e {
                counts[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_rows_mut_writes_disjoint() {
        let mut data = vec![0u32; 12 * 5];
        par_rows_mut(&mut data, 5, 4, |row0, chunk| {
            for (i, row) in chunk.chunks_mut(5).enumerate() {
                for v in row.iter_mut() {
                    *v = (row0 + i) as u32;
                }
            }
        });
        for r in 0..12 {
            assert!(data[r * 5..(r + 1) * 5].iter().all(|&v| v == r as u32));
        }
    }

    #[test]
    fn par_rows_mut2_rows_line_up() {
        let mut a = vec![0u32; 13 * 3];
        let mut b = vec![0u32; 13 * 7];
        par_rows_mut2(&mut a, 3, &mut b, 7, 4, |row0, ca, cb| {
            for (i, row) in ca.chunks_mut(3).enumerate() {
                row.fill((row0 + i) as u32);
            }
            for (i, row) in cb.chunks_mut(7).enumerate() {
                row.fill((row0 + i) as u32);
            }
        });
        for r in 0..13 {
            assert!(a[r * 3..(r + 1) * 3].iter().all(|&v| v == r as u32));
            assert!(b[r * 7..(r + 1) * 7].iter().all(|&v| v == r as u32));
        }
    }

    #[test]
    fn threads_for_scales_with_work() {
        assert_eq!(threads_for(0), 1);
        assert_eq!(threads_for(MIN_OPS_PER_THREAD - 1), 1);
        assert!(threads_for(MIN_OPS_PER_THREAD * 2) >= 2);
        assert!(threads_for(usize::MAX / 2) <= default_threads());
    }

    #[test]
    fn single_thread_fallback() {
        par_ranges(0, 4, |_t, s, e| {
            assert_eq!((s, e), (0, 0));
        });
        par_ranges(5, 1, |_t, s, e| {
            assert_eq!((s, e), (0, 5));
        });
        let mut v = vec![1u8; 4];
        par_rows_mut(&mut v, 2, 1, |_r, c| {
            for x in c.iter_mut() {
                *x = 9;
            }
        });
        assert!(v.iter().all(|&x| x == 9));
    }
}
