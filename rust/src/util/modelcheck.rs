//! Exhaustive interleaving exploration for small concurrent protocols —
//! the loom idea (model-check every schedule of a bounded concurrent
//! program) rebuilt on std only, because the offline crate set has no
//! `loom`.
//!
//! Formulation: a protocol under test is a *pure replay* — the checker
//! enumerates every interleaving of `threads[i]` atomic steps per
//! logical thread, and calls the scenario closure once per complete
//! schedule. The closure rebuilds its state from scratch and replays
//! the schedule deterministically (step `sched[j]` says which thread
//! moves at time `j`), then asserts its invariants. Replay-from-scratch
//! sidesteps checkpoint/clone of state containing atomics and keeps the
//! scenario a plain function of the schedule, which makes a failing
//! schedule printable and minimal to rerun.
//!
//! This is sound for protocols whose shared state is driven entirely by
//! the replayed steps (the cluster dedup/heartbeat logic under test is:
//! every transition is an explicit method call), and exhaustive up to
//! the step bounds. The number of schedules is the multinomial
//! `(Σn_i)! / Π n_i!` — keep per-thread step counts ≤ ~6. A cap guards
//! against combinatorial blowups in future edits; hitting it fails the
//! test rather than silently truncating coverage.
//!
//! Scenarios live next to the code they check (`modelcheck_*` tests in
//! `coordinator::cluster`); CI runs them all via
//! `cargo test --release modelcheck`.

/// Enumerate every interleaving of `threads[i]` steps per thread and
/// invoke `run(schedule)` for each. Returns the number of schedules
/// explored. Panics if that number would exceed `max_schedules` —
/// raising the cap is a deliberate act, truncated exploration is not.
pub fn explore<F: FnMut(&[usize])>(
    threads: &[usize],
    max_schedules: usize,
    mut run: F,
) -> usize {
    let total = count_schedules(threads);
    assert!(
        total <= max_schedules as u128,
        "model check would explore {} schedules (cap {}); shrink the \
         step bounds or raise the cap explicitly",
        total,
        max_schedules
    );
    let mut remaining = threads.to_vec();
    let mut schedule = Vec::with_capacity(threads.iter().sum());
    let mut explored = 0usize;
    dfs(&mut remaining, &mut schedule, &mut explored, &mut run);
    explored
}

fn dfs<F: FnMut(&[usize])>(
    remaining: &mut [usize],
    schedule: &mut Vec<usize>,
    explored: &mut usize,
    run: &mut F,
) {
    if remaining.iter().all(|&r| r == 0) {
        *explored += 1;
        run(schedule);
        return;
    }
    for t in 0..remaining.len() {
        if remaining[t] == 0 {
            continue;
        }
        remaining[t] -= 1;
        schedule.push(t);
        dfs(remaining, schedule, explored, run);
        schedule.pop();
        remaining[t] += 1;
    }
}

/// Multinomial schedule count `(Σn_i)! / Π n_i!`, in u128 so the cap
/// check itself cannot overflow for any bound worth exploring.
pub fn count_schedules(threads: &[usize]) -> u128 {
    let mut total: u128 = 1;
    let mut placed: u128 = 0;
    for &n in threads {
        // multiply by C(placed + n, n) incrementally
        for k in 1..=n as u128 {
            placed += 1;
            total = total * placed / k;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_match_multinomials() {
        assert_eq!(count_schedules(&[]), 1);
        assert_eq!(count_schedules(&[3]), 1);
        assert_eq!(count_schedules(&[2, 2]), 6);
        assert_eq!(count_schedules(&[3, 3]), 20);
        assert_eq!(count_schedules(&[2, 2, 2]), 90);
        assert_eq!(count_schedules(&[1, 1, 1, 1]), 24);
    }

    #[test]
    fn explores_every_schedule_exactly_once() {
        let mut seen = std::collections::BTreeSet::new();
        let n = explore(&[2, 2], 100, |s| {
            assert!(seen.insert(s.to_vec()), "duplicate schedule {:?}", s);
            assert_eq!(s.iter().filter(|&&t| t == 0).count(), 2);
            assert_eq!(s.iter().filter(|&&t| t == 1).count(), 2);
        });
        assert_eq!(n, 6);
        assert_eq!(seen.len(), 6);
    }

    #[test]
    fn finds_the_lost_update_race() {
        // the canonical torn read-modify-write: two threads each do
        // read (step 0) then write read+1 (step 1); some interleaving
        // must lose an update — proving the checker actually reaches
        // the racy schedules
        let mut lost = 0;
        explore(&[2, 2], 100, |sched| {
            let mut counter = 0u32;
            let mut reg = [0u32; 2]; // per-thread read register
            let mut step = [0usize; 2];
            for &t in sched {
                match step[t] {
                    0 => reg[t] = counter,
                    _ => counter = reg[t] + 1,
                }
                step[t] += 1;
            }
            if counter != 2 {
                lost += 1;
            }
        });
        assert!(lost > 0, "exploration missed the interleaved schedules");
    }

    #[test]
    #[should_panic(expected = "cap")]
    fn cap_refuses_blowups() {
        explore(&[4, 4], 10, |_| {});
    }
}
