//! Measurement harness for the benches (criterion is not in the offline
//! crate set): warmup + timed iterations, robust summary statistics, and a
//! tiny fixed-width table printer used by every `benches/table*.rs` binary
//! to render the paper's tables.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_s * 1e6
    }
}

/// Time `f` with `warmup` unrecorded runs then `iters` recorded runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    summarize(&times)
}

/// Adaptive: run until `budget_s` seconds of measurement or `max_iters`.
pub fn bench_for<F: FnMut()>(budget_s: f64, max_iters: usize, mut f: F) -> Stats {
    f(); // warmup
    let mut times = Vec::new();
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < budget_s && times.len() < max_iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    summarize(&times)
}

/// Summary statistics over raw iteration times. Percentiles are
/// nearest-rank via [`crate::obs::hist::percentile_exact`] — the one
/// percentile definition shared by every bench and the serve metrics.
pub fn summarize(times: &[f64]) -> Stats {
    let mut s: Vec<f64> = times.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = if s.is_empty() {
        f64::NAN
    } else {
        s.iter().sum::<f64>() / s.len() as f64
    };
    Stats {
        iters: s.len(),
        mean_s: mean,
        p50_s: crate::obs::hist::percentile_exact(&s, 0.5),
        p95_s: crate::obs::hist::percentile_exact(&s, 0.95),
        min_s: s.first().copied().unwrap_or(f64::NAN),
        max_s: s.last().copied().unwrap_or(f64::NAN),
    }
}

/// Fixed-width table printer (paper-style rows).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut w: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            println!("{}", s);
        };
        line(&self.headers);
        let mut sep = String::from("|");
        for wi in &w {
            sep.push_str(&format!("{}|", "-".repeat(wi + 2)));
        }
        println!("{}", sep);
        for r in &self.rows {
            line(r);
        }
    }
}

pub fn fmt_f(x: f64, prec: usize) -> String {
    if !x.is_finite() {
        return "-".into();
    }
    if x.abs() >= 1e4 {
        format!("{:.1e}", x)
    } else {
        format!("{:.*}", prec, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.iters, 4);
        assert!((s.mean_s - 2.5).abs() < 1e-12);
        // nearest-rank percentiles (shared with obs::hist): p50 of four
        // samples is the 2nd order statistic, not an interpolated 2.5
        assert!((s.p50_s - 2.0).abs() < 1e-12);
        assert!((s.p95_s - 4.0).abs() < 1e-12);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 4.0);
    }

    #[test]
    fn bench_runs() {
        let mut n = 0u64;
        let s = bench(1, 5, || n += 1);
        assert_eq!(s.iters, 5);
        assert_eq!(n, 6);
        assert!(s.mean_s >= 0.0);
    }

    #[test]
    fn fmt_handles_extremes() {
        assert_eq!(fmt_f(f64::NAN, 2), "-");
        assert_eq!(fmt_f(1.23456, 2), "1.23");
        assert!(fmt_f(54321.0, 2).contains('e'));
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new("t", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // smoke: must not panic
    }
}
