//! Environment substrates built in-repo because the offline crate set only
//! contains the `xla` closure: JSON, RNG, CLI parsing, scoped-thread
//! parallelism, bench timing/statistics, and a mini property-test harness.

pub mod cli;
pub mod json;
pub mod modelcheck;
pub mod ordered_lock;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod timer;

/// Repo-root-relative artifact dir resolution: honors GANQ_ARTIFACTS, else
/// walks up from cwd looking for `artifacts/manifest.json`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("GANQ_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
