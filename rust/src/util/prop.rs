//! Mini property-testing harness (proptest is not in the offline crate
//! set). Runs a property over N seeded random cases and reports the first
//! failing seed so the case is reproducible; used across quant/ and
//! coordinator/ tests for the paper's invariants (error monotonicity,
//! routing/batching/state invariants, pack/unpack roundtrips, ...).

use crate::util::rng::Rng;

/// Run `prop(rng, case_index)` for `cases` seeds derived from `base_seed`.
/// Panics with the failing seed on the first violation.
pub fn check<F>(name: &str, base_seed: u64, cases: usize, prop: F)
where
    F: Fn(&mut Rng, usize) -> Result<(), String>,
{
    for i in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(i as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, i) {
            panic!(
                "property '{}' failed on case {} (seed {:#x}): {}",
                name, i, seed, msg
            );
        }
    }
}

/// Assert helper producing Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Relative/absolute closeness for float properties.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

pub fn all_close(a: &[f32], b: &[f32], rtol: f64, atol: f64) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(&x, &y)| close(x as f64, y as f64, rtol, atol))
}

pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x as f64 - y as f64).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("adds", 1, 20, |rng, _| {
            let a = rng.below(100) as i64;
            let b = rng.below(100) as i64;
            prop_assert!(a + b == b + a, "commutativity");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 2, 10, |rng, _| {
            prop_assert!(rng.below(10) > 100, "impossible");
            Ok(())
        });
    }

    #[test]
    fn closeness() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, 0.0));
        assert!(!close(1.0, 1.1, 1e-6, 1e-6));
        assert!(all_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-5));
        assert_eq!(max_abs_diff(&[1.0], &[3.0]), 2.0);
    }
}
