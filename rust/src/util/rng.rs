//! Deterministic RNG: splitmix64, bit-identical to python/compile/corpus.py
//! (the corpus generator is pinned cross-language by a golden file), plus
//! float helpers for tests and synthetic workloads.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in [0, n). Simple modulo — must match the python
    /// side exactly (bias is irrelevant at our n).
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-18);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// Sample an index from cumulative integer weights (binary search for
    /// the first cum[i] > r) — identical to corpus.py `sample_cum`.
    pub fn sample_cum(&mut self, cum: &[u64], total: u64) -> usize {
        let r = self.below(total);
        let (mut lo, mut hi) = (0usize, cum.len() - 1);
        while lo < hi {
            let mid = (lo + hi) / 2;
            if cum[mid] > r {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn known_splitmix_values() {
        // cross-checked against the python implementation
        let mut r = Rng::new(0);
        let v = r.next_u64();
        let mut state = 0u64;
        state = state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        assert_eq!(v, z ^ (z >> 31));
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.05, "var {}", var);
    }

    #[test]
    fn sample_cum_matches_linear_scan() {
        let cum = vec![3u64, 10, 11, 20];
        let mut r = Rng::new(3);
        for _ in 0..200 {
            let mut probe = Rng::new(r.state);
            let idx = probe.sample_cum(&cum, 20);
            let mut check = Rng::new(r.state);
            let rv = check.below(20);
            let expect = cum.iter().position(|&c| c > rv).unwrap();
            assert_eq!(idx, expect);
            r.next_u64();
        }
    }
}
