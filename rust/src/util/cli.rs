//! Tiny CLI argument parser (clap is not in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Used by `main.rs` and every example / bench binary.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// `get_usize` with a floor — for knobs where 0 is meaningless
    /// (e.g. `--prefill-chunk` must feed at least one position).
    pub fn get_usize_min(&self, key: &str, default: usize, min: usize) -> usize {
        self.get_usize(key, default).max(min)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&[
            "serve", "extra", "--model", "opt-small", "--bits=3",
            "--verbose",
        ]);
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.get("model"), Some("opt-small"));
        assert_eq!(a.get_usize("bits", 4), 3);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_f64("r", 0.5), 0.5);
        assert!(!a.has_flag("q"));
    }

    #[test]
    fn usize_min_clamps() {
        let a = parse(&["--prefill-chunk", "0"]);
        assert_eq!(a.get_usize_min("prefill-chunk", 128, 1), 1);
        assert_eq!(a.get_usize_min("absent", 128, 1), 128);
    }

    #[test]
    fn flag_before_positional() {
        // `--flag positional` is ambiguous; our rule: next non-dashed token
        // becomes the value. Document-by-test.
        let a = parse(&["--fast", "run"]);
        assert_eq!(a.get("fast"), Some("run"));
    }
}
