//! Minimal JSON parser + writer.
//!
//! The offline environment vendors only the `xla` crate closure (no serde),
//! so manifest/golden/result I/O is handled by this module. It supports the
//! full JSON grammar we emit from `python/compile/aot.py` (objects, arrays,
//! strings with escapes, numbers, bools, null) and is covered by unit +
//! property tests (roundtrip through the writer).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["models", "opt-small", "config"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<f32> (common golden-fixture shape).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        Some(
            self.as_arr()?
                .iter()
                .map(|v| v.as_f64().unwrap_or(f64::NAN) as f32)
                .collect(),
        )
    }

    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        Some(
            self.as_arr()?
                .iter()
                .map(|v| v.as_f64().unwrap_or(f64::NAN))
                .collect(),
        )
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        Some(
            self.as_arr()?
                .iter()
                .map(|v| v.as_f64().unwrap_or(0.0) as usize)
                .collect(),
        )
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{}", x);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    e.write(out, indent, pretty);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        for _ in 0..indent + 1 {
                            out.push_str("  ");
                        }
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    for _ in 0..indent {
                        out.push_str("  ");
                    }
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers so call sites stay terse.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
}

pub fn arr_f32(v: &[f32]) -> Json {
    Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|x| x as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|e| e.to_string())?;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain utf-8 bytes
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|e| e.to_string())?;
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{}' at byte {}", txt, start))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let j = Json::parse(
            r#"{"a": 1, "b": [1.5, -2e3, true, null], "c": "x\ny"}"#,
        )
        .unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(j.at(&["b"]).unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(j.at(&["c"]).unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m":{"k":[0.25,"s",false]},"n":-3.5}"#;
        let j = Json::parse(src).unwrap();
        let back = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""aAb""#).unwrap();
        assert_eq!(j.as_str(), Some("aAb"));
    }

    #[test]
    fn property_roundtrip_random() {
        // mini property test: random JSON trees survive write->parse
        let mut rng = crate::util::rng::Rng::new(7);
        for _ in 0..50 {
            let v = random_json(&mut rng, 3);
            let s = v.to_string_pretty();
            let back = Json::parse(&s).unwrap();
            assert_eq!(v, back, "{}", s);
        }
    }

    fn random_json(rng: &mut crate::util::rng::Rng, depth: usize) -> Json {
        let choice = if depth == 0 { rng.below(4) } else { rng.below(6) };
        match choice {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.below(2001) as f64 - 1000.0) / 8.0),
            3 => Json::Str(format!("s{}\n\"x", rng.below(100))),
            4 => Json::Arr(
                (0..rng.below(4))
                    .map(|_| random_json(rng, depth - 1))
                    .collect(),
            ),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{}", i), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
}
