//! Rank-tagged mutex: deadlock freedom by construction.
//!
//! Every long-lived lock in the serving stack is declared in the
//! [`rank`] table and wrapped in an [`OrderedMutex`]. Debug builds keep
//! a per-thread stack of held ranks and assert that acquisitions happen
//! in strictly increasing rank order — any lock-order inversion (the
//! classic AB/BA deadlock shape) panics deterministically on the first
//! offending acquisition, single-threaded, instead of deadlocking once
//! in a thousand runs under contention. Release builds compile the
//! bookkeeping out entirely: an `OrderedMutex` is exactly a
//! `std::sync::Mutex` plus one `u32`.
//!
//! `cargo xtask lint` (rule `lock-rank`) closes the loop statically: it
//! parses this table, bans raw `Mutex::new` in the cluster/server/
//! traffic modules (forcing new locks through here), and flags lexical
//! nested acquisitions whose declared ranks are not increasing.
//!
//! Poisoning: these locks guard status boards and sinks, not critical
//! invariants — a panic while holding one must not cascade into every
//! reader. `lock()` therefore recovers the inner guard from a poisoned
//! mutex instead of propagating the poison.

use std::sync::{Mutex, MutexGuard, PoisonError};

/// The lock-rank table: acquire in strictly increasing rank order.
///
/// Keep this table exhaustive — every `OrderedMutex` in the tree names
/// a constant here, and the lint cross-checks nested acquisitions
/// against it. Leave gaps between values so a future lock can slot
/// between two existing ones without renumbering.
pub mod rank {
    /// Traffic/serve trace sink: engine threads drain their per-thread
    /// trace rings into this buffer (`main.rs`).
    pub const TRACE_SINK: u32 = 10;
    /// Cluster router status board: router thread publishes worker
    /// liveness/load; observers read it (`coordinator/cluster.rs`).
    pub const CLUSTER_STATUS: u32 = 20;
    /// Server panic slot: worker threads deposit panic payloads for the
    /// supervisor (`coordinator/server.rs`).
    pub const SERVER_PANIC: u32 = 30;
}

#[cfg(debug_assertions)]
thread_local! {
    /// Ranks (and names, for the panic message) of locks this thread
    /// currently holds, acquisition order.
    static HELD: std::cell::RefCell<Vec<(u32, &'static str)>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// A `std::sync::Mutex` that participates in the global lock ranking.
pub struct OrderedMutex<T> {
    rank: u32,
    name: &'static str,
    inner: Mutex<T>,
}

impl<T> OrderedMutex<T> {
    /// Wrap `value`; `rank` must be a [`rank`] constant and `name` its
    /// human-readable label (used in the inversion panic message).
    pub const fn new(rank: u32, name: &'static str, value: T) -> OrderedMutex<T> {
        OrderedMutex { rank, name, inner: Mutex::new(value) }
    }

    pub fn rank(&self) -> u32 {
        self.rank
    }

    /// Acquire the lock, debug-asserting the per-thread rank order.
    /// Recovers from poisoning (see module docs).
    pub fn lock(&self) -> OrderedGuard<'_, T> {
        #[cfg(debug_assertions)]
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(&(top, top_name)) = held.last() {
                assert!(
                    self.rank > top,
                    "lock-order inversion: acquiring {:?} (rank {}) while \
                     holding {:?} (rank {}) — see util::ordered_lock::rank",
                    self.name,
                    self.rank,
                    top_name,
                    top
                );
            }
            held.push((self.rank, self.name));
        });
        let guard = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        OrderedGuard {
            guard,
            #[cfg(debug_assertions)]
            rank: self.rank,
        }
    }

    /// Consume the mutex, returning its value (poison recovered).
    pub fn into_inner(self) -> T {
        self.inner.into_inner().unwrap_or_else(PoisonError::into_inner)
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for OrderedMutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OrderedMutex")
            .field("rank", &self.rank)
            .field("name", &self.name)
            .field("inner", &self.inner)
            .finish()
    }
}

/// RAII guard; popping the held-rank stack on drop is what makes the
/// order check per-acquisition rather than per-lifetime.
pub struct OrderedGuard<'a, T> {
    guard: MutexGuard<'a, T>,
    #[cfg(debug_assertions)]
    rank: u32,
}

impl<T> std::ops::Deref for OrderedGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T> std::ops::DerefMut for OrderedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.guard
    }
}

#[cfg(debug_assertions)]
impl<T> Drop for OrderedGuard<'_, T> {
    fn drop(&mut self) {
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            // drop order can be arbitrary (mem::drop, struct fields):
            // remove the most recent entry with this rank, not the top
            if let Some(i) = held.iter().rposition(|&(r, _)| r == self.rank) {
                held.remove(i);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_round_trips_data() {
        let m = OrderedMutex::new(rank::TRACE_SINK, "sink", vec![1, 2]);
        m.lock().push(3);
        assert_eq!(*m.lock(), vec![1, 2, 3]);
        assert_eq!(m.rank(), rank::TRACE_SINK);
        assert_eq!(m.into_inner(), vec![1, 2, 3]);
    }

    #[test]
    fn increasing_rank_order_is_fine() {
        let a = OrderedMutex::new(rank::TRACE_SINK, "sink", 1u32);
        let b = OrderedMutex::new(rank::CLUSTER_STATUS, "status", 2u32);
        let c = OrderedMutex::new(rank::SERVER_PANIC, "panic", 3u32);
        let ga = a.lock();
        let gb = b.lock();
        let gc = c.lock();
        assert_eq!(*ga + *gb + *gc, 6);
        drop(gc);
        drop(gb);
        drop(ga);
        // and again, proving the held stack fully unwound
        let _gb = b.lock();
        let _gc = c.lock();
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "rank checks are debug-only")]
    fn inversion_panics_in_debug() {
        let result = std::thread::spawn(|| {
            let lo = OrderedMutex::new(rank::TRACE_SINK, "sink", ());
            let hi = OrderedMutex::new(rank::SERVER_PANIC, "panic", ());
            let _g_hi = hi.lock();
            let _g_lo = lo.lock(); // inversion: SERVER_PANIC held, TRACE_SINK wanted
        })
        .join();
        let err = result.expect_err("inversion must panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("lock-order inversion"), "got {:?}", msg);
    }

    #[test]
    fn out_of_order_drop_keeps_stack_sane() {
        let a = OrderedMutex::new(rank::TRACE_SINK, "sink", ());
        let b = OrderedMutex::new(rank::CLUSTER_STATUS, "status", ());
        let ga = a.lock();
        let gb = b.lock();
        drop(ga); // dropped before gb: rposition removal, not pop
        drop(gb);
        let _ga = a.lock();
        let _gb = b.lock();
    }

    #[test]
    fn poisoned_lock_recovers() {
        let m = Arc::new(OrderedMutex::new(rank::SERVER_PANIC, "panic", 7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison the mutex");
        })
        .join();
        assert_eq!(*m.lock(), 7, "poison must not cascade to readers");
    }

    #[test]
    fn contended_counter_stays_consistent() {
        let m = Arc::new(OrderedMutex::new(rank::CLUSTER_STATUS, "n", 0u64));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for _ in 0..250 {
                    *m.lock() += 1;
                }
            }));
        }
        for h in handles {
            h.join().expect("worker");
        }
        assert_eq!(*m.lock(), 1000);
    }

    #[test]
    fn rank_table_is_strictly_increasing() {
        let ranks = [rank::TRACE_SINK, rank::CLUSTER_STATUS, rank::SERVER_PANIC];
        for w in ranks.windows(2) {
            assert!(w[0] < w[1], "rank table must be strictly increasing");
        }
    }
}

/// Real-`loom` shadow of the ordering tests: compiled only under
/// `--cfg loom` with the loom crate on the path (not part of the
/// offline build). The in-repo exhaustive checker
/// ([`super::modelcheck`]) covers the same protocols hermetically.
#[cfg(loom)]
mod loom_tests {
    use loom::sync::{Arc, Mutex};

    #[test]
    fn counter_increments_are_not_lost() {
        loom::model(|| {
            let m = Arc::new(Mutex::new(0u64));
            let m2 = Arc::clone(&m);
            let t = loom::thread::spawn(move || {
                *m2.lock().unwrap() += 1;
            });
            *m.lock().unwrap() += 1;
            t.join().unwrap();
            assert_eq!(*m.lock().unwrap(), 2);
        });
    }
}
