//! Fixed-size physical block pool with reference counting.
//!
//! A block is the unit of KV-cache allocation: `block_size` token
//! positions across all layers and heads (see [`super::KvLayout`]).
//! References come from two places — request block tables (one per slot
//! that maps the block) and the prefix index (one per cached chunk). A
//! block returns to the free list only when both are gone.

#[derive(Debug)]
pub struct BlockPool {
    refs: Vec<u32>,
    free: Vec<usize>,
    used_peak: usize,
}

impl BlockPool {
    pub fn new(num_blocks: usize) -> BlockPool {
        BlockPool {
            refs: vec![0; num_blocks],
            // pop from the back: hand out low block ids first
            free: (0..num_blocks).rev().collect(),
            used_peak: 0,
        }
    }

    pub fn num_blocks(&self) -> usize {
        self.refs.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.refs.len() - self.free.len()
    }

    pub fn peak_used(&self) -> usize {
        self.used_peak
    }

    pub fn refcount(&self, blk: usize) -> u32 {
        self.refs[blk]
    }

    /// Allocate a free block with refcount 1; `None` when exhausted (the
    /// caller then evicts from the prefix cache or preempts a request).
    pub fn alloc(&mut self) -> Option<usize> {
        let blk = self.free.pop()?;
        debug_assert_eq!(self.refs[blk], 0);
        self.refs[blk] = 1;
        self.used_peak = self.used_peak.max(self.used_blocks());
        Some(blk)
    }

    /// Add a reference (prefix share or cache pin).
    pub fn retain(&mut self, blk: usize) {
        assert!(self.refs[blk] > 0, "retain on free block {}", blk);
        self.refs[blk] += 1;
    }

    /// Drop a reference; returns true when the block became free.
    pub fn release(&mut self, blk: usize) -> bool {
        assert!(self.refs[blk] > 0, "release on free block {}", blk);
        self.refs[blk] -= 1;
        if self.refs[blk] == 0 {
            self.free.push(blk);
            true
        } else {
            false
        }
    }

    /// Invariant sweep against an externally-derived expectation:
    /// `expected[b]` is the number of references block `b` should hold
    /// (slot block-table mappings plus prefix-index pins — the only two
    /// legal reference sources). Checks refcount conservation, free-list
    /// consistency (free blocks have refcount 0 and appear exactly
    /// once), and leak freedom (every refcount-0 block is on the free
    /// list). Read-only; the caller decides whether a violation panics.
    pub fn audit(&self, expected: &[u32]) -> Result<(), String> {
        if expected.len() != self.refs.len() {
            return Err(format!(
                "expectation covers {} blocks, pool has {}",
                expected.len(),
                self.refs.len()
            ));
        }
        for (b, (&have, &want)) in
            self.refs.iter().zip(expected).enumerate()
        {
            if have != want {
                return Err(format!(
                    "refcount conservation broken at block {}: pool \
                     holds {}, reachable references total {}",
                    b, have, want
                ));
            }
        }
        let mut on_free = vec![false; self.refs.len()];
        for &b in &self.free {
            if b >= self.refs.len() {
                return Err(format!("free list holds bogus block {}", b));
            }
            if on_free[b] {
                return Err(format!("block {} on the free list twice", b));
            }
            on_free[b] = true;
            if self.refs[b] != 0 {
                return Err(format!(
                    "block {} is on the free list with refcount {}",
                    b, self.refs[b]
                ));
            }
        }
        for (b, &r) in self.refs.iter().enumerate() {
            if r == 0 && !on_free[b] {
                return Err(format!(
                    "block {} leaked: refcount 0 but not free-listed",
                    b
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_retain_release_cycle() {
        let mut p = BlockPool::new(2);
        assert_eq!(p.free_blocks(), 2);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert!(p.alloc().is_none());
        assert_eq!(p.used_blocks(), 2);

        p.retain(a); // shared
        assert!(!p.release(a)); // still referenced
        assert!(p.release(a)); // now free
        assert_eq!(p.free_blocks(), 1);
        assert!(p.release(b));
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.peak_used(), 2);
    }

    #[test]
    #[should_panic(expected = "release on free block")]
    fn double_free_panics() {
        let mut p = BlockPool::new(1);
        let a = p.alloc().unwrap();
        p.release(a);
        p.release(a);
    }
}
