//! Paged KV-cache subsystem (vLLM-style block tables + RadixAttention-
//! style prefix sharing), the serving-scale counterpart of the paper's
//! LUT weight compression: once weights stream as 3–4-bit codes, the KV
//! cache dominates serving memory and caps batch size.
//!
//! * [`BlockPool`] — fixed-size physical blocks with refcounts.
//! * [`PrefixIndex`] — radix tree over full token chunks; requests whose
//!   prompts share a prefix share physical blocks, and finished requests
//!   leave their blocks cached until LRU eviction reclaims them.
//! * [`KvBlockStore`] — block storage trait with two implementations:
//!   dense [`F32Blocks`] (bit-exact with the contiguous cache) and
//!   [`LutBlocks`] (per-(layer, head) 4-bit non-uniform codebooks fitted
//!   with the GANQ machinery on block fill).
//! * [`PagedKv`] — per-slot block tables, admission with prefix reuse,
//!   copy-on-write on the first divergent append into a shared block,
//!   and youngest-first preemption when the pool runs dry.
//!
//! The serving integration lives in `coordinator::serve`
//! (`PagedNativeBackend`); the decode step reads and appends through
//! [`crate::model::forward::KvSeq`].

pub mod paged;
pub mod pool;
pub mod prefix;
pub mod store;

pub use paged::{PagedKv, PagedSeqs, SlotView};
pub use pool::BlockPool;
pub use prefix::PrefixIndex;
pub use store::{F32Blocks, KvBlockStore, KvLayout, LutBlocks, KV_LUT_BITS};

/// Counters exported to the serving metrics (`ServeMetrics.kv`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KvPoolStats {
    pub blocks_total: usize,
    pub blocks_in_use: usize,
    pub peak_blocks_in_use: usize,
    /// blocks held (possibly only) by the prefix index
    pub cached_blocks: usize,
    /// prompt tokens examined by prefix lookups at admission
    pub prefix_lookup_tokens: usize,
    /// prompt tokens served from shared prefix blocks
    pub prefix_hit_tokens: usize,
    pub preemptions: usize,
    pub cow_copies: usize,
    pub evictions: usize,
    pub sealed_blocks: usize,
}

impl KvPoolStats {
    /// Peak fraction of the pool in use.
    pub fn peak_occupancy(&self) -> f64 {
        if self.blocks_total == 0 {
            0.0
        } else {
            self.peak_blocks_in_use as f64 / self.blocks_total as f64
        }
    }

    /// Fraction of admitted prompt tokens served from shared blocks.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookup_tokens == 0 {
            0.0
        } else {
            self.prefix_hit_tokens as f64 / self.prefix_lookup_tokens as f64
        }
    }
}
