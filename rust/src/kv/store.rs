//! Physical KV block storage. One block holds `block_size` token
//! positions for every (layer, head): K and V rows of `head_dim` floats.
//!
//! Two representations implement [`KvBlockStore`]:
//!
//! * [`F32Blocks`] — dense f32, bit-exact with the contiguous
//!   [`crate::model::forward::KvCache`] path.
//! * [`LutBlocks`] — LUT-GEMM-style table storage for the cache: a block
//!   is quantized when it fills (seal) to 4-bit codes plus one non-uniform
//!   codebook per (layer, head), fitted with the GANQ machinery under an
//!   identity Hessian (`quant::ganq::fit_codebook_identity`). The open
//!   tail block stays f32 so appends and the just-written position are
//!   exact.

use crate::model::ModelConfig;
use crate::quant::ganq;
use crate::quant::lut::{nibble_at, pack_nibbles_flat};

/// Geometry of the paged cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvLayout {
    pub layers: usize,
    pub heads: usize,
    pub head_dim: usize,
    /// token positions per block
    pub block_size: usize,
}

impl KvLayout {
    pub fn new(cfg: &ModelConfig, block_size: usize) -> KvLayout {
        assert!(block_size > 0, "block_size must be positive");
        KvLayout {
            layers: cfg.layers,
            heads: cfg.heads,
            head_dim: cfg.head_dim(),
            block_size,
        }
    }

    /// f32 values per (layer, head) segment of one block.
    pub fn vals_per_seg(&self) -> usize {
        self.block_size * self.head_dim
    }

    /// f32 values per block (K or V side).
    pub fn vals_per_block(&self) -> usize {
        self.layers * self.heads * self.vals_per_seg()
    }

    fn seg(&self, li: usize, hi: usize) -> usize {
        li * self.heads + hi
    }

    /// Offset of the (layer, head, in-block position) row in a dense
    /// block buffer.
    fn off(&self, li: usize, hi: usize, off: usize) -> usize {
        (self.seg(li, hi) * self.block_size + off) * self.head_dim
    }
}

/// Storage backend for physical KV blocks, addressed by block id.
pub trait KvBlockStore {
    fn layout(&self) -> KvLayout;

    /// Store the K/V rows (`head_dim` floats each) for (layer, head,
    /// in-block offset). The block must be exclusively owned — the paged
    /// cache copies shared blocks before the first divergent append.
    fn write(
        &mut self,
        blk: usize,
        li: usize,
        hi: usize,
        off: usize,
        k: &[f32],
        v: &[f32],
    );

    /// Store `rows` consecutive positions (offsets `off..off+rows`,
    /// `rows * head_dim` floats per side) in one call — the batched
    /// row-append used by chunked prefill. Default loops `write`;
    /// dense representations override with a memcpy per (layer, head).
    fn write_rows(
        &mut self,
        blk: usize,
        li: usize,
        hi: usize,
        off: usize,
        rows: usize,
        k: &[f32],
        v: &[f32],
    ) {
        if rows == 0 {
            return;
        }
        let hd = k.len() / rows;
        for r in 0..rows {
            self.write(
                blk,
                li,
                hi,
                off + r,
                &k[r * hd..(r + 1) * hd],
                &v[r * hd..(r + 1) * hd],
            );
        }
    }

    /// Copy the cached K row into `out` (dequantizing if sealed).
    fn read_k(&self, blk: usize, li: usize, hi: usize, off: usize, out: &mut [f32]);
    fn read_v(&self, blk: usize, li: usize, hi: usize, off: usize, out: &mut [f32]);

    /// Borrow the K row in place when it exists as contiguous f32
    /// (dense blocks, staged tails); `None` routes the reader through
    /// `read_k` + scratch (sealed LUT blocks).
    fn k_slice(&self, blk: usize, li: usize, hi: usize, off: usize) -> Option<&[f32]> {
        let _ = (blk, li, hi, off);
        None
    }
    fn v_slice(&self, blk: usize, li: usize, hi: usize, off: usize) -> Option<&[f32]> {
        let _ = (blk, li, hi, off);
        None
    }

    /// Borrow `rows` consecutive in-block K rows (offsets
    /// `off..off+rows`) as one contiguous f32 run when the
    /// representation allows it — the batched decode gather then pays a
    /// single memcpy per (layer, head, block) instead of a dispatch per
    /// position. `None` falls back to per-row reads (sealed LUT blocks).
    fn k_rows_slice(
        &self,
        blk: usize,
        li: usize,
        hi: usize,
        off: usize,
        rows: usize,
    ) -> Option<&[f32]> {
        let _ = (blk, li, hi, off, rows);
        None
    }
    fn v_rows_slice(
        &self,
        blk: usize,
        li: usize,
        hi: usize,
        off: usize,
        rows: usize,
    ) -> Option<&[f32]> {
        let _ = (blk, li, hi, off, rows);
        None
    }

    /// Copy `src`'s contents into `dst` as mutable state (the
    /// copy-on-write target of a divergent append).
    fn copy_block(&mut self, src: usize, dst: usize);

    /// The block just filled and will not be written again until cleared:
    /// compressed stores quantize here.
    fn seal(&mut self, blk: usize) {
        let _ = blk;
    }

    /// The block returned to the free list: drop its state.
    fn clear(&mut self, blk: usize) {
        let _ = blk;
    }

    /// Resident bytes per physical block (K + V + metadata) — the
    /// capacity-accounting quantity.
    fn bytes_per_block(&self) -> usize;
}

// ---------------------------------------------------------------------------
// dense f32 blocks
// ---------------------------------------------------------------------------

pub struct F32Blocks {
    layout: KvLayout,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl F32Blocks {
    pub fn new(layout: KvLayout, num_blocks: usize) -> F32Blocks {
        let sz = layout.vals_per_block() * num_blocks;
        F32Blocks { layout, k: vec![0.0; sz], v: vec![0.0; sz] }
    }

    pub fn bytes_per_block_for(layout: KvLayout) -> usize {
        layout.vals_per_block() * 4 * 2
    }

    fn base(&self, blk: usize, li: usize, hi: usize, off: usize) -> usize {
        blk * self.layout.vals_per_block() + self.layout.off(li, hi, off)
    }
}

impl KvBlockStore for F32Blocks {
    fn layout(&self) -> KvLayout {
        self.layout
    }

    fn write(
        &mut self,
        blk: usize,
        li: usize,
        hi: usize,
        off: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let hd = self.layout.head_dim;
        let b = self.base(blk, li, hi, off);
        self.k[b..b + hd].copy_from_slice(k);
        self.v[b..b + hd].copy_from_slice(v);
    }

    fn write_rows(
        &mut self,
        blk: usize,
        li: usize,
        hi: usize,
        off: usize,
        rows: usize,
        k: &[f32],
        v: &[f32],
    ) {
        debug_assert!(off + rows <= self.layout.block_size);
        let b = self.base(blk, li, hi, off);
        self.k[b..b + k.len()].copy_from_slice(k);
        self.v[b..b + v.len()].copy_from_slice(v);
    }

    fn read_k(&self, blk: usize, li: usize, hi: usize, off: usize, out: &mut [f32]) {
        let hd = self.layout.head_dim;
        let b = self.base(blk, li, hi, off);
        out.copy_from_slice(&self.k[b..b + hd]);
    }

    fn read_v(&self, blk: usize, li: usize, hi: usize, off: usize, out: &mut [f32]) {
        let hd = self.layout.head_dim;
        let b = self.base(blk, li, hi, off);
        out.copy_from_slice(&self.v[b..b + hd]);
    }

    fn k_slice(&self, blk: usize, li: usize, hi: usize, off: usize) -> Option<&[f32]> {
        let hd = self.layout.head_dim;
        let b = self.base(blk, li, hi, off);
        Some(&self.k[b..b + hd])
    }

    fn v_slice(&self, blk: usize, li: usize, hi: usize, off: usize) -> Option<&[f32]> {
        let hd = self.layout.head_dim;
        let b = self.base(blk, li, hi, off);
        Some(&self.v[b..b + hd])
    }

    fn k_rows_slice(
        &self,
        blk: usize,
        li: usize,
        hi: usize,
        off: usize,
        rows: usize,
    ) -> Option<&[f32]> {
        debug_assert!(off + rows <= self.layout.block_size);
        let hd = self.layout.head_dim;
        let b = self.base(blk, li, hi, off);
        Some(&self.k[b..b + rows * hd])
    }

    fn v_rows_slice(
        &self,
        blk: usize,
        li: usize,
        hi: usize,
        off: usize,
        rows: usize,
    ) -> Option<&[f32]> {
        debug_assert!(off + rows <= self.layout.block_size);
        let hd = self.layout.head_dim;
        let b = self.base(blk, li, hi, off);
        Some(&self.v[b..b + rows * hd])
    }

    fn copy_block(&mut self, src: usize, dst: usize) {
        let n = self.layout.vals_per_block();
        self.k.copy_within(src * n..(src + 1) * n, dst * n);
        self.v.copy_within(src * n..(src + 1) * n, dst * n);
    }

    fn bytes_per_block(&self) -> usize {
        F32Blocks::bytes_per_block_for(self.layout)
    }
}

// ---------------------------------------------------------------------------
// 4-bit non-uniform LUT blocks
// ---------------------------------------------------------------------------

pub const KV_LUT_BITS: u8 = 4;
const KV_LUT_K: usize = 1 << KV_LUT_BITS;
/// Alternating S/T refinement passes per codebook fit at seal time.
const KV_FIT_ITERS: usize = 2;

struct Staged {
    k: Vec<f32>,
    v: Vec<f32>,
}

impl Staged {
    fn zeros(layout: KvLayout) -> Staged {
        let n = layout.vals_per_block();
        Staged { k: vec![0.0; n], v: vec![0.0; n] }
    }
}

struct Sealed {
    /// nibble-packed 4-bit codes per (layer, head) segment
    kq: Vec<u8>,
    vq: Vec<u8>,
    /// per-(layer, head) codebooks, `KV_LUT_K` entries each
    kt: Vec<f32>,
    vt: Vec<f32>,
}

pub struct LutBlocks {
    layout: KvLayout,
    staged: Vec<Option<Staged>>,
    sealed: Vec<Option<Sealed>>,
}

impl LutBlocks {
    pub fn new(layout: KvLayout, num_blocks: usize) -> LutBlocks {
        assert!(
            layout.vals_per_seg() % 2 == 0,
            "LUT blocks need an even per-segment value count for nibble \
             packing (block_size {} x head_dim {})",
            layout.block_size,
            layout.head_dim
        );
        LutBlocks {
            layout,
            staged: (0..num_blocks).map(|_| None).collect(),
            sealed: (0..num_blocks).map(|_| None).collect(),
        }
    }

    pub fn bytes_per_block_for(layout: KvLayout) -> usize {
        let segs = layout.layers * layout.heads;
        // packed codes (K + V) + f32 codebooks (K + V)
        2 * segs * layout.vals_per_seg() / 2 + 2 * segs * KV_LUT_K * 4
    }

    fn seg_range(&self, li: usize, hi: usize) -> std::ops::Range<usize> {
        let n = self.layout.vals_per_seg();
        let s = self.layout.seg(li, hi);
        s * n..(s + 1) * n
    }

    fn quantize_seg(vals: &[f32]) -> (Vec<u8>, Vec<f32>) {
        let (codes, t) =
            ganq::fit_codebook_identity(vals, KV_LUT_BITS, KV_FIT_ITERS);
        (pack_nibbles_flat(&codes), t)
    }

    fn dequant_row(
        &self,
        side_q: &[u8],
        side_t: &[f32],
        li: usize,
        hi: usize,
        off: usize,
        out: &mut [f32],
    ) {
        let hd = self.layout.head_dim;
        let seg = self.layout.seg(li, hi);
        let segb = self.layout.vals_per_seg() / 2;
        let q = &side_q[seg * segb..(seg + 1) * segb];
        let t = &side_t[seg * KV_LUT_K..(seg + 1) * KV_LUT_K];
        for (d, o) in out.iter_mut().enumerate() {
            *o = t[nibble_at(q, off * hd + d) as usize];
        }
    }

    fn dequant_block(&self, blk: usize) -> Staged {
        let sealed = self.sealed[blk].as_ref().expect("sealed block");
        let mut st = Staged::zeros(self.layout);
        let hd = self.layout.head_dim;
        for li in 0..self.layout.layers {
            for hi in 0..self.layout.heads {
                for off in 0..self.layout.block_size {
                    let b = self.layout.off(li, hi, off);
                    self.dequant_row(
                        &sealed.kq,
                        &sealed.kt,
                        li,
                        hi,
                        off,
                        &mut st.k[b..b + hd],
                    );
                    self.dequant_row(
                        &sealed.vq,
                        &sealed.vt,
                        li,
                        hi,
                        off,
                        &mut st.v[b..b + hd],
                    );
                }
            }
        }
        st
    }
}

impl KvBlockStore for LutBlocks {
    fn layout(&self) -> KvLayout {
        self.layout
    }

    fn write(
        &mut self,
        blk: usize,
        li: usize,
        hi: usize,
        off: usize,
        k: &[f32],
        v: &[f32],
    ) {
        debug_assert!(
            self.sealed[blk].is_none(),
            "write into sealed block {} (CoW missing)",
            blk
        );
        let layout = self.layout;
        let st = self.staged[blk].get_or_insert_with(|| Staged::zeros(layout));
        let hd = layout.head_dim;
        let b = layout.off(li, hi, off);
        st.k[b..b + hd].copy_from_slice(k);
        st.v[b..b + hd].copy_from_slice(v);
    }

    fn write_rows(
        &mut self,
        blk: usize,
        li: usize,
        hi: usize,
        off: usize,
        rows: usize,
        k: &[f32],
        v: &[f32],
    ) {
        debug_assert!(off + rows <= self.layout.block_size);
        debug_assert!(
            self.sealed[blk].is_none(),
            "write into sealed block {} (CoW missing)",
            blk
        );
        let layout = self.layout;
        let st = self.staged[blk].get_or_insert_with(|| Staged::zeros(layout));
        let b = layout.off(li, hi, off);
        st.k[b..b + k.len()].copy_from_slice(k);
        st.v[b..b + v.len()].copy_from_slice(v);
    }

    fn read_k(&self, blk: usize, li: usize, hi: usize, off: usize, out: &mut [f32]) {
        let hd = self.layout.head_dim;
        if let Some(st) = &self.staged[blk] {
            let b = self.layout.off(li, hi, off);
            out.copy_from_slice(&st.k[b..b + hd]);
        } else {
            let sealed = self.sealed[blk]
                .as_ref()
                .unwrap_or_else(|| panic!("read of unwritten block {}", blk));
            self.dequant_row(&sealed.kq, &sealed.kt, li, hi, off, out);
        }
    }

    fn read_v(&self, blk: usize, li: usize, hi: usize, off: usize, out: &mut [f32]) {
        let hd = self.layout.head_dim;
        if let Some(st) = &self.staged[blk] {
            let b = self.layout.off(li, hi, off);
            out.copy_from_slice(&st.v[b..b + hd]);
        } else {
            let sealed = self.sealed[blk]
                .as_ref()
                .unwrap_or_else(|| panic!("read of unwritten block {}", blk));
            self.dequant_row(&sealed.vq, &sealed.vt, li, hi, off, out);
        }
    }

    fn k_slice(&self, blk: usize, li: usize, hi: usize, off: usize) -> Option<&[f32]> {
        let hd = self.layout.head_dim;
        self.staged[blk].as_ref().map(|st| {
            let b = self.layout.off(li, hi, off);
            &st.k[b..b + hd]
        })
    }

    fn v_slice(&self, blk: usize, li: usize, hi: usize, off: usize) -> Option<&[f32]> {
        let hd = self.layout.head_dim;
        self.staged[blk].as_ref().map(|st| {
            let b = self.layout.off(li, hi, off);
            &st.v[b..b + hd]
        })
    }

    fn k_rows_slice(
        &self,
        blk: usize,
        li: usize,
        hi: usize,
        off: usize,
        rows: usize,
    ) -> Option<&[f32]> {
        debug_assert!(off + rows <= self.layout.block_size);
        let hd = self.layout.head_dim;
        // staged (open / CoW'd) blocks are dense f32; sealed blocks
        // dequantize per row through the fallback
        self.staged[blk].as_ref().map(|st| {
            let b = self.layout.off(li, hi, off);
            &st.k[b..b + rows * hd]
        })
    }

    fn v_rows_slice(
        &self,
        blk: usize,
        li: usize,
        hi: usize,
        off: usize,
        rows: usize,
    ) -> Option<&[f32]> {
        debug_assert!(off + rows <= self.layout.block_size);
        let hd = self.layout.head_dim;
        self.staged[blk].as_ref().map(|st| {
            let b = self.layout.off(li, hi, off);
            &st.v[b..b + rows * hd]
        })
    }

    fn copy_block(&mut self, src: usize, dst: usize) {
        let st = match (&self.staged[src], &self.sealed[src]) {
            (Some(s), _) => Staged { k: s.k.clone(), v: s.v.clone() },
            (None, Some(_)) => self.dequant_block(src),
            (None, None) => Staged::zeros(self.layout),
        };
        self.staged[dst] = Some(st);
        self.sealed[dst] = None;
    }

    fn seal(&mut self, blk: usize) {
        let st = self.staged[blk].take().expect("seal of unwritten block");
        let segs = self.layout.layers * self.layout.heads;
        let segb = self.layout.vals_per_seg() / 2;
        let mut sealed = Sealed {
            kq: vec![0u8; segs * segb],
            vq: vec![0u8; segs * segb],
            kt: vec![0.0; segs * KV_LUT_K],
            vt: vec![0.0; segs * KV_LUT_K],
        };
        for li in 0..self.layout.layers {
            for hi in 0..self.layout.heads {
                let seg = self.layout.seg(li, hi);
                let r = self.seg_range(li, hi);
                let (kq, kt) = LutBlocks::quantize_seg(&st.k[r.clone()]);
                sealed.kq[seg * segb..(seg + 1) * segb].copy_from_slice(&kq);
                sealed.kt[seg * KV_LUT_K..(seg + 1) * KV_LUT_K]
                    .copy_from_slice(&kt);
                let (vq, vt) = LutBlocks::quantize_seg(&st.v[r]);
                sealed.vq[seg * segb..(seg + 1) * segb].copy_from_slice(&vq);
                sealed.vt[seg * KV_LUT_K..(seg + 1) * KV_LUT_K]
                    .copy_from_slice(&vt);
            }
        }
        self.sealed[blk] = Some(sealed);
    }

    fn clear(&mut self, blk: usize) {
        self.staged[blk] = None;
        self.sealed[blk] = None;
    }

    fn bytes_per_block(&self) -> usize {
        LutBlocks::bytes_per_block_for(self.layout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn layout() -> KvLayout {
        KvLayout { layers: 2, heads: 2, head_dim: 8, block_size: 4 }
    }

    fn fill_block(
        store: &mut dyn KvBlockStore,
        blk: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, Vec<f32>) {
        let l = store.layout();
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        for li in 0..l.layers {
            for hi in 0..l.heads {
                for off in 0..l.block_size {
                    let k = rng.normal_vec_f32(l.head_dim);
                    let v = rng.normal_vec_f32(l.head_dim);
                    store.write(blk, li, hi, off, &k, &v);
                    ks.extend_from_slice(&k);
                    vs.extend_from_slice(&v);
                }
            }
        }
        (ks, vs)
    }

    fn read_all(store: &dyn KvBlockStore, blk: usize) -> (Vec<f32>, Vec<f32>) {
        let l = store.layout();
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        let mut row = vec![0.0f32; l.head_dim];
        for li in 0..l.layers {
            for hi in 0..l.heads {
                for off in 0..l.block_size {
                    store.read_k(blk, li, hi, off, &mut row);
                    ks.extend_from_slice(&row);
                    store.read_v(blk, li, hi, off, &mut row);
                    vs.extend_from_slice(&row);
                }
            }
        }
        (ks, vs)
    }

    #[test]
    fn f32_store_roundtrips_and_copies() {
        let mut rng = Rng::new(7);
        let mut s = F32Blocks::new(layout(), 3);
        let (ks, vs) = fill_block(&mut s, 1, &mut rng);
        let (rk, rv) = read_all(&s, 1);
        assert_eq!(ks, rk);
        assert_eq!(vs, rv);
        s.copy_block(1, 2);
        let (ck, cv) = read_all(&s, 2);
        assert_eq!(ks, ck);
        assert_eq!(vs, cv);
    }

    #[test]
    fn lut_store_seal_keeps_values_within_tolerance() {
        let mut rng = Rng::new(8);
        let mut s = LutBlocks::new(layout(), 3);
        let (ks, vs) = fill_block(&mut s, 0, &mut rng);
        // open block reads are exact
        let (rk, rv) = read_all(&s, 0);
        assert_eq!(ks, rk);
        assert_eq!(vs, rv);

        s.seal(0);
        let (qk, qv) = read_all(&s, 0);
        // 4-bit non-uniform on ~N(0,1): coarse but bounded
        let worst_k = crate::util::prop::max_abs_diff(&ks, &qk);
        let worst_v = crate::util::prop::max_abs_diff(&vs, &qv);
        assert!(worst_k < 0.8, "K error {}", worst_k);
        assert!(worst_v < 0.8, "V error {}", worst_v);

        // CoW from a sealed block materializes the dequantized values
        s.copy_block(0, 2);
        let (ck, cv) = read_all(&s, 2);
        assert_eq!(qk, ck);
        assert_eq!(qv, cv);

        s.clear(0);
        s.write(0, 0, 0, 0, &[1.0; 8], &[2.0; 8]);
        let mut row = vec![0.0f32; 8];
        s.read_k(0, 0, 0, 0, &mut row);
        assert_eq!(row, vec![1.0; 8]);
    }

    #[test]
    fn lut_blocks_are_much_smaller_than_f32() {
        let l = layout();
        let f = F32Blocks::bytes_per_block_for(l);
        let q = LutBlocks::bytes_per_block_for(l);
        assert!(q * 4 < f, "lut {} vs f32 {}", q, f);
    }
}
