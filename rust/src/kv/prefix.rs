//! Radix-tree prefix index at block granularity (RadixAttention-style).
//!
//! Each edge is labelled with one full block of tokens; a node maps that
//! chunk to the physical block holding its KV. Requests whose prompts
//! share a prefix of full blocks share the physical blocks (the pool
//! refcounts them). Finished requests leave their sealed blocks cached in
//! the tree; when the pool runs dry the least-recently-used leaves are
//! evicted first (leaf-first keeps every cached path reachable from the
//! root).

use std::collections::BTreeMap;

const ROOT: usize = 0;
const NO_BLOCK: usize = usize::MAX;

#[derive(Debug)]
struct Node {
    children: BTreeMap<Vec<i32>, usize>,
    parent: usize,
    /// token chunk labelling the edge from `parent` (empty for the root)
    key: Vec<i32>,
    /// physical block holding this chunk's KV (`NO_BLOCK` for the root
    /// and tombstoned slab entries)
    block: usize,
    last_use: u64,
}

#[derive(Debug)]
pub struct PrefixIndex {
    nodes: Vec<Node>,
    free_nodes: Vec<usize>,
    tick: u64,
    cached: usize,
}

impl Default for PrefixIndex {
    fn default() -> Self {
        PrefixIndex::new()
    }
}

impl PrefixIndex {
    pub fn new() -> PrefixIndex {
        PrefixIndex {
            nodes: vec![Node {
                children: BTreeMap::new(),
                parent: ROOT,
                key: Vec::new(),
                block: NO_BLOCK,
                last_use: 0,
            }],
            free_nodes: Vec::new(),
            tick: 0,
            cached: 0,
        }
    }

    /// Number of blocks currently indexed.
    pub fn cached_blocks(&self) -> usize {
        self.cached
    }

    /// Physical block ids of every cached chunk (the root and tombstoned
    /// slab entries carry `NO_BLOCK` and are skipped). Each id appears
    /// once per node that pins it, so the auditor can count index-held
    /// references directly from the returned list.
    pub fn cached_block_ids(&self) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|&(id, n)| id != ROOT && n.block != NO_BLOCK)
            .map(|(_, n)| n.block)
            .collect()
    }

    /// Refresh LRU stamps along the path from `node` to the root so an
    /// ancestor is never older than a live descendant (eviction is
    /// leaf-first).
    fn touch(&mut self, mut node: usize) {
        self.tick += 1;
        while node != ROOT {
            self.nodes[node].last_use = self.tick;
            node = self.nodes[node].parent;
        }
    }

    /// Longest cached chain of full `bs`-token chunks prefixing `tokens`;
    /// returns the physical blocks, position order. Touches the LRU.
    pub fn lookup(&mut self, tokens: &[i32], bs: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut node = ROOT;
        for chunk in tokens.chunks_exact(bs) {
            match self.nodes[node].children.get(chunk) {
                Some(&c) => {
                    out.push(self.nodes[c].block);
                    node = c;
                }
                None => break,
            }
        }
        if node != ROOT {
            self.touch(node);
        }
        out
    }

    /// Non-mutating lookup for admission headroom checks: number of
    /// matched full blocks.
    pub fn peek(&self, tokens: &[i32], bs: usize) -> usize {
        let mut node = ROOT;
        let mut hits = 0;
        for chunk in tokens.chunks_exact(bs) {
            match self.nodes[node].children.get(chunk) {
                Some(&c) => {
                    node = c;
                    hits += 1;
                }
                None => break,
            }
        }
        hits
    }

    /// Non-mutating [`PrefixIndex::lookup`]: the matched block chain in
    /// position order, without touching the LRU stamps. The cluster
    /// router uses this as its prefix-affinity routing key (its "blocks"
    /// are replica ids), where a routing probe must not perturb eviction
    /// order.
    pub fn peek_blocks(&self, tokens: &[i32], bs: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut node = ROOT;
        for chunk in tokens.chunks_exact(bs) {
            match self.nodes[node].children.get(chunk) {
                Some(&c) => {
                    out.push(self.nodes[c].block);
                    node = c;
                }
                None => break,
            }
        }
        out
    }

    /// Index a sequence's sealed blocks: `blocks[i]` holds the KV of
    /// `tokens[i*bs..(i+1)*bs]`. Chunks already cached (possibly under a
    /// different physical block) are left as-is; the return value lists
    /// the physical blocks newly cached, which the caller must pin with a
    /// pool reference.
    pub fn insert_chain(
        &mut self,
        tokens: &[i32],
        bs: usize,
        blocks: &[usize],
    ) -> Vec<usize> {
        let mut fresh = Vec::new();
        let mut node = ROOT;
        for (ci, chunk) in tokens.chunks_exact(bs).enumerate() {
            node = match self.nodes[node].children.get(chunk) {
                Some(&c) => c,
                None => {
                    let nid = self.new_node(node, chunk.to_vec(), blocks[ci]);
                    fresh.push(blocks[ci]);
                    self.cached += 1;
                    nid
                }
            };
        }
        if node != ROOT {
            self.touch(node);
        }
        fresh
    }

    fn new_node(&mut self, parent: usize, key: Vec<i32>, block: usize) -> usize {
        let node = Node {
            children: BTreeMap::new(),
            parent,
            key: key.clone(),
            block,
            last_use: self.tick,
        };
        let id = match self.free_nodes.pop() {
            Some(id) => {
                self.nodes[id] = node;
                id
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        };
        self.nodes[parent].children.insert(key, id);
        id
    }

    /// Blocks that could eventually be reclaimed by eviction. `free`
    /// approves blocks held only by the cache; because a request pins its
    /// whole matched path, such blocks always form leaf-closed subtrees,
    /// so the count is exact.
    pub fn evictable_blocks<F: Fn(usize) -> bool>(&self, free: F) -> usize {
        self.nodes
            .iter()
            .enumerate()
            .filter(|&(id, n)| {
                id != ROOT && n.block != NO_BLOCK && free(n.block)
            })
            .count()
    }

    /// Evict the least-recently-used leaf whose block `free` approves
    /// (the caller passes "only the cache references it"); returns the
    /// evicted block, which the caller must release back to the pool.
    pub fn evict_lru<F: Fn(usize) -> bool>(&mut self, free: F) -> Option<usize> {
        let mut best: Option<(u64, usize)> = None;
        for (id, n) in self.nodes.iter().enumerate() {
            if id == ROOT || n.block == NO_BLOCK || !n.children.is_empty() {
                continue;
            }
            if !free(n.block) {
                continue;
            }
            if best.map_or(true, |(t, _)| n.last_use < t) {
                best = Some((n.last_use, id));
            }
        }
        let (_, id) = best?;
        let key = std::mem::take(&mut self.nodes[id].key);
        let parent = self.nodes[id].parent;
        self.nodes[parent].children.remove(&key);
        let block = self.nodes[id].block;
        self.nodes[id].block = NO_BLOCK;
        self.nodes[id].children = BTreeMap::new();
        self.free_nodes.push(id);
        self.cached -= 1;
        Some(block)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_matches_longest_prefix() {
        let mut ix = PrefixIndex::new();
        let toks: Vec<i32> = (0..12).collect();
        assert!(ix.insert_chain(&toks, 4, &[10, 11, 12]).len() == 3);
        assert_eq!(ix.cached_blocks(), 3);

        // identical prefix, divergent tail
        let mut other = toks.clone();
        other[9] = 99;
        assert_eq!(ix.lookup(&other, 4), vec![10, 11]);
        // re-inserting the shared path caches only the divergent chunk
        let fresh = ix.insert_chain(&other, 4, &[10, 11, 20]);
        assert_eq!(fresh, vec![20]);
        assert_eq!(ix.lookup(&other, 4), vec![10, 11, 20]);
        // partial chunks never match
        assert_eq!(ix.peek(&toks[..7], 4), 1);
    }

    #[test]
    fn peek_blocks_matches_lookup_without_lru_touch() {
        let mut ix = PrefixIndex::new();
        let a: Vec<i32> = (0..8).collect();
        let mut b = a.clone();
        b[7] = 77; // shares the first chunk
        ix.insert_chain(&a, 4, &[1, 2]);
        ix.insert_chain(&b, 4, &[1, 3]);

        assert_eq!(ix.peek_blocks(&a, 4), vec![1, 2]);
        assert_eq!(ix.peek_blocks(&b, 4), vec![1, 3]);
        assert_eq!(ix.peek_blocks(&a[..7], 4), vec![1]);
        assert!(ix.peek_blocks(&[9, 9, 9, 9], 4).is_empty());

        // peeking must not change eviction order: after a real touch of
        // branch b, a's leaf is the LRU victim, and a peek of branch a
        // does not rescue it
        ix.lookup(&b, 4);
        ix.peek_blocks(&a, 4);
        assert_eq!(ix.evict_lru(|_| true), Some(2));
    }

    #[test]
    fn eviction_is_leaf_first_and_lru_ordered() {
        let mut ix = PrefixIndex::new();
        let a: Vec<i32> = (0..8).collect();
        let mut b = a.clone();
        b[7] = 77; // shares the first chunk
        ix.insert_chain(&a, 4, &[1, 2]);
        ix.insert_chain(&b, 4, &[1, 3]);
        assert_eq!(ix.cached_blocks(), 3);

        // touch branch b: branch a's leaf becomes LRU
        ix.lookup(&b, 4);
        assert_eq!(ix.evict_lru(|_| true), Some(2));
        // shared chunk 1 has a child left (leaf-first): next is leaf 3
        assert_eq!(ix.evict_lru(|_| true), Some(3));
        assert_eq!(ix.evict_lru(|_| true), Some(1));
        assert_eq!(ix.evict_lru(|_| true), None);
        assert_eq!(ix.cached_blocks(), 0);

        // slab reuse after tombstoning
        ix.insert_chain(&a, 4, &[5, 6]);
        assert_eq!(ix.lookup(&a, 4), vec![5, 6]);
    }

    #[test]
    fn eviction_respects_pins() {
        let mut ix = PrefixIndex::new();
        let a: Vec<i32> = (0..8).collect();
        ix.insert_chain(&a, 4, &[1, 2]);
        // block 2 pinned (e.g. a running request still reads it)
        assert_eq!(ix.evict_lru(|b| b != 2), None); // 1 is not a leaf
        assert_eq!(ix.evictable_blocks(|b| b != 2), 1);
        assert_eq!(ix.evict_lru(|_| true), Some(2));
        assert_eq!(ix.evict_lru(|_| true), Some(1));
    }
}
