//! The paged KV cache: per-request block tables over a refcounted
//! [`BlockPool`], prefix sharing through a [`PrefixIndex`], copy-on-write
//! on the first divergent append into a shared block, and LRU eviction of
//! freed-but-cached prefixes.
//!
//! Lifecycle of a slot:
//!
//! 1. `admit` — match the prompt against the prefix index; share every
//!    reusable block (pool refcount++) and resume decoding after the
//!    shared positions (capped at `prompt.len() - 1` so the final prompt
//!    token still produces logits).
//! 2. `prepare_step_n` — before every scheduler step, make each active
//!    slot appendable for the positions it will write (one for a decode,
//!    several for a prefill chunk): allocate fresh tail blocks, or CoW a
//!    partially-shared tail. When the pool is dry even after evicting
//!    cached prefixes, the youngest-admitted slots are preempted
//!    (released and reported back for requeueing).
//! 3. `push_tokens` + [`SlotView`] — the engine step reads/writes
//!    through the block table ([`crate::model::forward::KvSeq`]).
//! 4. When an advance crosses a block boundary the filled block is
//!    sealed (quantized stores compress here) and indexed for future
//!    prefix hits.
//! 5. `release` — drop the slot's references; blocks also held by the
//!    index stay cached until evicted.

use crate::model::forward::{KvSeq, SeqAccess};
use crate::obs::trace;

use super::pool::BlockPool;
use super::prefix::PrefixIndex;
use super::store::KvBlockStore;
use super::KvPoolStats;

struct Seq {
    /// physical block per `block_size` positions, in order
    blocks: Vec<usize>,
    /// token history (the prefix index needs token identity at seal time)
    tokens: Vec<i32>,
    /// positions cached so far == tokens.len() after `push_token`
    pos: usize,
    /// admission order; preemption victims are picked youngest-first
    admitted_at: u64,
}

pub struct PagedKv {
    pool: BlockPool,
    store: Box<dyn KvBlockStore>,
    index: PrefixIndex,
    slots: Vec<Option<Seq>>,
    clock: u64,
    draft_window: bool,
    /// per-slot block-table length when the draft window opened: blocks
    /// acquired after the anchor hold draft rows and must never be
    /// prefix-indexed (see [`PagedKv::audit`])
    draft_anchor: Vec<Option<usize>>,
    /// indexed-block count when the draft window opened (the index must
    /// not grow while drafting)
    window_cached: Option<usize>,
    /// invariant sweep switch: `debug_assertions || GANQ_AUDIT=1` at
    /// construction, overridable via [`PagedKv::set_audit`]
    audit_on: bool,
    audits: usize,
    prefix_lookup_tokens: usize,
    prefix_hit_tokens: usize,
    preemptions: usize,
    cow_copies: usize,
    evictions: usize,
    sealed_blocks: usize,
}

/// Default auditor enablement: always in debug builds, `GANQ_AUDIT=1`
/// opt-in for release serving. The env is read once per process so the
/// release fast path stays one boolean test per step.
fn audit_default() -> bool {
    if cfg!(debug_assertions) {
        return true;
    }
    static FROM_ENV: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *FROM_ENV.get_or_init(|| {
        matches!(std::env::var("GANQ_AUDIT").as_deref(), Ok("1"))
    })
}

impl PagedKv {
    pub fn new(store: Box<dyn KvBlockStore>, num_blocks: usize, slots: usize) -> PagedKv {
        PagedKv {
            pool: BlockPool::new(num_blocks),
            store,
            index: PrefixIndex::new(),
            slots: (0..slots).map(|_| None).collect(),
            clock: 0,
            draft_window: false,
            draft_anchor: (0..slots).map(|_| None).collect(),
            window_cached: None,
            audit_on: audit_default(),
            audits: 0,
            prefix_lookup_tokens: 0,
            prefix_hit_tokens: 0,
            preemptions: 0,
            cow_copies: 0,
            evictions: 0,
            sealed_blocks: 0,
        }
    }

    /// The slot's live sequence. Callers pass slots the scheduler keeps
    /// admitted (active sets, router assignments); a vacant slot here is
    /// a scheduler bug, not a load condition.
    fn seq(&self, slot: usize) -> &Seq {
        // lint:allow(hot-expect): scheduler invariant — see doc above
        self.slots[slot].as_ref().expect("active slot")
    }

    /// Mutable twin of [`PagedKv::seq`], same invariant.
    fn seq_mut(&mut self, slot: usize) -> &mut Seq {
        // lint:allow(hot-expect): scheduler invariant — see seq() doc
        self.slots[slot].as_mut().expect("active slot")
    }

    pub fn num_slots(&self) -> usize {
        self.slots.len()
    }

    pub fn block_size(&self) -> usize {
        self.store.layout().block_size
    }

    pub fn bytes_per_block(&self) -> usize {
        self.store.bytes_per_block()
    }

    /// Cached positions of a slot (0 when vacant).
    pub fn pos(&self, slot: usize) -> usize {
        self.slots[slot].as_ref().map_or(0, |s| s.pos)
    }

    /// Free blocks plus cached blocks that eviction could reclaim.
    pub fn reclaimable_blocks(&self) -> usize {
        let pool = &self.pool;
        self.pool.free_blocks()
            + self.index.evictable_blocks(|b| pool.refcount(b) == 1)
    }

    /// Admission headroom check: blocks for the uncached prompt part plus
    /// one decode block must be reclaimable.
    pub fn can_admit(&self, prompt: &[i32], _max_new: usize) -> bool {
        let bs = self.block_size();
        let cached = self.index.peek(prompt, bs) * bs;
        let hit = cached.min(prompt.len().saturating_sub(1));
        let needed = (prompt.len() - hit).div_ceil(bs) + 1;
        self.reclaimable_blocks() >= needed
    }

    /// Admit a request into a vacant slot. Returns the number of prompt
    /// positions covered by shared prefix blocks — always less than
    /// `prompt.len()`, so the caller still decodes the final prompt token
    /// — or `None` when the pool lacks headroom.
    pub fn admit(
        &mut self,
        slot: usize,
        prompt: &[i32],
        max_new: usize,
    ) -> Option<usize> {
        assert!(self.slots[slot].is_none(), "admit into occupied slot {}", slot);
        if !self.can_admit(prompt, max_new) {
            return None;
        }
        let bs = self.block_size();
        let matched = self.index.lookup(prompt, bs);
        let hit = (matched.len() * bs).min(prompt.len().saturating_sub(1));
        let nshare = hit.div_ceil(bs);
        let mut blocks = Vec::with_capacity(nshare);
        for &b in &matched[..nshare] {
            self.pool.retain(b);
            blocks.push(b);
        }
        self.prefix_lookup_tokens += prompt.len();
        self.prefix_hit_tokens += hit;
        if hit > 0 {
            trace::instant("kv.prefix_hit", &[("tokens", hit as f64)]);
        }
        self.clock += 1;
        self.slots[slot] = Some(Seq {
            blocks,
            tokens: prompt[..hit].to_vec(),
            pos: hit,
            admitted_at: self.clock,
        });
        if self.draft_window {
            // a slot admitted mid-window anchors at its shared prefix:
            // every block it acquires before the window closes is
            // draft-only and must stay out of the index
            self.draft_anchor[slot] = Some(nshare);
        }
        Some(hit)
    }

    /// Drop the slot's block references; blocks still cached in the
    /// prefix index survive for future hits.
    pub fn release(&mut self, slot: usize) {
        if let Some(seq) = self.slots[slot].take() {
            for &b in &seq.blocks {
                if self.pool.release(b) {
                    self.store.clear(b);
                }
            }
        }
        self.draft_anchor[slot] = None;
    }

    /// Allocate a block, evicting LRU cached prefixes if needed.
    fn alloc_block(&mut self) -> Option<usize> {
        if let Some(b) = self.pool.alloc() {
            return Some(b);
        }
        let pool = &self.pool;
        let victim = self.index.evict_lru(|b| pool.refcount(b) == 1)?;
        self.evictions += 1;
        trace::instant("kv.evict", &[("block", victim as f64)]);
        let freed = self.pool.release(victim);
        debug_assert!(freed, "evicted block must become free");
        self.store.clear(victim);
        self.pool.alloc()
    }

    /// Make `slot` writable for `n` appended positions starting at its
    /// current one: copy-on-write a partially-shared tail, then allocate
    /// however many fresh tail blocks the run needs. False when the pool
    /// is exhausted (partially-allocated tails are kept; a retry after
    /// preemption continues from where it stopped).
    fn ensure_appendable_n(&mut self, slot: usize, n: usize) -> bool {
        let bs = self.block_size();
        let (pos, nblocks, tail) = {
            let seq = self.seq(slot);
            (seq.pos, seq.blocks.len(), seq.blocks.last().copied())
        };
        debug_assert!(pos <= nblocks * bs, "block table behind pos");
        if pos < nblocks * bs {
            // mid-block tail: CoW the first divergent append into a
            // shared block
            // lint:allow(hot-expect): pos < nblocks*bs ⇒ the table is
            // nonempty, so a last block exists
            let tail = tail.expect("mid-block position implies a tail");
            if self.pool.refcount(tail) > 1 {
                match self.alloc_block() {
                    Some(dst) => {
                        self.store.copy_block(tail, dst);
                        self.pool.release(tail);
                        // lint:allow(hot-expect): same nonempty-table
                        // argument as the read of `tail` above
                        let last = self.seq_mut(slot).blocks.last_mut().expect("tail");
                        *last = dst;
                        self.cow_copies += 1;
                        trace::instant(
                            "kv.cow",
                            &[("slot", slot as f64)],
                        );
                    }
                    None => return false,
                }
            }
        }
        let target = (pos + n).div_ceil(bs);
        while self.seq(slot).blocks.len() < target {
            match self.alloc_block() {
                Some(b) => self.seq_mut(slot).blocks.push(b),
                None => return false,
            }
        }
        true
    }

    /// Guarantee every active slot can append one position this step.
    /// Shorthand for [`PagedKv::prepare_step_n`] with `need = 1` per
    /// active slot (the all-decode step).
    pub fn prepare_step(&mut self, active: &[bool]) -> Vec<usize> {
        let need: Vec<usize> =
            active.iter().map(|&a| usize::from(a)).collect();
        self.prepare_step_n(&need)
    }

    /// Guarantee every slot can append `need[slot]` positions this step
    /// (0 = idle; a prefill chunk needs several), preempting the
    /// youngest-admitted slots when blocks run out. Returns the
    /// preempted slots; their state is already released and the caller
    /// requeues the requests (recompute-style preemption).
    pub fn prepare_step_n(&mut self, need: &[usize]) -> Vec<usize> {
        let mut victims = Vec::new();
        let mut alive: Vec<usize> = (0..need.len().min(self.slots.len()))
            .filter(|&i| need[i] > 0 && self.slots[i].is_some())
            .collect();
        // oldest admission first: under pressure the young yield to the old
        alive.sort_by_key(|&i| self.seq(i).admitted_at);
        let mut idx = 0;
        while idx < alive.len() {
            let slot = alive[idx];
            if self.ensure_appendable_n(slot, need[slot]) {
                idx += 1;
                continue;
            }
            // lint:allow(hot-expect): idx < alive.len() ⇒ nonempty
            let victim = *alive.last().expect("alive is nonempty");
            self.release(victim);
            self.preemptions += 1;
            trace::instant("kv.preempt", &[("slot", victim as f64)]);
            victims.push(victim);
            alive.pop();
            // if the victim was `slot` itself the loop index now points
            // past it; otherwise retry `slot` with the freed blocks
        }
        victims
    }

    /// Record the token about to be decoded at the slot's current
    /// position (sealing indexes the chunk under its token content).
    pub fn push_token(&mut self, slot: usize, tok: i32) {
        self.push_tokens(slot, &[tok]);
    }

    /// Record the run of tokens about to be appended this step (a
    /// prefill chunk; sealing indexes blocks under their token content).
    pub fn push_tokens(&mut self, slot: usize, toks: &[i32]) {
        let seq = self.seq_mut(slot);
        debug_assert_eq!(seq.tokens.len(), seq.pos, "tokens behind pos");
        seq.tokens.extend_from_slice(toks);
    }

    /// Roll a slot back to `n` cached positions (no-op when `n >= pos`):
    /// the speculative-decoding rollback primitive. Tail blocks past the
    /// new length are released (freed unless the prefix index still
    /// caches them); a kept mid-block tail that was already sealed is
    /// handled by ownership: exclusively-owned blocks are re-opened in
    /// place (`copy_block(b, b)` materializes the staged form), shared
    /// ones stay sealed and the next append copy-on-writes them exactly
    /// like a divergent append into a shared prefix.
    pub fn truncate_slot(&mut self, slot: usize, n: usize) {
        let bs = self.block_size();
        let (old_pos, tail) = {
            let Some(seq) = self.slots[slot].as_mut() else {
                return;
            };
            if n >= seq.pos {
                return;
            }
            let old_pos = seq.pos;
            let keep = n.div_ceil(bs);
            let tail = seq.blocks.split_off(keep);
            seq.tokens.truncate(n);
            seq.pos = n;
            (old_pos, tail)
        };
        let dropped = tail.len();
        for b in tail {
            if self.pool.release(b) {
                self.store.clear(b);
            }
        }
        let keep = n.div_ceil(bs);
        if n % bs != 0 && old_pos >= keep * bs {
            // the kept tail block was full (and sealed at the boundary);
            // re-open it for the coming appends if we own it outright —
            // sealed blocks the index or another slot still references
            // keep their state and CoW on the next prepare_step
            let tb = self.seq(slot).blocks[keep - 1];
            if self.pool.refcount(tb) == 1 {
                self.store.copy_block(tb, tb);
            }
        }
        trace::instant(
            "kv.truncate",
            &[
                ("slot", slot as f64),
                ("dropped", (old_pos - n) as f64),
                ("blocks", dropped as f64),
            ],
        );
    }

    /// Toggle the speculative draft window. While on, appended positions
    /// advance without sealing or prefix-indexing the blocks they fill:
    /// draft rows are written at the draft width and rolled back before
    /// the verifier rewrites the same positions, so indexing them would
    /// poison the prefix cache with content future admissions must never
    /// share. Verify-phase appends (window off) seal and index normally —
    /// their rows are a pure function of the token sequence, so even
    /// later-truncated blocks stay valid cache entries.
    pub fn set_draft_window(&mut self, on: bool) {
        if on && !self.draft_window {
            // anchor the auditor's draft-isolation invariant: blocks a
            // slot acquires from here on hold draft rows and must never
            // show up in the prefix index, and the index itself must not
            // grow until the window closes
            for (slot, seq) in self.slots.iter().enumerate() {
                self.draft_anchor[slot] = seq.as_ref().map(|s| s.blocks.len());
            }
            self.window_cached = Some(self.index.cached_blocks());
        } else if !on {
            self.draft_anchor.iter_mut().for_each(|a| *a = None);
            self.window_cached = None;
        }
        self.draft_window = on;
    }

    /// KvSeq view of one slot for single-sequence engine steps.
    pub fn slot_view(&mut self, slot: usize) -> SlotView<'_> {
        SlotView { kv: self, slot }
    }

    /// [`SeqAccess`] adapter over a set of active slots for
    /// `forward::Engine::step`: sequences are visited one at a time
    /// because slot views alias the shared block pool.
    pub fn seqs(&mut self, slots: Vec<usize>) -> PagedSeqs<'_> {
        PagedSeqs { kv: self, slots }
    }

    fn locate(&self, slot: usize, sj: usize) -> (usize, usize) {
        let seq = self.seq(slot);
        let bs = self.block_size();
        (seq.blocks[sj / bs], sj % bs)
    }

    /// Copy `rows` consecutive positions starting at `sj0` for one
    /// (layer, head), walking the block table in whole-block runs and
    /// taking the store's contiguous fast path where available.
    fn read_rows(
        &self,
        slot: usize,
        li: usize,
        hi: usize,
        sj0: usize,
        rows: usize,
        out: &mut [f32],
        k_side: bool,
    ) {
        if rows == 0 {
            return;
        }
        let bs = self.block_size();
        let hd = out.len() / rows;
        let seq = self.seq(slot);
        let mut done = 0usize;
        while done < rows {
            let sj = sj0 + done;
            let blk = seq.blocks[sj / bs];
            let off = sj % bs;
            let run = (bs - off).min(rows - done);
            let dst = &mut out[done * hd..(done + run) * hd];
            let fast = if k_side {
                self.store.k_rows_slice(blk, li, hi, off, run)
            } else {
                self.store.v_rows_slice(blk, li, hi, off, run)
            };
            match fast {
                Some(src) => dst.copy_from_slice(src),
                None => {
                    for (r, drow) in dst.chunks_mut(hd).enumerate() {
                        if k_side {
                            self.store.read_k(blk, li, hi, off + r, drow);
                        } else {
                            self.store.read_v(blk, li, hi, off + r, drow);
                        }
                    }
                }
            }
            done += run;
        }
    }

    /// Commit `n` appended positions, sealing (and prefix-indexing)
    /// every block the run fills. A chunked append seals exactly the
    /// blocks a token-by-token walk would have sealed.
    fn advance_n(&mut self, slot: usize, n: usize) {
        let bs = self.block_size();
        {
            let seq = self.seq(slot);
            debug_assert!(
                seq.tokens.len() >= seq.pos + n,
                "push_tokens must cover the advance"
            );
        }
        for _ in 0..n {
            let pos = {
                let seq = self.seq_mut(slot);
                seq.pos += 1;
                seq.pos
            };
            if pos % bs == 0 && !self.draft_window {
                // The block holding positions [pos-bs, pos) just filled.
                // insert_chain re-walks the chain from the root on every
                // seal: ctx/bs is small (<= 16 for the builtin configs)
                // and a cached node handle could go stale under LRU
                // eviction of ancestors between seals.
                let (blk, tokens, blocks) = {
                    let seq = self.seq(slot);
                    (
                        seq.blocks[pos / bs - 1],
                        seq.tokens[..pos].to_vec(),
                        seq.blocks[..pos / bs].to_vec(),
                    )
                };
                self.store.seal(blk);
                self.sealed_blocks += 1;
                for b in self.index.insert_chain(&tokens, bs, &blocks) {
                    self.pool.retain(b);
                }
            }
        }
    }

    pub fn stats(&self) -> KvPoolStats {
        KvPoolStats {
            blocks_total: self.pool.num_blocks(),
            blocks_in_use: self.pool.used_blocks(),
            peak_blocks_in_use: self.pool.peak_used(),
            cached_blocks: self.index.cached_blocks(),
            prefix_lookup_tokens: self.prefix_lookup_tokens,
            prefix_hit_tokens: self.prefix_hit_tokens,
            preemptions: self.preemptions,
            cow_copies: self.cow_copies,
            evictions: self.evictions,
            sealed_blocks: self.sealed_blocks,
        }
    }

    /// Full invariant sweep over the paged cache (see
    /// `rust/xtask/README.md`, "The paged-KV auditor"):
    ///
    /// 1. refcount conservation + leak freedom + free-list consistency,
    ///    delegated to [`BlockPool::audit`] with the expectation derived
    ///    from the two legal reference sources (slot block tables and
    ///    prefix-index pins);
    /// 2. index liveness — a cached block whose refcount went to zero
    ///    shows up as a conservation mismatch in (1);
    /// 3. block tables cover their slot's position and token history;
    /// 4. draft-window isolation — while the window is on, no block
    ///    acquired past a slot's draft anchor may be indexed, and the
    ///    index must not have grown since the window opened.
    ///
    /// Read-only and allocation-light (one `u32` per pool block); the
    /// caller decides whether a violation panics.
    pub fn audit(&self) -> Result<(), String> {
        let n = self.pool.num_blocks();
        let bs = self.block_size();
        let mut expected = vec![0u32; n];
        for (slot, seq) in self.slots.iter().enumerate() {
            let Some(seq) = seq.as_ref() else { continue };
            for &b in &seq.blocks {
                if b >= n {
                    return Err(format!(
                        "slot {} maps bogus block {}",
                        slot, b
                    ));
                }
                expected[b] += 1;
            }
            if seq.blocks.len() * bs < seq.pos {
                return Err(format!(
                    "slot {} block table covers {} positions but pos={}",
                    slot,
                    seq.blocks.len() * bs,
                    seq.pos
                ));
            }
            if seq.tokens.len() < seq.pos {
                return Err(format!(
                    "slot {} has {} tokens behind pos={}",
                    slot,
                    seq.tokens.len(),
                    seq.pos
                ));
            }
        }
        let cached = self.index.cached_block_ids();
        for &b in &cached {
            if b >= n {
                return Err(format!("prefix index caches bogus block {}", b));
            }
            // a dead cached block (refcount 0) surfaces as a
            // conservation mismatch below: expected >= 1, pool holds 0
            expected[b] += 1;
        }
        self.pool
            .audit(&expected)
            .map_err(|e| format!("pool audit: {}", e))?;
        if self.draft_window {
            if let Some(cap) = self.window_cached {
                if self.index.cached_blocks() > cap {
                    return Err(format!(
                        "prefix index grew {} -> {} inside the draft \
                         window",
                        cap,
                        self.index.cached_blocks()
                    ));
                }
            }
            let indexed: std::collections::BTreeSet<usize> =
                cached.iter().copied().collect();
            for (slot, seq) in self.slots.iter().enumerate() {
                let Some(seq) = seq.as_ref() else { continue };
                let Some(anchor) = self.draft_anchor[slot] else {
                    continue;
                };
                for &b in &seq.blocks[anchor.min(seq.blocks.len())..] {
                    if indexed.contains(&b) {
                        return Err(format!(
                            "draft row leaked into the prefix index: \
                             slot {} block {} was acquired after the \
                             draft anchor ({} blocks) yet is cached",
                            slot, b, anchor
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Step-boundary audit hook: free when disabled (one boolean test),
    /// panics on the first violated invariant when enabled.
    pub fn maybe_audit(&mut self) {
        if !self.audit_on {
            return;
        }
        self.audits += 1;
        trace::instant("kv.audit", &[("n", self.audits as f64)]);
        if let Err(e) = self.audit() {
            // lint:allow(hot-panic): the auditor is debug/env-gated and
            // a violated pool invariant means corrupted KV state — dying
            // loudly here is the feature
            panic!("kv audit failed: {}", e);
        }
    }

    /// Override the `debug_assertions || GANQ_AUDIT=1` default.
    pub fn set_audit(&mut self, on: bool) {
        self.audit_on = on;
    }

    pub fn audit_enabled(&self) -> bool {
        self.audit_on
    }

    /// Number of sweeps [`PagedKv::maybe_audit`] has actually run — the
    /// zero-overhead contract for disabled release builds is pinned by
    /// asserting this stays 0.
    pub fn audits_run(&self) -> usize {
        self.audits
    }

    /// Test-only fault injection: leak one reference on `blk` so the
    /// next audit must report a conservation violation. Proves the
    /// auditor catches real refcount bugs, not just vacuous truths.
    pub fn debug_retain_block(&mut self, blk: usize) {
        self.pool.retain(blk);
    }
}

/// Mutable view of one slot implementing the decode-step KV contract.
pub struct SlotView<'a> {
    kv: &'a mut PagedKv,
    slot: usize,
}

impl KvSeq for SlotView<'_> {
    fn pos(&self) -> usize {
        self.kv.pos(self.slot)
    }

    fn write(&mut self, li: usize, hi: usize, sj: usize, k: &[f32], v: &[f32]) {
        let (blk, off) = self.kv.locate(self.slot, sj);
        self.kv.store.write(blk, li, hi, off, k, v);
    }

    fn write_rows(
        &mut self,
        li: usize,
        hi: usize,
        sj0: usize,
        rows: usize,
        k: &[f32],
        v: &[f32],
    ) {
        // walk the block table in whole-block runs; each run is one
        // contiguous store write (the append analogue of read_rows)
        if rows == 0 {
            return;
        }
        let bs = self.kv.block_size();
        let hd = k.len() / rows;
        let mut done = 0usize;
        while done < rows {
            let sj = sj0 + done;
            let (blk, off) = self.kv.locate(self.slot, sj);
            let run = (bs - off).min(rows - done);
            self.kv.store.write_rows(
                blk,
                li,
                hi,
                off,
                run,
                &k[done * hd..(done + run) * hd],
                &v[done * hd..(done + run) * hd],
            );
            done += run;
        }
    }

    fn read_k(&self, li: usize, hi: usize, sj: usize, out: &mut [f32]) {
        let (blk, off) = self.kv.locate(self.slot, sj);
        self.kv.store.read_k(blk, li, hi, off, out);
    }

    fn read_v(&self, li: usize, hi: usize, sj: usize, out: &mut [f32]) {
        let (blk, off) = self.kv.locate(self.slot, sj);
        self.kv.store.read_v(blk, li, hi, off, out);
    }

    fn k_slice(&self, li: usize, hi: usize, sj: usize) -> Option<&[f32]> {
        let (blk, off) = self.kv.locate(self.slot, sj);
        self.kv.store.k_slice(blk, li, hi, off)
    }

    fn v_slice(&self, li: usize, hi: usize, sj: usize) -> Option<&[f32]> {
        let (blk, off) = self.kv.locate(self.slot, sj);
        self.kv.store.v_slice(blk, li, hi, off)
    }

    fn read_k_rows(
        &self,
        li: usize,
        hi: usize,
        sj0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        self.kv.read_rows(self.slot, li, hi, sj0, rows, out, true);
    }

    fn read_v_rows(
        &self,
        li: usize,
        hi: usize,
        sj0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        self.kv.read_rows(self.slot, li, hi, sj0, rows, out, false);
    }

    fn advance(&mut self, n: usize) {
        self.kv.advance_n(self.slot, n);
    }

    fn truncate(&mut self, n: usize) {
        self.kv.truncate_slot(self.slot, n);
    }
}

/// Mutable multi-slot access for the batched decode engine
/// ([`SeqAccess`]): hands the engine one [`SlotView`] at a time.
pub struct PagedSeqs<'a> {
    kv: &'a mut PagedKv,
    slots: Vec<usize>,
}

impl SeqAccess for PagedSeqs<'_> {
    fn count(&self) -> usize {
        self.slots.len()
    }

    fn with_seq(&mut self, i: usize, f: &mut dyn FnMut(&mut dyn KvSeq)) {
        let mut view = self.kv.slot_view(self.slots[i]);
        f(&mut view);
    }
}

#[cfg(test)]
mod tests {
    use super::super::store::{F32Blocks, KvLayout};
    use super::*;

    fn layout() -> KvLayout {
        KvLayout { layers: 1, heads: 1, head_dim: 2, block_size: 4 }
    }

    fn paged(num_blocks: usize, slots: usize) -> PagedKv {
        PagedKv::new(
            Box::new(F32Blocks::new(layout(), num_blocks)),
            num_blocks,
            slots,
        )
    }

    /// Drive `n` decode positions through a slot: prepare, token, write
    /// one marker row per (layer, head), advance.
    fn run_tokens(kv: &mut PagedKv, slot: usize, toks: &[i32]) {
        for &t in toks {
            let mut active = vec![false; kv.num_slots()];
            active[slot] = true;
            let victims = kv.prepare_step(&active);
            assert!(victims.is_empty(), "unexpected preemption");
            kv.push_token(slot, t);
            let mut view = kv.slot_view(slot);
            let row = [t as f32, -(t as f32)];
            let pos = view.pos();
            view.write(0, 0, pos, &row, &row);
            view.advance(1);
        }
    }

    /// Same positions appended as one chunk: prepare for the whole run,
    /// write all rows with `write_rows`, advance once.
    fn run_chunk(kv: &mut PagedKv, slot: usize, toks: &[i32]) {
        let mut need = vec![0usize; kv.num_slots()];
        need[slot] = toks.len();
        let victims = kv.prepare_step_n(&need);
        assert!(victims.is_empty(), "unexpected preemption");
        kv.push_tokens(slot, toks);
        let mut view = kv.slot_view(slot);
        let pos = view.pos();
        let mut ks = Vec::new();
        for &t in toks {
            ks.extend_from_slice(&[t as f32, -(t as f32)]);
        }
        view.write_rows(0, 0, pos, toks.len(), &ks, &ks);
        view.advance(toks.len());
    }

    #[test]
    fn shared_prefix_refcounts_and_release() {
        let mut kv = paged(8, 2);
        let prompt: Vec<i32> = (0..8).collect();
        assert_eq!(kv.admit(0, &prompt, 4), Some(0));
        run_tokens(&mut kv, 0, &prompt);
        // two sealed blocks, both cached and pinned by slot 0 and index
        let s = kv.stats();
        assert_eq!(s.sealed_blocks, 2);
        assert_eq!(s.cached_blocks, 2);

        // identical prompt: slot 1 shares the first block fully; position
        // 7 stays uncached (the last prompt token must produce logits),
        // so the second block is shared partially
        let hit = kv.admit(1, &prompt, 4).unwrap();
        assert_eq!(hit, 7);
        let b0 = kv.slots[0].as_ref().unwrap().blocks.clone();
        let b1 = kv.slots[1].as_ref().unwrap().blocks.clone();
        assert_eq!(b0[0], b1[0]);
        assert_eq!(b0[1], b1[1]);
        // refcounts: slot0 + slot1 + index
        assert_eq!(kv.pool.refcount(b0[0]), 3);
        assert_eq!(kv.pool.refcount(b0[1]), 3);

        kv.release(0);
        assert_eq!(kv.pool.refcount(b0[0]), 2);
        kv.release(1);
        // blocks stay cached (index ref), not freed
        assert_eq!(kv.pool.refcount(b0[0]), 1);
        assert_eq!(kv.pool.used_blocks(), 2);
    }

    #[test]
    fn divergent_append_copies_on_write() {
        let mut kv = paged(8, 2);
        let prompt: Vec<i32> = (0..8).collect(); // exactly 2 blocks
        kv.admit(0, &prompt, 4).unwrap();
        run_tokens(&mut kv, 0, &prompt);
        let b0 = kv.slots[0].as_ref().unwrap().blocks.clone();

        // identical prompt: hit caps at 7, so the second block is shared
        // partially and the first append into it must copy-on-write
        let hit = kv.admit(1, &prompt, 4).unwrap();
        assert_eq!(hit, 7);
        let before = kv.slots[1].as_ref().unwrap().blocks.clone();
        assert_eq!(before[1], b0[1]);

        // decode the last prompt token with a divergent value, then one
        // generated token
        run_tokens(&mut kv, 1, &[70, 200]);
        let after = kv.slots[1].as_ref().unwrap().blocks.clone();
        assert_eq!(after[0], b0[0], "full block still shared");
        assert_ne!(after[1], b0[1], "divergent tail was copied");
        assert_eq!(kv.stats().cow_copies, 1);

        // the copy preserved the shared positions...
        let mut row = [0.0f32; 2];
        let mut view = kv.slot_view(1);
        view.read_k(0, 0, 4, &mut row);
        assert_eq!(row, [4.0, -4.0]);
        // ...took the divergent write privately...
        view.read_k(0, 0, 7, &mut row);
        assert_eq!(row, [70.0, -70.0]);
        // ...and left slot 0's block untouched
        let mut view0 = kv.slot_view(0);
        view0.read_k(0, 0, 7, &mut row);
        assert_eq!(row, [7.0, -7.0], "slot 0 unaffected");
    }

    #[test]
    fn eviction_frees_lru_cached_prefixes() {
        let mut kv = paged(4, 1);
        // request A fills 2 blocks, finishes; blocks stay cached
        let a: Vec<i32> = (0..8).collect();
        kv.admit(0, &a, 1).unwrap();
        run_tokens(&mut kv, 0, &a);
        kv.release(0);
        assert_eq!(kv.stats().cached_blocks, 2);
        assert_eq!(kv.pool.free_blocks(), 2);

        // request B needs 3 fresh blocks: 2 free + 1 evicted (LRU leaf)
        let b: Vec<i32> = (100..112).collect();
        kv.admit(0, &b, 1).unwrap();
        run_tokens(&mut kv, 0, &b);
        let s = kv.stats();
        assert_eq!(s.evictions, 1);
        // A's first block is still cached; its tail was evicted
        assert_eq!(kv.index.peek(&a, 4), 1);
    }

    #[test]
    fn preemption_picks_youngest_and_reports_it() {
        let mut kv = paged(3, 2);
        let a: Vec<i32> = (0..4).collect();
        let b: Vec<i32> = (50..54).collect();
        kv.admit(0, &a, 8).unwrap();
        run_tokens(&mut kv, 0, &a); // slot 0 owns 1 sealed block
        kv.admit(1, &b, 8).unwrap();
        run_tokens(&mut kv, 1, &b); // slot 1 owns 1 sealed block
        // one free block left; both slots hit a boundary next step:
        // the younger slot 1 must yield
        let victims = kv.prepare_step(&[true, true]);
        assert_eq!(victims, vec![1]);
        assert_eq!(kv.stats().preemptions, 1);
        assert!(kv.slots[1].is_none());
        // slot 0 got the tail it needed
        assert_eq!(kv.slots[0].as_ref().unwrap().blocks.len(), 2);
    }

    #[test]
    fn batched_row_reads_cross_block_boundaries() {
        // dense store: ranges spanning sealed + tail blocks come back
        // identical to per-row reads
        let mut kv = paged(8, 1);
        let toks: Vec<i32> = (0..10).collect(); // 2.5 blocks of 4
        kv.admit(0, &toks, 1).unwrap();
        run_tokens(&mut kv, 0, &toks);
        let view = SlotView { kv: &mut kv, slot: 0 };
        let mut ranged = vec![0.0f32; 10 * 2];
        view.read_k_rows(0, 0, 0, 10, &mut ranged);
        let mut single = vec![0.0f32; 2];
        for sj in 0..10 {
            view.read_k(0, 0, sj, &mut single);
            assert_eq!(&ranged[sj * 2..sj * 2 + 2], &single[..], "pos {}", sj);
        }
        // offset range starting mid-block
        let mut mid = vec![0.0f32; 5 * 2];
        view.read_v_rows(0, 0, 3, 5, &mut mid);
        for (r, sj) in (3..8).enumerate() {
            view.read_v(0, 0, sj, &mut single);
            assert_eq!(&mid[r * 2..r * 2 + 2], &single[..], "pos {}", sj);
        }
    }

    #[test]
    fn batched_row_reads_through_sealed_lut_blocks() {
        use super::super::store::LutBlocks;
        let l = KvLayout { layers: 1, heads: 1, head_dim: 2, block_size: 4 };
        let mut kv =
            PagedKv::new(Box::new(LutBlocks::new(l, 8)), 8, 1);
        let toks: Vec<i32> = (0..6).collect(); // one sealed + one tail
        kv.admit(0, &toks, 1).unwrap();
        run_tokens(&mut kv, 0, &toks);
        let view = SlotView { kv: &mut kv, slot: 0 };
        let mut ranged = vec![0.0f32; 6 * 2];
        view.read_k_rows(0, 0, 0, 6, &mut ranged);
        let mut single = vec![0.0f32; 2];
        for sj in 0..6 {
            view.read_k(0, 0, sj, &mut single);
            assert_eq!(
                &ranged[sj * 2..sj * 2 + 2],
                &single[..],
                "sealed/tail pos {}",
                sj
            );
        }
    }

    #[test]
    fn chunked_append_matches_per_token_and_seals_identically() {
        // 10 positions (2.5 blocks of 4): a single chunked append must
        // leave the same rows, seal the same blocks, and index the same
        // prefixes as a token-by-token walk
        let toks: Vec<i32> = (0..10).collect();
        let mut kv_t = paged(8, 1);
        kv_t.admit(0, &toks, 1).unwrap();
        run_tokens(&mut kv_t, 0, &toks);
        let mut kv_c = paged(8, 1);
        kv_c.admit(0, &toks, 1).unwrap();
        run_chunk(&mut kv_c, 0, &toks);

        assert_eq!(kv_t.pos(0), kv_c.pos(0));
        assert_eq!(kv_t.stats().sealed_blocks, kv_c.stats().sealed_blocks);
        assert_eq!(kv_t.stats().cached_blocks, kv_c.stats().cached_blocks);
        assert_eq!(kv_t.index.peek(&toks, 4), kv_c.index.peek(&toks, 4));
        let mut a = [0.0f32; 2];
        let mut b = [0.0f32; 2];
        for sj in 0..10 {
            let vt = kv_t.slot_view(0);
            vt.read_k(0, 0, sj, &mut a);
            let vc = kv_c.slot_view(0);
            vc.read_k(0, 0, sj, &mut b);
            assert_eq!(a, b, "pos {}", sj);
        }
    }

    #[test]
    fn prepare_step_n_allocates_multi_block_runs() {
        // a 9-position chunk needs 3 fresh blocks at once
        let mut kv = paged(4, 1);
        kv.admit(0, &[1, 2], 1).unwrap();
        let victims = kv.prepare_step_n(&[9]);
        assert!(victims.is_empty());
        assert_eq!(kv.slots[0].as_ref().unwrap().blocks.len(), 3);
        // and an oversized run preempts (here: the slot itself, pool dry)
        let mut kv2 = paged(2, 1);
        kv2.admit(0, &[1], 1).unwrap();
        let victims = kv2.prepare_step_n(&[12]);
        assert_eq!(victims, vec![0]);
    }

    #[test]
    fn admission_respects_pool_headroom() {
        let mut kv = paged(2, 2);
        let long: Vec<i32> = (0..12).collect(); // needs 4 blocks
        assert_eq!(kv.admit(0, &long, 4), None);
        let short: Vec<i32> = vec![1, 2];
        assert!(kv.admit(0, &short, 2).is_some());
    }

    #[test]
    fn truncate_releases_tail_blocks() {
        let mut kv = paged(8, 1);
        let toks: Vec<i32> = (0..10).collect(); // 2.5 blocks of 4
        kv.admit(0, &toks, 1).unwrap();
        run_tokens(&mut kv, 0, &toks);
        assert_eq!(kv.slots[0].as_ref().unwrap().blocks.len(), 3);
        let free_before = kv.pool.free_blocks();

        // drop back to 5 positions: the open tail block (8..10) is freed
        // outright; the sealed mid-block tail (4..8) stays (index-cached)
        kv.slot_view(0).truncate(5);
        assert_eq!(kv.pos(0), 5);
        assert_eq!(kv.slots[0].as_ref().unwrap().blocks.len(), 2);
        assert_eq!(kv.pool.free_blocks(), free_before + 1);

        // kept positions still read back exactly (dense store)
        let mut row = [0.0f32; 2];
        for sj in 0..5 {
            kv.slot_view(0).read_k(0, 0, sj, &mut row);
            assert_eq!(row, [sj as f32, -(sj as f32)], "pos {}", sj);
        }
        // truncating at or past the current length is a no-op
        kv.slot_view(0).truncate(5);
        kv.slot_view(0).truncate(99);
        assert_eq!(kv.pos(0), 5);
    }

    #[test]
    fn truncate_shared_sealed_tail_cows_on_next_append() {
        let mut kv = paged(8, 1);
        let toks: Vec<i32> = (0..8).collect(); // exactly 2 sealed blocks
        kv.admit(0, &toks, 1).unwrap();
        run_tokens(&mut kv, 0, &toks);
        let b1 = kv.slots[0].as_ref().unwrap().blocks[1];
        assert_eq!(kv.pool.refcount(b1), 2, "slot + index");

        // roll back into the sealed tail: it stays sealed (the index
        // still caches it), so the divergent re-append must CoW
        kv.slot_view(0).truncate(5);
        assert_eq!(kv.pos(0), 5);
        assert_eq!(kv.slots[0].as_ref().unwrap().blocks[1], b1);
        run_tokens(&mut kv, 0, &[70, 80, 90]);
        assert_eq!(kv.stats().cow_copies, 1);
        assert_ne!(kv.slots[0].as_ref().unwrap().blocks[1], b1);

        // the slot sees kept history + the rewrite...
        let mut row = [0.0f32; 2];
        kv.slot_view(0).read_k(0, 0, 4, &mut row);
        assert_eq!(row, [4.0, -4.0]);
        kv.slot_view(0).read_k(0, 0, 5, &mut row);
        assert_eq!(row, [70.0, -70.0]);
        // ...while the original prefix stays intact in the index
        assert_eq!(kv.index.peek(&toks, 4), 2);
    }

    #[test]
    fn truncate_to_zero_then_reuse_slot() {
        let mut kv = paged(4, 1);
        let toks: Vec<i32> = (0..6).collect();
        kv.admit(0, &toks, 1).unwrap();
        run_tokens(&mut kv, 0, &toks);
        kv.slot_view(0).truncate(0);
        assert_eq!(kv.pos(0), 0);
        assert!(kv.slots[0].as_ref().unwrap().blocks.is_empty());
        // the slot stays admitted and can rebuild from scratch
        run_tokens(&mut kv, 0, &[9, 8, 7]);
        assert_eq!(kv.pos(0), 3);
        let mut row = [0.0f32; 2];
        kv.slot_view(0).read_k(0, 0, 0, &mut row);
        assert_eq!(row, [9.0, -9.0]);
    }

    #[test]
    fn truncate_matches_straight_run_after_reappend() {
        // rollback + re-append must be indistinguishable from a cache
        // that only ever saw the final history
        let mut kv = paged(8, 1);
        kv.admit(0, &(0..10).collect::<Vec<i32>>(), 1).unwrap();
        run_tokens(&mut kv, 0, &(0..10).collect::<Vec<i32>>());
        kv.slot_view(0).truncate(6);
        run_tokens(&mut kv, 0, &[60, 61, 62]);

        let straight: Vec<i32> =
            (0..6).chain([60, 61, 62]).collect();
        let mut kv_ref = paged(8, 1);
        kv_ref.admit(0, &straight, 1).unwrap();
        run_tokens(&mut kv_ref, 0, &straight);

        assert_eq!(kv.pos(0), kv_ref.pos(0));
        let mut a = [0.0f32; 2];
        let mut b = [0.0f32; 2];
        for sj in 0..9 {
            kv.slot_view(0).read_k(0, 0, sj, &mut a);
            kv_ref.slot_view(0).read_k(0, 0, sj, &mut b);
            assert_eq!(a, b, "k pos {}", sj);
            kv.slot_view(0).read_v(0, 0, sj, &mut a);
            kv_ref.slot_view(0).read_v(0, 0, sj, &mut b);
            assert_eq!(a, b, "v pos {}", sj);
        }
    }

    #[test]
    fn draft_window_skips_seal_and_index() {
        let mut kv = paged(8, 1);
        kv.admit(0, &[1, 2], 8).unwrap();
        run_tokens(&mut kv, 0, &[1, 2]);
        let sealed_before = kv.stats().sealed_blocks;

        // six draft positions cross two block boundaries inside the
        // window: nothing seals, nothing lands in the prefix index
        kv.set_draft_window(true);
        run_tokens(&mut kv, 0, &[10, 11, 12, 13, 14, 15]);
        kv.set_draft_window(false);
        assert_eq!(kv.pos(0), 8);
        assert_eq!(kv.stats().sealed_blocks, sealed_before);
        let drafted: Vec<i32> = vec![1, 2, 10, 11, 12, 13, 14, 15];
        assert_eq!(
            kv.index.peek(&drafted, 4),
            0,
            "draft-width rows must never be prefix-cached"
        );

        // roll the draft back and re-append the verify rows for the
        // same positions: now the blocks seal and index normally
        kv.slot_view(0).truncate(2);
        assert_eq!(kv.pos(0), 2);
        run_chunk(&mut kv, 0, &[10, 11, 12, 13, 14, 15]);
        assert_eq!(kv.stats().sealed_blocks, sealed_before + 2);
        assert_eq!(kv.index.peek(&drafted, 4), 2);
        let mut row = [0.0f32; 2];
        kv.slot_view(0).read_k(0, 0, 2, &mut row);
        assert_eq!(row, [10.0, -10.0], "verify row overwrote the draft");
    }

    #[test]
    fn truncate_mid_speculation_on_shared_sealed_tail() {
        let mut kv = paged(8, 2);
        let prompt: Vec<i32> = (0..8).collect(); // exactly 2 sealed blocks
        kv.admit(0, &prompt, 4).unwrap();
        run_tokens(&mut kv, 0, &prompt);
        assert_eq!(kv.admit(1, &prompt, 4), Some(7));
        let b = kv.slots[0].as_ref().unwrap().blocks.clone();

        // slot 1 speculates straight into the shared sealed tail: the
        // draft append CoWs a private copy (slot 0 and the index keep
        // the original), fills a third block in the window, rolls back
        kv.set_draft_window(true);
        run_tokens(&mut kv, 1, &[7, 90, 91]);
        kv.set_draft_window(false);
        assert_eq!(kv.stats().cow_copies, 1);
        let b1 = kv.slots[1].as_ref().unwrap().blocks.clone();
        assert_eq!(b1[0], b[0], "full block still shared");
        assert_ne!(b1[1], b[1], "draft went into a private copy");
        kv.slot_view(1).truncate(7);
        assert_eq!(kv.pos(1), 7);

        // slot 0's rows and the cached prefix are untouched by the
        // rolled-back speculation
        let mut row = [0.0f32; 2];
        kv.slot_view(0).read_k(0, 0, 7, &mut row);
        assert_eq!(row, [7.0, -7.0]);
        assert_eq!(kv.index.peek(&prompt, 4), 2);

        // resume with verify-width rows: slot 1 rebuilds from position
        // 7 and both slots read back their own histories
        run_tokens(&mut kv, 1, &[7, 80, 81]);
        assert_eq!(kv.pos(1), 10);
        kv.slot_view(1).read_k(0, 0, 7, &mut row);
        assert_eq!(row, [7.0, -7.0]);
        kv.slot_view(1).read_k(0, 0, 8, &mut row);
        assert_eq!(row, [80.0, -80.0]);
        kv.slot_view(0).read_k(0, 0, 7, &mut row);
        assert_eq!(row, [7.0, -7.0], "slot 0 unaffected");
    }

    #[test]
    fn truncate_through_quantized_store() {
        use super::super::store::LutBlocks;
        let l = KvLayout { layers: 1, heads: 1, head_dim: 2, block_size: 4 };
        let mut kv = PagedKv::new(Box::new(LutBlocks::new(l, 8)), 8, 1);
        let toks: Vec<i32> = (0..10).collect();
        kv.admit(0, &toks, 1).unwrap();
        run_tokens(&mut kv, 0, &toks);

        // roll back into the sealed second block and re-append: the CoW
        // copy dequantizes the kept rows, so reads stay within LUT
        // tolerance and new rows are exact (staged f32)
        kv.slot_view(0).truncate(5);
        run_tokens(&mut kv, 0, &[21, 22]);
        assert_eq!(kv.pos(0), 7);
        let mut row = [0.0f32; 2];
        for sj in 0..5 {
            kv.slot_view(0).read_k(0, 0, sj, &mut row);
            let want = sj as f32;
            // same single-quantization error bound the store tests pin
            assert!(
                (row[0] - want).abs() < 0.8 && (row[1] + want).abs() < 0.8,
                "pos {}: {:?}",
                sj,
                row
            );
        }
        kv.slot_view(0).read_k(0, 0, 5, &mut row);
        assert_eq!(row, [21.0, -21.0]);
        kv.slot_view(0).read_k(0, 0, 6, &mut row);
        assert_eq!(row, [22.0, -22.0]);
    }
}
