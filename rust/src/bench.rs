//! Shared scaffolding for the bench binaries (benches/table*.rs): loading
//! trained models + runtime, one-shot calibration reuse, and the
//! quantize->perplexity grid used by Tables 2/5/8/9/10. The `traffic`
//! submodule is the open-loop serving workload generator behind
//! `benches/serve_traffic.rs` and the `traffic` CLI subcommand.

pub mod traffic;

use crate::coordinator::{self, Calibration, QuantEngine};
use crate::data::corpus::{self, Flavor, Split};
use crate::eval::{perplexity, PplEngine};
use crate::model::forward::Weights;
use crate::model::{QuantizedModel, WeightStore};
use crate::runtime::Runtime;

pub struct BenchCtx {
    pub rt: Option<Runtime>,
}

impl BenchCtx {
    pub fn load() -> BenchCtx {
        let rt = match Runtime::load() {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!(
                    "NOTE: no artifacts ({}); benches fall back to the \
                     native path where possible",
                    e
                );
                None
            }
        };
        BenchCtx { rt }
    }

    pub fn store(&self, model: &str) -> Option<WeightStore> {
        let cfg = match self.rt.as_ref().and_then(|r| r.manifest.models.get(model)) {
            Some(e) => e.config,
            None => crate::model::ModelConfig::builtin(model)?,
        };
        let base = crate::util::artifacts_dir();
        match WeightStore::load(&base, model, cfg) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("skipping {}: {}", model, e);
                None
            }
        }
    }

    pub fn calibrate(&self, store: &WeightStore, seqs: usize) -> Calibration {
        coordinator::calibrate(store, seqs, 128)
    }

    pub fn quantize(
        &self,
        store: &WeightStore,
        calib: &Calibration,
        method: &str,
        bits: u8,
    ) -> QuantizedModel {
        let engine = match &self.rt {
            Some(rt) => QuantEngine::Hlo(rt),
            None => QuantEngine::Native,
        };
        coordinator::quantize_model(store, method, bits, calib, &engine, false)
            .expect("quantize")
    }

    /// Perplexity via the HLO nll graph when available, native otherwise.
    pub fn ppl(
        &self,
        model: &str,
        store: &WeightStore,
        qm: Option<&QuantizedModel>,
        flavor: Flavor,
        batches: usize,
    ) -> f64 {
        if let Some(rt) = &self.rt {
            if let Ok(mut eng) = PplEngine::hlo(rt, model, store, qm) {
                return perplexity(&mut eng, flavor, Split::Valid, batches)
                    .expect("ppl");
            }
        }
        let mut eng = match qm {
            Some(q) => PplEngine::native(Weights::Quant(q)),
            None => PplEngine::native(Weights::Fp(store)),
        };
        perplexity(&mut eng, flavor, Split::Valid, batches).expect("ppl")
    }
}

/// The standard ppl-grid row set for Tables 2/8/9: full + 4 basic methods
/// at 4 and 3 bits.
pub fn ppl_grid(
    ctx: &BenchCtx,
    models: &[&str],
    methods: &[&str],
    flavor_name: &str,
    batches: usize,
) -> Vec<(String, u8, Vec<Option<f64>>)> {
    let flavor = corpus::flavor(flavor_name).expect("flavor");
    let stores: Vec<Option<(WeightStore, Calibration)>> = models
        .iter()
        .map(|m| {
            ctx.store(m).map(|s| {
                let c = ctx.calibrate(&s, 32);
                (s, c)
            })
        })
        .collect();
    let mut rows = Vec::new();
    // FP baseline
    let full: Vec<Option<f64>> = stores
        .iter()
        .enumerate()
        .map(|(i, sc)| {
            sc.as_ref()
                .map(|(s, _)| ctx.ppl(models[i], s, None, flavor, batches))
        })
        .collect();
    rows.push(("full".to_string(), 16, full));
    for &bits in &[4u8, 3] {
        for &method in methods {
            let vals: Vec<Option<f64>> = stores
                .iter()
                .enumerate()
                .map(|(i, sc)| {
                    sc.as_ref().map(|(s, c)| {
                        let qm = ctx.quantize(s, c, method, bits);
                        ctx.ppl(models[i], s, Some(&qm), flavor, batches)
                    })
                })
                .collect();
            rows.push((method.to_string(), bits, vals));
        }
    }
    rows
}

pub fn print_ppl_table(
    title: &str,
    models: &[&str],
    rows: &[(String, u8, Vec<Option<f64>>)],
) {
    let mut headers = vec!["method", "bits"];
    headers.extend(models.iter().copied());
    let mut t = crate::util::timer::Table::new(title, &headers);
    for (method, bits, vals) in rows {
        let mut cells = vec![method.clone(), bits.to_string()];
        for v in vals {
            cells.push(match v {
                Some(p) => crate::util::timer::fmt_f(*p, 3),
                None => "-".to_string(),
            });
        }
        t.row(cells);
    }
    t.print();
}
