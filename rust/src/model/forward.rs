//! Native CPU forward path — numerically mirrors python/compile/model.py
//! (layer_norm eps, tanh-GELU, attention scaling, tied head).
//!
//! Everything runs through one session-based engine: [`Engine`] owns the
//! resolved/packed/interned per-layer weight plans and the scratch arena,
//! and [`Engine::step`] advances a [`StepPlan`] — a mixed batch of work
//! items where each item is either a **prefill chunk** (several prompt
//! positions of one sequence, run through the same batched linears with
//! an in-step causal attention mask) or a **single decode position**.
//! Weights stream once per step regardless of how many positions ride
//! along, which is what makes chunked prefill cut time-to-first-token on
//! the memory-bound quantized hot path (GANQ §4 / LUT-GEMM batching).
//!
//! The historical entry points are thin wrappers over the same engine:
//! [`forward_full`] and [`nll_sum`] are full-length prefill chunks with
//! all-position logits (plus the calibration [`Observer`] hook), and
//! [`Engine::generate`] is one prefill chunk followed by decode steps,
//! drawing each token through [`sample_logits`] ([`SamplingParams`]:
//! temperature / top-k / top-p / seed; temperature 0 is exact argmax).
//! Per-sequence op order is identical at every chunk size, batch size and
//! thread count, so dense (f32) KV stores produce bit-identical logits
//! whether a prompt is fed token-by-token or as one chunk.

use crate::model::{
    LayerWeights, ModelConfig, QuantizedModel, Tensor, WeightStore,
};
use crate::obs::trace;
use crate::quant::kernels::{self, LutScratch, PackedLut};
use crate::quant::{BitPlaneStore, LutLayer};
use crate::sparse::Csr;
use crate::tensor::{self, Mat};
use crate::util::pool;

/// Who provides the six quantizable linears.
#[derive(Clone, Copy)]
pub enum Weights<'a> {
    Fp(&'a WeightStore),
    Quant(&'a QuantizedModel),
}

impl<'a> Weights<'a> {
    pub fn store(&self) -> &'a WeightStore {
        match self {
            Weights::Fp(s) => s,
            Weights::Quant(q) => &q.base,
        }
    }
}

pub fn layer_norm_rows(x: &mut Mat, g: &[f32], b: &[f32]) {
    let d = x.cols;
    for row in x.data.chunks_mut(d) {
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 =
            row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (v, (&gi, &bi)) in row.iter_mut().zip(g.iter().zip(b)) {
            *v = (*v - mu) * inv * gi + bi;
        }
    }
}

pub fn gelu_tanh(x: &mut [f32]) {
    for v in x.iter_mut() {
        let x3 = *v * *v * *v;
        *v = 0.5 * *v * (1.0 + (0.7978845608 * (*v + 0.044715 * x3)).tanh());
    }
}

fn add_bias(x: &mut Mat, b: &[f32]) {
    for row in x.data.chunks_mut(b.len()) {
        for (v, &bi) in row.iter_mut().zip(b) {
            *v += bi;
        }
    }
}

/// Optional calibration observer: called with (linear_name, input [p, n])
/// for every quantizable linear, in canonical order, before the matmul.
pub type Observer<'o> = &'o mut dyn FnMut(&str, &Mat);

// ---------------------------------------------------------------------------
// KV storage contract
// ---------------------------------------------------------------------------

/// Abstract per-sequence KV storage driving the engine. The contiguous
/// [`KvCache`] and the paged cache (`kv::PagedKv` slot views) both
/// implement it, so [`Engine::step`] is the single attention path and
/// the dense variants stay bit-identical by construction.
///
/// A step appends a run of `n >= 1` positions: the engine calls
/// `write`/`write_rows` for absolute positions `pos()..pos() + n` on
/// every (layer, head), then `advance(n)` exactly once at the end of the
/// step. Callers must make those positions writable beforehand (the
/// paged cache allocates/CoWs tail blocks in `prepare_step_n`).
pub trait KvSeq {
    /// Positions cached so far (this step's writes land at `pos()..`).
    fn pos(&self) -> usize;
    /// Store the K/V rows (`head_dim` floats each) for (layer, head) at
    /// absolute position `sj` (inside the current append window).
    fn write(&mut self, li: usize, hi: usize, sj: usize, k: &[f32], v: &[f32]);
    /// Append `rows` consecutive positions starting at `sj0` in one call
    /// (`rows * head_dim` floats per side). Default loops `write`;
    /// stores with contiguous rows override for a memcpy per (layer,
    /// head) instead of a dispatch per position — the batched-row-append
    /// analogue of `read_k_rows`.
    fn write_rows(
        &mut self,
        li: usize,
        hi: usize,
        sj0: usize,
        rows: usize,
        k: &[f32],
        v: &[f32],
    ) {
        if rows == 0 {
            return;
        }
        let hd = k.len() / rows;
        for r in 0..rows {
            self.write(
                li,
                hi,
                sj0 + r,
                &k[r * hd..(r + 1) * hd],
                &v[r * hd..(r + 1) * hd],
            );
        }
    }
    /// Copy the cached K row at (layer, head, position `sj`) into `out`.
    fn read_k(&self, li: usize, hi: usize, sj: usize, out: &mut [f32]);
    fn read_v(&self, li: usize, hi: usize, sj: usize, out: &mut [f32]);
    /// Borrow the K row in place when the store holds it as contiguous
    /// f32 (dense caches, unsealed paged tails). `None` routes the
    /// caller to `read_k` + a scratch buffer (e.g. sealed LUT blocks).
    fn k_slice(&self, li: usize, hi: usize, sj: usize) -> Option<&[f32]> {
        let _ = (li, hi, sj);
        None
    }
    fn v_slice(&self, li: usize, hi: usize, sj: usize) -> Option<&[f32]> {
        let _ = (li, hi, sj);
        None
    }
    /// Copy `rows` consecutive K rows (positions `sj0..sj0+rows`) into
    /// `out` (`rows * head_dim` floats). Default loops `read_k`; stores
    /// whose rows are physically contiguous override this so the engine
    /// gather pays one call (and ideally one memcpy) per (layer, head)
    /// instead of two virtual dispatches per position.
    fn read_k_rows(
        &self,
        li: usize,
        hi: usize,
        sj0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        if rows == 0 {
            return;
        }
        let hd = out.len() / rows;
        for (r, orow) in out.chunks_mut(hd).enumerate() {
            self.read_k(li, hi, sj0 + r, orow);
        }
    }
    fn read_v_rows(
        &self,
        li: usize,
        hi: usize,
        sj0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        if rows == 0 {
            return;
        }
        let hd = out.len() / rows;
        for (r, orow) in out.chunks_mut(hd).enumerate() {
            self.read_v(li, hi, sj0 + r, orow);
        }
    }
    /// Commit the step: `pos += n` appended positions.
    fn advance(&mut self, n: usize);
    /// Roll the sequence back to `n` cached positions (no-op when
    /// `n >= pos()`). The rollback primitive for speculative decoding:
    /// rejected draft positions are discarded, and paged stores release
    /// the now-unused tail blocks. After `truncate(n)`, the next step's
    /// writes land at `n..` exactly as if positions `n..` were never
    /// appended.
    fn truncate(&mut self, n: usize);
}

/// Per-sequence contiguous KV cache for the native path.
#[derive(Clone)]
pub struct KvCache {
    cfg: ModelConfig,
    /// [layers][heads][ctx][hd], flattened
    k: Vec<f32>,
    v: Vec<f32>,
    pub len: usize,
}

impl KvCache {
    pub fn new(cfg: ModelConfig) -> KvCache {
        KvCache::with_capacity(cfg, cfg.ctx)
    }

    /// Cache sized for at most `cap` positions (stride and backing
    /// buffers shrink accordingly) — the one-shot eval/calibration
    /// prefills size to the sequence instead of zero-filling full-ctx
    /// buffers per call.
    pub fn with_capacity(mut cfg: ModelConfig, cap: usize) -> KvCache {
        cfg.ctx = cap.min(cfg.ctx).max(1);
        let sz = cfg.layers * cfg.heads * cfg.ctx * cfg.head_dim();
        KvCache { cfg, k: vec![0.0; sz], v: vec![0.0; sz], len: 0 }
    }

    fn idx(&self, li: usize, hi: usize, pos: usize) -> usize {
        let hd = self.cfg.head_dim();
        ((li * self.cfg.heads + hi) * self.cfg.ctx + pos) * hd
    }
}

impl KvSeq for KvCache {
    fn pos(&self) -> usize {
        self.len
    }

    fn write(&mut self, li: usize, hi: usize, sj: usize, k: &[f32], v: &[f32]) {
        let hd = self.cfg.head_dim();
        let base = self.idx(li, hi, sj);
        self.k[base..base + hd].copy_from_slice(k);
        self.v[base..base + hd].copy_from_slice(v);
    }

    fn write_rows(
        &mut self,
        li: usize,
        hi: usize,
        sj0: usize,
        rows: usize,
        k: &[f32],
        v: &[f32],
    ) {
        // positions are contiguous within a (layer, head): one memcpy
        let base = self.idx(li, hi, sj0);
        self.k[base..base + rows * self.cfg.head_dim()].copy_from_slice(k);
        self.v[base..base + rows * self.cfg.head_dim()].copy_from_slice(v);
    }

    fn read_k(&self, li: usize, hi: usize, sj: usize, out: &mut [f32]) {
        let hd = self.cfg.head_dim();
        let base = self.idx(li, hi, sj);
        out.copy_from_slice(&self.k[base..base + hd]);
    }

    fn read_v(&self, li: usize, hi: usize, sj: usize, out: &mut [f32]) {
        let hd = self.cfg.head_dim();
        let base = self.idx(li, hi, sj);
        out.copy_from_slice(&self.v[base..base + hd]);
    }

    fn k_slice(&self, li: usize, hi: usize, sj: usize) -> Option<&[f32]> {
        let hd = self.cfg.head_dim();
        let base = self.idx(li, hi, sj);
        Some(&self.k[base..base + hd])
    }

    fn v_slice(&self, li: usize, hi: usize, sj: usize) -> Option<&[f32]> {
        let hd = self.cfg.head_dim();
        let base = self.idx(li, hi, sj);
        Some(&self.v[base..base + hd])
    }

    fn read_k_rows(
        &self,
        li: usize,
        hi: usize,
        sj0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        let base = self.idx(li, hi, sj0);
        out.copy_from_slice(&self.k[base..base + rows * self.cfg.head_dim()]);
    }

    fn read_v_rows(
        &self,
        li: usize,
        hi: usize,
        sj0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        let base = self.idx(li, hi, sj0);
        out.copy_from_slice(&self.v[base..base + rows * self.cfg.head_dim()]);
    }

    fn advance(&mut self, n: usize) {
        self.len += n;
    }

    fn truncate(&mut self, n: usize) {
        // contiguous storage: clamping the length suffices — stale rows
        // beyond `n` are overwritten by the next append at those
        // positions before anything reads them
        self.len = n.min(self.len);
    }
}

/// Interned parameter names for one transformer layer — built once per
/// engine so hot loops never run `format!` (and the calibration observer
/// can name the linear it is watching).
pub struct LayerKeys {
    pub ln1_g: String,
    pub ln1_b: String,
    pub ln2_g: String,
    pub ln2_b: String,
    /// (weight, bias) names in canonical order: wq, wk, wv, wo, w1, w2
    pub lin: [(String, String); 6],
}

impl LayerKeys {
    pub fn build(layers: usize) -> Vec<LayerKeys> {
        (0..layers)
            .map(|li| {
                let p = format!("l{}.", li);
                let nb = |w: &str, b: &str| {
                    (format!("{}{}", p, w), format!("{}{}", p, b))
                };
                LayerKeys {
                    ln1_g: format!("{}ln1_g", p),
                    ln1_b: format!("{}ln1_b", p),
                    ln2_g: format!("{}ln2_g", p),
                    ln2_b: format!("{}ln2_b", p),
                    lin: [
                        nb("wq", "bq"),
                        nb("wk", "bk"),
                        nb("wv", "bv"),
                        nb("wo", "bo"),
                        nb("w1", "b1"),
                        nb("w2", "b2"),
                    ],
                }
            })
            .collect()
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

// ---------------------------------------------------------------------------
// sampling
// ---------------------------------------------------------------------------

/// Per-request generation config. `temperature == 0` is the exact greedy
/// path ([`argmax`], no RNG draw at all); positive temperatures sample
/// from the (optionally top-k / top-p truncated) softmax with a draw
/// that is a pure function of `(seed, draw index)` — see
/// [`sample_logits`] — so sampled outputs are reproducible regardless of
/// batch composition, preemption, or prefill chunking.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingParams {
    /// 0 (or negative) = greedy argmax; larger flattens the distribution
    pub temperature: f32,
    /// keep only the k highest-logit tokens (0 = no limit)
    pub top_k: usize,
    /// nucleus cut: smallest prefix of the sorted distribution with
    /// probability mass >= top_p (>= 1.0 = no cut)
    pub top_p: f32,
    /// per-request RNG seed (splitmix64 stream, `util::rng`)
    pub seed: u64,
}

impl SamplingParams {
    /// The historical deterministic path: argmax at every position.
    pub fn greedy() -> SamplingParams {
        SamplingParams { temperature: 0.0, top_k: 0, top_p: 1.0, seed: 0 }
    }

    /// Plain temperature sampling (no top-k/top-p truncation).
    pub fn sample(temperature: f32, seed: u64) -> SamplingParams {
        SamplingParams { temperature, top_k: 0, top_p: 1.0, seed }
    }

    pub fn with_top_k(mut self, k: usize) -> SamplingParams {
        self.top_k = k;
        self
    }

    pub fn with_top_p(mut self, p: f32) -> SamplingParams {
        self.top_p = p;
        self
    }

    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }
}

/// splitmix64 increment (`util::rng`): seeding at `seed + draw * GOLDEN`
/// makes draw `i` exactly the `(i+1)`-th output of the seed's stream.
const SPLITMIX_GOLDEN: u64 = 0x9E3779B97F4A7C15;

/// Sample the next token from a logits row. `draw` is the request's
/// generated-token index (0 for the first sampled token): the single
/// uniform consumed is the `(draw+1)`-th output of the `seed` splitmix64
/// stream, so the result depends only on `(logits, params, draw)` — not
/// on how many other sequences share the step, how the prompt was
/// chunked, or whether the request was preempted and replayed.
/// Temperature <= 0 short-circuits to [`argmax`] (bitwise the historical
/// greedy path). Ties in the logit sort break toward the lower index.
pub fn sample_logits(
    logits: &[f32],
    params: &SamplingParams,
    draw: u64,
) -> i32 {
    if params.is_greedy() || logits.len() <= 1 {
        return argmax(logits) as i32;
    }
    let mut rng = crate::util::rng::Rng::new(
        params.seed.wrapping_add(draw.wrapping_mul(SPLITMIX_GOLDEN)),
    );
    // temperature softmax is max-shifted: the leading exp is 1, so tiny
    // temperatures degrade to greedy instead of NaN
    let inv_t = 1.0 / params.temperature;
    let limit_k = params.top_k > 0 && params.top_k < logits.len();
    if !limit_k && params.top_p >= 1.0 {
        // plain temperature sampling: no candidate ordering needed —
        // one O(vocab) pass, cumulative walk in index order
        let m = logits[argmax(logits)];
        let probs: Vec<f32> =
            logits.iter().map(|&l| ((l - m) * inv_t).exp()).collect();
        let total: f32 = probs.iter().sum();
        let mut r = rng.uniform() as f32 * total;
        for (i, &p) in probs.iter().enumerate() {
            r -= p;
            if r <= 0.0 {
                return i as i32;
            }
        }
        return (probs.len() - 1) as i32;
    }
    // candidates ordered by (logit desc, index asc) — deterministic,
    // total (ties break on index). top-k partitions first so only the
    // kept candidates pay the sort; top-p needs the full order.
    let by_logit_desc = |&a: &u32, &b: &u32| {
        logits[b as usize]
            .partial_cmp(&logits[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    };
    let mut idx: Vec<u32> = (0..logits.len() as u32).collect();
    if limit_k {
        idx.select_nth_unstable_by(params.top_k - 1, by_logit_desc);
        idx.truncate(params.top_k);
    }
    idx.sort_by(by_logit_desc);
    let m = logits[idx[0] as usize]; // bound: idx nonempty (vocab > 0)
    let mut probs: Vec<f32> = idx
        .iter()
        .map(|&i| ((logits[i as usize] - m) * inv_t).exp())
        .collect();
    if params.top_p < 1.0 {
        // nucleus cut: smallest prefix with mass >= top_p
        let total: f32 = probs.iter().sum();
        let target = params.top_p.max(0.0) * total;
        let mut acc = 0.0f32;
        let mut cut = probs.len();
        for (i, &p) in probs.iter().enumerate() {
            acc += p;
            if acc >= target {
                cut = i + 1;
                break;
            }
        }
        idx.truncate(cut);
        probs.truncate(cut);
    }
    let total: f32 = probs.iter().sum();
    let mut r = rng.uniform() as f32 * total;
    for (i, &p) in probs.iter().enumerate() {
        r -= p;
        if r <= 0.0 {
            return idx[i] as i32;
        }
    }
    idx[idx.len() - 1] as i32
}

// ---------------------------------------------------------------------------
// step plans
// ---------------------------------------------------------------------------

/// Which logits a work item wants back from the step. Mid-prompt prefill
/// chunks take `None` (no tied-head matmul at all for their rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LogitsMode {
    None,
    Last,
    All,
}

/// One unit of work in a step: a run of `tokens` for the sequence at
/// SeqAccess index `seq`. One token is a decode position; several are a
/// prefill chunk (consecutive prompt positions, causally masked in-step).
pub struct StepItem {
    pub seq: usize,
    pub tokens: Vec<i32>,
    pub logits: LogitsMode,
}

impl StepItem {
    pub fn decode(seq: usize, tok: i32) -> StepItem {
        StepItem { seq, tokens: vec![tok], logits: LogitsMode::Last }
    }

    pub fn prefill(seq: usize, tokens: Vec<i32>, logits: LogitsMode) -> StepItem {
        assert!(!tokens.is_empty(), "empty prefill chunk");
        StepItem { seq, tokens, logits }
    }

    /// Speculative verification chunk: feed the pending token plus the
    /// draft run and score *every* position, so row `i` of the output is
    /// exactly the logits greedy decode would see after accepting `i`
    /// draft tokens.
    pub fn verify(seq: usize, tokens: Vec<i32>) -> StepItem {
        assert!(!tokens.is_empty(), "empty verify chunk");
        StepItem { seq, tokens, logits: LogitsMode::All }
    }
}

/// A mixed batch of work items advanced together by one [`Engine::step`]:
/// every linear runs as a single [rows, n] matmul over all items' rows,
/// so weights stream once per step regardless of how many prompt
/// positions ride along with the decodes.
pub struct StepPlan {
    pub items: Vec<StepItem>,
}

impl StepPlan {
    /// All-decode plan: item `i` feeds `toks[i]` to sequence `i`.
    pub fn decode(toks: &[i32]) -> StepPlan {
        StepPlan {
            items: toks
                .iter()
                .enumerate()
                .map(|(i, &t)| StepItem::decode(i, t))
                .collect(),
        }
    }

    /// Total positions (activation rows) this plan advances.
    pub fn rows(&self) -> usize {
        self.items.iter().map(|it| it.tokens.len()).sum()
    }
}

// ---------------------------------------------------------------------------
// batched multi-sequence access
// ---------------------------------------------------------------------------

/// Per-step access to a batch of per-sequence KV stores. The paged cache
/// can hand out only one mutable slot view at a time (views alias the
/// shared block pool), so the engine visits sequences through a closure
/// instead of holding simultaneous `&mut` views.
pub trait SeqAccess {
    fn count(&self) -> usize;
    fn with_seq(&mut self, i: usize, f: &mut dyn FnMut(&mut dyn KvSeq));
}

/// [`SeqAccess`] over independently owned caches (the contiguous
/// backend: one [`KvCache`] per slot).
pub struct SeqRefs<'a, 'b>(pub &'a mut [&'b mut dyn KvSeq]);

impl SeqAccess for SeqRefs<'_, '_> {
    fn count(&self) -> usize {
        self.0.len()
    }

    fn with_seq(&mut self, i: usize, f: &mut dyn FnMut(&mut dyn KvSeq)) {
        f(&mut *self.0[i]);
    }
}

// ---------------------------------------------------------------------------
// resolved weight plans
// ---------------------------------------------------------------------------

/// How the engine serves one linear. Built once at engine construction;
/// the hot loop dispatches on this enum instead of string-keyed maps.
/// Every variant borrows or repacks — the engine never clones dense
/// weights.
enum LinearPlan<'w> {
    /// dense f32 borrowed straight from the FP store's tensor (also the
    /// fallback for linears missing from a quantized model)
    Fp(&'w Tensor),
    /// dense f32 borrowed from the quantized store
    DenseRef(&'w Mat),
    /// packed LUT codes — the dequantization-free mpGEMM hot path
    Packed(PackedLut),
    /// packed LUT plus the CSR outlier branch (GANQ*/SqueezeLLM)
    PackedSparse(PackedLut, &'w Csr),
    /// unpacked-code LUT (>4-bit widths have no packed form): the same
    /// bucket kernel as `LutLayer::lut_matmul`, so bit-identity with
    /// single-row steps holds at every code width
    Codes(&'w LutLayer),
    CodesSparse(&'w LutLayer, &'w Csr),
    /// nested any-precision store served at one of its widths: the
    /// kernel streams only the top-`w` bit-planes (no per-width packed
    /// copy exists), bitwise identical to `Packed` over the
    /// materialized `w`-bit slice
    Planes(&'w BitPlaneStore, u8),
}

impl LinearPlan<'_> {
    fn apply(&self, x: &Mat, sc: &mut LutScratch, out: &mut Mat) {
        match self {
            LinearPlan::Fp(t) => {
                // bound: checkpoint tensors are always 2-d [rows, cols]
                tensor::matmul_tb_slice_into(x, &t.data, t.shape[0], out)
            }
            LinearPlan::DenseRef(w) => x.matmul_tb_into(w, out),
            LinearPlan::Packed(pl) => pl.matmul_into(x, sc, out),
            LinearPlan::PackedSparse(pl, sp) => {
                pl.matmul_into(x, sc, out);
                sp.spmm_add(x, out);
            }
            LinearPlan::Codes(l) => kernels::lut_gemm_codes_into(
                &l.codes,
                &l.codebook,
                l.n,
                x,
                sc,
                out,
            ),
            LinearPlan::CodesSparse(l, sp) => {
                kernels::lut_gemm_codes_into(
                    &l.codes,
                    &l.codebook,
                    l.n,
                    x,
                    sc,
                    out,
                );
                sp.spmm_add(x, out);
            }
            LinearPlan::Planes(b, w) => {
                kernels::lut_gemm_planes_into(b, *w, x, sc, out)
            }
        }
    }

    /// Weight bytes this linear streams per step.
    fn bytes_per_step(&self) -> usize {
        match self {
            LinearPlan::Fp(t) => t.data.len() * 4,
            LinearPlan::DenseRef(w) => w.data.len() * 4,
            LinearPlan::Packed(pl) => pl.bytes_per_decode(),
            LinearPlan::PackedSparse(pl, sp) => {
                pl.bytes_per_decode() + sp.storage_bytes()
            }
            // one byte per code + f32 codebook
            LinearPlan::Codes(l) => l.m * l.n + l.m * l.k() * 4,
            LinearPlan::CodesSparse(l, sp) => {
                l.m * l.n + l.m * l.k() * 4 + sp.storage_bytes()
            }
            // only the top-w planes + that width's codebook stream
            LinearPlan::Planes(b, w) => b.bytes_per_decode(*w),
        }
    }
}

/// Resolved per-layer plan: layernorm/bias slices and linear
/// implementations, indexed — no name lookups or `format!` per step.
struct LayerPlan<'w> {
    ln1_g: &'w [f32],
    ln1_b: &'w [f32],
    ln2_g: &'w [f32],
    ln2_b: &'w [f32],
    /// canonical order wq, wk, wv, wo, w1, w2
    linears: Vec<LinearPlan<'w>>,
    biases: Vec<&'w [f32]>,
}

/// Query rows one attention job covers. Long prefill chunks split into
/// tiles so a single (sequence, head) pair still parallelizes across
/// query positions.
const Q_TILE: usize = 8;

/// Preallocated per-step scratch: activation/projection matrices, the
/// K/V gather buffers, attention job rows, and the LUT kernel scratch.
/// Reused across steps — the hot loop performs no per-step heap
/// allocation beyond the returned logits and the kernels' small
/// per-thread bucket blocks.
struct BatchScratch {
    x: Mat,
    a: Mat,
    q: Mat,
    k: Mat,
    v: Mat,
    att: Mat,
    o: Mat,
    h1: Mat,
    h2: Mat,
    /// selected post-LN rows feeding the tied head
    xl: Mat,
    logits: Mat,
    /// gathered K/V history, (item, head)-major, strided by the step's
    /// longest (pos + chunk) extent
    kg: Vec<f32>,
    vg: Vec<f32>,
    /// per-(item, head) chunk rows staged contiguously for `write_rows`
    kbuf: Vec<f32>,
    vbuf: Vec<f32>,
    /// attention job rows: `[Q_TILE * hd + max_rows]` = output
    /// accumulator + scores
    jb: Vec<f32>,
    /// attention jobs: (item, head, first query row, last query row)
    jobs: Vec<(usize, usize, usize, usize)>,
    /// per-item start position / first activation row
    pos: Vec<usize>,
    row0: Vec<usize>,
    lut: LutScratch,
}

impl BatchScratch {
    fn new() -> BatchScratch {
        let z = || Mat::zeros(0, 0);
        BatchScratch {
            x: z(),
            a: z(),
            q: z(),
            k: z(),
            v: z(),
            att: z(),
            o: z(),
            h1: z(),
            h2: z(),
            xl: z(),
            logits: z(),
            kg: Vec::new(),
            vg: Vec::new(),
            kbuf: Vec::new(),
            vbuf: Vec::new(),
            jb: Vec::new(),
            jobs: Vec::new(),
            pos: Vec::new(),
            row0: Vec::new(),
            lut: LutScratch::new(),
        }
    }
}

// ---------------------------------------------------------------------------
// the engine
// ---------------------------------------------------------------------------

/// Session-based inference engine: weights resolved, packed, and
/// interned once, then every [`Engine::step`] advances a [`StepPlan`] —
/// decode positions and prefill chunks together — through each layer so
/// the quantized weights stream once per step instead of once per
/// sequence or position. Serving, evaluation ([`nll_sum`] /
/// [`forward_full`]), calibration (the [`Observer`] hook), and
/// generation ([`Engine::generate`]) all run through this one entry
/// point.
pub struct Engine<'w> {
    cfg: ModelConfig,
    /// the weight provider, kept so plans can be re-resolved at another
    /// serving width ([`Engine::set_width`]) without rebuilding the
    /// engine or touching the FP tensors
    weights: Weights<'w>,
    /// serving width for any-precision linears (None = each store's max)
    width: Option<u8>,
    /// token embedding, borrowed — doubles as the tied head weight
    /// (`Tensor::as_mat` clones per call; the engine never does)
    tok_emb: &'w Tensor,
    pos_emb: &'w [f32],
    ln_f_g: &'w [f32],
    ln_f_b: &'w [f32],
    layers: Vec<LayerPlan<'w>>,
    /// interned parameter names (observer labels)
    keys: Vec<LayerKeys>,
    scratch: BatchScratch,
}

impl<'w> Engine<'w> {
    pub fn new(w: &Weights<'w>) -> Engine<'w> {
        Engine::new_at(w, None)
    }

    /// Engine serving any-precision linears at `width` bits (`None` =
    /// each nested store's maximum width). Non-anyprec weights ignore
    /// the width entirely.
    pub fn new_at(w: &Weights<'w>, width: Option<u8>) -> Engine<'w> {
        let store = w.store();
        let cfg = store.cfg;
        let keys = LayerKeys::build(cfg.layers);
        let layers = keys
            .iter()
            .map(|key| LayerPlan {
                ln1_g: store.vec(&key.ln1_g),
                ln1_b: store.vec(&key.ln1_b),
                ln2_g: store.vec(&key.ln2_g),
                ln2_b: store.vec(&key.ln2_b),
                linears: key
                    .lin
                    .iter()
                    .map(|(wn, _)| plan_linear(w, wn, width))
                    .collect(),
                biases: key.lin.iter().map(|(_, bn)| store.vec(bn)).collect(),
            })
            .collect();
        Engine {
            cfg,
            weights: *w,
            width,
            tok_emb: store.get("tok_emb"),
            pos_emb: &store.get("pos_emb").data,
            ln_f_g: store.vec("ln_f_g"),
            ln_f_b: store.vec("ln_f_b"),
            layers,
            keys,
            scratch: BatchScratch::new(),
        }
    }

    pub fn cfg(&self) -> ModelConfig {
        self.cfg
    }

    /// Serving width for any-precision linears (None = max width).
    pub fn width(&self) -> Option<u8> {
        self.width
    }

    /// Re-resolve every linear plan at a different any-precision width.
    /// The weight planes are shared across widths, so this only swaps
    /// which codebook + how many planes each plan reads — no FP weights
    /// are touched and KV caches are unaffected.
    pub fn set_width(&mut self, width: u8) {
        if self.width == Some(width) {
            return;
        }
        self.width = Some(width);
        let w = self.weights;
        for (lp, key) in self.layers.iter_mut().zip(&self.keys) {
            for (slot, (wn, _)) in key.lin.iter().enumerate() {
                lp.linears[slot] = plan_linear(&w, wn, Some(width));
            }
        }
    }

    /// Weight bytes streamed per step (each linear exactly once,
    /// regardless of how many positions the plan advances — the
    /// memory-bound quantity).
    pub fn weight_bytes_per_step(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|l| l.linears.iter())
            .map(|p| p.bytes_per_step())
            .sum()
    }

    /// Advance a plan; returns one logits matrix per item ([0|1|c,
    /// vocab] per its [`LogitsMode`]).
    pub fn step(
        &mut self,
        plan: &StepPlan,
        seqs: &mut dyn SeqAccess,
    ) -> Vec<Mat> {
        self.step_with(plan, seqs, None)
    }

    /// [`Engine::step`] with a calibration observer: called with every
    /// linear's name and input rows (all items' rows concatenated),
    /// before the matmul, in canonical order.
    pub fn step_with(
        &mut self,
        plan: &StepPlan,
        seqs: &mut dyn SeqAccess,
        mut observer: Option<Observer>,
    ) -> Vec<Mat> {
        let items = &plan.items;
        if items.is_empty() {
            return Vec::new();
        }
        let _sp_step = trace::span("engine.step");
        let cfg = self.cfg;
        let (d, h, hd) = (cfg.d, cfg.heads, cfg.head_dim());
        let scale = 1.0 / (hd as f32).sqrt();
        let Engine {
            tok_emb,
            pos_emb,
            ln_f_g,
            ln_f_b,
            layers,
            keys,
            scratch,
            ..
        } = self;
        let BatchScratch {
            x,
            a,
            q,
            k,
            v,
            att,
            o,
            h1,
            h2,
            xl,
            logits,
            kg,
            vg,
            kbuf,
            vbuf,
            jb,
            jobs,
            pos,
            row0,
            lut,
        } = scratch;

        // per-item start positions and activation row offsets
        pos.clear();
        row0.clear();
        let mut rows_total = 0usize;
        for it in items.iter() {
            assert!(it.seq < seqs.count(), "item seq out of range");
            assert!(!it.tokens.is_empty(), "empty work item");
            let mut p = 0usize;
            seqs.with_seq(it.seq, &mut |s| p = s.pos());
            assert!(p + it.tokens.len() <= cfg.ctx, "context overflow");
            pos.push(p);
            row0.push(rows_total);
            rows_total += it.tokens.len();
        }

        // token + position embeddings (row r of item j is prompt/decode
        // position pos[j] + r)
        x.reset(rows_total, d);
        for (j, it) in items.iter().enumerate() {
            for (t, &tok) in it.tokens.iter().enumerate() {
                let row = x.row_mut(row0[j] + t);
                let te =
                    &tok_emb.data[(tok as usize) * d..(tok as usize + 1) * d];
                let pe = &pos_emb[(pos[j] + t) * d..(pos[j] + t + 1) * d];
                for (xo, (&e1, &e2)) in row.iter_mut().zip(te.iter().zip(pe)) {
                    *xo = e1 + e2;
                }
            }
        }

        // gather/job strides sized to this step's longest extent (not
        // ctx); Vec::resize retains the high-water allocation across
        // steps
        let max_rows = items
            .iter()
            .enumerate()
            .map(|(j, it)| pos[j] + it.tokens.len())
            .max()
            // lint:allow(hot-expect): step() contract — plan items nonempty
            .expect("items nonempty");
        let max_c =
            // lint:allow(hot-expect): step() contract — plan items nonempty
            items.iter().map(|it| it.tokens.len()).max().expect("nonempty");
        let gstride = max_rows * hd;
        let jstride = Q_TILE * hd + max_rows;
        kg.resize(items.len() * h * gstride, 0.0);
        vg.resize(items.len() * h * gstride, 0.0);
        kbuf.resize(max_c * hd, 0.0);
        vbuf.resize(max_c * hd, 0.0);

        // attention jobs: (item, head) pairs tiled over query rows so a
        // single long prefill chunk still spreads across threads; each
        // job owns a disjoint row of jb = [out accumulator | scores]
        jobs.clear();
        for (j, it) in items.iter().enumerate() {
            let c = it.tokens.len();
            for hi in 0..h {
                let mut t0 = 0usize;
                while t0 < c {
                    let t1 = (t0 + Q_TILE).min(c);
                    jobs.push((j, hi, t0, t1));
                    t0 = t1;
                }
            }
        }
        jb.resize(jobs.len() * jstride, 0.0);

        for (li, lp) in layers.iter().enumerate() {
            let key = &keys[li];
            {
                let _sp = trace::span("engine.qkv");
                a.copy_from(x);
                layer_norm_rows(a, lp.ln1_g, lp.ln1_b);
                apply_linear(
                    lp, key, 0, a, q, rows_total, d, lut, &mut observer,
                );
                apply_linear(
                    lp, key, 1, a, k, rows_total, d, lut, &mut observer,
                );
                apply_linear(
                    lp, key, 2, a, v, rows_total, d, lut, &mut observer,
                );
            }
            let sp_kv = trace::span("engine.kv");

            // append this step's K/V rows (chunk rows staged into one
            // contiguous buffer per (item, head) -> one write_rows
            // call), then gather each sequence's history including the
            // just-written positions so the attention math below can run
            // thread-parallel over plain buffers
            for (j, it) in items.iter().enumerate() {
                let c = it.tokens.len();
                let hist = pos[j] + c;
                let (kr, vr) = (&*k, &*v);
                let r0 = row0[j];
                seqs.with_seq(it.seq, &mut |s| {
                    for hi in 0..h {
                        if c == 1 {
                            // decode hot path: the single row is already
                            // contiguous in the projection — no staging
                            s.write(
                                li,
                                hi,
                                pos[j],
                                &kr.row(r0)[hi * hd..(hi + 1) * hd],
                                &vr.row(r0)[hi * hd..(hi + 1) * hd],
                            );
                        } else {
                            for t in 0..c {
                                kbuf[t * hd..(t + 1) * hd].copy_from_slice(
                                    &kr.row(r0 + t)[hi * hd..(hi + 1) * hd],
                                );
                                vbuf[t * hd..(t + 1) * hd].copy_from_slice(
                                    &vr.row(r0 + t)[hi * hd..(hi + 1) * hd],
                                );
                            }
                            s.write_rows(
                                li,
                                hi,
                                pos[j],
                                c,
                                &kbuf[..c * hd],
                                &vbuf[..c * hd],
                            );
                        }
                        let g = (j * h + hi) * gstride;
                        s.read_k_rows(li, hi, 0, hist, &mut kg[g..g + hist * hd]);
                        s.read_v_rows(li, hi, 0, hist, &mut vg[g..g + hist * hd]);
                    }
                });
            }

            drop(sp_kv);
            let sp_attn = trace::span("engine.attn");

            // causal in-step attention: query row t of item j attends
            // over positions 0..=pos[j]+t — identical per-row op order
            // to a single-position decode at that position
            let att_ops: usize = items
                .iter()
                .enumerate()
                .map(|(j, it)| {
                    let c = it.tokens.len();
                    (0..c).map(|t| pos[j] + t + 1).sum::<usize>()
                })
                .sum::<usize>()
                * hd
                * 2
                * h;
            let threads = pool::threads_for(att_ops);
            let qref: &Mat = q;
            let kgr: &[f32] = kg;
            let vgr: &[f32] = vg;
            let posr: &[usize] = pos;
            let row0r: &[usize] = row0;
            let jobsr: &[(usize, usize, usize, usize)] = jobs;
            pool::par_rows_mut(
                &mut jb[..jobsr.len() * jstride],
                jstride,
                threads,
                |job0, chunk| {
                    for (r, jrow) in chunk.chunks_mut(jstride).enumerate() {
                        let (j, hi, t0, t1) = jobsr[job0 + r];
                        let gi = (j * h + hi) * gstride;
                        let (obuf, rest) = jrow.split_at_mut(Q_TILE * hd);
                        for t in t0..t1 {
                            let rows_t = posr[j] + t + 1;
                            let scores = &mut rest[..rows_t];
                            let qrow = &qref.row(row0r[j] + t)
                                [hi * hd..(hi + 1) * hd];
                            let kbase = &kgr[gi..gi + rows_t * hd];
                            for (sj, sc) in scores.iter_mut().enumerate() {
                                *sc = tensor::dot(
                                    qrow,
                                    &kbase[sj * hd..(sj + 1) * hd],
                                ) * scale;
                            }
                            tensor::softmax(scores);
                            let orow =
                                &mut obuf[(t - t0) * hd..(t - t0 + 1) * hd];
                            orow.fill(0.0);
                            let vbase = &vgr[gi..gi + rows_t * hd];
                            for (sj, &w_att) in scores.iter().enumerate() {
                                let vrow = &vbase[sj * hd..(sj + 1) * hd];
                                for (ov, &vv) in orow.iter_mut().zip(vrow) {
                                    *ov += w_att * vv;
                                }
                            }
                        }
                    }
                },
            );
            att.reset(rows_total, d);
            for (ji, &(j, hi, t0, t1)) in jobs.iter().enumerate() {
                let jrow = &jb[ji * jstride..];
                for t in t0..t1 {
                    att.row_mut(row0[j] + t)[hi * hd..(hi + 1) * hd]
                        .copy_from_slice(
                            &jrow[(t - t0) * hd..(t - t0 + 1) * hd],
                        );
                }
            }

            apply_linear(lp, key, 3, att, o, rows_total, d, lut, &mut observer);
            x.add_assign(o);
            drop(sp_attn);
            let _sp_mlp = trace::span("engine.mlp");
            a.copy_from(x);
            layer_norm_rows(a, lp.ln2_g, lp.ln2_b);
            apply_linear(
                lp,
                key,
                4,
                a,
                h1,
                rows_total,
                cfg.ff,
                lut,
                &mut observer,
            );
            gelu_tanh(&mut h1.data);
            apply_linear(lp, key, 5, h1, h2, rows_total, d, lut, &mut observer);
            x.add_assign(h2);
        }

        // commit every item's appended positions
        for it in items.iter() {
            let c = it.tokens.len();
            seqs.with_seq(it.seq, &mut |s| s.advance(c));
        }

        let _sp_logits = trace::span("engine.logits");
        layer_norm_rows(x, ln_f_g, ln_f_b);
        // tied head straight off the borrowed embedding tensor, only for
        // the rows the plan asked logits for
        let vocab = tok_emb.shape[0];
        let mut sel: Vec<(usize, usize)> = Vec::new(); // (item, x row)
        for (j, it) in items.iter().enumerate() {
            let c = it.tokens.len();
            match it.logits {
                LogitsMode::None => {}
                LogitsMode::Last => sel.push((j, row0[j] + c - 1)),
                LogitsMode::All => {
                    sel.extend((0..c).map(|t| (j, row0[j] + t)))
                }
            }
        }
        xl.reset(sel.len(), d);
        for (r, &(_, xr)) in sel.iter().enumerate() {
            xl.row_mut(r).copy_from_slice(x.row(xr));
        }
        logits.reset(sel.len(), vocab);
        tensor::matmul_tb_slice_into(xl, &tok_emb.data, vocab, logits);
        let mut out: Vec<Mat> = items
            .iter()
            .map(|it| {
                let r = match it.logits {
                    LogitsMode::None => 0,
                    LogitsMode::Last => 1,
                    LogitsMode::All => it.tokens.len(),
                };
                Mat::zeros(r, vocab)
            })
            .collect();
        let mut cursor = vec![0usize; items.len()];
        for (r, &(j, _)) in sel.iter().enumerate() {
            out[j]
                .row_mut(cursor[j])
                .copy_from_slice(logits.row(r));
            cursor[j] += 1;
        }
        out
    }

    /// All-decode convenience: one token per sequence, last-row logits.
    pub fn decode_batch(
        &mut self,
        toks: &[i32],
        seqs: &mut dyn SeqAccess,
    ) -> Vec<Vec<f32>> {
        assert_eq!(seqs.count(), toks.len(), "one token per sequence");
        self.step(&StepPlan::decode(toks), seqs)
            .into_iter()
            .map(|m| m.data)
            .collect()
    }

    /// Full causal forward over a batch of equal-length sequences as
    /// full-length prefill chunks (fresh dense caches). Returns logits
    /// [(B*S), vocab].
    pub fn prefill_full(
        &mut self,
        tokens: &[Vec<i32>],
        observer: Option<Observer>,
    ) -> Mat {
        let cfg = self.cfg;
        let bsz = tokens.len();
        let s_len = tokens[0].len(); // bound: caller passes >= 1 sequence
        assert!(tokens.iter().all(|t| t.len() == s_len));
        assert!(s_len <= cfg.ctx);
        let mut caches: Vec<KvCache> = (0..bsz)
            .map(|_| KvCache::with_capacity(cfg, s_len))
            .collect();
        let plan = StepPlan {
            items: tokens
                .iter()
                .enumerate()
                .map(|(i, t)| {
                    StepItem::prefill(i, t.clone(), LogitsMode::All)
                })
                .collect(),
        };
        let mut refs: Vec<&mut dyn KvSeq> = caches
            .iter_mut()
            .map(|c| c as &mut dyn KvSeq)
            .collect();
        let outs = self.step_with(&plan, &mut SeqRefs(&mut refs), observer);
        let vocab = outs[0].cols; // bound: one output per plan item, bsz >= 1
        let mut out = Mat::zeros(bsz * s_len, vocab);
        for (b, m) in outs.iter().enumerate() {
            out.data[b * s_len * vocab..(b + 1) * s_len * vocab]
                .copy_from_slice(&m.data);
        }
        out
    }

    /// Sum of next-token NLLs over a batch of equal-length sequences,
    /// prefilled in `chunk`-position pieces (`usize::MAX` = one chunk).
    /// Dense-cache math is identical at every chunk size.
    pub fn nll_sum_chunked(
        &mut self,
        tokens: &[Vec<i32>],
        chunk: usize,
    ) -> f64 {
        let cfg = self.cfg;
        let bsz = tokens.len();
        let s_len = tokens[0].len(); // bound: caller passes >= 1 sequence
        assert!(tokens.iter().all(|t| t.len() == s_len));
        assert!(s_len <= cfg.ctx);
        let chunk = chunk.max(1);
        let vocab = cfg.vocab;
        let mut caches: Vec<KvCache> = (0..bsz)
            .map(|_| KvCache::with_capacity(cfg, s_len))
            .collect();
        let mut total = 0.0f64;
        let mut start = 0usize;
        while start < s_len {
            let end = (start + chunk).min(s_len);
            let plan = StepPlan {
                items: tokens
                    .iter()
                    .enumerate()
                    .map(|(i, t)| {
                        StepItem::prefill(
                            i,
                            t[start..end].to_vec(),
                            LogitsMode::All,
                        )
                    })
                    .collect(),
            };
            let mut refs: Vec<&mut dyn KvSeq> = caches
                .iter_mut()
                .map(|c| c as &mut dyn KvSeq)
                .collect();
            let outs = self.step(&plan, &mut SeqRefs(&mut refs));
            for (b, m) in outs.iter().enumerate() {
                for p in start..end {
                    if p + 1 >= s_len {
                        continue; // last position predicts nothing
                    }
                    let row = &m.row(p - start)[..vocab];
                    total -= tensor::log_softmax_at(
                        row,
                        tokens[b][p + 1] as usize,
                    ) as f64;
                }
            }
            start = end;
        }
        total
    }

    /// Generation: the prompt as one prefill chunk, then decode steps
    /// (bit-identical to feeding the prompt token-by-token). Token `i`
    /// is drawn with draw index `i` via [`sample_logits`], so
    /// `SamplingParams::greedy()` reproduces the historical greedy path
    /// exactly and sampled runs are reproducible from `params.seed`.
    pub fn generate(
        &mut self,
        prompt: &[i32],
        max_new: usize,
        params: &SamplingParams,
    ) -> Vec<i32> {
        let cfg = self.cfg;
        let mut out = Vec::with_capacity(max_new);
        if prompt.is_empty() {
            return out;
        }
        let mut cache = KvCache::new(cfg);
        let plan = StepPlan {
            items: vec![StepItem::prefill(
                0,
                prompt.to_vec(),
                LogitsMode::Last,
            )],
        };
        let mut logits = {
            let mut refs: Vec<&mut dyn KvSeq> = vec![&mut cache];
            let outs = self.step(&plan, &mut SeqRefs(&mut refs));
            // lint:allow(hot-expect): step() returns one output per plan item
            outs.into_iter().next().expect("one item").data
        };
        for _ in 0..max_new {
            if cache.len >= cfg.ctx {
                break;
            }
            let next = sample_logits(&logits, params, out.len() as u64);
            out.push(next);
            let mut refs: Vec<&mut dyn KvSeq> = vec![&mut cache];
            logits = self
                .decode_batch(&[next], &mut SeqRefs(&mut refs))
                .into_iter()
                .next()
                // lint:allow(hot-expect): decode_batch returns one row per token
                .expect("one row");
        }
        out
    }
}

/// One linear of a step: observer hook, shape the output, dispatch the
/// resolved plan, add bias.
#[allow(clippy::too_many_arguments)]
fn apply_linear(
    lp: &LayerPlan,
    key: &LayerKeys,
    slot: usize,
    inp: &Mat,
    out: &mut Mat,
    rows: usize,
    cols: usize,
    lut: &mut LutScratch,
    observer: &mut Option<Observer>,
) {
    if let Some(obs) = observer.as_mut() {
        obs(&key.lin[slot].0, inp);
    }
    out.reset(rows, cols);
    lp.linears[slot].apply(inp, lut, out);
    add_bias(out, lp.biases[slot]);
}

fn plan_linear<'w>(
    w: &Weights<'w>,
    name: &str,
    width: Option<u8>,
) -> LinearPlan<'w> {
    match *w {
        Weights::Fp(s) => LinearPlan::Fp(s.get(name)),
        Weights::Quant(q) => match q.linears.get(name) {
            Some(LayerWeights::Dense(m)) => LinearPlan::DenseRef(m),
            Some(LayerWeights::Lut(l)) if l.bits <= 4 => {
                LinearPlan::Packed(PackedLut::pack(l))
            }
            Some(LayerWeights::Lut(l)) => LinearPlan::Codes(l),
            Some(LayerWeights::LutSparse(l, sp)) if l.bits <= 4 => {
                LinearPlan::PackedSparse(PackedLut::pack(l), sp)
            }
            Some(LayerWeights::LutSparse(l, sp)) => {
                LinearPlan::CodesSparse(l, sp)
            }
            Some(LayerWeights::AnyPrec(b)) => {
                let w = width.unwrap_or(b.max_bits);
                assert!(
                    b.codebooks.contains_key(&w),
                    "{}: width {} not in anyprec store {:?}",
                    name,
                    w,
                    b.widths()
                );
                LinearPlan::Planes(b, w)
            }
            None => LinearPlan::Fp(q.base.get(name)),
        },
    }
}

// ---------------------------------------------------------------------------
// engine-backed convenience entry points (eval / calibration / tasks)
// ---------------------------------------------------------------------------

/// Full causal forward over a batch of equal-length sequences.
/// tokens: B x S. Returns logits [(B*S), vocab]. One-shot wrapper over
/// [`Engine::prefill_full`]; loops should hold an [`Engine`] instead.
pub fn forward_full(
    w: &Weights,
    tokens: &[Vec<i32>],
    observer: Option<Observer>,
) -> Mat {
    Engine::new(w).prefill_full(tokens, observer)
}

/// Sum of next-token NLLs over a batch (matches python nll_sum).
pub fn nll_sum(w: &Weights, tokens: &[Vec<i32>]) -> f64 {
    Engine::new(w).nll_sum_chunked(tokens, usize::MAX)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::WeightStore;
    use crate::util::prop;

    fn micro() -> WeightStore {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        WeightStore::random("t", cfg, 11)
    }

    /// One single-position step through a fresh plan (the per-token
    /// reference path used by the bit-identity tests).
    fn decode_one(
        engine: &mut Engine,
        tok: i32,
        cache: &mut dyn KvSeq,
    ) -> Vec<f32> {
        let mut refs: Vec<&mut dyn KvSeq> = vec![cache];
        engine
            .decode_batch(&[tok], &mut SeqRefs(&mut refs))
            .into_iter()
            .next()
            .unwrap()
    }

    #[test]
    fn forward_shapes_and_finite() {
        let s = micro();
        let toks = vec![vec![1, 2, 3, 4, 5], vec![9, 8, 7, 6, 5]];
        let logits = forward_full(&Weights::Fp(&s), &toks, None);
        assert_eq!(logits.rows, 10);
        assert_eq!(logits.cols, 256);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn decode_matches_full_forward() {
        let s = micro();
        let w = Weights::Fp(&s);
        let seq: Vec<i32> = vec![10, 65, 97, 32, 101, 120, 5];
        let logits_full = forward_full(&w, &[seq.clone()], None);
        let mut cache = KvCache::new(s.cfg);
        let mut engine = Engine::new(&w);
        let mut last = Vec::new();
        for &t in &seq {
            last = decode_one(&mut engine, t, &mut cache);
        }
        let expect = logits_full.row(seq.len() - 1);
        assert!(
            prop::all_close(&last, expect, 1e-3, 1e-3),
            "maxdiff {}",
            prop::max_abs_diff(&last, expect)
        );
    }

    #[test]
    fn chunked_prefill_bitwise_matches_per_token() {
        let s = micro();
        let w = Weights::Fp(&s);
        let prompt: Vec<i32> = (0..23).map(|i| (i * 31 + 7) % 256).collect();

        // per-token reference
        let mut eng_ref = Engine::new(&w);
        let mut c_ref = KvCache::new(s.cfg);
        let mut last_ref = Vec::new();
        for &t in &prompt {
            last_ref = decode_one(&mut eng_ref, t, &mut c_ref);
        }

        for chunk in [1usize, 7, 64, 999] {
            let mut engine = Engine::new(&w);
            let mut cache = KvCache::new(s.cfg);
            let mut last = Vec::new();
            let mut fed = 0usize;
            while fed < prompt.len() {
                let take = chunk.min(prompt.len() - fed);
                let plan = StepPlan {
                    items: vec![StepItem::prefill(
                        0,
                        prompt[fed..fed + take].to_vec(),
                        LogitsMode::Last,
                    )],
                };
                let mut refs: Vec<&mut dyn KvSeq> = vec![&mut cache];
                last = engine
                    .step(&plan, &mut SeqRefs(&mut refs))
                    .into_iter()
                    .next()
                    .unwrap()
                    .data;
                fed += take;
            }
            assert_eq!(last, last_ref, "chunk {}", chunk);
            // cache state must match too: one more decode agrees
            let mut c2 = c_ref.clone();
            let a = decode_one(&mut engine, 42, &mut cache);
            let b = decode_one(&mut eng_ref, 42, &mut c2);
            assert_eq!(a, b, "cache divergence after chunk {}", chunk);
        }
    }

    #[test]
    fn mixed_prefill_and_decode_step_matches_separate() {
        // one step advancing a prefill chunk and a decode position
        // together must equal running them in separate steps
        let s = micro();
        let w = Weights::Fp(&s);
        let prompt: Vec<i32> = vec![3, 1, 4, 1, 5, 9, 2, 6];

        // warm a decode sequence
        let mut eng_a = Engine::new(&w);
        let mut dec_cache = KvCache::new(s.cfg);
        for &t in &[10i32, 20, 30] {
            decode_one(&mut eng_a, t, &mut dec_cache);
        }
        let mut dec_cache_b = dec_cache.clone();

        // separate: prefill alone, decode alone
        let mut pre_cache = KvCache::new(s.cfg);
        let pre_logits = {
            let plan = StepPlan {
                items: vec![StepItem::prefill(
                    0,
                    prompt.clone(),
                    LogitsMode::Last,
                )],
            };
            let mut refs: Vec<&mut dyn KvSeq> = vec![&mut pre_cache];
            eng_a.step(&plan, &mut SeqRefs(&mut refs))[0].data.clone()
        };
        let dec_logits = decode_one(&mut eng_a, 40, &mut dec_cache);

        // mixed plan in one step
        let mut eng_b = Engine::new(&w);
        let mut pre_cache_b = KvCache::new(s.cfg);
        let plan = StepPlan {
            items: vec![
                StepItem::prefill(0, prompt.clone(), LogitsMode::Last),
                StepItem::decode(1, 40),
            ],
        };
        let mut refs: Vec<&mut dyn KvSeq> =
            vec![&mut pre_cache_b, &mut dec_cache_b];
        let outs = eng_b.step(&plan, &mut SeqRefs(&mut refs));
        assert_eq!(outs[0].data, pre_logits, "prefill item");
        assert_eq!(outs[1].data, dec_logits, "decode item");
    }

    #[test]
    fn all_logits_mode_matches_forward_full_rows() {
        let s = micro();
        let w = Weights::Fp(&s);
        let seq: Vec<i32> = vec![7, 11, 13, 17, 19];
        let full = forward_full(&w, &[seq.clone()], None);
        let mut engine = Engine::new(&w);
        let mut cache = KvCache::new(s.cfg);
        let plan = StepPlan {
            items: vec![StepItem::prefill(0, seq.clone(), LogitsMode::All)],
        };
        let mut refs: Vec<&mut dyn KvSeq> = vec![&mut cache];
        let outs = engine.step(&plan, &mut SeqRefs(&mut refs));
        assert_eq!(outs[0].rows, seq.len());
        assert_eq!(outs[0].data, full.data);
    }

    #[test]
    fn nll_positive_and_batch_additive() {
        let s = micro();
        let w = Weights::Fp(&s);
        let a = vec![vec![1, 2, 3, 4]];
        let b = vec![vec![5, 6, 7, 8]];
        let both = vec![a[0].clone(), b[0].clone()];
        let n_a = nll_sum(&w, &a);
        let n_b = nll_sum(&w, &b);
        let n_ab = nll_sum(&w, &both);
        assert!(n_a > 0.0 && n_b > 0.0);
        assert!(
            prop::close(n_ab, n_a + n_b, 1e-4, 1e-3),
            "{} vs {}",
            n_ab,
            n_a + n_b
        );
    }

    #[test]
    fn nll_chunked_matches_one_shot() {
        let s = micro();
        let w = Weights::Fp(&s);
        let toks = vec![
            (0..32).map(|i| (i * 5 + 1) % 256).collect::<Vec<i32>>(),
            (0..32).map(|i| (i * 3 + 9) % 256).collect::<Vec<i32>>(),
        ];
        let mut engine = Engine::new(&w);
        let full = engine.nll_sum_chunked(&toks, usize::MAX);
        for chunk in [1usize, 7, 16, 64] {
            let got = engine.nll_sum_chunked(&toks, chunk);
            assert!(
                prop::close(got, full, 1e-9, 1e-9),
                "chunk {}: {} vs {}",
                chunk,
                got,
                full
            );
        }
    }

    #[test]
    fn observer_sees_every_linear() {
        let s = micro();
        let mut seen = std::collections::BTreeSet::new();
        let mut obs = |name: &str, x: &Mat| {
            assert!(x.rows > 0);
            seen.insert(name.to_string());
        };
        forward_full(&Weights::Fp(&s), &[vec![1, 2, 3]], Some(&mut obs));
        assert_eq!(seen.len(), s.cfg.layers * 6);
        assert!(seen.contains("l0.wq") && seen.contains("l1.w2"));
    }

    #[test]
    fn generate_respects_ctx() {
        let s = micro();
        let w = Weights::Fp(&s);
        let prompt: Vec<i32> = (0..120).map(|i| i % 256).collect();
        let out = Engine::new(&w).generate(
            &prompt,
            50,
            &SamplingParams::greedy(),
        );
        assert!(out.len() <= s.cfg.ctx - prompt.len());
    }

    #[test]
    fn generate_matches_per_token_prompt_feed() {
        let s = micro();
        let w = Weights::Fp(&s);
        let prompt: Vec<i32> = vec![5, 80, 200, 3, 17];
        let chunked =
            Engine::new(&w).generate(&prompt, 6, &SamplingParams::greedy());
        // per-token prompt feed reference
        let mut engine = Engine::new(&w);
        let mut cache = KvCache::new(s.cfg);
        let mut logits = Vec::new();
        for &t in &prompt {
            logits = decode_one(&mut engine, t, &mut cache);
        }
        let mut expect = Vec::new();
        for _ in 0..6 {
            let next = argmax(&logits) as i32;
            expect.push(next);
            logits = decode_one(&mut engine, next, &mut cache);
        }
        assert_eq!(chunked, expect);
    }

    #[test]
    fn batched_decode_matches_sequential_bitwise() {
        let s = micro();
        let w = Weights::Fp(&s);
        // ragged warmup through single-item steps
        let prompts: [&[i32]; 3] = [&[1, 2, 3], &[9], &[5, 6, 7, 8, 20]];
        let mut eng_ref = Engine::new(&w);
        let mut caches: Vec<KvCache> =
            prompts.iter().map(|_| KvCache::new(s.cfg)).collect();
        for (p, c) in prompts.iter().zip(&mut caches) {
            for &t in *p {
                decode_one(&mut eng_ref, t, c);
            }
        }
        let toks = [11i32, 22, 33];
        let mut seq_caches = caches.clone();
        let seq_logits: Vec<Vec<f32>> = toks
            .iter()
            .zip(&mut seq_caches)
            .map(|(&t, c)| decode_one(&mut eng_ref, t, c))
            .collect();

        let mut engine = Engine::new(&w);
        let mut refs: Vec<&mut dyn KvSeq> = caches
            .iter_mut()
            .map(|c| c as &mut dyn KvSeq)
            .collect();
        let got = engine.decode_batch(&toks, &mut SeqRefs(&mut refs));
        assert_eq!(got, seq_logits, "batched logits must be bit-identical");

        // the cache state written by the batched step must match too:
        // one more step on both sides agrees
        for (c_b, c_s) in caches.iter_mut().zip(&mut seq_caches) {
            let a = decode_one(&mut engine, 40, c_b);
            let b = decode_one(&mut eng_ref, 40, c_s);
            assert_eq!(a, b, "cache divergence after batched step");
        }
    }

    #[test]
    fn sampler_temperature_zero_is_argmax_bitwise() {
        // the greedy path must not even be perturbed by top-k/top-p
        let mut rng = crate::util::rng::Rng::new(77);
        for draw in 0..50u64 {
            let logits = rng.normal_vec_f32(97);
            let greedy = argmax(&logits) as i32;
            for p in [
                SamplingParams::greedy(),
                SamplingParams::greedy().with_top_k(3).with_top_p(0.5),
                SamplingParams { temperature: -1.0, ..SamplingParams::greedy() },
            ] {
                assert_eq!(sample_logits(&logits, &p, draw), greedy);
            }
        }
    }

    #[test]
    fn sampler_top_k_one_is_argmax_at_any_temperature() {
        let mut rng = crate::util::rng::Rng::new(78);
        for draw in 0..20u64 {
            let logits = rng.normal_vec_f32(64);
            let p = SamplingParams::sample(1.3, 9).with_top_k(1);
            assert_eq!(sample_logits(&logits, &p, draw), argmax(&logits) as i32);
            // a vanishing nucleus keeps only the head of the distribution
            let p = SamplingParams::sample(1.3, 9).with_top_p(1e-6);
            assert_eq!(sample_logits(&logits, &p, draw), argmax(&logits) as i32);
        }
    }

    #[test]
    fn sampler_deterministic_in_seed_and_draw() {
        let mut rng = crate::util::rng::Rng::new(79);
        let logits = rng.normal_vec_f32(256);
        let p = SamplingParams::sample(1.0, 1234).with_top_k(40);
        for draw in 0..32u64 {
            let a = sample_logits(&logits, &p, draw);
            let b = sample_logits(&logits, &p, draw);
            assert_eq!(a, b);
        }
        // different draws must not all collapse to one token on a flat-ish
        // distribution (the stream actually advances per draw)
        let seen: std::collections::BTreeSet<i32> =
            (0..64u64).map(|d| sample_logits(&logits, &p, d)).collect();
        assert!(seen.len() > 4, "only {} distinct samples", seen.len());
    }

    #[test]
    fn sampler_respects_distribution_head() {
        // one dominant logit: nearly every draw picks it at T=1
        let mut logits = vec![0.0f32; 32];
        logits[7] = 8.0;
        let p = SamplingParams::sample(1.0, 5);
        let hits = (0..200u64)
            .filter(|&d| sample_logits(&logits, &p, d) == 7)
            .count();
        assert!(hits > 190, "dominant token sampled only {}/200", hits);
    }

    #[test]
    fn generate_sampled_reproducible_and_diverse() {
        let s = micro();
        let w = Weights::Fp(&s);
        let prompt: Vec<i32> = vec![10, 20, 30, 40];
        let p = SamplingParams::sample(1.0, 42);
        let a = Engine::new(&w).generate(&prompt, 8, &p);
        let b = Engine::new(&w).generate(&prompt, 8, &p);
        assert_eq!(a, b, "same seed must reproduce");
        // different seeds must diverge on at least one of several tries —
        // a random micro model's logits are nearly flat over 256 tokens
        let diverged = (43u64..47).any(|seed| {
            Engine::new(&w).generate(
                &prompt,
                8,
                &SamplingParams::sample(1.0, seed),
            ) != a
        });
        assert!(diverged, "different seeds should diverge");
    }

    #[test]
    fn engine_weight_bytes_accounting() {
        let s = micro();
        let w = Weights::Fp(&s);
        let engine = Engine::new(&w);
        let expect: usize = s
            .cfg
            .linear_shapes()
            .iter()
            .map(|(_, m, n)| m * n * 4)
            .sum();
        assert_eq!(engine.weight_bytes_per_step(), expect);
    }

    #[test]
    fn kv_truncate_rolls_back_decode_state() {
        // decoding past n, truncating back to n, then continuing must be
        // bitwise identical to never having decoded past n — the
        // speculative-decoding rollback contract
        let s = micro();
        let w = Weights::Fp(&s);
        let mut engine = Engine::new(&w);
        let toks = [3i32, 14, 15, 92, 65, 35, 89];

        let mut c_ref = KvCache::new(s.cfg);
        for &t in &toks[..4] {
            decode_one(&mut engine, t, &mut c_ref);
        }
        let expect = decode_one(&mut engine, 42, &mut c_ref);

        let mut c = KvCache::new(s.cfg);
        for &t in &toks {
            decode_one(&mut engine, t, &mut c);
        }
        c.truncate(4);
        assert_eq!(c.pos(), 4);
        let got = decode_one(&mut engine, 42, &mut c);
        assert_eq!(got, expect, "post-truncate decode diverged");
    }

    #[test]
    fn kv_truncate_past_len_is_noop() {
        let s = micro();
        let w = Weights::Fp(&s);
        let mut engine = Engine::new(&w);
        let mut c = KvCache::new(s.cfg);
        for &t in &[1i32, 2, 3] {
            decode_one(&mut engine, t, &mut c);
        }
        c.truncate(99);
        assert_eq!(c.pos(), 3);
        c.truncate(0);
        assert_eq!(c.pos(), 0);
    }

    /// Quantized model whose every linear is a random nested
    /// any-precision store (widths 2/3/4).
    fn anyprec_model(s: &WeightStore, seed: u64) -> crate::model::QuantizedModel {
        use crate::quant::lut::lut_from_parts;
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut linears = std::collections::BTreeMap::new();
        for (name, m, n) in s.cfg.linear_shapes() {
            let codes: Vec<u8> =
                (0..m * n).map(|_| rng.below(16) as u8).collect();
            let cb = Mat::from_vec(
                m,
                16,
                rng.normal_vec_f32(m * 16)
                    .into_iter()
                    .map(|v| v * 0.08)
                    .collect(),
            );
            let parent = lut_from_parts(m, n, 4, codes, cb);
            linears.insert(
                name,
                LayerWeights::AnyPrec(BitPlaneStore::nest(
                    &parent,
                    &[2, 3, 4],
                )),
            );
        }
        crate::model::QuantizedModel {
            base: s.clone(),
            method: "ganq-anyprec".into(),
            bits: 4,
            linears,
            weight_bits: 0,
        }
    }

    /// The same model with every store materialized as a standalone
    /// `w`-bit LUT layer.
    fn sliced_model(
        qm: &crate::model::QuantizedModel,
        w: u8,
    ) -> crate::model::QuantizedModel {
        let mut out = qm.clone();
        for lw in out.linears.values_mut() {
            if let LayerWeights::AnyPrec(b) = lw {
                *lw = LayerWeights::Lut(b.slice(w));
            }
        }
        out.bits = w;
        out
    }

    #[test]
    fn anyprec_engine_matches_standalone_slices_bitwise() {
        // serving through the plane-streaming plan must equal the packed
        // path over a separately materialized w-bit model, bit for bit
        let s = micro();
        let qm = anyprec_model(&s, 21);
        assert_eq!(qm.anyprec_widths(), vec![2, 3, 4]);
        let toks = vec![vec![3i32, 1, 4, 1, 5, 9, 2, 6]];
        for w in [2u8, 3, 4] {
            let a = Engine::new_at(&Weights::Quant(&qm), Some(w))
                .prefill_full(&toks, None);
            let std = sliced_model(&qm, w);
            let b = Engine::new(&Weights::Quant(&std)).prefill_full(&toks, None);
            assert_eq!(a.data, b.data, "width {}", w);
        }
    }

    #[test]
    fn engine_set_width_reresolves_plans() {
        let s = micro();
        let qm = anyprec_model(&s, 22);
        let toks = vec![vec![8i32, 6, 7, 5, 3, 0, 9]];
        let w4 = Engine::new_at(&Weights::Quant(&qm), Some(4))
            .prefill_full(&toks, None);
        let w3 = Engine::new_at(&Weights::Quant(&qm), Some(3))
            .prefill_full(&toks, None);
        assert_ne!(w4.data, w3.data, "widths should differ on random codes");

        let mut engine = Engine::new_at(&Weights::Quant(&qm), Some(4));
        assert_eq!(engine.width(), Some(4));
        assert_eq!(engine.prefill_full(&toks, None).data, w4.data);
        engine.set_width(3);
        assert_eq!(engine.prefill_full(&toks, None).data, w3.data);
        engine.set_width(4);
        assert_eq!(engine.prefill_full(&toks, None).data, w4.data);
    }

    #[test]
    fn anyprec_weight_bytes_shrink_with_width() {
        let s = micro();
        let qm = anyprec_model(&s, 23);
        let w = Weights::Quant(&qm);
        let b2 = Engine::new_at(&w, Some(2)).weight_bytes_per_step();
        let b3 = Engine::new_at(&w, Some(3)).weight_bytes_per_step();
        let b4 = Engine::new_at(&w, Some(4)).weight_bytes_per_step();
        assert!(b2 < b3 && b3 < b4, "{} {} {}", b2, b3, b4);
    }

    #[test]
    fn quantized_identity_matches_fp() {
        // a QuantizedModel whose linears are the exact FP weights must give
        // identical logits
        let s = micro();
        let mut linears = std::collections::BTreeMap::new();
        for (name, _m, _n) in s.cfg.linear_shapes() {
            linears.insert(
                name.clone(),
                crate::model::LayerWeights::Dense(s.mat(&name)),
            );
        }
        let qm = crate::model::QuantizedModel {
            base: s.clone(),
            method: "identity".into(),
            bits: 16,
            linears,
            weight_bits: 0,
        };
        let toks = vec![vec![3, 1, 4, 1, 5]];
        let l1 = forward_full(&Weights::Fp(&s), &toks, None);
        let l2 = forward_full(&Weights::Quant(&qm), &toks, None);
        assert!(prop::all_close(&l1.data, &l2.data, 1e-5, 1e-5));
    }
}
