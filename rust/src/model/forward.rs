//! Native CPU forward path — numerically mirrors python/compile/model.py
//! (layer_norm eps, tanh-GELU, attention scaling, tied head). Used for:
//! calibration capture (per-linear input activations -> Gram matrices),
//! evaluation fallback when HLO artifacts are absent, task scoring on
//! variable-length sequences, and cross-validation of the HLO path
//! (tests/golden.rs pins both against the python fixture).

use crate::model::{LayerWeights, ModelConfig, QuantizedModel, WeightStore};
use crate::tensor::{self, Mat};

/// Who provides the six quantizable linears.
pub enum Weights<'a> {
    Fp(&'a WeightStore),
    Quant(&'a QuantizedModel),
}

impl<'a> Weights<'a> {
    pub fn store(&self) -> &WeightStore {
        match self {
            Weights::Fp(s) => s,
            Weights::Quant(q) => &q.base,
        }
    }

    /// y = x @ W^T for the named quantizable linear (bias added by caller).
    fn linear(&self, name: &str, x: &Mat) -> Mat {
        match self {
            Weights::Fp(s) => x.matmul_tb(&s.mat(name)),
            Weights::Quant(q) => match q.linears.get(name) {
                Some(LayerWeights::Dense(w)) => x.matmul_tb(w),
                Some(LayerWeights::Lut(l)) => l.lut_matmul(x),
                Some(LayerWeights::LutSparse(l, sp)) => {
                    let mut y = l.lut_matmul(x);
                    sp.spmm_add(x, &mut y);
                    y
                }
                None => x.matmul_tb(&q.base.mat(name)),
            },
        }
    }
}

pub fn layer_norm_rows(x: &mut Mat, g: &[f32], b: &[f32]) {
    let d = x.cols;
    for row in x.data.chunks_mut(d) {
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 =
            row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (v, (&gi, &bi)) in row.iter_mut().zip(g.iter().zip(b)) {
            *v = (*v - mu) * inv * gi + bi;
        }
    }
}

pub fn gelu_tanh(x: &mut [f32]) {
    for v in x.iter_mut() {
        let x3 = *v * *v * *v;
        *v = 0.5 * *v * (1.0 + (0.7978845608 * (*v + 0.044715 * x3)).tanh());
    }
}

fn add_bias(x: &mut Mat, b: &[f32]) {
    for row in x.data.chunks_mut(b.len()) {
        for (v, &bi) in row.iter_mut().zip(b) {
            *v += bi;
        }
    }
}

/// Optional calibration observer: called with (linear_name, input [p, n]).
pub type Observer<'o> = &'o mut dyn FnMut(&str, &Mat);

/// Full causal forward over a batch of equal-length sequences.
/// tokens: B x S. Returns logits [(B*S), vocab].
pub fn forward_full(
    w: &Weights,
    tokens: &[Vec<i32>],
    mut observer: Option<Observer>,
) -> Mat {
    let store = w.store();
    let cfg = store.cfg;
    let bsz = tokens.len();
    let s_len = tokens[0].len();
    assert!(tokens.iter().all(|t| t.len() == s_len));
    assert!(s_len <= cfg.ctx);
    let d = cfg.d;
    let tok_emb = store.get("tok_emb");
    let pos_emb = store.get("pos_emb");

    let mut x = Mat::zeros(bsz * s_len, d);
    for (b, seq) in tokens.iter().enumerate() {
        for (s, &t) in seq.iter().enumerate() {
            let row = x.row_mut(b * s_len + s);
            let te = &tok_emb.data[(t as usize) * d..(t as usize + 1) * d];
            let pe = &pos_emb.data[s * d..(s + 1) * d];
            for (o, (&a, &b2)) in row.iter_mut().zip(te.iter().zip(pe)) {
                *o = a + b2;
            }
        }
    }

    for li in 0..cfg.layers {
        let p = format!("l{}.", li);
        x = block_full(w, &p, x, cfg, bsz, s_len, &mut observer);
    }
    layer_norm_rows(&mut x, store.vec("ln_f_g"), store.vec("ln_f_b"));
    // tied head: logits = x @ tok_emb^T
    let emb = tok_emb.as_mat();
    x.matmul_tb(&emb)
}

fn block_full(
    w: &Weights,
    p: &str,
    mut x: Mat,
    cfg: ModelConfig,
    bsz: usize,
    s_len: usize,
    observer: &mut Option<Observer>,
) -> Mat {
    let store = w.store();
    let d = cfg.d;
    let h = cfg.heads;
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();

    let mut a = x.clone();
    layer_norm_rows(
        &mut a,
        store.vec(&format!("{}ln1_g", p)),
        store.vec(&format!("{}ln1_b", p)),
    );
    let mut lin = |name: &str, inp: &Mat, bias: &str| -> Mat {
        let full = format!("{}{}", p, name);
        if let Some(obs) = observer.as_mut() {
            obs(&full, inp);
        }
        let mut y = w.linear(&full, inp);
        add_bias(&mut y, store.vec(&format!("{}{}", p, bias)));
        y
    };
    let q = lin("wq", &a, "bq");
    let k = lin("wk", &a, "bk");
    let v = lin("wv", &a, "bv");

    // attention per (batch, head)
    let mut o = Mat::zeros(bsz * s_len, d);
    let mut scores = vec![0.0f32; s_len];
    for b in 0..bsz {
        for hi in 0..h {
            for si in 0..s_len {
                let qrow = &q.row(b * s_len + si)[hi * hd..(hi + 1) * hd];
                for (sj, sc) in scores.iter_mut().enumerate().take(si + 1) {
                    let krow =
                        &k.row(b * s_len + sj)[hi * hd..(hi + 1) * hd];
                    *sc = tensor::dot(qrow, krow) * scale;
                }
                tensor::softmax(&mut scores[..si + 1]);
                let orow =
                    &mut o.row_mut(b * s_len + si)[hi * hd..(hi + 1) * hd];
                for (sj, &w_att) in scores.iter().enumerate().take(si + 1) {
                    let vrow =
                        &v.row(b * s_len + sj)[hi * hd..(hi + 1) * hd];
                    for (ov, &vv) in orow.iter_mut().zip(vrow) {
                        *ov += w_att * vv;
                    }
                }
            }
        }
    }
    let attn_out = lin("wo", &o, "bo");
    x.add_assign(&attn_out);

    let mut m = x.clone();
    layer_norm_rows(
        &mut m,
        store.vec(&format!("{}ln2_g", p)),
        store.vec(&format!("{}ln2_b", p)),
    );
    let mut h1 = lin("w1", &m, "b1");
    gelu_tanh(&mut h1.data);
    let h2 = lin("w2", &h1, "b2");
    x.add_assign(&h2);
    x
}

/// Sum of next-token NLLs over a batch (matches python nll_sum).
pub fn nll_sum(w: &Weights, tokens: &[Vec<i32>]) -> f64 {
    let logits = forward_full(w, tokens, None);
    let s_len = tokens[0].len();
    let vocab = w.store().cfg.vocab;
    let mut total = 0.0f64;
    for (b, seq) in tokens.iter().enumerate() {
        for s in 0..s_len - 1 {
            let row = &logits.row(b * s_len + s)[..vocab];
            total -=
                tensor::log_softmax_at(row, seq[s + 1] as usize) as f64;
        }
    }
    total
}

// ---------------------------------------------------------------------------
// KV-cache decode (native serving fallback + generation-based evals)
// ---------------------------------------------------------------------------

/// Abstract per-sequence KV storage driving one decode step. The
/// contiguous [`KvCache`] and the paged cache (`kv::PagedKv` slot views)
/// both implement it, so `decode_step_kv` is the single attention path
/// and the dense variants stay bit-identical by construction.
pub trait KvSeq {
    /// Positions cached so far (the next write lands here).
    fn pos(&self) -> usize;
    /// Store the K/V rows (`head_dim` floats each) for (layer, head) at
    /// position `pos()`.
    fn write(&mut self, li: usize, hi: usize, k: &[f32], v: &[f32]);
    /// Copy the cached K row at (layer, head, position `sj`) into `out`.
    fn read_k(&self, li: usize, hi: usize, sj: usize, out: &mut [f32]);
    fn read_v(&self, li: usize, hi: usize, sj: usize, out: &mut [f32]);
    /// Borrow the K row in place when the store holds it as contiguous
    /// f32 (dense caches, unsealed paged tails). `None` routes the
    /// caller to `read_k` + a scratch buffer (e.g. sealed LUT blocks).
    /// Keeps the dense hot path copy-free.
    fn k_slice(&self, li: usize, hi: usize, sj: usize) -> Option<&[f32]> {
        let _ = (li, hi, sj);
        None
    }
    fn v_slice(&self, li: usize, hi: usize, sj: usize) -> Option<&[f32]> {
        let _ = (li, hi, sj);
        None
    }
    /// Commit the step: `pos += 1`.
    fn advance(&mut self);
}

/// Per-sequence contiguous KV cache for the native path.
pub struct KvCache {
    cfg: ModelConfig,
    /// [layers][heads][ctx][hd], flattened
    k: Vec<f32>,
    v: Vec<f32>,
    pub len: usize,
}

impl KvCache {
    pub fn new(cfg: ModelConfig) -> KvCache {
        let sz = cfg.layers * cfg.heads * cfg.ctx * cfg.head_dim();
        KvCache { cfg, k: vec![0.0; sz], v: vec![0.0; sz], len: 0 }
    }

    fn idx(&self, li: usize, hi: usize, pos: usize) -> usize {
        let hd = self.cfg.head_dim();
        ((li * self.cfg.heads + hi) * self.cfg.ctx + pos) * hd
    }
}

impl KvSeq for KvCache {
    fn pos(&self) -> usize {
        self.len
    }

    fn write(&mut self, li: usize, hi: usize, k: &[f32], v: &[f32]) {
        let hd = self.cfg.head_dim();
        let base = self.idx(li, hi, self.len);
        self.k[base..base + hd].copy_from_slice(k);
        self.v[base..base + hd].copy_from_slice(v);
    }

    fn read_k(&self, li: usize, hi: usize, sj: usize, out: &mut [f32]) {
        let hd = self.cfg.head_dim();
        let base = self.idx(li, hi, sj);
        out.copy_from_slice(&self.k[base..base + hd]);
    }

    fn read_v(&self, li: usize, hi: usize, sj: usize, out: &mut [f32]) {
        let hd = self.cfg.head_dim();
        let base = self.idx(li, hi, sj);
        out.copy_from_slice(&self.v[base..base + hd]);
    }

    fn k_slice(&self, li: usize, hi: usize, sj: usize) -> Option<&[f32]> {
        let hd = self.cfg.head_dim();
        let base = self.idx(li, hi, sj);
        Some(&self.k[base..base + hd])
    }

    fn v_slice(&self, li: usize, hi: usize, sj: usize) -> Option<&[f32]> {
        let hd = self.cfg.head_dim();
        let base = self.idx(li, hi, sj);
        Some(&self.v[base..base + hd])
    }

    fn advance(&mut self) {
        self.len += 1;
    }
}

/// One decode step for a single sequence; appends to the cache.
/// Returns the logits row [vocab].
pub fn decode_step(w: &Weights, tok: i32, cache: &mut KvCache) -> Vec<f32> {
    decode_step_kv(w, tok, cache)
}

/// One decode step through any [`KvSeq`] (contiguous or paged). The
/// attention loop iterates positions in ascending order with identical
/// f32 accumulation to the historical contiguous path, so two stores
/// holding the same values produce bit-identical logits.
pub fn decode_step_kv(
    w: &Weights,
    tok: i32,
    cache: &mut dyn KvSeq,
) -> Vec<f32> {
    let store = w.store();
    let cfg = store.cfg;
    let d = cfg.d;
    let h = cfg.heads;
    let hd = cfg.head_dim();
    let pos = cache.pos();
    assert!(pos < cfg.ctx, "context overflow");
    let scale = 1.0 / (hd as f32).sqrt();

    let mut x = Mat::zeros(1, d);
    {
        let te = &store.get("tok_emb").data
            [(tok as usize) * d..(tok as usize + 1) * d];
        let pe = &store.get("pos_emb").data[pos * d..(pos + 1) * d];
        for (o, (&a, &b)) in x.row_mut(0).iter_mut().zip(te.iter().zip(pe)) {
            *o = a + b;
        }
    }

    let mut krow = vec![0.0f32; hd];
    let mut vrow = vec![0.0f32; hd];
    for li in 0..cfg.layers {
        let p = format!("l{}.", li);
        let mut a = x.clone();
        layer_norm_rows(
            &mut a,
            store.vec(&format!("{}ln1_g", p)),
            store.vec(&format!("{}ln1_b", p)),
        );
        let lin = |name: &str, inp: &Mat, bias: &str| -> Mat {
            let mut y = w.linear(&format!("{}{}", p, name), inp);
            add_bias(&mut y, store.vec(&format!("{}{}", p, bias)));
            y
        };
        let q = lin("wq", &a, "bq");
        let k = lin("wk", &a, "bk");
        let v = lin("wv", &a, "bv");
        // write cache at pos
        for hi in 0..h {
            cache.write(
                li,
                hi,
                &k.row(0)[hi * hd..(hi + 1) * hd],
                &v.row(0)[hi * hd..(hi + 1) * hd],
            );
        }
        // attend over 0..=pos
        let mut o = Mat::zeros(1, d);
        let mut scores = vec![0.0f32; pos + 1];
        for hi in 0..h {
            let qrow = &q.row(0)[hi * hd..(hi + 1) * hd];
            for (sj, sc) in scores.iter_mut().enumerate() {
                let kr = match cache.k_slice(li, hi, sj) {
                    Some(s) => s,
                    None => {
                        cache.read_k(li, hi, sj, &mut krow);
                        &krow[..]
                    }
                };
                *sc = tensor::dot(qrow, kr) * scale;
            }
            tensor::softmax(&mut scores);
            let orow = &mut o.row_mut(0)[hi * hd..(hi + 1) * hd];
            for (sj, &w_att) in scores.iter().enumerate() {
                let vr = match cache.v_slice(li, hi, sj) {
                    Some(s) => s,
                    None => {
                        cache.read_v(li, hi, sj, &mut vrow);
                        &vrow[..]
                    }
                };
                for (ov, &vv) in orow.iter_mut().zip(vr) {
                    *ov += w_att * vv;
                }
            }
        }
        let attn_out = lin("wo", &o, "bo");
        x.add_assign(&attn_out);
        let mut m = x.clone();
        layer_norm_rows(
            &mut m,
            store.vec(&format!("{}ln2_g", p)),
            store.vec(&format!("{}ln2_b", p)),
        );
        let mut h1 = lin("w1", &m, "b1");
        gelu_tanh(&mut h1.data);
        let h2 = lin("w2", &h1, "b2");
        x.add_assign(&h2);
    }
    cache.advance();
    layer_norm_rows(&mut x, store.vec("ln_f_g"), store.vec("ln_f_b"));
    let emb = store.get("tok_emb").as_mat();
    let logits = x.matmul_tb(&emb);
    logits.data
}

/// Greedy generation with the native path.
pub fn generate_greedy(
    w: &Weights,
    prompt: &[i32],
    max_new: usize,
) -> Vec<i32> {
    let cfg = w.store().cfg;
    let mut cache = KvCache::new(cfg);
    let mut logits = Vec::new();
    for &t in prompt {
        logits = decode_step(w, t, &mut cache);
    }
    let mut out = Vec::with_capacity(max_new);
    for _ in 0..max_new {
        if cache.len >= cfg.ctx {
            break;
        }
        let next = argmax(&logits) as i32;
        out.push(next);
        logits = decode_step(w, next, &mut cache);
    }
    out
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::WeightStore;
    use crate::util::prop;

    fn micro() -> WeightStore {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        WeightStore::random("t", cfg, 11)
    }

    #[test]
    fn forward_shapes_and_finite() {
        let s = micro();
        let toks = vec![vec![1, 2, 3, 4, 5], vec![9, 8, 7, 6, 5]];
        let logits = forward_full(&Weights::Fp(&s), &toks, None);
        assert_eq!(logits.rows, 10);
        assert_eq!(logits.cols, 256);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn decode_matches_full_forward() {
        let s = micro();
        let w = Weights::Fp(&s);
        let seq: Vec<i32> = vec![10, 65, 97, 32, 101, 120, 5];
        let logits_full = forward_full(&w, &[seq.clone()], None);
        let mut cache = KvCache::new(s.cfg);
        let mut last = Vec::new();
        for &t in &seq {
            last = decode_step(&w, t, &mut cache);
        }
        let expect = logits_full.row(seq.len() - 1);
        assert!(
            prop::all_close(&last, expect, 1e-3, 1e-3),
            "maxdiff {}",
            prop::max_abs_diff(&last, expect)
        );
    }

    #[test]
    fn nll_positive_and_batch_additive() {
        let s = micro();
        let w = Weights::Fp(&s);
        let a = vec![vec![1, 2, 3, 4]];
        let b = vec![vec![5, 6, 7, 8]];
        let both = vec![a[0].clone(), b[0].clone()];
        let n_a = nll_sum(&w, &a);
        let n_b = nll_sum(&w, &b);
        let n_ab = nll_sum(&w, &both);
        assert!(n_a > 0.0 && n_b > 0.0);
        assert!(
            prop::close(n_ab, n_a + n_b, 1e-4, 1e-3),
            "{} vs {}",
            n_ab,
            n_a + n_b
        );
    }

    #[test]
    fn observer_sees_every_linear() {
        let s = micro();
        let mut seen = std::collections::BTreeSet::new();
        let mut obs = |name: &str, x: &Mat| {
            assert!(x.rows > 0);
            seen.insert(name.to_string());
        };
        forward_full(&Weights::Fp(&s), &[vec![1, 2, 3]], Some(&mut obs));
        assert_eq!(seen.len(), s.cfg.layers * 6);
        assert!(seen.contains("l0.wq") && seen.contains("l1.w2"));
    }

    #[test]
    fn generate_respects_ctx() {
        let s = micro();
        let w = Weights::Fp(&s);
        let prompt: Vec<i32> = (0..120).map(|i| i % 256).collect();
        let out = generate_greedy(&w, &prompt, 50);
        assert!(out.len() <= s.cfg.ctx - prompt.len());
    }

    #[test]
    fn quantized_identity_matches_fp() {
        // a QuantizedModel whose linears are the exact FP weights must give
        // identical logits
        let s = micro();
        let mut linears = std::collections::BTreeMap::new();
        for (name, _m, _n) in s.cfg.linear_shapes() {
            linears.insert(
                name.clone(),
                crate::model::LayerWeights::Dense(s.mat(&name)),
            );
        }
        let qm = crate::model::QuantizedModel {
            base: s.clone(),
            method: "identity".into(),
            bits: 16,
            linears,
            weight_bits: 0,
        };
        let toks = vec![vec![3, 1, 4, 1, 5]];
        let l1 = forward_full(&Weights::Fp(&s), &toks, None);
        let l2 = forward_full(&Weights::Quant(&qm), &toks, None);
        assert!(prop::all_close(&l1.data, &l2.data, 1e-5, 1e-5));
    }
}
