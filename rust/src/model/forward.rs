//! Native CPU forward path — numerically mirrors python/compile/model.py
//! (layer_norm eps, tanh-GELU, attention scaling, tied head). Used for:
//! calibration capture (per-linear input activations -> Gram matrices),
//! evaluation fallback when HLO artifacts are absent, task scoring on
//! variable-length sequences, and cross-validation of the HLO path
//! (tests/golden.rs pins both against the python fixture).

use crate::model::{
    LayerWeights, ModelConfig, QuantizedModel, Tensor, WeightStore,
};
use crate::quant::kernels::{self, LutScratch, PackedLut};
use crate::quant::LutLayer;
use crate::sparse::Csr;
use crate::tensor::{self, Mat};
use crate::util::pool;

/// Who provides the six quantizable linears.
#[derive(Clone, Copy)]
pub enum Weights<'a> {
    Fp(&'a WeightStore),
    Quant(&'a QuantizedModel),
}

impl<'a> Weights<'a> {
    pub fn store(&self) -> &'a WeightStore {
        match self {
            Weights::Fp(s) => s,
            Weights::Quant(q) => &q.base,
        }
    }

    /// y = x @ W^T for the named quantizable linear (bias added by caller).
    fn linear(&self, name: &str, x: &Mat) -> Mat {
        match self {
            Weights::Fp(s) => x.matmul_tb(&s.mat(name)),
            Weights::Quant(q) => match q.linears.get(name) {
                Some(LayerWeights::Dense(w)) => x.matmul_tb(w),
                Some(LayerWeights::Lut(l)) => l.lut_matmul(x),
                Some(LayerWeights::LutSparse(l, sp)) => {
                    let mut y = l.lut_matmul(x);
                    sp.spmm_add(x, &mut y);
                    y
                }
                None => x.matmul_tb(&q.base.mat(name)),
            },
        }
    }
}

pub fn layer_norm_rows(x: &mut Mat, g: &[f32], b: &[f32]) {
    let d = x.cols;
    for row in x.data.chunks_mut(d) {
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 =
            row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (v, (&gi, &bi)) in row.iter_mut().zip(g.iter().zip(b)) {
            *v = (*v - mu) * inv * gi + bi;
        }
    }
}

pub fn gelu_tanh(x: &mut [f32]) {
    for v in x.iter_mut() {
        let x3 = *v * *v * *v;
        *v = 0.5 * *v * (1.0 + (0.7978845608 * (*v + 0.044715 * x3)).tanh());
    }
}

fn add_bias(x: &mut Mat, b: &[f32]) {
    for row in x.data.chunks_mut(b.len()) {
        for (v, &bi) in row.iter_mut().zip(b) {
            *v += bi;
        }
    }
}

/// Optional calibration observer: called with (linear_name, input [p, n]).
pub type Observer<'o> = &'o mut dyn FnMut(&str, &Mat);

/// Full causal forward over a batch of equal-length sequences.
/// tokens: B x S. Returns logits [(B*S), vocab].
pub fn forward_full(
    w: &Weights,
    tokens: &[Vec<i32>],
    mut observer: Option<Observer>,
) -> Mat {
    let store = w.store();
    let cfg = store.cfg;
    let bsz = tokens.len();
    let s_len = tokens[0].len();
    assert!(tokens.iter().all(|t| t.len() == s_len));
    assert!(s_len <= cfg.ctx);
    let d = cfg.d;
    let tok_emb = store.get("tok_emb");
    let pos_emb = store.get("pos_emb");

    let mut x = Mat::zeros(bsz * s_len, d);
    for (b, seq) in tokens.iter().enumerate() {
        for (s, &t) in seq.iter().enumerate() {
            let row = x.row_mut(b * s_len + s);
            let te = &tok_emb.data[(t as usize) * d..(t as usize + 1) * d];
            let pe = &pos_emb.data[s * d..(s + 1) * d];
            for (o, (&a, &b2)) in row.iter_mut().zip(te.iter().zip(pe)) {
                *o = a + b2;
            }
        }
    }

    for li in 0..cfg.layers {
        let p = format!("l{}.", li);
        x = block_full(w, &p, x, cfg, bsz, s_len, &mut observer);
    }
    layer_norm_rows(&mut x, store.vec("ln_f_g"), store.vec("ln_f_b"));
    // tied head: logits = x @ tok_emb^T
    let emb = tok_emb.as_mat();
    x.matmul_tb(&emb)
}

fn block_full(
    w: &Weights,
    p: &str,
    mut x: Mat,
    cfg: ModelConfig,
    bsz: usize,
    s_len: usize,
    observer: &mut Option<Observer>,
) -> Mat {
    let store = w.store();
    let d = cfg.d;
    let h = cfg.heads;
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();

    let mut a = x.clone();
    layer_norm_rows(
        &mut a,
        store.vec(&format!("{}ln1_g", p)),
        store.vec(&format!("{}ln1_b", p)),
    );
    let mut lin = |name: &str, inp: &Mat, bias: &str| -> Mat {
        let full = format!("{}{}", p, name);
        if let Some(obs) = observer.as_mut() {
            obs(&full, inp);
        }
        let mut y = w.linear(&full, inp);
        add_bias(&mut y, store.vec(&format!("{}{}", p, bias)));
        y
    };
    let q = lin("wq", &a, "bq");
    let k = lin("wk", &a, "bk");
    let v = lin("wv", &a, "bv");

    // attention per (batch, head)
    let mut o = Mat::zeros(bsz * s_len, d);
    let mut scores = vec![0.0f32; s_len];
    for b in 0..bsz {
        for hi in 0..h {
            for si in 0..s_len {
                let qrow = &q.row(b * s_len + si)[hi * hd..(hi + 1) * hd];
                for (sj, sc) in scores.iter_mut().enumerate().take(si + 1) {
                    let krow =
                        &k.row(b * s_len + sj)[hi * hd..(hi + 1) * hd];
                    *sc = tensor::dot(qrow, krow) * scale;
                }
                tensor::softmax(&mut scores[..si + 1]);
                let orow =
                    &mut o.row_mut(b * s_len + si)[hi * hd..(hi + 1) * hd];
                for (sj, &w_att) in scores.iter().enumerate().take(si + 1) {
                    let vrow =
                        &v.row(b * s_len + sj)[hi * hd..(hi + 1) * hd];
                    for (ov, &vv) in orow.iter_mut().zip(vrow) {
                        *ov += w_att * vv;
                    }
                }
            }
        }
    }
    let attn_out = lin("wo", &o, "bo");
    x.add_assign(&attn_out);

    let mut m = x.clone();
    layer_norm_rows(
        &mut m,
        store.vec(&format!("{}ln2_g", p)),
        store.vec(&format!("{}ln2_b", p)),
    );
    let mut h1 = lin("w1", &m, "b1");
    gelu_tanh(&mut h1.data);
    let h2 = lin("w2", &h1, "b2");
    x.add_assign(&h2);
    x
}

/// Sum of next-token NLLs over a batch (matches python nll_sum).
pub fn nll_sum(w: &Weights, tokens: &[Vec<i32>]) -> f64 {
    let logits = forward_full(w, tokens, None);
    let s_len = tokens[0].len();
    let vocab = w.store().cfg.vocab;
    let mut total = 0.0f64;
    for (b, seq) in tokens.iter().enumerate() {
        for s in 0..s_len - 1 {
            let row = &logits.row(b * s_len + s)[..vocab];
            total -=
                tensor::log_softmax_at(row, seq[s + 1] as usize) as f64;
        }
    }
    total
}

// ---------------------------------------------------------------------------
// KV-cache decode (native serving fallback + generation-based evals)
// ---------------------------------------------------------------------------

/// Abstract per-sequence KV storage driving one decode step. The
/// contiguous [`KvCache`] and the paged cache (`kv::PagedKv` slot views)
/// both implement it, so `decode_step_kv` is the single attention path
/// and the dense variants stay bit-identical by construction.
pub trait KvSeq {
    /// Positions cached so far (the next write lands here).
    fn pos(&self) -> usize;
    /// Store the K/V rows (`head_dim` floats each) for (layer, head) at
    /// position `pos()`.
    fn write(&mut self, li: usize, hi: usize, k: &[f32], v: &[f32]);
    /// Copy the cached K row at (layer, head, position `sj`) into `out`.
    fn read_k(&self, li: usize, hi: usize, sj: usize, out: &mut [f32]);
    fn read_v(&self, li: usize, hi: usize, sj: usize, out: &mut [f32]);
    /// Borrow the K row in place when the store holds it as contiguous
    /// f32 (dense caches, unsealed paged tails). `None` routes the
    /// caller to `read_k` + a scratch buffer (e.g. sealed LUT blocks).
    /// Keeps the dense hot path copy-free.
    fn k_slice(&self, li: usize, hi: usize, sj: usize) -> Option<&[f32]> {
        let _ = (li, hi, sj);
        None
    }
    fn v_slice(&self, li: usize, hi: usize, sj: usize) -> Option<&[f32]> {
        let _ = (li, hi, sj);
        None
    }
    /// Copy `rows` consecutive K rows (positions `sj0..sj0+rows`) into
    /// `out` (`rows * head_dim` floats). Default loops `read_k`; stores
    /// whose rows are physically contiguous override this so the batched
    /// decode gather pays one call (and ideally one memcpy) per
    /// (layer, head) instead of two virtual dispatches per position.
    fn read_k_rows(
        &self,
        li: usize,
        hi: usize,
        sj0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        if rows == 0 {
            return;
        }
        let hd = out.len() / rows;
        for (r, orow) in out.chunks_mut(hd).enumerate() {
            self.read_k(li, hi, sj0 + r, orow);
        }
    }
    fn read_v_rows(
        &self,
        li: usize,
        hi: usize,
        sj0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        if rows == 0 {
            return;
        }
        let hd = out.len() / rows;
        for (r, orow) in out.chunks_mut(hd).enumerate() {
            self.read_v(li, hi, sj0 + r, orow);
        }
    }
    /// Commit the step: `pos += 1`.
    fn advance(&mut self);
}

/// Per-sequence contiguous KV cache for the native path.
#[derive(Clone)]
pub struct KvCache {
    cfg: ModelConfig,
    /// [layers][heads][ctx][hd], flattened
    k: Vec<f32>,
    v: Vec<f32>,
    pub len: usize,
}

impl KvCache {
    pub fn new(cfg: ModelConfig) -> KvCache {
        let sz = cfg.layers * cfg.heads * cfg.ctx * cfg.head_dim();
        KvCache { cfg, k: vec![0.0; sz], v: vec![0.0; sz], len: 0 }
    }

    fn idx(&self, li: usize, hi: usize, pos: usize) -> usize {
        let hd = self.cfg.head_dim();
        ((li * self.cfg.heads + hi) * self.cfg.ctx + pos) * hd
    }
}

impl KvSeq for KvCache {
    fn pos(&self) -> usize {
        self.len
    }

    fn write(&mut self, li: usize, hi: usize, k: &[f32], v: &[f32]) {
        let hd = self.cfg.head_dim();
        let base = self.idx(li, hi, self.len);
        self.k[base..base + hd].copy_from_slice(k);
        self.v[base..base + hd].copy_from_slice(v);
    }

    fn read_k(&self, li: usize, hi: usize, sj: usize, out: &mut [f32]) {
        let hd = self.cfg.head_dim();
        let base = self.idx(li, hi, sj);
        out.copy_from_slice(&self.k[base..base + hd]);
    }

    fn read_v(&self, li: usize, hi: usize, sj: usize, out: &mut [f32]) {
        let hd = self.cfg.head_dim();
        let base = self.idx(li, hi, sj);
        out.copy_from_slice(&self.v[base..base + hd]);
    }

    fn k_slice(&self, li: usize, hi: usize, sj: usize) -> Option<&[f32]> {
        let hd = self.cfg.head_dim();
        let base = self.idx(li, hi, sj);
        Some(&self.k[base..base + hd])
    }

    fn v_slice(&self, li: usize, hi: usize, sj: usize) -> Option<&[f32]> {
        let hd = self.cfg.head_dim();
        let base = self.idx(li, hi, sj);
        Some(&self.v[base..base + hd])
    }

    fn read_k_rows(
        &self,
        li: usize,
        hi: usize,
        sj0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        // positions are contiguous within a (layer, head): one memcpy
        let base = self.idx(li, hi, sj0);
        out.copy_from_slice(&self.k[base..base + rows * self.cfg.head_dim()]);
    }

    fn read_v_rows(
        &self,
        li: usize,
        hi: usize,
        sj0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        let base = self.idx(li, hi, sj0);
        out.copy_from_slice(&self.v[base..base + rows * self.cfg.head_dim()]);
    }

    fn advance(&mut self) {
        self.len += 1;
    }
}

/// Interned parameter names for one transformer layer — built once per
/// decoder/engine so per-token hot loops never run `format!`.
pub struct LayerKeys {
    pub ln1_g: String,
    pub ln1_b: String,
    pub ln2_g: String,
    pub ln2_b: String,
    /// (weight, bias) names in canonical order: wq, wk, wv, wo, w1, w2
    pub lin: [(String, String); 6],
}

impl LayerKeys {
    pub fn build(layers: usize) -> Vec<LayerKeys> {
        (0..layers)
            .map(|li| {
                let p = format!("l{}.", li);
                let nb = |w: &str, b: &str| {
                    (format!("{}{}", p, w), format!("{}{}", p, b))
                };
                LayerKeys {
                    ln1_g: format!("{}ln1_g", p),
                    ln1_b: format!("{}ln1_b", p),
                    ln2_g: format!("{}ln2_g", p),
                    ln2_b: format!("{}ln2_b", p),
                    lin: [
                        nb("wq", "bq"),
                        nb("wk", "bk"),
                        nb("wv", "bv"),
                        nb("wo", "bo"),
                        nb("w1", "b1"),
                        nb("w2", "b2"),
                    ],
                }
            })
            .collect()
    }
}

/// One decode step for a single sequence; appends to the cache.
/// Returns the logits row [vocab].
pub fn decode_step(w: &Weights, tok: i32, cache: &mut KvCache) -> Vec<f32> {
    decode_step_kv(w, tok, cache)
}

/// One decode step through any [`KvSeq`] (contiguous or paged). The
/// attention loop iterates positions in ascending order with identical
/// f32 accumulation to the historical contiguous path, so two stores
/// holding the same values produce bit-identical logits.
///
/// Token-loop callers should hold a [`SeqDecoder`] instead: this
/// convenience wrapper rebuilds the key table and scratch every call.
pub fn decode_step_kv(
    w: &Weights,
    tok: i32,
    cache: &mut dyn KvSeq,
) -> Vec<f32> {
    SeqDecoder::new(*w).step(tok, cache)
}

/// Sequential (one-sequence-at-a-time) decoder with the per-token
/// constants hoisted out of the token loop: interned layer keys (no
/// `format!` per layer per token) and `scores`/`krow`/`vrow` attention
/// scratch reused across layers and steps.
pub struct SeqDecoder<'w> {
    w: Weights<'w>,
    keys: Vec<LayerKeys>,
    scores: Vec<f32>,
    krow: Vec<f32>,
    vrow: Vec<f32>,
}

impl<'w> SeqDecoder<'w> {
    pub fn new(w: Weights<'w>) -> SeqDecoder<'w> {
        let cfg = w.store().cfg;
        SeqDecoder {
            w,
            keys: LayerKeys::build(cfg.layers),
            scores: Vec::with_capacity(cfg.ctx),
            krow: vec![0.0; cfg.head_dim()],
            vrow: vec![0.0; cfg.head_dim()],
        }
    }

    /// One decode step; math identical to the historical
    /// `decode_step_kv` (same op order per element).
    pub fn step(&mut self, tok: i32, cache: &mut dyn KvSeq) -> Vec<f32> {
        let SeqDecoder { w, keys, scores, krow, vrow } = self;
        let w = *w;
        let store = w.store();
        let cfg = store.cfg;
        let d = cfg.d;
        let h = cfg.heads;
        let hd = cfg.head_dim();
        let pos = cache.pos();
        assert!(pos < cfg.ctx, "context overflow");
        let scale = 1.0 / (hd as f32).sqrt();

        let mut x = Mat::zeros(1, d);
        {
            let te = &store.get("tok_emb").data
                [(tok as usize) * d..(tok as usize + 1) * d];
            let pe = &store.get("pos_emb").data[pos * d..(pos + 1) * d];
            for (o, (&a, &b)) in
                x.row_mut(0).iter_mut().zip(te.iter().zip(pe))
            {
                *o = a + b;
            }
        }

        scores.resize(pos + 1, 0.0);
        for (li, key) in keys.iter().enumerate() {
            let mut a = x.clone();
            layer_norm_rows(&mut a, store.vec(&key.ln1_g), store.vec(&key.ln1_b));
            let lin = |slot: usize, inp: &Mat| -> Mat {
                let (wname, bname) = &key.lin[slot];
                let mut y = w.linear(wname, inp);
                add_bias(&mut y, store.vec(bname));
                y
            };
            let q = lin(0, &a);
            let k = lin(1, &a);
            let v = lin(2, &a);
            // write cache at pos
            for hi in 0..h {
                cache.write(
                    li,
                    hi,
                    &k.row(0)[hi * hd..(hi + 1) * hd],
                    &v.row(0)[hi * hd..(hi + 1) * hd],
                );
            }
            // attend over 0..=pos
            let mut o = Mat::zeros(1, d);
            for hi in 0..h {
                let qrow = &q.row(0)[hi * hd..(hi + 1) * hd];
                for (sj, sc) in scores.iter_mut().enumerate() {
                    let kr = match cache.k_slice(li, hi, sj) {
                        Some(s) => s,
                        None => {
                            cache.read_k(li, hi, sj, krow);
                            &krow[..]
                        }
                    };
                    *sc = tensor::dot(qrow, kr) * scale;
                }
                tensor::softmax(scores);
                let orow = &mut o.row_mut(0)[hi * hd..(hi + 1) * hd];
                for (sj, &w_att) in scores.iter().enumerate() {
                    let vr = match cache.v_slice(li, hi, sj) {
                        Some(s) => s,
                        None => {
                            cache.read_v(li, hi, sj, vrow);
                            &vrow[..]
                        }
                    };
                    for (ov, &vv) in orow.iter_mut().zip(vr) {
                        *ov += w_att * vv;
                    }
                }
            }
            let attn_out = lin(3, &o);
            x.add_assign(&attn_out);
            let mut m = x.clone();
            layer_norm_rows(&mut m, store.vec(&key.ln2_g), store.vec(&key.ln2_b));
            let mut h1 = lin(4, &m);
            gelu_tanh(&mut h1.data);
            let h2 = lin(5, &h1);
            x.add_assign(&h2);
        }
        cache.advance();
        layer_norm_rows(&mut x, store.vec("ln_f_g"), store.vec("ln_f_b"));
        let emb = store.get("tok_emb").as_mat();
        let logits = x.matmul_tb(&emb);
        logits.data
    }
}

/// Greedy generation with the native path.
pub fn generate_greedy(
    w: &Weights,
    prompt: &[i32],
    max_new: usize,
) -> Vec<i32> {
    let cfg = w.store().cfg;
    let mut cache = KvCache::new(cfg);
    let mut dec = SeqDecoder::new(*w);
    let mut logits = Vec::new();
    for &t in prompt {
        logits = dec.step(t, &mut cache);
    }
    let mut out = Vec::with_capacity(max_new);
    for _ in 0..max_new {
        if cache.len >= cfg.ctx {
            break;
        }
        let next = argmax(&logits) as i32;
        out.push(next);
        logits = dec.step(next, &mut cache);
    }
    out
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

// ---------------------------------------------------------------------------
// batched decode engine (the serving hot path)
// ---------------------------------------------------------------------------

/// Per-step access to a batch of per-sequence KV stores. The paged cache
/// can hand out only one mutable slot view at a time (views alias the
/// shared block pool), so the batched decode engine visits sequences
/// through a closure instead of holding simultaneous `&mut` views.
pub trait SeqAccess {
    fn count(&self) -> usize;
    fn with_seq(&mut self, i: usize, f: &mut dyn FnMut(&mut dyn KvSeq));
}

/// [`SeqAccess`] over independently owned caches (the contiguous
/// backend: one [`KvCache`] per slot).
pub struct SeqRefs<'a, 'b>(pub &'a mut [&'b mut dyn KvSeq]);

impl SeqAccess for SeqRefs<'_, '_> {
    fn count(&self) -> usize {
        self.0.len()
    }

    fn with_seq(&mut self, i: usize, f: &mut dyn FnMut(&mut dyn KvSeq)) {
        f(&mut *self.0[i]);
    }
}

/// How the engine serves one linear. Built once at engine construction;
/// the hot loop dispatches on this enum instead of string-keyed maps.
/// Every variant borrows or repacks — the engine never clones dense
/// weights.
enum LinearPlan<'w> {
    /// dense f32 borrowed straight from the FP store's tensor (also the
    /// fallback for linears missing from a quantized model)
    Fp(&'w Tensor),
    /// dense f32 borrowed from the quantized store
    DenseRef(&'w Mat),
    /// packed LUT codes — the dequantization-free mpGEMM hot path
    Packed(PackedLut),
    /// packed LUT plus the CSR outlier branch (GANQ*/SqueezeLLM)
    PackedSparse(PackedLut, &'w Csr),
    /// unpacked-code LUT (>4-bit widths have no packed form): the same
    /// bucket kernel as `LutLayer::lut_matmul`, so bit-identity with
    /// the sequential path holds at every code width
    Codes(&'w LutLayer),
    CodesSparse(&'w LutLayer, &'w Csr),
}

impl LinearPlan<'_> {
    fn apply(&self, x: &Mat, sc: &mut LutScratch, out: &mut Mat) {
        match self {
            LinearPlan::Fp(t) => {
                tensor::matmul_tb_slice_into(x, &t.data, t.shape[0], out)
            }
            LinearPlan::DenseRef(w) => x.matmul_tb_into(w, out),
            LinearPlan::Packed(pl) => pl.matmul_into(x, sc, out),
            LinearPlan::PackedSparse(pl, sp) => {
                pl.matmul_into(x, sc, out);
                sp.spmm_add(x, out);
            }
            LinearPlan::Codes(l) => kernels::lut_gemm_codes_into(
                &l.codes,
                &l.codebook,
                l.n,
                x,
                sc,
                out,
            ),
            LinearPlan::CodesSparse(l, sp) => {
                kernels::lut_gemm_codes_into(
                    &l.codes,
                    &l.codebook,
                    l.n,
                    x,
                    sc,
                    out,
                );
                sp.spmm_add(x, out);
            }
        }
    }

    /// Weight bytes this linear streams per step.
    fn bytes_per_step(&self) -> usize {
        match self {
            LinearPlan::Fp(t) => t.data.len() * 4,
            LinearPlan::DenseRef(w) => w.data.len() * 4,
            LinearPlan::Packed(pl) => pl.bytes_per_decode(),
            LinearPlan::PackedSparse(pl, sp) => {
                pl.bytes_per_decode() + sp.storage_bytes()
            }
            // one byte per code + f32 codebook
            LinearPlan::Codes(l) => l.m * l.n + l.m * l.k() * 4,
            LinearPlan::CodesSparse(l, sp) => {
                l.m * l.n + l.m * l.k() * 4 + sp.storage_bytes()
            }
        }
    }
}

/// Resolved per-layer decode plan: layernorm/bias slices and linear
/// implementations, indexed — no name lookups or `format!` per step.
struct LayerPlan<'w> {
    ln1_g: &'w [f32],
    ln1_b: &'w [f32],
    ln2_g: &'w [f32],
    ln2_b: &'w [f32],
    /// canonical order wq, wk, wv, wo, w1, w2
    linears: Vec<LinearPlan<'w>>,
    biases: Vec<&'w [f32]>,
}

/// Preallocated per-step scratch: activation/projection matrices, the
/// K/V gather buffers, attention job rows, and the LUT kernel scratch.
/// Reused across layers and steps — the batched hot loop performs no
/// per-step heap allocation beyond the returned logits rows and the
/// kernels' small per-thread bucket blocks.
struct BatchScratch {
    x: Mat,
    a: Mat,
    q: Mat,
    k: Mat,
    v: Mat,
    att: Mat,
    o: Mat,
    h1: Mat,
    h2: Mat,
    logits: Mat,
    /// gathered K/V history, (seq, head)-major, strided by the batch's
    /// longest sequence
    kg: Vec<f32>,
    vg: Vec<f32>,
    /// attention job rows: `[b*h, hd + max_rows]` = output accumulator
    /// + scores
    jb: Vec<f32>,
    pos: Vec<usize>,
    lut: LutScratch,
}

impl BatchScratch {
    fn new() -> BatchScratch {
        let z = || Mat::zeros(0, 0);
        BatchScratch {
            x: z(),
            a: z(),
            q: z(),
            k: z(),
            v: z(),
            att: z(),
            o: z(),
            h1: z(),
            h2: z(),
            logits: z(),
            kg: Vec::new(),
            vg: Vec::new(),
            jb: Vec::new(),
            pos: Vec::new(),
            lut: LutScratch::new(),
        }
    }
}

/// Batched decode engine: weights resolved, packed, and interned once,
/// then every [`decode_step_batch`] advances all sequences through each
/// layer together so the quantized weights stream once per token-step
/// instead of once per sequence.
pub struct DecodeEngine<'w> {
    cfg: ModelConfig,
    /// token embedding, borrowed — doubles as the tied head weight
    /// (`Tensor::as_mat` clones per call; the engine never does)
    tok_emb: &'w Tensor,
    pos_emb: &'w [f32],
    ln_f_g: &'w [f32],
    ln_f_b: &'w [f32],
    layers: Vec<LayerPlan<'w>>,
    scratch: BatchScratch,
}

impl<'w> DecodeEngine<'w> {
    pub fn new(w: &Weights<'w>) -> DecodeEngine<'w> {
        let store = w.store();
        let cfg = store.cfg;
        let layers = LayerKeys::build(cfg.layers)
            .iter()
            .map(|key| LayerPlan {
                ln1_g: store.vec(&key.ln1_g),
                ln1_b: store.vec(&key.ln1_b),
                ln2_g: store.vec(&key.ln2_g),
                ln2_b: store.vec(&key.ln2_b),
                linears: key
                    .lin
                    .iter()
                    .map(|(wn, _)| plan_linear(w, wn))
                    .collect(),
                biases: key.lin.iter().map(|(_, bn)| store.vec(bn)).collect(),
            })
            .collect();
        DecodeEngine {
            cfg,
            tok_emb: store.get("tok_emb"),
            pos_emb: &store.get("pos_emb").data,
            ln_f_g: store.vec("ln_f_g"),
            ln_f_b: store.vec("ln_f_b"),
            layers,
            scratch: BatchScratch::new(),
        }
    }

    pub fn cfg(&self) -> ModelConfig {
        self.cfg
    }

    /// Weight bytes streamed per batched step (each linear exactly once,
    /// regardless of batch size — the memory-bound quantity).
    pub fn weight_bytes_per_step(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|l| l.linears.iter())
            .map(|p| p.bytes_per_step())
            .sum()
    }
}

fn plan_linear<'w>(w: &Weights<'w>, name: &str) -> LinearPlan<'w> {
    match *w {
        Weights::Fp(s) => LinearPlan::Fp(s.get(name)),
        Weights::Quant(q) => match q.linears.get(name) {
            Some(LayerWeights::Dense(m)) => LinearPlan::DenseRef(m),
            Some(LayerWeights::Lut(l)) if l.bits <= 4 => {
                LinearPlan::Packed(PackedLut::pack(l))
            }
            Some(LayerWeights::Lut(l)) => LinearPlan::Codes(l),
            Some(LayerWeights::LutSparse(l, sp)) if l.bits <= 4 => {
                LinearPlan::PackedSparse(PackedLut::pack(l), sp)
            }
            Some(LayerWeights::LutSparse(l, sp)) => {
                LinearPlan::CodesSparse(l, sp)
            }
            None => LinearPlan::Fp(q.base.get(name)),
        },
    }
}

/// One decode step advancing a whole batch of sequences through each
/// layer together. Every linear runs as a single `[b, n]` matmul (or
/// packed LUT-mpGEMM), attention runs one job per (sequence, head)
/// against that sequence's own cache history, and the per-sequence op
/// order is identical to [`decode_step_kv`] — so for dense (f32) KV
/// stores the logits are bit-identical to the sequential path at any
/// batch size or thread count.
pub fn decode_step_batch(
    engine: &mut DecodeEngine,
    toks: &[i32],
    seqs: &mut dyn SeqAccess,
) -> Vec<Vec<f32>> {
    let b = toks.len();
    assert_eq!(seqs.count(), b, "one token per sequence");
    if b == 0 {
        return Vec::new();
    }
    let cfg = engine.cfg;
    let (d, h, hd) = (cfg.d, cfg.heads, cfg.head_dim());
    let scale = 1.0 / (hd as f32).sqrt();
    let DecodeEngine {
        tok_emb,
        pos_emb,
        ln_f_g,
        ln_f_b,
        layers,
        scratch,
        ..
    } = engine;
    let BatchScratch {
        x,
        a,
        q,
        k,
        v,
        att,
        o,
        h1,
        h2,
        logits,
        kg,
        vg,
        jb,
        pos,
        lut,
    } = scratch;

    pos.clear();
    for i in 0..b {
        let mut p = 0usize;
        seqs.with_seq(i, &mut |s| p = s.pos());
        assert!(p < cfg.ctx, "context overflow");
        pos.push(p);
    }

    // token + position embeddings
    x.reset(b, d);
    for (i, (&t, row)) in
        toks.iter().zip(x.data.chunks_mut(d)).enumerate()
    {
        let te = &tok_emb.data[(t as usize) * d..(t as usize + 1) * d];
        let pe = &pos_emb[pos[i] * d..(pos[i] + 1) * d];
        for (xo, (&e1, &e2)) in row.iter_mut().zip(te.iter().zip(pe)) {
            *xo = e1 + e2;
        }
    }

    // gather/job strides sized to the longest sequence in *this* batch
    // (not ctx), so short batches keep the scratch arena small and the
    // copies cache-resident; Vec::resize retains the high-water
    // allocation across steps
    let max_rows = pos.iter().map(|&p| p + 1).max().expect("b > 0");
    let gstride = max_rows * hd; // per-(seq, head) gather region
    let jstride = hd + max_rows; // job row: out accumulator + scores
    kg.resize(b * h * gstride, 0.0);
    vg.resize(b * h * gstride, 0.0);
    jb.resize(b * h * jstride, 0.0);

    for (li, lp) in layers.iter().enumerate() {
        a.copy_from(x);
        layer_norm_rows(a, lp.ln1_g, lp.ln1_b);
        q.reset(b, d);
        lp.linears[0].apply(a, lut, q);
        add_bias(q, lp.biases[0]);
        k.reset(b, d);
        lp.linears[1].apply(a, lut, k);
        add_bias(k, lp.biases[1]);
        v.reset(b, d);
        lp.linears[2].apply(a, lut, v);
        add_bias(v, lp.biases[2]);

        // append this step's K/V rows, then gather each sequence's
        // history (including the just-written position) so the math
        // below can run thread-parallel over plain buffers
        for i in 0..b {
            let rows = pos[i] + 1;
            let (kx, vx) = (k.row(i), v.row(i));
            seqs.with_seq(i, &mut |s| {
                for hi in 0..h {
                    s.write(
                        li,
                        hi,
                        &kx[hi * hd..(hi + 1) * hd],
                        &vx[hi * hd..(hi + 1) * hd],
                    );
                }
                for hi in 0..h {
                    let g = (i * h + hi) * gstride;
                    s.read_k_rows(li, hi, 0, rows, &mut kg[g..g + rows * hd]);
                    s.read_v_rows(li, hi, 0, rows, &mut vg[g..g + rows * hd]);
                }
            });
        }

        // attention: one job per (sequence, head); each job owns a
        // disjoint row of jb = [out accumulator | scores]
        let att_ops =
            pos.iter().map(|&p| (p + 1) * hd * 2).sum::<usize>() * h;
        let threads = pool::threads_for(att_ops);
        let qref: &Mat = q;
        let kgr: &[f32] = kg;
        let vgr: &[f32] = vg;
        let posr: &[usize] = pos;
        pool::par_rows_mut(
            &mut jb[..b * h * jstride],
            jstride,
            threads,
            |row0, chunk| {
                for (r, jrow) in chunk.chunks_mut(jstride).enumerate() {
                    let ji = row0 + r;
                    let (i, hi) = (ji / h, ji % h);
                    let rows = posr[i] + 1;
                    let (orow, rest) = jrow.split_at_mut(hd);
                    let scores = &mut rest[..rows];
                    let qrow = &qref.row(i)[hi * hd..(hi + 1) * hd];
                    let kbase = &kgr[ji * gstride..ji * gstride + rows * hd];
                    for (sj, sc) in scores.iter_mut().enumerate() {
                        *sc = tensor::dot(qrow, &kbase[sj * hd..(sj + 1) * hd])
                            * scale;
                    }
                    tensor::softmax(scores);
                    orow.fill(0.0);
                    let vbase = &vgr[ji * gstride..ji * gstride + rows * hd];
                    for (sj, &w_att) in scores.iter().enumerate() {
                        let vr = &vbase[sj * hd..(sj + 1) * hd];
                        for (ov, &vv) in orow.iter_mut().zip(vr) {
                            *ov += w_att * vv;
                        }
                    }
                }
            },
        );
        att.reset(b, d);
        for ji in 0..b * h {
            let (i, hi) = (ji / h, ji % h);
            att.row_mut(i)[hi * hd..(hi + 1) * hd]
                .copy_from_slice(&jb[ji * jstride..ji * jstride + hd]);
        }

        o.reset(b, d);
        lp.linears[3].apply(att, lut, o);
        add_bias(o, lp.biases[3]);
        x.add_assign(o);
        a.copy_from(x);
        layer_norm_rows(a, lp.ln2_g, lp.ln2_b);
        h1.reset(b, cfg.ff);
        lp.linears[4].apply(a, lut, h1);
        add_bias(h1, lp.biases[4]);
        gelu_tanh(&mut h1.data);
        h2.reset(b, d);
        lp.linears[5].apply(h1, lut, h2);
        add_bias(h2, lp.biases[5]);
        x.add_assign(h2);
    }

    for i in 0..b {
        seqs.with_seq(i, &mut |s| s.advance());
    }

    layer_norm_rows(x, ln_f_g, ln_f_b);
    // tied head straight off the borrowed embedding tensor
    logits.reset(b, tok_emb.shape[0]);
    tensor::matmul_tb_slice_into(x, &tok_emb.data, tok_emb.shape[0], logits);
    logits
        .data
        .chunks_exact(logits.cols)
        .map(|r| r.to_vec())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::WeightStore;
    use crate::util::prop;

    fn micro() -> WeightStore {
        let cfg = ModelConfig::builtin("opt-micro").unwrap();
        WeightStore::random("t", cfg, 11)
    }

    #[test]
    fn forward_shapes_and_finite() {
        let s = micro();
        let toks = vec![vec![1, 2, 3, 4, 5], vec![9, 8, 7, 6, 5]];
        let logits = forward_full(&Weights::Fp(&s), &toks, None);
        assert_eq!(logits.rows, 10);
        assert_eq!(logits.cols, 256);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn decode_matches_full_forward() {
        let s = micro();
        let w = Weights::Fp(&s);
        let seq: Vec<i32> = vec![10, 65, 97, 32, 101, 120, 5];
        let logits_full = forward_full(&w, &[seq.clone()], None);
        let mut cache = KvCache::new(s.cfg);
        let mut last = Vec::new();
        for &t in &seq {
            last = decode_step(&w, t, &mut cache);
        }
        let expect = logits_full.row(seq.len() - 1);
        assert!(
            prop::all_close(&last, expect, 1e-3, 1e-3),
            "maxdiff {}",
            prop::max_abs_diff(&last, expect)
        );
    }

    #[test]
    fn nll_positive_and_batch_additive() {
        let s = micro();
        let w = Weights::Fp(&s);
        let a = vec![vec![1, 2, 3, 4]];
        let b = vec![vec![5, 6, 7, 8]];
        let both = vec![a[0].clone(), b[0].clone()];
        let n_a = nll_sum(&w, &a);
        let n_b = nll_sum(&w, &b);
        let n_ab = nll_sum(&w, &both);
        assert!(n_a > 0.0 && n_b > 0.0);
        assert!(
            prop::close(n_ab, n_a + n_b, 1e-4, 1e-3),
            "{} vs {}",
            n_ab,
            n_a + n_b
        );
    }

    #[test]
    fn observer_sees_every_linear() {
        let s = micro();
        let mut seen = std::collections::BTreeSet::new();
        let mut obs = |name: &str, x: &Mat| {
            assert!(x.rows > 0);
            seen.insert(name.to_string());
        };
        forward_full(&Weights::Fp(&s), &[vec![1, 2, 3]], Some(&mut obs));
        assert_eq!(seen.len(), s.cfg.layers * 6);
        assert!(seen.contains("l0.wq") && seen.contains("l1.w2"));
    }

    #[test]
    fn generate_respects_ctx() {
        let s = micro();
        let w = Weights::Fp(&s);
        let prompt: Vec<i32> = (0..120).map(|i| i % 256).collect();
        let out = generate_greedy(&w, &prompt, 50);
        assert!(out.len() <= s.cfg.ctx - prompt.len());
    }

    #[test]
    fn batched_decode_matches_sequential_bitwise() {
        let s = micro();
        let w = Weights::Fp(&s);
        // ragged warmup through the sequential path
        let prompts: [&[i32]; 3] = [&[1, 2, 3], &[9], &[5, 6, 7, 8, 20]];
        let mut caches: Vec<KvCache> =
            prompts.iter().map(|_| KvCache::new(s.cfg)).collect();
        for (p, c) in prompts.iter().zip(&mut caches) {
            for &t in *p {
                decode_step_kv(&w, t, c);
            }
        }
        let toks = [11i32, 22, 33];
        let mut seq_caches = caches.clone();
        let seq_logits: Vec<Vec<f32>> = toks
            .iter()
            .zip(&mut seq_caches)
            .map(|(&t, c)| decode_step_kv(&w, t, c))
            .collect();

        let mut engine = DecodeEngine::new(&w);
        let mut refs: Vec<&mut dyn KvSeq> = caches
            .iter_mut()
            .map(|c| c as &mut dyn KvSeq)
            .collect();
        let got =
            decode_step_batch(&mut engine, &toks, &mut SeqRefs(&mut refs));
        assert_eq!(got, seq_logits, "batched logits must be bit-identical");

        // the cache state written by the batched step must match too:
        // one more sequential step on both sides agrees
        for (c_b, c_s) in caches.iter_mut().zip(&mut seq_caches) {
            let a = decode_step_kv(&w, 40, c_b);
            let b = decode_step_kv(&w, 40, c_s);
            assert_eq!(a, b, "cache divergence after batched step");
        }
    }

    #[test]
    fn batched_decode_batch_of_one_matches() {
        let s = micro();
        let w = Weights::Fp(&s);
        let mut engine = DecodeEngine::new(&w);
        let mut c_batch = KvCache::new(s.cfg);
        let mut c_seq = KvCache::new(s.cfg);
        for &t in &[7i32, 3, 250, 0] {
            let seq = decode_step_kv(&w, t, &mut c_seq);
            let mut refs: Vec<&mut dyn KvSeq> = vec![&mut c_batch];
            let got =
                decode_step_batch(&mut engine, &[t], &mut SeqRefs(&mut refs));
            assert_eq!(got[0], seq);
        }
    }

    #[test]
    fn decode_engine_weight_bytes_accounting() {
        let s = micro();
        let w = Weights::Fp(&s);
        let engine = DecodeEngine::new(&w);
        let expect: usize = s
            .cfg
            .linear_shapes()
            .iter()
            .map(|(_, m, n)| m * n * 4)
            .sum();
        assert_eq!(engine.weight_bytes_per_step(), expect);
    }

    #[test]
    fn seq_decoder_matches_one_shot_steps() {
        let s = micro();
        let w = Weights::Fp(&s);
        let mut dec = SeqDecoder::new(w);
        let mut c1 = KvCache::new(s.cfg);
        let mut c2 = KvCache::new(s.cfg);
        for &t in &[4i32, 99, 1, 255] {
            let a = dec.step(t, &mut c1);
            let b = decode_step_kv(&w, t, &mut c2);
            assert_eq!(a, b, "hoisted-scratch decoder must be bitwise");
        }
    }

    #[test]
    fn quantized_identity_matches_fp() {
        // a QuantizedModel whose linears are the exact FP weights must give
        // identical logits
        let s = micro();
        let mut linears = std::collections::BTreeMap::new();
        for (name, _m, _n) in s.cfg.linear_shapes() {
            linears.insert(
                name.clone(),
                crate::model::LayerWeights::Dense(s.mat(&name)),
            );
        }
        let qm = crate::model::QuantizedModel {
            base: s.clone(),
            method: "identity".into(),
            bits: 16,
            linears,
            weight_bits: 0,
        };
        let toks = vec![vec![3, 1, 4, 1, 5]];
        let l1 = forward_full(&Weights::Fp(&s), &toks, None);
        let l2 = forward_full(&Weights::Quant(&qm), &toks, None);
        assert!(prop::all_close(&l1.data, &l2.data, 1e-5, 1e-5));
    }
}
