//! Storage accounting — regenerates Table 1 (FP16 vs basic uniform vs
//! LUT-based, per weight matrix) and whole-model weight-memory figures
//! for Table 6's peak-memory column.

use crate::model::{ModelConfig, QuantizedModel};

/// Table 1 theory rows, in bits, for an m x n matrix.
pub fn fp16_bits(m: usize, n: usize) -> usize {
    16 * m * n
}

pub fn uniform_bits(m: usize, n: usize, bits: usize) -> usize {
    bits * m * n + m * 2 * 16 // scale + zero per channel (fp16)
}

pub fn lut_bits(m: usize, n: usize, bits: usize) -> usize {
    bits * m * n + m * (1 << bits) * 16 // codebook per channel (fp16)
}

/// Percentage vs FP16 (the numbers printed in Table 1).
pub fn pct_of_fp16(total_bits: usize, m: usize, n: usize) -> f64 {
    100.0 * total_bits as f64 / fp16_bits(m, n) as f64
}

/// Whole-model weight memory in bytes for a quantized model: quantized
/// linears at their stored size + FP16 for everything else (embeddings,
/// layernorms, biases) — matching the deployment the paper profiles.
pub fn model_weight_bytes(qm: &QuantizedModel) -> usize {
    let mut bits = qm.weight_bits;
    let quant_names: std::collections::BTreeSet<_> =
        qm.linears.keys().cloned().collect();
    for (name, t) in &qm.base.tensors {
        if !quant_names.contains(name) {
            bits += t.data.len() * 16;
        }
    }
    bits.div_ceil(8)
}

pub fn fp16_model_bytes(cfg: &ModelConfig) -> usize {
    cfg.n_params() * 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_percentages() {
        // paper Table 1: 4-bit values
        for (mn, uni_pct, lut_pct) in [
            (2048usize, 25.10, 25.78),
            (4096, 25.05, 25.39),
            (8192, 25.02, 25.20),
        ] {
            let u = pct_of_fp16(uniform_bits(mn, mn, 4), mn, mn);
            let l = pct_of_fp16(lut_bits(mn, mn, 4), mn, mn);
            assert!((u - uni_pct).abs() < 0.02, "uniform {} vs {}", u, uni_pct);
            assert!((l - lut_pct).abs() < 0.02, "lut {} vs {}", l, lut_pct);
        }
    }

    #[test]
    fn lut_overhead_is_small() {
        // difference between LUT and basic uniform < 0.8% of FP16 at 2048
        let mn = 2048;
        let diff = pct_of_fp16(lut_bits(mn, mn, 4), mn, mn)
            - pct_of_fp16(uniform_bits(mn, mn, 4), mn, mn);
        assert!(diff < 0.8);
    }

    #[test]
    fn fp16_model_bytes_sane() {
        let cfg = ModelConfig::builtin("opt-small").unwrap();
        let b = fp16_model_bytes(&cfg);
        assert_eq!(b, cfg.n_params() * 2);
        assert!(b > 1_000_000); // opt-small ~0.9M params
    }
}
