//! Model substrate: configs (mirroring python/compile/model.py CONFIGS),
//! the FP32 weight store loaded from `artifacts/weights/`, quantized
//! stores, and storage accounting (Table 1).

pub mod forward;
pub mod storage;

use std::collections::BTreeMap;
use std::path::Path;

use crate::quant::{BitPlaneStore, LutLayer, QuantResult};
use crate::sparse::Csr;
use crate::tensor::Mat;
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelConfig {
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub ff: usize,
    pub ctx: usize,
    pub vocab: usize,
    /// Optional end-of-sequence token id. The byte-level builtin configs
    /// have none; manifest configs may declare one (`"eos"`), and the
    /// serving stop criteria pick it up as an implicit stop token.
    pub eos: Option<i32>,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d / self.heads
    }

    pub fn from_json(j: &Json) -> Option<ModelConfig> {
        Some(ModelConfig {
            d: j.get("d")?.as_usize()?,
            layers: j.get("layers")?.as_usize()?,
            heads: j.get("heads")?.as_usize()?,
            ff: j.get("ff")?.as_usize()?,
            ctx: j.get("ctx")?.as_usize()?,
            vocab: j.get("vocab")?.as_usize()?,
            eos: j.get("eos").and_then(|e| e.as_usize()).map(|e| e as i32),
        })
    }

    /// Built-in fallback configs (match python CONFIGS) so unit tests run
    /// without artifacts.
    pub fn builtin(name: &str) -> Option<ModelConfig> {
        let (d, layers, heads, ff, ctx) = match name {
            "opt-micro" => (64, 2, 2, 256, 128),
            "opt-mini" | "opt-mini-instruct" => (96, 3, 4, 384, 128),
            "opt-small" | "opt-small-instruct" => (128, 4, 4, 512, 128),
            "opt-med" => (192, 6, 6, 768, 128),
            // long-context serving stand-in (TTFT benches at 2048-token
            // prompts on the AOT path); shares opt-mini's linear shapes
            "opt-longctx" => (96, 2, 4, 384, 2176),
            _ => return None,
        };
        Some(ModelConfig {
            d,
            layers,
            heads,
            ff,
            ctx,
            vocab: 256,
            eos: None,
        })
    }

    /// The six quantizable linears per layer, canonical order — mirrors
    /// python model.linear_shapes.
    pub fn linear_shapes(&self) -> Vec<(String, usize, usize)> {
        let mut out = Vec::new();
        for li in 0..self.layers {
            for nm in ["wq", "wk", "wv", "wo"] {
                out.push((format!("l{}.{}", li, nm), self.d, self.d));
            }
            out.push((format!("l{}.w1", li), self.ff, self.d));
            out.push((format!("l{}.w2", li), self.d, self.ff));
        }
        out
    }

    /// Canonical FP32 param spec (name, shape) — mirrors python
    /// model.param_spec; the AOT graphs consume weights in this order.
    pub fn param_spec(&self) -> Vec<(String, Vec<usize>)> {
        let d = self.d;
        let ff = self.ff;
        let mut spec: Vec<(String, Vec<usize>)> = vec![
            ("tok_emb".into(), vec![self.vocab, d]),
            ("pos_emb".into(), vec![self.ctx, d]),
        ];
        for li in 0..self.layers {
            let p = format!("l{}.", li);
            let mut push = |nm: &str, sh: Vec<usize>| {
                spec.push((format!("{}{}", p, nm), sh));
            };
            push("ln1_g", vec![d]);
            push("ln1_b", vec![d]);
            push("wq", vec![d, d]);
            push("bq", vec![d]);
            push("wk", vec![d, d]);
            push("bk", vec![d]);
            push("wv", vec![d, d]);
            push("bv", vec![d]);
            push("wo", vec![d, d]);
            push("bo", vec![d]);
            push("ln2_g", vec![d]);
            push("ln2_b", vec![d]);
            push("w1", vec![ff, d]);
            push("b1", vec![ff]);
            push("w2", vec![d, ff]);
            push("b2", vec![d]);
        }
        spec.push(("ln_f_g".into(), vec![d]));
        spec.push(("ln_f_b".into(), vec![d]));
        spec
    }

    pub fn n_params(&self) -> usize {
        self.param_spec().iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
}

/// A named tensor (row-major f32).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn as_mat(&self) -> Mat {
        assert_eq!(self.shape.len(), 2, "not a matrix: {:?}", self.shape);
        Mat::from_vec(self.shape[0], self.shape[1], self.data.clone())
    }
}

/// FP32 weight store for one model.
#[derive(Debug, Clone)]
pub struct WeightStore {
    pub name: String,
    pub cfg: ModelConfig,
    pub tensors: BTreeMap<String, Tensor>,
}

impl WeightStore {
    /// Load from artifacts/weights/<model>/ (weights.json + weights.bin).
    pub fn load(artifacts: &Path, name: &str, cfg: ModelConfig) -> Result<WeightStore, String> {
        let dir = artifacts.join("weights").join(name);
        let idx_txt = std::fs::read_to_string(dir.join("weights.json"))
            .map_err(|e| format!("read weights.json: {}", e))?;
        let idx = Json::parse(&idx_txt)?;
        let raw = std::fs::read(dir.join("weights.bin"))
            .map_err(|e| format!("read weights.bin: {}", e))?;
        let mut tensors = BTreeMap::new();
        for t in idx
            .get("tensors")
            .and_then(|t| t.as_arr())
            .ok_or("bad index")?
        {
            let name = t.get("name").and_then(|v| v.as_str()).ok_or("name")?;
            let shape =
                t.get("shape").and_then(|v| v.as_usize_vec()).ok_or("shape")?;
            let offset =
                t.get("offset").and_then(|v| v.as_usize()).ok_or("offset")?;
            let numel =
                t.get("numel").and_then(|v| v.as_usize()).ok_or("numel")?;
            if offset + numel * 4 > raw.len() {
                return Err(format!("tensor {} out of bounds", name));
            }
            let mut data = vec![0.0f32; numel];
            for (k, chunk) in
                raw[offset..offset + numel * 4].chunks_exact(4).enumerate()
            {
                data[k] =
                    f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
            tensors.insert(name.to_string(), Tensor { shape, data });
        }
        Ok(WeightStore { name: name.to_string(), cfg, tensors })
    }

    /// Random-initialized store (tests / fixtures without artifacts).
    pub fn random(name: &str, cfg: ModelConfig, seed: u64) -> WeightStore {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut tensors = BTreeMap::new();
        for (pname, shape) in cfg.param_spec() {
            let numel: usize = shape.iter().product();
            let base = pname.rsplit('.').next().unwrap();
            let data = if base.ends_with("_g") {
                vec![1.0; numel]
            } else if base.ends_with("_b") || base.starts_with('b') {
                vec![0.0; numel]
            } else {
                rng.normal_vec_f32(numel)
                    .into_iter()
                    .map(|v| v * 0.08)
                    .collect()
            };
            tensors.insert(pname, Tensor { shape, data });
        }
        WeightStore { name: name.to_string(), cfg, tensors }
    }

    pub fn get(&self, name: &str) -> &Tensor {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("missing tensor {}", name))
    }

    pub fn mat(&self, name: &str) -> Mat {
        self.get(name).as_mat()
    }

    pub fn vec(&self, name: &str) -> &[f32] {
        &self.get(name).data
    }

    pub fn fp_bits(&self) -> usize {
        // paper baseline is FP16 storage
        self.tensors.values().map(|t| t.data.len() * 16).sum()
    }
}

/// One quantized linear in a servable model.
#[derive(Debug, Clone)]
pub enum LayerWeights {
    Dense(Mat),
    Lut(LutLayer),
    LutSparse(LutLayer, Csr),
    /// Nested any-precision store: one resident artifact serving every
    /// width in `store.widths()` (dense form reads the max width).
    AnyPrec(BitPlaneStore),
}

impl LayerWeights {
    pub fn dense(&self) -> Mat {
        match self {
            LayerWeights::Dense(m) => m.clone(),
            LayerWeights::Lut(l) => l.dequant(),
            LayerWeights::LutSparse(l, s) => {
                let mut m = l.dequant();
                m.add_assign(&s.to_dense());
                m
            }
            LayerWeights::AnyPrec(b) => b.dequant_max(),
        }
    }

    pub fn from_result(r: &QuantResult) -> LayerWeights {
        match (&r.lut, &r.sparse) {
            (Some(l), Some(s)) => LayerWeights::LutSparse(l.clone(), s.clone()),
            (Some(l), None) => LayerWeights::Lut(l.clone()),
            _ => LayerWeights::Dense(r.w_hat.clone()),
        }
    }
}

/// A quantized model: FP parts from the base store + per-linear quantized
/// weights, plus bookkeeping for Table 1/6.
#[derive(Debug, Clone)]
pub struct QuantizedModel {
    pub base: WeightStore,
    pub method: String,
    pub bits: u8,
    pub linears: BTreeMap<String, LayerWeights>,
    pub weight_bits: usize,
}

impl QuantizedModel {
    /// Reconstructed dense weight for a linear (for the shared nll graph).
    pub fn dense_linear(&self, name: &str) -> Mat {
        match self.linears.get(name) {
            Some(lw) => lw.dense(),
            None => self.base.mat(name),
        }
    }

    /// Widths every quantized linear can serve: the intersection of the
    /// nested stores' width sets. Empty unless the model was quantized
    /// into the any-precision layout (`quantize_model_anyprec`).
    pub fn anyprec_widths(&self) -> Vec<u8> {
        let mut acc: Option<Vec<u8>> = None;
        for lw in self.linears.values() {
            let ws = match lw {
                LayerWeights::AnyPrec(b) => b.widths(),
                _ => return Vec::new(),
            };
            acc = Some(match acc {
                None => ws,
                Some(prev) => {
                    prev.into_iter().filter(|w| ws.contains(w)).collect()
                }
            });
        }
        acc.unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_configs_match_python() {
        let c = ModelConfig::builtin("opt-small").unwrap();
        assert_eq!((c.d, c.layers, c.heads, c.ff), (128, 4, 4, 512));
        assert_eq!(c.head_dim(), 32);
        assert_eq!(c.linear_shapes().len(), 6 * 4);
        assert!(ModelConfig::builtin("nope").is_none());
    }

    #[test]
    fn param_spec_counts() {
        let c = ModelConfig::builtin("opt-micro").unwrap();
        let spec = c.param_spec();
        // 2 emb + 16/layer * 2 + 2 final
        assert_eq!(spec.len(), 2 + 16 * 2 + 2);
        let n = c.n_params();
        // micro ~ 0.13M params
        assert!(n > 80_000 && n < 300_000, "{}", n);
    }

    #[test]
    fn random_store_has_all_params() {
        let c = ModelConfig::builtin("opt-micro").unwrap();
        let s = WeightStore::random("t", c, 1);
        for (name, shape) in c.param_spec() {
            let t = s.get(&name);
            assert_eq!(t.shape, shape);
        }
        // layernorm gains are 1
        assert!(s.vec("l0.ln1_g").iter().all(|&v| v == 1.0));
    }

    #[test]
    fn layer_weights_roundtrip() {
        let c = ModelConfig::builtin("opt-micro").unwrap();
        let s = WeightStore::random("t", c, 2);
        let w = s.mat("l0.wq");
        let lw = LayerWeights::Dense(w.clone());
        assert_eq!(lw.dense(), w);
    }
}
