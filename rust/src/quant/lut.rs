//! LUT-servable layer representation: per-row codebook T [m, 2^N] + codes
//! Q [m, n], with nibble packing (shared with the HLO serving graphs — see
//! python/compile/kernels/ref.py for the layout contract) and dense 3-bit
//! packing for the native path, plus the native LUT-mpGEMM used by the
//! fallback forward and the kernel benches.

use crate::tensor::Mat;

use super::Storage;

#[derive(Debug, Clone)]
pub struct LutLayer {
    pub m: usize,
    pub n: usize,
    pub bits: u8,
    /// codes, row-major [m * n], values in 0..2^bits
    pub codes: Vec<u8>,
    /// per-row codebook [m, 2^bits]
    pub codebook: Mat,
}

impl LutLayer {
    pub fn k(&self) -> usize {
        1usize << self.bits
    }

    pub fn code(&self, i: usize, j: usize) -> u8 {
        self.codes[i * self.n + j]
    }

    /// Reconstruct the dense W_hat.
    pub fn dequant(&self) -> Mat {
        let mut out = Mat::zeros(self.m, self.n);
        let k = self.k();
        for i in 0..self.m {
            let t = self.codebook.row(i);
            let row = out.row_mut(i);
            for (j, r) in row.iter_mut().enumerate() {
                let c = self.codes[i * self.n + j] as usize;
                debug_assert!(c < k);
                *r = t[c];
            }
        }
        out
    }

    /// Nibble packing: byte j holds columns 2j (low) and 2j+1 (high) —
    /// identical to ref.pack_nibbles, the layout the HLO graphs unpack.
    /// Odd n pads the final high nibble of each row with 0 (the HLO
    /// serving graphs only ever see even-n layers, where this is
    /// byte-identical to the python contract).
    pub fn packed_nibbles(&self) -> Vec<u8> {
        let rowb = self.n.div_ceil(2);
        let mut out = Vec::with_capacity(self.m * rowb);
        for i in 0..self.m {
            out.extend(pack_nibbles_flat(
                &self.codes[i * self.n..(i + 1) * self.n],
            ));
        }
        out
    }

    /// Inverse of [`packed_nibbles`](Self::packed_nibbles).
    pub fn unpack_nibbles(packed: &[u8], m: usize, n: usize) -> Vec<u8> {
        let rowb = n.div_ceil(2);
        assert_eq!(packed.len(), m * rowb);
        let mut out = vec![0u8; m * n];
        for i in 0..m {
            let row = &packed[i * rowb..(i + 1) * rowb];
            for j in 0..n {
                out[i * n + j] = nibble_at(row, j);
            }
        }
        out
    }

    /// Dense 3-bit packing: 8 codes -> 3 bytes per group, row-padded to a
    /// multiple of 8 — identical to ref.pack3.
    pub fn packed3(&self) -> Vec<u8> {
        assert!(self.bits == 3);
        let npad = self.n.div_ceil(8) * 8;
        let gbytes = npad / 8 * 3;
        let mut out = vec![0u8; self.m * gbytes];
        for i in 0..self.m {
            for g in 0..npad / 8 {
                let mut v: u32 = 0;
                for b in 0..8 {
                    let j = g * 8 + b;
                    let code = if j < self.n {
                        self.codes[i * self.n + j] as u32
                    } else {
                        0
                    };
                    v |= code << (3 * b);
                }
                out[i * gbytes + 3 * g] = (v & 0xFF) as u8;
                out[i * gbytes + 3 * g + 1] = ((v >> 8) & 0xFF) as u8;
                out[i * gbytes + 3 * g + 2] = ((v >> 16) & 0xFF) as u8;
            }
        }
        out
    }

    pub fn unpack3(packed: &[u8], m: usize, n: usize) -> Vec<u8> {
        let npad = n.div_ceil(8) * 8;
        let gbytes = npad / 8 * 3;
        assert_eq!(packed.len(), m * gbytes);
        let mut out = vec![0u8; m * n];
        for i in 0..m {
            for g in 0..npad / 8 {
                let v = packed[i * gbytes + 3 * g] as u32
                    | (packed[i * gbytes + 3 * g + 1] as u32) << 8
                    | (packed[i * gbytes + 3 * g + 2] as u32) << 16;
                for b in 0..8 {
                    let j = g * 8 + b;
                    if j < n {
                        out[i * n + j] = ((v >> (3 * b)) & 0x7) as u8;
                    }
                }
            }
        }
        out
    }

    /// Native LUT-based mpGEMM: y[p, m] = x[p, n] @ W_hat^T without ever
    /// materializing W_hat — mirrors the dequantization-free inference
    /// kernel (Fig. 1(a) right). Backed by the shared bucket kernel in
    /// [`crate::quant::kernels`]: one code scan per output channel fills
    /// all `p` batch lanes' buckets at once (instead of a bucket
    /// clear-and-rescan per output element), then one K-wide codebook dot
    /// per element. Bit-identical to the packed-code serving kernel.
    pub fn lut_matmul(&self, x: &Mat) -> Mat {
        let mut out = Mat::zeros(x.rows, self.m);
        let mut sc = super::kernels::LutScratch::new();
        super::kernels::lut_gemm_codes_into(
            &self.codes,
            &self.codebook,
            self.n,
            x,
            &mut sc,
            &mut out,
        );
        out
    }

    /// Storage accounting (Table 1 LUT row): N bits/code + fp16 codebook.
    pub fn storage(&self) -> Storage {
        Storage {
            code_bits: self.m * self.n * self.bits as usize,
            meta_bits: self.m * self.k() * 16,
            sparse_bits: 0,
        }
    }

    /// Weight bytes that must stream per token in decode (the memory-bound
    /// quantity behind the paper's speedup): packed codes + codebook.
    pub fn bytes_per_decode(&self) -> usize {
        let code_bytes = match self.bits {
            3 => self.m * (self.n.div_ceil(8) * 3),
            _ => self.m * self.n.div_ceil(2),
        };
        code_bytes + self.m * self.k() * 4
    }
}

/// Pack a flat code slice two-per-byte — low nibble first, the single
/// source of truth for the nibble layout (LutLayer rows and the KV-cache
/// block store both use it). Odd length pads the final high nibble with 0.
pub fn pack_nibbles_flat(codes: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; codes.len().div_ceil(2)];
    for (j2, b) in out.iter_mut().enumerate() {
        let lo = codes[2 * j2];
        let hi = if 2 * j2 + 1 < codes.len() { codes[2 * j2 + 1] } else { 0 };
        *b = lo | (hi << 4);
    }
    out
}

/// Code `j` of a flat nibble-packed buffer (inverse of
/// [`pack_nibbles_flat`]).
#[inline]
pub fn nibble_at(packed: &[u8], j: usize) -> u8 {
    let byte = packed[j / 2];
    if j % 2 == 0 {
        byte & 0x0F
    } else {
        byte >> 4
    }
}

/// Build a LutLayer from explicit parts (used by quantizers).
pub fn lut_from_parts(m: usize, n: usize, bits: u8, codes: Vec<u8>, codebook: Mat) -> LutLayer {
    assert_eq!(codes.len(), m * n);
    assert_eq!(codebook.rows, m);
    assert_eq!(codebook.cols, 1 << bits);
    LutLayer { m, n, bits, codes, codebook }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_lut(rng: &mut Rng, m: usize, n: usize, bits: u8) -> LutLayer {
        let k = 1usize << bits;
        let codes = (0..m * n).map(|_| rng.below(k as u64) as u8).collect();
        let codebook = Mat::from_vec(m, k, rng.normal_vec_f32(m * k));
        lut_from_parts(m, n, bits, codes, codebook)
    }

    #[test]
    fn nibble_pack_layout_matches_python_contract() {
        // byte j = lo | hi<<4 with lo = col 2j, hi = col 2j+1
        let codes = vec![1u8, 2, 3, 4];
        let l = lut_from_parts(1, 4, 4, codes, Mat::zeros(1, 16));
        assert_eq!(l.packed_nibbles(), vec![1 | 2 << 4, 3 | 4 << 4]);
    }

    #[test]
    fn pack3_roundtrip() {
        prop::check("pack3", 31, 12, |rng, _| {
            let m = 1 + rng.below(6) as usize;
            let n = 1 + rng.below(40) as usize;
            let l = random_lut(rng, m, n, 3);
            let packed = l.packed3();
            let back = LutLayer::unpack3(&packed, m, n);
            crate::prop_assert!(back == l.codes, "roundtrip failed");
            Ok(())
        });
    }

    #[test]
    fn nibble_pack_roundtrip() {
        // pack -> unpack -> identical codes, odd and even n
        prop::check("pack_nibbles", 33, 16, |rng, case| {
            let m = 1 + rng.below(6) as usize;
            // force odd n on half the cases so the padded tail is covered
            let mut n = 1 + rng.below(40) as usize;
            if case % 2 == 0 && n % 2 == 0 {
                n += 1;
            }
            let l = random_lut(rng, m, n, 4);
            let packed = l.packed_nibbles();
            crate::prop_assert!(
                packed.len() == m * n.div_ceil(2),
                "packed len {} for {}x{}",
                packed.len(),
                m,
                n
            );
            let back = LutLayer::unpack_nibbles(&packed, m, n);
            crate::prop_assert!(back == l.codes, "roundtrip failed");
            Ok(())
        });
    }

    #[test]
    fn pack3_odd_n_edge_cases() {
        // explicit odd-n shapes around the 8-code group boundary
        let mut rng = Rng::new(35);
        for n in [1usize, 7, 9, 15, 17, 23] {
            let l = random_lut(&mut rng, 3, n, 3);
            let back = LutLayer::unpack3(&l.packed3(), 3, n);
            assert_eq!(back, l.codes, "n={}", n);
        }
    }

    #[test]
    fn lut_matmul_equals_dequant_matmul() {
        prop::check("lut_matmul", 32, 8, |rng, _| {
            let m = 1 + rng.below(24) as usize;
            let n = 1 + rng.below(24) as usize;
            let p = 1 + rng.below(6) as usize;
            let bits = if rng.below(2) == 0 { 3 } else { 4 };
            let l = random_lut(rng, m, n, bits);
            let x = Mat::from_vec(p, n, rng.normal_vec_f32(p * n));
            let direct = x.matmul_tb(&l.dequant());
            let lutted = l.lut_matmul(&x);
            crate::prop_assert!(
                prop::all_close(&direct.data, &lutted.data, 1e-3, 1e-3),
                "maxdiff {}",
                prop::max_abs_diff(&direct.data, &lutted.data)
            );
            Ok(())
        });
    }

    #[test]
    fn storage_matches_table1_formula() {
        let l = random_lut(&mut Rng::new(3), 2048, 2048, 4);
        let st = l.storage();
        // theory: 0.5*m*n + 32*m bytes => ratio 25.78% (Table 1, row 1)
        let ratio = st.ratio_vs_fp16(2048, 2048);
        assert!((ratio - 0.2578).abs() < 0.001, "{}", ratio);
    }

    #[test]
    fn bytes_per_decode_3bit_smaller_than_4bit() {
        let mut rng = Rng::new(4);
        let l4 = random_lut(&mut rng, 128, 512, 4);
        let l3 = random_lut(&mut rng, 128, 512, 3);
        assert!(l3.bytes_per_decode() < l4.bytes_per_decode());
    }
}
