//! AWQ-like baseline (Lin et al., 2024): activation-aware per-input-channel
//! scaling before group-wise uniform quantization. Salient channels (large
//! activation magnitude) get scaled up so rounding error lands on channels
//! the layer output is least sensitive to; the inverse scale folds into the
//! (conceptual) preceding op. The scale exponent alpha is grid-searched
//! against the true layer objective tr(D H D^T), mirroring AWQ's search.

use crate::tensor::Mat;

use super::{rtn::Rtn, QuantResult, Quantizer};

#[derive(Debug, Clone)]
pub struct Awq {
    pub bits: u8,
    pub group: usize,
    pub n_grid: usize,
}

impl Awq {
    pub fn new(bits: u8, group: usize) -> Self {
        Awq { bits, group, n_grid: 12 }
    }
}

impl Quantizer for Awq {
    fn name(&self) -> String {
        format!("awq-g{}", self.group)
    }

    fn quantize(&self, w: &Mat, h: &Mat) -> QuantResult {
        let n = w.cols;
        // per-input-channel activation magnitude proxy: sqrt(E[x_j^2])
        // from the Gram diagonal
        let sx: Vec<f32> = (0..n)
            .map(|j| (h[(j, j)].max(1e-12)).sqrt())
            .collect();
        let inner = Rtn::grouped(self.bits, self.group);

        let mut best: Option<(f64, QuantResult)> = None;
        for gi in 0..self.n_grid {
            let alpha = gi as f32 / (self.n_grid - 1) as f32; // 0..1
            // scale columns: w'_j = w_j * s_j^alpha; dequant divides back
            let mut ws = w.clone();
            let scales: Vec<f32> =
                sx.iter().map(|&s| s.powf(alpha).max(1e-6)).collect();
            for i in 0..w.rows {
                let row = ws.row_mut(i);
                for (v, &s) in row.iter_mut().zip(&scales) {
                    *v *= s;
                }
            }
            let mut r = inner.quantize(&ws, h);
            for i in 0..w.rows {
                let row = r.w_hat.row_mut(i);
                for (v, &s) in row.iter_mut().zip(&scales) {
                    *v /= s;
                }
            }
            let err = crate::tensor::linalg::layer_error(w, &r.w_hat, h);
            if best.as_ref().map(|(e, _)| err < *e).unwrap_or(true) {
                r.method = self.name();
                // fp16 per-channel scales add to metadata storage
                r.storage.meta_bits += n * 16;
                best = Some((err, r));
            }
        }
        best.unwrap().1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn skewed_problem(rng: &mut Rng, m: usize, n: usize) -> (Mat, Mat) {
        let w = Mat::from_vec(m, n, rng.normal_vec_f32(m * n));
        // strongly anisotropic activations: a few salient channels
        let p = 3 * n;
        let mut x = Mat::from_vec(n, p, rng.normal_vec_f32(n * p));
        for j in 0..n / 8 {
            let scale = 8.0;
            for v in x.row_mut(j) {
                *v *= scale;
            }
        }
        (w, x.gram())
    }

    #[test]
    fn beats_plain_grouped_rtn_on_skewed_activations() {
        let mut rng = Rng::new(71);
        let (w, h) = skewed_problem(&mut rng, 16, 64);
        let e_awq = Awq::new(3, 16).quantize(&w, &h).layer_error(&w, &h);
        let e_rtn =
            Rtn::grouped(3, 16).quantize(&w, &h).layer_error(&w, &h);
        assert!(e_awq <= e_rtn, "awq {} !<= rtn {}", e_awq, e_rtn);
    }

    #[test]
    fn alpha_zero_is_in_grid_so_never_worse_than_inner() {
        // the grid includes alpha=0 (identity scaling), so AWQ can never
        // be worse than its inner quantizer on the same objective
        let mut rng = Rng::new(72);
        let (w, h) = skewed_problem(&mut rng, 8, 32);
        let e_awq = Awq::new(4, 8).quantize(&w, &h).layer_error(&w, &h);
        let e_rtn = Rtn::grouped(4, 8).quantize(&w, &h).layer_error(&w, &h);
        assert!(e_awq <= e_rtn + 1e-9);
    }

    #[test]
    fn finite_with_zero_activation_channels() {
        let mut rng = Rng::new(73);
        let w = Mat::from_vec(4, 16, rng.normal_vec_f32(64));
        let mut x = Mat::from_vec(16, 32, rng.normal_vec_f32(512));
        for v in x.row_mut(0) {
            *v = 0.0; // dead channel -> H[0,0] = 0
        }
        let h = x.gram();
        let r = Awq::new(4, 8).quantize(&w, &h);
        assert!(r.w_hat.data.iter().all(|v| v.is_finite()));
    }
}
