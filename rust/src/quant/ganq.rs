//! GANQ — the paper's contribution (§3, Algorithm 1), native Rust
//! implementation used by the quantization pipeline (the AOT HLO variant of
//! the same algorithm, with the L1 Pallas step kernel inside, lives in
//! runtime/ and is cross-validated against this one).
//!
//! Per layer: precondition H for diagonal dominance (eq. 23-24), factor
//! H' = L L^T, then alternate
//!   S-step: back-substitution over columns n-1..0, all rows in parallel
//!           (eq. 18/21/22 — rows are the paper's "GPU-adaptive" axis; here
//!           they map to worker threads),
//!   T-step: closed-form per-row codebook update via a regularized 2^N x
//!           2^N SPD solve (eq. 7).
//! Initialization T^0 is the RTN uniform grid; empty codebook buckets keep
//! their previous codeword (robustness tweak documented in DESIGN.md).

use crate::tensor::{linalg, Mat};
use crate::util::pool;

use super::{lut::lut_from_parts, rtn, QuantResult, Quantizer};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Precond {
    /// Adaptive diagonal dominance (paper eq. 23-24, the default).
    Adaptive,
    /// Fixed lambda*I (Remark 3.1) — the Table 7 ablation arm.
    Lambda(f64),
}

/// Codebook initialization T^0 (ablation; the paper does not specify —
/// we default to the RTN uniform grid so iteration 0 reproduces the
/// baseline and every GANQ iteration strictly improves on it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Init {
    RtnGrid,
    /// sensitivity-weighted k-means (SqueezeLLM-style) as the starting
    /// codebook, then refined by the alternating iterations
    Kmeans,
}

#[derive(Debug, Clone)]
pub struct Ganq {
    pub bits: u8,
    pub iters: usize,
    pub precond: Precond,
    pub init: Init,
    /// record per-iteration layer error (costs one extra O(m n^2) pass per
    /// iteration; it feeds the monotonicity property test and the
    /// ablation bench)
    pub track_error: bool,
}

impl Ganq {
    pub fn new(bits: u8) -> Self {
        Ganq {
            bits,
            iters: 10,
            precond: Precond::Adaptive,
            init: Init::RtnGrid,
            track_error: false,
        }
    }

    pub fn with_iters(bits: u8, iters: usize) -> Self {
        Ganq { iters, ..Ganq::new(bits) }
    }

    pub fn with_precond(bits: u8, precond: Precond) -> Self {
        Ganq { precond, ..Ganq::new(bits) }
    }

    pub fn with_init(bits: u8, init: Init) -> Self {
        Ganq { init, ..Ganq::new(bits) }
    }
}

/// Full solver output (richer than QuantResult; used by ablations).
pub struct GanqSolution {
    pub codes: Vec<u8>,
    pub codebook: Mat,
    pub errors: Vec<f64>,
}

/// One batched S-step (all rows, threaded). `l` is the lower Cholesky
/// factor; codebook `t` is [m, K]. Returns codes [m * n].
pub fn sstep(w: &Mat, l: &Mat, t: &Mat, threads: usize) -> Vec<u8> {
    let (m, n) = (w.rows, w.cols);
    let k = t.cols;
    let mut codes = vec![0u8; m * n];
    // Each thread owns a contiguous row range and runs the full j loop;
    // acc is the per-row residual accumulator (acc[j] collects
    // sum_{u>j} r_u L[u, j], built incrementally as r_u become known).
    let ldiag: Vec<f32> = (0..n).map(|j| l[(j, j)]).collect();
    pool::par_rows_mut(&mut codes, n, threads, |row0, chunk| {
        let rows = chunk.len() / n;
        let mut acc = vec![0.0f32; rows * n];
        for j in (0..n).rev() {
            let lrow = l.row(j);
            let inv_ljj = 1.0 / ldiag[j];
            for ri in 0..rows {
                let i = row0 + ri;
                let wrow = w.row(i);
                let trow = t.row(i);
                let a = &mut acc[ri * n..(ri + 1) * n];
                let e = wrow[j] + a[j] * inv_ljj;
                // argmin_s |e - T_s| (K <= 16: linear scan beats branchy
                // binary search on unsorted codebooks)
                let mut best = 0usize;
                let mut bestd = f32::INFINITY;
                for (s, &ts) in trow.iter().enumerate().take(k) {
                    let d = (e - ts).abs();
                    if d < bestd {
                        bestd = d;
                        best = s;
                    }
                }
                chunk[ri * n + j] = best as u8;
                let r = wrow[j] - trow[best];
                if r != 0.0 {
                    // acc[0..j] += r * L[j, 0..j] (row j of L is zero
                    // beyond the diagonal)
                    for (av, &lv) in a[..j].iter_mut().zip(&lrow[..j]) {
                        *av += r * lv;
                    }
                }
            }
        }
    });
    codes
}

/// One batched T-step (eq. 7): per row solve (S H S^T) t = S H W^T with
/// regularization; empty buckets keep previous codewords.
pub fn tstep(
    w: &Mat,
    h: &Mat,
    codes: &[u8],
    t_prev: &Mat,
    threads: usize,
) -> Mat {
    let n = w.cols;
    let k = t_prev.cols;
    let mut t_new = t_prev.clone();
    pool::par_rows_mut(&mut t_new.data, k, threads, |row0, chunk| {
        let mut b_mat = vec![0.0f64; k * n]; // B[s, j'] = sum_{j in s} H[j, j']
        let mut a = vec![0.0f64; k * k];
        let mut num = vec![0.0f64; k];
        let mut counts = vec![0usize; k];
        for (ri, trow) in chunk.chunks_mut(k).enumerate() {
            let i = row0 + ri;
            let crow = &codes[i * n..(i + 1) * n];
            let wrow = w.row(i);
            b_mat.iter_mut().for_each(|v| *v = 0.0);
            counts.iter_mut().for_each(|v| *v = 0);
            for (j, &c) in crow.iter().enumerate() {
                let s = c as usize;
                counts[s] += 1;
                let hrow = h.row(j);
                let brow = &mut b_mat[s * n..(s + 1) * n];
                for (bv, &hv) in brow.iter_mut().zip(hrow) {
                    *bv += hv as f64;
                }
            }
            // A[s, t] = sum_{j' in t} B[s, j'];  num[s] = B[s, :] . w
            a.iter_mut().for_each(|v| *v = 0.0);
            num.iter_mut().for_each(|v| *v = 0.0);
            for s in 0..k {
                let brow = &b_mat[s * n..(s + 1) * n];
                let mut dot = 0.0f64;
                for (j2, &bv) in brow.iter().enumerate() {
                    a[s * k + crow[j2] as usize] += bv;
                    dot += bv * wrow[j2] as f64;
                }
                num[s] = dot;
            }
            let tr: f64 = (0..k).map(|s| a[s * k + s]).sum();
            let eps = 1e-6 * (tr / k as f64).max(1e-12);
            if let Some(sol) = linalg::solve_spd_small(&a, k, &num, eps) {
                for s in 0..k {
                    if counts[s] > 0 && sol[s].is_finite() {
                        trow[s] = sol[s] as f32;
                    }
                }
            }
        }
    });
    t_new
}

/// Run the full solver on (W, raw H). Handles preconditioning + Cholesky.
pub fn solve(
    w: &Mat,
    h: &Mat,
    bits: u8,
    iters: usize,
    precond: Precond,
    track_error: bool,
) -> GanqSolution {
    solve_init(w, h, bits, iters, precond, Init::RtnGrid, track_error)
}

pub fn solve_init(
    w: &Mat,
    h: &Mat,
    bits: u8,
    iters: usize,
    precond: Precond,
    init: Init,
    track_error: bool,
) -> GanqSolution {
    let hp = match precond {
        Precond::Adaptive => linalg::precondition(h),
        Precond::Lambda(lam) => linalg::precondition_lambda(h, lam),
    };
    let l = match linalg::cholesky(&hp) {
        Some(l) => l,
        // fixed lambda too small: fall back to adaptive (Remark 3.1 notes
        // manual lambda selection can be suboptimal — this is why)
        None => linalg::cholesky(&linalg::precondition(&hp))
            .expect("adaptive preconditioning must yield SPD"),
    };
    let threads = pool::default_threads();
    let mut t = match init {
        Init::RtnGrid => rtn::rtn_codebook(w, bits).1,
        Init::Kmeans => {
            let k = 1usize << bits;
            let weights: Vec<f32> =
                (0..w.cols).map(|j| h[(j, j)].max(1e-12)).collect();
            let mut t = Mat::zeros(w.rows, k);
            for i in 0..w.rows {
                let (_, cents) =
                    crate::quant::squeezellm::weighted_kmeans_row(
                        w.row(i),
                        &weights,
                        k,
                        20,
                    );
                t.row_mut(i).copy_from_slice(&cents);
            }
            t
        }
    };
    let mut codes;
    let mut errors = Vec::new();
    for _ in 0..iters {
        codes = sstep(w, &l, &t, threads);
        t = tstep(w, &hp, &codes, &t, threads);
        if track_error {
            let w_hat = reconstruct(w.rows, w.cols, &codes, &t);
            errors.push(linalg::layer_error(w, &w_hat, &hp));
        }
    }
    // final S-step so codes are consistent with the last codebook
    codes = sstep(w, &l, &t, threads);
    GanqSolution { codes, codebook: t, errors }
}

/// Fit one K-entry non-uniform codebook to a flat value set: the
/// alternating solver specialized to an identity Hessian. With H = I the
/// S-step (eq. 18) degenerates to nearest-codeword assignment and the
/// T-step (eq. 7) to bucket means (empty buckets keep their codeword),
/// so both are computed directly in O(n * 2^bits) — no factor, no n x n
/// matrices. Used on the serving hot path by the KV-cache block store
/// (`kv::LutBlocks`), where values are consumed directly by attention
/// and no activation statistics exist. Close to
/// `squeezellm::weighted_kmeans_row` with uniform weights, but keeps
/// GANQ's T^0 convention (the RTN uniform grid) so iteration 0 exactly
/// reproduces the RTN assignment. Returns (codes, codebook[2^bits]).
pub fn fit_codebook_identity(
    vals: &[f32],
    bits: u8,
    iters: usize,
) -> (Vec<u8>, Vec<f32>) {
    let k = 1usize << bits;
    let mut t = rtn::rtn_codebook_row(vals, bits).1;
    let mut codes = vec![0u8; vals.len()];
    let assign = |t: &[f32], codes: &mut [u8]| {
        for (c, &v) in codes.iter_mut().zip(vals) {
            let mut best = 0usize;
            let mut bestd = f32::INFINITY;
            for (s, &ts) in t.iter().enumerate() {
                let d = (v - ts).abs();
                if d < bestd {
                    bestd = d;
                    best = s;
                }
            }
            *c = best as u8;
        }
    };
    assign(&t, &mut codes);
    for _ in 0..iters {
        let mut sum = vec![0.0f64; k];
        let mut cnt = vec![0usize; k];
        for (&c, &v) in codes.iter().zip(vals) {
            sum[c as usize] += v as f64;
            cnt[c as usize] += 1;
        }
        for s in 0..k {
            if cnt[s] > 0 {
                t[s] = (sum[s] / cnt[s] as f64) as f32;
            }
        }
        assign(&t, &mut codes);
    }
    (codes, t)
}

pub fn reconstruct(m: usize, n: usize, codes: &[u8], t: &Mat) -> Mat {
    let mut out = Mat::zeros(m, n);
    for i in 0..m {
        let trow = t.row(i);
        let crow = &codes[i * n..(i + 1) * n];
        for (o, &c) in out.row_mut(i).iter_mut().zip(crow) {
            *o = trow[c as usize];
        }
    }
    out
}

impl Quantizer for Ganq {
    fn name(&self) -> String {
        "ganq".to_string()
    }

    fn quantize(&self, w: &Mat, h: &Mat) -> QuantResult {
        let sol = solve_init(
            w,
            h,
            self.bits,
            self.iters,
            self.precond,
            self.init,
            self.track_error,
        );
        let w_hat = reconstruct(w.rows, w.cols, &sol.codes, &sol.codebook);
        let lut = lut_from_parts(
            w.rows,
            w.cols,
            self.bits,
            sol.codes,
            sol.codebook,
        );
        let storage = lut.storage();
        QuantResult {
            method: self.name(),
            bits: self.bits,
            w_hat,
            lut: Some(lut),
            sparse: None,
            storage,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::Rtn;
    use crate::quant::Quantizer;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn problem(rng: &mut Rng, m: usize, n: usize, p: usize) -> (Mat, Mat) {
        let w = Mat::from_vec(m, n, rng.normal_vec_f32(m * n));
        let x = Mat::from_vec(n, p, rng.normal_vec_f32(n * p));
        (w, x.gram())
    }

    #[test]
    fn beats_rtn_on_layer_error() {
        prop::check("ganq_beats_rtn", 51, 6, |rng, _| {
            let (w, h) = problem(rng, 24, 32, 80);
            for bits in [3u8, 4] {
                let e_g =
                    Ganq::new(bits).quantize(&w, &h).layer_error(&w, &h);
                let e_r = Rtn::new(bits).quantize(&w, &h).layer_error(&w, &h);
                crate::prop_assert!(
                    e_g < e_r,
                    "bits={} ganq {} !< rtn {}",
                    bits,
                    e_g,
                    e_r
                );
            }
            Ok(())
        });
    }

    #[test]
    fn iteration_error_monotone() {
        let mut rng = Rng::new(52);
        let (w, h) = problem(&mut rng, 16, 24, 64);
        let sol = solve(&w, &h, 3, 8, Precond::Adaptive, true);
        for win in sol.errors.windows(2) {
            assert!(
                win[1] <= win[0] * (1.0 + 1e-4) + 1e-6,
                "errors {:?}",
                sol.errors
            );
        }
    }

    #[test]
    fn matches_golden_fixture_if_present() {
        // full cross-language check lives in tests/golden.rs; here we only
        // pin internal self-consistency: reconstruct(dequant) == w_hat
        let mut rng = Rng::new(53);
        let (w, h) = problem(&mut rng, 8, 16, 48);
        let r = Ganq::new(4).quantize(&w, &h);
        let lut = r.lut.as_ref().unwrap();
        assert!(prop::all_close(
            &lut.dequant().data,
            &r.w_hat.data,
            1e-6,
            1e-6
        ));
    }

    #[test]
    fn more_iters_never_worse() {
        let mut rng = Rng::new(54);
        let (w, h) = problem(&mut rng, 16, 24, 64);
        let e1 = Ganq::with_iters(3, 1).quantize(&w, &h).layer_error(&w, &h);
        let e10 =
            Ganq::with_iters(3, 10).quantize(&w, &h).layer_error(&w, &h);
        assert!(e10 <= e1 * 1.001, "{} vs {}", e10, e1);
    }

    #[test]
    fn lambda_precond_close_to_adaptive() {
        // Table 7: quantization quality is largely insensitive to the
        // preconditioning strategy
        let mut rng = Rng::new(55);
        let (w, h) = problem(&mut rng, 16, 24, 64);
        let e_a = Ganq::new(4).quantize(&w, &h).layer_error(&w, &h);
        let e_l = Ganq::with_precond(4, Precond::Lambda(1.0))
            .quantize(&w, &h)
            .layer_error(&w, &h);
        assert!(e_l < 2.0 * e_a + 1e-6, "adaptive {} lambda {}", e_a, e_l);
    }

    #[test]
    fn handles_rank_deficient_h() {
        // fc2-style degenerate Gram (Remark 3.1 scenario)
        let mut rng = Rng::new(56);
        let w = Mat::from_vec(8, 16, rng.normal_vec_f32(128));
        let x = Mat::from_vec(16, 4, rng.normal_vec_f32(64)); // rank 4
        let h = x.gram();
        let r = Ganq::new(4).quantize(&w, &h);
        assert!(r.w_hat.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn kmeans_init_also_beats_rtn_and_is_finite() {
        let mut rng = Rng::new(58);
        let (w, h) = problem(&mut rng, 16, 24, 64);
        let e_km = Ganq::with_init(3, Init::Kmeans)
            .quantize(&w, &h)
            .layer_error(&w, &h);
        let e_rtn = Rtn::new(3).quantize(&w, &h).layer_error(&w, &h);
        assert!(e_km.is_finite() && e_km < e_rtn, "{} vs {}", e_km, e_rtn);
    }

    #[test]
    fn identity_codebook_beats_rtn_reconstruction() {
        let mut rng = Rng::new(59);
        let vals = rng.normal_vec_f32(256);
        let (codes, t) = fit_codebook_identity(&vals, 4, 3);
        assert_eq!(codes.len(), 256);
        assert_eq!(t.len(), 16);
        let err: f64 = vals
            .iter()
            .zip(&codes)
            .map(|(&v, &c)| (v - t[c as usize]) as f64)
            .map(|d| d * d)
            .sum();
        let (rcodes, rt) = rtn::rtn_codebook_row(&vals, 4);
        let rerr: f64 = vals
            .iter()
            .zip(&rcodes)
            .map(|(&v, &c)| (v - rt[c as usize]) as f64)
            .map(|d| d * d)
            .sum();
        assert!(
            err <= rerr * 1.0001 + 1e-9,
            "identity fit {} !<= rtn {}",
            err,
            rerr
        );
    }

    #[test]
    fn single_threaded_equals_multithreaded() {
        let mut rng = Rng::new(57);
        let (w, h) = problem(&mut rng, 12, 20, 40);
        let hp = linalg::precondition(&h);
        let l = linalg::cholesky(&hp).unwrap();
        let (_, t0) = rtn::rtn_codebook(&w, 4);
        let c1 = sstep(&w, &l, &t0, 1);
        let c8 = sstep(&w, &l, &t0, 8);
        assert_eq!(c1, c8);
        let t1 = tstep(&w, &hp, &c1, &t0, 1);
        let t8 = tstep(&w, &hp, &c1, &t0, 8);
        assert!(prop::all_close(&t1.data, &t8.data, 1e-6, 1e-6));
    }
}
