//! OmniQuant-like baseline (Shao et al., 2024): *learnable* uniform
//! quantization parameters. The original learns clipping strengths
//! (gamma, beta) by gradient descent on block output error; at our scale an
//! exhaustive grid search over the same (gamma, beta) clipping space against
//! the diag-H-weighted layer error reproduces the method's behaviour
//! (better than RTN/GPTQ's fixed min/max, worse than non-uniform GANQ) —
//! see DESIGN.md substitution table.

use crate::tensor::Mat;
use crate::util::pool;

use super::{QuantResult, Quantizer, Storage};

#[derive(Debug, Clone)]
pub struct OmniQ {
    pub bits: u8,
    pub group: Option<usize>,
    pub n_grid: usize,
}

impl OmniQ {
    pub fn new(bits: u8) -> Self {
        OmniQ { bits, group: None, n_grid: 10 }
    }

    pub fn grouped(bits: u8, group: usize) -> Self {
        OmniQ { bits, group: Some(group), n_grid: 10 }
    }
}

/// Quantize one segment with clipped range [wmin*beta, wmax*gamma],
/// returning the dequantized values and the weighted squared error.
fn quant_clipped(
    seg: &[f32],
    diag: &[f32],
    bits: u8,
    gamma: f32,
    beta: f32,
    out: &mut [f32],
) -> f64 {
    let levels = ((1u32 << bits) - 1) as f32;
    let mut wmin = f32::INFINITY;
    let mut wmax = f32::NEG_INFINITY;
    for &v in seg {
        wmin = wmin.min(v);
        wmax = wmax.max(v);
    }
    let lo = wmin * beta;
    let hi = wmax * gamma;
    let scale = ((hi - lo) / levels).max(1e-12);
    let zero = (-lo / scale).round();
    let mut err = 0.0f64;
    for (k, (&v, o)) in seg.iter().zip(out.iter_mut()).enumerate() {
        let c = ((v / scale).round() + zero).clamp(0.0, levels);
        let deq = (c - zero) * scale;
        *o = deq;
        let d = (v - deq) as f64;
        err += diag[k] as f64 * d * d;
    }
    err
}

impl Quantizer for OmniQ {
    fn name(&self) -> String {
        match self.group {
            Some(g) => format!("omniq-g{}", g),
            None => "omniq".to_string(),
        }
    }

    fn quantize(&self, w: &Mat, h: &Mat) -> QuantResult {
        let (m, n) = (w.rows, w.cols);
        let g = self.group.unwrap_or(n).min(n);
        let diag: Vec<f32> = (0..n).map(|j| h[(j, j)].max(1e-12)).collect();
        let mut w_hat = Mat::zeros(m, n);
        let n_grid = self.n_grid;
        let bits = self.bits;
        let threads = pool::default_threads();
        let wref = w;
        pool::par_rows_mut(&mut w_hat.data, n, threads, |row0, chunk| {
            let mut tmp = vec![0.0f32; g];
            for (ri, orow) in chunk.chunks_mut(n).enumerate() {
                let i = row0 + ri;
                let row = wref.row(i);
                for (gi, seg) in row.chunks(g).enumerate() {
                    let dseg = &diag[gi * g..gi * g + seg.len()];
                    let mut best = f64::INFINITY;
                    // joint grid over symmetric clip strengths
                    for a in 0..n_grid {
                        let gamma = 1.0 - 0.06 * a as f32;
                        for b in 0..n_grid {
                            let beta = 1.0 - 0.06 * b as f32;
                            let e = quant_clipped(
                                seg,
                                dseg,
                                bits,
                                gamma,
                                beta,
                                &mut tmp[..seg.len()],
                            );
                            if e < best {
                                best = e;
                                orow[gi * g..gi * g + seg.len()]
                                    .copy_from_slice(&tmp[..seg.len()]);
                            }
                        }
                    }
                }
            }
        });
        let groups = n.div_ceil(g);
        let storage = Storage {
            code_bits: m * n * bits as usize,
            // scale + zero (+ two learned clip factors) per group
            meta_bits: m * groups * 4 * 16,
            sparse_bits: 0,
        };
        QuantResult {
            method: self.name(),
            bits,
            w_hat,
            lut: None,
            sparse: None,
            storage,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::Rtn;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn outlier_problem(rng: &mut Rng, m: usize, n: usize) -> (Mat, Mat) {
        let mut w = Mat::from_vec(m, n, rng.normal_vec_f32(m * n));
        // inject weight outliers that blow up the RTN range
        for i in 0..m {
            let j = rng.below(n as u64) as usize;
            w[(i, j)] = 12.0 * if rng.below(2) == 0 { 1.0 } else { -1.0 };
        }
        let x = Mat::from_vec(n, 2 * n, rng.normal_vec_f32(2 * n * n));
        (w, x.gram())
    }

    #[test]
    fn clipping_beats_rtn_with_outliers() {
        prop::check("omniq_beats_rtn", 81, 5, |rng, _| {
            let (w, h) = outlier_problem(rng, 12, 48);
            let e_o = OmniQ::new(3).quantize(&w, &h).layer_error(&w, &h);
            let e_r = Rtn::new(3).quantize(&w, &h).layer_error(&w, &h);
            crate::prop_assert!(e_o < e_r, "omniq {} !< rtn {}", e_o, e_r);
            Ok(())
        });
    }

    #[test]
    fn includes_identity_clip_so_never_worse_weighted() {
        // gamma=beta=1 is in the grid; on the *diag-weighted* proxy OmniQ
        // is by construction <= RTN per segment
        let mut rng = Rng::new(82);
        let (w, h) = outlier_problem(&mut rng, 8, 32);
        let o = OmniQ::new(4).quantize(&w, &h);
        let r = Rtn::new(4).quantize(&w, &h);
        let proxy = |wh: &Mat| -> f64 {
            let mut e = 0.0;
            for i in 0..w.rows {
                for j in 0..w.cols {
                    let d = (w[(i, j)] - wh[(i, j)]) as f64;
                    e += h[(j, j)] as f64 * d * d;
                }
            }
            e
        };
        assert!(proxy(&o.w_hat) <= proxy(&r.w_hat) + 1e-6);
    }

    #[test]
    fn grouped_runs() {
        let mut rng = Rng::new(83);
        let (w, h) = outlier_problem(&mut rng, 6, 64);
        let r = OmniQ::grouped(3, 16).quantize(&w, &h);
        assert!(r.w_hat.data.iter().all(|v| v.is_finite()));
        assert_eq!(r.storage.meta_bits, 6 * 4 * 4 * 16);
    }
}
