//! Post-training weight-only quantization library: GANQ (paper §3) plus
//! every baseline the evaluation compares against (RTN, GPTQ, AWQ,
//! OmniQuant-like, SqueezeLLM-like; each with optional g128 grouping and
//! outlier handling).
//!
//! Every method consumes the layer weight `W [m, n]` and the calibration
//! Gram matrix `H = X X^T [n, n]` and produces a [`QuantResult`]:
//! reconstructed weights (for perplexity evaluation through the shared
//! `nll_fp32_*` graph), an optional LUT-servable form (codes + per-channel
//! codebook, for the `*_lut*` serving graphs and the native LUT path), and
//! exact storage accounting (Table 1).
//!
//! On top of the per-width methods, `anyprec` nests a GANQ solution into
//! a single any-precision artifact: [`BitPlaneStore`] holds the 4-bit
//! codes as bit-planes with per-width codebooks, so one resident copy
//! serves 2/3/4-bit (`kernels::lut_gemm_planes_into` streams only the
//! top-`w` planes).

pub mod anyprec;
pub mod awq;
pub mod ganq;
pub mod gptq;
pub mod kernels;
pub mod lut;
pub mod omniq;
pub mod outlier;
pub mod rtn;
pub mod squeezellm;
pub mod stats;

use crate::sparse::Csr;
use crate::tensor::{linalg, Mat};
pub use anyprec::{BitPlaneStore, StorageReport};
pub use kernels::{LutScratch, PackedLut};
pub use lut::LutLayer;

/// Storage accounting in bits (paper Table 1 rows).
#[derive(Debug, Clone, Default)]
pub struct Storage {
    pub code_bits: usize,
    pub meta_bits: usize,
    pub sparse_bits: usize,
}

impl Storage {
    pub fn total_bits(&self) -> usize {
        self.code_bits + self.meta_bits + self.sparse_bits
    }

    pub fn total_bytes(&self) -> usize {
        self.total_bits().div_ceil(8)
    }

    pub fn ratio_vs_fp16(&self, m: usize, n: usize) -> f64 {
        self.total_bits() as f64 / (16.0 * (m * n) as f64)
    }
}

#[derive(Debug, Clone)]
pub struct QuantResult {
    pub method: String,
    pub bits: u8,
    /// Reconstructed dense weights (sparse outliers already added back):
    /// exactly what the layer computes at inference.
    pub w_hat: Mat,
    /// LUT-servable form (per-channel codebook methods only).
    pub lut: Option<LutLayer>,
    /// Outlier component (GANQ*/SqueezeLLM dense-and-sparse).
    pub sparse: Option<Csr>,
    pub storage: Storage,
}

impl QuantResult {
    /// Layer-wise objective value ||W X - W_hat X||_F^2 = tr(D H D^T).
    pub fn layer_error(&self, w: &Mat, h: &Mat) -> f64 {
        linalg::layer_error(w, &self.w_hat, h)
    }
}

/// A layer-wise PTQ method.
pub trait Quantizer: Send + Sync {
    fn name(&self) -> String;
    fn quantize(&self, w: &Mat, h: &Mat) -> QuantResult;
}

/// Method registry for the CLI and benches.
/// Names: rtn, rtn-g128, gptq, gptq-g128, awq-g128, omniq, omniq-g128,
/// squeezellm, ganq, ganq-star.
pub fn by_name(name: &str, bits: u8) -> Option<Box<dyn Quantizer>> {
    Some(match name {
        "rtn" => Box::new(rtn::Rtn::new(bits)),
        "rtn-g128" => Box::new(rtn::Rtn::grouped(bits, 128)),
        "gptq" => Box::new(gptq::Gptq::new(bits)),
        "gptq-g128" => Box::new(gptq::Gptq::grouped(bits, 128)),
        "awq-g128" => Box::new(awq::Awq::new(bits, 128)),
        "omniq" => Box::new(omniq::OmniQ::new(bits)),
        "omniq-g128" => Box::new(omniq::OmniQ::grouped(bits, 128)),
        "squeezellm" => Box::new(squeezellm::SqueezeLlm::new(bits)),
        "ganq" => Box::new(ganq::Ganq::new(bits)),
        "ganq-star" => Box::new(outlier::GanqStar::new(bits, 0.005, 0)),
        _ => return None,
    })
}

pub const BASIC_METHODS: [&str; 4] = ["rtn", "gptq", "omniq", "ganq"];
pub const OUTLIER_METHODS: [&str; 6] = [
    "rtn-g128",
    "gptq-g128",
    "awq-g128",
    "omniq-g128",
    "squeezellm",
    "ganq-star",
];

/// Shared helper: uniform asymmetric quantization of one row-segment.
/// Returns (codes, scale, zero) with code = clamp(round(w/scale)+zero).
pub fn uniform_quant_segment(seg: &[f32], bits: u8) -> (Vec<u8>, f32, f32) {
    let levels = ((1u32 << bits) - 1) as f32;
    let mut wmin = f32::INFINITY;
    let mut wmax = f32::NEG_INFINITY;
    for &v in seg {
        wmin = wmin.min(v);
        wmax = wmax.max(v);
    }
    if !wmin.is_finite() || !wmax.is_finite() {
        return (vec![0; seg.len()], 1.0, 0.0);
    }
    let scale = ((wmax - wmin) / levels).max(1e-12);
    let zero = (-wmin / scale).round();
    let codes = seg
        .iter()
        .map(|&v| ((v / scale).round() + zero).clamp(0.0, levels) as u8)
        .collect();
    (codes, scale, zero)
}

pub fn dequant_code(code: u8, scale: f32, zero: f32) -> f32 {
    (code as f32 - zero) * scale
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn registry_covers_all_methods() {
        for name in BASIC_METHODS.iter().chain(OUTLIER_METHODS.iter()) {
            assert!(by_name(name, 4).is_some(), "{}", name);
            assert!(by_name(name, 3).is_some(), "{}", name);
        }
        assert!(by_name("nope", 4).is_none());
    }

    #[test]
    fn uniform_segment_roundtrip_accuracy() {
        let mut rng = Rng::new(1);
        let seg = rng.normal_vec_f32(64);
        let (codes, scale, zero) = uniform_quant_segment(&seg, 8);
        let maxerr = seg
            .iter()
            .zip(&codes)
            .map(|(&v, &c)| (v - dequant_code(c, scale, zero)).abs())
            .fold(0.0f32, f32::max);
        assert!(maxerr <= scale * 0.5 + 1e-6, "{} vs {}", maxerr, scale);
    }

    #[test]
    fn uniform_segment_range_endpoints() {
        let seg = vec![-1.0f32, 0.0, 2.0];
        let (codes, scale, zero) = uniform_quant_segment(&seg, 4);
        assert_eq!(dequant_code(codes[0], scale, zero), -1.0);
        assert!((dequant_code(codes[2], scale, zero) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn storage_ratio_table1_shape() {
        // LUT-based 4-bit at m=n=4096 should be ~25.39% of FP16 (Table 1)
        let m = 4096;
        let n = 4096;
        let st = Storage {
            code_bits: m * n * 4,
            meta_bits: m * 16 * 16,
            sparse_bits: 0,
        };
        let r = st.ratio_vs_fp16(m, n);
        assert!((r - 0.2539).abs() < 0.001, "{}", r);
    }
}
