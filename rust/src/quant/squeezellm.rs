//! SqueezeLLM-like baseline (Kim et al., 2024): sensitivity-weighted
//! k-means codebooks (dense) + optional sparse outlier extraction
//! (dense-and-sparse decomposition). Sensitivity weights use diag(H) as
//! the Fisher-information proxy, exactly as the paper approximates the
//! Hessian by the diagonal Fisher.

use crate::sparse::Csr;
use crate::tensor::Mat;
use crate::util::pool;

use super::{
    lut::lut_from_parts, outlier::split_outliers, QuantResult, Quantizer,
};

#[derive(Debug, Clone)]
pub struct SqueezeLlm {
    pub bits: u8,
    /// outlier extraction ratio (paper default 0.45-0.5%); 0 disables
    pub outlier_ratio: f64,
    pub kmeans_iters: usize,
}

impl SqueezeLlm {
    pub fn new(bits: u8) -> Self {
        SqueezeLlm { bits, outlier_ratio: 0.005, kmeans_iters: 25 }
    }

    pub fn dense_only(bits: u8) -> Self {
        SqueezeLlm { bits, outlier_ratio: 0.0, kmeans_iters: 25 }
    }
}

/// Weighted 1-D k-means (Lloyd) for one row. Returns (codes, centroids).
/// Init: weighted quantiles (stable and deterministic).
pub fn weighted_kmeans_row(
    vals: &[f32],
    weights: &[f32],
    k: usize,
    iters: usize,
) -> (Vec<u8>, Vec<f32>) {
    let n = vals.len();
    // init centroids at weighted quantiles
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| vals[a].partial_cmp(&vals[b]).unwrap());
    let total_w: f64 = weights.iter().map(|&w| w.max(1e-12) as f64).sum();
    let mut centroids = vec![0.0f32; k];
    {
        let mut acc = 0.0f64;
        let mut ci = 0usize;
        for &idx in &order {
            acc += weights[idx].max(1e-12) as f64;
            while ci < k && acc >= total_w * (ci as f64 + 0.5) / k as f64 {
                centroids[ci] = vals[idx];
                ci += 1;
            }
        }
        while ci < k {
            centroids[ci] = vals[order[n - 1]];
            ci += 1;
        }
    }
    let mut codes = vec![0u8; n];
    for _ in 0..iters {
        // assign
        for (j, &v) in vals.iter().enumerate() {
            let mut best = 0usize;
            let mut bestd = f32::INFINITY;
            for (s, &c) in centroids.iter().enumerate() {
                let d = (v - c).abs();
                if d < bestd {
                    bestd = d;
                    best = s;
                }
            }
            codes[j] = best as u8;
        }
        // update (weighted means)
        let mut sums = vec![0.0f64; k];
        let mut wsum = vec![0.0f64; k];
        for (j, &c) in codes.iter().enumerate() {
            let w = weights[j].max(1e-12) as f64;
            sums[c as usize] += w * vals[j] as f64;
            wsum[c as usize] += w;
        }
        let mut changed = false;
        for s in 0..k {
            if wsum[s] > 0.0 {
                let nc = (sums[s] / wsum[s]) as f32;
                if (nc - centroids[s]).abs() > 1e-9 {
                    changed = true;
                }
                centroids[s] = nc;
            }
        }
        if !changed {
            break;
        }
    }
    // final assign for consistency
    for (j, &v) in vals.iter().enumerate() {
        let mut best = 0usize;
        let mut bestd = f32::INFINITY;
        for (s, &c) in centroids.iter().enumerate() {
            let d = (v - c).abs();
            if d < bestd {
                bestd = d;
                best = s;
            }
        }
        codes[j] = best as u8;
    }
    (codes, centroids)
}

impl Quantizer for SqueezeLlm {
    fn name(&self) -> String {
        "squeezellm".to_string()
    }

    fn quantize(&self, w: &Mat, h: &Mat) -> QuantResult {
        let (m, n) = (w.rows, w.cols);
        let k = 1usize << self.bits;
        let (sparse, dense) = if self.outlier_ratio > 0.0 {
            let (s, d) = split_outliers(w, self.outlier_ratio);
            (Some(Csr::from_dense(&s)), d)
        } else {
            (None, w.clone())
        };
        let weights: Vec<f32> = (0..n).map(|j| h[(j, j)].max(1e-12)).collect();
        let mut codes = vec![0u8; m * n];
        let mut codebook = Mat::zeros(m, k);
        let iters = self.kmeans_iters;
        let threads = pool::default_threads();
        // parallel across rows: each worker owns the same row range of
        // both outputs (codes stride n, codebook stride k)
        let dense_ref = &dense;
        let weights_ref = &weights;
        pool::par_rows_mut2(
            &mut codes,
            n,
            &mut codebook.data,
            k,
            threads,
            |row0, crows, cbrows| {
                let rows = crows.chunks_mut(n).zip(cbrows.chunks_mut(k));
                for (ri, (crow, cbrow)) in rows.enumerate() {
                    let (c, cent) = weighted_kmeans_row(
                        dense_ref.row(row0 + ri),
                        weights_ref,
                        k,
                        iters,
                    );
                    crow.copy_from_slice(&c);
                    cbrow.copy_from_slice(&cent);
                }
            },
        );
        let lut = lut_from_parts(m, n, self.bits, codes, codebook);
        let mut w_hat = lut.dequant();
        let mut storage = lut.storage();
        if let Some(sp) = &sparse {
            w_hat.add_assign(&sp.to_dense());
            storage.sparse_bits = sp.nnz() * (16 + 32) + (m + 1) * 32;
        }
        QuantResult {
            method: self.name(),
            bits: self.bits,
            w_hat,
            lut: Some(lut),
            sparse,
            storage,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::Rtn;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn problem(rng: &mut Rng, m: usize, n: usize) -> (Mat, Mat) {
        let w = Mat::from_vec(m, n, rng.normal_vec_f32(m * n));
        let x = Mat::from_vec(n, 2 * n, rng.normal_vec_f32(2 * n * n));
        (w, x.gram())
    }

    #[test]
    fn kmeans_reduces_weighted_distortion_vs_uniform_grid() {
        prop::check("kmeans_vs_grid", 91, 6, |rng, _| {
            let vals = rng.normal_vec_f32(128);
            let weights = vec![1.0f32; 128];
            let (codes, cents) = weighted_kmeans_row(&vals, &weights, 8, 30);
            let e_km: f64 = vals
                .iter()
                .zip(&codes)
                .map(|(&v, &c)| {
                    let d = (v - cents[c as usize]) as f64;
                    d * d
                })
                .sum();
            let (gcodes, grid) =
                crate::quant::rtn::rtn_codebook_row(&vals, 3);
            let e_grid: f64 = vals
                .iter()
                .zip(&gcodes)
                .map(|(&v, &c)| {
                    let d = (v - grid[c as usize]) as f64;
                    d * d
                })
                .sum();
            crate::prop_assert!(
                e_km < e_grid,
                "kmeans {} !< grid {}",
                e_km,
                e_grid
            );
            Ok(())
        });
    }

    #[test]
    fn kmeans_handles_constant_input() {
        let vals = vec![0.7f32; 32];
        let weights = vec![1.0f32; 32];
        let (codes, cents) = weighted_kmeans_row(&vals, &weights, 4, 10);
        assert!(codes.iter().all(|&c| (c as usize) < 4));
        assert!((cents[codes[0] as usize] - 0.7).abs() < 1e-6);
    }

    #[test]
    fn dense_only_beats_rtn() {
        let mut rng = Rng::new(92);
        let (w, h) = problem(&mut rng, 12, 48);
        let e_s = SqueezeLlm::dense_only(3)
            .quantize(&w, &h)
            .layer_error(&w, &h);
        let e_r = Rtn::new(3).quantize(&w, &h).layer_error(&w, &h);
        assert!(e_s < e_r, "squeezellm {} !< rtn {}", e_s, e_r);
    }

    #[test]
    fn outlier_split_reduces_error_further() {
        let mut rng = Rng::new(93);
        let (mut w, h) = problem(&mut rng, 12, 64);
        for i in 0..12 {
            let j = rng.below(64) as usize;
            w[(i, j)] = 15.0;
        }
        let e_dense = SqueezeLlm::dense_only(3)
            .quantize(&w, &h)
            .layer_error(&w, &h);
        let e_star = SqueezeLlm { bits: 3, outlier_ratio: 0.02, kmeans_iters: 25 }
            .quantize(&w, &h)
            .layer_error(&w, &h);
        assert!(e_star < e_dense, "{} vs {}", e_star, e_dense);
    }

    #[test]
    fn sparse_plus_lut_reconstructs_w_hat() {
        let mut rng = Rng::new(94);
        let (w, h) = problem(&mut rng, 8, 32);
        let r = SqueezeLlm::new(4).quantize(&w, &h);
        let mut recon = r.lut.as_ref().unwrap().dequant();
        if let Some(sp) = &r.sparse {
            recon.add_assign(&sp.to_dense());
        }
        assert!(prop::all_close(&recon.data, &r.w_hat.data, 1e-6, 1e-6));
    }
}
